"""Fault-tolerant npz-shard checkpointing with elastic reshard-on-load.

Design (mirrors what a real multi-pod deployment needs, minus GCS):

* **Atomicity** — write to ``step_N.tmp-<nonce>/`` then ``os.rename`` to
  ``step_N/``; a crash mid-save never corrupts the latest checkpoint, and
  ``latest_step`` only ever sees complete directories.
* **Sharding** — each host saves only the addressable shards of its
  jax.Arrays (here: one host). Leaves are stored in one npz per save-shard
  with a JSON manifest (pytree structure, shapes, dtypes, shardings).
* **Elastic reshard** — ``load_checkpoint`` takes the *target* shardings;
  arrays are re-laid-out with ``jax.device_put`` on load, so a checkpoint
  from an N-chip run restores onto an M-chip mesh (elastic scaling /
  shrink-on-failure restarts).
* **Async** — ``AsyncCheckpointer`` snapshots to host memory on-thread,
  serializes + renames on a background thread; training never blocks on
  disk. ``wait()`` joins at shutdown.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import uuid
from typing import Any

import jax
import numpy as np

from repro.testing import faults

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten_with_names(tree: PyTree) -> tuple[list[tuple[str, Any]], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path), leaf) for path, leaf in flat]
    return named, treedef


def save_checkpoint(directory: str, step: int, tree: PyTree) -> str:
    """Atomic synchronous save. Returns the final checkpoint path.

    Crash discipline (each ``faults.trip`` marks a window a real process
    can die in; the fault suite kills the save there and asserts the
    latest *complete* checkpoint still loads):

    1. all payload is written under ``step_N.tmp-<nonce>/`` and fsynced
       (file contents first, then the tmp dir entry) — a crash here
       leaves only a tmp dir, which ``latest_step`` never matches;
    2. an existing ``step_N/`` is moved ASIDE (rename, not rmtree!) —
       the old code deleted it before publishing the replacement, so a
       crash in between lost BOTH copies of step N;
    3. one atomic ``os.rename(tmp, final)`` publishes, then the parent
       directory entry is fsynced so the publish survives power loss;
    4. only after publishing are the old copy and stale tmp dirs
       removed.
    """
    named, _ = _flatten_with_names(tree)
    os.makedirs(directory, exist_ok=True)
    nonce = uuid.uuid4().hex[:8]
    tmp = os.path.join(directory, f"step_{step}.tmp-{nonce}")
    os.makedirs(tmp)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for name, leaf in named:
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{len(arrays)}"
        raw = arr.dtype.kind not in "biufc"     # bf16/fp8: npz can't cast
        arrays[key] = (np.frombuffer(arr.tobytes(), np.uint8) if raw
                       else arr)
        manifest["leaves"].append({"name": name, "key": key,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype),
                                   "raw": raw})
    shard = os.path.join(tmp, "shard_0.npz")
    np.savez(shard, **arrays)
    faults.trip("checkpoint.mid_write")
    mani = os.path.join(tmp, "manifest.json")
    with open(mani, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_file(shard)
    _fsync_dir(tmp)
    faults.trip("checkpoint.after_write")
    final = os.path.join(directory, f"step_{step}")
    aside = None
    if os.path.exists(final):
        aside = os.path.join(directory, f"step_{step}.tmp-old-{nonce}")
        os.rename(final, aside)
        faults.trip("checkpoint.between_renames")
    os.rename(tmp, final)
    _fsync_dir(directory)
    faults.trip("checkpoint.after_publish")
    if aside is not None:
        shutil.rmtree(aside, ignore_errors=True)
    # Drop stale tmp dirs from crashed saves (ours are gone already).
    for d in os.listdir(directory):
        if ".tmp-" in d:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, target: PyTree) -> PyTree:
    """Restore into the structure/shardings of ``target``.

    ``target`` supplies the pytree structure and (optionally) shardings —
    either concrete arrays or ShapeDtypeStructs with ``.sharding``.  Loaded
    arrays are device_put to the target sharding: this is the elastic
    reshard path (checkpoint written on N devices, loaded onto M).
    """
    import ml_dtypes  # bundled with jax; needed to revive bf16/fp8 leaves

    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_0.npz"))
    by_name = {}
    for leaf in manifest["leaves"]:
        arr = data[leaf["key"]]
        if leaf.get("raw"):
            dt = np.dtype(getattr(ml_dtypes, leaf["dtype"]))
            arr = arr.view(dt).reshape(leaf["shape"])
        by_name[leaf["name"]] = arr

    named, treedef = _flatten_with_names(target)
    leaves = []
    for name, tgt in named:
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        arr = by_name[name]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {tgt.shape}")
        sharding = getattr(tgt, "sharding", None)
        if sharding is not None and not isinstance(
                sharding, jax.sharding.SingleDeviceSharding):
            leaves.append(jax.device_put(arr.astype(tgt.dtype), sharding))
        else:
            leaves.append(jax.numpy.asarray(arr.astype(tgt.dtype)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Background-thread checkpointer: snapshot on-call, IO off-thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue()
        self._errors: list[BaseException] = []
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, host_tree = item
            try:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()
            except BaseException as e:      # surfaced on wait()
                self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(int(m.group(1)) for d in os.listdir(self.directory)
                       if (m := _STEP_RE.match(d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, tree: PyTree) -> None:
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host))

    def wait(self) -> None:
        self._q.join()
        if self._errors:
            raise self._errors[0]

    def close(self) -> None:
        self.wait()
        self._q.put(None)
        self._thread.join()
