from repro.checkpoint.npz_store import (save_checkpoint, load_checkpoint,
                                        latest_step, AsyncCheckpointer)
