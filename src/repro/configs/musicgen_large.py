"""musicgen-large [audio] — decoder-only transformer over EnCodec tokens
(arXiv:2306.05284). The EnCodec frontend is a STUB: ``input_specs`` feeds
precomputed frame embeddings for the conditioning prefix.

48L d_model=2048 32H (MHA: kv=32) d_ff=8192 vocab=2048.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    act="gelu",
    qk_norm=False,
    rope_theta=10_000.0,
    frontend="embeddings",
    frontend_len=256,            # text/melody conditioning prefix (stub)
    dtype="bfloat16",
)
