"""pixtral-12b [vlm] — mistral-nemo decoder backbone; the pixtral-ViT
frontend is a STUB supplying precomputed patch embeddings
(hf:mistralai/Pixtral-12B-2409).

40L d_model=5120 32H (GQA kv=8, head_dim=128) d_ff=14336 vocab=131072.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    act="swiglu",
    rope_theta=1_000_000.0,
    frontend="embeddings",
    frontend_len=1024,           # image patch tokens (stub)
    dtype="bfloat16",
)
