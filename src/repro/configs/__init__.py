"""Architecture registry: one module per assigned arch (+ the paper's own
KPCA workload).  ``get_config(name)`` returns the full ArchConfig;
``get_config(name, smoke=True)`` the reduced same-family smoke variant.
"""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "musicgen_large",
    "pixtral_12b",
    "xlstm_125m",
    "jamba_1_5_large_398b",
    "qwen3_32b",
    "stablelm_12b",
    "command_r_plus_104b",
    "minicpm_2b",
    "kimi_k2_1t_a32b",
    "dbrx_132b",
]

_ALIASES = {name.replace("_", "-"): name for name in ARCH_IDS}

# (arch × shape) assignment: every arch gets the 4 LM shapes; long_500k is
# assigned only to sub-quadratic-decode families (SSM/hybrid).  Dense archs
# can still *lower* long_500k with attention="nystrom" — tracked separately
# as a beyond-paper extra (EXPERIMENTS.md §Dry-run).
SHAPES = {
    "train_4k":    {"kind": "train",  "seq_len": 4096,    "global_batch": 256},
    "prefill_32k": {"kind": "train",  "seq_len": 32768,   "global_batch": 32},
    "decode_32k":  {"kind": "decode", "seq_len": 32768,   "global_batch": 128},
    "long_500k":   {"kind": "decode", "seq_len": 524288,  "global_batch": 1},
}

LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg: ArchConfig = mod.CONFIG
    return cfg.smoke() if smoke else cfg


def cells(include_skipped: bool = False):
    """All assigned (arch, shape) dry-run cells, with skip annotations."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape, spec in SHAPES.items():
            skip = (shape == "long_500k"
                    and cfg.family not in LONG_CONTEXT_FAMILIES
                    and cfg.attention != "nystrom")
            if skip and not include_skipped:
                continue
            out.append((arch, shape, spec, skip))
    return out
