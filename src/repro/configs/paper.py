"""The paper's own workload configurations (incremental KPCA / Nyström).

These drive the reproduction benchmarks (Fig. 1 drift, Fig. 2 Nyström
error) and the distributed streaming-KPCA dry-run.
"""
from dataclasses import dataclass


@dataclass(frozen=True)
class KPCAWorkload:
    name: str
    dataset: str          # 'magic' | 'yeast'
    n_seed: int = 20      # paper: matrices of size 20+m
    n_stream: int = 480   # streamed points after the seed
    n_total: int = 1000   # Nyström: first 1000 observations (paper §5.2)
    capacity: int = 512
    adjusted: bool = True
    dtype: str = "float64"   # paper uses NumPy f64; f32 variant benchmarked


MAGIC = KPCAWorkload(name="paper-magic", dataset="magic")
YEAST = KPCAWorkload(name="paper-yeast", dataset="yeast")

WORKLOADS = {"magic": MAGIC, "yeast": YEAST}
