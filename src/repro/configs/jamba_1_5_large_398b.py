"""jamba-1.5-large-398b [hybrid] — Mamba + attention at 1:7 interleave with
MoE every other layer (arXiv:2403.19887).  Mamba decode state is O(1) and
the single attention layer per period uses a KV cache, so long_500k runs.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2.
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    # one attention layer per 8 (position 4 of the Jamba block), rest Mamba
    block_pattern=("mamba", "mamba", "mamba", "attn",
                   "mamba", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    moe_every=2,
    moe_offset=1,                # MoE on odd layers, dense FFN on even
    ssm_d_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=256,
    dtype="bfloat16",
)
