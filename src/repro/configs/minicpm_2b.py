"""minicpm-2b [dense] — llama-like with depth-scaled residuals and the WSD
(warmup–stable–decay) schedule (arXiv:2404.06395); the launcher selects
``schedule='wsd'`` for this arch.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753.
"""
from repro.models.config import ArchConfig

_SCALE_DEPTH = 1.4

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    residual_scale=_SCALE_DEPTH / (40 ** 0.5),   # scale_depth/sqrt(L)
    act="swiglu",
    dtype="bfloat16",
)

SCHEDULE = "wsd"
