"""stablelm-12b [dense] — partial rotary embeddings (fraction 0.25), GQA
(hf:stabilityai/stablelm-2-12b lineage).

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab=100352,
    rope_fraction=0.25,
    act="swiglu",
    dtype="bfloat16",
)
