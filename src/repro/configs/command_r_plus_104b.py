"""command-r-plus-104b [dense] — parallel attention∥FFN blocks, no biases,
tied embeddings (hf:CohereForAI/c4ai-command-r-plus lineage).

64L d_model=12288 96H (GQA kv=8, head_dim=128) d_ff=33792 vocab=256000.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab=256000,
    parallel_block=True,
    tie_embeddings=True,
    act="swiglu",
    rope_theta=75_000_000.0,
    dtype="bfloat16",
)
