"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1 shared
expert, fine-grained experts (d_ff_expert=2048)  [arXiv:2501.kimi2,
paper-table].  Optimizer plan: Adafactor (factored 2nd moment), bf16
params — see DESIGN.md §5 memory plan.

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384e top-8.
"""
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, capacity_factor=1.25),
    qk_norm=True,
    act="swiglu",
    dtype="bfloat16",
)

OPTIMIZER = "adafactor"
