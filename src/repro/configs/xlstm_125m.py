"""xlstm-125m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517), ratio ~5:1
mLSTM:sLSTM.  Blocks carry their own up/down projections (d_ff=0: no
separate FFN).  Recurrent decode state is O(1) in context length, so the
long_500k cell runs for this arch.

12L d_model=768 4H d_ff=0 vocab=50304.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                      # mLSTM/sLSTM blocks are self-contained
    vocab=50304,
    block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    xlstm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
    dtype="bfloat16",
)
