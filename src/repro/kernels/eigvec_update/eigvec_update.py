"""Fused Cauchy-factor eigenvector rotation — the paper's O(m^3) hot spot.

Computes  C = U @ (W * inv[None, :])  where  W[k, j] = zhat[k] / (d[k] - lam[j])
without ever materializing W in HBM: each (BK, BJ) tile of W is generated in
VMEM from three O(M) vectors immediately before the MXU dot-accumulate.

Roofline motivation (TPU v5e, bf16/f32): the naive two-step
(materialize W, then matmul) moves 3·M^2 reads + 2·M^2 writes of HBM traffic;
the fused kernel moves M^2 reads (U) + M^2 writes (C) — a ~2.5× cut on the
memory term, and the VPU divide pipeline overlaps the MXU dot.

Rectangular operands: ``u`` may be a row *block* (R, M) of the full
eigenvector matrix with R != M — the shape the row-sharded distributed
path hands each device ((M/P, M) per mesh slice).  ``row_offset`` carries
the block's first global row index so active-tile pruning works along the
row axis too (see below); R == M with row_offset 0 recovers the original
square kernel exactly.

Active-tile pruning: the incremental-KPCA state is fixed-capacity (M) with
an *active count* m; beyond the active prefix, U is identity, zhat/inv are
zero, and the consumer overwrites the columns anyway.  The grid therefore
prefetches TWO scalar tile counts,

    g_cols = ceil(m / B)                       (column/reduction axes)
    g_rows = ceil(clamp(m - row_offset, 0, R) / B)   (row axis)

and skips every (i, j, k) tile with i >= g_rows or a column/reduction
coordinate >= g_cols: MXU work drops from ceil(R/B)·ceil(M/B)^2 to
ceil(m_rows/B)·ceil(m/B)^2 tiles per update — the flop count the paper's
~8m^3 claim assumes, now preserved at any sharding factor P.  Pruned
output tiles are written as zeros (their true value: rows past m of active
columns are exactly 0 because z is masked beyond the active prefix;
inactive columns are replaced by the caller's own identity columns
downstream).  The original-domain un-flip in ``rankone._solve_factor``
folds the sigma<0 flip's sign into z, so the active region is a prefix —
and this pruning valid — for BOTH sigma signs.

``eigvec_rotate2`` additionally fuses the paper's back-to-back ±sigma
rotations of eq. (2)/(3): C = U @ W1n @ W2n in one pass over U (both W
tiles generated in VMEM), halving HBM round-trips of U per streamed point.
Deflated columns are generated in-kernel as identity columns e_{cid[j]}
(cid carries the inter-update sort permutation), so no intermediate U1 is
ever needed.  The grid walks (i, k) U-tiles with the row axis bounded by
g_rows and every column loop bounded by g_cols, so the fused kernel is
also fully m-pruned at any block shape — g_rows·g_cols² MXU tiles per
factor and only the active rows × active columns of U fetched.

Tiling: (BI, BJ) output tiles, reduction over K in the innermost grid axis;
MXU-aligned 128×128×128 blocks by default.  Vectors are carried as (M, 1) /
(1, M) so no in-kernel transposes are needed (lane/sublane friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128
NPROJ = 8    # projected column count of ``eigvec_project`` (v padded to 8)


def _tile_counts(num_active, row_offset, R: int, M: int, block: int,
                 steps_r: int, steps_c: int) -> jax.Array:
    """(2,) int32 scalar-prefetch vector [g_rows, g_cols].

    g_cols bounds the column AND reduction axes (both indexed by the
    factor's active prefix m); g_rows bounds the row axis of the (R, M)
    block whose first global row is ``row_offset``.
    """
    if num_active is None:
        return jnp.asarray([steps_r, steps_c], jnp.int32)
    na = jnp.asarray(num_active, jnp.int32)
    g_cols = jnp.minimum(-(-na // block), steps_c)
    r0 = (jnp.zeros((), jnp.int32) if row_offset is None
          else jnp.asarray(row_offset, jnp.int32))
    rows_active = jnp.clip(na - r0, 0, R)
    g_rows = jnp.minimum(-(-rows_active // block), steps_r)
    return jnp.stack([g_rows, g_cols]).astype(jnp.int32)


def _clamp(t, lim):
    # Redirect pruned-tile block loads to tile 0: the iteration is skipped
    # anyway, so don't spend HBM bandwidth on its operands.
    return jnp.minimum(t, jnp.maximum(lim - 1, 0))


def _kernel(g_ref, u_ref, z_ref, d_ref, lam_ref, inv_ref, out_ref, acc_ref,
            *, k_steps: int):
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    gr, gc = g_ref[0], g_ref[1]
    active = (i < gr) & (j < gc)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(active & (k < gc))
    def _acc():
        # Generate the W tile in VMEM: (BK, 1) vectors against (1, BJ).
        zcol = z_ref[...]            # (BK, 1)
        dcol = d_ref[...]            # (BK, 1)
        lamrow = lam_ref[...]        # (1, BJ)
        w = zcol / (dcol - lamrow)   # (BK, BJ) — Cauchy tile, never hits HBM
        acc_ref[...] += jnp.dot(u_ref[...], w,
                                preferred_element_type=acc_ref.dtype)

    @pl.when(k == k_steps - 1)
    def _done():
        # Pruned tiles were never accumulated: acc is still zero there, the
        # correct value for rows/columns beyond the active prefix.
        out_ref[...] = (acc_ref[...] * inv_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eigvec_rotate(u: jax.Array, zhat: jax.Array, d: jax.Array,
                  lam: jax.Array, inv: jax.Array,
                  num_active: jax.Array | None = None,
                  row_offset: jax.Array | None = None, *,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = False) -> jax.Array:
    """C[i, j] = sum_k U[i,k] * zhat[k]/(d[k]-lam[j]) * inv[j].

    u: (R, M) — a row block of the eigenvector matrix (R == M for the
    single-device square case); zhat, d, lam, inv: (M,).  Both dims are
    padded internally to a multiple of ``block``; padded columns use
    lam=1e30 / d=2e30 so generated W entries are exactly 0 (no NaNs enter
    the accumulator).

    ``num_active`` (traced scalar, optional): active count m.  Column and
    reduction tiles beyond ceil(m/block) are skipped; row tiles beyond
    ceil(clamp(m - row_offset, 0, R)/block) likewise (``row_offset`` is
    the block's first global row, default 0).  Pruned output is written
    as zero — callers must treat columns >= m as garbage-to-overwrite
    (rankone does) while pruned *rows* of active columns are exactly 0 by
    the padding contract, so zeros there are the true values.
    """
    R, M = u.shape
    Rp = -(-R // block) * block
    Mp = -(-M // block) * block
    pad_r, pad_c = Rp - R, Mp - M
    dtype = u.dtype
    if pad_r or pad_c:
        u = jnp.pad(u, ((0, pad_r), (0, pad_c)))
    if pad_c:
        zhat = jnp.pad(zhat, (0, pad_c))
        d = jnp.pad(d, (0, pad_c), constant_values=2e30)
        lam = jnp.pad(lam, (0, pad_c), constant_values=1e30)
        inv = jnp.pad(inv, (0, pad_c))
    zcol = zhat.reshape(Mp, 1).astype(dtype)
    dcol = d.reshape(Mp, 1).astype(dtype)
    lamrow = lam.reshape(1, Mp).astype(dtype)
    invrow = inv.reshape(1, Mp).astype(dtype)

    steps_r = Rp // block
    steps = Mp // block
    g = _tile_counts(num_active, row_offset, R, M, block, steps_r, steps)
    # Accumulate in f32 for <=32-bit operands, f64 for f64 states (the
    # precise/x64 numerics tier needs the rotation itself at 1e-12).
    acc_dtype = jnp.promote_types(dtype, jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps_r, steps, steps),
        in_specs=[
            pl.BlockSpec((block, block),
                         lambda i, j, k, g: (_clamp(i, g[0]),
                                             _clamp(k, g[1]))),
            pl.BlockSpec((block, 1), lambda i, j, k, g: (_clamp(k, g[1]), 0)),
            pl.BlockSpec((block, 1), lambda i, j, k, g: (_clamp(k, g[1]), 0)),
            pl.BlockSpec((1, block), lambda i, j, k, g: (0, _clamp(j, g[1]))),
            pl.BlockSpec((1, block), lambda i, j, k, g: (0, _clamp(j, g[1]))),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k, g: (i, j)),
        scratch_shapes=[pltpu.VMEM((block, block), acc_dtype)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, Mp), dtype),
        interpret=interpret,
    )(g, u, zcol, dcol, lamrow, invrow)
    return out[:R, :M]


def _proj_kernel(g_ref, u_ref, v_ref, out_ref, acc_ref, *, r_steps: int,
                 block: int):
    """P-tile accumulate for ``eigvec_project``: out[j] = Σ_i Uᵀ[j,i] V[i]."""
    j, i = pl.program_id(0), pl.program_id(1)
    gr, gc = g_ref[0], g_ref[1]
    m, r0 = g_ref[2], g_ref[3]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i < gr) & (j < gc))
    def _acc():
        rows = (r0 + i * block
                + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0))
        v = jnp.where(rows < m, v_ref[...].astype(acc_ref.dtype), 0.0)
        acc_ref[...] += jax.lax.dot_general(
            u_ref[...].astype(acc_ref.dtype), v, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_ref.dtype)

    @pl.when(i == r_steps - 1)
    def _done():
        # Pruned (j >= gc) output tiles were never accumulated: zero is
        # their true value — inactive U columns are identity columns whose
        # single 1 sits on a masked row (>= m) of V.
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eigvec_project(u: jax.Array, v: jax.Array,
                   num_active: jax.Array | None = None,
                   row_offset: jax.Array | None = None, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool = False) -> jax.Array:
    """P = Uᵀ V with the row mask and active-tile pruning of the rotation
    kernels: the post-rotation projection pass of Algorithm 2's second
    ±sigma pair (and any other Uᵀv the caller owes in the CURRENT basis).

    u: (R, M) eigenvector row block (first global row ``row_offset``);
    v: (R, C) columns to project, C <= NPROJ; rows >= ``num_active``
    (global index) are masked to zero in-kernel, so the caller may pass
    unmasked vectors.  Returns (M, C).  Reduction (row) tiles stop at
    ceil(clamp(m - row_offset, 0, R)/block) and output (column-of-U) tiles
    at ceil(m/block); pruned output rows are exact zeros — the true value,
    because inactive U columns are identity columns supported on masked
    rows.  Row-sharded callers psum the (M, C) partials over shards.
    """
    R, M = u.shape
    C = v.shape[1]
    if C > NPROJ:
        raise ValueError(f"eigvec_project supports <= {NPROJ} columns, "
                         f"got {C}")
    Rp = -(-R // block) * block
    Mp = -(-M // block) * block
    pad_r, pad_c = Rp - R, Mp - M
    dtype = u.dtype
    if pad_r or pad_c:
        u = jnp.pad(u, ((0, pad_r), (0, pad_c)))
    if pad_r or C < NPROJ:
        v = jnp.pad(v, ((0, pad_r), (0, NPROJ - C)))
    v = v.astype(dtype)

    steps_r = Rp // block
    steps_c = Mp // block
    g2 = _tile_counts(num_active, row_offset, R, M, block, steps_r, steps_c)
    m_eff = (jnp.asarray(M, jnp.int32) if num_active is None
             else jnp.asarray(num_active, jnp.int32))
    r0 = (jnp.zeros((), jnp.int32) if row_offset is None
          else jnp.asarray(row_offset, jnp.int32))
    g = jnp.concatenate([g2, m_eff[None], r0[None]]).astype(jnp.int32)
    acc_dtype = jnp.promote_types(dtype, jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps_c, steps_r),
        in_specs=[
            pl.BlockSpec((block, block),
                         lambda j, i, g: (_clamp(i, g[0]), _clamp(j, g[1]))),
            pl.BlockSpec((block, NPROJ),
                         lambda j, i, g: (_clamp(i, g[0]), 0)),
        ],
        out_specs=pl.BlockSpec((block, NPROJ), lambda j, i, g: (j, 0)),
        scratch_shapes=[pltpu.VMEM((block, NPROJ), acc_dtype)],
    )
    out = pl.pallas_call(
        functools.partial(_proj_kernel, r_steps=steps_r, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Mp, NPROJ), dtype),
        interpret=interpret,
    )(g, u, v)
    return out[:M, :C]


def _w_tile(z_ref, d_ref, lam_ref, inv_ref, defl_ref, cid_ref, k, l, *,
            block: int, eps: float):
    """(block, block) tile (k, l) of a normalized Cauchy factor.

    w[r, c] = defl[c] ? (row_r == cid[c]) : z[r] * inv[c] / (d[r] - lam[c])
    with r/c the in-tile offsets of global rows k·B+r, columns l·B+c.
    (W's row space is the eigenvector COLUMN index, so this is independent
    of any row-blocking of U.)
    """
    rs = pl.dslice(k * block, block)
    cs = pl.dslice(l * block, block)
    z = z_ref[rs, :]                     # (block, 1)
    d = d_ref[rs, :]                     # (block, 1)
    lam = lam_ref[:, cs]                 # (1, block)
    inv = inv_ref[:, cs]                 # (1, block)
    defl = defl_ref[:, cs]               # (1, block) float 0/1
    cid = cid_ref[:, cs]                 # (1, block) int32
    den = d - lam
    den = jnp.where(jnp.abs(den) < eps,
                    jnp.where(den < 0, -eps, eps), den)
    w = z * inv / den
    rows = k * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    return jnp.where(defl > 0, (rows == cid).astype(w.dtype), w)


def _kernel2(g_ref, u_ref,
             z1_ref, d1_ref, lam1_ref, inv1_ref, defl1_ref, cid1_ref,
             z2_ref, d2_ref, lam2_ref, inv2_ref, defl2_ref, cid2_ref,
             out_ref, t_ref, *, k_steps: int, block: int, eps: float):
    i, k = pl.program_id(0), pl.program_id(1)
    gr, gc = g_ref[0], g_ref[1]

    @pl.when(k == 0)
    def _init():
        t_ref[...] = jnp.zeros_like(t_ref)

    # Accumulate T = U_row @ W1n one (i, k) U-tile at a time, so both the
    # MXU work and the U HBM fetches stop at the active tile ranges.
    @pl.when((i < gr) & (k < gc))
    def _acc():
        u_blk = u_ref[...]                               # (block, block)

        def body1(l, carry):
            w1 = _w_tile(z1_ref, d1_ref, lam1_ref, inv1_ref, defl1_ref,
                         cid1_ref, k, l, block=block, eps=eps)
            sl = pl.dslice(l * block, block)
            t_ref[:, sl] += jnp.dot(u_blk, w1,
                                    preferred_element_type=t_ref.dtype)
            return carry

        jax.lax.fori_loop(0, gc, body1, 0)

    # Second factor once T is complete.  Pruned column slabs (and pruned
    # row blocks entirely) are zero — correct for the padding contract.
    @pl.when(k == k_steps - 1)
    def _emit():
        out_ref[...] = jnp.zeros_like(out_ref)

        @pl.when(i < gr)
        def _second():
            def body2(j, carry):
                def inner(l, acc):
                    w2 = _w_tile(z2_ref, d2_ref, lam2_ref, inv2_ref,
                                 defl2_ref, cid2_ref, l, j, block=block,
                                 eps=eps)
                    t_blk = t_ref[:, pl.dslice(l * block, block)]
                    return acc + jnp.dot(t_blk, w2.astype(t_ref.dtype),
                                         preferred_element_type=t_ref.dtype)

                acc0 = jnp.zeros((block, block), t_ref.dtype)
                out_ref[:, pl.dslice(j * block, block)] = (
                    jax.lax.fori_loop(0, gc, inner, acc0).astype(
                        out_ref.dtype))
                return carry

            jax.lax.fori_loop(0, gc, body2, 0)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eigvec_rotate2(u: jax.Array,
                   z1: jax.Array, d1: jax.Array, lam1: jax.Array,
                   inv1: jax.Array, defl1: jax.Array, cid1: jax.Array,
                   z2: jax.Array, d2: jax.Array, lam2: jax.Array,
                   inv2: jax.Array, defl2: jax.Array, cid2: jax.Array,
                   num_active: jax.Array | None = None,
                   row_offset: jax.Array | None = None, *,
                   block: int = DEFAULT_BLOCK,
                   interpret: bool = False) -> jax.Array:
    """Fused double rotation  C = U @ W1n @ W2n  in one pass over U.

    Each factor is W[k, j] = z[k]·inv[j]/(d[k]-lam[j]), except deflated
    columns (defl[j] != 0) which are identity columns e_{cid[j]} — cid
    carries the sort permutation applied between the two updates.  ``u``
    may be a rectangular (R, M) row block (``row_offset`` = first global
    row); the grid walks (i, k) U-tiles bounded by (g_rows, g_cols); the
    intermediate T = U_row @ W1n lives only in VMEM scratch (never HBM).
    VMEM footprint per program is the (B, M) T row plus (B, B) tiles
    ≈ B·M·4 bytes.
    """
    R, M = u.shape
    Rp = -(-R // block) * block
    Mp = -(-M // block) * block
    pad_r, pad_c = Rp - R, Mp - M
    dtype = u.dtype
    if pad_r or pad_c:
        u = jnp.pad(u, ((0, pad_r), (0, pad_c)))
    if pad_c:
        z1, z2 = (jnp.pad(v, (0, pad_c)) for v in (z1, z2))
        d1, d2 = (jnp.pad(v, (0, pad_c), constant_values=2e30)
                  for v in (d1, d2))
        lam1, lam2 = (jnp.pad(v, (0, pad_c), constant_values=1e30)
                      for v in (lam1, lam2))
        inv1, inv2 = (jnp.pad(v, (0, pad_c)) for v in (inv1, inv2))
        defl1, defl2 = (jnp.pad(v, (0, pad_c)) for v in (defl1, defl2))
        cid1, cid2 = (jnp.pad(v, (0, pad_c), constant_values=Mp)
                      for v in (cid1, cid2))

    def col(v):
        return v.reshape(Mp, 1).astype(dtype)

    def row(v, as_dtype=None):
        return v.reshape(1, Mp).astype(as_dtype or dtype)

    steps_r = Rp // block
    steps = Mp // block
    g = _tile_counts(num_active, row_offset, R, M, block, steps_r, steps)
    acc_dtype = jnp.promote_types(dtype, jnp.float32)

    vec_specs = [
        pl.BlockSpec((Mp, 1), lambda i, k, g: (0, 0)),   # z
        pl.BlockSpec((Mp, 1), lambda i, k, g: (0, 0)),   # d
        pl.BlockSpec((1, Mp), lambda i, k, g: (0, 0)),   # lam
        pl.BlockSpec((1, Mp), lambda i, k, g: (0, 0)),   # inv
        pl.BlockSpec((1, Mp), lambda i, k, g: (0, 0)),   # defl
        pl.BlockSpec((1, Mp), lambda i, k, g: (0, 0)),   # cid
    ]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps_r, steps),
        in_specs=[pl.BlockSpec(
            (block, block),
            lambda i, k, g: (_clamp(i, g[0]), _clamp(k, g[1])))]
        + vec_specs + vec_specs,
        out_specs=pl.BlockSpec((block, Mp), lambda i, k, g: (i, 0)),
        scratch_shapes=[pltpu.VMEM((block, Mp), acc_dtype)],
    )
    eps = float(jnp.finfo(dtype).eps)
    out = pl.pallas_call(
        functools.partial(_kernel2, k_steps=steps, block=block, eps=eps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Rp, Mp), dtype),
        interpret=interpret,
    )(g, u,
      col(z1), col(d1), row(lam1), row(inv1), row(defl1),
      row(cid1, jnp.int32),
      col(z2), col(d2), row(lam2), row(inv2), row(defl2),
      row(cid2, jnp.int32))
    return out[:R, :M]
