"""Fused Cauchy-factor eigenvector rotation — the paper's O(m^3) hot spot.

Computes  C = U @ (W * inv[None, :])  where  W[k, j] = zhat[k] / (d[k] - lam[j])
without ever materializing W in HBM: each (BK, BJ) tile of W is generated in
VMEM from three O(M) vectors immediately before the MXU dot-accumulate.

Roofline motivation (TPU v5e, bf16/f32): the naive two-step
(materialize W, then matmul) moves 3·M^2 reads + 2·M^2 writes of HBM traffic;
the fused kernel moves M^2 reads (U) + M^2 writes (C) — a ~2.5× cut on the
memory term, and the VPU divide pipeline overlaps the MXU dot.

Tiling: (BI, BJ) output tiles, reduction over K in the innermost grid axis;
MXU-aligned 128×128×128 blocks by default.  Vectors are carried as (M, 1) /
(1, M) so no in-kernel transposes are needed (lane/sublane friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _kernel(u_ref, z_ref, d_ref, lam_ref, inv_ref, out_ref, acc_ref, *,
            k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Generate the W tile in VMEM: (BK, 1) vectors against (1, BJ) vectors.
    zcol = z_ref[...]            # (BK, 1)
    dcol = d_ref[...]            # (BK, 1)
    lamrow = lam_ref[...]        # (1, BJ)
    w = zcol / (dcol - lamrow)   # (BK, BJ) — Cauchy tile, never hits HBM

    acc_ref[...] += jnp.dot(u_ref[...], w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        out_ref[...] = (acc_ref[...] * inv_ref[...]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def eigvec_rotate(u: jax.Array, zhat: jax.Array, d: jax.Array,
                  lam: jax.Array, inv: jax.Array, *,
                  block: int = DEFAULT_BLOCK,
                  interpret: bool = False) -> jax.Array:
    """C[i, j] = sum_k U[i,k] * zhat[k]/(d[k]-lam[j]) * inv[j].

    u: (M, M); zhat, d, lam, inv: (M,).  M is padded internally to a multiple
    of ``block``; padded columns use lam=1e30 / d=2e30 so generated W entries
    are exactly 0 (no NaNs enter the accumulator).
    """
    M = u.shape[0]
    Mp = -(-M // block) * block
    pad = Mp - M
    dtype = u.dtype
    if pad:
        u = jnp.pad(u, ((0, pad), (0, pad)))
        zhat = jnp.pad(zhat, (0, pad))
        d = jnp.pad(d, (0, pad), constant_values=2e30)
        lam = jnp.pad(lam, (0, pad), constant_values=1e30)
        inv = jnp.pad(inv, (0, pad))
    zcol = zhat.reshape(Mp, 1).astype(dtype)
    dcol = d.reshape(Mp, 1).astype(dtype)
    lamrow = lam.reshape(1, Mp).astype(dtype)
    invrow = inv.reshape(1, Mp).astype(dtype)

    steps = Mp // block
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=steps),
        grid=(steps, steps, steps),
        in_specs=[
            pl.BlockSpec((block, block), lambda i, j, k: (i, k)),   # U
            pl.BlockSpec((block, 1), lambda i, j, k: (k, 0)),       # zhat
            pl.BlockSpec((block, 1), lambda i, j, k: (k, 0)),       # d
            pl.BlockSpec((1, block), lambda i, j, k: (0, j)),       # lam
            pl.BlockSpec((1, block), lambda i, j, k: (0, j)),       # inv
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Mp), dtype),
        scratch_shapes=[pltpu.VMEM((block, block), jnp.float32)],
        interpret=interpret,
    )(u, zcol, dcol, lamrow, invrow)
    return out[:M, :M]
