from repro.kernels.eigvec_update import ops, ref
from repro.kernels.eigvec_update.eigvec_update import (eigvec_rotate,
                                                      eigvec_rotate2)

__all__ = ["ops", "ref", "eigvec_rotate", "eigvec_rotate2"]
