from repro.kernels.eigvec_update import ops, ref
from repro.kernels.eigvec_update.eigvec_update import eigvec_rotate

__all__ = ["ops", "ref", "eigvec_rotate"]
