"""Jit'd public wrappers for the fused eigenvector rotation kernels.

Dispatch: real TPU -> compiled Pallas; CPU (this container) -> Pallas
interpret mode for small sizes in tests, pure-jnp oracle otherwise (the
interpreter is Python-slow; numerics are identical).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.eigvec_update.eigvec_update import (eigvec_project,
                                                       eigvec_rotate,
                                                       eigvec_rotate2)
from repro.kernels.eigvec_update.ref import (eigvec_project_ref,
                                             eigvec_rotate2_ref,
                                             eigvec_rotate_ref)
from repro.obs.hub import note_kernel_dispatch


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _force(force: str | None) -> str | None:
    return force or os.environ.get("REPRO_PALLAS_FORCE") or None


def _route(force: str | None) -> str:
    if force == "ref" or (force is None and not _on_tpu()):
        return "ref"
    if force == "interpret":
        return "interpret"
    return "pallas"


def rotate_vectors(u: jax.Array, zhat: jax.Array, d: jax.Array,
                   lam: jax.Array, inv: jax.Array,
                   num_active: jax.Array | None = None,
                   row_offset: jax.Array | None = None, *,
                   force: str | None = None) -> jax.Array:
    """C = U @ (diag-normalized Cauchy factor).

    ``u`` may be square (M, M) or a rectangular (R, M) row block whose
    first global row is ``row_offset`` (the distributed row-sharded
    shape).  ``num_active`` enables active-tile grid pruning along both
    axes (see eigvec_update.py); pruned columns come back as zeros for
    the caller to overwrite.

    force in {None, 'pallas', 'interpret', 'ref'} overrides dispatch; the
    REPRO_PALLAS_FORCE env var does the same (tests set it to 'interpret'
    so the real kernel body executes on CPU).
    """
    route = _route(_force(force))
    note_kernel_dispatch("eigvec_rotate", route)
    if route == "ref":
        return eigvec_rotate_ref(u, zhat, d, lam, inv)
    if route == "interpret":
        # Re-enable jit locally: pallas_call's interpret impl recurses
        # forever under an ambient jax.disable_jit() on this JAX version.
        with jax.disable_jit(False):
            return eigvec_rotate(u, zhat, d, lam, inv, num_active,
                                 row_offset, interpret=True)
    return eigvec_rotate(u, zhat, d, lam, inv, num_active, row_offset)


def rotate_vectors2(u: jax.Array,
                    z1: jax.Array, d1: jax.Array, lam1: jax.Array,
                    inv1: jax.Array, defl1: jax.Array, cid1: jax.Array,
                    z2: jax.Array, d2: jax.Array, lam2: jax.Array,
                    inv2: jax.Array, defl2: jax.Array, cid2: jax.Array,
                    num_active: jax.Array | None = None,
                    row_offset: jax.Array | None = None, *,
                    force: str | None = None) -> jax.Array:
    """Fused double rotation C = U @ W1n @ W2n (eq. (2)/(3) back-to-back).

    Same dispatch and rectangular-operand contract as ``rotate_vectors``.
    Deflated columns are generated as identity columns e_{cid[j]} inside
    the kernel, so the intermediate U @ W1n never exists in HBM.
    """
    route = _route(_force(force))
    note_kernel_dispatch("eigvec_rotate2", route)
    args = (u, z1, d1, lam1, inv1, defl1, cid1,
            z2, d2, lam2, inv2, defl2, cid2)
    if route == "ref":
        return eigvec_rotate2_ref(*args)
    if route == "interpret":
        with jax.disable_jit(False):
            return eigvec_rotate2(*args, num_active, row_offset,
                                  interpret=True)
    return eigvec_rotate2(*args, num_active, row_offset)


def project_vectors(u: jax.Array, v: jax.Array,
                    num_active: jax.Array | None = None,
                    row_offset: jax.Array | None = None, *,
                    force: str | None = None) -> jax.Array:
    """P = Uᵀ V (row-masked at ``num_active``) — the post-rotation
    projection of Algorithm 2's second ±sigma pair as one rect-pruned
    kernel pass instead of a dense einsum over the (M, M) eigenvectors.

    Same dispatch and rectangular-operand contract as ``rotate_vectors``;
    pruned output rows (>= the active tile range) come back as exact
    zeros, their true value.  Row-sharded callers psum the partials.
    """
    route = _route(_force(force))
    note_kernel_dispatch("eigvec_project", route)
    if route == "ref":
        return eigvec_project_ref(u, v, num_active, row_offset)
    if route == "interpret":
        with jax.disable_jit(False):
            return eigvec_project(u, v, num_active, row_offset,
                                  interpret=True)
    return eigvec_project(u, v, num_active, row_offset)


def rotate(u: jax.Array, wn: jax.Array) -> jax.Array:
    """Fallback entry used by rankone when only the dense factor is at hand
    (keeps the pallas code-path selectable end-to-end)."""
    return u @ wn
