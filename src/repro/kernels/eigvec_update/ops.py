"""Jit'd public wrapper for the fused eigenvector rotation kernel.

Dispatch: real TPU -> compiled Pallas; CPU (this container) -> Pallas
interpret mode for small sizes in tests, pure-jnp oracle otherwise (the
interpreter is Python-slow; numerics are identical).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.eigvec_update.eigvec_update import eigvec_rotate
from repro.kernels.eigvec_update.ref import eigvec_rotate_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def rotate_vectors(u: jax.Array, zhat: jax.Array, d: jax.Array,
                   lam: jax.Array, inv: jax.Array, *,
                   force: str | None = None) -> jax.Array:
    """C = U @ (diag-normalized Cauchy factor).

    force in {None, 'pallas', 'interpret', 'ref'} overrides dispatch; the
    REPRO_PALLAS_FORCE env var does the same (tests set it to 'interpret'
    so the real kernel body executes on CPU).
    """
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and not _on_tpu()):
        return eigvec_rotate_ref(u, zhat, d, lam, inv)
    if force == "interpret":
        return eigvec_rotate(u, zhat, d, lam, inv, interpret=True)
    return eigvec_rotate(u, zhat, d, lam, inv)


def rotate(u: jax.Array, wn: jax.Array) -> jax.Array:
    """Fallback entry used by rankone when only the dense factor is at hand
    (keeps the pallas code-path selectable end-to-end)."""
    return u @ wn
