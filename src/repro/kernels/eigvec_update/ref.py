"""Pure-jnp oracle for the fused Cauchy eigenvector rotation."""
import jax
import jax.numpy as jnp


def eigvec_rotate_ref(u: jax.Array, zhat: jax.Array, d: jax.Array,
                      lam: jax.Array, inv: jax.Array) -> jax.Array:
    """Materialize W then matmul — the unfused baseline the kernel beats."""
    W = zhat[:, None] / (d[:, None] - lam[None, :])
    return (u @ W) * inv[None, :]
