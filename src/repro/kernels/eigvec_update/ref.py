"""Pure-jnp oracles for the fused Cauchy eigenvector rotations."""
import jax
import jax.numpy as jnp


def eigvec_rotate_ref(u: jax.Array, zhat: jax.Array, d: jax.Array,
                      lam: jax.Array, inv: jax.Array) -> jax.Array:
    """Materialize W then matmul — the unfused baseline the kernel beats.

    ``u`` may be square (M, M) or a rectangular (R, M) row block; the
    product is over u's columns either way.
    """
    W = zhat[:, None] / (d[:, None] - lam[None, :])
    return (u @ W) * inv[None, :]


def eigvec_project_ref(u: jax.Array, v: jax.Array,
                       num_active: jax.Array | None = None,
                       row_offset: jax.Array | None = None) -> jax.Array:
    """P = Uᵀ V with rows >= num_active (global index) masked to zero —
    the unfused oracle for ``eigvec_project``.  ``u``/``v`` may be a
    rectangular (R, ·) row block whose first global row is ``row_offset``."""
    if num_active is not None:
        r0 = 0 if row_offset is None else row_offset
        rows = r0 + jnp.arange(u.shape[0])
        v = jnp.where((rows < num_active)[:, None], v, 0.0)
    return u.T @ v


def pruned_region_mask(R: int, M: int, m, row_offset=None, *,
                       block: int) -> tuple[jax.Array, jax.Array]:
    """(row_mask (R,), col_mask (M,)) of the tiles the pruned kernels WRITE.

    True = inside the active tile range (kernel computes real values);
    False = pruned (kernel writes exact zeros).  Mirrors ``_tile_counts``
    in eigvec_update.py so tests and callers can assert the contract:
    within the active region the kernel matches ``eigvec_rotate_ref``,
    outside it the output is zero (which is also the true value for rows
    past the active prefix of active columns).
    """
    r0 = 0 if row_offset is None else row_offset
    m = jnp.asarray(m, jnp.int32)
    rows_active = jnp.clip(m - r0, 0, R)
    g_rows = -(-rows_active // block)
    g_cols = -(-m // block)
    row_mask = jnp.arange(R) < g_rows * block
    col_mask = jnp.arange(M) < g_cols * block
    return row_mask, col_mask


def cauchy_factor_ref(z: jax.Array, d: jax.Array, lam: jax.Array,
                      inv: jax.Array, defl: jax.Array | None = None,
                      cid: jax.Array | None = None) -> jax.Array:
    """Dense normalized Cauchy factor with deflated identity columns.

    W[k, j] = z[k]·inv[j]/(d[k]-lam[j]); columns with defl[j] != 0 are
    replaced by e_{cid[j]} (cid defaults to j).  Matches the in-VMEM tile
    generation of ``eigvec_rotate2`` including its eps denominator guard.
    """
    M = z.shape[0]
    eps = jnp.finfo(z.dtype).eps
    den = d[:, None] - lam[None, :]
    den = jnp.where(jnp.abs(den) < eps, jnp.where(den < 0, -eps, eps), den)
    W = z[:, None] * inv[None, :] / den
    if defl is None:
        return W
    if cid is None:
        cid = jnp.arange(M, dtype=jnp.int32)
    E = (jnp.arange(M)[:, None] == cid[None, :]).astype(W.dtype)
    return jnp.where(defl[None, :] > 0, E, W)


def eigvec_rotate2_ref(u: jax.Array,
                       z1: jax.Array, d1: jax.Array, lam1: jax.Array,
                       inv1: jax.Array, defl1: jax.Array, cid1: jax.Array,
                       z2: jax.Array, d2: jax.Array, lam2: jax.Array,
                       inv2: jax.Array, defl2: jax.Array,
                       cid2: jax.Array) -> jax.Array:
    """Two sequential dense rotations — the oracle for ``eigvec_rotate2``."""
    W1 = cauchy_factor_ref(z1, d1, lam1, inv1, defl1, cid1)
    W2 = cauchy_factor_ref(z2, d2, lam2, inv2, defl2, cid2)
    return (u @ W1) @ W2
