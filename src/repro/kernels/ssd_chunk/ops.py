"""Jit'd public wrapper for the fused SSD intra-chunk kernel."""
from __future__ import annotations

import os

import jax

from repro.kernels.ssd_chunk.ref import ssd_intra_chunk_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_intra_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def intra_chunk(c, b, x, cum, *, force: str | None = None):
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and not _on_tpu()):
        return ssd_intra_chunk_ref(c, b, x, cum)
    if force == "interpret":
        return ssd_intra_chunk(c, b, x, cum, interpret=True)
    return ssd_intra_chunk(c, b, x, cum)
