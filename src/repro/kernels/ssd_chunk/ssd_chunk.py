"""Fused SSD intra-chunk kernel (Mamba-2 chunked form, TPU target).

Computes, per (batch·chunk, head):

    y[t] = Σ_{s<=t} (C_t·B_s) · exp(cum_t − cum_s) · x_s

i.e. masked-decay attention with scores from the (Q, N) state projections.
The XLA lowering materializes the (Q, Q, H) f32 decay/score tensors to HBM
(measured as jamba's dominant memory term before the chunk-scan rewrite);
here the (Q, Q) tile lives in VMEM: HBM traffic is C, B, x, cum in and y
out. The inter-chunk recurrence (tiny, sequential) stays in jnp.

Grid: (B·nc, H). Scores C·Bᵀ are shared across heads and recomputed per
head — 2·Q²·N flops against 2·Q²·P for the apply; the VMEM savings win on
the memory-bound side (arithmetic intensity of the fused form ≈ Q/2 ≫ 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_Q = 256


def _kernel(c_ref, b_ref, x_ref, cum_ref, o_ref):
    c = c_ref[0]                                  # (Q, N)
    b = b_ref[0]                                  # (Q, N)
    x = x_ref[0, :, 0, :]                         # (Q, P)
    cum = cum_ref[0, :, 0]                        # (Q,)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    Q = scores.shape[0]
    ldiff = cum[:, None] - cum[None, :]           # (Q, Q) log decay
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(t_pos >= s_pos, jnp.exp(ldiff), 0.0)
    m = scores * decay                            # (Q, Q) in VMEM only
    y = jax.lax.dot(m.astype(x.dtype), x,
                    preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(c: jax.Array, b: jax.Array, x: jax.Array,
                    cum: jax.Array, *, interpret: bool = False) -> jax.Array:
    """y_intra for all chunks in parallel (no sequential dependence).

    c, b: (G, Q, N) state projections per (batch·chunk) group;
    x:    (G, Q, H, P) dt-scaled inputs;
    cum:  (G, Q, H) within-chunk cumulative log decay (fp32).
    Returns (G, Q, H, P).
    """
    G, Q, N = c.shape
    H, P = x.shape[2], x.shape[3]

    return pl.pallas_call(
        _kernel,
        grid=(G, H),
        in_specs=[
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),       # C
            pl.BlockSpec((1, Q, N), lambda g, h: (g, 0, 0)),       # B
            pl.BlockSpec((1, Q, 1, P), lambda g, h: (g, 0, h, 0)),  # x
            pl.BlockSpec((1, Q, 1), lambda g, h: (g, 0, h)),       # cum
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda g, h: (g, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((G, Q, H, P), x.dtype),
        interpret=interpret,
    )(c, b, x, cum)
