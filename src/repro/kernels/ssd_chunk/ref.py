"""Pure-jnp oracle for the fused SSD intra-chunk kernel."""
import jax.numpy as jnp


def ssd_intra_chunk_ref(c, b, x, cum):
    """c, b: (G,Q,N); x: (G,Q,H,P); cum: (G,Q,H) -> (G,Q,H,P)."""
    G, Q, N = c.shape
    scores = jnp.einsum("gqn,gsn->gqs", c, b)
    ldiff = cum[:, :, None, :] - cum[:, None, :, :]       # (G,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    decay = jnp.where(causal, jnp.exp(ldiff), 0.0)
    m = scores[..., None] * decay
    return jnp.einsum("gqsh,gshp->gqhp", m.astype(x.dtype), x)
