"""Pure-jnp oracles for the tiled RBF gram / fused k-row kernels."""
import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf


def rbf_gram_ref(x: jax.Array, y: jax.Array, sigma: jax.Array) -> jax.Array:
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-d2 / sigma)


def krow_project_ref(u: jax.Array, x: jax.Array, x_new: jax.Array,
                     aux: jax.Array, num_active: jax.Array,
                     row_offset: jax.Array | None = None, *,
                     spec: kf.KernelSpec) -> tuple[jax.Array, jax.Array]:
    """(a, P) oracle — uses kernels_fn.gram_block so the masked row is
    bitwise the unfused engine.masked_row value."""
    dtype = u.dtype
    R = u.shape[0]
    r0 = (jnp.zeros((), jnp.int32) if row_offset is None
          else jnp.asarray(row_offset, jnp.int32))
    rows = r0 + jnp.arange(R, dtype=jnp.int32)
    kr = kf.gram_block(x.astype(dtype), x_new.astype(dtype)[None, :],
                       spec=spec)[:, 0]
    a = jnp.where(rows < num_active, kr, 0.0).astype(dtype)
    auxm = jnp.where(rows[:, None] < num_active, aux.astype(dtype), 0.0)
    v = jnp.concatenate([a[:, None], auxm], axis=1)
    return a, u.T @ v
