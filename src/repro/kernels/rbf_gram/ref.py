"""Pure-jnp oracle for the tiled RBF gram kernel."""
import jax
import jax.numpy as jnp


def rbf_gram_ref(x: jax.Array, y: jax.Array, sigma: jax.Array) -> jax.Array:
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = jnp.maximum(xn + yn - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-d2 / sigma)
