"""Fused kernel-row producer + eigenbasis projection: the ingest prologue.

Every streamed point consumes a kernel row a = [k(x_i, x_new)] and its
projection P = U^T [a | aux] (aux carries Algorithm-2 side vectors such as
the masked ones vector and the row-sum vector K1).  The unfused pipeline
pays three HBM round-trips — write a, re-read a, re-read U — before the
rotation kernels even start.  This kernel produces the row tile-by-tile in
VMEM from the stored points X and immediately contracts it against the
matching U row tile, so the kernel row never makes a standalone trip to
HBM and U is read exactly once for the whole prologue.

Supports the rectangular (R, M) row-block form of ``eigvec_update``: ``u``
and ``x`` may cover only rows [row_offset, row_offset + R) of the global
state, so the row-sharded distributed path runs the same kernel per shard
and psums the partial P.  Active-prefix pruning follows the same
``g_rows``/``g_cols`` scalar-prefetch discipline as the rotation kernels:
U tiles beyond the active prefix are never fetched, and pruned P tiles are
zero — their true value, because the masked row and masked aux vanish on
rows >= m and inactive U columns are identity columns living entirely in
that masked region.

Kernels: RBF and Matérn-3/2 (the stationary kernels of ``kernels_fn``);
the epilogues match ``kernels_fn.gram_block`` term-for-term so the fused
path is numerically the reference path.  The KernelSpec is jit-static, so
sigma/scale are compile-time constants inside the kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import kernels_fn as kf

DEFAULT_BLOCK = 128
NAUX = 8          # projected column count: kernel row + up to 7 aux columns

PALLAS_KERNELS = ("rbf", "matern32")


def _clamp(t, lim):
    # Redirect pruned-tile block loads to tile 0 (iteration skipped anyway).
    return jnp.minimum(t, jnp.maximum(lim - 1, 0))


def kernel_epilogue(d2, *, name: str, sigma: float, scale: float):
    """Squared-distance -> kernel-value epilogue, shared by every fused
    kernel tile (k-row ingest here, batched transform in nystrom_recon).
    Matches ``kernels_fn`` term-for-term."""
    if name == "rbf":
        return scale * jnp.exp(-d2 / sigma)
    if name == "matern32":
        aa = jnp.sqrt(3.0) * jnp.sqrt(d2 + 1e-30) / sigma
        return scale * (1.0 + aa) * jnp.exp(-aa)
    raise ValueError(f"no fused epilogue for kernel {name!r}")


def _krow_tile(x_blk, xn_blk, xq, *, name: str, sigma: float, scale: float):
    """(block, 1) kernel-row tile k(x_blk, xq) — matches kernels_fn exactly."""
    qn = jnp.sum(xq * xq)
    dot = jax.lax.dot_general(
        x_blk, xq, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.promote_types(x_blk.dtype, jnp.float32))
    d2 = jnp.maximum(xn_blk + qn - 2.0 * dot.astype(xn_blk.dtype), 0.0)
    return kernel_epilogue(d2, name=name, sigma=sigma, scale=scale)


def _kernel(g_ref, u_ref, x_ref, xn_ref, xq_ref, aux_ref, a_ref, p_ref,
            acc_ref, *, r_steps: int, block: int, name: str, sigma: float,
            scale: float):
    j, i = pl.program_id(0), pl.program_id(1)
    gr, gc = g_ref[0], g_ref[1]
    m, r0 = g_ref[2], g_ref[3]

    kr = _krow_tile(x_ref[...], xn_ref[...], xq_ref[...],
                    name=name, sigma=sigma, scale=scale)
    rows = (r0 + i * block
            + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0))
    krm = jnp.where(rows < m, kr, 0.0).astype(a_ref.dtype)
    # Row tiles beyond g_rows load clamped (wrong) operands, but every such
    # row is >= m, so the mask writes the true value (zero) regardless.
    a_ref[...] = krm

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((i < gr) & (j < gc))
    def _acc():
        cols = jax.lax.broadcasted_iota(jnp.int32, (block, NAUX), 1)
        v = jnp.where(cols == 0, krm.astype(acc_ref.dtype),
                      aux_ref[...].astype(acc_ref.dtype))
        acc_ref[...] += jax.lax.dot_general(
            u_ref[...].astype(acc_ref.dtype), v, (((0,), (0,)), ((), ())),
            preferred_element_type=acc_ref.dtype)

    @pl.when(i == r_steps - 1)
    def _done():
        # Pruned (j >= gc) tiles were never accumulated: zero is their true
        # value — inactive U columns are identity columns whose single 1
        # lands on a masked row of [a | aux].
        p_ref[...] = acc_ref[...].astype(p_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("spec", "block", "interpret"))
def krow_project(u: jax.Array, x: jax.Array, x_new: jax.Array,
                 aux: jax.Array, num_active: jax.Array,
                 row_offset: jax.Array | None = None, *,
                 spec: kf.KernelSpec, block: int = DEFAULT_BLOCK,
                 interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(a, P): masked kernel row + its eigenbasis projection, one pass.

    u:   (R, M) eigenvector row block (R == M, row_offset 0 single-device)
    x:   (R, d) stored points for those rows
    aux: (R, naux) extra columns to project alongside the row (naux <= 7)

    Returns a: (R,) = k(x, x_new) zeroed on global rows >= num_active, and
    P: (M, 1 + naux) = u^T [a | aux_masked].  Sharded callers psum P.
    """
    R, M = u.shape
    d = x.shape[1]
    naux = aux.shape[1]
    if naux + 1 > NAUX:
        raise ValueError(f"at most {NAUX - 1} aux columns, got {naux}")
    dtype = u.dtype
    Rp = -(-R // block) * block
    Mp = -(-M // block) * block
    dp = -(-d // 8) * 8

    m = jnp.asarray(num_active, jnp.int32)
    r0 = (jnp.zeros((), jnp.int32) if row_offset is None
          else jnp.asarray(row_offset, jnp.int32))
    rows = r0 + jnp.arange(R, dtype=jnp.int32)
    auxm = jnp.where(rows[:, None] < m, aux.astype(dtype), 0.0)

    up = jnp.pad(u, ((0, Rp - R), (0, Mp - M)))
    xp = jnp.pad(x.astype(dtype), ((0, Rp - R), (0, dp - d)))
    xn = jnp.sum(xp * xp, axis=1, keepdims=True)              # (Rp, 1)
    xq = jnp.pad(x_new.astype(dtype), (0, dp - d)).reshape(1, dp)
    auxp = jnp.zeros((Rp, NAUX), dtype).at[:R, 1:1 + naux].set(auxm)

    steps_r = Rp // block
    steps_c = Mp // block
    g_cols = jnp.minimum(-(-m // block), steps_c)
    g_rows = jnp.minimum(-(-jnp.clip(m - r0, 0, R) // block), steps_r)
    g = jnp.stack([g_rows, g_cols, m, r0]).astype(jnp.int32)
    acc_dtype = jnp.promote_types(dtype, jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(steps_c, steps_r),
        in_specs=[
            pl.BlockSpec((block, block),
                         lambda j, i, g: (_clamp(i, g[0]),
                                          _clamp(j, g[1]))),    # u
            pl.BlockSpec((block, dp),
                         lambda j, i, g: (_clamp(i, g[0]), 0)),  # x
            pl.BlockSpec((block, 1),
                         lambda j, i, g: (_clamp(i, g[0]), 0)),  # ||x||^2
            pl.BlockSpec((1, dp), lambda j, i, g: (0, 0)),      # x_new
            pl.BlockSpec((block, NAUX),
                         lambda j, i, g: (_clamp(i, g[0]), 0)),  # aux
        ],
        out_specs=[
            pl.BlockSpec((block, 1), lambda j, i, g: (i, 0)),    # a
            pl.BlockSpec((block, NAUX), lambda j, i, g: (j, 0)),  # P
        ],
        scratch_shapes=[pltpu.VMEM((block, NAUX), acc_dtype)],
    )
    a, P = pl.pallas_call(
        functools.partial(_kernel, r_steps=steps_r, block=block,
                          name=spec.name, sigma=float(spec.sigma),
                          scale=float(spec.scale)),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Rp, 1), dtype),
                   jax.ShapeDtypeStruct((Mp, NAUX), dtype)],
        interpret=interpret,
    )(g, up, xp, xn, xq, auxp)
    return a[:R, 0], P[:M, :1 + naux]
