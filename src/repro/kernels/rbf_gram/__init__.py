from repro.kernels.rbf_gram import ops, ref
from repro.kernels.rbf_gram.rbf_gram import rbf_gram

__all__ = ["ops", "ref", "rbf_gram"]
