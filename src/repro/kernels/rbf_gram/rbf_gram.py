"""Tiled RBF gram-matrix kernel: G[i,j] = exp(-||x_i - y_j||^2 / sigma).

Decomposed as ||x||^2 + ||y||^2 - 2 x·y so the inner loop is an MXU matmul
over the feature dimension; the norms and the exp() epilogue are fused into
the final reduction step (VPU), so G is written to HBM exactly once and the
distance matrix never materializes.

Used for: streaming kernel rows k(X, x_new) (the per-update O(m d) hot path),
gram blocks for Nyström columns, and the full-K construction in benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _kernel(x_ref, y_ref, xn_ref, yn_ref, sig_ref, out_ref, acc_ref, *,
            k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # y block arrives as (BJ, BK); contract its dim 1 against x's dim 1 so no
    # in-kernel transpose is required.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        d2 = xn_ref[...] + yn_ref[...] - 2.0 * acc_ref[...]
        d2 = jnp.maximum(d2, 0.0)
        inv_sigma = sig_ref[0, 0]
        out_ref[...] = jnp.exp(-d2 * inv_sigma).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def rbf_gram(x: jax.Array, y: jax.Array, sigma: jax.Array, *,
             block: int = DEFAULT_BLOCK, interpret: bool = False) -> jax.Array:
    """G = exp(-pairwise_sqdist(x, y)/sigma); x: (n,d), y: (m,d)."""
    n, d = x.shape
    m = y.shape[0]
    bi = bj = block
    bk = min(block, max(8, -(-d // 8) * 8))
    np_, mp_, dp_ = -(-n // bi) * bi, -(-m // bj) * bj, -(-d // bk) * bk
    xp = jnp.pad(x, ((0, np_ - n), (0, dp_ - d)))
    yp = jnp.pad(y, ((0, mp_ - m), (0, dp_ - d)))
    xn = jnp.sum(xp * xp, axis=1, keepdims=True)            # (np, 1)
    yn = jnp.sum(yp * yp, axis=1, keepdims=True).T          # (1, mp)
    inv_sigma = (1.0 / sigma).reshape(1, 1).astype(jnp.float32)

    steps = dp_ // bk
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=steps),
        grid=(np_ // bi, mp_ // bj, steps),
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),    # x
            pl.BlockSpec((bj, bk), lambda i, j, k: (j, k)),    # y
            pl.BlockSpec((bi, 1), lambda i, j, k: (i, 0)),     # ||x||^2
            pl.BlockSpec((1, bj), lambda i, j, k: (0, j)),     # ||y||^2
            pl.BlockSpec(memory_space=pltpu.SMEM),             # 1/sigma
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, mp_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(xp, yp, xn, yn, inv_sigma)
    return out[:n, :m]
