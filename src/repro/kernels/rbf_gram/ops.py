"""Jit'd public wrapper for the RBF gram kernel (dispatch as eigvec_update)."""
from __future__ import annotations

import os

import jax

from repro.kernels.rbf_gram.krow_fused import PALLAS_KERNELS
from repro.kernels.rbf_gram.krow_fused import krow_project as _krow_pallas
from repro.kernels.rbf_gram.rbf_gram import rbf_gram
from repro.kernels.rbf_gram.ref import krow_project_ref, rbf_gram_ref
from repro.obs.hub import note_kernel_dispatch


def _route(force: str | None) -> str:
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return "ref"
    if force == "interpret":
        return "interpret"
    return "pallas"


def gram(x: jax.Array, y: jax.Array, sigma, *, force: str | None = None
         ) -> jax.Array:
    route = _route(force)
    note_kernel_dispatch("rbf_gram", route)
    if route == "ref":
        return rbf_gram_ref(x, y, sigma)
    if route == "interpret":
        return rbf_gram(x, y, sigma, interpret=True)
    return rbf_gram(x, y, sigma)


def krow_project(u: jax.Array, x: jax.Array, x_new: jax.Array,
                 aux: jax.Array, num_active: jax.Array,
                 row_offset: jax.Array | None = None, *, spec,
                 force: str | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused masked kernel row + projection P = U^T [a | aux]."""
    if spec.name not in PALLAS_KERNELS:
        force = "ref"    # non-stationary kernels: reference epilogue only
    route = _route(force)
    note_kernel_dispatch("krow_project", route)
    if route == "ref":
        return krow_project_ref(u, x, x_new, aux, num_active, row_offset,
                                spec=spec)
    if route == "interpret":
        return _krow_pallas(u, x, x_new, aux, num_active, row_offset,
                            spec=spec, interpret=True)
    return _krow_pallas(u, x, x_new, aux, num_active, row_offset, spec=spec)
