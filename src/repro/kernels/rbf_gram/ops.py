"""Jit'd public wrapper for the RBF gram kernel (dispatch as eigvec_update)."""
from __future__ import annotations

import os

import jax

from repro.kernels.rbf_gram.rbf_gram import rbf_gram
from repro.kernels.rbf_gram.ref import rbf_gram_ref


def gram(x: jax.Array, y: jax.Array, sigma, *, force: str | None = None
         ) -> jax.Array:
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return rbf_gram_ref(x, y, sigma)
    if force == "interpret":
        return rbf_gram(x, y, sigma, interpret=True)
    return rbf_gram(x, y, sigma)
