"""Pure-jnp oracle for the fused flash-attention kernel."""
import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array
                        ) -> jax.Array:
    """Naive causal softmax attention; q, k, v: (BH, T, hd)."""
    T = q.shape[1]
    hd = q.shape[2]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((T, T), bool))
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)
