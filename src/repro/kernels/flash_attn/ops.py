"""Jit'd public wrapper for the fused flash-attention kernel.

Dispatch: real TPU -> compiled Pallas; CPU (this container) -> interpret
mode in tests (REPRO_PALLAS_FORCE=interpret) or the jnp oracle otherwise.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.flash_attn.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     force: str | None = None) -> jax.Array:
    """Fused causal attention over (BH, T, hd) slices."""
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and not _on_tpu()):
        return flash_attention_ref(q, k, v)
    if force == "interpret":
        return flash_attention(q, k, v, interpret=True)
    return flash_attention(q, k, v)
