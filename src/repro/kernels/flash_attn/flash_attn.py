"""Fused causal flash-attention Pallas kernel (TPU target).

The XLA blockwise form (models/layers._flash_attention) still writes every
(Bq, Bk) f32 score/probability block to HBM — measured at ~19 TB/device on
the qwen3 prefill_32k cell (§Perf). This kernel keeps the whole online-
softmax recurrence in VMEM: HBM traffic is exactly q + k + v reads and the
output write.

Grid: (batch·heads, nq, nk) with the KV loop innermost; the causal upper
triangle is skipped via a mask (blocks with j > i contribute nothing and
their loads hit the same VMEM window — on TPU the dominant win is removing
the HBM score traffic, not the ~2× masked-block MACs, which the MXU hides
behind the memory savings; a block-sparse grid is the follow-up step).

Running statistics (m, l) and the f32 accumulator live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            nk: int, block_q: int, block_k: int, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j <= i)
    def _block():
        q = q_ref[0]                             # (Bq, hd)
        k = k_ref[0]                             # (Bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # causal mask only matters on the diagonal block
        q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                   # masked -> exp(-inf)=0
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v_ref.dtype), v_ref[0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """Causal attention, one (batch·head) slice per grid row.

    q, k, v: (BH, T, hd) with identical T (self-attention, prefill/train).
    Returns (BH, T, hd). hd is padded to a lane multiple internally.
    """
    BH, T, hd = q.shape
    bq = min(block_q, T)
    bk = min(block_k, T)
    assert T % bq == 0 and T % bk == 0, (T, bq, bk)
    hdp = -(-hd // 128) * 128
    if hdp != hd:
        pad = ((0, 0), (0, 0), (0, hdp - hd))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    nq, nk = T // bq, T // bk
    scale = 1.0 / (hd ** 0.5)                   # scale by the TRUE head dim

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, block_q=bq, block_k=bk,
                          scale=scale),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hdp), lambda b, i, j: (b, i, 0)),   # q
            pl.BlockSpec((1, bk, hdp), lambda b, i, j: (b, j, 0)),   # k
            pl.BlockSpec((1, bk, hdp), lambda b, i, j: (b, j, 0)),   # v
        ],
        out_specs=pl.BlockSpec((1, bq, hdp), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, hdp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum l
            pltpu.VMEM((bq, hdp), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :hd]
