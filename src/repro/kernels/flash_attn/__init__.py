from repro.kernels.flash_attn.ops import causal_attention
from repro.kernels.flash_attn.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import flash_attention_ref
