from repro.kernels.nystrom_recon import ops, ref
from repro.kernels.nystrom_recon.nystrom_recon import scaled_gram

__all__ = ["ops", "ref", "scaled_gram"]
