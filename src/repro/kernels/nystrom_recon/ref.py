"""Pure-jnp oracles for the Nyström reconstruction / fused transform kernels."""
import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf


def scaled_gram_ref(b: jax.Array, s: jax.Array) -> jax.Array:
    return (b * s[None, :]) @ b.T


def transform_project_ref(xq: jax.Array, x: jax.Array, s: jax.Array,
                          num_active: jax.Array, *, spec: kf.KernelSpec
                          ) -> tuple[jax.Array, jax.Array]:
    """(Y, rowsum) oracle — materializes the masked query gram."""
    dtype = s.dtype
    kq = kf.gram_block(xq.astype(dtype), x.astype(dtype), spec=spec)
    mask = jnp.arange(x.shape[0]) < num_active
    kq = jnp.where(mask[None, :], kq, 0.0).astype(dtype)
    return kq @ s, jnp.sum(kq, axis=1)
