"""Pure-jnp oracle for the fused Nyström reconstruction kernel."""
import jax


def scaled_gram_ref(b: jax.Array, s: jax.Array) -> jax.Array:
    return (b * s[None, :]) @ b.T
