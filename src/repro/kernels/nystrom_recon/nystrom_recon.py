"""Fused Nyström reconstruction kernel: K̃ = B diag(s) B^T.

B = K_{n,m} U is (n, m); s = 1/λ.  The diagonal scaling is fused into the
MXU accumulation (scale the left operand tile in VMEM), so the scaled copy
of B never materializes in HBM — the O(n m^2 / n^2 m) reconstruction used by
the incremental-Nyström stopping rule (paper §4) reads B once and writes K̃
once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _kernel(bi_ref, bj_ref, s_ref, out_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    left = bi_ref[...] * s_ref[...]          # fuse diag(s) into the tile
    acc_ref[...] += jax.lax.dot_general(
        left, bj_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def scaled_gram(b: jax.Array, s: jax.Array, *, block: int = DEFAULT_BLOCK,
                interpret: bool = False) -> jax.Array:
    """K̃[i,j] = sum_k B[i,k] * s[k] * B[j,k]; b: (n, m), s: (m,)."""
    n, m = b.shape
    bi = bj = bk = block
    np_, mp_ = -(-n // bi) * bi, -(-m // bk) * bk
    bp = jnp.pad(b, ((0, np_ - n), (0, mp_ - m)))
    sp = jnp.pad(s, (0, mp_ - m)).reshape(1, mp_).astype(b.dtype)

    steps = mp_ // bk
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=steps),
        grid=(np_ // bi, np_ // bj, steps),
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),   # B rows (i)
            pl.BlockSpec((bj, bk), lambda i, j, k: (j, k)),   # B rows (j)
            pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),    # s
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, np_), b.dtype),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(bp, bp, sp)
    return out[:n, :n]
