"""Jit'd public wrapper for the Nyström reconstruction kernel."""
from __future__ import annotations

import os

import jax

from repro.kernels.nystrom_recon.nystrom_recon import scaled_gram as _pallas
from repro.kernels.nystrom_recon.ref import (scaled_gram_ref,
                                             transform_project_ref)
from repro.kernels.nystrom_recon.transform_batch import \
    transform_project as _tb_pallas
from repro.kernels.rbf_gram.krow_fused import PALLAS_KERNELS


def scaled_gram(b: jax.Array, s: jax.Array, *, force: str | None = None
                ) -> jax.Array:
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return scaled_gram_ref(b, s)
    if force == "interpret":
        return _pallas(b, s, interpret=True)
    return _pallas(b, s)


def transform_project(xq: jax.Array, x: jax.Array, s: jax.Array,
                      num_active: jax.Array, *, spec,
                      force: str | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused masked query gram + projection (Y, rowsum) — see
    ``transform_batch.py``."""
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if spec.name not in PALLAS_KERNELS:
        force = "ref"    # non-stationary kernels: reference epilogue only
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return transform_project_ref(xq, x, s, num_active, spec=spec)
    if force == "interpret":
        return _tb_pallas(xq, x, s, num_active, spec=spec, interpret=True)
    return _tb_pallas(xq, x, s, num_active, spec=spec)
