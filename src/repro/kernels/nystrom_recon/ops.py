"""Jit'd public wrapper for the Nyström reconstruction kernel."""
from __future__ import annotations

import os

import jax

from repro.kernels.nystrom_recon.nystrom_recon import scaled_gram as _pallas
from repro.kernels.nystrom_recon.ref import scaled_gram_ref


def scaled_gram(b: jax.Array, s: jax.Array, *, force: str | None = None
                ) -> jax.Array:
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return scaled_gram_ref(b, s)
    if force == "interpret":
        return _pallas(b, s, interpret=True)
    return _pallas(b, s)
