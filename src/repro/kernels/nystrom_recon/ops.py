"""Jit'd public wrapper for the Nyström reconstruction kernel."""
from __future__ import annotations

import os

import jax

from repro.kernels.nystrom_recon.nystrom_recon import scaled_gram as _pallas
from repro.kernels.nystrom_recon.ref import (scaled_gram_ref,
                                             transform_project_ref)
from repro.kernels.nystrom_recon.transform_batch import \
    transform_project as _tb_pallas
from repro.kernels.rbf_gram.krow_fused import PALLAS_KERNELS
from repro.obs.hub import note_kernel_dispatch


def _route(force: str | None) -> str:
    force = force or os.environ.get("REPRO_PALLAS_FORCE") or None
    if force == "ref" or (force is None and jax.default_backend() != "tpu"):
        return "ref"
    if force == "interpret":
        return "interpret"
    return "pallas"


def scaled_gram(b: jax.Array, s: jax.Array, *, force: str | None = None
                ) -> jax.Array:
    route = _route(force)
    note_kernel_dispatch("scaled_gram", route)
    if route == "ref":
        return scaled_gram_ref(b, s)
    if route == "interpret":
        return _pallas(b, s, interpret=True)
    return _pallas(b, s)


def transform_project(xq: jax.Array, x: jax.Array, s: jax.Array,
                      num_active: jax.Array, *, spec,
                      force: str | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused masked query gram + projection (Y, rowsum) — see
    ``transform_batch.py``."""
    if spec.name not in PALLAS_KERNELS:
        force = "ref"    # non-stationary kernels: reference epilogue only
    route = _route(force)
    note_kernel_dispatch("transform_project", route)
    if route == "ref":
        return transform_project_ref(xq, x, s, num_active, spec=spec)
    if route == "interpret":
        return _tb_pallas(xq, x, s, num_active, spec=spec, interpret=True)
    return _tb_pallas(xq, x, s, num_active, spec=spec)
