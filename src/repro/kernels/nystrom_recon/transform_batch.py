"""Fused batched transform: query kernel rows + component projection.

The unfused transform materializes the full (Q, M) query gram K_q in HBM,
re-reads it for the projection K_q @ S (S = U_active / sqrt(lam)), and —
on the mean-adjusted path — re-reads it a third time for the per-query
row sums.  This kernel produces each (block, block) K_q tile in VMEM from
the stored points (same squared-distance + ``kernel_epilogue`` recipe as
the fused ingest kernel ``rbf_gram/krow_fused.py``) and immediately
contracts it against the matching S row tile, accumulating the row sums
in the same pass — K_q never makes a trip to HBM, X and S are read once
per query tile, and the outputs (Y, rowsum) are everything the adjusted
centering needs as an affine post-correction.

Active-prefix pruning: m-tiles beyond ceil(m / block) are skipped via the
scalar-prefetched tile count (the masked K_q columns >= m are zero, and S
rows >= m are zero for active components — the state invariant), so the
pass costs O(Q·m·(d + C)), not O(Q·M·(d + C)).

Nyström query features ride the same kernel: S = U diag(pinv-ish scaling)
is just a different projection matrix, and the reconstruction
K̃_qq = Y diag(lam) Yᵀ then reuses the ``scaled_gram`` tile recipe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import kernels_fn as kf
from repro.kernels.rbf_gram.krow_fused import _clamp, kernel_epilogue

DEFAULT_BLOCK = 128


def _kernel(g_ref, xq_ref, x_ref, xn_ref, qn_ref, s_ref, y_ref, rs_ref,
            acc_ref, rs_acc_ref, *, m_steps: int, block: int, name: str,
            sigma: float, scale: float):
    k = pl.program_id(1)
    gc, m = g_ref[0], g_ref[1]

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        rs_acc_ref[...] = jnp.zeros_like(rs_acc_ref)

    @pl.when(k < gc)
    def _acc():
        dot = jax.lax.dot_general(
            xq_ref[...], x_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=acc_ref.dtype)
        d2 = jnp.maximum(
            qn_ref[...] + xn_ref[...] - 2.0 * dot.astype(acc_ref.dtype), 0.0)
        kq = kernel_epilogue(d2, name=name, sigma=sigma, scale=scale)
        cols = (k * block
                + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1))
        kqm = jnp.where(cols < m, kq, 0.0)
        acc_ref[...] += jax.lax.dot_general(
            kqm, s_ref[...].astype(acc_ref.dtype), (((1,), (0,)), ((), ())),
            preferred_element_type=acc_ref.dtype)
        rs_acc_ref[...] += jnp.sum(kqm, axis=1, keepdims=True)

    @pl.when(k == m_steps - 1)
    def _done():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)
        rs_ref[...] = rs_acc_ref[...].astype(rs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "block", "interpret"))
def transform_project(xq: jax.Array, x: jax.Array, s: jax.Array,
                      num_active: jax.Array, *, spec: kf.KernelSpec,
                      block: int = DEFAULT_BLOCK, interpret: bool = False
                      ) -> tuple[jax.Array, jax.Array]:
    """(Y, rowsum): Y = K_q_masked @ s and rowsum = K_q_masked @ 1, fused.

    xq: (Q, d) query points; x: (M, d) stored points; s: (M, C) projection
    matrix (component scaling already folded in).  K_q[i, j] =
    k(xq[i], x[j]) zeroed on columns >= num_active, never materialized.
    """
    Q, d = xq.shape
    M = x.shape[0]
    C = s.shape[1]
    dtype = s.dtype
    Qp = -(-Q // block) * block
    Mp = -(-M // block) * block
    dp = -(-d // 8) * 8
    Cp = max(8, -(-C // 8) * 8)

    m = jnp.asarray(num_active, jnp.int32)
    xqp = jnp.pad(xq.astype(dtype), ((0, Qp - Q), (0, dp - d)))
    xp = jnp.pad(x.astype(dtype), ((0, Mp - M), (0, dp - d)))
    qn = jnp.sum(xqp * xqp, axis=1, keepdims=True)           # (Qp, 1)
    xn = jnp.sum(xp * xp, axis=1).reshape(1, Mp)             # (1, Mp)
    sp = jnp.pad(s, ((0, Mp - M), (0, Cp - C)))

    steps_m = Mp // block
    g_cols = jnp.minimum(-(-m // block), steps_m)
    g = jnp.stack([g_cols, m]).astype(jnp.int32)
    acc_dtype = jnp.promote_types(dtype, jnp.float32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(Qp // block, steps_m),
        in_specs=[
            pl.BlockSpec((block, dp), lambda i, k, g: (i, 0)),       # xq
            pl.BlockSpec((block, dp),
                         lambda i, k, g: (_clamp(k, g[0]), 0)),      # x
            pl.BlockSpec((1, block),
                         lambda i, k, g: (0, _clamp(k, g[0]))),      # ||x||^2
            pl.BlockSpec((block, 1), lambda i, k, g: (i, 0)),        # ||xq||^2
            pl.BlockSpec((block, Cp),
                         lambda i, k, g: (_clamp(k, g[0]), 0)),      # s
        ],
        out_specs=[
            pl.BlockSpec((block, Cp), lambda i, k, g: (i, 0)),       # Y
            pl.BlockSpec((block, 1), lambda i, k, g: (i, 0)),        # rowsum
        ],
        scratch_shapes=[pltpu.VMEM((block, Cp), acc_dtype),
                        pltpu.VMEM((block, 1), acc_dtype)],
    )
    y, rs = pl.pallas_call(
        functools.partial(_kernel, m_steps=steps_m, block=block,
                          name=spec.name, sigma=float(spec.sigma),
                          scale=float(spec.scale)),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((Qp, Cp), dtype),
                   jax.ShapeDtypeStruct((Qp, 1), dtype)],
        interpret=interpret,
    )(g, xqp, xp, xn, qn, sp)
    return y[:Q, :C], rs[:Q, 0]
