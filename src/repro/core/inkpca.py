"""Incremental kernel PCA (paper §3, Algorithms 1 & 2).

State is fixed-capacity (capacity M, active count m) so a whole stream of
updates compiles once; see ``rankone.py`` for the padding invariants.

* ``update_unadjusted``  — Algorithm 1: expansion + 2 rank-one updates of the
  raw kernel matrix K.
* ``update_adjusted``    — Algorithm 2: 2 mean-adjustment updates of K', then
  expansion + 2 updates for the new row/column (4 rank-one updates total).

Both consume a precomputed kernel row ``a = [k(x_i, x_new)]`` and diagonal
value ``k_new = k(x_new, x_new)``; ``KPCAStream`` wires in the kernel-function
evaluation and an optional Pallas gram-row kernel, and ``update_stream`` runs
a scan over a block of points (one compilation, sequential semantics).
"""
from __future__ import annotations

from functools import partial
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf
from repro.core import rankone

Array = jax.Array


def _apply_pair(L, U, v1, sigma, v2, m, *, method, matmul, iters):
    """Apply the ±sigma update pair: fused double rotation when matmul is
    'jnp2'/'pallas2' (one pass over U, see rankone.rank_one_update_pair),
    two sequential rank-one updates otherwise."""
    if matmul in ("jnp2", "pallas2"):
        inner = "pallas" if matmul == "pallas2" else "jnp"
        return rankone.rank_one_update_pair(L, U, v1, sigma, v2, -sigma, m,
                                            method=method, matmul=inner,
                                            iters=iters)
    L, U = rankone.rank_one_update(L, U, v1, sigma, m, method=method,
                                   matmul=matmul, iters=iters)
    return rankone.rank_one_update(L, U, v2, -sigma, m, method=method,
                                   matmul=matmul, iters=iters)


class KPCAState(NamedTuple):
    """Fixed-capacity incremental KPCA state.

    L:  (M,)   eigenvalues (ascending; sentinels above the active spectrum)
    U:  (M,M)  eigenvectors in columns (identity on inactive columns)
    m:  ()     active count (int32)
    S:  ()     sum of all entries of the *unadjusted* K_mm          (Alg. 2)
    K1: (M,)   row sums K_mm @ 1_m, zero-padded                     (Alg. 2)
    X:  (M,d)  stored data points (needed to evaluate kernel rows)
    """

    L: Array
    U: Array
    m: Array
    S: Array
    K1: Array
    X: Array


def init_state(x0: Array, capacity: int, spec: kf.KernelSpec,
               *, adjusted: bool, dtype=jnp.float32) -> KPCAState:
    """Batch-initialize from m0 >= 1 seed points (eigh of the small gram)."""
    m0, d = x0.shape
    assert m0 <= capacity
    x0 = x0.astype(dtype)
    K0 = kf.gram_block(x0, x0, spec=spec)
    S = jnp.sum(K0)
    K1 = jnp.sum(K0, axis=1)
    Keff = kf.center_gram(K0) if adjusted else K0
    lam, vec = jnp.linalg.eigh(Keff)

    M = capacity
    L = jnp.zeros((M,), dtype)
    U = jnp.eye(M, dtype=dtype)
    L = L.at[:m0].set(lam.astype(dtype))
    U = U.at[:m0, :m0].set(vec.astype(dtype))
    m = jnp.asarray(m0, jnp.int32)
    L = rankone.sentinelize(L, m, jnp.zeros((), dtype))

    X = jnp.zeros((M, d), dtype).at[:m0].set(x0)
    K1p = jnp.zeros((M,), dtype).at[:m0].set(K1.astype(dtype))
    return KPCAState(L=L, U=U, m=m, S=S.astype(dtype), K1=K1p, X=X)


def _masked_row(state: KPCAState, x_new: Array, spec: kf.KernelSpec) -> tuple[Array, Array]:
    """Kernel row against stored points, zeroed beyond the active count."""
    a_full = kf.kernel_row(x_new, state.X, spec=spec)
    mask = rankone.active_mask(state.X.shape[0], state.m)
    a = jnp.where(mask, a_full, 0.0)
    k_new = kf.gram_block(x_new[None], x_new[None], spec=spec)[0, 0]
    return a, k_new


@partial(jax.jit, static_argnames=("method", "matmul", "iters"))
def update_unadjusted(state: KPCAState, a: Array, k_new: Array, x_new: Array,
                      *, method: str = "gu", matmul: str = "jnp",
                      iters: int = 62) -> KPCAState:
    """Algorithm 1: K_{m,m} -> K_{m+1,m+1} via expansion + 2 rank-one updates."""
    M = state.L.shape[0]
    m = state.m
    kn = jnp.maximum(k_new, jnp.finfo(state.L.dtype).tiny)  # sigma = 4/k guard

    # Bookkeeping for the unadjusted matrix (shared with Alg. 2 / Nyström).
    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    K1 = jnp.where(rankone.active_mask(M, m), state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    X = jax.lax.dynamic_update_slice(state.X, x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))

    # Expansion: eigenpair (k/4, e_m), then the two updates from paper eq. (2).
    L, U, m1 = rankone.expand_eigensystem(state.L, state.U, kn / 4.0, m)
    v1 = a.at[m].set(kn / 2.0)
    v2 = a.at[m].set(kn / 4.0)
    sigma = 4.0 / kn
    L, U = _apply_pair(L, U, v1, sigma, v2, m1, method=method, matmul=matmul,
                       iters=iters)
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


@partial(jax.jit, static_argnames=("method", "matmul", "iters"))
def update_adjusted(state: KPCAState, a: Array, k_new: Array, x_new: Array,
                    *, method: str = "gu", matmul: str = "jnp",
                    iters: int = 62) -> KPCAState:
    """Algorithm 2: K'_{m,m} -> K'_{m+1,m+1} via 4 rank-one updates.

    Follows the paper's derivation (§3.1.2); Alg. 2 line 4 contains an
    erratum (the square on m(m+1)) — we use the derived
    u = K1/(m(m+1)) - a/(m+1) + C/2 * 1_m, verified against direct
    construction of K' in the tests.
    """
    M = state.L.shape[0]
    m = state.m
    mf = m.astype(state.L.dtype)
    mask_m = rankone.active_mask(M, m)

    # --- Step 1: mean-adjustment of the existing m×m block (2 updates). ---
    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    C = -state.S / mf**2 + S2 / (mf + 1.0) ** 2
    u = (state.K1 / (mf * (mf + 1.0)) - a / (mf + 1.0) + 0.5 * C)
    u = jnp.where(mask_m, u, 0.0)
    ones_u_p = jnp.where(mask_m, 1.0 + u, 0.0)
    ones_u_m = jnp.where(mask_m, 1.0 - u, 0.0)
    L, U = _apply_pair(state.L, state.U, ones_u_p,
                       jnp.asarray(0.5, state.L.dtype), ones_u_m, m,
                       method=method, matmul=matmul, iters=iters)

    # --- Step 2: bookkeeping updates (paper lines 7-9). ---
    K1 = jnp.where(mask_m, state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    m_new_f = mf + 1.0

    # --- Step 3: new centered row/column v (paper line 10). ---
    k_vec = a.at[m].set(k_new)
    mask_m1 = rankone.active_mask(M, m + 1)
    v = k_vec - (jnp.sum(k_vec) + K1 - S2 / m_new_f) / m_new_f
    v = jnp.where(mask_m1, v, 0.0)
    v0 = v[m]
    v0 = jnp.where(jnp.abs(v0) < jnp.finfo(L.dtype).eps,
                   jnp.finfo(L.dtype).eps, v0)  # sigma = 4/v0 guard

    # --- Step 4: expansion + 2 updates (paper eq. (3)). ---
    L, U, m1 = rankone.expand_eigensystem(L, U, v0 / 4.0, m)
    v1 = v.at[m].set(v0 / 2.0)
    v2 = v.at[m].set(v0 / 4.0)
    sigma = 4.0 / v0
    L, U = _apply_pair(L, U, v1, sigma, v2, m1, method=method, matmul=matmul,
                       iters=iters)

    X = jax.lax.dynamic_update_slice(state.X, x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


class KPCAStream:
    """User-facing streaming driver around the jitted update functions.

    ``dispatch="bucketed"`` routes updates through ``repro.core.buckets``:
    each step runs at the smallest power-of-two bucket capacity holding
    the active set, so per-update cost scales with m instead of the fixed
    capacity M (one extra compilation per bucket visited; see buckets.py
    for the crossing/retrace cost model).
    """

    def __init__(self, x0: Array, capacity: int, spec: kf.KernelSpec, *,
                 adjusted: bool = True, method: Literal["gu", "bns"] = "gu",
                 matmul: Literal["jnp", "pallas", "jnp2", "pallas2"] = "jnp",
                 iters: int = 62, dtype=jnp.float32,
                 dispatch: Literal["fixed", "bucketed"] = "fixed",
                 min_bucket: int | None = None):
        self.spec = spec
        self.adjusted = adjusted
        self.method = method
        self.matmul = matmul
        self.iters = iters
        self.dispatch = dispatch
        self.min_bucket = min_bucket
        self.state = init_state(x0, capacity, spec, adjusted=adjusted,
                                dtype=dtype)

    def _bucket_kwargs(self) -> dict:
        kw = dict(adjusted=self.adjusted, method=self.method,
                  matmul=self.matmul, iters=self.iters)
        if self.min_bucket is not None:
            kw["min_bucket"] = self.min_bucket
        return kw

    def update(self, x_new: Array) -> KPCAState:
        if self.dispatch == "bucketed":
            from repro.core import buckets
            self.state = buckets.update(self.state, x_new, self.spec,
                                        **self._bucket_kwargs())
            return self.state
        a, k_new = _masked_row(self.state, x_new, self.spec)
        fn = update_adjusted if self.adjusted else update_unadjusted
        self.state = fn(self.state, a, k_new, x_new, method=self.method,
                        matmul=self.matmul, iters=self.iters)
        return self.state

    def update_block(self, xs: Array) -> KPCAState:
        """Scan over a block of points — one compilation, exact sequential
        semantics (the paper's per-point algorithm, amortized for TPU).
        Bucketed dispatch scans within a bucket and re-buckets at
        crossings, keeping the same sequential semantics."""
        if self.dispatch == "bucketed":
            from repro.core import buckets
            self.state = buckets.update_block(self.state, xs, self.spec,
                                              **self._bucket_kwargs())
            return self.state
        spec, adjusted = self.spec, self.adjusted
        method, matmul, iters = self.method, self.matmul, self.iters

        def step(state, x_new):
            a, k_new = _masked_row(state, x_new, spec)
            fn = update_adjusted if adjusted else update_unadjusted
            return fn(state, a, k_new, x_new, method=method, matmul=matmul,
                      iters=iters), None

        self.state, _ = jax.lax.scan(step, self.state, xs)
        return self.state

    def truncate(self, k: int) -> KPCAState:
        """Keep only the k dominant eigenpairs (paper conclusion: 'adapt the
        proposed algorithm to only maintain a subset') — subsequent updates
        then track the dominant subspace at O(k³)-per-update cost, trading
        exactness for the Hoegaerts-style subset regime."""
        st = self.state
        M = st.L.shape[0]
        mask = rankone.active_mask(M, st.m)
        order = jnp.argsort(jnp.where(mask, -st.L, jnp.inf))
        keep = order[:k]
        L = jnp.zeros_like(st.L).at[:k].set(st.L[keep])
        U = jnp.eye(M, dtype=st.U.dtype).at[:, :k].set(st.U[:, keep])
        m = jnp.minimum(st.m, jnp.asarray(k, st.m.dtype))
        L = rankone.sentinelize(L, m, jnp.zeros((), L.dtype))
        self.state = KPCAState(L=L, U=U, m=m, S=st.S, K1=st.K1, X=st.X)
        return self.state

    # ---- read-out utilities -------------------------------------------------
    def eigpairs(self) -> tuple[Array, Array]:
        """Active (descending) eigenvalues and eigenvectors."""
        st = self.state
        M = st.L.shape[0]
        mask = rankone.active_mask(M, st.m)
        order = jnp.argsort(jnp.where(mask, -st.L, jnp.inf))
        return st.L[order], st.U[:, order]

    def reconstruction(self) -> Array:
        return rankone.reconstruct(self.state.L, self.state.U, self.state.m)

    def transform(self, x: Array, n_components: int) -> Array:
        """Project new points on the leading kernel principal components."""
        st = self.state
        lam, vec = self.eigpairs()
        lam = lam[:n_components]
        vec = vec[:, :n_components]
        krow = kf.gram_block(x.astype(st.X.dtype), st.X, spec=self.spec)
        mask = rankone.active_mask(st.X.shape[0], st.m)
        krow = jnp.where(mask[None, :], krow, 0.0)
        if self.adjusted:
            mf = st.m.astype(st.L.dtype)
            rowmean = jnp.sum(krow, axis=1, keepdims=True) / mf
            colmean = (st.K1 / mf)[None, :]
            grand = st.S / mf**2
            krow = jnp.where(mask[None, :],
                             krow - rowmean - colmean + grand, 0.0)
        denom = jnp.sqrt(jnp.maximum(lam, jnp.finfo(st.L.dtype).eps))
        return (krow @ vec) / denom[None, :]
