"""Incremental kernel PCA (paper §3, Algorithms 1 & 2).

State is fixed-capacity (capacity M, active count m) so a whole stream of
updates compiles once; see ``rankone.py`` for the padding invariants.

* ``update_unadjusted``  — Algorithm 1: expansion + 2 rank-one updates of the
  raw kernel matrix K.
* ``update_adjusted``    — Algorithm 2: 2 mean-adjustment updates of K', then
  expansion + 2 updates for the new row/column (4 rank-one updates total).

Both consume a precomputed kernel row ``a = [k(x_i, x_new)]`` and diagonal
value ``k_new = k(x_new, x_new)``; ``KPCAStream`` wires in the kernel-function
evaluation and an optional Pallas gram-row kernel, and ``update_stream`` runs
a scan over a block of points (one compilation, sequential semantics).
"""
from __future__ import annotations

from functools import partial
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import kernels_fn as kf
from repro.core import rankone

Array = jax.Array


class KPCAState(NamedTuple):
    """Fixed-capacity incremental KPCA state.

    L:  (M,)   eigenvalues (ascending; sentinels above the active spectrum)
    U:  (M,M)  eigenvectors in columns (identity on inactive columns)
    m:  ()     active count (int32)
    S:  ()     sum of all entries of the *unadjusted* K_mm          (Alg. 2)
    K1: (M,)   row sums K_mm @ 1_m, zero-padded                     (Alg. 2)
    X:  (M,d)  stored data points (needed to evaluate kernel rows)
    """

    L: Array
    U: Array
    m: Array
    S: Array
    K1: Array
    X: Array


def init_state(x0: Array, capacity: int, spec: kf.KernelSpec,
               *, adjusted: bool, dtype=jnp.float32) -> KPCAState:
    """Batch-initialize from m0 >= 1 seed points (eigh of the small gram)."""
    m0, d = x0.shape
    assert m0 <= capacity
    x0 = x0.astype(dtype)
    K0 = kf.gram_block(x0, x0, spec=spec)
    S = jnp.sum(K0)
    K1 = jnp.sum(K0, axis=1)
    Keff = kf.center_gram(K0) if adjusted else K0
    lam, vec = jnp.linalg.eigh(Keff)

    M = capacity
    L = jnp.zeros((M,), dtype)
    U = jnp.eye(M, dtype=dtype)
    L = L.at[:m0].set(lam.astype(dtype))
    U = U.at[:m0, :m0].set(vec.astype(dtype))
    m = jnp.asarray(m0, jnp.int32)
    L = rankone.sentinelize(L, m, jnp.zeros((), dtype))

    X = jnp.zeros((M, d), dtype).at[:m0].set(x0)
    K1p = jnp.zeros((M,), dtype).at[:m0].set(K1.astype(dtype))
    return KPCAState(L=L, U=U, m=m, S=S.astype(dtype), K1=K1p, X=X)


def _masked_row(state: KPCAState, x_new: Array, spec: kf.KernelSpec) -> tuple[Array, Array]:
    """Kernel row against stored points, zeroed beyond the active count.
    (Canonical implementation lives in the engine layer.)"""
    return eng.masked_row(state, x_new, spec)


@partial(jax.jit, static_argnames=("plan",))
def update_unadjusted(state: KPCAState, a: Array, k_new: Array, x_new: Array,
                      *, plan: eng.UpdatePlan = eng.DEFAULT_PLAN
                      ) -> KPCAState:
    """Algorithm 1: K_{m,m} -> K_{m+1,m+1} via expansion + 2 rank-one updates."""
    M = state.L.shape[0]
    m = state.m
    kn = jnp.maximum(k_new, jnp.finfo(state.L.dtype).tiny)  # sigma = 4/k guard

    # Bookkeeping for the unadjusted matrix (shared with Alg. 2 / Nyström).
    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    K1 = jnp.where(rankone.active_mask(M, m), state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    X = jax.lax.dynamic_update_slice(state.X, x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))

    # Expansion: eigenpair (k/4, e_m), then the two updates from paper eq. (2).
    L, U, m1 = rankone.expand_eigensystem(state.L, state.U, kn / 4.0, m)
    v1 = a.at[m].set(kn / 2.0)
    v2 = a.at[m].set(kn / 4.0)
    sigma = 4.0 / kn
    L, U = eng.apply_pair(L, U, v1, sigma, v2, -sigma, m1, plan=plan)
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


@partial(jax.jit, static_argnames=("plan",))
def update_adjusted(state: KPCAState, a: Array, k_new: Array, x_new: Array,
                    *, plan: eng.UpdatePlan = eng.DEFAULT_PLAN
                    ) -> KPCAState:
    """Algorithm 2: K'_{m,m} -> K'_{m+1,m+1} via 4 rank-one updates.

    Follows the paper's derivation (§3.1.2); Alg. 2 line 4 contains an
    erratum (the square on m(m+1)) — we use the derived
    u = K1/(m(m+1)) - a/(m+1) + C/2 * 1_m, verified against direct
    construction of K' in the tests.
    """
    M = state.L.shape[0]
    m = state.m
    mf = m.astype(state.L.dtype)
    mask_m = rankone.active_mask(M, m)

    # --- Step 1: mean-adjustment of the existing m×m block (2 updates). ---
    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    C = -state.S / mf**2 + S2 / (mf + 1.0) ** 2
    u = (state.K1 / (mf * (mf + 1.0)) - a / (mf + 1.0) + 0.5 * C)
    u = jnp.where(mask_m, u, 0.0)
    ones_u_p = jnp.where(mask_m, 1.0 + u, 0.0)
    ones_u_m = jnp.where(mask_m, 1.0 - u, 0.0)
    half = jnp.asarray(0.5, state.L.dtype)
    L, U = eng.apply_pair(state.L, state.U, ones_u_p, half, ones_u_m, -half,
                          m, plan=plan)

    # --- Step 2: bookkeeping updates (paper lines 7-9). ---
    K1 = jnp.where(mask_m, state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    m_new_f = mf + 1.0

    # --- Step 3: new centered row/column v (paper line 10). ---
    k_vec = a.at[m].set(k_new)
    mask_m1 = rankone.active_mask(M, m + 1)
    v = k_vec - (jnp.sum(k_vec) + K1 - S2 / m_new_f) / m_new_f
    v = jnp.where(mask_m1, v, 0.0)
    v0 = v[m]
    v0 = jnp.where(jnp.abs(v0) < jnp.finfo(L.dtype).eps,
                   jnp.finfo(L.dtype).eps, v0)  # sigma = 4/v0 guard

    # --- Step 4: expansion + 2 updates (paper eq. (3)). ---
    L, U, m1 = rankone.expand_eigensystem(L, U, v0 / 4.0, m)
    v1 = v.at[m].set(v0 / 2.0)
    v2 = v.at[m].set(v0 / 4.0)
    sigma = 4.0 / v0
    L, U = eng.apply_pair(L, U, v1, sigma, v2, -sigma, m1, plan=plan)

    X = jax.lax.dynamic_update_slice(state.X, x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


# ------------------------------------------------------------ fused ingest --
# ``update_unadjusted``/``update_adjusted`` consume a precomputed kernel
# row and then let the rank-one machinery re-read U for every projection
# Uᵀv.  The ingest_* variants below instead run the fused
# ``kernels/rbf_gram.krow_project`` prologue: ONE pass over U produces the
# masked row a AND the projections of every update vector that lives in the
# pre-update basis.  The z vectors handed to ``eng.apply_pair`` are exact
# identities, not approximations:
#
# * pre-expansion, Uᵀe_m = e_m (column m is an identity column and active
#   columns vanish on row m), so the expansion pair's projections are
#   z = (Uᵀa).at[m].set(kn/2 | kn/4) permuted by the expansion sort;
# * Algorithm 2's mean-adjustment vectors 1±u are affine in (a, 1_m, K1),
#   so their projections are the same affine combination of the three
#   projected columns.
#
# Algorithm 2's second (expansion) pair cannot ride the krow prologue —
# its basis is the post-rotation U₁, which does not exist until the first
# pair runs — but its projection is still one rect-pruned
# ``eigvec_update.project_vectors`` pass (Uᵀ[v₁|v₂]) rather than a dense
# einsum, so no per-step dense pass over the (M, M) eigenvectors remains.


@partial(jax.jit, static_argnames=("spec", "plan"))
def ingest_unadjusted(state: KPCAState, x_new: Array, *, spec: kf.KernelSpec,
                      plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> KPCAState:
    """Algorithm 1 with the fused kernel-row prologue (plan.fuse_krow)."""
    from repro.kernels.rbf_gram import ops as kops

    M = state.L.shape[0]
    m = state.m
    dtype = state.L.dtype
    x_new = x_new.astype(state.X.dtype)
    k_new = kf.kernel_diag(x_new[None], spec=spec)[0].astype(dtype)
    kn = jnp.maximum(k_new, jnp.finfo(dtype).tiny)  # sigma = 4/k guard

    aux = jnp.zeros((M, 0), dtype)
    a, P = kops.krow_project(state.U, state.X, x_new, aux, m, spec=spec)
    p = P[:, 0]                                     # Uᵀa, pre-expansion

    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    K1 = jnp.where(rankone.active_mask(M, m), state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    X = jax.lax.dynamic_update_slice(state.X,
                                     x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))

    L, perm, m1 = rankone.expand_eigensystem_perm(state.L, kn / 4.0, m)
    U = state.U[:, perm]
    v1 = a.at[m].set(kn / 2.0)
    v2 = a.at[m].set(kn / 4.0)
    # Uᵀe_m = e_m and (Uᵀa)[m] = a[m] = 0 pre-expansion, so the expanded
    # basis's projections are p with slot m overwritten, permuted.
    z1 = p.at[m].set(kn / 2.0)[perm]
    z2 = p.at[m].set(kn / 4.0)[perm]
    sigma = 4.0 / kn
    L, U = eng.apply_pair(L, U, v1, sigma, v2, -sigma, m1, plan=plan,
                          z1=z1, z2=z2)
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


@partial(jax.jit, static_argnames=("spec", "plan"))
def ingest_adjusted(state: KPCAState, x_new: Array, *, spec: kf.KernelSpec,
                    plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> KPCAState:
    """Algorithm 2 with the fused kernel-row prologue (plan.fuse_krow).

    The mean-adjustment pair's projections come from the fused kernel
    (z_± = Uᵀ1_m ± Uᵀu as affine combinations of the projected columns);
    the expansion pair projects against the rotated U₁ through the
    rect-pruned ``project_vectors`` kernel.
    """
    from repro.kernels.rbf_gram import ops as kops

    M = state.L.shape[0]
    m = state.m
    dtype = state.L.dtype
    mf = m.astype(dtype)
    mask_m = rankone.active_mask(M, m)
    x_new = x_new.astype(state.X.dtype)
    k_new = kf.kernel_diag(x_new[None], spec=spec)[0].astype(dtype)

    # One fused pass: a plus Uᵀ[a | 1_m | K1] (the kernel masks rows >= m).
    aux = jnp.stack([jnp.ones((M,), dtype), state.K1], axis=1)
    a, P = kops.krow_project(state.U, state.X, x_new, aux, m, spec=spec)
    pa, p1, pk1 = P[:, 0], P[:, 1], P[:, 2]

    # --- Step 1: mean-adjustment of the existing m×m block (2 updates). ---
    sum_a = jnp.sum(a)
    S2 = state.S + 2.0 * sum_a + k_new
    C = -state.S / mf**2 + S2 / (mf + 1.0) ** 2
    u = (state.K1 / (mf * (mf + 1.0)) - a / (mf + 1.0) + 0.5 * C)
    u = jnp.where(mask_m, u, 0.0)
    ones_u_p = jnp.where(mask_m, 1.0 + u, 0.0)
    ones_u_m = jnp.where(mask_m, 1.0 - u, 0.0)
    zu = pk1 / (mf * (mf + 1.0)) - pa / (mf + 1.0) + 0.5 * C * p1
    half = jnp.asarray(0.5, dtype)
    L, U = eng.apply_pair(state.L, state.U, ones_u_p, half, ones_u_m, -half,
                          m, plan=plan, z1=p1 + zu, z2=p1 - zu)

    # --- Steps 2-4: identical to ``update_adjusted`` (expansion unfused). ---
    K1 = jnp.where(mask_m, state.K1 + a, 0.0)
    K1 = K1.at[m].set(sum_a + k_new)
    m_new_f = mf + 1.0

    k_vec = a.at[m].set(k_new)
    mask_m1 = rankone.active_mask(M, m + 1)
    v = k_vec - (jnp.sum(k_vec) + K1 - S2 / m_new_f) / m_new_f
    v = jnp.where(mask_m1, v, 0.0)
    v0 = v[m]
    v0 = jnp.where(jnp.abs(v0) < jnp.finfo(L.dtype).eps,
                   jnp.finfo(L.dtype).eps, v0)  # sigma = 4/v0 guard

    L, U, m1 = rankone.expand_eigensystem(L, U, v0 / 4.0, m)
    v1 = v.at[m].set(v0 / 2.0)
    v2 = v.at[m].set(v0 / 4.0)
    sigma = 4.0 / v0
    # The expansion pair's basis is the rotated U₁ (it does not exist
    # before the first pair runs), so its projections cannot ride the
    # krow prologue — but they are still one rect-pruned kernel pass
    # (Uᵀ[v₁|v₂]) instead of the dense einsum rank_one_update_pair would
    # otherwise run.  Post-expansion both v's vanish on rows >= m1 and
    # inactive columns are identity columns on that masked region, so the
    # pruned projection is exact.
    from repro.kernels.eigvec_update import ops as eops
    Z = eops.project_vectors(U, jnp.stack([v1, v2], axis=1), m1)
    L, U = eng.apply_pair(L, U, v1, sigma, v2, -sigma, m1, plan=plan,
                          z1=Z[:, 0], z2=Z[:, 1])

    X = jax.lax.dynamic_update_slice(state.X,
                                     x_new[None].astype(state.X.dtype),
                                     (m, jnp.zeros((), m.dtype)))
    return KPCAState(L=L, U=U, m=m1, S=S2, K1=K1, X=X)


class KPCAStream:
    """User-facing streaming driver — a thin shell over ``engine.Engine``.

    All dispatch decisions (bucket selection, fused-pair vs sequential,
    merge fallback, compaction) live in the engine's ``UpdatePlan``; pass
    one directly via ``plan=`` or use the legacy keyword spellings
    (``method``/``matmul``/``iters``/``dispatch``/``min_bucket``), which
    are folded into a plan here and nowhere else.

    ``dispatch="bucketed"`` runs each step at the smallest power-of-two
    bucket capacity holding the active set, so per-update cost scales with
    m instead of the fixed capacity M (one extra compilation per bucket
    visited; see engine.py for the crossing/retrace cost model).

    ``window=W`` turns the stream into a **sliding window** over the
    trailing W points: ingesting past a full window first evicts the
    oldest point via the decremental pipeline (``core/downdate.py``), so
    memory and per-step cost are bounded on unbounded streams.  In this
    mode ``self.state`` is a ``window.WindowState`` — the eigensystem
    plus a FIFO arrival ring, so eviction order survives checkpoint
    round-trips; ``kpca_state`` always exposes the inner ``KPCAState``.
    """

    def __init__(self, x0: Array, capacity: int, spec: kf.KernelSpec, *,
                 adjusted: bool = True, plan: eng.UpdatePlan | None = None,
                 method: Literal["gu", "bns"] = "gu",
                 matmul: Literal["jnp", "pallas", "jnp2", "pallas2"] = "jnp",
                 iters: int | None = None, dtype=jnp.float32,
                 dispatch: Literal["fixed", "bucketed"] = "fixed",
                 min_bucket: int | None = None,
                 window: int | None = None):
        from repro.core import window as wnd

        if plan is None:
            plan = eng.UpdatePlan(
                method=method, matmul=matmul, iters=iters, dispatch=dispatch,
                min_bucket=(min_bucket if min_bucket is not None
                            else eng.DEFAULT_MIN_BUCKET),
                window=window)
        if window is None:
            window = plan.window
        self.spec = spec
        self.adjusted = adjusted
        self.plan = plan
        self.window = window
        self.engine = eng.Engine(spec, plan, adjusted=adjusted)
        if window is not None:
            if not 2 <= window <= capacity:
                raise ValueError(f"window must be in [2, capacity], got "
                                 f"{window} (capacity {capacity})")
            if int(jnp.asarray(x0).shape[0]) > window:
                raise ValueError(f"seed size {jnp.asarray(x0).shape[0]} "
                                 f"exceeds window {window}")
            self.state = wnd.init_window(x0, capacity, spec,
                                         adjusted=adjusted, dtype=dtype)
        else:
            self.state = init_state(x0, capacity, spec, adjusted=adjusted,
                                    dtype=dtype)
        # Row-support floor for bucket selection: a truncated, uncompacted
        # state keeps eigenvector mass on rows beyond m (see Engine.truncate).
        self._min_rows = 0
        # Self-healing layer (core/health.py): with plan.health set, every
        # update routes through the guarded dispatches — input quarantine
        # plus in-graph probes riding along in self.health.
        self.health = None
        if plan.health is not None:
            from repro.core import health as hl
            self.health = hl.init_health(self.kpca_state.L.dtype)
        # Telemetry lane (core/telemetry.py): with plan.metrics set, a
        # MetricsState rides the stream.  The eigensystem still goes
        # through the IDENTICAL dispatches — each update is followed by
        # one tiny separate note dispatch, so metrics-on state is bitwise
        # metrics-off state.
        self.metrics = None
        if plan.metrics:
            from repro.core import telemetry as tm
            self.metrics = tm.init_metrics(self.kpca_state.L.dtype)

    @property
    def kpca_state(self) -> KPCAState:
        """The eigensystem state, regardless of windowing."""
        return self.state.kpca if self.window is not None else self.state

    def _bundle(self) -> eng.StreamState:
        """The stream's whole mutable state as ONE pipeline bundle: the
        eigensystem, plus the arrival ring / HealthState / MetricsState
        exactly when the plan carries the matching stage."""
        return eng.make_stream(self.state, health=self.health,
                               metrics=self.metrics)

    def _unbundle(self, s: eng.StreamState):
        """Write an advanced bundle back into the stream's attributes and
        return ``self.state`` (the legacy return convention)."""
        if self.window is not None:
            from repro.core import window as wnd
            self.state = wnd.WindowState(kpca=s.kpca, ages=s.ages,
                                         clock=s.clock)
        else:
            self.state = s.kpca
        self.health = s.health
        self.metrics = s.metrics
        return self.state

    def update(self, x_new: Array):
        """One point through the composed gate→evict|ingest→note pipeline
        (``engine.Engine.step``) — the bundle's structure, set from the
        plan at construction, selects the stages."""
        return self._unbundle(self.engine.step(
            self._bundle(), x_new, window=self.window,
            min_rows=self._min_rows))

    def downdate(self, i: int):
        """Remove point ``i`` (physical row) from the stream."""
        if self.window is not None:
            from repro.core import window as wnd
            self.state = wnd.evict(self.engine, self.state, i,
                                   min_rows=self._min_rows)
        else:
            self.state = self.engine.downdate(self.state, i,
                                              min_rows=self._min_rows)
        if self.metrics is not None:
            from repro.core import telemetry as tm
            self.metrics = tm.note_downdate(self.metrics,
                                            self.kpca_state.m)
        return self.state

    def update_block(self, xs: Array):
        """Scan over a block of points — one compilation, exact sequential
        semantics (the paper's per-point algorithm, amortized for TPU).
        Bucketed dispatch scans within a bucket and re-buckets at
        crossings, keeping the same sequential semantics.  A windowed
        stream routes through ``Engine.window_block``: growth points scan
        append-only, and once the window fills the evict+ingest pairs run
        as ONE scanned dispatch per block (fixed shape at m ≡ W) instead
        of the old per-point host-decided stepping."""
        return self._unbundle(self.engine.step_block(
            self._bundle(), xs, window=self.window,
            min_rows=self._min_rows))

    # sklearn-style spelling for streaming consumers: identical semantics.
    partial_fit_block = update_block

    # ---- self-healing (core/health.py) ------------------------------------
    def heal(self, *, level: str = "auto"):
        """Walk the heal ladder on the stream's state (polish → resync;
        ``health.HealthError`` escalates to restore-from-checkpoint).
        Clears the sticky probe flags so post-heal probes start clean."""
        rung_out: list = []
        self.state = self.engine.heal(self.state, level=level,
                                      rung_out=rung_out)
        if self.health is not None:
            self.health = self.health._replace(
                nonfinite=jnp.zeros((), jnp.int32),
                orth_err=jnp.zeros((), self.health.orth_err.dtype))
        if self.metrics is not None and rung_out:
            from repro.core import telemetry as tm
            self.metrics = tm.note_heal(self.metrics, rung_out[-1])
        return self.state

    def health_report(self) -> dict:
        """Host-side snapshot of the riding HealthState (one sync)."""
        if self.health is None:
            return {}
        h = self.health
        return {"orth_err": float(h.orth_err), "neg_frac": float(h.neg_frac),
                "nonfinite": int(h.nonfinite),
                "quarantined": int(h.quarantined),
                "rejected_last": int(h.rejected_last),
                "probes": int(h.probes), "spec_drift": float(h.spec_drift)}

    def is_healthy(self) -> bool:
        """Verdict of the last in-graph probe against the plan policy."""
        if self.health is None:
            return True
        from repro.core import health as hl
        return hl.is_healthy(self.health, self.plan.health)

    def metrics_report(self) -> dict:
        """Host snapshot of the riding MetricsState (one sync); empty
        without ``plan.metrics``."""
        if self.metrics is None:
            return {}
        from repro.core import telemetry as tm
        return tm.metrics_report(self.metrics)

    def truncate(self, k: int, *, compact: bool | None = None) -> KPCAState:
        """Keep only the k dominant eigenpairs (paper conclusion: 'adapt the
        proposed algorithm to only maintain a subset') — subsequent updates
        then track the dominant subspace at O(k³)-per-update cost, trading
        exactness for the Hoegaerts-style subset regime.

        With ``compact`` (default: ``plan.compact_shrink``) the state is
        re-expressed on its leading k rows and the arrays shrink to the
        active bucket; without it the old rows keep eigenvector support
        and bucketed dispatch keeps slicing at the old active count.
        That support floor is host-side stream state — it does NOT
        survive a checkpoint, so compact a truncated stream before
        saving it mid-stream.
        """
        if self.window is not None:
            raise ValueError("truncate is not supported on a windowed "
                             "stream — the window itself bounds the state")
        if compact is None:
            compact = self.plan.compact_shrink
        support = max(int(self.state.m), self._min_rows)
        self.state = self.engine.truncate(self.state, k, compact=compact)
        self._min_rows = 0 if compact else support
        return self.state

    # ---- read-out utilities -------------------------------------------------
    def eigpairs(self) -> tuple[Array, Array]:
        """Active (descending) eigenvalues and eigenvectors."""
        return eng.eigpairs(self.kpca_state)

    def reconstruction(self) -> Array:
        st = self.kpca_state
        return rankone.reconstruct(st.L, st.U, st.m)

    def transform(self, x: Array, n_components: int) -> Array:
        """Project new points on the leading kernel principal components.

        Under ``plan.fuse_krow`` the projection runs the fused
        query-gram+projection kernel; with bucketed dispatch the state is
        first sliced to the smallest bucket holding the active set (the
        slice is lossless — engine invariants), so the transform costs
        O(Q·m_b·(d+k)) instead of O(Q·M·(d+k)) at small active counts."""
        st = self.kpca_state
        if self.plan.fuse_krow and self.plan.dispatch == "bucketed":
            need = max(int(st.m), self._min_rows, n_components, 1)
            Mb = eng.bucket_for(need, st.L.shape[0], self.plan.min_bucket)
            if Mb < st.L.shape[0]:
                st = eng.slice_state(st, Mb)
        return eng.transform_state(st, x, spec=self.spec,
                                   adjusted=self.adjusted,
                                   n_components=n_components,
                                   plan=self.plan)
