"""Kernel functions k(x, y) and related utilities.

The paper uses the RBF kernel k(x,y) = exp(-||x-y||^2 / sigma) with sigma set
by the median heuristic (median of pairwise squared distances over a subset).
We additionally provide linear, polynomial and Matern-3/2 kernels so the
incremental eigendecomposition machinery is exercised on kernels with
non-constant diagonal (k(x,x) != 1), which the paper notes as the general case.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclass(frozen=True)
class KernelSpec:
    """Declarative kernel configuration (hashable, jit-static)."""

    name: str = "rbf"
    sigma: float = 1.0          # RBF / matern bandwidth
    degree: int = 3             # polynomial degree
    coef0: float = 1.0          # polynomial bias
    scale: float = 1.0          # output scale

    def fn(self) -> Callable[[Array, Array], Array]:
        return functools.partial(gram_block, spec=self)


def _sqdist(x: Array, y: Array) -> Array:
    """Pairwise squared euclidean distances, (n,d),(m,d) -> (n,m)."""
    xn = jnp.sum(x * x, axis=-1)[:, None]
    yn = jnp.sum(y * y, axis=-1)[None, :]
    d2 = xn + yn - 2.0 * (x @ y.T)
    return jnp.maximum(d2, 0.0)


def gram_block(x: Array, y: Array, *, spec: KernelSpec) -> Array:
    """Dense gram block K[i,j] = k(x_i, y_j). Pure-jnp reference path.

    The tiled Pallas kernel in ``repro.kernels.rbf_gram`` implements the RBF
    case; this function is the oracle for it and the general fallback.
    """
    if spec.name == "rbf":
        return spec.scale * jnp.exp(-_sqdist(x, y) / spec.sigma)
    if spec.name == "linear":
        return spec.scale * (x @ y.T)
    if spec.name == "poly":
        return spec.scale * (x @ y.T + spec.coef0) ** spec.degree
    if spec.name == "matern32":
        r = jnp.sqrt(_sqdist(x, y) + 1e-30)
        a = jnp.sqrt(3.0) * r / spec.sigma
        return spec.scale * (1.0 + a) * jnp.exp(-a)
    raise ValueError(f"unknown kernel {spec.name!r}")


def kernel_row(x_new: Array, xs: Array, *, spec: KernelSpec) -> Array:
    """a = [k(x_1, x_new), ..., k(x_m, x_new)] — the streaming hot path."""
    return gram_block(xs, x_new[None, :], spec=spec)[:, 0]


def constant_diag(spec: KernelSpec) -> float | None:
    """k(x, x) when it is input-independent (stationary kernels: RBF,
    Matérn), else None — lets consumers evaluate diagonal sums without
    the row points (see ``nystrom.trace_error``)."""
    return spec.scale if spec.name in ("rbf", "matern32") else None


def kernel_diag(x: Array, *, spec: KernelSpec) -> Array:
    """k(x_i, x_i) for each row — O(n) (constant 'scale' for RBF)."""
    if spec.name == "rbf":
        return jnp.full((x.shape[0],), spec.scale, x.dtype)
    if spec.name == "linear":
        return spec.scale * jnp.sum(x * x, axis=-1)
    if spec.name == "poly":
        return spec.scale * (jnp.sum(x * x, axis=-1) + spec.coef0) ** spec.degree
    if spec.name == "matern32":
        return jnp.full((x.shape[0],), spec.scale, x.dtype)
    raise ValueError(f"unknown kernel {spec.name!r}")


def median_heuristic(x: Array, max_points: int = 512) -> Array:
    """sigma = median of pairwise squared distances over a subset (paper §5)."""
    sub = x[:max_points]
    d2 = _sqdist(sub, sub)
    iu = jnp.triu_indices(sub.shape[0], k=1)
    return jnp.median(d2[iu])


def center_gram(K: Array) -> Array:
    """Mean-adjusted kernel matrix K' = (I-1)K(I-1), eq. (1) of the paper."""
    n = K.shape[0]
    one = jnp.full((n, n), 1.0 / n, K.dtype)
    return K - one @ K - K @ one + one @ K @ one
