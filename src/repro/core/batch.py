"""Batch oracles and baseline incremental algorithms (paper §2.3 comparisons).

* ``batch_kpca``      — eigh of the (optionally centered) gram matrix; the
  exactness oracle used by every test and the drift benchmark.
* ``rotated_eigh_step`` — the *dense small-problem* incremental baseline: the
  update to K' is expressed in the current eigenbasis Q = blockdiag(U, 1),
  the (m+1)x(m+1) projected matrix is eigendecomposed and U rotated.  This
  performs exactly the operation mix the paper attributes to Chin & Suter
  (2007) — one small eigh (~9m^3 flops) plus an m×m matmul (2m^3) — minus
  their extra eigh of the unadjusted kernel matrix, i.e. it is a *stronger*
  version of that baseline (~11m^3 vs their ~20m^3 vs ours ~8m^3).
* ``hoegaerts_step``  — the unadjusted two-rank-one-update scheme of
  Hoegaerts et al. (2007) coincides with Algorithm 1; provided as an alias.

All baselines produce exact eigendecompositions (up to fp error), so tests
cross-check all algorithms against each other and against ``batch_kpca``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf

Array = jax.Array


def batch_kpca(K: Array, *, adjusted: bool) -> tuple[Array, Array]:
    """Oracle: eigendecomposition (ascending) of K or the centered K'."""
    Keff = kf.center_gram(K) if adjusted else K
    return jnp.linalg.eigh(Keff)


def refit_state(state, spec: kf.KernelSpec, *, adjusted: bool):
    """From-scratch re-fit oracle: rebuild a padded ``KPCAState`` by batch
    KPCA of the stored active points X[:m] — the baseline the heal
    ladder's in-place ``health.resync`` is benchmarked against (resync
    skips the stream replay and the gram's host round-trip, but both end
    at the same eigensystem).  Returns a state with identical capacity,
    padding sentinels and running sums to a fresh ``inkpca.init_state``
    of the same points."""
    from repro.core import inkpca

    m = int(state.m)
    return inkpca.init_state(state.X[:m], state.L.shape[0], spec,
                             adjusted=adjusted, dtype=state.L.dtype)


@partial(jax.jit)
def rotated_eigh_step(L: Array, U: Array, Kprev: Array, Knew: Array
                      ) -> tuple[Array, Array]:
    """Chin–Suter-class baseline: one incremental step via projected eigh.

    L, U: eigendecomposition of the centered K' of the first m points
    Kprev: unadjusted m×m gram, Knew: unadjusted (m+1)×(m+1) gram.
    Returns eigendecomposition of the centered (m+1)×(m+1) K'.
    """
    m = L.shape[0]
    Kp_new = kf.center_gram(Knew)
    # Q = blockdiag(U, 1) spans R^{m+1}; project, eigh, rotate.
    Kp_old = (U * L[None, :]) @ U.T
    delta = Kp_new - jnp.pad(Kp_old, ((0, 1), (0, 1)))
    Q = jnp.pad(U, ((0, 1), (0, 1))).at[m, m].set(1.0)
    small = jnp.diag(jnp.pad(L, (0, 1))) + Q.T @ delta @ Q
    lam, V = jnp.linalg.eigh(small)
    return lam, Q @ V   # one (m+1)x(m+1) matmul — the baseline's hot spot


# Alias: the unadjusted-case baseline of Hoegaerts et al. (2007) performs the
# same two symmetric rank-one updates as our Algorithm 1.
from repro.core.inkpca import update_unadjusted as hoegaerts_step  # noqa: E402,F401


def flop_model(m: int) -> dict[str, float]:
    """Leading-order flop counts per incremental step at size m (paper §3).

    Paper's accounting: a rank-one eigenvector update costs one m×m matmul
    (2m^3); QR-algorithm eigh ~ 9m^3; Chin & Suter: eigh(m+2) + eigh(m) +
    m×m matmul ~ 20m^3.
    """
    return {
        "ours_adjusted": 8.0 * m**3,        # 4 rank-one updates × 2m^3
        "ours_unadjusted": 4.0 * m**3,      # 2 rank-one updates × 2m^3
        "chin_suter_2007": 20.0 * m**3,     # paper's cited cost
        "rotated_eigh_baseline": 11.0 * m**3,  # eigh(m+1) + rotate
        "batch_eigh": 9.0 * m**3,           # recompute from scratch
    }
