"""Self-healing layer: in-graph health probes, input quarantine, heal ladder.

The rank-one eigendecomposition updates (paper Algorithms 1–2) are exact
in theory but accumulate floating-point error over unbounded streams, and
a single non-finite input poisons ``U`` forever.  This module gives every
consumer (stream, window scan, multi-tenant batch, Nyström tracker,
sharded mesh, serving loop) three things:

**In-graph probes** (``probe``) — a cheap O(M·B) sampled orthogonality
residual, eigenvalue-negativity and non-finite flags, computed INSIDE the
existing update/window dispatches.  A ``HealthState`` pytree rides along
the ``KPCAState`` exactly the way the arrival ring rides ``WindowState``:
no extra host sync, no extra dispatch.  The probe rotates through the
active eigenvector columns (``probes`` counts dispatches and picks the
next B columns each time), so a slowly drifting column is caught within
ceil(m/B) dispatches while each individual probe stays O(M·B).

**Input quarantine** (``_gate`` inside the guarded dispatches) — a
non-finite (or, optionally, kernel-row-outlier) point is rejected BEFORE
the rank-one pair fires.  The rejection is spelled sanitize + per-leaf
``jnp.where`` select, NOT ``lax.cond``: the update body executes
unconditionally on a sanitized stand-in (the stored seed row), and the
select discards it.  That keeps the collective schedule of the scanned
window block and the sharded paths FIXED (the same deadlock-free
discipline as the merge fallback — see ``core/distributed.py``), works
identically under vmap, and makes a rejected step return the prior state
bitwise (``where(False, new, old)`` copies ``old``'s bits; the guarded
dispatches additionally select at the FULL state so bucketed
scatter-sentinel regeneration cannot perturb a rejected step either).

**The heal ladder** (``heal_kpca`` / ``Engine.heal``) — escalation:

    polish   — QR re-orthonormalization of the eigenvector block;
               eigenvalues untouched.  O(M³) but heals only the loss of
               orthogonality; preserves the padding invariants exactly
               (active columns vanish on rows ≥ m, so Gram–Schmidt never
               mixes mass into the inactive identity columns).
    resync   — exact re-diagonalization from the stored active points,
               mirroring ``inkpca.init_state`` (gram, optional centering,
               eigh): post-heal state matches batch KPCA of the same
               window by construction.  Also rebuilds S/K1 bookkeeping.
    restore  — the stored points themselves are corrupt: raise
               ``HealthError`` so the caller reloads the last checkpoint
               (``checkpoint/npz_store.load_checkpoint``), whose
               crash-atomicity the fault suite now actually tests.

``level="auto"`` walks the ladder from the cheapest rung that the exact
(host-side, O(M²·m)) residual says will work.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import kernels_fn as kf
from repro.core import rankone

Array = jax.Array


class HealthError(RuntimeError):
    """Raised when in-place healing cannot proceed (restore rung): the
    stored points are themselves corrupt, so the only exact recovery is
    reloading the last good checkpoint."""


class HealthPolicy(NamedTuple):
    """Plan-level health configuration — hashable, so it can ride
    ``UpdatePlan.health`` as a jit-static field (like ``window`` and
    ``landmark_policy``).

    probe_cols:  columns sampled per orthogonality probe (B); the probe
                 costs O(M·B) and rotates, covering all m active columns
                 every ceil(m/B) dispatches
    orth_tol:    healthy-threshold on the sampled residual
                 max_j ‖(UᵀU − I) e_j‖₂ — crossing it is the heal trigger
    neg_tol:     relative eigenvalue-negativity tolerance: the gram (or
                 centered gram) is PSD, so min(L) < −neg_tol·max|L| flags
                 corruption.  Small negatives near 0 are normal f32
                 noise — centering deflates one dimension to a slightly
                 negative eigenvalue that healthy adjusted streams carry
                 at up to ~2e-3·max|L| when the spectrum is small — so
                 the default stays well above that floor while still
                 flagging genuinely negative eigenvalues (corruption
                 shows relative negativity near 1)
    quarantine:  reject non-finite inputs in-graph (zero state mutation)
    outlier_tol: kernel-row outlier gate — reject a point whose masked
                 kernel row carries almost no mass against the stored
                 points: max_i|a_i| < outlier_tol·k(x,x).  0 disables
                 (linear kernels can have legitimately tiny rows).
    polish_max:  largest exact residual ``heal(level='auto')`` still
                 hands to the cheap polish rung; beyond it (or when
                 eigenvalues are implicated) auto escalates to resync
    drift_tol:   staleness-aware publication threshold: relative L2
                 drift of the working top-C spectrum vs the spectrum
                 frozen into the front snapshot that triggers a republish
                 (``launch/serve.IngestServeLoop``)
    """

    probe_cols: int = 8
    orth_tol: float = 1e-3
    neg_tol: float = 1e-2
    quarantine: bool = True
    outlier_tol: float = 0.0
    polish_max: float = 1e-2
    drift_tol: float = 0.05


DEFAULT_POLICY = HealthPolicy()


class HealthState(NamedTuple):
    """Probe results + quarantine counters — a small pytree of scalars
    that rides along the eigensystem state through the guarded
    dispatches (device-resident; reading it is the caller's sync).

    orth_err:      last sampled orthogonality residual
                   max_j ‖(UᵀU − I) e_j‖₂ over the probed columns
    neg_frac:      relative negativity of the most negative active
                   eigenvalue, max(0, −min L)/max|L| (0 when PSD holds)
    nonfinite:     sticky flag: 1 once any probe saw a non-finite
                   eigenvalue/eigenvector entry (cleared by ``heal``)
    quarantined:   points rejected by the input gate so far
    rejected_last: 1 iff the MOST RECENT offered point was rejected
    probes:        probe dispatch counter (drives column rotation)
    spec_drift:    relative top-C spectral drift vs. the reference
                   spectrum of the last published snapshot; −1 when no
                   reference has been folded in yet
    """

    orth_err: Array
    neg_frac: Array
    nonfinite: Array
    quarantined: Array
    rejected_last: Array
    probes: Array
    spec_drift: Array


def init_health(dtype=jnp.float32) -> HealthState:
    z = jnp.zeros((), dtype)
    zi = jnp.zeros((), jnp.int32)
    return HealthState(orth_err=z, neg_frac=z, nonfinite=zi, quarantined=zi,
                       rejected_last=zi, probes=zi,
                       spec_drift=jnp.asarray(-1.0, dtype))


# ------------------------------------------------------------- probes --
def top_spectrum(state, C: int) -> Array:
    """Descending top-C active eigenvalues, zero-padded past m (traced)."""
    M = state.L.shape[0]
    mask = rankone.active_mask(M, state.m)
    order = jnp.argsort(jnp.where(mask, -state.L, jnp.inf))
    lam = state.L[order[:C]]
    return jnp.where(jnp.arange(C) < state.m, lam, 0.0)


def spectral_drift(state, ref_lam: Array) -> Array:
    """Relative L2 distance of the working top-C spectrum from a frozen
    reference — the staleness signal for drift-triggered publication."""
    cur = top_spectrum(state, ref_lam.shape[0])
    tiny = jnp.asarray(jnp.finfo(cur.dtype).tiny, cur.dtype)
    return (jnp.linalg.norm(cur - ref_lam)
            / jnp.maximum(jnp.linalg.norm(ref_lam), tiny))


def probe(state, hstate: HealthState, policy: HealthPolicy,
          ref_lam: Array | None = None) -> HealthState:
    """One in-graph health probe of a KPCAState-like (L, U, m) pytree.

    O(M·B) matmul + O(M) reductions: B rotating active columns are
    checked for orthogonality against the whole basis (which also
    catches row-support violations — an inactive row r carrying mass
    shows up in the r-th entry of UᵀU e_j), the active spectrum for
    negativity and non-finiteness.  Pure function of scalars-in /
    scalars-out: safe under jit, scan and vmap, no host sync.
    """
    L, U, m = state.L, state.U, state.m
    M = L.shape[0]
    dtype = L.dtype
    B = max(1, min(int(policy.probe_cols), M))
    mm = jnp.maximum(m, 1)
    idx = (hstate.probes * B + jnp.arange(B, dtype=jnp.int32)) % mm
    cols = jnp.take(U, idx, axis=1)                      # (M, B)
    E = U.T @ cols - jax.nn.one_hot(idx, M, dtype=dtype).T
    orth = jnp.sqrt(jnp.max(jnp.sum(E * E, axis=0)))
    act = rankone.active_mask(M, m)
    Lact = jnp.where(act, L, 0.0)
    lmax = jnp.max(jnp.abs(Lact))
    tiny = jnp.asarray(jnp.finfo(dtype).tiny, dtype)
    neg = jnp.maximum(-jnp.min(Lact), 0.0) / jnp.maximum(lmax, tiny)
    finite = (jnp.all(jnp.isfinite(Lact)) & jnp.all(jnp.isfinite(cols))
              & jnp.isfinite(orth))
    bad = (~finite).astype(jnp.int32)
    drift = (spectral_drift(state, ref_lam) if ref_lam is not None
             else hstate.spec_drift)
    return hstate._replace(
        orth_err=orth.astype(dtype), neg_frac=neg.astype(dtype),
        nonfinite=jnp.maximum(hstate.nonfinite, bad),
        probes=hstate.probes + 1,
        spec_drift=jnp.asarray(drift, dtype))


def verdict(hstate: HealthState, policy: HealthPolicy) -> Array:
    """Traced healthy/unhealthy boolean from the last probe."""
    return ((hstate.nonfinite == 0)
            & (hstate.orth_err <= policy.orth_tol)
            & (hstate.neg_frac <= policy.neg_tol))


def is_healthy(hstate: HealthState, policy: HealthPolicy) -> bool:
    """Host-side spelling of ``verdict`` (forces a sync — call once per
    block, not per point)."""
    return bool(verdict(hstate, policy))


@partial(jax.jit, static_argnames=("policy",))
def _probe_jit(state, hstate, policy):
    return probe(state, hstate, policy)


@partial(jax.jit, static_argnames=("policy",))
def _probe_ref_jit(state, hstate, policy, ref_lam):
    return probe(state, hstate, policy, ref_lam)


# -------------------------------------------------------- input gate --
def _gate(sub, x_new: Array, spec: kf.KernelSpec, policy: HealthPolicy
          ) -> tuple[Array, Array]:
    """Quarantine decision + sanitized stand-in for one offered point.

    Returns ``(ok, x_safe)``: ``ok`` is a traced boolean, ``x_safe`` is
    the point itself when accepted and the stored seed row ``X[0]`` when
    rejected — a well-conditioned stand-in (a real, finite point of the
    stream) so the unconditionally-executed update body cannot overflow,
    and its result is discarded by the caller's select anyway.
    """
    x_new = jnp.asarray(x_new, sub.X.dtype)
    if not policy.quarantine:
        return jnp.ones((), jnp.bool_), x_new
    ok = jnp.all(jnp.isfinite(x_new))
    stand_in = sub.X[0]
    if policy.outlier_tol > 0.0:
        x_tmp = jnp.where(ok, x_new, stand_in)
        a, k_new = eng.masked_row(sub, x_tmp, spec)
        amax = jnp.max(jnp.abs(a))
        ok = ok & ((amax >= policy.outlier_tol * k_new) | (sub.m == 0))
    return ok, jnp.where(ok, x_new, stand_in)


def _note_gate(hstate: HealthState, ok: Array) -> HealthState:
    rej = (~ok).astype(jnp.int32)
    return hstate._replace(quarantined=hstate.quarantined + rej,
                           rejected_last=rej)


def _select(ok, new, old):
    return jax.tree.map(lambda n, o: jnp.where(ok, n, o), new, old)


# ------------------------------------------------- guarded dispatches --
@partial(jax.jit, static_argnames=("spec", "adjusted", "plan", "Mb"))
def _guarded_update_impl(full, hstate, x_new, spec: kf.KernelSpec,
                         adjusted: bool, plan: eng.UpdatePlan, Mb: int):
    """slice → gate → ingest → scatter → full-level select → probe,
    all under ONE jit.  The final select runs at full capacity so a
    rejected point returns the caller's state bitwise even on bucketed
    dispatch (scatter would otherwise regenerate the sentinel tail)."""
    policy = plan.health
    M = full.L.shape[0]
    sub = eng.slice_state(full, Mb) if Mb < M else full
    ok, x_safe = _gate(sub, x_new, spec, policy)
    new = eng._ingest(sub, x_safe, spec, adjusted, plan.kernel_plan())
    out = eng.scatter_state(full, new) if Mb < M else new
    out = _select(ok, out, full)
    h = _note_gate(hstate, ok)
    h = probe(eng.slice_state(out, Mb) if Mb < M else out, h, policy)
    return out, h


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan", "Mb"))
def _guarded_scan_chunk_impl(full, hstate, xs: Array, spec: kf.KernelSpec,
                             adjusted: bool, plan: eng.UpdatePlan, Mb: int):
    """Guarded mirror of ``engine._scan_chunk``: per-point gate+select
    inside the scan, ONE probe per chunk (the probe is for drift, which
    moves per-block, not per-point), full-level select when the whole
    chunk was rejected."""
    policy = plan.health
    kplan = plan.kernel_plan()
    M = full.L.shape[0]
    sub0 = eng.slice_state(full, Mb) if Mb < M else full

    def step(carry, x_new):
        st, h = carry
        ok, x_safe = _gate(st, x_new, spec, policy)
        new = eng._ingest(st, x_safe, spec, adjusted, kplan)
        return (_select(ok, new, st), _note_gate(h, ok)), ok

    (sub, h), oks = jax.lax.scan(step, (sub0, hstate), xs)
    out = eng.scatter_state(full, sub) if Mb < M else sub
    out = _select(jnp.any(oks), out, full)
    h = probe(sub, h, policy)
    return out, h


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan", "Mb"))
def _guarded_grow_step_impl(kpca, ages: Array, clock: Array, hstate,
                            x_new: Array, spec: kf.KernelSpec,
                            adjusted: bool, plan: eng.UpdatePlan, Mb: int):
    """One guarded append-only window step: the arrival stamp and the
    clock advance only when the point is accepted, so quarantine leaves
    ring, ages and clock untouched (the ``window.ingest`` bugfix)."""
    policy = plan.health
    M = kpca.L.shape[0]
    sub = eng.slice_state(kpca, Mb) if Mb < M else kpca
    ok, x_safe = _gate(sub, x_new, spec, policy)
    new = eng._ingest(sub, x_safe, spec, adjusted, plan.kernel_plan())
    out = eng.scatter_state(kpca, new) if Mb < M else new
    out = _select(ok, out, kpca)
    ages_out = jnp.where(ok, ages.at[kpca.m].set(clock), ages)
    clock_out = jnp.where(ok, clock + 1, clock)
    h = _note_gate(hstate, ok)
    h = probe(eng.slice_state(out, Mb) if Mb < M else out, h, policy)
    return out, ages_out, clock_out, h


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan", "Mb"))
def _guarded_window_chunk_impl(kpca, ages: Array, clock: Array, hstate,
                               xs: Array, spec: kf.KernelSpec,
                               adjusted: bool, plan: eng.UpdatePlan,
                               Mb: int):
    """Guarded mirror of ``engine._window_scan_chunk``: the evict+ingest
    pair executes unconditionally (fixed shapes, fixed collective
    schedule under shard_map) on the sanitized stand-in, and the select
    keeps state, ages AND clock untouched on rejection — so the ring
    stays consistent and a clean stream that never saw the bad point is
    indistinguishable.  Accepted count is recoverable on the host as
    ``clock_after − clock_before``."""
    from repro.core import downdate as dd

    policy = plan.health
    kplan = plan.kernel_plan()
    M = kpca.L.shape[0]
    sub0 = eng.slice_state(kpca, Mb) if Mb < M else kpca
    ages0 = ages[:Mb] if Mb < M else ages

    def step(carry, x_new):
        st, ag, ck, h = carry
        ok, x_safe = _gate(st, x_new, spec, policy)
        victim = jnp.argmin(ag).astype(jnp.int32)
        order = dd.boundary_perm(victim, st.m, ag.shape[0])
        st_n = eng._window_pair(st, victim, x_safe, spec, adjusted, kplan)
        ag_n = ag[order].at[st_n.m - 1].set(ck)
        return (_select(ok, st_n, st), jnp.where(ok, ag_n, ag),
                jnp.where(ok, ck + 1, ck), _note_gate(h, ok)), None

    (sub, ages_sub, clock_n, h), _ = jax.lax.scan(
        step, (sub0, ages0, clock, hstate), xs)
    if Mb < M:
        out = eng.scatter_state(kpca, sub)
        ages_out = ages.at[:Mb].set(ages_sub)
    else:
        out, ages_out = sub, ages_sub
    any_acc = clock_n > clock
    out = _select(any_acc, out, kpca)
    ages_out = jnp.where(any_acc, ages_out, ages)
    h = probe(sub, h, policy)
    return out, ages_out, clock_n, h


# --------------------------------------------------------- heal ladder --
def exact_orth_residual(state) -> float:
    """Host-side EXACT orthogonality residual max_j ‖(UᵀU − I) e_j‖₂
    over all M columns (O(M³) — heal-time only, never on the hot path).
    Returns +inf when U holds non-finite entries."""
    U = state.U
    if not bool(jnp.all(jnp.isfinite(U))):
        return float("inf")
    M = U.shape[0]
    E = U.T @ U - jnp.eye(M, dtype=U.dtype)
    return float(jnp.sqrt(jnp.max(jnp.sum(E * E, axis=0))))


def polish(state):
    """Cheapest heal rung: QR re-orthonormalization of the eigenvector
    block, eigenvalues untouched.  Sign-fixed so Q stays aligned with U
    column-for-column.  Preserves the padding invariants exactly when
    the input does (active columns vanish on rows ≥ m ⇒ Gram–Schmidt
    never leaks mass into the inactive identity columns)."""
    Q, R = jnp.linalg.qr(state.U)
    s = jnp.sign(jnp.diagonal(R))
    s = jnp.where(s == 0, jnp.ones_like(s), s)
    return state._replace(U=Q * s[None, :])


def resync(state, spec: kf.KernelSpec, adjusted: bool):
    """Exact heal rung: re-diagonalize from the stored active points,
    mirroring ``inkpca.init_state`` — gram of X[:m], optional centering,
    eigh — and rebuild the S/K1 running sums.  Post-resync the state
    matches a batch KPCA of the same points by construction.  Raises
    ``HealthError`` (restore rung) when the stored points are corrupt.
    """
    m = int(state.m)
    M = state.L.shape[0]
    dtype = state.L.dtype
    Xa = state.X[:m]
    if not bool(jnp.all(jnp.isfinite(Xa))):
        raise HealthError(
            "stored points are non-finite — in-place resync impossible; "
            "restore from the last checkpoint")
    K0 = kf.gram_block(Xa, Xa, spec=spec)
    S = jnp.sum(K0)
    K1 = jnp.sum(K0, axis=1)
    Keff = kf.center_gram(K0) if adjusted else K0
    lam, vec = jnp.linalg.eigh(Keff)
    L = jnp.zeros((M,), dtype).at[:m].set(lam.astype(dtype))
    U = jnp.eye(M, dtype=dtype).at[:m, :m].set(vec.astype(dtype))
    L = rankone.sentinelize(L, state.m, jnp.zeros((), dtype))
    K1p = jnp.zeros((M,), dtype).at[:m].set(K1.astype(dtype))
    return state._replace(L=L, U=U, S=S.astype(dtype), K1=K1p)


def heal_kpca(state, spec: kf.KernelSpec, adjusted: bool,
              policy: HealthPolicy = DEFAULT_POLICY, *,
              level: str = "auto", rung_out: list | None = None):
    """Walk the escalation ladder on one KPCAState.

    ``level``: "polish" | "resync" force a rung; "auto" measures the
    exact residual and picks the cheapest rung that restores health —
    no-op when already healthy, polish for pure (small) orthogonality
    loss, resync when eigenvalues are implicated or the drift is past
    ``policy.polish_max``.  Non-finite stored points raise
    ``HealthError`` from every rung: that is the restore-from-checkpoint
    escalation, which only the caller (who owns the checkpoint
    directory) can execute.

    ``rung_out``: optional list; the rung actually taken ("noop" |
    "polish" | "resync") is appended — the telemetry layer's
    heals-by-rung counters read it without a second residual pass.
    """

    def took(rung: str):
        if rung_out is not None:
            rung_out.append(rung)

    m = int(state.m)
    if not bool(jnp.all(jnp.isfinite(state.X[:m]))):
        raise HealthError(
            "stored points are non-finite — restore from the last "
            "checkpoint")
    if level == "polish":
        took("polish")
        return polish(state)
    if level == "resync":
        took("resync")
        return resync(state, spec, adjusted)
    if level != "auto":
        raise ValueError(f"unknown heal level {level!r}")
    M = state.L.shape[0]
    Lact = jnp.where(rankone.active_mask(M, state.m), state.L, 0.0)
    lmax = float(jnp.max(jnp.abs(Lact)))
    eig_ok = (bool(jnp.all(jnp.isfinite(Lact)))
              and float(-jnp.min(Lact)) <= policy.neg_tol * max(lmax, 1e-30))
    r = exact_orth_residual(state)
    if eig_ok and r <= policy.orth_tol:
        took("noop")
        return state
    if eig_ok and r <= policy.polish_max:
        polished = polish(state)
        if exact_orth_residual(polished) <= policy.orth_tol:
            took("polish")
            return polished
    took("resync")
    return resync(state, spec, adjusted)
