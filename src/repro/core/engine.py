"""Unified update engine: one code path from kernel row to scatter.

Every consumer of the paper's rank-one eigendecomposition updates —
``inkpca.KPCAStream`` (Algorithms 1/2), ``nystrom.add_landmark`` (§4), the
row-sharded ``core/distributed.py`` drivers, and the ``serve.py`` streaming
service — used to re-thread its own ``method``/``matmul``/``iters``/
``dispatch`` kwargs, and only the first of them got bucketed dispatch and
the fused ±sigma pair.  This module centralizes that plumbing:

* ``UpdatePlan`` — a hashable (jit-static) description of *how* updates
  run: secular method, rotation backend, bisection iterations, bucket
  policy, fused-pair merge-fallback policy, shrink compaction.
* ``Engine`` — owns slice→update→scatter, bucket selection, and the
  fused-pair vs sequential choice for a single stream (KPCA or Nyström).
* ``StreamBatch`` — vmapped multi-tenant streaming: one stacked
  ``KPCAState`` advances B independent tenants per device step, bucketed
  at the cohort maximum active count.

Bucket geometry and invariants
------------------------------
The padding convention of ``rankone.py`` makes slicing sound:

* L is ascending with all inactive entries (sentinels) strictly *above*
  the active spectrum, so the m active eigenvalues always occupy
  ``L[:m]`` and ``L[:M_b]`` carries the active spectrum plus the lowest
  M_b − m sentinels — still ascending, still sentinels-on-top.
* Inactive columns of U are exact identity columns, and (U orthogonal)
  the active columns are zero on rows ≥ m.  Hence ``U[:M_b, :M_b]``
  loses nothing and the complement of the bucket is exactly I.
* K1 / X are zero beyond m; S is a scalar.

``slice_state`` therefore maps a capacity-M state with m < M_b active
pairs to a *valid* capacity-M_b state, and ``scatter_state`` writes the
updated bucket back (re-sentinelizing the tail of L).  The one exception
is a *truncated* state: ``Engine.truncate`` keeps eigenvector support on
the pre-truncation rows, so the engine buckets at the row-support bound
(``min_rows``) until ``compact`` re-expresses the system on the leading
rows — see those methods.

Retrace / bucket-crossing cost model
------------------------------------
Each jitted update specializes on the bucket capacity, so a stream pays
one compilation per bucket it visits — at most log2(M / min_bucket) + 1
of them, ever.  ``update_block`` additionally specializes the scan on the
chunk length; chunks are cut at bucket crossings, so a monotone stream
sees at most two shapes per bucket.  Bucket choice reads ``int(m)`` on
the host — one device sync per chunk (per point for ``update``), which
the scan amortizes.  ``UpdatePlan.kernel_plan()`` normalizes the fields
that do not affect numerics before they reach a jitted function, so
switching dispatch or bucket ladder never retraces the update kernels.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf, rankone

Array = jax.Array

DEFAULT_MIN_BUCKET = 128


class UpdatePlan(NamedTuple):
    """How updates run — hashable, so usable as a jit static argument.

    method:         secular-solve eigenvector variant ("gu" | "bns")
    matmul:         rotation backend — "jnp" | "pallas" (sequential ±sigma
                    updates) or "jnp2" | "pallas2" (fused double rotation)
    iters:          fixed bisection iteration count; None (default) resolves
                    per state dtype — 62 for f64, 32 for f32 (bracket widths
                    shrink 2^-iters relative, so 32 is still ~500x beyond
                    f32 resolution; see ``resolve_iters``)
    dispatch:       "fixed" (capacity-M every step) | "bucketed"
    min_bucket:     smallest rung of the power-of-two bucket ladder
    merge_fallback: cond-guard the fused pair back to the sequential path
                    when a dlaed2 cluster-merge fires (safe on clustered
                    spectra; the O(M³) rotation is what's conditional).
                    Note: under vmap (StreamBatch) lax.cond lowers to a
                    select that executes BOTH branches — fused multi-tenant
                    plans should set merge_fallback=False or use the
                    sequential matmul spellings
    compact_shrink: default for Engine.truncate(compact=...) — re-express
                    a truncated state on its leading rows and shrink the
                    arrays to the active bucket
    precise:        solve the secular systems in f64 when x64 is enabled
    window:         default sliding-window size for streams built from
                    this plan (``KPCAStream``/``StreamBatch`` evict the
                    oldest point before ingesting past the window); None
                    keeps the append-only behaviour
    landmark_policy: Nyström landmark admission — "append" (every offered
                    point becomes a landmark, the paper's §4 loop) or
                    "leverage" (admit on projection residual, replace the
                    lowest-leverage landmark when at budget; see
                    ``nystrom.consider_landmark``)
    fuse_krow:      produce each ingest's kernel row fused with its
                    eigenbasis projection (``kernels/rbf_gram.krow_project``)
                    instead of a standalone gram dispatch followed by the
                    update's own Uᵀv pass — one read of U for the whole
                    prologue.  Changes the traced graph (NOT normalized by
                    ``kernel_plan``); numerics agree with the unfused
                    reference to rotation tolerance.
    serve_every:    decoupled-serving policy: publish a fresh
                    ``core/serving.ServingSnapshot`` every N ingest blocks
                    (``launch/serve.IngestServeLoop``); queries batch
                    against the last published snapshot in between
    serve_components: projection width C frozen into published snapshots
                    (the S matrix is (M, C)); queries return C components
    health:         a ``core/health.HealthPolicy`` (hashable NamedTuple,
                    jit-static like the rest of the plan) enabling the
                    self-healing layer: in-graph probes + input
                    quarantine on the ``*_guarded`` dispatches, heal
                    thresholds for ``Engine.heal``/``KPCAStream``, and
                    the drift threshold for staleness-aware publication
                    (``launch/serve.IngestServeLoop``).  None (default)
                    keeps every pre-existing path bit-identical;
                    normalized away by ``kernel_plan`` so the inner
                    update kernels never re-specialize per policy.
    metrics:        enable the in-graph telemetry lane
                    (``core/telemetry.MetricsState`` riding the stream in
                    ``KPCAStream``/``StreamBatch``).  Metric notes NEVER
                    enter the update dispatches — the eigensystem goes
                    through the identical jitted callables either way, so
                    metrics-on state is bitwise metrics-off state (see
                    ``core/telemetry.py``); normalized away by
                    ``kernel_plan`` accordingly.
    """

    method: str = "gu"
    matmul: str = "jnp"
    iters: int | None = None
    dispatch: str = "fixed"
    min_bucket: int = DEFAULT_MIN_BUCKET
    merge_fallback: bool = True
    compact_shrink: bool = False
    precise: bool = True
    window: int | None = None
    landmark_policy: str = "append"
    fuse_krow: bool = False
    serve_every: int = 1
    serve_components: int = 8
    health: object | None = None
    metrics: bool = False

    @property
    def fused(self) -> bool:
        return self.matmul in ("jnp2", "pallas2")

    @property
    def inner_matmul(self) -> str:
        """The single-rotation backend behind a possibly-fused spelling."""
        return {"jnp2": "jnp", "pallas2": "pallas"}.get(self.matmul,
                                                        self.matmul)

    def kernel_plan(self) -> "UpdatePlan":
        """Normalize fields that do not change update numerics, so jitted
        updates are cached once per (method, matmul, iters, ...) rather
        than once per dispatch/bucket-ladder combination."""
        return self._replace(dispatch="fixed",
                             min_bucket=DEFAULT_MIN_BUCKET,
                             compact_shrink=False,
                             window=None,
                             landmark_policy="append",
                             serve_every=1,
                             serve_components=8,
                             health=None,
                             metrics=False)


DEFAULT_PLAN = UpdatePlan()


def resolve_iters(iters: int | None, dtype) -> int:
    """Bisection iteration count for a plan: explicit value, or the dtype
    default (the bracket width shrinks 2^-iters relative per root, so f32
    needs far fewer passes than the f64-calibrated 62)."""
    if iters is not None:
        return iters
    return 62 if jnp.dtype(dtype).itemsize >= 8 else 32


# ------------------------------------------------------- bucket geometry --
def bucket_sizes(capacity: int, min_bucket: int = DEFAULT_MIN_BUCKET
                 ) -> tuple[int, ...]:
    """Power-of-two ladder min_bucket, 2·min_bucket, …, capped at capacity.

    The capacity itself is always the top rung (even when not a power of
    two) so every state the fixed-capacity API accepts is representable.
    """
    if capacity <= 0:
        raise ValueError(f"capacity must be positive, got {capacity}")
    sizes = []
    b = min(min_bucket, capacity)
    while b < capacity:
        sizes.append(b)
        b *= 2
    sizes.append(capacity)
    return tuple(sizes)


def bucket_for(m_needed: int, capacity: int,
               min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """Smallest bucket that can hold ``m_needed`` active pairs."""
    if m_needed > capacity:
        raise ValueError(
            f"need room for {m_needed} active pairs but capacity is "
            f"{capacity} — grow the state before streaming more points")
    for b in bucket_sizes(capacity, min_bucket):
        if b >= m_needed:
            return b
    raise AssertionError("unreachable: capacity is always a bucket")


# ------------------------------------------------------- slice / scatter --
def slice_state(state, Mb: int):
    """View the leading M_b×M_b block as a capacity-M_b state (see module
    docstring for why this is lossless while m < M_b)."""
    return state._replace(L=state.L[:Mb], U=state.U[:Mb, :Mb],
                          K1=state.K1[:Mb], X=state.X[:Mb])


def scatter_state(full, sub):
    """Write an updated bucket back into the fixed-capacity state."""
    Mb = sub.L.shape[0]
    L = full.L.at[:Mb].set(sub.L)
    # The tail L[Mb:] still holds sentinels for the *pre-update* spectrum;
    # regenerate so the whole array is ascending with sentinels on top.
    L = rankone.sentinelize(L, sub.m, jnp.zeros((), L.dtype))
    return full._replace(L=L, U=full.U.at[:Mb, :Mb].set(sub.U), m=sub.m,
                         S=sub.S, K1=full.K1.at[:Mb].set(sub.K1),
                         X=full.X.at[:Mb].set(sub.X))


def _slice_stacked(states, Mb: int):
    """Leading-axis (tenant-batched) version of ``slice_state``."""
    return states._replace(L=states.L[:, :Mb], U=states.U[:, :Mb, :Mb],
                           K1=states.K1[:, :Mb], X=states.X[:, :Mb])


def _scatter_stacked(full, sub):
    return jax.vmap(scatter_state)(full, sub)


# ------------------------------------------------------ shared primitives --
def masked_row(state, x_new: Array, spec: kf.KernelSpec
               ) -> tuple[Array, Array]:
    """Kernel row against stored points, zeroed beyond the active count."""
    a_full = kf.kernel_row(x_new, state.X, spec=spec)
    mask = rankone.active_mask(state.X.shape[0], state.m)
    a = jnp.where(mask, a_full, 0.0)
    k_new = kf.gram_block(x_new[None], x_new[None], spec=spec)[0, 0]
    return a, k_new


def apply_pair(L: Array, U: Array, v1: Array, sigma1: Array, v2: Array,
               sigma2: Array, m: Array, *, plan: UpdatePlan,
               z1: Array | None = None, z2: Array | None = None
               ) -> tuple[Array, Array]:
    """Apply a ±sigma update pair under ``plan``: one fused double rotation
    (matmul 'jnp2'/'pallas2'; cond-guarded back to sequential when a
    cluster-merge fires and plan.merge_fallback is set) or two sequential
    rank-one updates.

    ``z1``/``z2`` are optional precomputed Uᵀv₁/Uᵀv₂ in the CURRENT basis
    (from the fused ingest kernel).  The fused pair consumes both; the
    sequential spelling can only reuse z1 — z2 is stale after the first
    rotation, so the second update recomputes its own projection."""
    iters = resolve_iters(plan.iters, L.dtype)
    if plan.fused:
        return rankone.rank_one_update_pair(
            L, U, v1, sigma1, v2, sigma2, m, method=plan.method,
            matmul=plan.inner_matmul, iters=iters, precise=plan.precise,
            merge_fallback=plan.merge_fallback, z1=z1, z2=z2)
    L, U = rankone.rank_one_update(L, U, v1, sigma1, m, method=plan.method,
                                   matmul=plan.matmul, iters=iters,
                                   precise=plan.precise, z=z1)
    return rankone.rank_one_update(L, U, v2, sigma2, m, method=plan.method,
                                   matmul=plan.matmul, iters=iters,
                                   precise=plan.precise)


def rank_one(L: Array, U: Array, v: Array, sigma: Array, m: Array, *,
             plan: UpdatePlan) -> tuple[Array, Array]:
    """One ``rankone.rank_one_update`` under ``plan``: run at the active
    bucket and scatter back (no kernel involved — usable without an
    Engine)."""
    M = L.shape[0]
    Mb = (M if plan.dispatch != "bucketed"
          else bucket_for(max(int(m), 1), M, plan.min_bucket))
    kwargs = dict(method=plan.method, matmul=plan.inner_matmul,
                  iters=resolve_iters(plan.iters, L.dtype),
                  precise=plan.precise)
    if Mb == M:
        return rankone.rank_one_update(L, U, v, sigma, m, **kwargs)
    Lb, Ub = rankone.rank_one_update(L[:Mb], U[:Mb, :Mb], v[:Mb], sigma, m,
                                     **kwargs)
    L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m, jnp.zeros((), L.dtype))
    return L_new, U.at[:Mb, :Mb].set(Ub)


def eigpairs(state) -> tuple[Array, Array]:
    """Active (descending) eigenvalues and eigenvectors."""
    M = state.L.shape[0]
    mask = rankone.active_mask(M, state.m)
    order = jnp.argsort(jnp.where(mask, -state.L, jnp.inf))
    return state.L[order], state.U[:, order]


def transform_state(state, x: Array, *, spec: kf.KernelSpec, adjusted: bool,
                    n_components: int, plan: UpdatePlan | None = None
                    ) -> Array:
    """Project points on the leading kernel principal components (pure
    function of the state — vmappable across tenants).

    With ``plan.fuse_krow`` the query gram is never materialized: the
    fused ``nystrom_recon.transform_project`` kernel produces each K_q
    tile in VMEM and contracts it against S = U_active / sqrt(lam) in the
    same pass, returning (Y, rowsum).  The mean-adjusted centering is
    then an affine post-correction of Y: with colsum = 1ᵀS (S rows >= m
    vanish — active columns live on the active prefix) and
    colproj = (K1/m) @ S,

        Y_adj = Y − (rowsum/m)·colsumᵀ − 1·colprojᵀ + (S_sum/m²)·colsumᵀ

    which equals centering the masked gram before projecting.

    Implemented as publish-then-query over ``core/serving``: an ephemeral
    ``ServingSnapshot`` is built (the eigpair sort / top-C gather /
    rescale prologue) and the shared query head projects against it — so
    a transform of a frozen state is bit-identical to serving queries
    against a snapshot published from that state, by construction.  The
    decoupled-serving path hoists the publish out of the per-query cost
    entirely (``serving.DoubleBuffer`` keeps it off the query path)."""
    from repro.core import serving
    snap = serving.publish_transform(state, n_components=n_components,
                                     adjusted=adjusted)
    return serving.query(snap, x, spec=spec, plan=plan)


# ------------------------------------------------------- jitted update fns --
def _ingest(st, x_new: Array, spec: kf.KernelSpec, adjusted: bool,
            plan: UpdatePlan):
    """One Algorithm-1/2 ingest under ``plan`` — THE shared prologue of
    every consumer (stream, scan, window, multi-tenant, Nyström).

    ``plan.fuse_krow`` routes through ``inkpca.ingest_*``: the kernel row
    is produced tile-by-tile fused with its eigenbasis projection
    (``kernels/rbf_gram.krow_project``), so U is read once for the whole
    prologue.  Otherwise the reference two-dispatch path runs: standalone
    masked kernel row, then the update's own Uᵀv pass."""
    from repro.core import inkpca
    if plan.fuse_krow:
        fn = inkpca.ingest_adjusted if adjusted else inkpca.ingest_unadjusted
        return fn(st, x_new, spec=spec, plan=plan)
    a, k_new = masked_row(st, x_new, spec)
    fn = inkpca.update_adjusted if adjusted else inkpca.update_unadjusted
    return fn(st, a, k_new, x_new, plan=plan)


def _window_pair(st, victim, x_new: Array, spec: kf.KernelSpec,
                 adjusted: bool, plan: UpdatePlan):
    """The steady-state ``evict|ingest`` pair stage at m ≡ W: inverse
    ±sigma pair + contraction on the victim row, then one Algorithm-1/2
    ingest.  THE shared windowed composition — the single-stream scan,
    the guarded scan (``health._guarded_window_chunk_impl``) and the
    multi-tenant lockstep scan all fold this exact pair; the sharded
    mirror (``distributed._window_step_sharded``) composes the same two
    stages from the sharded bodies."""
    from repro.core import downdate as dd

    st = dd.downdate(st, victim, spec, adjusted=adjusted, plan=plan)
    return _ingest(st, x_new, spec, adjusted, plan)


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _scan_chunk(sub, xs: Array, spec: kf.KernelSpec, adjusted: bool,
                plan: UpdatePlan):
    """Fixed-capacity scan over a chunk that fits inside one bucket."""
    def step(st, x_new):
        return _ingest(st, x_new, spec, adjusted, plan), None

    out, _ = jax.lax.scan(step, sub, xs)
    return out


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_update(states, xs: Array, spec: kf.KernelSpec,
                    adjusted: bool, plan: UpdatePlan):
    """One vmapped step: fold xs[i] into tenant i, all tenants active."""
    def one(st, x):
        return _ingest(st, x, spec, adjusted, plan)

    return jax.vmap(one)(states, xs)


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_update_masked(states, xs: Array, active: Array,
                           spec: kf.KernelSpec, adjusted: bool,
                           plan: UpdatePlan):
    """One vmapped step: fold xs[i] into tenant i where active[i]."""
    def one(st, x, act):
        new = _ingest(st, x, spec, adjusted, plan)
        return jax.tree.map(lambda n, o: jnp.where(act, n, o), new, st)

    return jax.vmap(one)(states, xs, active)


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_downdate_masked(states, rows: Array, active: Array,
                             spec: kf.KernelSpec, adjusted: bool,
                             plan: UpdatePlan):
    """One vmapped step: evict row rows[i] from tenant i where active[i]
    (the decremental mirror of ``_batched_update_masked``)."""
    from repro.core import downdate as dd

    def one(st, r, act):
        new = dd.downdate(st, r, spec, adjusted=adjusted, plan=plan)
        return jax.tree.map(lambda n, o: jnp.where(act, n, o), new, st)

    return jax.vmap(one)(states, rows, active)


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_scan_masked(states, xs: Array, active: Array,
                         spec: kf.KernelSpec, adjusted: bool,
                         plan: UpdatePlan):
    """Scan a (T, B, d) block with a T-constant tenant mask (used by
    padded cohorts, whose pad lanes must never advance)."""
    def step(sts, x_row):
        def one(st, x, act):
            new = _ingest(st, x, spec, adjusted, plan)
            return jax.tree.map(lambda n, o: jnp.where(act, n, o), new, st)

        return jax.vmap(one)(sts, x_row, active), None

    out, _ = jax.lax.scan(step, states, xs)
    return out


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _window_scan_chunk(sub, ages: Array, clock: Array, xs: Array,
                       spec: kf.KernelSpec, adjusted: bool,
                       plan: UpdatePlan):
    """Steady-state sliding-window scan: every step evicts the oldest
    point and ingests one new one, all under ONE dispatch.

    At m ≡ W the evict+ingest pair is a fixed-shape composition (inverse
    ±sigma pair + Householder contraction at m = W, then the forward
    update back to W), so a whole (T, d) block folds through a single
    ``lax.scan`` — the windowed mirror of ``_scan_chunk``.  The arrival
    ring advances fully in-graph: the victim is ``argmin(ages)`` (a
    traced read, not the host-side ``oldest_row``), the survivor
    permutation reuses ``downdate.boundary_perm``, and the new point is
    stamped with the traced clock.  Zero host syncs inside the block;
    the caller hoists the rebase check to once per block.
    """
    from repro.core import downdate as dd

    def step(carry, x_new):
        st, ages, clock = carry
        victim = jnp.argmin(ages).astype(jnp.int32)
        order = dd.boundary_perm(victim, st.m, ages.shape[0])
        # No sentinel write for the evicted slot: at m ≡ W the freed
        # boundary row W−1 is exactly where the new point lands.
        st = _window_pair(st, victim, x_new, spec, adjusted, plan)
        ages = ages[order].at[st.m - 1].set(clock)     # new point's row
        return (st, ages, clock + 1), None

    (sub, ages, clock), _ = jax.lax.scan(step, (sub, ages, clock), xs)
    return sub, ages, clock


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_window_scan_masked(states, xs: Array, active: Array,
                                spec: kf.KernelSpec, adjusted: bool,
                                plan: UpdatePlan):
    """Scan a (T, B, d) block of steady-state window steps: every active
    tenant sits at m ≡ W, evicts its oldest point (physical row 0 —
    lockstep FIFO, see ``StreamBatch``) and ingests, one device dispatch
    for the whole block.  ``active`` is T-constant (pad lanes and parked
    tenants stay bitwise untouched), which is what makes the whole block
    a fixed-shape scan — the windowed mirror of ``_batched_scan_masked``.
    """
    def step(sts, x_row):
        def one(st, x, act):
            new = _window_pair(st, jnp.zeros((), jnp.int32), x, spec,
                               adjusted, plan)
            return jax.tree.map(lambda n, o: jnp.where(act, n, o), new, st)

        return jax.vmap(one)(sts, x_row, active), None

    out, _ = jax.lax.scan(step, states, xs)
    return out


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def _batched_scan(states, xs: Array, spec: kf.KernelSpec, adjusted: bool,
                  plan: UpdatePlan):
    """Scan a (T, B, d) block: T sequential steps, B tenants per step."""
    def step(sts, x_row):
        def one(st, x):
            return _ingest(st, x, spec, adjusted, plan)

        return jax.vmap(one)(sts, x_row), None

    out, _ = jax.lax.scan(step, states, xs)
    return out


# ------------------------------------------------------- stream bundle --
class StreamState(NamedTuple):
    """The unified stream bundle the composed pipeline advances.

    One pytree carries everything a stream can accumulate: the
    eigensystem plus the OPTIONAL cross-cutting members — the sliding
    window's arrival ring, the self-healing layer's ``HealthState``, the
    telemetry lane's ``MetricsState``.  Absent members are ``None``
    leaves (``None`` is an empty pytree node), so the treestructure is a
    pure function of the plan: jit never retraces because a member
    appeared mid-stream, and ``Engine.step``/``step_block`` select their
    stages from the bundle SHAPE at trace time —

        gate  — runs iff ``health``  is present (quarantine + probe)
        evict — runs iff ``ages``    is present (sliding-window FIFO)
        note  — runs iff ``metrics`` is present (telemetry accounting)

    ``kpca``    ``inkpca.KPCAState`` — the fixed-capacity eigensystem
    ``ages``    (M,) arrival ring, or None for append-only streams
    ``clock``   () next arrival stamp (present iff ``ages`` is)
    ``health``  ``health.HealthState`` or None
    ``metrics`` ``telemetry.MetricsState`` or None
    """

    kpca: object
    ages: object = None
    clock: object = None
    health: object = None
    metrics: object = None

    @property
    def windowed(self) -> bool:
        return self.ages is not None


def make_stream(state, *, health=None, metrics=None) -> StreamState:
    """Wrap a bare ``KPCAState`` or a ``window.WindowState`` (plus any
    riding layers) into the bundle ``Engine.step`` advances.  The inverse
    is structural: read ``.kpca`` (or rebuild a ``WindowState`` from
    ``kpca``/``ages``/``clock``), ``.health``, ``.metrics``."""
    if hasattr(state, "kpca"):                         # WindowState
        return StreamState(kpca=state.kpca, ages=state.ages,
                           clock=state.clock, health=health, metrics=metrics)
    return StreamState(kpca=state, health=health, metrics=metrics)


# ---------------------------------------------------------------- engine --
class Engine:
    """Slice→update→scatter for one stream, under an ``UpdatePlan``.

    The engine is stateless with respect to the stream (states go in and
    out), so one engine can serve many states with the same plan/kernel.
    Streams advance through the composed ``step``/``step_block``
    pipeline; the pre-collapse cartesian spellings survive as one-line
    deprecation shims (see the marked block below).
    """

    def __init__(self, spec: kf.KernelSpec, plan: UpdatePlan = DEFAULT_PLAN,
                 *, adjusted: bool = True):
        self.spec = spec
        self.plan = plan
        self.adjusted = adjusted

    # ---- bucket selection -------------------------------------------------
    def _bucket(self, capacity: int, need: int) -> int:
        if self.plan.dispatch != "bucketed":
            return capacity
        return bucket_for(need, capacity, self.plan.min_bucket)

    # ---- KPCA streaming ---------------------------------------------------
    def _kpca_step(self, state, x_new):
        return _ingest(state, x_new, self.spec, self.adjusted,
                       self.plan.kernel_plan())

    # ---- composed stream-step pipeline -------------------------------------
    # THE update path.  ``step``/``step_block`` advance a ``StreamState``
    # bundle through up to four stages, selected at TRACE TIME from the
    # bundle's structure (absent members are None leaves):
    #
    #     gate → (evict|ingest|pair) → note
    #
    #     gate          health present:  quarantine gate + in-graph probe
    #                   (the guarded impls in ``core/health.py``)
    #     evict         ages present:    FIFO eviction, fused with the
    #                   ingest at m ≡ W (``_window_pair``)
    #     ingest|pair   always:          Algorithm 1/2 expansion + ±sigma
    #                   pair (``_ingest``)
    #     note          metrics present: one tiny separate accounting
    #                   dispatch (``telemetry.note_block``)
    #
    # Every stage routes through the SAME jitted impls the pre-collapse
    # variant methods used (``_scan_chunk``, ``_window_scan_chunk``,
    # ``health._guarded_*_impl``, ``telemetry.note_block``), so each of
    # the 2×2×2 (window × health × metrics) combinations is bitwise
    # identical to its legacy spelling — and a future cross-cutting
    # feature is ONE new stage here, not 2^k new methods.

    def _stream_window(self, stream: "StreamState",
                       window: int | None) -> int | None:
        if window is None:
            window = self.plan.window
        if stream.ages is not None and window is None:
            raise ValueError(
                "windowed StreamState needs a window size — pass window= "
                "or build the engine with UpdatePlan(window=W)")
        return window if stream.ages is not None else None

    def step(self, stream: "StreamState", x_new: Array, *,
             window: int | None = None, min_rows: int = 0) -> "StreamState":
        """Advance the bundle by ONE offered point through the composed
        gate → (evict|ingest|pair) → note pipeline.  Absent members stay
        absent; ``window`` defaults to the plan's and is required only
        for windowed bundles.  Point-wise windowed steps keep the
        two-dispatch evict+ingest spelling (the evict decision reads
        ``int(m)`` on the host); fold blocks through ``step_block`` for
        the single-dispatch steady-state scan."""
        from repro.core import window as wnd

        window = self._stream_window(stream, window)
        metered = stream.metrics is not None
        if metered:
            m0, c0 = stream.kpca.m, stream.clock
            q0 = (stream.health.quarantined if stream.health is not None
                  else None)
        if stream.ages is not None:
            w = wnd.WindowState(kpca=stream.kpca, ages=stream.ages,
                                clock=stream.clock)
            if stream.health is not None:
                w, h = self._gated_window_point(w, stream.health, x_new,
                                                window=window,
                                                min_rows=min_rows)
                stream = stream._replace(kpca=w.kpca, ages=w.ages,
                                         clock=w.clock, health=h)
            else:
                w = self._window_point(w, x_new, window=window,
                                       min_rows=min_rows)
                stream = stream._replace(kpca=w.kpca, ages=w.ages,
                                         clock=w.clock)
        elif stream.health is not None:
            st, h = self._gated_point(stream.kpca, stream.health, x_new,
                                      min_rows=min_rows)
            stream = stream._replace(kpca=st, health=h)
        else:
            stream = stream._replace(kpca=self._ingest_point(
                stream.kpca, x_new, min_rows=min_rows))
        if metered:
            stream = self._note_stage(stream, m0, c0, q0, offered=1,
                                      window=window)
        return stream

    def step_block(self, stream: "StreamState", xs: Array, *,
                   window: int | None = None,
                   min_rows: int = 0) -> "StreamState":
        """Fold a (T, d) block through the composed pipeline — the block
        mirror of ``step``.  Windowed bundles scan steady-state points
        under ONE dispatch (victim selection and the arrival ring fully
        in-graph); guarded bundles gate per point inside the scan; the
        note stage accounts the whole block once at the end."""
        from repro.core import window as wnd

        xs = jnp.asarray(xs)
        window = self._stream_window(stream, window)
        metered = stream.metrics is not None
        if metered:
            m0, c0 = stream.kpca.m, stream.clock
            q0 = (stream.health.quarantined if stream.health is not None
                  else None)
        if stream.ages is not None:
            w = wnd.WindowState(kpca=stream.kpca, ages=stream.ages,
                                clock=stream.clock)
            if stream.health is not None:
                w, h = self._gated_window_block(w, stream.health, xs,
                                                window=window,
                                                min_rows=min_rows)
                stream = stream._replace(kpca=w.kpca, ages=w.ages,
                                         clock=w.clock, health=h)
            else:
                w = self._window_block(w, xs, window=window,
                                       min_rows=min_rows)
                stream = stream._replace(kpca=w.kpca, ages=w.ages,
                                         clock=w.clock)
        elif stream.health is not None:
            st, h = self._gated_block(stream.kpca, stream.health, xs,
                                      min_rows=min_rows)
            stream = stream._replace(kpca=st, health=h)
        else:
            stream = stream._replace(kpca=self._ingest_block(
                stream.kpca, xs, min_rows=min_rows))
        if metered:
            stream = self._note_stage(stream, m0, c0, q0,
                                      offered=xs.shape[0], window=window)
        return stream

    def _note_stage(self, stream: "StreamState", m0, c0, q0, *,
                    offered: int, window: int | None) -> "StreamState":
        """The note stage: account the step into the riding MetricsState
        as ONE tiny separate dispatch, leaving the eigensystem path's jit
        cache entries untouched.  Accepted-count identities (all traced,
        zero host syncs): windowed bundles use the clock delta (guarded
        scans advance the clock only for accepted points); guarded plain
        bundles use the quarantine-counter delta; unguarded plain bundles
        accept everything offered."""
        from repro.core import telemetry as tm

        if c0 is not None:
            accepted = stream.clock - c0
        elif q0 is not None:
            accepted = offered - (stream.health.quarantined - q0)
        else:
            accepted = offered
        return stream._replace(metrics=tm.note_block(
            stream.metrics, m0, stream.kpca.m, offered, accepted,
            stream.health, window=window))

    # ---- stage impls: plain ingest -----------------------------------------
    def _ingest_point(self, state, x_new: Array, *, min_rows: int = 0):
        """One streaming point through Algorithm 1/2 at bucket capacity.

        The kernel row is evaluated against the sliced X as well, so the
        whole step — gram row, secular solve, rotation — is O(M_b²)/O(M_b³).
        ``min_rows`` is a row-support floor (a truncated, uncompacted state
        keeps eigenvector mass on rows beyond m — see ``truncate``).
        """
        M = state.L.shape[0]
        Mb = self._bucket(M, max(int(state.m) + 1, min_rows))
        sub = slice_state(state, Mb) if Mb < M else state
        sub = self._kpca_step(sub, x_new)
        return scatter_state(state, sub) if Mb < M else sub

    def _ingest_block(self, state, xs: Array, *, min_rows: int = 0):
        """Stream a block of points: scan within a bucket, re-bucket at
        crossings (see the cost model in the module docstring)."""
        M = state.L.shape[0]
        n = xs.shape[0]
        plan = self.plan.kernel_plan()
        i = 0
        while i < n:
            m = int(state.m)
            Mb = self._bucket(M, max(m + 1, min_rows))
            # Bucketed dispatch cuts chunks at crossings — including at the
            # top bucket, so exhaustion raises (via bucket_for) instead of
            # silently clamping writes past capacity.  Fixed dispatch keeps
            # the legacy one-scan semantics.
            take = (min(Mb - m, n - i) if self.plan.dispatch == "bucketed"
                    else n - i)
            sub = slice_state(state, Mb) if Mb < M else state
            sub = _scan_chunk(sub, xs[i:i + take], self.spec, self.adjusted,
                              plan)
            state = scatter_state(state, sub) if Mb < M else sub
            i += take
        return state

    # ---- decremental path --------------------------------------------------
    def downdate(self, state, i: int, *, min_rows: int = 0):
        """Remove point ``i`` from the stream at bucket capacity — the
        decremental mirror of ``update`` (see ``core/downdate.py``).

        The downdate never grows the system, so the bucket only needs to
        hold the CURRENT active count; once m drops below a rung, the
        next call (update or downdate) re-buckets downward automatically
        since bucket choice reads ``int(m)``.  A ``NystromState`` routes
        to ``remove_landmark``.  Requires m ≥ 2.
        """
        if hasattr(state, "kpca"):
            return self.remove_landmark(state, i, min_rows=min_rows)
        from repro.core import downdate as dd

        M = state.L.shape[0]
        m = int(state.m)
        if m < 2:
            raise ValueError(f"downdate needs at least 2 active points, "
                             f"got m={m}")
        if not 0 <= i < m:
            raise ValueError(f"point index {i} outside active range "
                             f"[0, {m})")
        Mb = self._bucket(M, max(m, min_rows, 1))
        sub = slice_state(state, Mb) if Mb < M else state
        sub = dd.downdate(sub, jnp.asarray(i, jnp.int32), self.spec,
                          adjusted=self.adjusted,
                          plan=self.plan.kernel_plan())
        return scatter_state(state, sub) if Mb < M else sub

    def replace(self, state, i: int, x_new: Array, *, min_rows: int = 0):
        """Swap point ``i`` for ``x_new``: downdate then update, both at
        bucket capacity.  Works on full states (downdate first frees the
        slot the update needs).  A ``NystromState`` routes to
        ``replace_landmark`` (grow_rows mode)."""
        if hasattr(state, "kpca"):
            return self.replace_landmark(state, None, i, x_new,
                                         min_rows=min_rows)
        state = self.downdate(state, i, min_rows=min_rows)
        return self.update(state, x_new, min_rows=min_rows)

    # ---- steady-state sliding window ---------------------------------------
    def _window_bucket(self, M: int, window: int, min_rows: int) -> int:
        """Bucket for a steady-state window step: the downdate runs at
        m = W and the following update needs W rows (m = W−1 growing by
        one), so the whole evict+ingest pair fits at bucket_for(W)."""
        return self._bucket(M, max(window, min_rows, 1))

    # ---- stage impls: window (evict|ingest fused) ---------------------------
    def _window_point(self, wstate, x_new: Array, *, window: int,
                      min_rows: int = 0):
        """Point-wise evict|ingest: append-only below a full window,
        evict-oldest + ingest at m ≡ W — the two-dispatch spelling
        ``window.ingest`` established (the evict decision reads
        ``int(m)`` on the host, the same sync bucket selection pays).
        Blocks fold through ``_window_block``'s single-dispatch scan."""
        from repro.core import window as wnd

        wstate = wnd.maybe_rebase(wstate)
        if int(wstate.kpca.m) >= window:
            wstate = wnd.evict(self, wstate, wnd.oldest_row(wstate),
                               min_rows=min_rows)
        kpca = self._ingest_point(wstate.kpca, jnp.asarray(x_new),
                                  min_rows=min_rows)
        ages = wstate.ages.at[wstate.kpca.m].set(wstate.clock)
        return wnd.WindowState(kpca=kpca, ages=ages,
                               clock=wstate.clock + 1)

    def _window_block(self, wstate, xs: Array, *, window: int,
                      min_rows: int = 0):
        """Fold a (T, d) block into a windowed stream — the windowed
        mirror of ``_ingest_block``.

        Growth phase (m < W): the leading W − m points are append-only
        and route through ``_ingest_block`` (scan within buckets), with
        their arrival stamps written in one fused slice.  Steady state
        (m ≡ W): the remaining points fold through ``_window_scan_chunk``
        — ONE dispatch for the whole chunk, victim selection and the
        arrival ring fully in-graph, zero host syncs inside the block.
        The rebase check is hoisted to once per block (the clock advances
        by exactly T), so no per-point ``int(clock)`` read either.
        """
        from repro.core import window as wnd

        xs = jnp.asarray(xs)
        T = xs.shape[0]
        if T == 0:
            return wstate
        m = int(wstate.kpca.m)
        if m > window:
            raise ValueError(f"active count {m} exceeds window {window}")
        # Hoisted rebase guard: one host clock read per block.
        if int(wstate.clock) + T >= wnd.age_sentinel(wstate.ages.dtype) - 1:
            wstate = wnd.rebase_ages(wstate)
        i = 0
        if m < window:
            g = min(window - m, T)
            grown = self._ingest_block(wstate.kpca, xs[:g],
                                       min_rows=min_rows)
            wstate = wnd.stamp_grown_ages(wstate, grown, g)
            i = g
        if i == T:
            return wstate
        M = wstate.kpca.L.shape[0]
        Mb = self._window_bucket(M, window, min_rows)
        plan = self.plan.kernel_plan()
        sub = slice_state(wstate.kpca, Mb) if Mb < M else wstate.kpca
        ages_sub = wstate.ages[:Mb] if Mb < M else wstate.ages
        sub, ages_sub, clock = _window_scan_chunk(
            sub, ages_sub, wstate.clock, xs[i:], self.spec, self.adjusted,
            plan)
        if Mb < M:
            kpca = scatter_state(wstate.kpca, sub)
            ages = wstate.ages.at[:Mb].set(ages_sub)
        else:
            kpca, ages = sub, ages_sub
        return wnd.WindowState(kpca=kpca, ages=ages, clock=clock)

    # ---- stage impls: gate (core/health.py) ---------------------------------
    def _health_policy(self):
        policy = self.plan.health
        if policy is None:
            raise ValueError(
                "guarded dispatch needs a health policy — build the engine "
                "with UpdatePlan(health=health.HealthPolicy(...))")
        return policy

    def _gated_point(self, state, hstate, x_new: Array, *,
                     min_rows: int = 0):
        """Gated ingest: the offered point runs the quarantine gate
        (non-finite / outlier) before the rank-one pair fires, and an
        in-graph probe refreshes ``hstate`` — all under the same single
        dispatch, zero extra host syncs.  A rejected point returns the
        input state bitwise.  Returns ``(state, hstate)``."""
        self._health_policy()
        from repro.core import health as hl

        M = state.L.shape[0]
        Mb = self._bucket(M, max(int(state.m) + 1, min_rows))
        return hl._guarded_update_impl(state, hstate, jnp.asarray(x_new),
                                       self.spec, self.adjusted, self.plan,
                                       Mb)

    def _gated_block(self, state, hstate, xs: Array, *,
                     min_rows: int = 0):
        """Gated block ingest: per-point gate + select inside the scan,
        one probe per chunk.  Chunk cuts re-read the ACTUAL active
        count, so rejected points never push a chunk past its bucket."""
        self._health_policy()
        from repro.core import health as hl

        xs = jnp.asarray(xs)
        M = state.L.shape[0]
        n = xs.shape[0]
        i = 0
        while i < n:
            m = int(state.m)
            Mb = self._bucket(M, max(m + 1, min_rows))
            take = (min(Mb - m, n - i) if self.plan.dispatch == "bucketed"
                    else n - i)
            state, hstate = hl._guarded_scan_chunk_impl(
                state, hstate, xs[i:i + take], self.spec, self.adjusted,
                self.plan, Mb)
            i += take
        return state, hstate

    def _gated_window_point(self, wstate, hstate, x_new: Array, *,
                            window: int, min_rows: int = 0):
        """Gated sliding-window point: one arrival through the
        quarantine gate.  Rejection leaves the eigensystem, the arrival
        ring, the ages AND the clock untouched (bitwise), so the evict
        order of a stream that saw a bad point is identical to one that
        never did.  Returns ``(wstate, hstate)``."""
        self._health_policy()
        from repro.core import health as hl
        from repro.core import window as wnd

        x_new = jnp.asarray(x_new)
        M = wstate.kpca.L.shape[0]
        m = int(wstate.kpca.m)
        if int(wstate.clock) + 1 >= wnd.age_sentinel(wstate.ages.dtype) - 1:
            wstate = wnd.rebase_ages(wstate)
        if m >= window:
            Mb = self._window_bucket(M, window, min_rows)
            kpca, ages, clock, hstate = hl._guarded_window_chunk_impl(
                wstate.kpca, wstate.ages, wstate.clock, hstate,
                x_new[None], self.spec, self.adjusted, self.plan, Mb)
        else:
            Mb = self._bucket(M, max(m + 1, min_rows))
            kpca, ages, clock, hstate = hl._guarded_grow_step_impl(
                wstate.kpca, wstate.ages, wstate.clock, hstate, x_new,
                self.spec, self.adjusted, self.plan, Mb)
        return wnd.WindowState(kpca=kpca, ages=ages, clock=clock), hstate

    def _gated_window_block(self, wstate, hstate, xs: Array, *,
                            window: int, min_rows: int = 0):
        """Gated window block: growth-phase points step through the
        per-point gate (the arrival stamp is conditional, so the ring
        semantics match the point path), steady-state points fold through
        ONE guarded scan — fixed shapes, fixed collective schedule,
        clock advances only by the accepted count."""
        self._health_policy()
        from repro.core import health as hl
        from repro.core import window as wnd

        xs = jnp.asarray(xs)
        T = xs.shape[0]
        if T == 0:
            return wstate, hstate
        M = wstate.kpca.L.shape[0]
        if int(wstate.clock) + T >= wnd.age_sentinel(wstate.ages.dtype) - 1:
            wstate = wnd.rebase_ages(wstate)
        i = 0
        # Growth phase: per-point host loop — acceptance changes m, and
        # the bucket / phase decision reads it (same sync window.ingest
        # already pays per point).
        while i < T and int(wstate.kpca.m) < window:
            Mb = self._bucket(M, max(int(wstate.kpca.m) + 1, min_rows))
            kpca, ages, clock, hstate = hl._guarded_grow_step_impl(
                wstate.kpca, wstate.ages, wstate.clock, hstate, xs[i],
                self.spec, self.adjusted, self.plan, Mb)
            wstate = wnd.WindowState(kpca=kpca, ages=ages, clock=clock)
            i += 1
        if i == T:
            return wstate, hstate
        Mb = self._window_bucket(M, window, min_rows)
        kpca, ages, clock, hstate = hl._guarded_window_chunk_impl(
            wstate.kpca, wstate.ages, wstate.clock, hstate, xs[i:],
            self.spec, self.adjusted, self.plan, Mb)
        return wnd.WindowState(kpca=kpca, ages=ages, clock=clock), hstate

    # ======== legacy variant-matrix shims (deprecated) =======================
    # The pre-collapse cartesian spellings — plain/guarded/metered ×
    # point/block × plain/window.  Each is a one-line delegation that
    # wraps its arguments into a ``StreamState`` bundle, runs the
    # composed ``step``/``step_block`` pipeline, and unwraps — bitwise
    # identical by construction (the pipeline routes through the same
    # jitted impls these spellings used).  Kept only for callers not yet
    # on the bundle API.  Do NOT add new ``*_guarded``/``*_metered``
    # variants here or anywhere on Engine: add a STAGE to the pipeline
    # instead (``make lint-api`` enforces this).
    def _wstate(self, stream: "StreamState"):
        from repro.core import window as wnd

        return wnd.WindowState(kpca=stream.kpca, ages=stream.ages,
                               clock=stream.clock)

    def update(self, state, x_new: Array, *, min_rows: int = 0):
        """Deprecated spelling of ``step`` on a bare-eigensystem bundle."""
        return self.step(StreamState(kpca=state), x_new,
                         min_rows=min_rows).kpca

    def update_block(self, state, xs: Array, *, min_rows: int = 0):
        """Deprecated spelling of ``step_block`` on a bare bundle."""
        return self.step_block(StreamState(kpca=state), xs,
                               min_rows=min_rows).kpca

    def window_step(self, wstate, x_new: Array, *, window: int,
                    min_rows: int = 0):
        """One steady-state sliding-window step (m ≡ W): evict-oldest +
        ingest fused under ONE jitted dispatch at the window's bucket —
        a length-1 ``step_block`` (the point-wise ``step`` keeps the
        two-dispatch ``window.ingest`` spelling instead)."""
        return self.window_block(wstate, jnp.asarray(x_new)[None],
                                 window=window, min_rows=min_rows)

    def window_block(self, wstate, xs: Array, *, window: int,
                     min_rows: int = 0):
        """Deprecated spelling of ``step_block`` on a windowed bundle."""
        return self._wstate(self.step_block(make_stream(wstate), xs,
                                            window=window,
                                            min_rows=min_rows))

    def update_guarded(self, state, hstate, x_new: Array, *,
                       min_rows: int = 0):
        """Deprecated spelling of ``step`` on a guarded bundle."""
        out = self.step(StreamState(kpca=state, health=hstate), x_new,
                        min_rows=min_rows)
        return out.kpca, out.health

    def update_block_guarded(self, state, hstate, xs: Array, *,
                             min_rows: int = 0):
        out = self.step_block(StreamState(kpca=state, health=hstate), xs,
                              min_rows=min_rows)
        return out.kpca, out.health

    def window_ingest_guarded(self, wstate, hstate, x_new: Array, *,
                              window: int, min_rows: int = 0):
        out = self.step(make_stream(wstate, health=hstate), x_new,
                        window=window, min_rows=min_rows)
        return self._wstate(out), out.health

    def window_block_guarded(self, wstate, hstate, xs: Array, *,
                             window: int, min_rows: int = 0):
        out = self.step_block(make_stream(wstate, health=hstate), xs,
                              window=window, min_rows=min_rows)
        return self._wstate(out), out.health

    def update_metered(self, state, mstate, x_new: Array, *,
                       min_rows: int = 0):
        """Deprecated spelling of ``step`` on a metered bundle."""
        out = self.step(StreamState(kpca=state, metrics=mstate), x_new,
                        min_rows=min_rows)
        return out.kpca, out.metrics

    def update_block_metered(self, state, mstate, xs: Array, *,
                             min_rows: int = 0):
        out = self.step_block(StreamState(kpca=state, metrics=mstate), xs,
                              min_rows=min_rows)
        return out.kpca, out.metrics

    def window_block_metered(self, wstate, mstate, xs: Array, *,
                             window: int, min_rows: int = 0):
        out = self.step_block(make_stream(wstate, metrics=mstate), xs,
                              window=window, min_rows=min_rows)
        return self._wstate(out), out.metrics

    def update_guarded_metered(self, state, hstate, mstate, x_new: Array, *,
                               min_rows: int = 0):
        out = self.step(StreamState(kpca=state, health=hstate,
                                    metrics=mstate), x_new,
                        min_rows=min_rows)
        return out.kpca, out.health, out.metrics

    def update_block_guarded_metered(self, state, hstate, mstate, xs: Array,
                                     *, min_rows: int = 0):
        out = self.step_block(StreamState(kpca=state, health=hstate,
                                          metrics=mstate), xs,
                              min_rows=min_rows)
        return out.kpca, out.health, out.metrics

    def window_block_guarded_metered(self, wstate, hstate, mstate,
                                     xs: Array, *, window: int,
                                     min_rows: int = 0):
        out = self.step_block(make_stream(wstate, health=hstate,
                                          metrics=mstate), xs,
                              window=window, min_rows=min_rows)
        return self._wstate(out), out.health, out.metrics

    def window_ingest_guarded_metered(self, wstate, hstate, mstate,
                                      x_new: Array, *, window: int,
                                      min_rows: int = 0):
        out = self.step(make_stream(wstate, health=hstate,
                                    metrics=mstate), x_new,
                        window=window, min_rows=min_rows)
        return self._wstate(out), out.health, out.metrics

    def downdate_metered(self, state, mstate, i: int, *, min_rows: int = 0):
        from repro.core import telemetry as tm

        state = self.downdate(state, i, min_rows=min_rows)
        m_after = (state.kpca.m if hasattr(state, "kpca") else state.m)
        return state, tm.note_downdate(mstate, m_after)
    # ======== end legacy variant-matrix shims ================================

    def probe(self, state, hstate=None, *, ref_lam: Array | None = None):
        """Standalone in-graph health probe of any state this engine
        serves (KPCAState, WindowState or NystromState — wrapper states
        probe their ``.kpca`` block).  ``ref_lam`` folds the spectral
        staleness check into the same dispatch.  Returns a fresh/updated
        ``HealthState`` (device-resident)."""
        from repro.core import health as hl

        policy = self.plan.health or hl.DEFAULT_POLICY
        kpca = getattr(state, "kpca", state)
        if hstate is None:
            hstate = hl.init_health(kpca.L.dtype)
        if ref_lam is None:
            return hl._probe_jit(kpca, hstate, policy)
        return hl._probe_ref_jit(kpca, hstate, policy, jnp.asarray(ref_lam))

    def heal(self, state, *, level: str = "auto", rung_out: list | None = None):
        """Walk the heal ladder (polish → resync; see ``core/health``)
        on any state this engine serves.  WindowState keeps its ring and
        clock; NystromState heals the landmark eigensystem (always
        unadjusted — the K_mm block) and keeps ``Knm``/``Xrows``, after
        which the caller should re-anchor any ``TraceErrorTracker`` via
        ``tracker.resync(state)``.  Raises ``health.HealthError`` when
        the stored points are corrupt — the restore-from-checkpoint
        rung, executed by whoever owns the checkpoint directory."""
        from repro.core import health as hl

        policy = self.plan.health or hl.DEFAULT_POLICY
        if hasattr(state, "Knm"):                      # NystromState
            kpca = hl.heal_kpca(state.kpca, self.spec, False, policy,
                                level=level, rung_out=rung_out)
            return state._replace(kpca=kpca)
        if hasattr(state, "kpca"):                     # WindowState
            kpca = hl.heal_kpca(state.kpca, self.spec, self.adjusted,
                                policy, level=level, rung_out=rung_out)
            return state._replace(kpca=kpca)
        return hl.heal_kpca(state, self.spec, self.adjusted, policy,
                            level=level, rung_out=rung_out)

    # ---- low-level rank-one -----------------------------------------------
    def rank_one(self, L: Array, U: Array, v: Array, sigma: Array, m: Array
                 ) -> tuple[Array, Array]:
        """``rankone.rank_one_update`` at bucket capacity, scattered back."""
        return rank_one(L, U, v, sigma, m, plan=self.plan)

    # ---- Nyström landmarks ------------------------------------------------
    def add_landmark(self, state, x_all, x_new: Array, *,
                     min_rows: int = 0):
        """Bucketed ``nystrom.add_landmark``: the O(M³) eigensystem update
        and the O(n·M) column write both run at bucket capacity.

        ``min_rows`` is the row-support floor, exactly as in ``update``: a
        truncated-but-UNcompacted state keeps eigenvector mass on rows
        beyond m, and bucketing below that support silently discards it —
        pass the pre-truncation landmark count until the state is
        compacted (``truncate(..., compact=True)`` needs no floor).
        """
        from repro.core import nystrom

        M = state.kpca.L.shape[0]
        Mb = self._bucket(M, max(int(state.kpca.m) + 1, min_rows))
        plan = self.plan.kernel_plan()
        if Mb == M:
            return nystrom.add_landmark(state, x_all, x_new, self.spec,
                                        plan=plan)
        sub = state._replace(kpca=slice_state(state.kpca, Mb),
                             Knm=state.Knm[:, :Mb])
        sub = nystrom.add_landmark(sub, x_all, x_new, self.spec, plan=plan)
        return state._replace(kpca=scatter_state(state.kpca, sub.kpca),
                              Knm=state.Knm.at[:, :Mb].set(sub.Knm),
                              Xrows=sub.Xrows)

    def remove_landmark(self, state, j: int, *, min_rows: int = 0):
        """Bucketed ``nystrom.remove_landmark``: the eigensystem downdate
        and the Knm column shuffle both run at the bucket holding the
        current landmark count (no growth, so the bucket needs m rows,
        not m+1)."""
        from repro.core import nystrom

        M = state.kpca.L.shape[0]
        m = int(state.kpca.m)
        if m < 2:
            raise ValueError(f"remove_landmark needs at least 2 landmarks, "
                             f"got m={m}")
        if not 0 <= j < m:
            raise ValueError(f"landmark index {j} outside active range "
                             f"[0, {m})")
        Mb = self._bucket(M, max(m, min_rows, 1))
        plan = self.plan.kernel_plan()
        if Mb == M:
            return nystrom.remove_landmark(state, jnp.asarray(j, jnp.int32),
                                           self.spec, plan=plan)
        sub = state._replace(kpca=slice_state(state.kpca, Mb),
                             Knm=state.Knm[:, :Mb])
        sub = nystrom.remove_landmark(sub, jnp.asarray(j, jnp.int32),
                                      self.spec, plan=plan)
        return state._replace(kpca=scatter_state(state.kpca, sub.kpca),
                              Knm=state.Knm.at[:, :Mb].set(sub.Knm))

    def replace_landmark(self, state, x_all, j: int, x_new: Array, *,
                         min_rows: int = 0, donate: bool = False):
        """Swap landmark ``j`` for ``x_new``: remove + add fused into ONE
        jitted dispatch at the bucket (the eager slice/scatter of two
        separate bucketed calls would rival the compute at serving
        sizes).  O(M_b³ + n) against the O(n·m·d + m³ + n·M alloc)
        from-scratch rebuild — the landmark-lifecycle fast path (see
        benchmarks/bench_window.py).  The bucket needs m rows only: the
        removal frees the slot before the add writes row m−1.

        ``donate=True`` consumes the input state: the (n, M) Knm and the
        (M, M) eigenvector buffers are updated in place, so the swap's
        memory traffic is O(n + M_b²) instead of O(n·M).  Use it in the
        steady-state lifecycle (serve loop, benchmarks) where the
        pre-swap state is dead anyway; the default copies.
        """
        M = state.kpca.L.shape[0]
        m = int(state.kpca.m)
        if m < 2:
            raise ValueError(f"replace_landmark needs at least 2 "
                             f"landmarks, got m={m}")
        if not 0 <= j < m:
            raise ValueError(f"landmark index {j} outside active range "
                             f"[0, {m})")
        Mb = self._bucket(M, max(m, min_rows, 1))
        plan = self.plan.kernel_plan()
        # Mb == M still routes through the jitted impl (the slice is a
        # no-op there) so donation holds for fixed-dispatch and
        # top-bucket states too — not just sliced buckets.
        fn = (_replace_landmark_sliced_donated if donate
              else _replace_landmark_sliced)
        return fn(state, jnp.asarray(j, jnp.int32), x_new, x_all,
                  self.spec, plan, Mb)

    def offer_landmark(self, state, x: Array, *, x_all=None,
                       budget: int | None = None, admit_tol: float = 1e-3,
                       reg: float = 1e-6, min_rows: int = 0,
                       residual: float | None = None):
        """Offer one candidate landmark under ``plan.landmark_policy``.

        * ``"append"`` — the paper's §4 loop: admit every candidate until
          the budget fills, then reject.
        * ``"leverage"`` — residual-gated admission with lowest-leverage
          replacement at budget (``nystrom.consider_landmark``);
          ``residual`` forwards a precomputed ``admission_residual``.

        Returns ``(state, action)`` with action in
        {"admitted", "rejected", "replaced"}.
        """
        from repro.core import nystrom

        if self.plan.landmark_policy == "leverage":
            return nystrom.consider_landmark(
                self, state, x, x_all=x_all, budget=budget,
                admit_tol=admit_tol, reg=reg, min_rows=min_rows,
                residual=residual)
        if self.plan.landmark_policy != "append":
            raise ValueError(f"unknown landmark_policy "
                             f"{self.plan.landmark_policy!r}")
        M = state.kpca.L.shape[0]
        budget = budget if budget is not None else M - 1
        if int(state.kpca.m) < budget:
            return self.add_landmark(state, x_all, x,
                                     min_rows=min_rows), "admitted"
        return state, "rejected"

    # ---- truncation / compaction ------------------------------------------
    def truncate(self, state, k: int, *, compact: bool | None = None,
                 capacity: int | None = None):
        """Keep only the k dominant eigenpairs (paper conclusion: 'adapt the
        proposed algorithm to only maintain a subset').

        The kept eigenvector columns retain support on the pre-truncation
        rows.  ``compact`` policy:

        * ``True`` — re-express the state on its leading rows and shrink
          the arrays to the active bucket (or ``capacity``): the old large
          bucket's memory is freed.
        * ``False`` — seed-faithful truncation (old rows keep eigenvector
          mass).  Bucketed dispatch MUST then keep slicing at the OLD
          active count: pass the old m as ``min_rows`` to
          ``update``/``update_block``.  ``KPCAStream`` tracks this floor
          automatically; direct engine callers own it themselves (results
          silently degrade otherwise), and the floor does not survive a
          checkpoint — compact before saving a truncated state.
        * ``None`` (default) — ``plan.compact_shrink``, except that a
          bucketed-dispatch engine compacts at UNCHANGED capacity, so a
          bare ``engine.truncate(state, k)`` is always safe to keep
          streaming from without any ``min_rows`` bookkeeping.

        A ``NystromState`` (anything with a ``.kpca`` field) is routed
        through ``_truncate_nystrom``: its rows are OBSERVED landmarks
        with live ``Knm`` columns, so compaction is clamped to the
        row-support floor instead of dropping out-of-support mass.
        """
        if hasattr(state, "kpca"):
            return self._truncate_nystrom(state, k, compact=compact,
                                          capacity=capacity)
        keep_capacity = False
        if compact is None:
            compact = self.plan.compact_shrink
            if not compact and self.plan.dispatch == "bucketed":
                compact, keep_capacity = True, True
        M = state.L.shape[0]
        mask = rankone.active_mask(M, state.m)
        order = jnp.argsort(jnp.where(mask, -state.L, jnp.inf))
        keep = order[:k]
        L = jnp.zeros_like(state.L).at[:k].set(state.L[keep])
        U = jnp.eye(M, dtype=state.U.dtype).at[:, :k].set(state.U[:, keep])
        m = jnp.minimum(state.m, jnp.asarray(k, state.m.dtype))
        L = rankone.sentinelize(L, m, jnp.zeros((), L.dtype))
        out = state._replace(L=L, U=U, m=m)
        if compact:
            out = self.compact(out, capacity=M if keep_capacity else capacity)
        return out

    def _truncate_nystrom(self, state, k: int, *, compact: bool | None,
                          capacity: int | None):
        """Truncate a Nyström state's eigensystem without losing landmarks.

        Unlike a pure KPCA stream — whose downstream consumers only ever
        read the leading m rows — a Nyström state's rows are *observed*
        landmarks: row j of the kpca block pairs with the live column
        ``Knm[:, j]``, and ``nystrom_eigpairs``/``reconstruct_tilde``
        contract over ALL rows carrying eigenvector mass.  Plain
        ``compact`` would re-diagonalize the leading k×k block and drop
        rows k..m — silently corrupting every later reconstruction.  Here
        compaction is CLAMPED to the row-support floor r = m (the
        landmark count): the truncated rank-k system is re-diagonalized
        on all r rows (top-k spectrum plus r−k ≈ 0 eigenvalues), m stays
        r, and the capacity shrinks to the bucket holding r+1 — memory
        is freed without dropping a single observed row.  ``Knm`` columns
        follow the new capacity; its rows (the observed stream) are never
        touched.  An explicit ``capacity`` below r+1 raises.
        """
        kpca = state.kpca
        if compact is None:
            compact = (self.plan.compact_shrink
                       or self.plan.dispatch == "bucketed")
        r = int(kpca.m)                       # row-support floor: landmarks
        trunc = self.truncate(kpca, k, compact=False)
        if not compact:
            # Uncompacted: eigenvector mass stays on all r landmark rows,
            # and a bucketed engine would otherwise re-bucket at the NEW
            # m and drop it — callers own the floor: pass min_rows=r (the
            # pre-truncation landmark count) to every subsequent
            # ``add_landmark``/``update`` until the state is compacted.
            return state._replace(kpca=trunc)
        M = kpca.L.shape[0]
        cap = (capacity if capacity is not None
               else bucket_for(r + 1, max(M, r + 1), self.plan.min_bucket))
        if cap <= r:
            raise ValueError(
                f"compaction capacity {cap} would drop observed landmark "
                f"rows (row support {r}) — Nyström compaction is clamped "
                f"to the row-support floor")
        dtype = kpca.L.dtype
        mask = rankone.active_mask(M, trunc.m)
        Lm = jnp.where(mask, trunc.L, 0.0)
        Kc = ((trunc.U * Lm[None, :]) @ trunc.U.T)[:r, :r]
        lam, vec = jnp.linalg.eigh(Kc)
        # The block has rank <= k: flush the r-k numerically-zero
        # eigenvalues to exact 0 so the Nyström pseudo-inverse consumers
        # (nystrom_eigpairs / reconstruct_tilde) deflate them cleanly.
        tol = r * jnp.finfo(dtype).eps * jnp.max(jnp.abs(lam))
        lam = jnp.where(jnp.abs(lam) <= tol, 0.0, lam)
        L = jnp.zeros((cap,), dtype).at[:r].set(lam.astype(dtype))
        U = jnp.eye(cap, dtype=dtype).at[:r, :r].set(vec.astype(dtype))
        mm = jnp.asarray(r, kpca.m.dtype)
        L = rankone.sentinelize(L, mm, jnp.zeros((), dtype))
        ncopy = min(cap, M)
        K1 = jnp.zeros((cap,), dtype).at[:ncopy].set(kpca.K1[:ncopy])
        X = jnp.zeros((cap,) + kpca.X.shape[1:],
                      kpca.X.dtype).at[:ncopy].set(kpca.X[:ncopy])
        new_kpca = kpca._replace(L=L, U=U, m=mm, K1=K1, X=X)
        n = state.Knm.shape[0]
        Knm = jnp.zeros((n, cap), state.Knm.dtype)
        Knm = Knm.at[:, :ncopy].set(state.Knm[:, :ncopy])
        return state._replace(kpca=new_kpca, Knm=Knm)

    def compact(self, state, capacity: int | None = None):
        """Re-express the active eigensystem on its leading m rows and
        re-allocate at ``capacity`` (default: the smallest bucket holding
        m+1) — the shrink half of bucketed dispatch.

        The maintained model only ever *reads* the leading m rows of the
        active columns (kernel rows, update vectors and transform queries
        are all masked beyond m), so re-diagonalizing the m×m block of the
        reconstruction is exact for every downstream consumer.  For a
        state whose support already sits in the leading rows (any stream
        that never truncated) this is a pure re-allocation; after
        ``truncate`` it also drops the out-of-support eigenvector mass,
        which is what frees the old large bucket.
        """
        M = state.L.shape[0]
        m = int(state.m)
        cap = (capacity if capacity is not None
               else bucket_for(m + 1, max(M, m + 1), self.plan.min_bucket))
        if cap <= m:
            raise ValueError(f"compaction capacity {cap} cannot hold "
                             f"{m} active pairs plus one update")
        dtype = state.L.dtype
        Kc = rankone.reconstruct(state.L, state.U, state.m)[:m, :m]
        lam, vec = jnp.linalg.eigh(Kc)
        L = jnp.zeros((cap,), dtype).at[:m].set(lam.astype(dtype))
        U = jnp.eye(cap, dtype=dtype).at[:m, :m].set(vec.astype(dtype))
        mm = jnp.asarray(m, state.m.dtype)
        L = rankone.sentinelize(L, mm, jnp.zeros((), dtype))
        ncopy = min(cap, M)
        K1 = jnp.zeros((cap,), dtype).at[:ncopy].set(state.K1[:ncopy])
        X = jnp.zeros((cap,) + state.X.shape[1:],
                      state.X.dtype).at[:ncopy].set(state.X[:ncopy])
        return state._replace(L=L, U=U, m=mm, K1=K1, X=X)


def _replace_landmark_sliced_impl(state, j: Array, x_new: Array, x_all,
                                  spec: kf.KernelSpec, plan: UpdatePlan,
                                  Mb: int):
    """slice → remove_landmark → add_landmark → scatter under one jit."""
    from repro.core import nystrom

    sub = state._replace(kpca=slice_state(state.kpca, Mb),
                         Knm=state.Knm[:, :Mb])
    sub = nystrom.replace_landmark(sub, x_all, j, x_new, spec, plan=plan)
    return state._replace(kpca=scatter_state(state.kpca, sub.kpca),
                          Knm=state.Knm.at[:, :Mb].set(sub.Knm),
                          Xrows=sub.Xrows)


_replace_landmark_sliced = jax.jit(
    _replace_landmark_sliced_impl, static_argnames=("spec", "plan", "Mb"))
# Donating spelling for the steady-state lifecycle: the O(n·M) Knm (and
# the M×M eigenvectors) update IN PLACE instead of being copied per swap,
# so a replace's memory traffic is O(n + M_b²), not O(n·M).  The caller's
# input state is consumed.
_replace_landmark_sliced_donated = jax.jit(
    _replace_landmark_sliced_impl, static_argnames=("spec", "plan", "Mb"),
    donate_argnums=(0,))


# ---------------------------------------------------- multi-tenant batch --
class StreamBatch:
    """B independent KPCA streams advanced in lockstep via vmap.

    The production-serving shape: rather than one Python loop per tenant
    (B dispatches per wall-clock step), one stacked ``KPCAState`` folds a
    point into every tenant's eigendecomposition in a single device step.
    Per-tenant active counts ``m_i`` may diverge (pass ``active`` masks).

    Cohort geometry (``cohorts=``):

    * ``"max"`` (default) — bucketed dispatch runs the whole cohort at
      the bucket of ``max_i m_i + 1``, so a cohort's cost tracks its
      largest tenant.
    * ``"bucket"`` — **bucket-homogeneous cohorts**: tenants are grouped
      by their own active bucket, and one step runs one vmapped update
      per GROUP at that group's M_b.  A mixed-size cohort (m_i spread
      ≥ the bucket ratio) then pays Σ_b |group_b|·O(M_b³) instead of
      B·O(max_b M_b³); the per-step device dispatch count equals the
      number of occupied buckets (≤ log2(M/min_bucket)+1), not B.
      Group membership migrates at bucket crossings (host-side
      regroup + re-slice, amortized like any bucket crossing).
    * ``"bucket-padded"`` — like ``"bucket"``, but each group's tenant
      axis is padded to the next power of two with inert copies of the
      group's first tenant (masked out of every step, never scattered
      back).  Each vmapped step then compiles per (pow2 group size,
      M_b) pair — at most log2(B)+1 sizes per bucket — so tenant churn
      (joins/leaves re-cutting group sizes every few steps) pays
      bounded recompiles instead of one per distinct group size, at the
      cost of ≤ 2× redundant lane compute inside a group.

    Sliding windows (``window=W``): an active tenant sitting at m = W
    first evicts its oldest point via a masked batched downdate
    (``_batched_downdate_masked`` — the decremental mirror of the update
    step) and then ingests, so per-tenant memory and cost are bounded
    forever.  Lockstep FIFO means the oldest point is always physical
    row 0 (the eviction permutation preserves survivor order), so no
    per-tenant ring is needed here — single streams carry one in
    ``core/window.py`` for checkpoint-portable eviction order.

    Unlike the single-stream engine (which slices and scatters the
    capacity-M state every step), the working state here is *bucket
    resident*: it lives at the cohort/group bucket between crossings,
    active counts are tracked on the host (exact: every folded point
    advances its tenant's m by one), and the capacity-M arrays are
    materialized only at bucket crossings or when ``.states`` is read —
    so a serving step has no slice/scatter traffic, and steps can
    pipeline.

    x0: (B, m0, d) per-tenant seed points (same m0; tenants that should
    start smaller can simply skip steps via ``active`` — their m_i, and
    with ``cohorts="bucket"`` their cost, stays behind the cohort's).
    """

    def __init__(self, x0: Array, capacity: int, spec: kf.KernelSpec, *,
                 plan: UpdatePlan = DEFAULT_PLAN, adjusted: bool = True,
                 dtype=jnp.float32, cohorts: str = "max",
                 window: int | None = None):
        import numpy as np

        from repro.core import inkpca

        x0 = jnp.asarray(x0)
        if x0.ndim != 3:
            raise ValueError(f"x0 must be (tenants, m0, d), got {x0.shape}")
        if cohorts not in ("max", "bucket", "bucket-padded"):
            raise ValueError(f"cohorts must be 'max', 'bucket' or "
                             f"'bucket-padded', got {cohorts!r}")
        if window is None:
            window = plan.window
        if window is not None:
            if not 2 <= window <= capacity:
                raise ValueError(f"window must be in [2, capacity], got "
                                 f"{window} (capacity {capacity})")
            if int(x0.shape[1]) > window:
                raise ValueError(f"seed size {x0.shape[1]} exceeds window "
                                 f"{window}")
        self.spec = spec
        self.plan = plan
        self.adjusted = adjusted
        self.capacity = capacity
        self.cohorts = cohorts
        self.window = window
        self.n_tenants = int(x0.shape[0])
        self._full = jax.vmap(
            lambda x: inkpca.init_state(x, capacity, spec, adjusted=adjusted,
                                        dtype=dtype))(x0)
        self._sub = None          # bucket-resident working state ("max")
        self._Mb = capacity
        # Host-side upper bound on max_i m_i (exact while every step is
        # fully active; re-synced from the device at crossings).
        self._ceiling = int(x0.shape[1])
        # Exact host-side per-tenant active counts ("bucket" mode): every
        # accepted point advances its tenant by exactly one.
        self._m_host = np.full((self.n_tenants,), int(x0.shape[1]),
                               dtype=np.int64)
        self._groups: list[dict] | None = None
        # Per-tenant tally of points rejected by the non-finite gate
        # (``plan.health.quarantine``) before any device dispatch.
        self.quarantined = np.zeros((self.n_tenants,), dtype=np.int64)
        # Host-exact fold/evict tallies: every accepted point and every
        # window eviction increments its tenant's entry at the same spot
        # ``_m_host`` moves, so the metric lanes below are exact without
        # reading anything back from the device.
        self._ingest_host = np.zeros((self.n_tenants,), dtype=np.int64)
        self._evict_host = np.zeros((self.n_tenants,), dtype=np.int64)
        # Per-tenant metric lanes (core/telemetry.py): a (B,)-leaf
        # MetricsState updated once per public update/update_block call.
        self.metrics = None
        if plan.metrics:
            from repro.core import telemetry as tm

            self.metrics = tm.init_metrics_stacked(self.n_tenants, dtype)

    # ---- bucket residency ---------------------------------------------------
    def _flush(self):
        """Scatter the working state back into the capacity-M arrays."""
        if self._sub is not None:
            self._full = (_scatter_stacked(self._full, self._sub)
                          if self._Mb < self.capacity else self._sub)
            self._sub = None
        if self._groups is not None:
            for grp in self._groups:
                self._scatter_group(grp)
            self._groups = None

    # ---- bucket-homogeneous groups ("bucket"/"bucket-padded" cohorts) -------
    @property
    def _grouped(self) -> bool:
        return self.cohorts in ("bucket", "bucket-padded")

    def _tenant_bucket(self, m: int) -> int:
        if self.plan.dispatch != "bucketed":
            return self.capacity
        return bucket_for(min(m + 1, self.capacity), self.capacity,
                          self.plan.min_bucket)

    def _gather_group(self, idx) -> dict:
        import numpy as np

        Mb = self._tenant_bucket(int(self._m_host[idx].max()))
        n_real = len(idx)
        if self.cohorts == "bucket-padded" and n_real > 0:
            # Pad the tenant axis to the next power of two with inert
            # copies of the first tenant: vmapped steps compile once per
            # (pow2 size, Mb), bounding recompiles under tenant churn.
            size = 1 << (n_real - 1).bit_length()
            idx_pad = np.concatenate([idx, np.repeat(idx[:1],
                                                     size - n_real)])
        else:
            idx_pad = idx
        rows = jax.tree.map(lambda leaf: leaf[idx_pad], self._full)
        state = _slice_stacked(rows, Mb) if Mb < self.capacity else rows
        return {"Mb": Mb, "idx": idx, "idx_pad": idx_pad, "n_real": n_real,
                "state": state}

    def _scatter_group(self, grp) -> None:
        idx = grp["idx"]
        sub = jax.tree.map(lambda leaf: leaf[:grp["n_real"]], grp["state"])
        full_rows = jax.tree.map(lambda leaf: leaf[idx], self._full)
        rows = (jax.vmap(scatter_state)(full_rows, sub)
                if grp["Mb"] < self.capacity else sub)
        self._full = jax.tree.map(
            lambda leaf, r: leaf.at[idx].set(r), self._full, rows)

    def _group_mask(self, grp, host_mask):
        """Pad a per-tenant host mask to the group's (padded) lanes; pad
        lanes are always inert."""
        import numpy as np

        out = np.asarray(host_mask)[grp["idx_pad"]].copy()
        out[grp["n_real"]:] = False
        return out

    def _regroup(self):
        """(Re)partition tenants into bucket-homogeneous groups.

        Called lazily: only when no grouping exists or some tenant's next
        update would cross its group's bucket — the same crossing points
        at which the "max" cohort re-slices.
        """
        import numpy as np

        if self._groups is not None:
            stale = any(
                self._tenant_bucket(int(self._m_host[g["idx"]].max()))
                != g["Mb"]
                or len(set(self._tenant_bucket(int(mi))
                           for mi in self._m_host[g["idx"]])) > 1
                for g in self._groups)
            if not stale:
                return
            for grp in self._groups:
                self._scatter_group(grp)
            self._groups = None
        buckets = np.asarray([self._tenant_bucket(int(mi))
                              for mi in self._m_host])
        self._groups = [self._gather_group(np.nonzero(buckets == b)[0])
                        for b in sorted(set(buckets.tolist()))]

    @property
    def states(self):
        """The capacity-M stacked ``KPCAState`` (flushes the working
        bucket; use the return value of ``update`` for hot-path reads)."""
        self._flush()
        return self._full

    def _working(self, need: int):
        """Bucket-resident stacked state holding ≥ ``need`` active pairs."""
        Mb = (self.capacity if self.plan.dispatch != "bucketed"
              else bucket_for(need, self.capacity, self.plan.min_bucket))
        if self._sub is None or Mb != self._Mb:
            self._flush()
            self._Mb = Mb
            self._sub = (_slice_stacked(self._full, Mb)
                         if Mb < self.capacity else self._full)
        return self._sub

    def _need(self) -> int:
        """Rows the next update must fit, re-syncing the host ceiling from
        the device when it matters (crossing or apparent exhaustion) —
        idle tenants make the ceiling an overestimate."""
        if self.window is not None:
            # Sliding windows bound every tenant at m <= window <= capacity
            # (active tenants at the window evict before ingesting; idle
            # tenants don't grow), so exhaustion is impossible — an idle
            # tenant parked at m == capacity must not trip the raise.
            return min(self._ceiling + 1, self.capacity)
        resync = self._ceiling + 1 > self.capacity or (
            self.plan.dispatch == "bucketed" and self._sub is not None
            and bucket_for(min(self._ceiling + 1, self.capacity),
                           self.capacity, self.plan.min_bucket) > self._Mb)
        if resync:
            st = self._sub if self._sub is not None else self._full
            self._ceiling = int(jnp.max(st.m))
        if self._ceiling + 1 > self.capacity:
            raise ValueError(
                f"tenant at active count {self._ceiling} exhausted capacity "
                f"{self.capacity} — truncate/compact or re-shard the cohort")
        return self._ceiling + 1

    # ---- streaming ----------------------------------------------------------
    def _evict_mask(self, act_host):
        """Tenants whose next active ingest must first evict (window full)."""
        import numpy as np

        if self.window is None:
            return np.zeros(self.n_tenants, bool)
        return act_host & (self._m_host >= self.window)

    def _evict_grouped(self, evict, plan) -> None:
        """Masked batched downdates of the oldest point (row 0) per group."""
        for grp in self._groups:
            ge = self._group_mask(grp, evict)
            if ge.any():
                rows = jnp.zeros((len(grp["idx_pad"]),), jnp.int32)
                grp["state"] = _batched_downdate_masked(
                    grp["state"], rows, jnp.asarray(ge), self.spec,
                    self.adjusted, plan)
        self._m_host[evict] -= 1
        self._evict_host[evict] += 1
        self._ceiling = int(self._m_host.max())

    # ---- per-tenant metric lanes (core/telemetry.py) ------------------------
    def _metrics_begin(self):
        """Snapshot the host tallies at a public entry point; the commit
        applies the deltas to the metric lanes in ONE fused dispatch —
        the eigensystem dispatches above are untouched (bitwise identity
        with ``plan.metrics`` off)."""
        if self.metrics is None:
            return None
        return (self._ingest_host.copy(), self._evict_host.copy(),
                self.quarantined.copy())

    def _metrics_commit(self, snap) -> None:
        import numpy as np

        from repro.core import telemetry as tm

        if snap is None:
            return
        i0, e0, q0 = snap
        fill = (self._m_host / float(self.window) if self.window is not None
                else np.full(self.n_tenants, tm.GAUGE_UNSET))
        self.metrics = tm.note_lanes(
            self.metrics, self._ingest_host - i0, self.quarantined - q0,
            self._evict_host - e0, self._m_host, fill)

    def metrics_report(self) -> dict:
        """Host snapshot of the per-tenant metric lanes (one sync)."""
        from repro.core import telemetry as tm

        return {} if self.metrics is None else tm.metrics_report(self.metrics)

    def note_skipped_publish(self) -> None:
        """Telemetry hook for the serving loop: a publication was refused
        on health grounds (counted on every lane — the verdict is
        cohort-wide)."""
        if self.metrics is not None:
            from repro.core import telemetry as tm

            self.metrics = tm.note_skipped_publish(self.metrics)

    def note_drift(self, drift) -> None:
        """Record the last probed per-tenant spectral drift as a gauge."""
        if self.metrics is not None:
            from repro.core import telemetry as tm

            self.metrics = tm.note_drift(self.metrics, drift)

    def update(self, xs: Array, active: Array | None = None):
        """Fold xs[i] (shape (B, d)) into tenant i, one device step per
        occupied bucket (one total for ``cohorts="max"``) — preceded, in
        sliding-window mode, by one masked batched downdate per bucket
        for the tenants whose window is full.

        Returns the bucket-resident stacked state ("max": the whole cohort
        at the cohort bucket; grouped cohorts: the LARGEST group's state —
        use ``states``/``state_of`` for full-cohort reads).
        """
        snap = self._metrics_begin()
        out = self._update_impl(xs, active)
        self._metrics_commit(snap)
        return out

    def _update_impl(self, xs: Array, active: Array | None = None):
        import numpy as np

        xs = jnp.asarray(xs)
        plan = self.plan.kernel_plan()
        act_host = (np.ones(self.n_tenants, bool) if active is None
                    else np.asarray(active, bool))
        policy = getattr(self.plan, "health", None)
        if policy is not None and policy.quarantine:
            # Host-side non-finite gate: a poisoned lane drops out of the
            # active mask BEFORE the evict mask is computed, so a windowed
            # tenant never evicts for an ingest that does not happen, its
            # ring/clock bookkeeping (_m_host) stays untouched, and the
            # rejected point is zeroed so it cannot NaN-poison the shared
            # batched dispatch other lanes ride.
            ok = np.isfinite(np.asarray(xs)).all(axis=1)
            if not ok.all():
                self.quarantined[act_host & ~ok] += 1
                act_host = act_host & ok
                active = jnp.asarray(act_host)
                xs = jnp.where(jnp.asarray(ok)[:, None], xs, 0.0)
        evict = self._evict_mask(act_host)
        if self._grouped:
            self._m_host_pending_check(act_host, evict)
            self._regroup()
            if evict.any():
                self._evict_grouped(evict, plan)
            act_dev = None if active is None else jnp.asarray(active)
            for grp in self._groups:
                idxp = grp["idx_pad"]
                if self.cohorts == "bucket-padded":
                    ga = self._group_mask(grp, act_host)
                    if ga.any():
                        grp["state"] = _batched_update_masked(
                            grp["state"], xs[idxp], jnp.asarray(ga),
                            self.spec, self.adjusted, plan)
                elif active is None:
                    grp["state"] = _batched_update(
                        grp["state"], xs[idxp], self.spec, self.adjusted,
                        plan)
                elif act_host[idxp].any():
                    grp["state"] = _batched_update_masked(
                        grp["state"], xs[idxp], act_dev[idxp], self.spec,
                        self.adjusted, plan)
            self._m_host[act_host] += 1
            self._ingest_host[act_host] += 1
            self._ceiling = int(self._m_host.max())
            return self._groups[-1]["state"]
        if evict.any():
            # One bucket serves the evict AND the following update (a
            # larger bucket is always sound), so a steady-state window
            # step never re-slices between its two device calls.
            post_max = int((self._m_host
                            - evict.astype(self._m_host.dtype)).max())
            need = max(int(self._m_host.max()),
                       min(post_max + 1, self.capacity))
            sub = self._working(need)
            rows = jnp.zeros((self.n_tenants,), jnp.int32)
            self._sub = _batched_downdate_masked(
                sub, rows, jnp.asarray(evict), self.spec, self.adjusted,
                plan)
            self._m_host[evict] -= 1
            self._evict_host[evict] += 1
            self._ceiling = int(self._m_host.max())
            sub = self._sub
        else:
            sub = self._working(self._need())
        if active is None:
            self._sub = _batched_update(sub, xs, self.spec, self.adjusted,
                                        plan)
            self._m_host += 1
            self._ingest_host += 1
        else:
            self._sub = _batched_update_masked(sub, xs, jnp.asarray(active),
                                               self.spec, self.adjusted,
                                               plan)
            act = np.asarray(active, bool)
            self._m_host[act] += 1
            self._ingest_host[act] += 1
        self._ceiling += 1
        return self._sub

    def _steady_window_scan(self, xs: Array, mask_host, plan: UpdatePlan):
        """Fold a whole block of evict+ingest pairs for the lanes in
        ``mask_host`` (each at m ≡ W) — one scanned dispatch per cohort
        group; lanes outside the mask pass through untouched."""
        import numpy as np

        # Every masked lane folds (and therefore evicts) one point per
        # scanned step; m is invariant at W so only the tallies move.
        mk = np.asarray(mask_host, bool)
        self._ingest_host[mk] += int(xs.shape[0])
        self._evict_host[mk] += int(xs.shape[0])
        if self._grouped:
            self._regroup()
            out = None
            for grp in self._groups:
                ga = self._group_mask(grp, mask_host)
                if ga.any():
                    grp["state"] = _batched_window_scan_masked(
                        grp["state"], xs[:, grp["idx_pad"]],
                        jnp.asarray(ga), self.spec, self.adjusted, plan)
                    out = grp["state"]
            return out if out is not None else self._groups[-1]["state"]
        sub = self._working(max(int(self._m_host.max()), 1))
        self._sub = _batched_window_scan_masked(
            sub, xs, jnp.asarray(mask_host), self.spec, self.adjusted, plan)
        return self._sub

    def _m_host_pending_check(self, act_host, evict=None) -> None:
        """Raise on capacity exhaustion BEFORE mutating any state.
        ``evict`` marks tenants whose ingest evicts first (window mode),
        so their net growth is zero."""
        after = self._m_host + act_host.astype(self._m_host.dtype)
        if evict is not None:
            after = after - evict.astype(self._m_host.dtype)
        if (after > self.capacity).any():
            worst = int(self._m_host.max())
            raise ValueError(
                f"tenant at active count {worst} exhausted capacity "
                f"{self.capacity} — truncate/compact or re-shard the cohort")

    def update_block(self, xs: Array):
        """Stream a (T, B, d) block: scan over T with tenants vmapped per
        step; chunks are cut at bucket crossings (any group's, in grouped
        cohort modes).  Window mode: tenants still below their window
        step point-by-point (each step may evict, a host-side dispatch
        decision), but once EVERY tenant sits at m ≡ W the remaining
        steps are fixed-shape evict+ingest pairs and fold through ONE
        scanned dispatch per cohort group
        (``_batched_window_scan_masked``) — the multi-tenant mirror of
        ``Engine.window_block``'s steady state.

        With ``plan.health.quarantine`` the block is cut at the steps
        that carry a non-finite point: maximal clean runs keep the
        scanned block path, poisoned steps route through the per-point
        ``update`` gate (which drops only the offending lanes and tallies
        them in ``quarantined``)."""
        snap = self._metrics_begin()
        out = self._update_block_impl(xs)
        self._metrics_commit(snap)
        return out

    def _update_block_impl(self, xs: Array):
        import numpy as np

        xs = jnp.asarray(xs)
        T = xs.shape[0]
        policy = getattr(self.plan, "health", None)
        if policy is not None and policy.quarantine:
            finite = np.isfinite(np.asarray(xs)).all(axis=(1, 2))
            if not bool(finite.all()):
                out = None
                i = 0
                while i < T:
                    if finite[i]:
                        j = i + 1
                        while j < T and finite[j]:
                            j += 1
                        out = self._update_block_clean(xs[i:j])
                        i = j
                    else:
                        out = self._update_impl(xs[i])
                        i += 1
                return out
        return self._update_block_clean(xs)

    def _update_block_clean(self, xs: Array):
        """``update_block`` body for an all-finite block (see above)."""
        import numpy as np

        T = xs.shape[0]
        if self.window is not None:
            # Mixed-cohort windowed blocks: tenant lanes are disjoint, so
            # the two phases split by LANE, not by time.  Tenants already
            # sitting at m ≡ W fold the ENTIRE block through one scanned
            # dispatch per group immediately (their active counts are
            # frozen — evict+ingest nets zero, no bucket crossing can
            # occur); only the growing lanes step point-by-point (each
            # step may evict, a host-side dispatch decision), and once
            # every grower reaches W their remaining steps scan too.  A
            # mixed cohort no longer drags its steady majority through
            # per-point dispatches.
            plan = self.plan.kernel_plan()
            steady = np.asarray(self._m_host >= self.window)
            grow = ~steady
            out = None
            if steady.any():
                out = self._steady_window_scan(xs, steady, plan)
            if grow.any():
                act = None if not steady.any() else jnp.asarray(grow)
                t = 0
                while t < T and int(self._m_host[grow].min()) < self.window:
                    out = self._update_impl(xs[t], active=act)
                    t += 1
                if t < T:
                    out = self._steady_window_scan(xs[t:], grow, plan)
            return out
        i = 0
        if self._grouped:
            ones = np.ones(self.n_tenants, bool)
            plan = self.plan.kernel_plan()
            while i < T:
                self._m_host_pending_check(ones)
                self._regroup()
                take = min(min(g["Mb"] - int(self._m_host[g["idx"]].max())
                               for g in self._groups), T - i)
                for grp in self._groups:
                    blk = xs[i:i + take][:, grp["idx_pad"]]
                    if self.cohorts == "bucket-padded":
                        ga = self._group_mask(grp, ones)
                        grp["state"] = _batched_scan_masked(
                            grp["state"], blk, jnp.asarray(ga), self.spec,
                            self.adjusted, plan)
                    else:
                        grp["state"] = _batched_scan(
                            grp["state"], blk, self.spec, self.adjusted,
                            plan)
                self._m_host += take
                self._ingest_host += take
                i += take
            self._ceiling = int(self._m_host.max())
            return self._groups[-1]["state"]
        while i < T:
            sub = self._working(self._need())
            # Chunk at the working bucket even when it is the capacity rung,
            # so _need() raises on exhaustion instead of clamping writes.
            take = min(self._Mb - self._ceiling, T - i)
            self._sub = _batched_scan(sub, xs[i:i + take], self.spec,
                                      self.adjusted, self.plan.kernel_plan())
            self._ceiling += take
            self._m_host += take
            self._ingest_host += take
            i += take
        return self._sub

    def transform(self, q: Array, n_components: int) -> Array:
        """Project per-tenant query batches q: (B, nq, d) -> (B, nq, k)."""
        q = jnp.asarray(q)
        fn = partial(transform_state, spec=self.spec, adjusted=self.adjusted,
                     n_components=n_components, plan=self.plan)
        if self._grouped and self._groups is not None:
            out = None
            for grp in self._groups:
                yg = jax.vmap(fn)(grp["state"], q[grp["idx_pad"]])
                yg = yg[:grp["n_real"]]
                if out is None:
                    out = jnp.zeros((self.n_tenants,) + yg.shape[1:],
                                    yg.dtype)
                out = out.at[grp["idx"]].set(yg)
            return out
        st = self._sub if self._sub is not None else self._full
        return jax.vmap(fn)(st, q)

    def working_states(self) -> list:
        """The bucket-resident working state(s) without flushing: one
        stacked state per occupied bucket group (grouped cohorts), else
        the single cohort state.  For hot-path synchronization
        (``jax.block_until_ready``) and inspection."""
        if self._grouped and self._groups is not None:
            return [g["state"] for g in self._groups]
        return [self._sub if self._sub is not None else self._full]

    def health_summary(self) -> dict:
        """Host-side quarantine tally (``plan.health.quarantine``): total
        and per-tenant counts of points rejected by the non-finite gate
        before any device dispatch."""
        return {"quarantined": int(self.quarantined.sum()),
                "quarantined_per_tenant": self.quarantined.copy()}

    def probe_all(self, ref_lam=None):
        """Vmapped in-graph health probe over every tenant's working
        state — no flush, one probe dispatch per occupied bucket group.
        Returns host arrays ``(healthy, drift)`` of shape (B,); ``drift``
        is None unless ``ref_lam`` (a (B, C) frozen top spectrum, e.g.
        the one recorded at the last publication) is given, in which case
        it carries each tenant's relative spectral drift — the staleness
        signal for drift-triggered publication."""
        import numpy as np

        from repro.core import health as hl

        policy = getattr(self.plan, "health", None) or hl.DEFAULT_POLICY
        healthy = np.zeros(self.n_tenants, bool)
        drift = None if ref_lam is None else np.zeros(self.n_tenants, float)
        ref = None if ref_lam is None else jnp.asarray(ref_lam)

        def one(st, lanes, idx):
            # lanes: tenant id per stacked lane (repeats pad the group);
            # the first len(idx) lanes are the real tenants.
            h0 = hl.init_health(st.L.dtype)
            hb = jax.vmap(lambda s: hl.probe(s, h0, policy))(st)
            ok = np.asarray(jax.vmap(lambda h: hl.verdict(h, policy))(hb))
            healthy[idx] = ok[:len(idx)]
            if ref is not None:
                dr = np.asarray(jax.vmap(hl.spectral_drift)(
                    st, ref[np.asarray(lanes)]))
                drift[idx] = dr[:len(idx)]

        if self._grouped and self._groups is not None:
            for grp in self._groups:
                one(grp["state"], np.asarray(grp["idx_pad"]),
                    np.asarray(grp["idx"]))
        else:
            st = self._sub if self._sub is not None else self._full
            idx = np.arange(self.n_tenants)
            one(st, idx, idx)
        return healthy, drift

    def heal(self, *, level: str = "auto") -> int:
        """Walk the heal ladder (``health.heal_kpca``) over the cohort:
        probe every tenant, flush, and heal the unhealthy ones in place
        ("auto"; a forced ``level`` heals all).  Returns the number of
        tenants healed.  ``health.HealthError`` propagates — the
        restore-from-checkpoint rung belongs to the caller, who owns the
        checkpoint directory."""
        import numpy as np

        from repro.core import health as hl

        policy = getattr(self.plan, "health", None) or hl.DEFAULT_POLICY
        if level == "auto":
            healthy, _ = self.probe_all()
            todo = np.nonzero(~healthy)[0]
        else:
            todo = np.arange(self.n_tenants)
        if len(todo) == 0:
            return 0
        self._flush()
        full = self._full
        rungs = np.zeros((2, self.n_tenants), np.int64)  # polish / resync
        for i in todo:
            st = jax.tree.map(lambda leaf: leaf[int(i)], full)
            rung_out: list = []
            st = hl.heal_kpca(st, self.spec, self.adjusted, policy,
                              level=level, rung_out=rung_out)
            if rung_out and rung_out[-1] in ("polish", "resync"):
                rungs[0 if rung_out[-1] == "polish" else 1, int(i)] += 1
            full = jax.tree.map(lambda fl, sl: fl.at[int(i)].set(sl),
                                full, st)
        self._full = full
        if self.metrics is not None and rungs.any():
            from repro.core import telemetry as tm

            self.metrics = self.metrics._replace(
                heals_polish=self.metrics.heals_polish
                + jnp.asarray(rungs[0], jnp.int32),
                heals_resync=self.metrics.heals_resync
                + jnp.asarray(rungs[1], jnp.int32))
        return len(todo)

    def publish(self, n_components: int | None = None):
        """Publish per-tenant ``serving.ServingSnapshot``s (stacked on the
        tenant axis) from the current working state — the decoupled-serve
        read path: queries batch against the returned snapshots
        (``serving.query_batch``) while subsequent updates keep folding
        into the working state A.  Default width is
        ``plan.serve_components``.  "max" cohorts publish from the
        bucket-resident state (snapshot capacity = the cohort bucket);
        grouped cohorts flush first so one stacked snapshot covers every
        tenant."""
        from repro.core import serving

        nc = int(self.plan.serve_components if n_components is None
                 else n_components)
        self._serve_gen = getattr(self, "_serve_gen", -1) + 1
        gen = jnp.asarray(self._serve_gen, jnp.int32)
        if self.metrics is not None:
            from repro.core import telemetry as tm

            self.metrics = tm.note_publish(self.metrics, self._serve_gen)
        if self._grouped:
            st = self.states
        else:
            st = self._sub if self._sub is not None else self._full
        return jax.vmap(lambda s: serving.publish_transform(
            s, n_components=nc, adjusted=self.adjusted, generation=gen))(st)

    def state_of(self, i: int):
        """Unstack tenant i's capacity-M state (checkpoint convenience)."""
        return jax.tree.map(lambda leaf: leaf[i], self.states)
