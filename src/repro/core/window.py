"""Sliding-window incremental KPCA: bounded memory, unbounded streams.

``KPCAStream(window=W)`` tracks the exact mean-adjusted (or raw) kernel
eigensystem of the **trailing W points** of an endless stream: once the
window is full, every ingested point first evicts the oldest one via the
decremental pipeline (``core/downdate.py``) and then folds in as usual —
so per-step cost stays at the window's bucket forever and memory never
grows, which is what the ROADMAP's unbounded-stream serving scenario
requires (append-only streams saturate at capacity instead).

The FIFO ordering is carried **in the state** as an arrival-index ring
(``ages``/``clock``), not as host-side stream bookkeeping, so a windowed
stream checkpointed mid-window restores and continues identically to an
uninterrupted run.  Carrying it in-state is also what lets the
steady-state scan (``engine.Engine.window_block``) advance the ring
inside ``lax.scan`` — victim selection (argmin of ages) needs no host
round-trip, so a full-window block folds in ONE dispatch.  The eviction permutation (``downdate.boundary_perm``)
preserves the survivors' arrival order, so physically the oldest active
point is always row argmin(ages) — row 0 for a pure FIFO stream — but
the ring stays authoritative across replace-arbitrary-row calls and
checkpoint round-trips.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import downdate as dd
from repro.core import engine as eng
from repro.core import kernels_fn as kf

Array = jax.Array

def age_sentinel(dtype) -> int:
    """Inactive-slot marker: far above any real arrival index.  Derived
    from the REALIZED dtype — without x64, int64 requests silently become
    int32 and a fixed 2⁶² constant would overflow into a negative value
    that argmin then prefers over live rows."""
    return int(jnp.iinfo(dtype).max // 2)


class WindowState(NamedTuple):
    """A ``KPCAState`` plus the FIFO arrival ring.

    kpca:  the fixed-capacity eigensystem state (see ``inkpca.KPCAState``)
    ages:  (M,) arrival index of the point in each physical row, in the
           realized integer dtype (int64 under x64, int32 otherwise —
           which is why ``rebase_ages`` exists); ``age_sentinel(dtype)``
           marks inactive rows
    clock: ()  arrival index of the next ingested point (same dtype)
    """

    kpca: object
    ages: Array
    clock: Array


def init_window(x0: Array, capacity: int, spec: kf.KernelSpec, *,
                adjusted: bool = True, dtype=jnp.float32) -> WindowState:
    from repro.core import inkpca

    kpca = inkpca.init_state(x0, capacity, spec, adjusted=adjusted,
                             dtype=dtype)
    m0 = x0.shape[0]
    ages = jnp.zeros((capacity,), jnp.int64)     # realized: int32 w/o x64
    ages = jnp.full((capacity,), age_sentinel(ages.dtype), ages.dtype)
    ages = ages.at[:m0].set(jnp.arange(m0, dtype=ages.dtype))
    return WindowState(kpca=kpca, ages=ages,
                       clock=jnp.asarray(m0, ages.dtype))


def oldest_row(wstate: WindowState) -> int:
    """Physical row of the oldest active point (host-side read)."""
    return int(jnp.argmin(wstate.ages))


def evict(engine: eng.Engine, wstate: WindowState, row: int, *,
          min_rows: int = 0) -> WindowState:
    """Remove the point in physical ``row`` and update the ages ring with
    the same survivor-order-preserving permutation the downdate applied."""
    kpca = engine.downdate(wstate.kpca, row, min_rows=min_rows)
    order = dd.boundary_perm(jnp.asarray(row, jnp.int32), wstate.kpca.m,
                             wstate.ages.shape[0])
    ages = wstate.ages[order].at[wstate.kpca.m - 1].set(
        age_sentinel(wstate.ages.dtype))
    return wstate._replace(kpca=kpca, ages=ages)


def rebase_ages(wstate: WindowState) -> WindowState:
    """Shift all active arrival stamps (and the clock) down so the clock
    restarts at ``capacity``.  Active ages live in [clock − m, clock), so
    subtracting clock − capacity preserves their order and keeps them
    non-negative; sentinel slots stay sentinels.  Called when the clock
    nears the sentinel — without x64 the ring is int32 and a forever
    stream would otherwise collide with the sentinel after ~10⁹ points
    (argmin would then pick an inactive slot and eviction would raise).
    """
    sent = age_sentinel(wstate.ages.dtype)
    base = wstate.clock - wstate.ages.shape[0]
    ages = jnp.where(wstate.ages == sent, sent, wstate.ages - base)
    return wstate._replace(ages=ages, clock=wstate.clock - base)


def maybe_rebase(wstate: WindowState) -> WindowState:
    """Traced rebase guard: rebase when the clock nears the sentinel,
    selected with ``jnp.where`` so the check never forces a device sync
    (the rebase arithmetic is O(M) elementwise — cheaper than the sync
    the old host-side ``int(clock)`` comparison paid on every step)."""
    sent = age_sentinel(wstate.ages.dtype)
    reb = rebase_ages(wstate)
    need = wstate.clock >= sent - 1
    return wstate._replace(ages=jnp.where(need, reb.ages, wstate.ages),
                           clock=jnp.where(need, reb.clock, wstate.clock))


def stamp_grown_ages(wstate: WindowState, grown, count: int) -> WindowState:
    """Stamp arrival indices for ``count`` append-only points just folded
    into ``grown`` (a KPCAState) — the growth-phase half of
    ``Engine.window_block``.  ``count`` and the pre-growth active count
    are host values, so the stamp is one fused slice write."""
    m0 = int(wstate.kpca.m)
    stamps = wstate.clock + jnp.arange(count, dtype=wstate.ages.dtype)
    ages = jax.lax.dynamic_update_slice(wstate.ages, stamps, (m0,))
    return WindowState(kpca=grown, ages=ages, clock=wstate.clock + count)


def ingest(engine: eng.Engine, wstate: WindowState, x_new: Array, *,
           window: int, min_rows: int = 0, hstate=None):
    """One sliding-window step: evict-oldest if the window is full, then
    fold the new point in and stamp its arrival index.

    The evict decision reads ``int(m)`` on the host (the same sync bucket
    selection already pays); the rebase guard is traced.  For steady-state
    blocks use ``Engine.window_block`` — one scanned dispatch, no host
    syncs inside the block.

    With a health policy on the plan (``plan.health``) the point goes
    through the quarantine gate first — a rejected (non-finite/outlier)
    point leaves the eigensystem, the arrival ring, the ages AND the
    clock untouched, so evict order stays consistent with a stream that
    never saw it.  (The old behaviour evicted and stamped regardless,
    which skewed the ring even though the update should not happen.)
    Pass ``hstate`` (a ``health.HealthState``) to also receive the
    updated probe/quarantine counters: returns ``(wstate, hstate)``;
    without it, returns ``wstate`` alone.

    This is now a thin spelling of the composed pipeline: the bundle's
    ``ages`` member selects the evict stage, ``plan.health`` decides the
    gate stage (see ``engine.Engine.step``).
    """
    policy = getattr(engine.plan, "health", None)
    h = None
    if policy is not None:
        from repro.core import health as hl

        h = hstate if hstate is not None else hl.init_health(
            wstate.kpca.L.dtype)
    s = engine.step(eng.make_stream(wstate, health=h), x_new,
                    window=window, min_rows=min_rows)
    out = WindowState(kpca=s.kpca, ages=s.ages, clock=s.clock)
    if policy is not None and hstate is not None:
        return out, s.health
    return out
