"""Rank-one updates to the symmetric eigendecomposition (paper §3.2).

Given A = U diag(d) U^T and a symmetric perturbation A + sigma * v v^T, the
updated eigenvalues are the roots of the secular equation (Golub 1973)

    w(t) = 1 + sigma * sum_i z_i^2 / (d_i - t),        z = U^T v

and the updated eigenvectors are U @ W with W[:, j] ∝ z / (d - t_j)
(Bunch, Nielsen & Sorensen 1978).  Two eigenvector variants are provided:

* ``method="bns"``  — paper-faithful: use z directly (Bunch et al. 1978).
* ``method="gu"``   — beyond-paper stability upgrade: recompute ẑ from the
  computed roots via the Gu & Eisenstat (1994) identity, which restores
  numerical orthogonality of the updated eigenvectors (the paper cites this
  line of work as a possible improvement; we implement it).

Design for TPUs / jit:

* **Fixed capacity M with an active count m.**  All arrays are padded to a
  static capacity; inactive eigenpairs are kept as exact identity pairs
  (U[:, j] = e_j) with *sentinel* eigenvalues placed strictly above the
  active spectrum.  One XLA compilation then serves an entire stream of
  updates — no per-step retracing, and static shapes as TPUs require.
* **Vectorized fixed-iteration bisection** for the secular equation: all M
  roots are bracketed by the interlacing bounds (paper eq. 5) and refined
  branch-free in parallel — O(iters · M^2) VPU work.
* The O(M^3) eigenvector rotation U @ W is the compute hot spot; W is a
  Cauchy-like matrix generated from three O(M) vectors, so the matmul is
  performed by a fused Pallas kernel (``repro.kernels.eigvec_update``) that
  builds W tiles in VMEM on the fly (set ``matmul="pallas"``).
* sigma < 0 is reduced to sigma > 0 via the flip identity
  ``eig(D + s zz^T) = -rev(eig(-rev(D) + |s| rev(z)rev(z)^T))``.
"""
from __future__ import annotations

from functools import partial
from typing import Literal, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Margin multiplier used when regenerating sentinel eigenvalues.
_SENTINEL_GAP = 1.0


def _eps_for(dtype) -> float:
    return float(jnp.finfo(dtype).eps)


def active_mask(M: int, m: Array) -> Array:
    return jnp.arange(M) < m


def sentinelize(d: Array, m: Array, room: Array) -> Array:
    """Place inactive eigenvalues strictly above the active spectrum.

    ``room`` is an upper bound on how far the top active root can travel
    (sigma * ||z||^2 for sigma > 0, else 0).  Sentinels are spaced by 1 so
    bisection intervals in the inactive region are well conditioned.
    """
    M = d.shape[0]
    mask = active_mask(M, m)
    top = jnp.max(jnp.where(mask, d, -jnp.inf))
    top = jnp.where(jnp.isfinite(top), top, 0.0)  # m == 0 corner
    base = top + jnp.abs(room) + _SENTINEL_GAP
    idx = jnp.arange(M, dtype=d.dtype)
    sent = base + _SENTINEL_GAP * (idx - m.astype(d.dtype))
    return jnp.where(mask, d, sent)


def _secular_bisect(d: Array, z2: Array, sigma: Array, iters: int,
                    defl: Array | None = None) -> Array:
    """All roots of 1 + sigma * sum_i z2_i/(d_i - t), sigma > 0, d ascending.

    Root j lives in (d_j, next pole) for j < M-1 and (d_{M-1}, d_{M-1} +
    sigma*sum(z2)) for the top root (paper eq. 5).  Fixed-iteration
    bisection, fully vectorized over all M roots.

    ``defl`` marks deflated poles (z_i == 0, Bunch §4): their eigenvalue
    stays AT the pole, and the bracket of every other root skips over them
    (the upper end is the next NON-deflated pole) — otherwise a root to the
    right of a deflated pole is lost and the pole double-counted.
    """
    M = d.shape[0]
    znorm2 = jnp.sum(z2)
    top = d[-1] + sigma * znorm2 + _eps_for(d.dtype)
    lo = d
    if defl is None:
        hi = jnp.concatenate([d[1:], top[None]])
    else:
        d_nd = jnp.where(defl, jnp.inf, d)
        nxt = jnp.concatenate(
            [jax.lax.cummin(d_nd[::-1])[::-1][1:], jnp.asarray([jnp.inf],
                                                               d.dtype)])
        hi = jnp.where(jnp.isinf(nxt), top, nxt)

    def w_at(t: Array) -> Array:
        # t: (M,) candidate per root; terms (M poles, M roots)
        den = d[:, None] - t[None, :]
        safe = jnp.where(den == 0.0, _eps_for(d.dtype), den)
        return 1.0 + sigma * jnp.sum(z2[:, None] / safe, axis=0)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        pos = w_at(mid) > 0.0  # w increasing between poles => root below mid
        return jnp.where(pos, lo, mid), jnp.where(pos, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    roots = 0.5 * (lo + hi)
    if defl is not None:
        roots = jnp.where(defl, d, roots)
    return roots


def _cluster_merge(d: Array, z: Array, tol: Array):
    """LAPACK dlaed2-style cluster deflation, vectorized.

    Poles closer than ``tol`` cannot be separated by the secular solver and
    wreck the Cauchy eigenvector columns (the near-zero cluster that mean-
    centering + near-duplicate points create on every real dataset).  For
    each run of near-equal poles, a Householder reflector H (block-diagonal
    over runs) rotates the run's z-mass into its LAST element; the others
    become exactly zero and deflate.  Replacing D by H D H ≈ D errs by at
    most the run width ≤ tol — the standard LAPACK trade.

    Returns (z_new, apply, fired) where apply(X) = H @ X in O(M²) via
    segment sums (no extra matmul: the paper's 2m³-per-update flop count is
    preserved) and ``fired`` is a traced bool — True iff H is not the
    identity, i.e. a merge actually rotates z-mass.  ``fired`` is what the
    fused pair path conds on to fall back to this sequential pipeline.
    """
    M = d.shape[0]
    gap = jnp.diff(d)
    new_seg = jnp.concatenate([jnp.ones((1,), bool), gap > tol])
    seg = jnp.cumsum(new_seg.astype(jnp.int32)) - 1          # (M,)
    ones = jnp.ones_like(z)
    seg_size = jax.ops.segment_sum(ones, seg, num_segments=M)[seg]
    z2sum = jax.ops.segment_sum(z * z, seg, num_segments=M)[seg]
    znorm_seg = jnp.sqrt(z2sum)
    is_last = jnp.concatenate([new_seg[1:], jnp.ones((1,), bool)])
    z_last = jax.ops.segment_sum(jnp.where(is_last, z, 0.0), seg,
                                 num_segments=M)[seg]
    sl = jnp.where(z_last >= 0, 1.0, -1.0)
    target = -sl * znorm_seg                  # H z_run = target · e_last
    w = z - jnp.where(is_last, target, 0.0)
    wnorm2 = jax.ops.segment_sum(w * w, seg, num_segments=M)[seg]
    tiny = jnp.finfo(d.dtype).tiny
    active = (seg_size > 1.5) & (wnorm2 > tiny)
    coef = jnp.where(active, 2.0 / jnp.where(active, wnorm2, 1.0), 0.0)

    def apply(X: Array) -> Array:             # H @ X, rows mixed per run
        s = jax.ops.segment_sum(w[:, None] * X, seg, num_segments=M)[seg]
        return X - (coef * w)[:, None] * s

    wz = jax.ops.segment_sum(w * z, seg, num_segments=M)[seg]
    z_new = z - coef * w * wz
    # exact zeros on merged (non-last) members so deflation catches them
    z_new = jnp.where(active & ~is_last, 0.0, z_new)
    return z_new, apply, jnp.any(active)


def _gu_zhat(d: Array, roots: Array, sigma: Array, z: Array) -> Array:
    """Gu–Eisenstat recomputation of |z| from the computed roots.

    sigma * ẑ_i^2 = prod_j (roots_j - d_i) / prod_{j != i} (d_j - d_i).
    Evaluated in log space (signs cancel pairwise under interlacing).
    Inactive entries (roots_j == d_j exactly) contribute log(1) = 0 to both
    products, so padding is transparent; the i-th numerator factor makes
    ẑ_i = 0 exactly for deflated/inactive entries.
    """
    num = roots[None, :] - d[:, None]                      # (i, j)
    den = d[None, :] - d[:, None]
    den = den.at[jnp.diag_indices(d.shape[0])].set(1.0)
    tiny = jnp.finfo(d.dtype).tiny
    log_z2 = (jnp.sum(jnp.log(jnp.abs(num) + tiny), axis=1)
              - jnp.sum(jnp.log(jnp.abs(den) + tiny), axis=1)
              - jnp.log(jnp.abs(sigma)))
    z2hat = jnp.exp(log_z2)
    zhat = jnp.sign(z) * jnp.sqrt(z2hat)
    # Guard: if the identity degenerates numerically, fall back to z.
    ok = jnp.isfinite(zhat)
    return jnp.where(ok, zhat, z)


def _cauchy_W(d: Array, roots: Array, zhat: Array) -> tuple[Array, Array]:
    """W[i, j] = zhat_i / (d_i - roots_j) and per-column inverse norms."""
    den = d[:, None] - roots[None, :]
    eps = _eps_for(d.dtype)
    safe = jnp.where(jnp.abs(den) < eps, jnp.where(den < 0, -eps, eps), den)
    W = zhat[:, None] / safe
    norms = jnp.sqrt(jnp.sum(W * W, axis=0))
    inv = jnp.where(norms > 0, 1.0 / norms, 1.0)
    return W, inv


def _update_body(L: Array, U: Array, v: Array, sigma: Array, m: Array, *,
                 iters: int, method: str, matmul: str, precise: bool,
                 z: Array | None = None, row_offset: Array | None = None
                 ) -> tuple[Array, Array]:
    """Un-jitted body of ``rank_one_update`` (reused by the fused pair's
    cond-guarded merge fallback, which must inline it under one jit).

    ``U`` may be a (R, M) row block of the full eigenvector matrix, in
    which case ``z`` = Uᵀv must be supplied precomputed (the distributed
    path obtains it with one psum over the row shards) and ``row_offset``
    names the block's first global row so the Pallas rotation can prune
    along the row axis too.  With z=None (default) it is computed locally
    from the full square U — the original single-device semantics.
    """
    M = L.shape[0]
    dtype = L.dtype
    mask = active_mask(M, m)

    if z is None:
        v = jnp.where(mask, v, 0.0)
        z = U.T @ v
    else:
        z = jnp.where(mask, z, 0.0)
    # Deflation (Bunch §4, the case the paper handles by exclusion in §5):
    # eigendirections with |z_i| ~ 0 do not move — zero them out, pin their
    # roots at the poles, and skip them in every other root's bracket.
    # (Centering makes K' exactly singular along 1, and near-duplicate
    # points cluster eigenvalues near 0, so this path is exercised on every
    # real dataset, not just in corner cases.)
    sig_abs = jnp.abs(sigma)

    # Re-sentinelize with head-room for the top root's travel, then apply the
    # flip identity so the effective sigma is positive.  Under the flip the
    # sentinels land (negated) at the *bottom* of the array, still sorted.
    room = sig_abs * jnp.sum(z * z)
    d_sent = sentinelize(L, m, room)

    # Cluster-merge deflation (dlaed2-style): rotate the z-mass of runs of
    # near-equal poles into one member; U absorbs the block reflector at
    # O(M²). Sentinels are spaced by 1 ≫ tol and never merge.
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    tol = 64.0 * _eps_for(dtype) * scale
    z, applyH, _ = _cluster_merge(d_sent, z, tol)
    U = applyH(U.T).T                            # U @ H, no matmul

    f = _solve_factor(d_sent, z, sigma, m, scale, iters=iters, method=method,
                      precise=precise)
    U_new = _apply_factor(U, f, mask, m, matmul=matmul,
                          row_offset=row_offset)
    # Deflation can locally reorder roots (a root may legitimately cross a
    # deflated pole); the next update's interlacing needs ascending order.
    perm = jnp.argsort(f.L_new)
    return f.L_new[perm], U_new[:, perm]


@partial(jax.jit, static_argnames=("iters", "method", "matmul", "precise"))
def rank_one_update(
    L: Array,
    U: Array,
    v: Array,
    sigma: Array,
    m: Array,
    *,
    iters: int = 62,
    method: Literal["gu", "bns"] = "gu",
    matmul: Literal["jnp", "pallas"] = "jnp",
    precise: bool = True,
    z: Array | None = None,
) -> tuple[Array, Array]:
    """One symmetric rank-one update of the eigendecomposition.

    L: (M,) eigenvalues ascending (sentinels above active spectrum),
    U: (M, M) eigenvectors in columns (identity on inactive columns),
    v: (M,) update vector, zero beyond the active region,
    sigma: scalar, either sign (sign handled by the flip identity),
    m: active count (traced scalar).

    ``z`` (optional) is a precomputed Uᵀv in the CURRENT basis — the fused
    ingest kernel produces it alongside the kernel row, skipping this
    update's own pass over U.

    Returns the updated (L, U), sorted ascending, same padding invariants.
    """
    return _update_body(L, U, v, sigma, m, iters=iters, method=method,
                        matmul=matmul, precise=precise, z=z)


class _Factor(NamedTuple):
    """One solved rank-one update as an original-domain Cauchy factor.

    The normalized eigenvector rotation is W[k, j] = z_k·inv_j/(d_k-lam_j)
    with deflated columns replaced by identity columns; ``L_new`` is the
    updated (pre-sort) spectrum.  All vectors live in the original domain
    (the sigma<0 flip's sign is folded into z), so the active region is a
    prefix regardless of sigma's sign.
    """

    z: Array
    d: Array
    lam: Array
    inv: Array
    defl: Array
    L_new: Array


def _solve_factor(d_sent: Array, z: Array, sigma: Array, m: Array,
                  scale: Array, *, iters: int, method: str,
                  precise: bool) -> _Factor:
    """Displacement deflation + secular solve + un-flip, as a ``_Factor``.

    The single shared solve pipeline behind ``rank_one_update`` and
    ``rank_one_update_pair`` — the deflation thresholds, the sigma<0 flip
    identity, and the precise/x64 solve-dtype policy live only here.

    Displacement-based deflation (the LAPACK criterion): if an eigenvalue
    moves by less than the representable resolution of the spectrum
    (σ·z_i² ≲ eps·‖A‖), bisection collapses the root ONTO the pole and two
    eigenvector columns degenerate to the same basis vector — deflate
    instead (root pinned at the pole, column = e_i, brackets skip it).

    The secular solve is O(M²) VPU work but numerically delicate (pole
    differences d_i - t_j); when ``precise`` and x64 is enabled it runs in
    f64 (the factor's vectors come back in the solve dtype) — negligible
    cost next to the O(M³) rotation, large drift win for f32 states.

    Un-flip: folding the flip identity's sign into z gives, exactly,
    W_eff[::-1, ::-1] == (-zhat_eff[::-1]) / (d_sent - (-roots_eff[::-1])),
    so the returned factor lives in the original domain and its active
    region is a prefix for either sigma sign — which is what lets the
    Pallas kernels prune every tile beyond ceil(m/B).
    """
    M = d_sent.shape[0]
    dtype = d_sent.dtype
    mask = active_mask(M, m)
    sig_abs = jnp.abs(sigma)
    neg = sigma < 0
    znorm = jnp.sqrt(jnp.sum(z * z))
    floor = 32.0 * _eps_for(dtype) * jnp.maximum(znorm, _eps_for(dtype))
    defl = (~mask | (jnp.abs(z) < floor)
            | (sig_abs * z * z < 64.0 * _eps_for(dtype) * scale))
    z = jnp.where(defl, 0.0, z)

    d_eff = jnp.where(neg, -d_sent[::-1], d_sent)
    z_eff = jnp.where(neg, z[::-1], z)
    defl_eff = jnp.where(neg, defl[::-1], defl)
    solve_dtype = (jnp.float64 if (precise and jax.config.jax_enable_x64)
                   else dtype)
    d_s = d_eff.astype(solve_dtype)
    z_s = z_eff.astype(solve_dtype)
    sig_s = sig_abs.astype(solve_dtype)
    roots_eff = _secular_bisect(d_s, z_s * z_s, sig_s, iters, defl=defl_eff)
    if method == "gu":
        zhat_eff = _gu_zhat(d_s, roots_eff, sig_s, z_s)
        zhat_eff = jnp.where(defl_eff, 0.0, zhat_eff)
    else:
        zhat_eff = z_s
    _, inv_eff = _cauchy_W(d_s, roots_eff, zhat_eff)
    inv_eff = jnp.where(defl_eff, 1.0, inv_eff)

    z_o = jnp.where(neg, -zhat_eff[::-1], zhat_eff)
    lam_o = jnp.where(neg, -roots_eff[::-1], roots_eff)
    inv_o = jnp.where(neg, inv_eff[::-1], inv_eff)
    L_new = jnp.where(mask, lam_o.astype(dtype), d_sent)
    return _Factor(z=jnp.where(mask, z_o, 0.0),
                   d=d_sent.astype(solve_dtype), lam=lam_o, inv=inv_o,
                   defl=defl, L_new=L_new)


def _apply_factor(U: Array, f: _Factor, mask: Array, m: Array, *,
                  matmul: str, row_offset: Array | None = None) -> Array:
    """U @ Ŵn for a single factor, preserving the padding invariants.

    ``U`` may be a row *block* of the full eigenvector matrix (the
    distributed row-sharded path rotates only its local rows): every
    overwrite below selects old columns of ``U`` itself, never a fresh
    identity, so the result is exact for any row count.  The Pallas kernel
    accepts rectangular (R, M) blocks; ``row_offset`` (the block's first
    global row) lets it prune row tiles beyond the active prefix as well,
    which is what keeps per-update MXU work at O(m_rows·m²) on P > 1
    meshes.  Pruned rows of active columns come back as zeros — their
    true value, since z is masked beyond the active prefix.
    """
    dtype = U.dtype
    if matmul == "pallas":
        # The factor is regenerated tile-by-tile in VMEM from O(M) vectors
        # (see kernels/eigvec_update), with tiles beyond the active range
        # pruned along rows, columns and the reduction axis.
        from repro.kernels.eigvec_update import ops as _ops
        z_k = jnp.where(mask, f.z.astype(dtype), 0.0)
        d_k = jnp.where(mask, f.d.astype(dtype), 2e30)
        lam_k = jnp.where(mask, f.lam.astype(dtype), 1e30)
        inv_k = jnp.where(mask, f.inv.astype(dtype), 0.0)
        C = _ops.rotate_vectors(U, z_k, d_k, lam_k, inv_k, m, row_offset)
        # f.defl ⊇ ~mask (inactive entries always deflate), so this also
        # restores the pruned inactive columns — which are the block's own
        # rows of identity columns by invariant.
        return jnp.where(f.defl[None, :], U, C)
    from repro.kernels.eigvec_update.ref import cauchy_factor_ref
    Wn = cauchy_factor_ref(f.z, f.d, f.lam, f.inv,
                           f.defl.astype(f.z.dtype)).astype(dtype)
    return U @ Wn


def _pair_factor(L: Array, z: Array, sigma: Array, m: Array, *, iters: int,
                 method: str, precise: bool) -> _Factor:
    """Sentinelize + solve one update into a Cauchy factor (no U rotation).

    ``rank_one_update``'s pipeline minus the dlaed2 cluster-merge, whose
    block reflector is not a Cauchy factor and so cannot sit between the
    two fused rotations.  Displacement deflation (in ``_solve_factor``)
    still guards every degenerate direction (the paper itself handles
    z_i = 0 by exclusion and has no cluster-merge either); extremely
    clustered spectra lose some of the beyond-paper orthogonality
    polish — use the sequential path when that matters more than HBM
    traffic.
    """
    mask = active_mask(L.shape[0], m)
    room = jnp.abs(sigma) * jnp.sum(z * z)
    d_sent = sentinelize(L, m, room)
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    return _solve_factor(d_sent, z, sigma, m, scale, iters=iters,
                         method=method, precise=precise)


def _factor_tmatvec(f: _Factor, y: Array) -> Array:
    """(Ŵn)ᵀ y in O(M²) from the factor's vectors — never materializes U's
    rotation, which is what lets the second secular solve run before the
    first eigenvector rotation has happened."""
    eps = _eps_for(f.z.dtype)
    den = f.d[:, None] - f.lam[None, :]
    den = jnp.where(jnp.abs(den) < eps, jnp.where(den < 0, -eps, eps), den)
    s = jnp.sum((f.z * y)[:, None] / den, axis=0) * f.inv
    return jnp.where(f.defl, y, s)


class _PairFactors(NamedTuple):
    """Both solved factors of a fused ±sigma pair.

    Factor 1's columns carry the inter-update sort (lam1/inv1/defl1 are
    already permuted; cid1 records the permutation so deflated columns
    become e_{cid1[j]}).  ``L_new`` is the post-update-2 spectrum before
    the final ``perm2`` sort; ``merge_fired`` flags that a dlaed2
    cluster-merge would fire on either update, in which case the fused
    rotation is unsafe and callers should fall back to the sequential
    two-update path.
    """

    z1: Array
    d1: Array
    lam1: Array
    inv1: Array
    defl1: Array
    cid1: Array
    z2: Array
    d2: Array
    lam2: Array
    inv2: Array
    defl2: Array
    cid2: Array
    L_new: Array
    perm2: Array
    merge_fired: Array


def _merge_fires(L: Array, z: Array, sigma: Array, m: Array) -> Array:
    """Would ``rank_one_update``'s dlaed2 cluster-merge rotate z-mass for
    this (spectrum, z, sigma)?  Same sentinelization + tolerance as the
    sequential path, detection only (the reflector is discarded)."""
    M = L.shape[0]
    mask = active_mask(M, m)
    room = jnp.abs(sigma) * jnp.sum(z * z)
    d_sent = sentinelize(L, m, room)
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    tol = 64.0 * _eps_for(L.dtype) * scale
    _, _, fired = _cluster_merge(d_sent, z, tol)
    return fired


def _pair_solve(L: Array, z1: Array, sigma1: Array, z2_raw: Array,
                sigma2: Array, m: Array, *, iters: int, method: str,
                precise: bool) -> _PairFactors:
    """Solve both secular systems of a fused pair — no U rotation.

    ``z2_raw`` is Uᵀv₂ in the *pre-update* basis; the second update's
    z₂ = U₁ᵀv₂ is recovered via the Cauchy transpose-matvec (O(M²)), so
    neither solve ever touches U.  Shared by the local fused path and the
    row-sharded distributed path (where Uᵀv needs one psum and everything
    here runs replicated).
    """
    M = L.shape[0]
    dtype = L.dtype
    f1 = _pair_factor(L, z1, sigma1, m, iters=iters, method=method,
                      precise=precise)
    perm1 = jnp.argsort(f1.L_new)
    L1 = f1.L_new[perm1]

    y = _factor_tmatvec(f1, z2_raw.astype(f1.z.dtype))
    z2 = y[perm1].astype(dtype)
    f2 = _pair_factor(L1, z2, sigma2, m, iters=iters, method=method,
                      precise=precise)
    perm2 = jnp.argsort(f2.L_new)

    fired = _merge_fires(L, z1, sigma1, m) | _merge_fires(L1, z2, sigma2, m)
    # Sentinels sort to themselves, so inactive cid stays the column index.
    cid1 = perm1.astype(jnp.int32)
    cid2 = jnp.arange(M, dtype=jnp.int32)
    return _PairFactors(z1=f1.z, d1=f1.d, lam1=f1.lam[perm1],
                        inv1=f1.inv[perm1], defl1=f1.defl[perm1], cid1=cid1,
                        z2=f2.z, d2=f2.d, lam2=f2.lam, inv2=f2.inv,
                        defl2=f2.defl, cid2=cid2, L_new=f2.L_new,
                        perm2=perm2, merge_fired=fired)


def _pair_rotate_block(U: Array, pf: _PairFactors, m: Array, *,
                       matmul: str, row_offset: Array | None = None
                       ) -> Array:
    """Fused double rotation (U @ W1n @ W2n)[:, perm2] of a row block.

    Like ``_apply_factor``, ``U`` may be a rectangular row block of the
    full eigenvector matrix: the dense route's deflated/inactive columns
    are e_{cid} columns of the factors themselves, so no full-height
    identity is ever needed, and the Pallas kernel takes (R, M) operands
    with ``row_offset`` naming the block's first global row (row-axis
    pruning).  Columns pruned by the kernel (>= the active tile range)
    are restored from ``U`` itself — by invariant those columns of any
    row block are the block's rows of identity columns.
    """
    M = U.shape[-1]
    dtype = U.dtype
    if matmul == "pallas":
        from repro.kernels.eigvec_update import ops as _ops
        C = _ops.rotate_vectors2(
            U,
            pf.z1.astype(dtype), pf.d1.astype(dtype), pf.lam1.astype(dtype),
            pf.inv1.astype(dtype), pf.defl1.astype(dtype), pf.cid1,
            pf.z2.astype(dtype), pf.d2.astype(dtype), pf.lam2.astype(dtype),
            pf.inv2.astype(dtype), pf.defl2.astype(dtype), pf.cid2,
            m, row_offset)
        mask = active_mask(M, m)
        C = jnp.where(mask[None, :], C, U)
    else:
        from repro.kernels.eigvec_update.ref import cauchy_factor_ref
        W1 = cauchy_factor_ref(pf.z1, pf.d1, pf.lam1, pf.inv1,
                               pf.defl1.astype(pf.z1.dtype),
                               pf.cid1).astype(dtype)
        W2 = cauchy_factor_ref(pf.z2, pf.d2, pf.lam2, pf.inv2,
                               pf.defl2.astype(pf.z2.dtype),
                               pf.cid2).astype(dtype)
        C = (U @ W1) @ W2
    return C[:, pf.perm2]


@partial(jax.jit, static_argnames=("iters", "method", "matmul", "precise",
                                   "merge_fallback"))
def rank_one_update_pair(
    L: Array,
    U: Array,
    v1: Array,
    sigma1: Array,
    v2: Array,
    sigma2: Array,
    m: Array,
    *,
    iters: int = 62,
    method: Literal["gu", "bns"] = "gu",
    matmul: Literal["jnp", "pallas"] = "jnp",
    precise: bool = True,
    merge_fallback: bool = True,
    z1: Array | None = None,
    z2: Array | None = None,
) -> tuple[Array, Array]:
    """Two back-to-back rank-one updates with ONE fused double rotation.

    Semantically ``rank_one_update(·, v2, sigma2) ∘ rank_one_update(·, v1,
    sigma1)`` — the ±sigma pairs of Algorithms 1 and 2 — except the U
    rotation happens once: C = U @ W1n @ W2n.  The second update's
    z₂ = U₁ᵀ v₂ is obtained without U₁ via the Cauchy transpose-matvec
    (O(M²)), so U is read and written exactly once per streamed point —
    half the HBM round-trips of two sequential updates.

    The dlaed2 cluster-merge cannot sit between the two fused rotations
    (its block reflector is not a Cauchy factor); with ``merge_fallback``
    (default) a lax.cond re-runs the pair through the sequential two-update
    path whenever a merge would fire on either update, so clustered spectra
    keep the full orthogonality polish.  The solves (O(M²·iters)) always
    run; only the O(M³) rotation is conditional — merges are rare, so the
    fused rotation is what executes in the steady state.

    matmul='jnp' materializes both factors densely (reference semantics,
    still one pass over U); 'pallas' generates both factors' tiles in VMEM
    (``eigvec_rotate2``) with active-tile pruning.

    ``z1``/``z2`` (optional, both or neither) are precomputed Uᵀv₁ / Uᵀv₂
    in the CURRENT basis — the fused ingest kernel emits them with the
    kernel row, eliminating this function's own projection pass over U.
    The merge fallback reuses z1 for its first sequential update (same
    basis) and recomputes z2 from the rotated U1 itself.
    """
    M = L.shape[0]
    mask = active_mask(M, m)
    v1 = jnp.where(mask, v1, 0.0)
    v2 = jnp.where(mask, v2, 0.0)

    if z1 is None:
        Z = U.T @ jnp.stack([v1, v2], axis=1)   # one pass over U for both z
        z1, z2 = Z[:, 0], Z[:, 1]
    else:
        z1 = jnp.where(mask, z1, 0.0)
        z2 = jnp.where(mask, z2, 0.0)
    pf = _pair_solve(L, z1, sigma1, z2, sigma2, m, iters=iters,
                     method=method, precise=precise)

    def _fused(U):
        return pf.L_new[pf.perm2], _pair_rotate_block(U, pf, m,
                                                      matmul=matmul)

    if not merge_fallback:
        return _fused(U)

    def _sequential(U):
        # z1 is valid for the first update (same basis); the second update
        # needs U1ᵀv2, which _update_body recomputes from the rotated U1.
        L1, U1 = _update_body(L, U, v1, sigma1, m, iters=iters,
                              method=method, matmul=matmul, precise=precise,
                              z=z1)
        return _update_body(L1, U1, v2, sigma2, m, iters=iters,
                            method=method, matmul=matmul, precise=precise)

    return jax.lax.cond(pf.merge_fired, _sequential, _fused, U)


def expand_eigensystem_perm(L: Array, lam_new: Array, m: Array
                            ) -> tuple[Array, Array, Array]:
    """Eigenvalue half of ``expand_eigensystem``: the sorted spectrum plus
    the column permutation to apply to U (and to any precomputed Uᵀv — the
    fused ingest path permutes its projections instead of U twice)."""
    m_new = m + 1
    L = L.at[m].set(lam_new)
    L = sentinelize(L, m_new, jnp.zeros((), L.dtype))
    perm = jnp.argsort(L)
    return L[perm], perm, m_new


@partial(jax.jit, static_argnames=())
def expand_eigensystem(L: Array, U: Array, lam_new: Array, m: Array
                       ) -> tuple[Array, Array, Array]:
    """Append eigenpair (lam_new, e_m) and restore ascending order.

    Because inactive columns are identity, appending is just writing L[m];
    a single argsort-permutation of (L, U-columns) then restores order.
    (Paper Alg. 1 line 2 writes k/4 into the U corner — an erratum; the new
    unit eigenvector must be e_{m+1}.)
    """
    L_new, perm, m_new = expand_eigensystem_perm(L, lam_new, m)
    return L_new, U[:, perm], m_new


def reconstruct(L: Array, U: Array, m: Array) -> Array:
    """K̃ = U diag(L) U^T restricted to the active block (testing utility)."""
    M = L.shape[0]
    mask = active_mask(M, m)
    Lm = jnp.where(mask, L, 0.0)
    K = (U * Lm[None, :]) @ U.T
    blk = mask[:, None] & mask[None, :]
    return jnp.where(blk, K, 0.0)
