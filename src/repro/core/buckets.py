"""Back-compat shims for bucketed dispatch — the logic lives in the engine.

This module used to own bucket geometry and the slice→update→scatter
dispatch for m-scaled updates.  That machinery moved to
``repro.core.engine`` (``UpdatePlan`` + ``Engine``), where the KPCA
stream, the Nyström landmark path, the row-sharded distributed drivers
and the serving layer all share it.  The functions below keep the old
kwarg-style entry points alive for existing callers and tests; new code
should construct an ``engine.Engine`` (or pass ``plan=`` to
``KPCAStream``) directly.
"""
from __future__ import annotations

import jax

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf

Array = jax.Array

DEFAULT_MIN_BUCKET = eng.DEFAULT_MIN_BUCKET

# Geometry + slice/scatter are re-exported verbatim from the engine layer.
bucket_sizes = eng.bucket_sizes
bucket_for = eng.bucket_for
slice_state = eng.slice_state
scatter_state = eng.scatter_state


def _plan(method: str, matmul: str, iters: int | None,
          min_bucket: int) -> eng.UpdatePlan:
    return eng.UpdatePlan(method=method, matmul=matmul, iters=iters,
                          dispatch="bucketed", min_bucket=min_bucket)


def rank_one_update(L: Array, U: Array, v: Array, sigma: Array, m: Array,
                    *, min_bucket: int = DEFAULT_MIN_BUCKET,
                    method: str = "gu", matmul: str = "jnp",
                    iters: int | None = None) -> tuple[Array, Array]:
    """``rankone.rank_one_update`` at bucket capacity, scattered back."""
    return eng.rank_one(L, U, v, sigma, m,
                        plan=_plan(method, matmul, iters, min_bucket))


def update(state: inkpca.KPCAState, x_new: Array, spec: kf.KernelSpec, *,
           adjusted: bool = True, method: str = "gu", matmul: str = "jnp",
           iters: int | None = None,
           min_bucket: int = DEFAULT_MIN_BUCKET) -> inkpca.KPCAState:
    """One streaming point through Algorithm 1/2 at bucket capacity."""
    engine = eng.Engine(spec, _plan(method, matmul, iters, min_bucket),
                        adjusted=adjusted)
    return engine.update(state, x_new)


def update_block(state: inkpca.KPCAState, xs: Array, spec: kf.KernelSpec, *,
                 adjusted: bool = True, method: str = "gu",
                 matmul: str = "jnp", iters: int | None = None,
                 min_bucket: int = DEFAULT_MIN_BUCKET) -> inkpca.KPCAState:
    """Stream a block of points: scan within a bucket, re-bucket at
    crossings (see the cost model in engine.py)."""
    engine = eng.Engine(spec, _plan(method, matmul, iters, min_bucket),
                        adjusted=adjusted)
    return engine.update_block(state, xs)


def add_landmark(state, x_all: Array, x_new: Array, spec: kf.KernelSpec, *,
                 method: str = "gu", matmul: str = "jnp", iters: int | None = None,
                 min_bucket: int = DEFAULT_MIN_BUCKET):
    """Bucketed ``nystrom.add_landmark`` via the engine."""
    engine = eng.Engine(spec, _plan(method, matmul, iters, min_bucket),
                        adjusted=False)
    return engine.add_landmark(state, x_all, x_new)
