"""Bucketed dispatch: per-update cost that scales with the active count m.

The fixed-capacity design in ``rankone.py`` / ``inkpca.py`` compiles one
XLA program for the whole stream, but every step then pays the O(M³)
eigenvector rotation and O(M²) secular solve at *capacity* M.  A stream
that grows m from 16 to 1024 inside a capacity-4096 state does ~64× the
paper's ~8m³ flops early on.  This module restores m-dependent cost while
keeping static shapes: updates run at the smallest power-of-two *bucket*
capacity M_b ≥ m+1 drawn from {min_bucket, 2·min_bucket, …, M}.

Capacity-vs-bucket invariants
-----------------------------
The padding convention of ``rankone.py`` makes slicing sound:

* L is ascending with all inactive entries (sentinels) strictly *above*
  the active spectrum, so the m active eigenvalues always occupy
  ``L[:m]`` and ``L[:M_b]`` carries the active spectrum plus the lowest
  M_b − m sentinels — still ascending, still sentinels-on-top.
* Inactive columns of U are exact identity columns, and (U orthogonal)
  the active columns are zero on rows ≥ m.  Hence ``U[:M_b, :M_b]``
  loses nothing and the complement of the bucket is exactly I.
* K1 / X are zero beyond m; S is a scalar.

``slice_state`` therefore maps a capacity-M state with m < M_b active
pairs to a *valid* capacity-M_b state, and ``scatter_state`` writes the
updated bucket back (re-sentinelizing the tail of L so subsequent
fixed-capacity or larger-bucket calls see the full-capacity invariant).

Retrace / bucket-crossing cost model
------------------------------------
Each jitted update specializes on the bucket capacity, so a stream pays
one compilation per bucket it visits — at most log2(M / min_bucket) + 1
of them, ever.  ``update_block`` additionally specializes the scan on the
chunk length; chunks are cut at bucket crossings, so a monotone stream
sees at most two shapes per bucket (the fill-to-crossing chunk and the
full-bucket chunk).  Bucket choice reads ``int(state.m)`` on the host —
one device sync per chunk (per point for ``update``), which the scan
amortizes.  Between crossings the semantics are exactly the fixed
capacity ``lax.scan`` block semantics; across a crossing the state is
re-sliced and the scan resumes, so results match the fixed path to fp
rounding (the arithmetic is identical — padded lanes never mix with
active lanes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import inkpca, kernels_fn as kf, rankone

Array = jax.Array

DEFAULT_MIN_BUCKET = 128


# ------------------------------------------------------- bucket geometry --
def bucket_sizes(capacity: int, min_bucket: int = DEFAULT_MIN_BUCKET
                 ) -> tuple[int, ...]:
    """Power-of-two ladder min_bucket, 2·min_bucket, …, capped at capacity.

    The capacity itself is always the top rung (even when not a power of
    two) so every state the fixed-capacity API accepts is representable.
    """
    if capacity <= 0:
        raise ValueError(f"capacity must be positive, got {capacity}")
    sizes = []
    b = min(min_bucket, capacity)
    while b < capacity:
        sizes.append(b)
        b *= 2
    sizes.append(capacity)
    return tuple(sizes)


def bucket_for(m_needed: int, capacity: int,
               min_bucket: int = DEFAULT_MIN_BUCKET) -> int:
    """Smallest bucket that can hold ``m_needed`` active pairs."""
    if m_needed > capacity:
        raise ValueError(
            f"need room for {m_needed} active pairs but capacity is "
            f"{capacity} — grow the state before streaming more points")
    for b in bucket_sizes(capacity, min_bucket):
        if b >= m_needed:
            return b
    raise AssertionError("unreachable: capacity is always a bucket")


# ------------------------------------------------------- slice / scatter --
def slice_state(state: inkpca.KPCAState, Mb: int) -> inkpca.KPCAState:
    """View the leading M_b×M_b block as a capacity-M_b state (see module
    docstring for why this is lossless while m < M_b)."""
    return inkpca.KPCAState(L=state.L[:Mb], U=state.U[:Mb, :Mb], m=state.m,
                            S=state.S, K1=state.K1[:Mb], X=state.X[:Mb])


def scatter_state(full: inkpca.KPCAState,
                  sub: inkpca.KPCAState) -> inkpca.KPCAState:
    """Write an updated bucket back into the fixed-capacity state."""
    Mb = sub.L.shape[0]
    L = full.L.at[:Mb].set(sub.L)
    # The tail L[Mb:] still holds sentinels for the *pre-update* spectrum;
    # regenerate so the whole array is ascending with sentinels on top.
    L = rankone.sentinelize(L, sub.m, jnp.zeros((), L.dtype))
    U = full.U.at[:Mb, :Mb].set(sub.U)
    K1 = full.K1.at[:Mb].set(sub.K1)
    X = full.X.at[:Mb].set(sub.X)
    return inkpca.KPCAState(L=L, U=U, m=sub.m, S=sub.S, K1=K1, X=X)


# ------------------------------------------------------ bucketed updates --
def rank_one_update(L: Array, U: Array, v: Array, sigma: Array, m: Array,
                    *, min_bucket: int = DEFAULT_MIN_BUCKET,
                    **kwargs) -> tuple[Array, Array]:
    """``rankone.rank_one_update`` at bucket capacity, scattered back."""
    M = L.shape[0]
    Mb = bucket_for(max(int(m), 1), M, min_bucket)
    Lb, Ub = rankone.rank_one_update(L[:Mb], U[:Mb, :Mb], v[:Mb], sigma, m,
                                     **kwargs)
    L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m, jnp.zeros((), L.dtype))
    return L_new, U.at[:Mb, :Mb].set(Ub)


def update(state: inkpca.KPCAState, x_new: Array, spec: kf.KernelSpec, *,
           adjusted: bool = True, method: str = "gu", matmul: str = "jnp",
           iters: int = 62,
           min_bucket: int = DEFAULT_MIN_BUCKET) -> inkpca.KPCAState:
    """One streaming point through Algorithm 1/2 at bucket capacity.

    The kernel row is evaluated against the sliced X as well, so the whole
    step — gram row, secular solve, rotation — is O(M_b²)/O(M_b³).
    """
    M = state.L.shape[0]
    Mb = bucket_for(int(state.m) + 1, M, min_bucket)
    sub = slice_state(state, Mb)
    a, k_new = inkpca._masked_row(sub, x_new, spec)
    fn = inkpca.update_adjusted if adjusted else inkpca.update_unadjusted
    sub = fn(sub, a, k_new, x_new, method=method, matmul=matmul, iters=iters)
    return scatter_state(state, sub)


@partial(jax.jit,
         static_argnames=("spec", "adjusted", "method", "matmul", "iters"))
def _scan_chunk(sub: inkpca.KPCAState, xs: Array, spec: kf.KernelSpec,
                adjusted: bool, method: str, matmul: str,
                iters: int) -> inkpca.KPCAState:
    """Fixed-capacity scan over a chunk that fits inside one bucket."""

    def step(st, x_new):
        a, k_new = inkpca._masked_row(st, x_new, spec)
        fn = inkpca.update_adjusted if adjusted else inkpca.update_unadjusted
        return fn(st, a, k_new, x_new, method=method, matmul=matmul,
                  iters=iters), None

    out, _ = jax.lax.scan(step, sub, xs)
    return out


def update_block(state: inkpca.KPCAState, xs: Array, spec: kf.KernelSpec, *,
                 adjusted: bool = True, method: str = "gu",
                 matmul: str = "jnp", iters: int = 62,
                 min_bucket: int = DEFAULT_MIN_BUCKET) -> inkpca.KPCAState:
    """Stream a block of points: scan within a bucket, re-bucket at
    crossings (see the retrace cost model in the module docstring)."""
    M = state.L.shape[0]
    n = xs.shape[0]
    i = 0
    while i < n:
        m = int(state.m)
        Mb = bucket_for(m + 1, M, min_bucket)
        take = min(Mb - m, n - i)          # steps until the bucket fills
        sub = slice_state(state, Mb)
        sub = _scan_chunk(sub, xs[i:i + take], spec, adjusted, method,
                          matmul, iters)
        state = scatter_state(state, sub)
        i += take
    return state


# ------------------------------------------------------ Nyström landmarks --
def add_landmark(state, x_all: Array, x_new: Array, spec: kf.KernelSpec, *,
                 method: str = "gu", matmul: str = "jnp", iters: int = 62,
                 min_bucket: int = DEFAULT_MIN_BUCKET):
    """Bucketed ``nystrom.add_landmark``: the O(M³) eigensystem update and
    the O(n·M) column write both run at bucket capacity."""
    from repro.core import nystrom

    M = state.kpca.L.shape[0]
    Mb = bucket_for(int(state.kpca.m) + 1, M, min_bucket)
    sub = nystrom.NystromState(kpca=slice_state(state.kpca, Mb),
                               Knm=state.Knm[:, :Mb])
    sub = nystrom.add_landmark(sub, x_all, x_new, spec, method=method,
                               matmul=matmul, iters=iters)
    return nystrom.NystromState(kpca=scatter_state(state.kpca, sub.kpca),
                                Knm=state.Knm.at[:, :Mb].set(sub.Knm))
