"""DEPRECATED — bucketed dispatch lives in ``repro.core.engine`` (use
``UpdatePlan(dispatch="bucketed")`` + ``Engine``); this stub re-exports
the geometry helpers for stragglers and will be deleted in a later PR."""
from repro.core.engine import (  # noqa: F401
    bucket_for, bucket_sizes, scatter_state, slice_state,
    DEFAULT_MIN_BUCKET,
)
