"""Distributed incremental KPCA / Nyström via shard_map (data-parallel rows).

Sharding scheme (designed for the production mesh in ``repro.launch.mesh``):

* U (M×M eigenvectors) and the stored points X are **row-sharded** over the
  'data' axis: each device owns M/P rows (data points).  Eigenvalues L and
  all O(M) bookkeeping are replicated.
* One update needs a single collective: z = psum_p(U_p^T v_p)  (M floats).
  The secular solve (O(M^2) VPU) is replicated — cheaper than communicating.
  The Cauchy factor W is built replicated from (d, roots, ẑ); each device
  rotates only its row block: U_p <- U_p @ W  (local matmul, no comm).
* The Nyström extension row-shards K_{n,m} over 'data' as well; the
  reconstruction B diag(1/λ) B^T is local per row-block.

Per update the communication volume is M floats (one all-reduce) against
O(M^2 / P) local flops — strongly compute-bound for M ≳ P, which is what the
roofline analysis in EXPERIMENTS.md shows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kernels_fn as kf, rankone
from repro.distributed.sharding import shard_map as _shard_map

Array = jax.Array


def _rank_one_update_sharded(L, U_local, v_local, sigma, m, *, axis: str,
                             iters: int, method: str):
    """Body run under shard_map: U_local is a row block of U.

    The solve pipeline (deflation thresholds, flip identity, secular
    bisection) is ``rankone._solve_factor`` — the same one the local and
    fused paths use — run replicated on every device; no cluster-merge
    (its reflector would need a second collective).  Only the row-block
    rotation is local.
    """
    M = L.shape[0]
    dtype = L.dtype
    mask = rankone.active_mask(M, m)

    z = jax.lax.psum(U_local.T @ v_local, axis)
    room = jnp.abs(sigma) * jnp.sum(z * z)
    d_sent = rankone.sentinelize(L, m, room)
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    f = rankone._solve_factor(d_sent, z, sigma, m, scale, iters=iters,
                              method=method, precise=False)

    from repro.kernels.eigvec_update.ref import cauchy_factor_ref
    Wn = cauchy_factor_ref(f.z, f.d, f.lam, f.inv,
                           f.defl.astype(f.z.dtype)).astype(dtype)
    U_new = U_local @ Wn            # local row-block rotation, no comm
    perm = jnp.argsort(f.L_new)     # deflation can locally reorder
    return f.L_new[perm], U_new[:, perm]


def make_sharded_update(mesh, *, axis: str = "data", iters: int = 62,
                        method: str = "gu"):
    """Build a pjit-compatible sharded rank-one update over ``mesh``.

    Returns f(L, U, v, sigma, m) with U sharded P(axis, None); everything
    else replicated.  Composable under jit with other computation.
    """
    body = partial(_rank_one_update_sharded, axis=axis, iters=iters,
                   method=method)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis), P(), P()),
        out_specs=(P(), P(axis, None)),
        check_vma=False,
    )


def make_sharded_expand(mesh, *, axis: str = "data"):
    """Sharded version of expand_eigensystem: permutation applies to columns
    (replicated dimension), so each row block permutes locally."""

    def body(L, U_local, lam_new, m):
        m_new = m + 1
        L = L.at[m].set(lam_new)
        L = rankone.sentinelize(L, m_new, jnp.zeros((), L.dtype))
        perm = jnp.argsort(L)
        return L[perm], U_local[:, perm], m_new

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P()),
        out_specs=(P(), P(axis, None), P()),
        check_vma=False,
    )


def sharded_gram_row(mesh, spec: kf.KernelSpec, *, axis: str = "data"):
    """k(X, x_new) with X row-sharded: embarrassingly parallel."""

    def body(X_local, x_new):
        return kf.kernel_row(x_new, X_local, spec=spec)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                         out_specs=P(axis), check_vma=False)
