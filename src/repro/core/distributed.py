"""Distributed incremental KPCA / Nyström via shard_map (data-parallel rows).

Sharding scheme (designed for the production mesh in ``repro.launch.mesh``):

* U (M×M eigenvectors) and the stored points X are **row-sharded** over the
  'data' axis: each device owns M/P rows (data points).  Eigenvalues L and
  all O(M) bookkeeping are replicated.
* One update needs a single collective: z = psum_p(U_p^T v_p)  (M floats).
  The secular solve (O(M^2) VPU) is replicated — cheaper than communicating.
  The Cauchy factor is built replicated from O(M) vectors; each device
  rotates only its row block: U_p <- U_p @ W  (local matmul, no comm).
* The Nyström extension row-shards K_{n,m} over 'data' as well; the
  reconstruction B diag(1/λ) B^T is local per row-block.

All updates are constructed from an ``engine.UpdatePlan`` — the same
object that drives the local and serving paths — so the sharded body
shares ``rankone``'s factor pipeline verbatim (including the dlaed2
cluster-merge: its Householder reflector acts on U's *columns*, which are
local to every row block).  ``plan.matmul`` selects the rotation backend:
the Pallas kernels take rectangular (M/P, M) row blocks directly, with
each block's ``row_offset`` (= axis_index · M/P) driving row-axis
active-tile pruning, so P > 1 meshes keep the paper's O(m³) per-update
flop count instead of falling back to dense O(M³/P) rotations.  The
fused spellings ('jnp2'/'pallas2') route ±sigma pairs through
``make_sharded_update_pair``.

``plan.dispatch == "bucketed"`` additionally slices every *local* operand
to the active power-of-two bucket before the update — row blocks become
(min(M/P, M_b), M_b) rectangles — so the replicated secular solve runs at
O(M_b²·iters) and the rotation at the bucket size, mirroring the engine's
single-stream bucketed dispatch.  The global (sharded) shapes never
change, so the slicing composes with any mesh; each bucket rung compiles
once (host-side ``int(m)`` read per call, as in ``engine.rank_one``).

Fused-pair merge fallback (``plan.merge_fallback``): the fused rotation
skips the dlaed2 cluster-merge, so clustered spectra need the sequential
two-update path.  Collectives inside a ``lax.cond`` branch would deadlock
a multi-device mesh if any device disagreed on the predicate, so the pair
body is *collective-balanced*: BOTH psums are always issued outside the
conds (the fused steady state pays one redundant O(M) all-reduce), and
the cond branches contain only local compute.  The merge predicate is a
deterministic function of replicated operands, so every device takes the
same branch.

Per update the communication volume is M floats (one all-reduce; two for
a guarded fused pair) against O(M_b²·m/P) local flops — strongly
compute-bound for M ≳ P, which is what the roofline analysis in
EXPERIMENTS.md shows.

Decremental path: ``make_sharded_downdate`` evicts the boundary row
(victim pre-permuted by the host); ``make_sharded_evict`` lifts that
restriction with an IN-GRAPH boundary permutation (one ppermute moving
each device's boundary row + one psum gathering the victim row along
the replicated axis), so the victim index may be traced.
``make_sharded_window_block`` composes evict + ingest into the scanned
steady-state sliding-window engine (m ≡ W, unadjusted system; X and
the arrival ring replicated) — every collective in the step is
unconditional, preserving the deadlock-free discipline above.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import downdate as dd
from repro.core import engine as eng
from repro.core import kernels_fn as kf, rankone
from repro.distributed.sharding import shard_map as _shard_map

Array = jax.Array


def _solve_kwargs(plan: eng.UpdatePlan, dtype) -> dict:
    return dict(iters=eng.resolve_iters(plan.iters, dtype),
                method=plan.method, precise=plan.precise)


def _rank_one_update_sharded(L, U_local, v_local, sigma, m, *,
                             axis: str, plan: eng.UpdatePlan,
                             rows_full: int | None = None):
    """Body run under shard_map: U_local is a row block of U.

    z comes from ONE psum; everything after is ``rankone._update_body`` —
    the exact single-device pipeline (deflation thresholds, dlaed2
    cluster-merge, flip identity, secular bisection) run replicated, with
    only the row-block rotation local.  ``rankone._apply_factor`` routes
    it through the rectangular Pallas kernel with row/column active-tile
    pruning (``row_offset`` = this device's first global row; bucketed
    dispatch passes the pre-slicing local row count as ``rows_full`` so
    the offset stays the global one).
    """
    r0 = jax.lax.axis_index(axis) * (rows_full or U_local.shape[0])
    z = jax.lax.psum(U_local.T @ v_local, axis)
    return rankone._update_body(L, U_local, v_local, sigma, m,
                                matmul=plan.inner_matmul, z=z, row_offset=r0,
                                **_solve_kwargs(plan, L.dtype))


def _rank_one_update_pair_sharded(L, U_local, v1_local, sigma1, v2_local,
                                  sigma2, m, *, axis: str,
                                  plan: eng.UpdatePlan,
                                  rows_full: int | None = None,
                                  Z: Array | None = None):
    """Fused ±sigma pair under shard_map, with a collective-balanced
    merge fallback.

    ONE psum carries both z vectors; z₂ = U₁ᵀv₂ for the fused path comes
    from the Cauchy transpose-matvec (replicated, no collective).  A
    caller that already holds the replicated (M, 2) projections — the
    fused k-row ingest psums them out of its own kernel pass — supplies
    ``Z`` and the psum here is skipped (a trace-time decision, identical
    on every device, so the collective schedule stays deterministic).
    When ``plan.merge_fallback`` is set, a dlaed2 cluster-merge firing on
    either update re-routes the pair through the sequential two-update
    pipeline — and to keep a multi-device mesh deadlock-free the second
    psum is ALWAYS issued (on the post-update-1 row block, which is the
    unchanged U when no merge fired), so both cond branches contain only
    local compute and every device runs an identical collective schedule.
    """
    r0 = jax.lax.axis_index(axis) * (rows_full or U_local.shape[0])
    kw = _solve_kwargs(plan, L.dtype)
    if Z is None:
        Z = jax.lax.psum(
            U_local.T @ jnp.stack([v1_local, v2_local], axis=1), axis)
    pf = rankone._pair_solve(L, Z[:, 0], sigma1, Z[:, 1], sigma2, m, **kw)

    def _fused(U):
        return pf.L_new[pf.perm2], rankone._pair_rotate_block(
            U, pf, m, matmul=plan.inner_matmul, row_offset=r0)

    if not plan.merge_fallback:
        return _fused(U_local)

    def _seq1(U):
        return rankone._update_body(L, U, v1_local, sigma1, m, z=Z[:, 0],
                                    row_offset=r0,
                                    matmul=plan.inner_matmul, **kw)

    def _keep(U):
        return L, U

    # Stage 1 (local compute only): run sequential update 1 iff a merge
    # fires; otherwise pass the row block through untouched.
    L1, U1 = jax.lax.cond(pf.merge_fired, _seq1, _keep, U_local)
    # Collective balance: psum 2 is unconditional.  Merge-free steady
    # state: U1 == U_local, so this recomputes Z[:, 1] redundantly — the
    # O(M) price of a deadlock-free fallback.
    z2 = jax.lax.psum(U1.T @ v2_local, axis)

    def _seq2(U):
        return rankone._update_body(L1, U, v2_local, sigma2, m, z=z2,
                                    row_offset=r0,
                                    matmul=plan.inner_matmul, **kw)

    return jax.lax.cond(pf.merge_fired, _seq2, _fused, U1)


# ------------------------------------------------- bucketed local slicing --
# Soundness of the local bucket slice (L -> L[:Mb], row block ->
# (min(R, Mb), Mb)) mirrors ``engine.slice_state`` plus one sharded
# argument: every global row excluded from some device's slice has index
# >= Mb (devices past the first keep rows whose global index starts at
# R >= min(R, Mb); the first device keeps min(R, Mb) rows), and such rows
# are exact identity rows with their unit entry OUTSIDE the sliced
# columns — they contribute nothing to z and are provably unchanged by
# the update, so slicing loses nothing while m < M_b.


def _bucketed_dispatch(build, plan: eng.UpdatePlan):
    """Shared dispatch shell for every builder in this module.

    ``build(Mb)`` returns the jitted shard_map for one bucket (None =
    full capacity).  Fixed dispatch compiles once; bucketed dispatch
    reads ``int(m)`` — by convention the LAST positional argument of
    every builder's callable, with L first — on the host and caches one
    compilation per bucket rung, exactly as ``engine.rank_one``.
    """
    if plan.dispatch != "bucketed":
        return build(None)

    cache: dict[int, object] = {}

    def dispatch(*args):
        L, m = args[0], args[-1]
        M = L.shape[0]
        # A downdate/evict never grows m and an update's caller passes
        # the pre-update m, so the bucket holds m itself (full-capacity
        # states stay legal; m on a rung doesn't jump to the next one).
        Mb = eng.bucket_for(max(int(m), 1), M, plan.min_bucket)
        key = Mb if Mb < M else -1
        if key not in cache:
            cache[key] = build(None if Mb >= M else Mb)
        return cache[key](*args)

    return dispatch


def make_sharded_update(mesh, *, axis: str = "data",
                        plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Build a pjit-compatible sharded rank-one update over ``mesh``.

    Returns f(L, U, v, sigma, m) with U sharded P(axis, None); everything
    else replicated.  Composable under jit with other computation.  With
    ``plan.dispatch == "bucketed"`` the returned callable reads
    ``int(m)`` on the host and dispatches to a per-bucket compilation
    whose local operands are sliced to the bucket (see module docstring).
    """

    def fixed_body(L, U_local, v_local, sigma, m):
        return _rank_one_update_sharded(L, U_local, v_local, sigma, m,
                                        axis=axis, plan=plan)

    def sliced_body(Mb: int):
        def body(L, U_local, v_local, sigma, m):
            R = U_local.shape[0]
            Rb = min(R, Mb)
            Lb, Ub = _rank_one_update_sharded(
                L[:Mb], U_local[:Rb, :Mb], v_local[:Rb], sigma, m,
                axis=axis, plan=plan, rows_full=R)
            L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m,
                                        jnp.zeros((), L.dtype))
            return L_new, U_local.at[:Rb, :Mb].set(Ub)

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_body(Mb)
        # jit the shard_map so repeated eager calls hit the compile cache
        # (bare shard_map re-traces per call).
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis, None), P(axis), P(), P()),
            out_specs=(P(), P(axis, None)),
            check_vma=False,
        ))

    return _bucketed_dispatch(build, plan)


def make_sharded_update_pair(mesh, *, axis: str = "data",
                             plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Sharded fused ±sigma pair: f(L, U, v1, sigma1, v2, sigma2, m).

    Reads/writes each U row block once in the merge-free steady state and
    issues two psums total (one carrying both z vectors, one balancing
    the fallback — see module docstring).  ``plan.merge_fallback`` re-runs
    clustered-spectrum pairs through the sequential two-update pipeline
    under a cond whose branches are collective-free, closing the PR-2
    clustered-spectrum gap without risking a mesh deadlock.  Bucketed
    dispatch slices local operands exactly as ``make_sharded_update``.
    """

    def fixed_body(L, U_local, v1_local, sigma1, v2_local, sigma2, m):
        return _rank_one_update_pair_sharded(L, U_local, v1_local, sigma1,
                                             v2_local, sigma2, m,
                                             axis=axis, plan=plan)

    def sliced_pair_body(Mb: int):
        def body(L, U_local, v1_local, sigma1, v2_local, sigma2, m):
            R = U_local.shape[0]
            Rb = min(R, Mb)
            Lb, Ub = _rank_one_update_pair_sharded(
                L[:Mb], U_local[:Rb, :Mb], v1_local[:Rb], sigma1,
                v2_local[:Rb], sigma2, m, axis=axis, plan=plan, rows_full=R)
            L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m,
                                        jnp.zeros((), L.dtype))
            return L_new, U_local.at[:Rb, :Mb].set(Ub)

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_pair_body(Mb)
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis, None), P(axis), P(), P(axis), P(), P()),
            out_specs=(P(), P(axis, None)),
            check_vma=False,
        ))

    return _bucketed_dispatch(build, plan)


def _downdate_sharded(L, U_local, a, k_new, m, *, axis: str,
                      plan: eng.UpdatePlan, rows_full: int | None = None):
    """Row-sharded decremental update: evict the boundary point q = m−1.

    The inverse ±sigma pair reuses ``_rank_one_update_pair_sharded``
    verbatim (so it inherits the collective-balanced merge fallback);
    the kernel row ``a`` arrives REPLICATED — it is O(M) and the caller
    typically built it with one ``sharded_gram_row`` psum — and each
    device slices its local rows.  The contraction needs row q of the
    post-pair U, which lives on one shard: ONE extra psum of M floats
    broadcasts it, and the Householder that folds the decoupled
    eigenpair into an exact identity pair acts on U's *columns* — local
    to every row block, like the dlaed2 reflector.  Total per downdate:
    three psums of O(M) floats (two from the guarded pair), against the
    same O(M_b²·m/P) local rotation flops as an update.
    """
    M = L.shape[0]
    dtype = L.dtype
    R = U_local.shape[0]
    q = m - 1
    r0 = jax.lax.axis_index(axis) * (rows_full or R)
    local_idx = jnp.arange(R) + r0

    kn = jnp.maximum(k_new, jnp.finfo(dtype).tiny)
    a = jnp.where(jnp.arange(M) < q, a, 0.0)
    v1 = a.at[q].set(kn / 2.0)
    v2 = a.at[q].set(kn / 4.0)
    sigma = 4.0 / kn
    v1_l = jax.lax.dynamic_slice(v1, (r0,), (R,))
    v2_l = jax.lax.dynamic_slice(v2, (r0,), (R,))
    L, U_local = _rank_one_update_pair_sharded(
        L, U_local, v2_l, sigma, v1_l, -sigma, m, axis=axis, plan=plan,
        rows_full=rows_full)

    # Contraction: ONE psum broadcasts the global row q of the post-pair
    # U; the Householder + column permutation + identity forcing are
    # column-local and shared with the single-device path
    # (``downdate.contract_rows`` — the row block passes its global row
    # indices so the forced identity pair lands on the owner shard).
    eq_local = (local_idx == q).astype(dtype)
    w = jax.lax.psum(U_local.T @ eq_local, axis)        # global row q of U
    w = jnp.where(rankone.active_mask(M, m), w, 0.0)
    return dd.contract_rows(L, U_local, w, m, row_ids=local_idx)


def make_sharded_downdate(mesh, *, axis: str = "data",
                          plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Sharded decremental update: f(L, U, a, k_new, m) -> (L, U, m−1).

    Evicts the ACTIVE BOUNDARY point (row m−1) of the unadjusted system —
    the caller permutes the victim there first (``downdate.boundary_perm``
    is a pure function of (i, m); applying it to row-sharded U is a
    gather along the replicated dimension).  ``a`` is the victim's kernel
    row against the stored points, replicated; with
    ``plan.dispatch == "bucketed"`` local operands are sliced to the
    bucket holding m (a downdate never grows the system), exactly as in
    ``make_sharded_update``.
    """

    def fixed_body(L, U_local, a, k_new, m):
        return _downdate_sharded(L, U_local, a, k_new, m, axis=axis,
                                 plan=plan)

    def sliced_body(Mb: int):
        def body(L, U_local, a, k_new, m):
            R = U_local.shape[0]
            Rb = min(R, Mb)
            Lb, Ub, m_new = _downdate_sharded(
                L[:Mb], U_local[:Rb, :Mb], a[:Mb], k_new, m, axis=axis,
                plan=plan, rows_full=R)
            L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m_new,
                                        jnp.zeros((), L.dtype))
            return L_new, U_local.at[:Rb, :Mb].set(Ub), m_new

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_body(Mb)
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis, None), P(), P(), P()),
            out_specs=(P(), P(axis, None), P()),
            check_vma=False,
        ))

    return _bucketed_dispatch(build, plan)


def _permute_rows_sharded(rows_block, i, m, *, axis: str, nshards: int,
                          rows_full: int | None = None):
    """Row-sharded boundary permutation: move global row ``i`` to the
    active boundary q = m−1, survivors shifting up — entirely in-graph
    (``i`` and ``m`` may be traced scalars, so no host round-trip decides
    the victim).

    The permutation is a cyclic shift confined to rows [i, m−1], so each
    device needs only (a) its own rows, (b) ONE boundary row from the
    next device — a ``ppermute`` of O(M) floats — and (c) global row i
    for whichever device owns row m−1, gathered along the replicated
    axis with one O(M) psum.  Bucketed local slicing is transparent:
    either the slice keeps every per-device row (contiguous global ids)
    or the bucket fits inside device 0's block and every other device
    holds only inactive identity rows the shift never touches.  Both
    collectives are unconditional, keeping the module's
    collective-balanced discipline.
    """
    R = rows_block.shape[0]
    r0 = jax.lax.axis_index(axis) * (rows_full or R)
    gids = jnp.arange(R) + r0
    # (b) next device's first row closes each device's local shift window.
    nbr = jax.lax.ppermute(rows_block[0], axis,
                           perm=[((p + 1) % nshards, p)
                                 for p in range(nshards)])
    shifted = jnp.concatenate([rows_block[1:], nbr[None]], axis=0)
    # (c) global row i, replicated to every device.
    sel = (gids == i).astype(rows_block.dtype)
    row_i = jax.lax.psum(sel @ rows_block, axis)
    keep = (gids < i) | (gids >= m)
    last = gids == (m - 1)
    return jnp.where(keep[:, None], rows_block,
                     jnp.where(last[:, None], row_i[None, :], shifted))


def make_sharded_evict(mesh, *, axis: str = "data",
                       plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Sharded eviction of an ARBITRARY active row:
    f(L, U, a, k_new, i, m) -> (L, U, m−1).

    Closes the boundary-permutation follow-up of ``make_sharded_downdate``
    (which evicts row m−1 only and leaves the victim permutation to the
    host): the survivor-order-preserving permutation runs in-graph via
    ``_permute_rows_sharded``, so ``i`` may be a traced scalar — e.g. the
    FIFO-oldest ``argmin(ages)`` of a sliding window — and the whole
    evict needs no host round-trip.  ``a`` is the victim's kernel row
    against the stored points (replicated, self-entry at position i,
    inactive entries zero); ``k_new`` its diagonal value.  Cost on top of
    the boundary downdate: one O(M) ppermute + one O(M) psum.
    """
    nsh = mesh.shape[axis]

    def fixed_body(L, U_local, a, k_new, i, m):
        U_p = _permute_rows_sharded(U_local, i, m, axis=axis, nshards=nsh)
        order = dd.boundary_perm(i, m, L.shape[0])
        return _downdate_sharded(L, U_p, a[order], k_new, m, axis=axis,
                                 plan=plan)

    def sliced_body(Mb: int):
        def body(L, U_local, a, k_new, i, m):
            R = U_local.shape[0]
            Rb = min(R, Mb)
            U_p = _permute_rows_sharded(U_local[:Rb, :Mb], i, m, axis=axis,
                                        nshards=nsh, rows_full=R)
            order = dd.boundary_perm(i, m, Mb)
            Lb, Ub, m_new = _downdate_sharded(
                L[:Mb], U_p, a[:Mb][order], k_new, m, axis=axis, plan=plan,
                rows_full=R)
            L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m_new,
                                        jnp.zeros((), L.dtype))
            return L_new, U_local.at[:Rb, :Mb].set(Ub), m_new

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_body(Mb)
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis, None), P(), P(), P(), P()),
            out_specs=(P(), P(axis, None), P()),
            check_vma=False,
        ))

    return _bucketed_dispatch(build, plan)


# ------------------------------------------------- sharded window engine --
def _window_step_sharded(L, U_local, X, ages, clock, x_new, m, *,
                         axis: str, spec: kf.KernelSpec,
                         plan: eng.UpdatePlan, nshards: int,
                         rows_full: int | None = None):
    """One steady-state sliding-window step (m ≡ W) of the UNADJUSTED
    sharded eigensystem: evict the FIFO-oldest row, ingest ``x_new``,
    advance the arrival ring — all in-graph.

    U is row-sharded; L, the stored points X, and the O(M) arrival ring
    (``ages``/``clock``) are replicated, matching the module's "O(M)
    bookkeeping is replicated" scheme (X is consumed by replicated kernel
    rows, so sharding it would just add gathers).  The victim is
    ``argmin(ages)`` — a traced read — permuted to the boundary by
    ``_permute_rows_sharded``; the inverse pair + contraction and the
    forward expansion + ±sigma pair reuse the sharded bodies above, so
    the per-step collective schedule is fixed (ppermute + 6 O(M) psums,
    all unconditional) and the step composes under ``lax.scan``.

    With ``plan.health`` quarantine on, a non-finite (or kernel-row
    outlier) arrival is rejected with ZERO state mutation: the step body
    still executes unconditionally on a sanitized stand-in (the stored
    row 0) — ``x_new`` is replicated, so the verdict is identical on
    every shard and the collective schedule above stays fixed (the same
    deadlock-free discipline as the merge fallback) — and a final
    replicated elementwise select discards the result.  The clock then
    does not advance, so the caller recovers the quarantine count as
    ``T − (clock_after − clock_before)``.

    The step is the same ``gate → evict|ingest`` composition that
    ``engine.Engine.step`` assembles for single streams, built from the
    sharded stage helpers below (``_window_gate_sharded``,
    ``_window_evict_sharded``, ``_window_ingest_sharded``) — extraction
    only, op-for-op identical, so the traced collective schedule is
    unchanged.
    """
    policy = getattr(plan, "health", None)
    guard = policy is not None and policy.quarantine
    if guard:
        ok, x_new = _window_gate_sharded(x_new, X, m, spec=spec,
                                         policy=policy)
        L0, U0, X0, ages0, clock0 = L, U_local, X, ages, clock
    L1, U1, X1, ages1, m1 = _window_evict_sharded(
        L, U_local, X, ages, m, axis=axis, spec=spec, plan=plan,
        nshards=nshards, rows_full=rows_full)
    L3, U3, X2, ages2 = _window_ingest_sharded(
        L1, U1, X1, ages1, clock, x_new, m1, axis=axis, spec=spec,
        plan=plan, rows_full=rows_full)
    if guard:
        return (jnp.where(ok, L3, L0), jnp.where(ok, U3, U0),
                jnp.where(ok, X2, X0), jnp.where(ok, ages2, ages0),
                jnp.where(ok, clock + 1, clock0))
    return L3, U3, X2, ages2, clock + 1


def _window_gate_sharded(x_new, X, m, *, spec: kf.KernelSpec, policy):
    """The gate stage of the sharded window step: quarantine verdict plus
    the sanitized stand-in (stored row 0).  ``x_new`` and ``X`` are
    replicated, so the verdict is identical on every shard and no
    collective is issued — downstream stages stay schedule-fixed."""
    M = X.shape[0]
    ok = jnp.all(jnp.isfinite(x_new))
    if policy.outlier_tol > 0.0:
        x_tmp = jnp.where(ok, x_new, X[0].astype(x_new.dtype))
        a_g = kf.kernel_row(x_tmp, X, spec=spec)
        a_g = jnp.where(rankone.active_mask(M, m), a_g, 0.0)
        k_g = kf.gram_block(x_tmp[None], x_tmp[None], spec=spec)[0, 0]
        ok = ok & (jnp.max(jnp.abs(a_g)) >= policy.outlier_tol * k_g)
    return ok, jnp.where(ok, x_new, X[0].astype(x_new.dtype))


def _window_evict_sharded(L, U_local, X, ages, m, *, axis: str,
                          spec: kf.KernelSpec, plan: eng.UpdatePlan,
                          nshards: int, rows_full: int | None = None):
    """The evict stage: permute the FIFO victim (argmin of ages) to the
    boundary, inverse ±sigma pair + contraction — the sharded mirror of
    the downdate half of ``engine._window_pair`` (ppermute + 3 psums,
    unconditional)."""
    M = L.shape[0]
    victim = jnp.argmin(ages).astype(jnp.int32)
    order = dd.boundary_perm(victim, m, M)
    U_p = _permute_rows_sharded(U_local, victim, m, axis=axis,
                                nshards=nshards, rows_full=rows_full)
    X_p = X[order]
    q = m - 1
    a = kf.kernel_row(X_p[q], X_p, spec=spec)
    a = jnp.where(rankone.active_mask(M, m), a, 0.0)
    L1, U1, m1 = _downdate_sharded(L, U_p, a, a[q], m, axis=axis, plan=plan,
                                   rows_full=rows_full)
    idx = jnp.arange(M)
    X1 = jnp.where((idx == q)[:, None], 0.0, X_p)
    # No sentinel write for the freed boundary slot: at m ≡ W the ingest
    # stage stamps the same index m1 with the clock.
    ages1 = ages[order]
    return L1, U1, X1, ages1, m1


def _window_ingest_sharded(L1, U1, X1, ages1, clock, x_new, m1, *,
                           axis: str, spec: kf.KernelSpec,
                           plan: eng.UpdatePlan,
                           rows_full: int | None = None):
    """The ingest stage: expansion + forward ±sigma pair (Algorithm 1) —
    the sharded mirror of the ingest half of ``engine._window_pair``
    (one fused or separate z psum + the pair's collectives)."""
    M = L1.shape[0]
    dtype = L1.dtype
    idx = jnp.arange(M)
    k_new = kf.gram_block(x_new[None], x_new[None], spec=spec)[0, 0]
    kn = jnp.maximum(k_new, jnp.finfo(dtype).tiny)
    sigma = 4.0 / kn
    R = U1.shape[0]
    r0 = jax.lax.axis_index(axis) * (rows_full or R)
    if plan.fuse_krow:
        # Fused prologue, rectangular per-shard: ONE pass over this
        # device's (R, M) row block of U produces its slice of the masked
        # kernel row AND the partial projection Uᵀa; one psum replaces
        # the pair's own z collective (see _rank_one_update_pair_sharded).
        # Shards whose rows lie beyond a bucket slice contribute zero
        # (their global rows are >= m, masked inside the kernel).
        from repro.kernels.rbf_gram import ops as kops

        X_loc = jax.lax.dynamic_slice(
            X1, (r0, jnp.zeros((), r0.dtype)), (R, X1.shape[1]))
        a_loc, Pp = kops.krow_project(U1, X_loc, x_new,
                                      jnp.zeros((R, 0), dtype), m1, r0,
                                      spec=spec)
        p = jax.lax.psum(Pp[:, 0], axis)
        L2, perm, m2 = rankone.expand_eigensystem_perm(L1, kn / 4.0, m1)
        U2 = U1[:, perm]
        # Uᵀe_{m1} = e_{m1} pre-expansion (identity column), so the
        # expanded projections are p with slot m1 overwritten, permuted.
        Z = jnp.stack([p.at[m1].set(kn / 2.0)[perm],
                       p.at[m1].set(kn / 4.0)[perm]], axis=1)
        gids = jnp.arange(R) + r0
        v1_l = jnp.where(gids == m1, kn / 2.0, a_loc)
        v2_l = jnp.where(gids == m1, kn / 4.0, a_loc)
        L3, U3 = _rank_one_update_pair_sharded(
            L2, U2, v1_l, sigma, v2_l, -sigma, m2, axis=axis, plan=plan,
            rows_full=rows_full, Z=Z)
    else:
        a_new = kf.kernel_row(x_new, X1, spec=spec)
        a_new = jnp.where(rankone.active_mask(M, m1), a_new, 0.0)
        # expand_eigensystem only writes L and permutes U columns — both
        # device-local on a row block, so the local helper is reused as-is.
        L2, U2, m2 = rankone.expand_eigensystem(L1, U1, kn / 4.0, m1)
        v1 = a_new.at[m1].set(kn / 2.0)
        v2 = a_new.at[m1].set(kn / 4.0)
        v1_l = jax.lax.dynamic_slice(v1, (r0,), (R,))
        v2_l = jax.lax.dynamic_slice(v2, (r0,), (R,))
        L3, U3 = _rank_one_update_pair_sharded(L2, U2, v1_l, sigma, v2_l,
                                               -sigma, m2, axis=axis,
                                               plan=plan,
                                               rows_full=rows_full)
    X2 = jnp.where((idx == m1)[:, None], x_new[None, :].astype(X1.dtype), X1)
    ages2 = ages1.at[m1].set(clock)
    return L3, U3, X2, ages2


def _rebase_ring_traced(ages, clock, span: int):
    """Traced mirror of ``window.maybe_rebase``, hoisted per block: shift
    the arrival stamps down when ``clock + span`` could reach the
    sentinel (without x64 the ring is int32 and a forever stream would
    otherwise collide with it after ~10⁹ points).  Replicated elementwise
    arithmetic — deterministic on every device, no collective.
    """
    from repro.core import window as wnd

    sent = wnd.age_sentinel(ages.dtype)
    base = clock - ages.shape[0]
    reb = jnp.where(ages == sent, sent, ages - base)
    need = clock >= sent - 1 - span
    return (jnp.where(need, reb, ages),
            jnp.where(need, clock - base, clock))


def make_sharded_window_block(mesh, spec: kf.KernelSpec, *,
                              axis: str = "data",
                              plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Sharded steady-state window engine:
    f(L, U, X, ages, clock, xs, m) -> (L, U, X, ages, clock).

    Folds a (T, d) block into a FULL sliding window (m ≡ W, unadjusted
    system) with ONE dispatch: ``lax.scan`` over ``_window_step_sharded``
    — the distributed mirror of ``engine.Engine.window_block``'s steady
    state.  The FIFO-oldest victim of every step is chosen in-graph from
    the replicated arrival ring, and the sharded boundary permutation
    means no host round-trip anywhere inside the block.  ``m`` is
    invariant (each step nets zero), so one compilation serves the
    steady state forever; with ``plan.dispatch == "bucketed"`` every
    local operand is sliced to the bucket holding W, as in the other
    builders.  The int32 clock-rebase guard runs traced at block entry
    (``_rebase_ring_traced``), mirroring ``Engine.window_block``'s
    hoisted check, so forever streams never collide with the age
    sentinel.  Pass T = 1 blocks for a single fused step.
    """
    nsh = mesh.shape[axis]

    def fixed_body(L, U_local, X, ages, clock, xs, m):
        ages, clock = _rebase_ring_traced(ages, clock, xs.shape[0])

        def step(carry, x_new):
            L, U_local, X, ages, clock = carry
            return _window_step_sharded(
                L, U_local, X, ages, clock, x_new, m, axis=axis, spec=spec,
                plan=plan, nshards=nsh), None

        carry, _ = jax.lax.scan(step, (L, U_local, X, ages, clock), xs)
        return carry

    def sliced_body(Mb: int):
        def body(L, U_local, X, ages, clock, xs, m):
            R = U_local.shape[0]
            Rb = min(R, Mb)
            ages_b, clock = _rebase_ring_traced(ages[:Mb], clock,
                                                xs.shape[0])

            def step(carry, x_new):
                Lb, Ub, Xb, agb, clk = carry
                return _window_step_sharded(
                    Lb, Ub, Xb, agb, clk, x_new, m, axis=axis, spec=spec,
                    plan=plan, nshards=nsh, rows_full=R), None

            carry, _ = jax.lax.scan(
                step, (L[:Mb], U_local[:Rb, :Mb], X[:Mb], ages_b, clock),
                xs)
            Lb, Ub, Xb, agb, clock = carry
            L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m,
                                        jnp.zeros((), L.dtype))
            return (L_new, U_local.at[:Rb, :Mb].set(Ub), X.at[:Mb].set(Xb),
                    ages.at[:Mb].set(agb), clock)

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_body(Mb)
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(axis, None), P(), P(), P(), P(), P()),
            out_specs=(P(), P(axis, None), P(), P(), P()),
            check_vma=False,
        ))

    return _bucketed_dispatch(build, plan)


def make_sharded_window_block_metered(mesh, spec: kf.KernelSpec, *,
                                      axis: str = "data",
                                      plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Metered sharded window engine:
    f(L, U, X, ages, clock, xs, m, mstate) -> (L, U, X, ages, clock, mstate).

    Wraps the UNMODIFIED ``make_sharded_window_block`` executable — same
    shard_map body, same jit cache entry, bitwise-identical eigensystem —
    and accounts the block into a riding ``telemetry.MetricsState`` from
    replicated outputs only: the accepted count is the clock delta (the
    guarded step advances the clock only on acceptance), m is invariant
    at the full window so every accepted fold evicted one point.  The
    note consumes replicated scalars, so the MetricsState stays
    shard-consistent without adding a single collective — the fixed
    ppermute/psum schedule inside the block is untouched.
    """
    from repro.core import telemetry as tm

    inner = make_sharded_window_block(mesh, spec, axis=axis, plan=plan)

    def fn(L, U_local, X, ages, clock, xs, m, mstate):
        out = inner(L, U_local, X, ages, clock, xs, m)
        clock_after = out[4]
        mstate = tm.note_block(mstate, m, m, xs.shape[0],
                               clock_after - clock)
        # m ≡ W on this path by contract: the window is always full.
        mstate = mstate._replace(
            window_fill=jnp.ones((), mstate.window_fill.dtype))
        return out + (mstate,)

    return fn


def make_sharded_expand(mesh, *, axis: str = "data"):
    """Sharded version of expand_eigensystem: permutation applies to columns
    (replicated dimension), so each row block permutes locally."""

    def body(L, U_local, lam_new, m):
        m_new = m + 1
        L = L.at[m].set(lam_new)
        L = rankone.sentinelize(L, m_new, jnp.zeros((), L.dtype))
        perm = jnp.argsort(L)
        return L[perm], U_local[:, perm], m_new

    return jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P()),
        out_specs=(P(), P(axis, None), P()),
        check_vma=False,
    ))


def sharded_gram_row(mesh, spec: kf.KernelSpec, *, axis: str = "data"):
    """k(X, x_new) with X row-sharded: embarrassingly parallel."""

    def body(X_local, x_new):
        return kf.kernel_row(x_new, X_local, spec=spec)

    return jax.jit(_shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                              out_specs=P(axis), check_vma=False))


# ------------------------------------------------ tenant x row 2-D mesh --
# Multi-tenant serving shards the TENANT axis of stacked (B, ...) states
# over a second mesh dimension: a (P_t, P_r) mesh places B/P_t tenants on
# each tenant slice, and within a slice each tenant's U is row-sharded
# over the P_r 'data' devices exactly as in the 1-D builders above.  The
# update body is the SAME collective-balanced `_rank_one_update_pair_-
# sharded`, vmapped over the local tenants: its psums name only the row
# axis, so vmap batches them into one fused all-reduce per tenant slice
# and the tenant axis needs zero collectives — tenants are independent
# eigensystems.  Queries against published snapshots are likewise
# embarrassingly parallel over tenants.


def make_tenant_mesh(p_tenant: int, p_rows: int, *, devices=None):
    """A (tenant, data) 2-D mesh of P_t x P_r devices.

    Row 0 varies the 'data' axis fastest, so the P_r-device row meshes of
    a tenant slice are contiguous device groups — the layout the 1-D
    builders assume when a tenant slice degenerates to P_t = 1.
    """
    import numpy as np

    devs = np.asarray(jax.devices() if devices is None
                      else devices).reshape(-1)
    need = p_tenant * p_rows
    if devs.size < need:
        raise ValueError(f"mesh needs {need} devices, have {devs.size}")
    return jax.sharding.Mesh(devs[:need].reshape(p_tenant, p_rows),
                             ("tenant", "data"))


def make_tenant_update_pair(mesh, *, tenant_axis: str = "tenant",
                            axis: str = "data",
                            plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Fused ±sigma pair over tenant-stacked states on a 2-D mesh:
    f(L, U, v1, sigma1, v2, sigma2, m), every argument stacked on a
    leading tenant axis (L (B, M), U (B, M, M), v* (B, M), sigma* (B,),
    m (B,)).

    The tenant axis shards dim 0 and the row axis dim 1 of U, so each
    device holds a (B/P_t, M/P_r, M) brick; the body vmaps the 1-D
    collective-balanced pair over its local tenants, batching the row
    psums (still zero tenant-axis collectives, preserving the
    deadlock-free discipline).  Bucketed dispatch reads the COHORT
    ceiling max(m) on the host — one bucket rung serves the whole stack,
    mirroring ``StreamBatch``'s "max" cohort policy — and slices every
    local operand to it.
    """

    def _vpair(rows_full=None):
        def f(L, U_loc, v1, s1, v2, s2, m):
            return _rank_one_update_pair_sharded(
                L, U_loc, v1, s1, v2, s2, m, axis=axis, plan=plan,
                rows_full=rows_full)

        return jax.vmap(f)

    def fixed_body(L, U_loc, v1, s1, v2, s2, m):
        return _vpair()(L, U_loc, v1, s1, v2, s2, m)

    def sliced_body(Mb: int):
        def body(L, U_loc, v1, s1, v2, s2, m):
            R = U_loc.shape[1]
            Rb = min(R, Mb)
            Lb, Ub = _vpair(rows_full=R)(
                L[:, :Mb], U_loc[:, :Rb, :Mb], v1[:, :Rb], s1,
                v2[:, :Rb], s2, m)
            L_new = jax.vmap(lambda Lf, Lr, mm: rankone.sentinelize(
                Lf.at[:Mb].set(Lr), mm, jnp.zeros((), L.dtype)))(L, Lb, m)
            return L_new, U_loc.at[:, :Rb, :Mb].set(Ub)

        return body

    def build(Mb: int | None):
        body = fixed_body if Mb is None else sliced_body(Mb)
        return jax.jit(_shard_map(
            body, mesh=mesh,
            in_specs=(P(tenant_axis), P(tenant_axis, axis),
                      P(tenant_axis, axis), P(tenant_axis),
                      P(tenant_axis, axis), P(tenant_axis), P(tenant_axis)),
            out_specs=(P(tenant_axis), P(tenant_axis, axis)),
            check_vma=False,
        ))

    if plan.dispatch != "bucketed":
        return build(None)

    cache: dict[int, object] = {}

    def dispatch(*args):
        L, m = args[0], args[-1]
        M = L.shape[1]
        Mb = eng.bucket_for(max(int(jnp.max(m)), 1), M, plan.min_bucket)
        key = Mb if Mb < M else -1
        if key not in cache:
            cache[key] = build(None if Mb >= M else Mb)
        return cache[key](*args)

    return dispatch


def make_tenant_query(mesh, spec: kf.KernelSpec, *,
                      tenant_axis: str = "tenant", plan=None):
    """Tenant-sharded snapshot queries: f(snaps, xq) -> (B, nq, C) with
    ``snaps`` a tenant-stacked ``serving.ServingSnapshot`` (every leaf
    carrying a leading B axis, e.g. from ``StreamBatch.publish``) and
    xq (B, nq, d).

    Snapshots are immutable and per-tenant independent, so the read path
    is embarrassingly parallel: the tenant axis shards every leaf's
    leading dim, the body vmaps ``serving.query`` over local tenants, and
    there are ZERO collectives — query latency never rides the update
    path's all-reduces, which is the point of decoupled serving.
    """
    from repro.core import serving

    def body(snaps, xq):
        return jax.vmap(
            lambda s, x: serving.query(s, x, spec=spec, plan=plan))(snaps,
                                                                    xq)

    return jax.jit(_shard_map(
        body, mesh=mesh,
        in_specs=(P(tenant_axis), P(tenant_axis)),
        out_specs=P(tenant_axis), check_vma=False))


# ------------------------------------------------ row-rebalancing reshard --
def make_rebalanced_update(mesh, *, axis: str = "data",
                           plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Bucketed sharded update that REBALANCES small buckets across the
    mesh: f(L, U, v, sigma, m), same contract as ``make_sharded_update``.

    With m ≪ M/P the bucketed full-mesh update degenerates: only the
    devices owning global rows < M_b hold active data, yet every device
    still runs the per-bucket body — the ones past the bucket on dead
    masked rows.  Below the crossover P_eff = ceil(M_b / (M/P)) < P this
    builder re-lays the (M_b, M_b) active system out over ALL P devices
    (each getting M_b/P ACTIVE rows) and runs the 1-D sharded update on
    that balanced layout before scattering back into the full-capacity
    sharding.

    The reshard is IN-GRAPH: one jitted shard_map per bucket rung gathers
    the (M_b, M_b) active system with ``jax.lax.all_gather``, hands every
    device a balanced M_b/P slice of its rows, runs the 1-D sharded
    update on that layout, and scatters the result back into the
    full-capacity row sharding — all inside the same traced step, so the
    rebalanced update composes with scanned window blocks (the carried-
    over follow-up this closes).  Collective fan-in stays P (the psums
    still span the full mesh), but the O(M_b · m²) rotation flops now
    balance across all P devices with ZERO dead identity-row work,
    instead of piling onto the ceil(M_b/(M/P)) devices that happen to own
    low rows.  Buckets not divisible by P (and fixed dispatch, and at or
    above the bucket = capacity rung) fall back to
    ``make_sharded_update`` unchanged.
    """
    nP = mesh.shape[axis]
    full_fn = make_sharded_update(mesh, axis=axis, plan=plan)
    if plan.dispatch != "bucketed" or nP == 1:
        return full_fn

    bal_cache: dict[int, object] = {}

    def _balanced(Mb: int, M: int):
        if Mb not in bal_cache:
            R = M // nP                 # local rows, capacity layout
            Rb = Mb // nP               # local rows, balanced bucket layout
            nloc = min(R, Mb)           # local rows overlapping the bucket

            def body(L, U_local, v, sigma, m):
                p = jax.lax.axis_index(axis)
                zero = jnp.zeros((), p.dtype)
                # Gather the bucket: each device contributes its first
                # nloc rows; in device order the first Mb gathered rows
                # are exactly global rows [0, Mb) (devices past the
                # bucket contribute rows that land beyond Mb and are
                # dropped by the slice).
                U_all = jax.lax.all_gather(U_local[:nloc, :Mb], axis,
                                           tiled=True)
                Ubkt = U_all[:Mb]                       # (Mb, Mb) repl
                U_b = jax.lax.dynamic_slice(Ubkt, (p * Rb, zero), (Rb, Mb))
                v_b = jax.lax.dynamic_slice(v, (p * Rb,), (Rb,))
                Lb, U_b = _rank_one_update_sharded(L[:Mb], U_b, v_b, sigma,
                                                   m, axis=axis, plan=plan)
                # Second gather: the updated bucket, replicated, scattered
                # back into this device's capacity-layout rows.
                U_upd = jax.lax.all_gather(U_b, axis, tiled=True)  # (Mb,Mb)
                gids = jnp.arange(R) + p * R
                cand = U_upd[jnp.clip(gids, 0, Mb - 1)]
                newcols = jnp.where((gids < Mb)[:, None], cand,
                                    U_local[:, :Mb])
                L_new = rankone.sentinelize(L.at[:Mb].set(Lb), m,
                                            jnp.zeros((), L.dtype))
                return L_new, U_local.at[:, :Mb].set(newcols)

            bal_cache[Mb] = jax.jit(_shard_map(
                body, mesh=mesh,
                in_specs=(P(), P(axis, None), P(), P(), P()),
                out_specs=(P(), P(axis, None)),
                check_vma=False,
            ))
        return bal_cache[Mb]

    def dispatch(L, U, v, sigma, m):
        M = L.shape[0]
        R = M // nP
        Mb = eng.bucket_for(max(int(m), 1), M, plan.min_bucket)
        P_eff = max(1, -(-Mb // R))              # ceil(Mb / R)
        if P_eff >= nP or Mb % nP != 0:
            return full_fn(L, U, v, sigma, m)
        return _balanced(Mb, M)(L, U, v, sigma, m)

    return dispatch
