"""Distributed incremental KPCA / Nyström via shard_map (data-parallel rows).

Sharding scheme (designed for the production mesh in ``repro.launch.mesh``):

* U (M×M eigenvectors) and the stored points X are **row-sharded** over the
  'data' axis: each device owns M/P rows (data points).  Eigenvalues L and
  all O(M) bookkeeping are replicated.
* One update needs a single collective: z = psum_p(U_p^T v_p)  (M floats).
  The secular solve (O(M^2) VPU) is replicated — cheaper than communicating.
  The Cauchy factor is built replicated from O(M) vectors; each device
  rotates only its row block: U_p <- U_p @ W  (local matmul, no comm).
* The Nyström extension row-shards K_{n,m} over 'data' as well; the
  reconstruction B diag(1/λ) B^T is local per row-block.

All updates are constructed from an ``engine.UpdatePlan`` — the same
object that drives the local and serving paths — so the sharded body
shares ``rankone``'s factor pipeline verbatim: ``plan.matmul`` selects the
rotation backend (the Pallas kernel with active-tile pruning engages
whenever the local row block is square, i.e. P == 1 meshes or per-host
sub-meshes; multi-device row blocks take the dense route), and the fused
spellings ('jnp2'/'pallas2') route ±sigma pairs through
``make_sharded_update_pair`` — ONE psum for both z vectors instead of two
sequential collectives, with the O(M³/P) rotation applied once.

Per update the communication volume is M floats (one all-reduce) against
O(M^2 / P) local flops — strongly compute-bound for M ≳ P, which is what the
roofline analysis in EXPERIMENTS.md shows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import engine as eng
from repro.core import kernels_fn as kf, rankone
from repro.distributed.sharding import shard_map as _shard_map

Array = jax.Array


def _rank_one_update_sharded(L, U_local, v_local, sigma, m, *,
                             axis: str, plan: eng.UpdatePlan):
    """Body run under shard_map: U_local is a row block of U.

    The solve pipeline (deflation thresholds, flip identity, secular
    bisection) is ``rankone._solve_factor`` — the same one the local and
    fused paths use — run replicated on every device; no cluster-merge
    (the fused pair path's fallback would need collectives inside a cond).
    Only the row-block rotation is local; ``rankone._apply_factor`` routes
    it through the Pallas kernel with active-tile pruning when the block
    is square, dense Cauchy factors otherwise.
    """
    M = L.shape[0]
    mask = rankone.active_mask(M, m)

    z = jax.lax.psum(U_local.T @ v_local, axis)
    room = jnp.abs(sigma) * jnp.sum(z * z)
    d_sent = rankone.sentinelize(L, m, room)
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    f = rankone._solve_factor(d_sent, z, sigma, m, scale,
                              iters=eng.resolve_iters(plan.iters, L.dtype),
                              method=plan.method, precise=plan.precise)
    U_new = rankone._apply_factor(U_local, f, mask, m,
                                  matmul=plan.inner_matmul)
    perm = jnp.argsort(f.L_new)     # deflation can locally reorder
    return f.L_new[perm], U_new[:, perm]


def _rank_one_update_pair_sharded(L, U_local, v1_local, sigma1, v2_local,
                                  sigma2, m, *, axis: str,
                                  plan: eng.UpdatePlan):
    """Fused ±sigma pair under shard_map: ONE psum carries both z vectors,
    z₂ = U₁ᵀv₂ comes from the Cauchy transpose-matvec (replicated, no
    second collective), and the local row block is rotated once by both
    factors (``rankone._pair_rotate_block``)."""
    Z = jax.lax.psum(U_local.T @ jnp.stack([v1_local, v2_local], axis=1),
                     axis)
    pf = rankone._pair_solve(L, Z[:, 0], sigma1, Z[:, 1], sigma2, m,
                             iters=eng.resolve_iters(plan.iters, L.dtype),
                             method=plan.method, precise=plan.precise)
    U_new = rankone._pair_rotate_block(U_local, pf, m,
                                       matmul=plan.inner_matmul)
    return pf.L_new[pf.perm2], U_new


def make_sharded_update(mesh, *, axis: str = "data",
                        plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Build a pjit-compatible sharded rank-one update over ``mesh``.

    Returns f(L, U, v, sigma, m) with U sharded P(axis, None); everything
    else replicated.  Composable under jit with other computation.
    """
    body = partial(_rank_one_update_sharded, axis=axis, plan=plan)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis), P(), P()),
        out_specs=(P(), P(axis, None)),
        check_vma=False,
    )


def make_sharded_update_pair(mesh, *, axis: str = "data",
                             plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Sharded fused ±sigma pair: f(L, U, v1, sigma1, v2, sigma2, m).

    Halves the collectives of two sequential sharded updates (one psum for
    both z vectors) and reads/writes each U row block once.  Like the
    local fused path it skips the dlaed2 cluster-merge; unlike the local
    path there is no cond fallback (collectives inside a cond branch would
    deadlock a multi-device mesh), so pathologically clustered spectra
    should use two ``make_sharded_update`` calls instead.
    """
    body = partial(_rank_one_update_pair_sharded, axis=axis, plan=plan)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis), P(), P(axis), P(), P()),
        out_specs=(P(), P(axis, None)),
        check_vma=False,
    )


def make_sharded_expand(mesh, *, axis: str = "data"):
    """Sharded version of expand_eigensystem: permutation applies to columns
    (replicated dimension), so each row block permutes locally."""

    def body(L, U_local, lam_new, m):
        m_new = m + 1
        L = L.at[m].set(lam_new)
        L = rankone.sentinelize(L, m_new, jnp.zeros((), L.dtype))
        perm = jnp.argsort(L)
        return L[perm], U_local[:, perm], m_new

    return _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P()),
        out_specs=(P(), P(axis, None), P()),
        check_vma=False,
    )


def sharded_gram_row(mesh, spec: kf.KernelSpec, *, axis: str = "data"):
    """k(X, x_new) with X row-sharded: embarrassingly parallel."""

    def body(X_local, x_new):
        return kf.kernel_row(x_new, X_local, spec=spec)

    return _shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                         out_specs=P(axis), check_vma=False)
