"""Distributed incremental KPCA / Nyström via shard_map (data-parallel rows).

Sharding scheme (designed for the production mesh in ``repro.launch.mesh``):

* U (M×M eigenvectors) and the stored points X are **row-sharded** over the
  'data' axis: each device owns M/P rows (data points).  Eigenvalues L and
  all O(M) bookkeeping are replicated.
* One update needs a single collective: z = psum_p(U_p^T v_p)  (M floats).
  The secular solve (O(M^2) VPU) is replicated — cheaper than communicating.
  The Cauchy factor W is built replicated from (d, roots, ẑ); each device
  rotates only its row block: U_p <- U_p @ W  (local matmul, no comm).
* The Nyström extension row-shards K_{n,m} over 'data' as well; the
  reconstruction B diag(1/λ) B^T is local per row-block.

Per update the communication volume is M floats (one all-reduce) against
O(M^2 / P) local flops — strongly compute-bound for M ≳ P, which is what the
roofline analysis in EXPERIMENTS.md shows.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kernels_fn as kf, rankone

Array = jax.Array


def _rank_one_update_sharded(L, U_local, v_local, sigma, m, *, axis: str,
                             iters: int, method: str):
    """Body run under shard_map: U_local is a row block of U."""
    M = L.shape[0]
    dtype = L.dtype
    mask = rankone.active_mask(M, m)

    z = jax.lax.psum(U_local.T @ v_local, axis)

    # deflation, mirroring rankone.rank_one_update
    sig_abs = jnp.abs(sigma)
    neg = sigma < 0
    room = sig_abs * jnp.sum(z * z)
    scale = jnp.max(jnp.abs(jnp.where(mask, L, 0.0))) + room + 1e-30
    znorm = jnp.sqrt(jnp.sum(z * z))
    floor = 32.0 * jnp.finfo(dtype).eps * jnp.maximum(znorm,
                                                      jnp.finfo(dtype).eps)
    defl = (~mask | (jnp.abs(z) < floor)
            | (sig_abs * z * z < 64.0 * jnp.finfo(dtype).eps * scale))
    z = jnp.where(defl, 0.0, z)
    d_sent = rankone.sentinelize(L, m, room)
    d_eff = jnp.where(neg, -d_sent[::-1], d_sent)
    z_eff = jnp.where(neg, z[::-1], z)
    defl_eff = jnp.where(neg, defl[::-1], defl)

    roots_eff = rankone._secular_bisect(d_eff, z_eff * z_eff, sig_abs, iters,
                                        defl=defl_eff)
    zhat_eff = (rankone._gu_zhat(d_eff, roots_eff, sig_abs, z_eff)
                if method == "gu" else z_eff)
    zhat_eff = jnp.where(defl_eff, 0.0, zhat_eff)
    W_eff, inv_eff = rankone._cauchy_W(d_eff, roots_eff, zhat_eff)
    eye = jnp.eye(M, dtype=dtype)
    W_eff = jnp.where(defl_eff[None, :], eye, W_eff)
    inv_eff = jnp.where(defl_eff, 1.0, inv_eff)

    roots = jnp.where(neg, -roots_eff[::-1], roots_eff)
    W = jnp.where(neg, W_eff[::-1, ::-1], W_eff)
    inv = jnp.where(neg, inv_eff[::-1], inv_eff)

    blk = mask[:, None] & mask[None, :]
    Wn = jnp.where(blk, W * inv[None, :], eye)

    U_new = U_local @ Wn            # local row-block rotation, no comm
    L_new = jnp.where(mask, roots, d_sent)
    perm = jnp.argsort(L_new)       # deflation can locally reorder
    return L_new[perm], U_new[:, perm]


def make_sharded_update(mesh, *, axis: str = "data", iters: int = 62,
                        method: str = "gu"):
    """Build a pjit-compatible sharded rank-one update over ``mesh``.

    Returns f(L, U, v, sigma, m) with U sharded P(axis, None); everything
    else replicated.  Composable under jit with other computation.
    """
    body = partial(_rank_one_update_sharded, axis=axis, iters=iters,
                   method=method)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis), P(), P()),
        out_specs=(P(), P(axis, None)),
        check_vma=False,
    )


def make_sharded_expand(mesh, *, axis: str = "data"):
    """Sharded version of expand_eigensystem: permutation applies to columns
    (replicated dimension), so each row block permutes locally."""

    def body(L, U_local, lam_new, m):
        m_new = m + 1
        L = L.at[m].set(lam_new)
        L = rankone.sentinelize(L, m_new, jnp.zeros((), L.dtype))
        perm = jnp.argsort(L)
        return L[perm], U_local[:, perm], m_new

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(), P()),
        out_specs=(P(), P(axis, None), P()),
        check_vma=False,
    )


def sharded_gram_row(mesh, spec: kf.KernelSpec, *, axis: str = "data"):
    """k(X, x_new) with X row-sharded: embarrassingly parallel."""

    def body(X_local, x_new):
        return kf.kernel_row(x_new, X_local, spec=spec)

    return jax.shard_map(body, mesh=mesh, in_specs=(P(axis, None), P()),
                         out_specs=P(axis), check_vma=False)
