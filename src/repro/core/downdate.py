"""Decremental updates: remove a point from the maintained eigensystem.

The paper's rank-one machinery is sign-symmetric — Algorithm 1 folds a
point *in* by expanding with the eigenpair (k/4, e_m) and applying the
±sigma pair (v1, +4/k), (v2, −4/k); the exact inverse folds it back *out*
by applying (v2, +4/k), (v1, −4/k) and then *contracting* the decoupled
(k/4, e_q) eigenpair.  Algorithm 2 (mean-adjusted) composes the same way:
the expansion pair inverts first, then the mean-adjustment pair with its
sigmas negated and order swapped.  Streaming KPCA under this kind of
eviction/forgetting is the regime of Ghashami et al. (1512.05059); here
the downdate is *exact* (up to rounding), not a sketch.

Pipeline for ``downdate(state, i)``:

1. **Permute** point i to the active boundary q = m−1 (a cyclic shift
   that preserves the arrival order of the survivors).  K → P K Pᵀ maps
   the eigensystem to (L, P U): a row permutation of U, X and K1 confined
   to the active prefix, so every padding invariant — and therefore the
   Pallas kernels' active-tile pruning — survives untouched.
2. **Inverse pair(s)** via the shared ``engine.apply_pair`` machinery
   (fused double rotation or sequential, per the plan): after them the
   maintained matrix is exactly block-diagonal with row q decoupled.
3. **Contract**: rotate the eigensystem so the decoupled eigenpair
   becomes the exact identity pair (sentinel, e_q), then shrink m.  The
   rotation is a single Householder on U's *columns* built from row q of
   U (O(M²), no extra matmul): in exact arithmetic row q of the active
   columns is already ±e_{j*} and the reflector is the identity; under
   degeneracy (the contracted eigenvalue collides with the spectrum) it
   rotates only inside the near-degenerate eigenspace — the same
   error-versus-gap trade as the dlaed2 cluster merge in ``rankone``.

Cost matches the forward update: O(M_b³) in the rotation at the active
bucket — ``Engine.downdate`` slices to the bucket holding m, and the next
*update* re-buckets downward automatically since bucket choice reads the
(now smaller) active count.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import kernels_fn as kf, rankone

Array = jax.Array


def boundary_perm(i: Array, m: Array, M: int) -> Array:
    """Row order moving index ``i`` to the active boundary q = m−1.

    Survivors keep their relative (arrival) order: the returned ``order``
    satisfies new = old[order] = [0..i−1, i+1..q, i, q+1..M−1].  Inactive
    rows never move.  Pure function of (i, m), so callers maintaining
    side arrays (ages rings, Nyström Knm columns) apply the same order.
    """
    idx = jnp.arange(M)
    key = jnp.where(idx == i, (m - 1).astype(jnp.float64) + 0.5,
                    idx.astype(jnp.float64))
    return jnp.argsort(key)


def permute_to_boundary(state, i: Array):
    """Apply ``boundary_perm`` to the state's row-indexed arrays."""
    order = boundary_perm(i, state.m, state.L.shape[0])
    return state._replace(U=state.U[order, :], K1=state.K1[order],
                          X=state.X[order])


def contract_rows(L: Array, U: Array, w: Array, m: Array, *,
                  row_ids: Array | None = None
                  ) -> tuple[Array, Array, Array]:
    """Contraction core on a ROW BLOCK of the eigenvector matrix.

    Precondition: the maintained matrix is block-diagonal with row
    q = m−1 decoupled (the inverse pair has just run), so exactly one
    active eigenvector carries the e_q direction.  ``w`` is the
    (replicated) global row q of U masked to the active columns — a unit
    vector; a Householder H concentrates that direction into column
    j* = argmax |w|, which then *is* ±e_q by orthogonality.  The column
    is permuted to position q and the identity row/column forced
    exactly, restoring the padding invariants for the shrunk system.
    When w is already ±e_{j*} (the generic case) H ≈ a column flip and
    the contraction is exact; otherwise the rotation mixes only columns
    where w has mass — near-degenerate eigenvalues — erring by at most
    the cluster width, the standard deflation trade.

    The reflector and permutation act on U's COLUMNS, so ``U`` may be
    any row block (the distributed path passes its local (R, M) shard
    with ``row_ids`` naming the block's global rows; None = the full
    square matrix).  The LAPACK sign choice (reflect onto
    −sign(w_{j*})·e_{j*}, ‖u‖² ≈ 4) avoids the catastrophic
    cancellation of the same-sign target (‖u‖² ~ coupling²) — the
    target's sign is irrelevant since the identity pair is forced.
    """
    M = L.shape[0]
    dtype = L.dtype
    q = m - 1
    if row_ids is None:
        row_ids = jnp.arange(U.shape[0])
    j_star = jnp.argmax(jnp.abs(w))
    sgn = jnp.where(w[j_star] < 0, -1.0, 1.0).astype(dtype)
    u = w + sgn * jax.nn.one_hot(j_star, M, dtype=dtype)
    unorm2 = jnp.sum(u * u)
    coef = jnp.where(unorm2 > jnp.finfo(dtype).tiny, 2.0 / unorm2, 0.0)
    U = U - coef * jnp.outer(U @ u, u)           # U @ H, rank-one apply

    # Column j* -> position q; columns between shift left by one.  Keys
    # mirror boundary_perm, on the column axis.
    idx = jnp.arange(M)
    key = jnp.where(idx == j_star, q.astype(jnp.float64) + 0.5,
                    idx.astype(jnp.float64))
    order = jnp.argsort(key)
    U = U[:, order]
    L = L[order]

    # Force the exact identity pair at position q (rounding-level cleanup:
    # by orthogonality the column already is ±e_q and row q of every other
    # active column is ~0).  Both forcings are local to the row block.
    U = U.at[:, q].set((row_ids == q).astype(dtype))
    e_qM = jax.nn.one_hot(q, M, dtype=dtype)
    U = jnp.where((row_ids == q)[:, None], e_qM[None, :], U)
    m_new = m - 1
    L = rankone.sentinelize(L, m_new, jnp.zeros((), dtype))
    return L, U, m_new


def contract_last(L: Array, U: Array, m: Array) -> tuple[Array, Array, Array]:
    """Remove the decoupled boundary eigenpair of the full square system
    and shrink m by one (see ``contract_rows``)."""
    mask = rankone.active_mask(L.shape[0], m)
    w = jnp.where(mask, U[m - 1, :], 0.0)
    return contract_rows(L, U, w, m)


def _boundary_row(state, spec: kf.KernelSpec) -> tuple[Array, Array, Array]:
    """Kernel row of the boundary point against the survivors.

    Returns (a, k_new, sum_a): a is zero at and beyond q = m−1, matching
    exactly the masked row the forward update consumed when this point
    streamed in (same stored X rows, elementwise kernel).
    """
    M = state.L.shape[0]
    q = state.m - 1
    x_ev = state.X[q]
    k_full = kf.kernel_row(x_ev, state.X, spec=spec)
    k_full = jnp.where(rankone.active_mask(M, state.m), k_full, 0.0)
    a = jnp.where(jnp.arange(M) < q, k_full, 0.0)
    return a, k_full[q], jnp.sum(a)


@partial(jax.jit, static_argnames=("spec", "plan"))
def downdate_unadjusted(state, spec: kf.KernelSpec, *,
                        plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Inverse of Algorithm 1 for the boundary point (row m−1)."""
    M = state.L.shape[0]
    q = state.m - 1
    a, k_new, sum_a = _boundary_row(state, spec)
    kn = jnp.maximum(k_new, jnp.finfo(state.L.dtype).tiny)

    v1 = a.at[q].set(kn / 2.0)
    v2 = a.at[q].set(kn / 4.0)
    sigma = 4.0 / kn
    L, U = eng.apply_pair(state.L, state.U, v2, sigma, v1, -sigma, state.m,
                          plan=plan)
    L, U, m_new = contract_last(L, U, state.m)

    K1 = jnp.where(jnp.arange(M) < q, state.K1 - a, 0.0)
    S = state.S - 2.0 * sum_a - k_new
    X = state.X.at[q].set(jnp.zeros_like(state.X[q]))
    return state._replace(L=L, U=U, m=m_new, S=S, K1=K1, X=X)


@partial(jax.jit, static_argnames=("spec", "plan"))
def downdate_adjusted(state, spec: kf.KernelSpec, *,
                      plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Inverse of Algorithm 2 for the boundary point (row m−1).

    Forward order was: mean-adjustment pair at m, expansion, new-row pair
    at m+1.  The inverse runs the new-row pair first (negated sigmas,
    swapped order), contracts the expansion eigenpair, then inverts the
    mean-adjustment pair — whose u vector is rebuilt from the *pre*-add
    bookkeeping (S, K1) recovered from the maintained sums.
    """
    M = state.L.shape[0]
    dtype = state.L.dtype
    q = state.m - 1
    mask_m = rankone.active_mask(M, state.m)
    mf_post = state.m.astype(dtype)

    a, k_new, sum_a = _boundary_row(state, spec)

    # --- Invert step 4: the expansion pair (paper eq. (3)). ---
    k_vec = a.at[q].set(k_new)
    v = k_vec - (jnp.sum(k_vec) + state.K1 - state.S / mf_post) / mf_post
    v = jnp.where(mask_m, v, 0.0)
    v0 = v[q]
    v0 = jnp.where(jnp.abs(v0) < jnp.finfo(dtype).eps,
                   jnp.finfo(dtype).eps, v0)
    v1 = v.at[q].set(v0 / 2.0)
    v2 = v.at[q].set(v0 / 4.0)
    sigma = 4.0 / v0
    L, U = eng.apply_pair(state.L, state.U, v2, sigma, v1, -sigma, state.m,
                          plan=plan)
    L, U, m_new = contract_last(L, U, state.m)

    # --- Invert step 1: the mean-adjustment pair, at m_new actives. ---
    S_pre = state.S - 2.0 * sum_a - k_new
    mask_q = rankone.active_mask(M, m_new)
    K1_pre = jnp.where(mask_q, state.K1 - a, 0.0)
    mf = m_new.astype(dtype)
    C = -S_pre / mf**2 + state.S / (mf + 1.0) ** 2
    u = K1_pre / (mf * (mf + 1.0)) - a / (mf + 1.0) + 0.5 * C
    u = jnp.where(mask_q, u, 0.0)
    ones_u_p = jnp.where(mask_q, 1.0 + u, 0.0)
    ones_u_m = jnp.where(mask_q, 1.0 - u, 0.0)
    half = jnp.asarray(0.5, dtype)
    L, U = eng.apply_pair(L, U, ones_u_m, half, ones_u_p, -half, m_new,
                          plan=plan)

    X = state.X.at[q].set(jnp.zeros_like(state.X[q]))
    return state._replace(L=L, U=U, m=m_new, S=S_pre, K1=K1_pre, X=X)


@partial(jax.jit, static_argnames=("spec", "adjusted", "plan"))
def downdate(state, i: Array, spec: kf.KernelSpec, *, adjusted: bool,
             plan: eng.UpdatePlan = eng.DEFAULT_PLAN):
    """Remove point ``i`` (0 ≤ i < m) from the maintained eigensystem.

    Fully traced (i may be a device scalar), so it vmaps across tenants —
    ``engine.StreamBatch`` uses exactly that for masked batched
    downdates.  Requires m ≥ 2 (the mean-adjusted inverse needs at least
    one survivor); callers enforce this on the host.
    """
    state = permute_to_boundary(state, i)
    fn = downdate_adjusted if adjusted else downdate_unadjusted
    return fn(state, spec, plan=plan)
