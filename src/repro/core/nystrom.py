"""Incremental Nyström approximation (paper §4) — the first incremental
algorithm for the full Nyström approximation to the kernel matrix.

The landmark set grows one point at a time; the eigendecomposition of the
(unadjusted) landmark gram K_{m,m} is maintained by Algorithm 1
(``inkpca.update_unadjusted``), and the Nyström eigenpairs of the full n×n
kernel matrix follow from the Williams–Seeger rescaling (paper eq. 7):

    Λ_nys = (n/m) Λ,        U_nys = sqrt(m/n) K_{n,m} U Λ^{-1}

so that  K̃ = U_nys Λ_nys U_nys^T = K_{n,m} K_{m,m}^{-1} K_{m,n}.

The O(n m^2) reconstruction hot spot  B diag(1/Λ) B^T  (B = K_{n,m} U) is
implemented by the fused Pallas kernel ``repro.kernels.nystrom_recon``.

This enables *empirical* stopping: monitor the chosen norm of K - K̃ (or a
cheap proxy) after each added landmark and stop when it plateaus.

For landmark sets that grow far below capacity, ``repro.core.buckets.
add_landmark`` wraps this module's ``add_landmark`` with bucketed dispatch
so each addition costs O(M_b³) at the active power-of-two bucket M_b
instead of O(M³) at capacity.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import inkpca, kernels_fn as kf, rankone

Array = jax.Array


class NystromState(NamedTuple):
    kpca: inkpca.KPCAState   # eigendecomposition of K_{m,m} (unadjusted)
    Knm: Array               # (n, M) columns k(X_all, x_j) for landmarks j<m


def init_nystrom(x_all: Array, x0: Array, capacity: int, spec: kf.KernelSpec,
                 *, dtype=jnp.float32) -> NystromState:
    kpca = inkpca.init_state(x0, capacity, spec, adjusted=False, dtype=dtype)
    n = x_all.shape[0]
    Knm = jnp.zeros((n, capacity), dtype)
    cols = kf.gram_block(x_all.astype(dtype), x0.astype(dtype), spec=spec)
    Knm = Knm.at[:, : x0.shape[0]].set(cols.astype(dtype))
    return NystromState(kpca=kpca, Knm=Knm)


@partial(jax.jit, static_argnames=("spec", "method", "matmul", "iters"))
def add_landmark(state: NystromState, x_all: Array, x_new: Array,
                 spec: kf.KernelSpec, *, method: str = "gu",
                 matmul: str = "jnp", iters: int = 62) -> NystromState:
    """Grow the landmark set by one point (streaming-compatible)."""
    a, k_new = inkpca._masked_row(state.kpca, x_new, spec)
    m = state.kpca.m
    kpca = inkpca.update_unadjusted(state.kpca, a, k_new, x_new,
                                    method=method, matmul=matmul, iters=iters)
    col = kf.kernel_row(x_new, x_all.astype(state.Knm.dtype), spec=spec)
    zero = jnp.zeros((), m.dtype)
    Knm = jax.lax.dynamic_update_slice(state.Knm, col[:, None].astype(state.Knm.dtype),
                                       (zero, m))
    return NystromState(kpca=kpca, Knm=Knm)


def nystrom_eigpairs(state: NystromState, n: int) -> tuple[Array, Array]:
    """Approximate eigenpairs of the full K via the rescaling (paper eq. 7)."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    mf = st.m.astype(st.L.dtype)
    lam_nys = jnp.where(mask, (n / mf) * st.L, 0.0)
    inv_lam = jnp.where(mask, 1.0 / jnp.where(mask, st.L, 1.0), 0.0)
    U_nys = jnp.sqrt(mf / n) * (state.Knm @ (st.U * inv_lam[None, :]))
    U_nys = jnp.where(mask[None, :], U_nys, 0.0)
    return lam_nys, U_nys


def reconstruct_tilde(state: NystromState, *, use_pallas: bool = False) -> Array:
    """K̃ = K_{n,m} K_{m,m}^{-1} K_{m,n} via the maintained eigenpairs."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    B = state.Knm @ jnp.where(mask[None, :], st.U, 0.0)   # (n, M)
    inv_lam = jnp.where(mask, 1.0 / jnp.where(mask, st.L, 1.0), 0.0)
    if use_pallas:
        from repro.kernels.nystrom_recon import ops as _ops
        return _ops.scaled_gram(B, inv_lam)
    return (B * inv_lam[None, :]) @ B.T


@dataclass
class ErrorNorms:
    fro: float
    spectral: float
    trace: float


def approximation_error(K: Array, K_tilde: Array) -> ErrorNorms:
    """Frobenius / spectral / trace norms of K - K̃ (paper Fig. 2 metrics)."""
    D = K - K_tilde
    fro = jnp.linalg.norm(D)
    ev = jnp.linalg.eigvalsh(D)            # D symmetric
    spectral = jnp.max(jnp.abs(ev))
    trace = jnp.sum(jnp.abs(ev))
    return ErrorNorms(fro=float(fro), spectral=float(spectral),
                      trace=float(trace))
