"""Incremental Nyström approximation (paper §4) — the first incremental
algorithm for the full Nyström approximation to the kernel matrix.

The landmark set grows one point at a time; the eigendecomposition of the
(unadjusted) landmark gram K_{m,m} is maintained by Algorithm 1
(``inkpca.update_unadjusted``), and the Nyström eigenpairs of the full n×n
kernel matrix follow from the Williams–Seeger rescaling (paper eq. 7):

    Λ_nys = (n/m) Λ,        U_nys = sqrt(m/n) K_{n,m} U Λ^{-1}

so that  K̃ = U_nys Λ_nys U_nys^T = K_{n,m} K_{m,m}^{-1} K_{m,n}.

The O(n m^2) reconstruction hot spot  B diag(1/Λ) B^T  (B = K_{n,m} U) is
implemented by the fused Pallas kernel ``repro.kernels.nystrom_recon``.

This enables *empirical* stopping: monitor the chosen norm of K - K̃ (or a
cheap proxy) after each added landmark and stop when it plateaus.

For landmark sets that grow far below capacity, construct an
``engine.Engine`` over this module with
``UpdatePlan(dispatch="bucketed")``: ``Engine.add_landmark`` wraps this
module's ``add_landmark`` with bucketed dispatch so each addition costs
O(M_b³) at the active power-of-two bucket M_b instead of O(M³) at
capacity.  (Landmark streams also ride the composed ``Engine.step``
pipeline via ``offer_landmark``/``add_landmark`` — the stage selection
in ``step`` is orthogonal to which state family the ingest touches.)

Two row regimes:

* **Fixed rows** (default): the full dataset ``x_all`` is known upfront
  and ``Knm`` is allocated dense (n, M).
* **Growing rows** (``init_nystrom(..., grow_rows=True)``): the stream is
  open-ended, so ``Knm`` starts at the seed landmarks' rows and
  ``observe_rows`` appends a row block per observed (non-landmark) point —
  memory tracks the observed stream instead of paying n upfront.  The
  observed points are carried in ``NystromState.Xrows`` so later
  ``add_landmark`` calls can fill the new landmark's column; pass
  ``x_all=None`` in this mode.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf, rankone

Array = jax.Array


class NystromState(NamedTuple):
    kpca: inkpca.KPCAState   # eigendecomposition of K_{m,m} (unadjusted)
    Knm: Array               # (n, M) columns k(X_rows, x_j) for landmarks j<m
    Xrows: Array | None = None   # (n, d) observed row points (grow_rows mode)


def init_nystrom(x_all: Array | None, x0: Array, capacity: int,
                 spec: kf.KernelSpec, *, dtype=jnp.float32,
                 grow_rows: bool = False) -> NystromState:
    kpca = inkpca.init_state(x0, capacity, spec, adjusted=False, dtype=dtype)
    x0 = x0.astype(dtype)
    if grow_rows:
        if x_all is not None:
            raise ValueError("grow_rows=True derives rows from the stream; "
                             "pass x_all=None and call observe_rows")
        x_rows = x0              # landmarks are observed points too
    else:
        if x_all is None:
            raise ValueError("x_all is required unless grow_rows=True")
        x_rows = x_all.astype(dtype)
    n = x_rows.shape[0]
    Knm = jnp.zeros((n, capacity), dtype)
    cols = kf.gram_block(x_rows, x0, spec=spec)
    Knm = Knm.at[:, : x0.shape[0]].set(cols.astype(dtype))
    return NystromState(kpca=kpca, Knm=Knm,
                        Xrows=x_rows if grow_rows else None)


def observe_rows(state: NystromState, xb: Array,
                 spec: kf.KernelSpec, *,
                 plan: eng.UpdatePlan | None = None) -> NystromState:
    """Append a block of observed (non-landmark) points as new Knm rows.

    Only valid in ``grow_rows`` mode.  Row growth is a host-level concat
    (each distinct row count is a new shape), so feed points in batches —
    the kernel block itself is one fused device call.  Under a bucketed
    ``plan.fuse_krow`` the gram is evaluated only against the active
    landmark bucket (columns beyond it are zero by the masking anyway),
    so the call costs O(b·M_b·d) instead of O(b·M·d).

    With ``plan.health`` quarantine enabled, non-finite observed points
    are dropped before any Knm row is built (row growth is host-level
    already, so the filter costs nothing extra): a NaN row would
    otherwise poison every later trace-error contraction.  The caller
    sees the rejection in the returned row count (``Xrows.shape[0]``);
    the serving loop surfaces it as a quarantine counter.
    """
    if state.Xrows is None:
        raise ValueError("observe_rows needs a grow_rows=True state")
    dtype = state.Knm.dtype
    xb = jnp.atleast_2d(xb).astype(dtype)
    policy = getattr(plan, "health", None) if plan is not None else None
    if policy is not None and policy.quarantine:
        import numpy as np
        keep = np.isfinite(np.asarray(xb)).all(axis=1)
        if not keep.all():
            xb = xb[jnp.asarray(keep)]
            if xb.shape[0] == 0:
                return state
    M = state.Knm.shape[1]
    if (plan is not None and plan.fuse_krow
            and plan.dispatch == "bucketed"):
        Mb = eng.bucket_for(max(int(state.kpca.m), 1), M, plan.min_bucket)
    else:
        Mb = M
    mask = rankone.active_mask(Mb, state.kpca.m)
    rows_b = kf.gram_block(xb, state.kpca.X[:Mb], spec=spec).astype(dtype)
    rows_b = jnp.where(mask[None, :], rows_b, 0.0)
    rows = (rows_b if Mb == M
            else jnp.zeros((xb.shape[0], M), dtype).at[:, :Mb].set(rows_b))
    return state._replace(Knm=jnp.concatenate([state.Knm, rows], axis=0),
                          Xrows=jnp.concatenate([state.Xrows, xb], axis=0))


@partial(jax.jit, static_argnames=("spec", "plan"))
def add_landmark(state: NystromState, x_all: Array | None, x_new: Array,
                 spec: kf.KernelSpec, *,
                 plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> NystromState:
    """Grow the landmark set by one point (streaming-compatible).

    In ``grow_rows`` mode the new column is evaluated against the observed
    rows carried in the state (``x_all`` must be None); add the point via
    ``observe_rows`` first if it should also appear as a row.

    ``plan.fuse_krow`` routes the eigensystem growth through the fused
    kernel-row + projection prologue (``engine._ingest``) — the same
    single-pass-over-U ingest the KPCA stream uses.
    """
    m = state.kpca.m
    kpca = eng._ingest(state.kpca, x_new, spec, False, plan)
    x_rows = state.Xrows if state.Xrows is not None else x_all
    col = kf.kernel_row(x_new, x_rows.astype(state.Knm.dtype), spec=spec)
    zero = jnp.zeros((), m.dtype)
    Knm = jax.lax.dynamic_update_slice(state.Knm, col[:, None].astype(state.Knm.dtype),
                                       (zero, m))
    return state._replace(kpca=kpca, Knm=Knm)


@partial(jax.jit, static_argnames=("spec", "plan"))
def remove_landmark(state: NystromState, j: Array, spec: kf.KernelSpec, *,
                    plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> NystromState:
    """Shrink the landmark set by one point — the paper's admission loop
    made reversible.

    The eigensystem of K_{m,m} is downdated by the inverse ±sigma pair
    (``downdate.downdate_unadjusted``, the exact inverse of Algorithm 1);
    the Knm columns follow the same survivor-order-preserving permutation
    the downdate applies to the landmark rows, and the evicted landmark's
    column is zeroed.  Observed rows (``Xrows``/Knm rows) are untouched —
    an ex-landmark remains an observed point.
    """
    from repro.core import downdate as dd

    kpca = dd.permute_to_boundary(state.kpca, j)
    order = dd.boundary_perm(j, state.kpca.m, state.kpca.L.shape[0])
    q = state.kpca.m - 1
    Knm = state.Knm[:, order]
    Knm = Knm.at[:, q].set(jnp.zeros((Knm.shape[0],), Knm.dtype))
    kpca = dd.downdate_unadjusted(kpca, spec, plan=plan)
    return state._replace(kpca=kpca, Knm=Knm)


def replace_landmark(state: NystromState, x_all: Array | None, j: Array,
                     x_new: Array, spec: kf.KernelSpec, *,
                     plan: eng.UpdatePlan = eng.DEFAULT_PLAN
                     ) -> NystromState:
    """Swap landmark ``j`` for ``x_new``: remove + add.

    O(m³) eigensystem work plus ONE new Knm column (O(n) kernel evals)
    versus the O(n·m·d) gram rebuild + eigh of a from-scratch recompute —
    see ``benchmarks/bench_window.py`` for the measured gap.  Use
    ``engine.Engine.replace_landmark`` for the bucketed spelling.
    """
    state = remove_landmark(state, jnp.asarray(j, jnp.int32), spec,
                            plan=plan)
    return add_landmark(state, x_all, x_new, spec, plan=plan)


# ------------------------------------------------- landmark admission ----
def leverage_scores(state: NystromState, reg: float = 1e-6) -> Array:
    """Ridge leverage score of each landmark under the maintained
    eigendecomposition: l_j = Σ_k U[j,k]² λ_k/(λ_k + reg·tr/m).

    The regularizer is scaled by the mean active eigenvalue so ``reg``
    is dimensionless.  Low-leverage landmarks are the redundant ones —
    the replacement victims of the "leverage" admission policy
    (leverage-style subset quality scoring follows Sterge &
    Sriperumbudur, 2105.08875).
    """
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    lam = jnp.where(mask, st.L, 0.0)
    lam_bar = jnp.sum(lam) / jnp.maximum(st.m.astype(st.L.dtype), 1.0)
    lam_reg = jnp.maximum(reg * lam_bar, jnp.finfo(st.L.dtype).tiny)
    w = jnp.where(mask, lam / (lam + lam_reg), 0.0)
    scores = jnp.sum(st.U**2 * w[None, :], axis=1)
    return jnp.where(mask, scores, 0.0)


def admission_residual(state: NystromState, x: Array,
                       spec: kf.KernelSpec) -> Array:
    """Projection residual of a candidate landmark onto the current
    landmark span: δ(x) = k(x,x) − b(x)ᵀ K_{m,m}⁺ b(x) ≥ 0.

    This is the Schur complement of the candidate against the landmark
    gram — exactly the marginal the incremental Nyström approximation
    gains by admitting x (δ = 0 means x is already spanned).  O(m²) per
    candidate from the maintained eigenpairs; no n×n object is formed.
    """
    st = state.kpca
    mask = rankone.active_mask(st.L.shape[0], st.m)
    b, k_xx = eng.masked_row(st, x, spec)
    y = st.U.T @ b
    return k_xx - jnp.sum(_pinv_lam(st.L, mask) * y * y)


def _rows_are_landmarks(state: NystromState, spec: kf.KernelSpec) -> bool:
    """Do the stored landmark points coincide with the observed rows, in
    order?  Verified by rebuilding the maintained K_{n,m} columns from
    the stored points and comparing — O(n·m·d), the cost of one Knm
    column rebuild, and the only evidence available once ``x_all`` is
    gone.  A count match alone is NOT enough: ``add_landmark`` accepts
    points from outside the observed rows.
    """
    st = state.kpca
    n = state.Knm.shape[0]
    m = int(st.m)
    G = kf.gram_block(st.X[:n].astype(st.L.dtype), st.X[:m],
                      spec=spec).astype(state.Knm.dtype)
    scale = float(jnp.max(jnp.abs(G))) + 1e-30
    err = float(jnp.max(jnp.abs(state.Knm[:, :m] - G)))
    return err <= 1e-5 * scale


def trace_error(state: NystromState, spec: kf.KernelSpec,
                x_all: Array | None = None) -> Array:
    """Trace-norm of K − K̃ over the observed rows, incrementally.

    For Nyström, K − K̃ is PSD, so the trace norm is the exact trace gap
    Σ_i (k(x_i,x_i) − K̃_ii) — computable in O(n·m) from the maintained
    eigenpairs without ever forming the n×n difference the offline
    ``approximation_error`` needs.  This is the quantity whose plateau
    the sufficient-subset stopping rule watches (the paper's headline
    "empirical evaluation of when a subset of sufficient size has been
    obtained", made online).
    """
    st = state.kpca
    x_rows = state.Xrows if state.Xrows is not None else x_all
    n = state.Knm.shape[0]
    if x_rows is not None:
        diag_k = kf.kernel_diag(x_rows.astype(st.L.dtype), spec=spec)
    elif kf.constant_diag(spec) is not None:
        # Stationary kernels have an input-independent diagonal — the row
        # points only ever feed Σ_i k(x_i, x_i), so nothing is lost.
        diag_k = jnp.full((n,), kf.constant_diag(spec), st.L.dtype)
    elif n == int(st.m) and _rows_are_landmarks(state, spec):
        # The stored landmark points cover the observed stream (verified
        # against the maintained Knm, not just the row count — landmarks
        # admitted from OUTSIDE the observed rows must keep raising).
        diag_k = kf.kernel_diag(st.X[:n].astype(st.L.dtype), spec=spec)
    else:
        raise ValueError(
            "trace_error is underdetermined: fixed-row state without "
            "x_all, a non-constant-diagonal kernel, and observed rows "
            "not covered by the stored landmarks — pass x_all")
    mask = rankone.active_mask(st.L.shape[0], st.m)
    B = state.Knm @ jnp.where(mask[None, :], st.U, 0.0)
    diag_tilde = jnp.sum(B**2 * _pinv_lam(st.L, mask)[None, :], axis=1)
    return jnp.sum(diag_k - diag_tilde)


def admission_trace_delta(state: NystromState, x: Array,
                          spec: kf.KernelSpec,
                          x_all: Array | None = None
                          ) -> tuple[Array, Array]:
    """Exact decrease of ``trace_error`` from admitting ``x`` as a
    landmark — O(n·m), against the O(n·m²) full recompute.

    Admitting x borders the landmark gram with (b, k_xx) and appends the
    column c = k(X_rows, x); by the block-inverse (Schur complement)
    identity the Nyström reconstruction gains exactly one PSD rank-one
    term:

        K̃' = K̃ + r rᵀ / δ,      r = K_nm K_mm⁺ b − c,

    with δ = k_xx − bᵀ K_mm⁺ b the admission residual.  The trace gap
    therefore drops by exactly Σ_i r_i² / δ.  Returns ``(delta,
    residual)``; delta is clamped to 0 when δ is numerically zero (the
    candidate is already spanned, nothing to gain).
    """
    st = state.kpca
    x = jnp.asarray(x)
    x_rows = state.Xrows if state.Xrows is not None else x_all
    if x_rows is None:
        raise ValueError("admission_trace_delta needs the observed rows "
                         "(grow_rows state or x_all)")
    mask = rankone.active_mask(st.L.shape[0], st.m)
    b, k_xx = eng.masked_row(st, x, spec)
    y = st.U.T @ b
    alpha = _pinv_lam(st.L, mask) * y          # K_mm⁺ b in the eigenbasis
    delta_res = k_xx - jnp.sum(y * alpha)
    c = kf.kernel_row(x, x_rows.astype(st.L.dtype), spec=spec)
    r = state.Knm @ (st.U @ alpha) - c
    tol = jnp.finfo(st.L.dtype).eps * jnp.maximum(k_xx, 1.0)
    delta = jnp.where(delta_res > tol,
                      jnp.sum(r * r) / jnp.maximum(delta_res, tol), 0.0)
    return delta, delta_res


@jax.jit
def removal_trace_delta(state: NystromState, j: Array
                        ) -> tuple[Array, Array]:
    """Exact increase of ``trace_error`` from removing landmark ``j`` —
    O(n·m) from the maintained eigenpairs.

    Deleting row/column j from the landmark gram is the reverse bordering
    of ``admission_trace_delta``: with W = K_mm⁺ = U diag(λ⁺) Uᵀ and
    w = W e_j, the block-inverse identity gives

        K̃_minus = K_nm (W − w wᵀ / W_jj) K_nm^T,

    (the deflated matrix has zero j-th row/column, so the dropped Knm
    column is inert) and the trace gap grows by exactly
    Σ_i (K_nm w)_i² / W_jj.  Returns ``(inc, W_jj)``; W_jj ≤ 0 (victim
    support entirely in deflated directions) means the leave-one-out
    inverse does not exist — callers should fall back to an exact resync.
    """
    st = state.kpca
    mask = rankone.active_mask(st.L.shape[0], st.m)
    pinv = _pinv_lam(st.L, mask)
    uj = st.U[j, :]
    w = st.U @ (pinv * uj)
    Wjj = jnp.sum(uj * uj * pinv)
    t = state.Knm @ w
    safe = jnp.maximum(Wjj, jnp.finfo(st.L.dtype).tiny)
    return jnp.sum(t * t) / safe, Wjj


@partial(jax.jit, static_argnames=("spec",))
def swap_trace_delta(state: NystromState, j: Array, x: Array,
                     spec: kf.KernelSpec, x_all: Array | None = None
                     ) -> tuple[Array, Array]:
    """Exact net change of ``trace_error`` from replacing landmark ``j``
    with ``x`` — O(n·m), no leave-one-out eigensystem ever formed.

    Composes the two block-inverse identities from the PRE-swap state:
    removal adds Σ(K_nm w)²/W_jj (``removal_trace_delta``), then the
    admission against the DEFLATED inverse A = W − w wᵀ/W_jj subtracts
    Σ r²/δ' with b̃ the candidate's kernel row zeroed at the victim slot,
    δ' = k_xx − b̃ᵀAb̃ and r = K_nm A b̃ − c.  Returns ``(net, W_jj)``
    (net = inc − dec, to be ADDED to the tracked value); W_jj ≤ 0 or a
    non-finite net means fall back to resync.
    """
    st = state.kpca
    x = jnp.asarray(x)
    x_rows = state.Xrows if state.Xrows is not None else x_all
    if x_rows is None:
        raise ValueError("swap_trace_delta needs the observed rows "
                         "(grow_rows state or x_all)")
    dtype = st.L.dtype
    mask = rankone.active_mask(st.L.shape[0], st.m)
    pinv = _pinv_lam(st.L, mask)
    tiny = jnp.finfo(dtype).tiny

    uj = st.U[j, :]
    w = st.U @ (pinv * uj)                     # W e_j
    Wjj = jnp.maximum(jnp.sum(uj * uj * pinv), tiny)
    t = state.Knm @ w
    inc = jnp.sum(t * t) / Wjj

    b, k_xx = eng.masked_row(st, x, spec)
    bt = b.at[j].set(0.0)                      # row vs SURVIVING landmarks
    Wb = st.U @ (pinv * (st.U.T @ bt))
    Ab = Wb - w * (jnp.dot(w, bt) / Wjj)       # A b̃, A = W − w wᵀ/W_jj
    delta_res = k_xx - jnp.dot(bt, Ab)
    c = kf.kernel_row(x, x_rows.astype(dtype), spec=spec)
    r = state.Knm @ Ab - c
    tol = jnp.finfo(dtype).eps * jnp.maximum(k_xx, 1.0)
    dec = jnp.where(delta_res > tol,
                    jnp.sum(r * r) / jnp.maximum(delta_res, tol), 0.0)
    return inc - dec, jnp.sum(uj * uj * pinv)


class TraceErrorTracker:
    """Maintains the sufficient-subset error metric incrementally across
    the landmark lifecycle (ROADMAP PR-4 follow-up).

    The stopping rule watches ``trace_error`` after every admission, and
    recomputing it exactly costs O(n·m²) (the ``Knm @ U`` contraction) —
    the dominant per-offer cost of the leverage policy once n is large.
    This tracker keeps the value current from O(n·m) increments instead:

    * ``observe(state, x)`` — a newly observed row adds its own
      projection residual δ(x) to the trace gap (O(m²); call once per
      ``observe_rows`` point, before or after — the residual only reads
      the landmark eigensystem).
    * ``admitted(state_before, x)`` — subtract
      ``admission_trace_delta(state_before, x)``; ``state_before`` is
      the state the candidate was offered to (rows already observed).
    * ``replaced(state_after, state_before=..., x=...)`` — apply the
      O(n·m) ``swap_trace_delta`` computed from the pre-swap state: the
      leave-one-out inverse comes from the maintained eigenpairs via the
      block-inverse identity, so a swap no longer forces the O(n·m²)
      exact resync.  The victim index defaults to the lowest-leverage
      landmark (the ``consider_landmark`` choice); pass ``j=`` to
      override.  Degenerate victims (W_jj ≤ 0) or a non-finite delta
      fall back to the exact resync, as does calling with only
      ``state_after`` (the legacy spelling).
    * every ``resync_every`` admissions/swaps the value re-anchors to
      the exact recompute, bounding float drift on unbounded lifecycles
      (the drift itself is regression-tested against the recompute).
    """

    def __init__(self, state: NystromState, spec: kf.KernelSpec, *,
                 x_all: Array | None = None, resync_every: int = 64):
        self.spec = spec
        self.x_all = x_all
        self.resync_every = int(resync_every)
        self.value = float(trace_error(state, spec, x_all))
        self._admits = 0
        self._pending_resync = False

    def resync(self, state: NystromState) -> float:
        self.value = float(trace_error(state, self.spec, self.x_all))
        self._admits = 0
        self._pending_resync = False
        return self.value

    def observe(self, state: NystromState, x: Array,
                residual: float | None = None) -> float:
        """Pass ``residual`` when the caller already computed
        ``admission_residual`` for this point (the serving loop offers
        the same point next — one dispatch instead of two)."""
        if residual is None:
            residual = float(admission_residual(state, jnp.asarray(x),
                                                self.spec))
        self.value += max(float(residual), 0.0)
        return self.value

    def admitted(self, state_before: NystromState, x: Array) -> float:
        delta, _ = admission_trace_delta(state_before, x, self.spec,
                                         self.x_all)
        self.value = max(self.value - float(delta), 0.0)
        self._count_increment()
        return self.value

    def replaced(self, state_after: NystromState, *,
                 state_before: NystromState | None = None,
                 x: Array | None = None, j: int | None = None) -> float:
        import math

        import numpy as np

        if state_before is None or x is None:
            return self.resync(state_after)       # legacy exact spelling
        if j is None:
            m = int(state_before.kpca.m)
            j = int(np.argmin(np.asarray(
                leverage_scores(state_before)[:m])))
        net, Wjj = swap_trace_delta(state_before,
                                    jnp.asarray(j, jnp.int32),
                                    jnp.asarray(x), self.spec, self.x_all)
        net, Wjj = float(net), float(Wjj)
        if not math.isfinite(net) or Wjj <= 0.0:
            return self.resync(state_after)
        self.value = max(self.value + net, 0.0)
        self._count_increment()
        return self.value

    def _count_increment(self) -> None:
        self._admits += 1
        if self.resync_every and self._admits >= self.resync_every:
            # Re-anchoring needs the POST-event state; callers hand us the
            # pre-state, so defer to the next lifecycle event instead of
            # recomputing on a stale snapshot.
            self._admits = 0
            self._pending_resync = True

    def maybe_resync(self, state: NystromState) -> float:
        """Honor a pending periodic re-anchor (call with the CURRENT
        state after the lifecycle event that tripped it)."""
        if self._pending_resync:
            return self.resync(state)
        return self.value


class SufficientSubsetRule:
    """Online stopping rule for landmark admission (paper §4 made online).

    Feed the error trend (``trace_error`` after each admitted landmark);
    the subset is declared sufficient once the *relative* improvement has
    stayed below ``rel_tol`` for ``patience`` consecutive admissions —
    the plateau of the paper's Fig. 2 curves, detected without a
    reference spectrum.
    """

    def __init__(self, rel_tol: float = 1e-2, patience: int = 3):
        self.rel_tol = float(rel_tol)
        self.patience = int(patience)
        self.history: list[float] = []
        self._flat = 0

    @property
    def sufficient(self) -> bool:
        return self._flat >= self.patience

    def observe(self, err) -> bool:
        """Record one error value; returns True once sufficient."""
        err = float(err)
        if self.history:
            prev = self.history[-1]
            rel = (prev - err) / max(abs(prev), 1e-30)
            self._flat = self._flat + 1 if rel < self.rel_tol else 0
        self.history.append(err)
        return self.sufficient


def consider_landmark(engine, state: NystromState, x: Array, *,
                      x_all: Array | None = None,
                      budget: int | None = None,
                      admit_tol: float = 1e-3,
                      reg: float = 1e-6,
                      min_rows: int = 0,
                      residual: float | None = None
                      ) -> tuple[NystromState, str]:
    """Leverage-policy admission of one candidate landmark.

    Decision ladder (returns the new state and what happened):

    * residual δ(x) ≤ admit_tol · k(x,x): already spanned — "rejected".
    * below ``budget`` landmarks: "admitted" (bucketed add).
    * at budget: find the lowest-leverage landmark; if its leverage is
      below the candidate's normalized residual, swap — "replaced";
      otherwise "rejected".

    ``engine`` is an ``engine.Engine`` (adjusted=False) so every path
    runs at bucket capacity; drive it from a ``SufficientSubsetRule`` to
    stop offering candidates altogether.  ``residual`` short-circuits
    the O(m²) ``admission_residual`` dispatch when the caller already
    has it (e.g. a ``TraceErrorTracker.observe`` on the same point).
    """
    import numpy as np

    M = state.kpca.L.shape[0]
    m = int(state.kpca.m)
    budget = budget if budget is not None else M - 1
    delta = (float(residual) if residual is not None
             else float(admission_residual(state, jnp.asarray(x),
                                           engine.spec)))
    k_xx = float(kf.kernel_diag(jnp.asarray(x)[None].astype(state.kpca.L.dtype),
                                spec=engine.spec)[0])
    gain = delta / max(k_xx, 1e-30)
    if gain <= admit_tol:
        return state, "rejected"
    if m < budget:
        return engine.add_landmark(state, x_all, x, min_rows=min_rows), \
            "admitted"
    lev = np.asarray(leverage_scores(state, reg=reg)[:m])
    victim = int(np.argmin(lev))
    if float(lev[victim]) < gain:
        return engine.replace_landmark(state, x_all, victim, x,
                                       min_rows=min_rows), "replaced"
    return state, "rejected"


def _pinv_lam(L: Array, mask: Array) -> Array:
    """Pseudo-inverse of the active spectrum: exact/near-zero eigenvalues
    (a compacted rank-truncated state carries rank-deficient active pairs)
    deflate to 0 instead of amplifying to 1/0."""
    tol = (L.shape[0] * jnp.finfo(L.dtype).eps
           * jnp.max(jnp.where(mask, jnp.abs(L), 0.0)))
    ok = mask & (jnp.abs(L) > tol)
    return jnp.where(ok, 1.0 / jnp.where(ok, L, 1.0), 0.0)


def nystrom_eigpairs(state: NystromState, n: int) -> tuple[Array, Array]:
    """Approximate eigenpairs of the full K via the rescaling (paper eq. 7)."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    mf = st.m.astype(st.L.dtype)
    lam_nys = jnp.where(mask, (n / mf) * st.L, 0.0)
    U_nys = jnp.sqrt(mf / n) * (state.Knm @ (st.U * _pinv_lam(st.L, mask)[None, :]))
    U_nys = jnp.where(mask[None, :], U_nys, 0.0)
    return lam_nys, U_nys


def query_features(state: NystromState, xq: Array, n: int,
                   spec: kf.KernelSpec, *,
                   plan: eng.UpdatePlan | None = None) -> Array:
    """Nyström eigenvector rows for OUT-OF-SAMPLE query points:
    sqrt(m/n) · k(x_q, X_lm) U Λ⁺ — the ``nystrom_eigpairs`` rescaling
    (paper eq. 7) evaluated at new points, e.g. to extend K̃ to a query
    batch via ``U_q Λ_nys U_nysᵀ``.

    Under ``plan.fuse_krow`` the query gram never materializes: the fused
    ``nystrom_recon.transform_project`` kernel (shared with the KPCA
    batched transform) contracts each kernel tile against
    S = U diag(λ⁺) in VMEM.
    """
    st = state.kpca
    mask = rankone.active_mask(st.L.shape[0], st.m)
    mf = st.m.astype(st.L.dtype)
    s_mat = (st.U * _pinv_lam(st.L, mask)[None, :]).astype(st.X.dtype)
    if plan is not None and plan.fuse_krow:
        from repro.kernels.nystrom_recon import ops as nops
        y, _ = nops.transform_project(jnp.asarray(xq), st.X, s_mat, st.m,
                                      spec=spec)
    else:
        kq = kf.gram_block(jnp.asarray(xq).astype(st.X.dtype), st.X,
                           spec=spec)
        kq = jnp.where(mask[None, :], kq, 0.0)
        y = kq @ s_mat
    return jnp.sqrt(mf / n) * jnp.where(mask[None, :], y, 0.0)


def publish_features(state: NystromState, n: int, *,
                     generation: int | Array = 0):
    """Freeze the out-of-sample feature head (``query_features``) into a
    ``serving.ServingSnapshot``: S = sqrt(m/n)·U·lam⁺ precomputed at
    publication, so serving-time Nyström features are plain snapshot
    queries against the frozen landmark set — immutable under concurrent
    landmark lifecycle updates to the working state."""
    from repro.core import serving

    st = state.kpca
    mask = rankone.active_mask(st.L.shape[0], st.m)
    mf = st.m.astype(st.L.dtype)
    s_mat = (jnp.sqrt(mf / n)
             * (st.U * _pinv_lam(st.L, mask)[None, :])).astype(st.X.dtype)
    return serving.ServingSnapshot(
        S=s_mat, X=st.X, m=st.m, affine=None,
        generation=jnp.asarray(generation, jnp.int32))


def snapshot_features(snap, xq: Array, spec: kf.KernelSpec, *,
                      plan: eng.UpdatePlan | None = None) -> Array:
    """Nyström eigenvector rows at query points for a published snapshot
    ((nq, d) -> (nq, M); columns >= m are zero)."""
    from repro.core import serving

    return serving.query(snap, xq, spec=spec, plan=plan)


def reconstruct_tilde(state: NystromState, *, use_pallas: bool = False) -> Array:
    """K̃ = K_{n,m} K_{m,m}^{-1} K_{m,n} via the maintained eigenpairs."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    B = state.Knm @ jnp.where(mask[None, :], st.U, 0.0)   # (n, M)
    inv_lam = _pinv_lam(st.L, mask)
    if use_pallas:
        from repro.kernels.nystrom_recon import ops as _ops
        return _ops.scaled_gram(B, inv_lam)
    return (B * inv_lam[None, :]) @ B.T


@dataclass
class ErrorNorms:
    fro: float
    spectral: float
    trace: float


def approximation_error(K: Array, K_tilde: Array) -> ErrorNorms:
    """Frobenius / spectral / trace norms of K - K̃ (paper Fig. 2 metrics)."""
    D = K - K_tilde
    fro = jnp.linalg.norm(D)
    ev = jnp.linalg.eigvalsh(D)            # D symmetric
    spectral = jnp.max(jnp.abs(ev))
    trace = jnp.sum(jnp.abs(ev))
    return ErrorNorms(fro=float(fro), spectral=float(spectral),
                      trace=float(trace))
