"""Incremental Nyström approximation (paper §4) — the first incremental
algorithm for the full Nyström approximation to the kernel matrix.

The landmark set grows one point at a time; the eigendecomposition of the
(unadjusted) landmark gram K_{m,m} is maintained by Algorithm 1
(``inkpca.update_unadjusted``), and the Nyström eigenpairs of the full n×n
kernel matrix follow from the Williams–Seeger rescaling (paper eq. 7):

    Λ_nys = (n/m) Λ,        U_nys = sqrt(m/n) K_{n,m} U Λ^{-1}

so that  K̃ = U_nys Λ_nys U_nys^T = K_{n,m} K_{m,m}^{-1} K_{m,n}.

The O(n m^2) reconstruction hot spot  B diag(1/Λ) B^T  (B = K_{n,m} U) is
implemented by the fused Pallas kernel ``repro.kernels.nystrom_recon``.

This enables *empirical* stopping: monitor the chosen norm of K - K̃ (or a
cheap proxy) after each added landmark and stop when it plateaus.

For landmark sets that grow far below capacity, construct an
``engine.Engine`` over this module (or use the ``repro.core.buckets``
shims): ``Engine.add_landmark`` wraps this module's ``add_landmark`` with
bucketed dispatch so each addition costs O(M_b³) at the active
power-of-two bucket M_b instead of O(M³) at capacity.

Two row regimes:

* **Fixed rows** (default): the full dataset ``x_all`` is known upfront
  and ``Knm`` is allocated dense (n, M).
* **Growing rows** (``init_nystrom(..., grow_rows=True)``): the stream is
  open-ended, so ``Knm`` starts at the seed landmarks' rows and
  ``observe_rows`` appends a row block per observed (non-landmark) point —
  memory tracks the observed stream instead of paying n upfront.  The
  observed points are carried in ``NystromState.Xrows`` so later
  ``add_landmark`` calls can fill the new landmark's column; pass
  ``x_all=None`` in this mode.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf, rankone

Array = jax.Array


class NystromState(NamedTuple):
    kpca: inkpca.KPCAState   # eigendecomposition of K_{m,m} (unadjusted)
    Knm: Array               # (n, M) columns k(X_rows, x_j) for landmarks j<m
    Xrows: Array | None = None   # (n, d) observed row points (grow_rows mode)


def init_nystrom(x_all: Array | None, x0: Array, capacity: int,
                 spec: kf.KernelSpec, *, dtype=jnp.float32,
                 grow_rows: bool = False) -> NystromState:
    kpca = inkpca.init_state(x0, capacity, spec, adjusted=False, dtype=dtype)
    x0 = x0.astype(dtype)
    if grow_rows:
        if x_all is not None:
            raise ValueError("grow_rows=True derives rows from the stream; "
                             "pass x_all=None and call observe_rows")
        x_rows = x0              # landmarks are observed points too
    else:
        if x_all is None:
            raise ValueError("x_all is required unless grow_rows=True")
        x_rows = x_all.astype(dtype)
    n = x_rows.shape[0]
    Knm = jnp.zeros((n, capacity), dtype)
    cols = kf.gram_block(x_rows, x0, spec=spec)
    Knm = Knm.at[:, : x0.shape[0]].set(cols.astype(dtype))
    return NystromState(kpca=kpca, Knm=Knm,
                        Xrows=x_rows if grow_rows else None)


def observe_rows(state: NystromState, xb: Array,
                 spec: kf.KernelSpec) -> NystromState:
    """Append a block of observed (non-landmark) points as new Knm rows.

    Only valid in ``grow_rows`` mode.  Row growth is a host-level concat
    (each distinct row count is a new shape), so feed points in batches —
    the O(b·M) kernel block itself is one fused device call.
    """
    if state.Xrows is None:
        raise ValueError("observe_rows needs a grow_rows=True state")
    dtype = state.Knm.dtype
    xb = jnp.atleast_2d(xb).astype(dtype)
    M = state.Knm.shape[1]
    mask = rankone.active_mask(M, state.kpca.m)
    rows = kf.gram_block(xb, state.kpca.X, spec=spec).astype(dtype)
    rows = jnp.where(mask[None, :], rows, 0.0)
    return state._replace(Knm=jnp.concatenate([state.Knm, rows], axis=0),
                          Xrows=jnp.concatenate([state.Xrows, xb], axis=0))


@partial(jax.jit, static_argnames=("spec", "plan"))
def add_landmark(state: NystromState, x_all: Array | None, x_new: Array,
                 spec: kf.KernelSpec, *,
                 plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> NystromState:
    """Grow the landmark set by one point (streaming-compatible).

    In ``grow_rows`` mode the new column is evaluated against the observed
    rows carried in the state (``x_all`` must be None); add the point via
    ``observe_rows`` first if it should also appear as a row.
    """
    a, k_new = eng.masked_row(state.kpca, x_new, spec)
    m = state.kpca.m
    kpca = inkpca.update_unadjusted(state.kpca, a, k_new, x_new, plan=plan)
    x_rows = state.Xrows if state.Xrows is not None else x_all
    col = kf.kernel_row(x_new, x_rows.astype(state.Knm.dtype), spec=spec)
    zero = jnp.zeros((), m.dtype)
    Knm = jax.lax.dynamic_update_slice(state.Knm, col[:, None].astype(state.Knm.dtype),
                                       (zero, m))
    return state._replace(kpca=kpca, Knm=Knm)


def _pinv_lam(L: Array, mask: Array) -> Array:
    """Pseudo-inverse of the active spectrum: exact/near-zero eigenvalues
    (a compacted rank-truncated state carries rank-deficient active pairs)
    deflate to 0 instead of amplifying to 1/0."""
    tol = (L.shape[0] * jnp.finfo(L.dtype).eps
           * jnp.max(jnp.where(mask, jnp.abs(L), 0.0)))
    ok = mask & (jnp.abs(L) > tol)
    return jnp.where(ok, 1.0 / jnp.where(ok, L, 1.0), 0.0)


def nystrom_eigpairs(state: NystromState, n: int) -> tuple[Array, Array]:
    """Approximate eigenpairs of the full K via the rescaling (paper eq. 7)."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    mf = st.m.astype(st.L.dtype)
    lam_nys = jnp.where(mask, (n / mf) * st.L, 0.0)
    U_nys = jnp.sqrt(mf / n) * (state.Knm @ (st.U * _pinv_lam(st.L, mask)[None, :]))
    U_nys = jnp.where(mask[None, :], U_nys, 0.0)
    return lam_nys, U_nys


def reconstruct_tilde(state: NystromState, *, use_pallas: bool = False) -> Array:
    """K̃ = K_{n,m} K_{m,m}^{-1} K_{m,n} via the maintained eigenpairs."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    B = state.Knm @ jnp.where(mask[None, :], st.U, 0.0)   # (n, M)
    inv_lam = _pinv_lam(st.L, mask)
    if use_pallas:
        from repro.kernels.nystrom_recon import ops as _ops
        return _ops.scaled_gram(B, inv_lam)
    return (B * inv_lam[None, :]) @ B.T


@dataclass
class ErrorNorms:
    fro: float
    spectral: float
    trace: float


def approximation_error(K: Array, K_tilde: Array) -> ErrorNorms:
    """Frobenius / spectral / trace norms of K - K̃ (paper Fig. 2 metrics)."""
    D = K - K_tilde
    fro = jnp.linalg.norm(D)
    ev = jnp.linalg.eigvalsh(D)            # D symmetric
    spectral = jnp.max(jnp.abs(ev))
    trace = jnp.sum(jnp.abs(ev))
    return ErrorNorms(fro=float(fro), spectral=float(spectral),
                      trace=float(trace))
