"""Double-buffered snapshot serving: publish-once, query-many.

The ingest engine folds blocks into a *working* state A while queries
(`transform`, KRR predict, Nyström features) batch against a published
immutable ``ServingSnapshot`` B.  A snapshot freezes everything a query
needs — the stored points X, the active count m, and the precomputed
projection matrix

    S = U_active / sqrt(lam)        (transform head; other heads below)

so queries skip the per-call eigpair sort / slice / rescale that
``engine.transform_state`` pays on every invocation: the full argsort of L
and the (M, M) column gather of U happen once per *publication*, not once
per query batch.

One query head serves every workload.  ``query`` computes

    Y, rowsum = K(x_q, X_masked) @ S          (fused kernel or masked gram)
    Y        += affine correction             (mean-adjusted KPCA only)

and the head specializes purely through the published S / affine fields:

* unadjusted KPCA transform:  S = U_act/sqrt(lam),       affine = None
* adjusted KPCA transform:    same S, affine carries the centering
  (colsum = 1ᵀS, colproj = (K1/m)·S, grand = S_sum/m²) — identical to the
  ``transform_state`` post-correction, term for term
* KRR predict:                S = alpha[:, None],        affine = None
* Nyström query features:     S = sqrt(m/n)·U·lam⁺,      affine = None

Publication is O(M·C + M·d) — it never touches the (M, M) eigenvectors
beyond the C-column gather — and the ``retire=`` argument donates a
retired snapshot's buffers to the new one, so the steady-state
double-buffer (``DoubleBuffer``) publishes with no fresh allocation: the
swap itself is a host-side reference flip.  Snapshots are immutable jax
arrays: concurrent ingest into A can never perturb a query against B, and
queries against the same snapshot are bit-identical regardless of what
the ingest engine is doing.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import kernels_fn as kf, rankone

Array = jax.Array


class AffineCorrection(NamedTuple):
    """Mean-adjustment post-correction of a projected query batch (the
    ``transform_state`` centering identity): with rowsum rs per query,

        Y_adj = Y − (rs/mf)·colsumᵀ − 1·colprojᵀ + grand·colsumᵀ
    """

    mf: Array        # ()  active count as float
    colsum: Array    # (C,) 1ᵀS
    colproj: Array   # (C,) (K1/m)·S
    grand: Array     # ()  S_sum/m²


class ServingSnapshot(NamedTuple):
    """Immutable published query state (see module docstring).

    S:          (M, C) precomputed projection matrix (X dtype)
    X:          (M, d) stored points frozen at publication
    m:          ()     active count
    affine:     mean-adjustment correction, or None for linear heads
    generation: ()     int32 publication counter
    """

    S: Array
    X: Array
    m: Array
    affine: AffineCorrection | None
    generation: Array


def _transform_fields(state, *, n_components: int, adjusted: bool):
    """(S, affine) of the KPCA transform head — the per-query prologue of
    ``engine.transform_state`` hoisted to publication time.  Matches it
    bit-for-bit: same masked argsort, same top-C gather, same eps floor."""
    M = state.L.shape[0]
    mask = rankone.active_mask(M, state.m)
    order = jnp.argsort(jnp.where(mask, -state.L, jnp.inf))[:n_components]
    lam = state.L[order]
    vec = state.U[:, order]                        # (M, C) gather — not M²
    denom = jnp.sqrt(jnp.maximum(lam, jnp.finfo(state.L.dtype).eps))
    s_mat = (vec / denom[None, :]).astype(state.X.dtype)
    if not adjusted:
        return s_mat, None
    mf = state.m.astype(state.L.dtype)
    return s_mat, AffineCorrection(mf=mf,
                                   colsum=jnp.sum(s_mat, axis=0),
                                   colproj=(state.K1 / mf) @ s_mat,
                                   grand=state.S / mf**2)


def _publish_impl(state, generation, *, n_components: int, adjusted: bool):
    s_mat, affine = _transform_fields(state, n_components=n_components,
                                      adjusted=adjusted)
    return ServingSnapshot(S=s_mat, X=state.X, m=state.m, affine=affine,
                           generation=jnp.asarray(generation, jnp.int32))


def _publish_retiring_impl(state, retire, *, n_components: int,
                           adjusted: bool):
    # The retired snapshot is two publications old (double-buffer
    # discipline: the CURRENT front keeps serving while this publish
    # runs), so the new generation is retire.generation + 2.
    return _publish_impl(state, retire.generation + 2,
                         n_components=n_components, adjusted=adjusted)


@lru_cache(maxsize=None)
def _publish_fns(n_components: int, adjusted: bool):
    fresh = jax.jit(partial(_publish_impl, n_components=n_components,
                            adjusted=adjusted))
    donating = jax.jit(partial(_publish_retiring_impl,
                               n_components=n_components,
                               adjusted=adjusted),
                       donate_argnums=(1,))
    return fresh, donating


def publish_transform(state, *, n_components: int, adjusted: bool,
                      generation: int | Array = 0,
                      retire: ServingSnapshot | None = None
                      ) -> ServingSnapshot:
    """Publish a KPCA transform snapshot from (a copy of) the working
    state.  ``retire`` donates a snapshot that is no longer referenced —
    under the ``DoubleBuffer`` alternation, the one retired TWO publishes
    ago — so the new snapshot reuses its buffers instead of allocating;
    its generation is then derived in-graph (retire.generation + 2)."""
    fresh, donating = _publish_fns(int(n_components), bool(adjusted))
    if retire is None:
        return fresh(state, jnp.asarray(generation, jnp.int32))
    return donating(state, retire)


def query(snap: ServingSnapshot, xq: Array, *, spec: kf.KernelSpec,
          plan=None) -> Array:
    """Batch queries against a published snapshot: (nq, d) -> (nq, C).

    Under ``plan.fuse_krow`` the query gram never materializes — the
    fused ``nystrom_recon.transform_project`` kernel contracts each
    kernel tile against S in VMEM; otherwise the masked-gram reference
    path runs.  Pure function of (snap, xq): vmappable across tenants,
    shardable across a tenant mesh axis, and — because snapshots are
    immutable — bit-stable under any concurrent ingest.
    """
    xq = jnp.asarray(xq)
    if plan is not None and getattr(plan, "fuse_krow", False):
        from repro.kernels.nystrom_recon import ops as nops
        y, rs = nops.transform_project(xq, snap.X, snap.S, snap.m,
                                       spec=spec)
    else:
        kq = kf.gram_block(xq.astype(snap.X.dtype), snap.X, spec=spec)
        mask = rankone.active_mask(snap.X.shape[0], snap.m)
        kq = jnp.where(mask[None, :], kq, 0.0)
        y = kq @ snap.S
        rs = jnp.sum(kq, axis=1)
    if snap.affine is not None:
        aff = snap.affine
        y = (y - (rs / aff.mf)[:, None] * aff.colsum[None, :]
             - aff.colproj[None, :] + aff.grand * aff.colsum[None, :])
    return y


def query_batch(snaps: ServingSnapshot, xq: Array, *, spec: kf.KernelSpec,
                plan=None) -> Array:
    """Per-tenant queries against tenant-stacked snapshots (leading axis
    B on every leaf, e.g. from ``StreamBatch.publish``):
    (B, nq, d) -> (B, nq, C)."""
    return jax.vmap(lambda s, x: query(s, x, spec=spec, plan=plan))(snaps,
                                                                    xq)


class DoubleBuffer:
    """Host-side double buffer over published snapshots.

    ``front`` is the snapshot queries read; ``publish`` freezes the
    working state into a new front and retires the old one.  The snapshot
    retired two publishes ago is donated to the new publication (its
    buffers become the new snapshot's storage), so steady-state
    publication allocates nothing and the swap is a reference flip —
    O(1) regardless of capacity M.

    **Graceful degradation** (``core/health``): ``publish`` takes a
    ``healthy`` verdict from the caller's probe pass.  An unhealthy
    working state is NEVER frozen into a generation — the buffer keeps
    serving the last healthy front (queries are bit-stable against it by
    immutability) and counts the refusal in ``skipped``, so a drifting
    or NaN-poisoned ingest path degrades to stale-but-correct answers
    instead of serving garbage.  ``ref_lam`` freezes the published
    top-C spectrum alongside each front, giving the staleness-aware
    publication policy its drift reference for free.
    """

    def __init__(self, state=None, *, n_components: int | None = None,
                 adjusted: bool = True):
        self.n_components = n_components
        self.adjusted = adjusted
        self.front: ServingSnapshot | None = None
        self._retired: ServingSnapshot | None = None
        self._generation = 0
        self.skipped = 0
        self.ref_lam: Array | None = None
        if state is not None:
            self.publish(state)

    def publish(self, state, *, n_components: int | None = None,
                adjusted: bool | None = None,
                healthy: bool = True) -> ServingSnapshot:
        nc = self.n_components if n_components is None else n_components
        adj = self.adjusted if adjusted is None else adjusted
        if nc is None:
            raise ValueError("n_components must be set on the buffer or "
                             "passed to publish()")
        if not healthy:
            if self.front is None:
                raise ValueError("refusing to publish an unhealthy state "
                                 "with no prior healthy snapshot to serve")
            self.skipped += 1
            return self.front
        from repro.core import health as hl

        retire, self._retired = self._retired, self.front
        self.front = publish_transform(state, n_components=nc, adjusted=adj,
                                       generation=self._generation,
                                       retire=retire)
        self.ref_lam = hl.top_spectrum(state, nc)
        self._generation += 1
        return self.front

    def query(self, xq: Array, *, spec: kf.KernelSpec, plan=None) -> Array:
        if self.front is None:
            raise ValueError("no snapshot published yet")
        return query(self.front, xq, spec=spec, plan=plan)
