"""Incremental kernel ridge regression — the paper's §3 claim made
concrete: "any incremental algorithm for the eigendecomposition of the
kernel matrix can be applied where the explicit or implicit inverse of the
same is required, such as kernel regression and kernel SVM."

The KRR coefficients are α = (K + λI)⁻¹ y. With the maintained
eigendecomposition K = U Λ Uᵀ (Algorithm 1 state), the solve is a
diagonal rescale

    α = U (Λ + λI)⁻¹ Uᵀ y

so adding a data point costs the rank-one update (4m² + the O(m³)
rotation already paid for KPCA) plus an O(m²) re-solve — and λ can be
*swept for free* (one diagonal rescale per λ), which is how the
regularization path is usually chosen in practice.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf, rankone

Array = jax.Array


class KRRState(NamedTuple):
    kpca: inkpca.KPCAState       # eigendecomposition of K_{m,m} (Alg. 1)
    y: Array                     # (M,) targets, zero-padded


def init_krr(x0: Array, y0: Array, capacity: int, spec: kf.KernelSpec,
             *, dtype=jnp.float64) -> KRRState:
    kpca = inkpca.init_state(x0, capacity, spec, adjusted=False, dtype=dtype)
    y = jnp.zeros((capacity,), dtype).at[: y0.shape[0]].set(
        y0.astype(dtype))
    return KRRState(kpca=kpca, y=y)


def add_point(state: KRRState, x_new: Array, y_new: Array,
              spec: kf.KernelSpec, *,
              plan: eng.UpdatePlan = eng.DEFAULT_PLAN) -> KRRState:
    a, k_new = inkpca._masked_row(state.kpca, x_new, spec)
    m = state.kpca.m
    kpca = inkpca.update_unadjusted(state.kpca, a, k_new, x_new, plan=plan)
    y = state.y.at[m].set(jnp.asarray(y_new, state.y.dtype))
    return KRRState(kpca=kpca, y=y)


def coefficients(state: KRRState, lam: float) -> Array:
    """α = U (Λ + λ)⁻¹ Uᵀ y — O(m²) given the maintained eigenpairs."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    y = jnp.where(mask, state.y, 0.0)
    z = st.U.T @ y
    inv = jnp.where(mask, 1.0 / (st.L + lam), 0.0)
    return st.U @ (inv * z)


def predict(state: KRRState, x: Array, lam: float,
            spec: kf.KernelSpec) -> Array:
    """f(x) = k(x, X) α for new points x: (n, d)."""
    st = state.kpca
    alpha = coefficients(state, lam)
    K_x = kf.gram_block(x.astype(st.X.dtype), st.X, spec=spec)
    mask = rankone.active_mask(st.X.shape[0], st.m)
    return (jnp.where(mask[None, :], K_x, 0.0) @ alpha)


def publish_predict(state: KRRState, lam: float, *,
                    generation: int | Array = 0):
    """Freeze the KRR predict head into a ``serving.ServingSnapshot``:
    S = α[:, None] (the maintained-eigenpair solve runs once, at
    publication), so serving predictions are plain snapshot queries —
    f(x) = k(x, X_masked) @ α — with no per-call O(M²) coefficient
    solve, and immutable under concurrent ingest into the working state."""
    from repro.core import serving

    st = state.kpca
    alpha = coefficients(state, lam)
    return serving.ServingSnapshot(
        S=alpha[:, None].astype(st.X.dtype), X=st.X, m=st.m, affine=None,
        generation=jnp.asarray(generation, jnp.int32))


def snapshot_predict(snap, x: Array, spec: kf.KernelSpec, *,
                     plan: eng.UpdatePlan | None = None) -> Array:
    """f(x) for a published KRR snapshot: (n, d) -> (n,)."""
    from repro.core import serving

    return serving.query(snap, x, spec=spec, plan=plan)[:, 0]


def loocv_residuals(state: KRRState, lam: float) -> Array:
    """Leave-one-out residuals in closed form — e_i = (y−Kα)_i/(1−H_ii)
    with the hat diagonal H_ii = Σ_j U_ij² λ_j/(λ_j+λ) from the maintained
    eigenpairs. The streaming λ-selection loop this enables is the same
    'empirical evaluation' story the paper tells for Nyström subset size."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    lam_safe = jnp.where(mask, st.L, 0.0)
    w = lam_safe / (lam_safe + lam)
    H_diag = jnp.sum((st.U * st.U) * w[None, :], axis=1)
    alpha = coefficients(state, lam)
    resid = jnp.where(mask, state.y, 0.0) - lam_safe_dot(state, alpha)
    denom = jnp.maximum(1.0 - H_diag, 1e-12)
    return jnp.where(mask, resid / denom, 0.0)


def lam_safe_dot(state: KRRState, alpha: Array) -> Array:
    """K α via the maintained eigenpairs (avoids storing K)."""
    st = state.kpca
    M = st.L.shape[0]
    mask = rankone.active_mask(M, st.m)
    lam_active = jnp.where(mask, st.L, 0.0)
    return st.U @ (lam_active * (st.U.T @ alpha))
