"""In-graph stream metrics — the device half of the observability layer.

A ``MetricsState`` pytree rides the stream next to ``HealthState`` (the
discipline PR 8 proved out): device-resident counters and gauges that
are updated with pure functional ``note_*`` helpers and only ever read
on the host when someone scrapes them.  Two invariants make the layer
free to turn on:

* **Bitwise identity.**  The eigensystem NEVER flows through a
  metrics-aware dispatch.  Every metered path runs the *identical*
  jitted update callables (same jit cache keys, same executables) as
  the metrics-off path, and the note fires as a separate tiny fused
  dispatch afterwards, consuming only values the update already
  produced (``state.m``, the window clock, ``HealthState`` counters)
  plus host-known block sizes.  ``UpdatePlan.metrics`` is therefore
  normalized away by ``kernel_plan()`` like every other policy field —
  metrics-on and metrics-off states are bitwise equal by construction,
  and ``tests/test_telemetry.py`` locks that in across the update,
  window, and P=2 sharded paths.

* **Exact counters, no host syncs.**  Accepted/rejected/evicted counts
  are identities over traced scalars the guarded paths already
  maintain — ``accepted = clock_after − clock_before`` on window paths
  (the guarded scan only advances the clock for accepted points),
  ``accepted = offered − Δ(hstate.quarantined)`` on guarded plain
  paths, and ``evictions = accepted − (m_after − m_before)`` always.
  Nothing is read back until ``metrics_report``/``TelemetryHub.scrape``
  — the caller's one explicit sync, exactly like reading HealthState.

On the sharded window path the note consumes only replicated outputs
(``m``, ``clock``), so the MetricsState stays consistent across shards
without adding a single collective — the fixed psum/ppermute schedule
of ``core/distributed.py`` is untouched.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array

# Gauge value meaning "not applicable / never observed".
GAUGE_UNSET = -1.0


class MetricsState(NamedTuple):
    """Counters (int32, monotone) and gauges (state dtype) for one stream.

    Stacked on a leading tenant axis by ``init_metrics_stacked`` for
    ``StreamBatch`` — every note helper is shape-polymorphic over that
    axis, so per-tenant metric lanes ride the same code path.
    """

    # -- counters ----------------------------------------------------------
    ingests: Array            # points folded into the eigensystem
    rejections: Array         # points quarantined (gate or host pre-gate)
    evictions: Array          # window evictions (implicit downdates)
    downdates: Array          # explicit downdates / landmark removals
    publishes: Array          # serving snapshots published
    skipped_publishes: Array  # publications refused on health
    heals_polish: Array       # heal-ladder rungs taken, by rung
    heals_resync: Array
    # -- gauges ------------------------------------------------------------
    m: Array                  # active count after the last noted step
    window_fill: Array        # m / window (GAUGE_UNSET when unwindowed)
    generation: Array         # last published snapshot generation
    spec_drift: Array         # mirror of HealthState.spec_drift
    orth_err: Array           # mirror of HealthState.orth_err
    neg_frac: Array           # mirror of HealthState.neg_frac
    trace_err: Array          # Nyström trace-error estimate (GAUGE_UNSET
    #                           until a tracker reports one)


def init_metrics(dtype=jnp.float32) -> MetricsState:
    z = jnp.zeros((), jnp.int32)
    g = jnp.zeros((), dtype)
    unset = jnp.asarray(GAUGE_UNSET, dtype)
    return MetricsState(ingests=z, rejections=z, evictions=z, downdates=z,
                        publishes=z, skipped_publishes=z, heals_polish=z,
                        heals_resync=z, m=g, window_fill=unset,
                        generation=jnp.asarray(-1, jnp.int32),
                        spec_drift=unset, orth_err=g, neg_frac=g,
                        trace_err=unset)


def init_metrics_stacked(n: int, dtype=jnp.float32) -> MetricsState:
    """(n,)-leaf MetricsState: one metric lane per tenant."""
    one = init_metrics(dtype)
    return jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf, (n,) + leaf.shape) + 0, one)


def _i32(x) -> Array:
    return jnp.asarray(x).astype(jnp.int32)


@partial(jax.jit, static_argnames=("window",))
def note_block(ms: MetricsState, m_before, m_after, offered, accepted,
               hstate=None, *, window: int | None = None) -> MetricsState:
    """Account one update/update_block/window_block step.

    ``accepted`` is the exact folded count (see module docstring for the
    per-path identities); evictions fall out as
    ``accepted − (m_after − m_before)`` — zero on append-only paths,
    the evict+ingest pair count at a full window.  With ``hstate`` the
    probe gauges are mirrored; ``window`` (static) sets the fill gauge.
    """
    acc = _i32(accepted)
    off = _i32(offered)
    grown = _i32(m_after) - _i32(m_before)
    mf = jnp.asarray(m_after).astype(ms.m.dtype)
    fill = (mf / window if window is not None
            else jnp.asarray(GAUGE_UNSET, ms.window_fill.dtype))
    ms = ms._replace(ingests=ms.ingests + acc,
                     rejections=ms.rejections + (off - acc),
                     evictions=ms.evictions + (acc - grown),
                     m=mf, window_fill=fill)
    if hstate is not None:
        ms = ms._replace(
            spec_drift=hstate.spec_drift.astype(ms.spec_drift.dtype),
            orth_err=hstate.orth_err.astype(ms.orth_err.dtype),
            neg_frac=hstate.neg_frac.astype(ms.neg_frac.dtype))
    return ms


@jax.jit
def note_lanes(ms: MetricsState, ingests, rejections, evictions, m,
               window_fill) -> MetricsState:
    """Stacked-lane account: per-tenant host-exact deltas (``StreamBatch``
    tracks every fold/evict/quarantine on the host already) applied in
    one fused dispatch."""
    return ms._replace(ingests=ms.ingests + _i32(ingests),
                       rejections=ms.rejections + _i32(rejections),
                       evictions=ms.evictions + _i32(evictions),
                       m=jnp.asarray(m).astype(ms.m.dtype),
                       window_fill=jnp.asarray(window_fill).astype(
                           ms.window_fill.dtype))


# -------------------------------------------------- host-triggered notes --
# These fire on host-decided events (publish, heal, explicit downdate) —
# eager element-wise ops on scalar leaves, nowhere near a hot loop.
def note_downdate(ms: MetricsState, m_after=None, n: int = 1) -> MetricsState:
    ms = ms._replace(downdates=ms.downdates + jnp.asarray(n, jnp.int32))
    if m_after is not None:
        ms = ms._replace(m=jnp.asarray(m_after).astype(ms.m.dtype))
    return ms


def note_publish(ms: MetricsState, generation) -> MetricsState:
    gen = jnp.broadcast_to(jnp.asarray(generation, jnp.int32),
                           ms.generation.shape)
    return ms._replace(publishes=ms.publishes + 1, generation=gen)


def note_skipped_publish(ms: MetricsState) -> MetricsState:
    return ms._replace(skipped_publishes=ms.skipped_publishes + 1)


def note_heal(ms: MetricsState, rung: str, n=1) -> MetricsState:
    """``rung``: "polish" | "resync" ("noop" is not counted)."""
    n = jnp.asarray(n, jnp.int32)
    if rung == "polish":
        return ms._replace(heals_polish=ms.heals_polish + n)
    if rung == "resync":
        return ms._replace(heals_resync=ms.heals_resync + n)
    return ms


def note_drift(ms: MetricsState, drift) -> MetricsState:
    d = jnp.broadcast_to(jnp.asarray(drift).astype(ms.spec_drift.dtype),
                         ms.spec_drift.shape)
    return ms._replace(spec_drift=d)


def note_trace_error(ms: MetricsState, value) -> MetricsState:
    v = jnp.broadcast_to(jnp.asarray(value).astype(ms.trace_err.dtype),
                         ms.trace_err.shape)
    return ms._replace(trace_err=v)


# ------------------------------------------------------------- read-out --
def metrics_report(ms: MetricsState) -> dict:
    """Host-side snapshot (THE one sync): counters as python ints, gauges
    as floats; stacked lanes come back as numpy arrays per field plus a
    summed ``*_total`` for every counter."""
    import numpy as np

    host = jax.device_get(ms)
    out: dict = {}
    counters = ("ingests", "rejections", "evictions", "downdates",
                "publishes", "skipped_publishes", "heals_polish",
                "heals_resync")
    for k, v in host._asdict().items():
        arr = np.asarray(v)
        if arr.ndim == 0:
            out[k] = (int(arr) if k in counters or k == "generation"
                      else float(arr))
        else:
            out[k] = arr
            if k in counters:
                out[f"{k}_total"] = int(arr.sum())
    return out
