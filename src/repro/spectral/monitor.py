"""Streaming spectral monitor — the paper's incremental KPCA applied to
training observability.

Feeds blocks of layer activations (fetched from the device between steps)
into an incremental kernel-PCA state (Algorithm 2) and tracks the kernel
eigenspectrum over training: effective rank collapse, feature drift and
saturation show up as spectrum shape changes *without* ever forming an
n×n gram matrix over the run — memory stays O(capacity²).

The monitor rides the **sliding-window** stream (``core/window.py``): once
the window is full every new activation evicts the oldest one, so the
tracked spectrum is always that of the trailing ``window`` examples and
the history keeps evolving for the entire run.  (The pre-window monitor
silently stopped ingesting once the capacity filled — a run's later
drift was invisible.)

This is exactly the streaming use case the paper motivates (§1, §3): data
examples arrive sequentially and a solution is desired at each step.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import health as hl, inkpca, kernels_fn as kf


@dataclass
class SpectralMonitor:
    """``window`` defaults to ``capacity``: the monitor always tracks the
    trailing ``capacity`` examples instead of freezing at the first
    ``capacity`` ingested.

    Every ``observe`` also publishes its stats as gauges on a
    ``TelemetryHub`` (``hub``, default the process hub, under
    ``{prefix}_*``) including ``drift`` — the relative L2 motion of the
    tracked top spectrum since the previous observe, computed by the
    health probe's ``spectral_drift`` against a frozen reference rather
    than by diffing history entries."""

    capacity: int = 128
    kernel: str = "rbf"
    adjusted: bool = True
    dtype: object = jnp.float32
    window: int | None = None
    prefix: str = "spectral"
    hub: object = field(default=None, repr=False)
    _stream: inkpca.KPCAStream | None = field(default=None, repr=False)
    _ref_lam: object = field(default=None, repr=False)
    history: list = field(default_factory=list)

    def observe(self, activations) -> dict:
        """activations: (n, d) block (e.g. pooled per-example features)."""
        x = jnp.asarray(activations, self.dtype)
        if self._stream is None:
            W = self.window or self.capacity
            seed = x[: max(2, min(4, W, x.shape[0]),
                           min(16, W, x.shape[0] // 2))]
            sigma = float(kf.median_heuristic(x))
            spec = kf.KernelSpec(name=self.kernel, sigma=max(sigma, 1e-6))
            self._stream = inkpca.KPCAStream(
                seed, capacity=self.capacity, spec=spec,
                adjusted=self.adjusted, dtype=self.dtype,
                window=self.window or self.capacity)
            rest = x[seed.shape[0]:]
        else:
            rest = x
        if rest.shape[0] > 0:
            self._stream.update_block(rest)
        stats = self.stats()
        # Spectrum motion since the previous observe: one traced
        # top-spectrum read + the probe's relative-L2 drift metric.
        st = self._stream.kpca_state
        nc = min(8, self.capacity)
        if self._ref_lam is not None:
            stats["drift"] = float(hl.spectral_drift(st, self._ref_lam))
        else:
            stats["drift"] = 0.0
        self._ref_lam = hl.top_spectrum(st, nc)
        hub = self.hub if self.hub is not None else obs.get_hub()
        for k, v in stats.items():
            hub.set_gauge(f"{self.prefix}_{k}", v)
        self.history.append(stats)
        return stats

    def stats(self) -> dict:
        st = self._stream.kpca_state
        m = int(st.m)
        lam = np.sort(np.asarray(st.L[:m]))[::-1]
        lam = np.maximum(lam, 0.0)
        total = lam.sum() + 1e-30
        p = lam / total
        entropy = float(-np.sum(p * np.log(p + 1e-30)))
        return {
            "m": m,
            "seen": int(self._stream.state.clock),
            "top_eig": float(lam[0]) if m else 0.0,
            "trace": float(total),
            "effective_rank": float(np.exp(entropy)),
            "explained_90": int(np.searchsorted(np.cumsum(p), 0.90) + 1),
        }

    def eigenvalues(self) -> np.ndarray:
        st = self._stream.kpca_state
        return np.sort(np.asarray(st.L[: int(st.m)]))[::-1]
