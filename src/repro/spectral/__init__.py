from repro.spectral.monitor import SpectralMonitor
