"""Testing utilities: the fault-injection harness (``repro.testing.faults``)."""
