"""Fault-injection harness: controlled corruption + crash points.

Two halves:

**Killpoints** — production code paths embed named ``faults.trip(point)``
calls at the instants a real deployment can die (between the checkpoint
writes and renames, for instance).  ``trip`` is a no-op unless a test
``arm``-ed that point, in which case it raises ``FaultInjected`` —
simulating a kill -9 at exactly that line.  The registry is process-local
and intentionally trivial: ``trip`` costs one dict check when nothing is
armed, so shipping the killpoints in production code is free.

**Corruptors** — pure functions that damage eigensystem state in
controlled, realistic ways (a NaN input point, a bit-flipped eigenvector
tile, a poisoned stored row) so the detection + recovery path
(``core/health``) can be asserted end-to-end.

Used by ``tests/test_faults.py`` / ``tests/test_health.py`` and the
``make faults`` target.
"""
from __future__ import annotations

from contextlib import contextmanager

import numpy as np

__all__ = ["FaultInjected", "arm", "disarm", "armed", "trip", "injected",
           "nan_point", "corrupt_eigvecs", "bitflip_eigvec",
           "corrupt_eigenvalue", "poison_stored_row"]


class FaultInjected(BaseException):
    """Raised at an armed killpoint.  Derives from BaseException so
    production ``except Exception`` recovery blocks do NOT swallow it —
    a killed process doesn't run its own exception handlers either."""

    def __init__(self, point: str):
        super().__init__(f"injected fault at {point!r}")
        self.point = point


_armed: dict[str, int] = {}
_hits: dict[str, int] = {}


def arm(point: str, *, after: int = 0) -> None:
    """Arm ``point``: the (after+1)-th ``trip(point)`` raises."""
    _armed[point] = int(after)
    _hits[point] = 0


def disarm(point: str | None = None) -> None:
    """Disarm one point, or everything when called with no argument."""
    if point is None:
        _armed.clear()
        _hits.clear()
    else:
        _armed.pop(point, None)
        _hits.pop(point, None)


def armed(point: str) -> bool:
    return point in _armed


def trip(point: str) -> None:
    """Killpoint: no-op unless armed (one dict lookup on the fast path)."""
    if not _armed or point not in _armed:
        return
    _hits[point] = _hits.get(point, 0) + 1
    if _hits[point] > _armed[point]:
        disarm(point)
        raise FaultInjected(point)


@contextmanager
def injected(point: str, *, after: int = 0):
    """Scope an armed killpoint; always disarms on exit."""
    arm(point, after=after)
    try:
        yield
    finally:
        disarm(point)


# ------------------------------------------------------------ corruptors --
def nan_point(d: int, *, kind: str = "nan", index: int = 0,
              base=None) -> np.ndarray:
    """A d-dimensional input point with a non-finite entry — the
    canonical bad arrival the quarantine gate must reject."""
    x = (np.zeros(d, np.float32) if base is None
         else np.array(base, np.float32, copy=True))
    x[index] = {"nan": np.nan, "inf": np.inf, "-inf": -np.inf}[kind]
    return x


def corrupt_eigvecs(state, *, magnitude: float = 0.1, seed: int = 0):
    """Additive gaussian damage to the ACTIVE eigenvector block — models
    slow orthogonality drift (or a partial HBM scribble) that the
    sampled probe must detect and ``heal`` must repair.  Keeps the
    padding invariants (only rows/cols < m are touched)."""
    import jax.numpy as jnp

    m = int(state.m)
    rng = np.random.default_rng(seed)
    noise = rng.normal(scale=magnitude, size=(m, m))
    U = state.U.at[:m, :m].add(jnp.asarray(noise, state.U.dtype))
    return state._replace(U=U)


def bitflip_eigvec(state, i: int = 0, j: int = 0, *, bit: int = 31):
    """Flip one bit of eigenvector entry U[i, j] — a literal SDC
    (silent-data-corruption) event.  Bit 31 of an f32 is the sign bit;
    bit 30 scribbles the exponent (a huge entry the non-finite /
    negativity probes catch even when orthogonality sampling misses
    column j)."""
    import jax.numpy as jnp

    U = np.asarray(state.U).copy()
    if U.dtype == np.float32:
        U.view(np.uint32)[i, j] ^= np.uint32(1) << np.uint32(bit)
    elif U.dtype == np.float64:
        U.view(np.uint64)[i, j] ^= np.uint64(1) << np.uint64(bit)
    else:
        raise TypeError(f"bitflip_eigvec supports f32/f64, got {U.dtype}")
    return state._replace(U=jnp.asarray(U))


def corrupt_eigenvalue(state, j: int = 0, *, value: float = -1.0):
    """Overwrite an active eigenvalue — PSD violation the negativity
    probe flags."""
    import jax.numpy as jnp

    return state._replace(L=state.L.at[j].set(jnp.asarray(value,
                                                          state.L.dtype)))


def poison_stored_row(state, row: int = 0):
    """NaN a stored point row — makes in-place resync impossible, forcing
    the restore-from-checkpoint rung (``health.HealthError``)."""
    import jax.numpy as jnp

    return state._replace(X=state.X.at[row].set(jnp.nan))
