from repro.optim.optimizers import (OptState, adamw, adafactor, sgdm,
                                    make_optimizer)
from repro.optim.schedules import (constant, cosine, wsd, linear_warmup,
                                   make_schedule)
from repro.optim.compression import (compress_int8, decompress_int8,
                                     compressed_psum, CompressionState)
