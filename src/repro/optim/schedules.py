"""Learning-rate schedules.

WSD (warmup–stable–decay) is a first-class citizen because the assigned
minicpm-2b architecture trains with it (arXiv:2404.06395): LR warms up,
holds at peak for the bulk of training, then decays rapidly in the final
``decay_frac`` of steps (we use the paper's exponential-to-floor form).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

Schedule = Callable[[jnp.ndarray], jnp.ndarray]


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup: int) -> Schedule:
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return lr * frac
    return fn


def cosine(lr: float, warmup: int, total: int, floor: float = 0.1) -> Schedule:
    def fn(step):
        s = step.astype(jnp.float32)
        wf = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * wf * cos
    return fn


def wsd(lr: float, warmup: int, total: int, decay_frac: float = 0.1,
        floor: float = 0.01) -> Schedule:
    """Warmup–Stable–Decay (minicpm): stable at peak, exp decay at the end."""
    decay_start = int(total * (1.0 - decay_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        wf = jnp.minimum(s / max(warmup, 1), 1.0)
        prog = jnp.clip((s - decay_start) / max(total - decay_start, 1),
                        0.0, 1.0)
        decay = jnp.exp(jnp.log(floor) * prog)   # 1 -> floor exponentially
        return lr * wf * decay
    return fn


@dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"          # constant | cosine | wsd
    lr: float = 3e-4
    warmup: int = 100
    total: int = 10_000
    decay_frac: float = 0.1
    floor: float = 0.1


def make_schedule(cfg: ScheduleConfig) -> Schedule:
    if cfg.kind == "constant":
        return constant(cfg.lr)
    if cfg.kind == "cosine":
        return cosine(cfg.lr, cfg.warmup, cfg.total, cfg.floor)
    if cfg.kind == "wsd":
        return wsd(cfg.lr, cfg.warmup, cfg.total, cfg.decay_frac,
                   floor=min(cfg.floor, 0.05))
    raise ValueError(cfg.kind)
