"""Int8 gradient compression with error feedback, for the slow pod axis.

At 512+ chips the inter-pod (DCI) links are the scarcest bandwidth; the
cross-pod gradient all-reduce is the dominant collective for pure-DP pod
scaling.  We compress pod-axis gradient traffic 4× (f32 -> int8 blockwise)
with an error-feedback accumulator (Seide et al. 2014; Karimireddy et al.
2019) so the quantization bias does not accumulate in the optimizer:

    e_t        <- residual from the previous step
    q_t        =  Q(g_t + e_t)
    e_{t+1}    =  (g_t + e_t) - DQ(q_t)
    all-reduce over 'pod' runs on q_t (int8 payload + per-block scales).

``compressed_psum`` is shard_map-compatible: inside a shard_map over the
pod axis, call it instead of ``jax.lax.psum``.  Under jit-of-pjit the
int8 cast happens before the collective, so the HLO all-reduce moves 1/4
of the bytes (visible in the §Roofline collective term).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

BLOCK = 256


class CompressionState(NamedTuple):
    error: PyTree    # error-feedback residual, same structure as grads


def init_state(grads_like: PyTree) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _pad_to_block(x: Array) -> tuple[Array, int]:
    n = x.size
    np_ = -(-n // BLOCK) * BLOCK
    flat = jnp.pad(x.reshape(-1), (0, np_ - n))
    return flat.reshape(-1, BLOCK), n


def compress_int8(g: Array) -> tuple[Array, Array]:
    """Blockwise symmetric int8 quantization. Returns (q, scales)."""
    blocks, _ = _pad_to_block(g.astype(jnp.float32))
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def decompress_int8(q: Array, scale: Array, shape: tuple, n: int) -> Array:
    deq = q.astype(jnp.float32) * scale[:, None]
    return deq.reshape(-1)[:n].reshape(shape)


def compressed_psum(grads: PyTree, state: CompressionState, axis: str,
                    *, npods: int) -> tuple[PyTree, CompressionState]:
    """Error-feedback int8 gradient mean over ``axis`` (inside shard_map).

    Scheme: quantize locally, all-gather the int8 payload (+ f32 per-block
    scales) over the pod axis, dequantize and average locally.  The wire
    payload is 1 byte/element (+ 4/BLOCK bytes of scales) versus the ring
    all-reduce's 2·(P-1)/P · 4 bytes/element — a ≥4× cut for P=2 pods,
    visible in the dry-run's collective-bytes term.
    """

    def one(g: Array, e: Array) -> tuple[Array, Array]:
        target = g.astype(jnp.float32) + e
        q, scale = compress_int8(target)
        q_all = jax.lax.all_gather(q, axis)          # (P, nblocks, BLOCK) i8
        s_all = jax.lax.all_gather(scale, axis)      # (P, nblocks) f32
        deq = jnp.sum(q_all.astype(jnp.float32) * s_all[..., None], axis=0)
        mean = (deq.reshape(-1)[: g.size].reshape(g.shape) / npods)
        new_e = target - decompress_int8(q, scale, g.shape, g.size)
        return mean.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            CompressionState(error=tdef.unflatten([o[1] for o in outs])))
