"""Optimizers, functional-style (no optax dependency).

* ``adamw``     — fp32 moments; the default for ≤132B-param archs, with
  moments FSDP-sharded like the params (ZeRO-1/3 by construction: the
  optimizer state inherits the param sharding).
* ``adafactor`` — factored second moment (row/col statistics), the memory
  plan for kimi-k2-1t: no fp32 master copy, state is O(r+c) per matrix.
* ``sgdm``      — baseline.

All expose  init(params) -> state  and
update(grads, state, params, lr) -> (new_params, new_state).
Gradient clipping is a separate combinator so it composes with the int8
pod-axis compression in ``compression.py``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class OptState(NamedTuple):
    step: Array
    inner: PyTree


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[..., tuple[PyTree, OptState]]
    name: str = "opt"


def global_norm(tree: PyTree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ------------------------------------------------------------------ AdamW --
def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)       # noqa: E731
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner={"mu": jax.tree.map(zeros, params),
                               "nu": jax.tree.map(zeros, params)})

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            u = (mu / c1) / (jnp.sqrt(nu / c2) + eps)
            if p.ndim >= 2:                      # decoupled wd, matrices only
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), mu, nu

        out = jax.tree.map(upd, grads, state.inner["mu"], state.inner["nu"],
                           params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, inner={"mu": mu, "nu": nu})

    return Optimizer(init=init, update=update, name="adamw")


# --------------------------------------------------------------- Adafactor --
def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern 2018), the
    O(r+c)-state memory plan for the 1T-param arch."""

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(st, params,
                                           is_leaf=lambda x: hasattr(x, "shape")))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay_pow)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]       # (..., 1, 1)
                u = g * jax.lax.rsqrt(vr[..., None] / denom) \
                    * jax.lax.rsqrt(vc[..., None, :])
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g * jax.lax.rsqrt(nv["v"])
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay and p.ndim >= 2:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_v = treedef.flatten_up_to(state.inner)
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        new_params = treedef.unflatten([o[0] for o in outs])
        new_inner = treedef.unflatten([o[1] for o in outs])
        return new_params, OptState(step=step, inner=new_inner)

    return Optimizer(init=init, update=update, name="adafactor")


# -------------------------------------------------------------------- SGDm --
def sgdm(momentum: float = 0.9) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        inner=jax.tree.map(
                            lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(grads, state, params, lr):
        step = state.step + 1

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state.inner, params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return new_params, OptState(step=step, inner=m)

    return Optimizer(init=init, update=update, name="sgdm")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    if name == "sgdm":
        return sgdm(**kw)
    raise ValueError(name)
