"""Trip-count-aware HLO analysis (parser-lite over compiled HLO text).

``compiled.cost_analysis()`` counts while-loop bodies ONCE — useless for
scan-over-layers programs where >95% of the work sits inside loops.  This
module parses the post-SPMD-partitioning HLO text and resolves, per
computation and recursively through ``while``/``fusion``/``conditional``:

* **flops** — 2·K·prod(result) for every ``dot`` (incl. dots inside fusion
  computations), the dominant LM compute;
* **hbm bytes** — Σ (operand + result bytes) over non-free ops in real
  (non-fusion) computations: the post-fusion boundary model of HBM traffic
  (same model HloCostAnalysis uses), fusion-internal temps excluded;
* **collective wire bytes** — ring-model per-device bytes per op kind;

each multiplied by the enclosing while's trip count (read from the largest
integer constant in the loop-condition computation — XLA emits
``compare(induction, constant(T))`` for scan loops; fallback 1).

All numbers are PER DEVICE (the partitioned module is the per-device
program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "add-dependency", "domain",
    "opt-barrier", "partition-id", "replica-id", "call",
}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start"}

_TYPE_RE = re.compile(r"([a-z]\d*[a-z]*\d*(?:e\dm\d\w*)?)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"(?:branch_computations|true_computation|"
                          r"false_computation)=\{?%?([\w\.\-,% ]+)\}?")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_INT_RE = re.compile(r"[su]\d+\[\]\s+constant\((\d+)\)")


def _type_bytes(segment: str) -> float:
    total = 0.0
    for dt, dims in _TYPE_RE.findall(segment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _dims_of(segment: str) -> list[int]:
    m = _TYPE_RE.search(segment)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    opcode: str
    result_bytes: float
    line: str
    result_seg: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)   # name -> result bytes
    dims: dict = field(default_factory=dict)      # name -> result dims
    by_name: dict = field(default_factory=dict)   # name -> Op


@dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    payload_bytes: float = 0.0
    coll_count: int = 0
    by_kind: dict = field(default_factory=dict)

    def add_scaled(self, other: "Stats", k: float, flops_only: bool = False):
        self.flops += k * other.flops
        if flops_only:
            return
        self.bytes += k * other.bytes
        self.wire_bytes += k * other.wire_bytes
        self.payload_bytes += k * other.payload_bytes
        self.coll_count += int(k * other.coll_count)
        for kk, v in other.by_kind.items():
            d = self.by_kind.setdefault(kk, {"count": 0, "wire_bytes": 0.0})
            d["count"] += int(k * v["count"])
            d["wire_bytes"] += k * v["wire_bytes"]


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for line in hlo.splitlines():
        # Computation headers sit at column 0: `%name (args) -> type {`
        # (args may contain nested parens for tuple types, so parse by
        # position rather than a paren-matching regex).
        if (line and not line[0].isspace() and line.rstrip().endswith("{")
                and "->" in line):
            head = line.strip()
            if head.startswith("ENTRY"):
                head = head[len("ENTRY"):].strip()
            name = head.split("(")[0].split()[0].lstrip("%").rstrip(".")
            current = Computation(name=name)
            comps[name] = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        mo = _OPLINE_RE.match(line)
        if not mo:
            continue
        name, result_seg, opcode = mo.groups()
        rbytes = _type_bytes(result_seg)
        current.symbols[name] = rbytes
        current.dims[name] = _dims_of(result_seg)
        o = Op(name=name, opcode=opcode, result_bytes=rbytes,
               line=line, result_seg=result_seg)
        current.ops.append(o)
        current.by_name[name] = o
    return comps


def _bf16_legalized(op: Op, comp: Computation,
                    comps: dict[str, Computation] | None = None) -> bool:
    """True when a collective's f32 payload is an XLA:CPU bf16->f32
    legalization artifact: on TPU the tensor stays bf16 and the wire cost
    is half. Detected as: f32 collective whose direct operand is a
    convert (or convert-fusion whose callee upconverts from bf16)."""
    if not op.result_seg.lstrip("(").startswith("f32"):
        return False
    m = re.search(re.escape(op.opcode) + r"\(%([\w\.\-]+)", op.line)
    if not m:
        return False
    src = comp.by_name.get(m.group(1))
    if src is None:
        return False
    if src.opcode == "convert" and "bf16" in src.line:
        return True
    if src.opcode in ("fusion", "copy") and "convert" in src.name:
        if "bf16" in src.line:
            return True
        if comps is not None:
            mc = _CALLS_RE.search(src.line)
            callee = comps.get(mc.group(1)) if mc else None
            if callee is not None and any(
                    o.opcode == "convert" and "bf16" in o.line
                    for o in callee.ops):
                return True
    return False


def _operand_bytes_list(op: Op, comp: Computation) -> list[float]:
    # operand names inside the call parens; the symbol table is
    # authoritative (handles tuple-typed operands and bare names).
    m = re.search(re.escape(op.opcode) + r"\((.*)$", op.line)
    if not m:
        return []
    seg = m.group(1)
    depth = 1
    out = []
    for ch in seg:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(ch)
    inner = "".join(out)
    return [comp.symbols.get(nm, 0.0)
            for nm in re.findall(r"%([\w\.\-]+)", inner)]


def _fusion_param_reads(callee: Computation) -> dict[int, float | None]:
    """Per-parameter read bytes inside a fusion computation.

    None  -> full operand read (default);
    float -> slice-limited read (parameter consumed ONLY by dynamic-slice /
             gather ops: a scan reading one step of a stacked buffer).
    """
    param_names: dict[str, int] = {}
    for op in callee.ops:
        if op.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", op.line)
            if m:
                param_names[op.name] = int(m.group(1))
    reads: dict[int, float | None] = {}
    consumers: dict[str, list[Op]] = {n: [] for n in param_names}
    for op in callee.ops:
        if op.opcode == "parameter":
            continue
        for nm in re.findall(r"%([\w\.\-]+)", op.line.split("=", 1)[-1]):
            if nm in consumers:
                consumers[nm].append(op)
    for nm, idx in param_names.items():
        ops = consumers.get(nm, [])
        if ops and all(o.opcode in ("dynamic-slice", "gather")
                       for o in ops):
            reads[idx] = sum(o.result_bytes for o in ops)
        else:
            reads[idx] = None
    return reads


def _fusion_bytes(op: Op, comp: Computation,
                  callee: Computation | None) -> float:
    ops_b = _operand_bytes_list(op, comp)
    if callee is None:
        return sum(ops_b) + op.result_bytes
    reads = _fusion_param_reads(callee)
    total = 0.0
    for i, b in enumerate(ops_b):
        r = reads.get(i, None)
        total += b if r is None else min(r, b)
    # A fusion containing dynamic-update-slice updates its buffer in place
    # (possibly behind a convert/bitcast root): write = update-slice bytes,
    # and the aliased big buffer is neither fully read nor fully written.
    dus_ops = [o for o in callee.ops if o.opcode == "dynamic-update-slice"]
    if dus_ops:
        upd = 0.0
        for o in dus_ops:
            m = re.search(
                r"dynamic-update-slice\(%([\w\.\-]+),\s*%([\w\.\-]+)",
                o.line)
            upd += callee.symbols.get(m.group(2), 0.0) if m else 0.0
        big = max(ops_b) if ops_b else 0.0
        return max(total - big, 0.0) + upd
    return total + op.result_bytes


def _hbm_bytes(op: Op, comp: Computation,
               comps: dict[str, Computation] | None = None) -> float:
    """Post-fusion HBM traffic model for one op.

    In-place / slice ops are the critical special case: a
    dynamic-update-slice on a (T, ...) stacking buffer inside a scan writes
    only the slice, and a fused dynamic-slice reads only one step — counting
    whole buffers per trip overstates bytes by ~1000×.
    """
    if op.opcode == "fusion":
        callee = None
        if comps is not None:
            mc = _CALLS_RE.search(op.line)
            if mc:
                callee = comps.get(mc.group(1))
        return _fusion_bytes(op, comp, callee)
    ops_b = _operand_bytes_list(op, comp)
    if op.opcode == "dynamic-update-slice":
        upd = ops_b[1] if len(ops_b) > 1 else 0.0
        return 2.0 * upd
    if op.opcode == "dynamic-slice":
        return 2.0 * op.result_bytes
    total_in = sum(ops_b)
    if "output_to_operand_aliasing" in op.line and ops_b:
        aliased = max(ops_b)
        return max(total_in - aliased, 0.0) + max(op.result_bytes - aliased,
                                                  0.0)
    return total_in + op.result_bytes


def _dot_flops(op: Op, comp: Computation) -> float:
    # flops = 2 × prod(result dims) × prod(lhs contracting dims).
    # Operands are printed as bare names in compiled HLO — resolve the lhs
    # dims through the computation's symbol table.
    res_dims = _dims_of(op.result_seg)
    m = re.search(r"\sdot\(\s*(?:[a-z0-9]+\[[\d,]*\][^\s]*\s+)?%([\w\.\-]+)",
                  op.line)
    lhs_dims: list[int] = []
    if m:
        lhs_dims = comp.dims.get(m.group(1), [])
        if not lhs_dims:
            mt = re.search(r"dot\(\s*([a-z0-9]+\[[\d,]*\])", op.line)
            if mt:
                lhs_dims = _dims_of(mt.group(1))
    mc = _LHS_CONTRACT_RE.search(op.line)
    k = 1
    if mc and lhs_dims:
        for idx in mc.group(1).split(","):
            if idx:
                k *= lhs_dims[int(idx)]
    n = 1
    for d in res_dims:
        n *= d
    return 2.0 * n * k


def _participants(line: str, kind: str) -> int:
    mg = _GROUPS_RE.search(line)
    if mg:
        first = mg.group(1).split("}")[0]
        return max(len([t for t in first.split(",") if t.strip() != ""]), 1)
    mi = _GROUPS_IOTA_RE.search(line)
    if mi:
        return int(mi.group(2))
    if kind.startswith("collective-permute"):
        return 2
    return 1


def _collective_wire(op: Op) -> tuple[str, float, float]:
    kind = op.opcode.replace("-start", "")
    p = _participants(op.line, kind)
    out_b = op.result_bytes
    if op.opcode.endswith("-start"):
        # start ops return (operand, result) tuples: halve the estimate
        out_b = out_b / 2.0
    if p <= 1 and kind != "collective-permute":
        return kind, 0.0, 0.0
    if kind == "all-reduce":
        wire = 2.0 * (p - 1) / p * out_b
    elif kind == "all-gather":
        wire = (p - 1) / p * out_b
    elif kind == "reduce-scatter":
        wire = (p - 1) * out_b
    elif kind == "all-to-all":
        wire = (p - 1) / p * out_b
    else:
        wire = out_b
    return kind, wire, out_b


def _trip_count(cond_name: str, comps: dict) -> int:
    comp = comps.get(cond_name)
    if not comp:
        return 1
    best = 1
    for op in comp.ops:
        for mm in _CONST_INT_RE.finditer(op.line):
            best = max(best, int(mm.group(1)))
    return best


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_computations(hlo)
        self._memo: dict[tuple[str, bool], Stats] = {}
        self.entry = self._find_entry(hlo)

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
        if m:
            return m.group(1)
        # fallback: computation named 'main*'
        for name in self.comps:
            if name.startswith("main"):
                return name
        return next(iter(self.comps))

    def stats(self, comp_name: str | None = None,
              flops_only: bool = False) -> Stats:
        name = comp_name or self.entry
        key = (name, flops_only)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        s = Stats()
        self._memo[key] = s
        if comp is None:
            return s
        for op in comp.ops:
            if op.opcode == "dot":
                s.flops += _dot_flops(op, comp)
            if op.opcode == "fusion":
                mc = _CALLS_RE.search(op.line)
                if mc:
                    s.add_scaled(self.stats(mc.group(1), flops_only=True),
                                 1.0)
            elif op.opcode == "while":
                mb = _BODY_RE.search(op.line)
                mc = _COND_RE.search(op.line)
                trips = _trip_count(mc.group(1), self.comps) if mc else 1
                if mb:
                    s.add_scaled(self.stats(mb.group(1), flops_only),
                                 trips, flops_only)
            elif op.opcode == "conditional":
                mb = _BRANCHES_RE.search(op.line)
                if mb:
                    names = re.findall(r"[\w\.\-]+", mb.group(1))
                    for nm in names:
                        s.add_scaled(self.stats(nm, flops_only), 1.0,
                                     flops_only)
            elif op.opcode == "call":
                mc = re.search(r"to_apply=%?([\w\.\-]+)", op.line)
                if mc:
                    s.add_scaled(self.stats(mc.group(1), flops_only), 1.0,
                                 flops_only)

            if flops_only:
                continue
            if op.opcode in _FREE_OPS:
                continue
            if op.opcode in _COLLECTIVES:
                if op.opcode.endswith("-done"):
                    continue
                kind, wire, payload = _collective_wire(op)
                if _bf16_legalized(op, comp, self.comps):
                    wire *= 0.5
                    payload *= 0.5
                s.wire_bytes += wire
                s.payload_bytes += payload
                s.coll_count += 1
                d = s.by_kind.setdefault(kind,
                                         {"count": 0, "wire_bytes": 0.0})
                d["count"] += 1
                d["wire_bytes"] += wire
            s.bytes += _hbm_bytes(op, comp, self.comps)
        return s


def analyze(hlo: str) -> Stats:
    return Analyzer(hlo).stats()
