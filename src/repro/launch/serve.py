"""Batched serving drivers.

Two serving workloads share this entry point:

* ``--mode lm`` (default): decode loop with KV/recurrent caches.
  CPU-runnable on smoke configs; the same step function is what the
  decode_32k / long_500k dry-run cells lower for the production mesh.

      PYTHONPATH=src python -m repro.launch.serve --arch qwen3_32b --smoke \
          --batch 4 --prompt-len 16 --gen 32

* ``--mode kpca``: streaming incremental-KPCA ingest + transform service.
  Points arrive one at a time; each is folded into the eigendecomposition
  (Algorithm 2) and every ``--transform-every`` points a batch of queries
  is projected on the current principal components.  All dispatch policy
  is carried by one ``engine.UpdatePlan``: ``--dispatch bucketed`` runs
  early-stream updates at the active bucket's O(M_b³), not capacity O(M³)
  (the per-update latencies printed at the end show the staircase), and
  ``--tenants B`` serves B independent streams through the vmapped
  ``engine.StreamBatch`` — one device step folds a point into every
  tenant, instead of B Python-loop dispatches.  ``--cohorts bucket``
  shards a mixed-size cohort into bucket-homogeneous groups: each group
  runs its vmapped step at its OWN bucket M_b, so small tenants stop
  paying the largest tenant's O(M³).

      PYTHONPATH=src python -m repro.launch.serve --mode kpca \
          --capacity 512 --points 200 --dispatch bucketed
      PYTHONPATH=src python -m repro.launch.serve --mode kpca \
          --capacity 512 --points 200 --tenants 8 --dispatch bucketed

  ``--window W`` turns every stream (single and multi-tenant) into a
  sliding window over the trailing W points: ingest past a full window
  first evicts the oldest point through the decremental pipeline
  (``core/downdate.py``), so the service runs forever in bounded memory
  instead of exhausting capacity.

  Every ingest below — single-stream, windowed, guarded, metered, and
  their combinations — is one spelling of the composed
  ``engine.Engine.step``/``step_block`` pipeline: the plan flags select
  the gate/evict/note stages at trace time, so this driver never has to
  pick a ``*_guarded``/``*_metered`` variant by hand.

  ``--decouple`` switches to the double-buffered snapshot architecture
  (``core/serving``): ingest folds blocks into working state A while
  ``--query-rate`` query micro-batches per step read the last PUBLISHED
  immutable snapshot B, republished every ``--serve-every`` blocks with
  an O(1) buffer swap.  Queries never wait on the in-flight update —
  the decoupled p99 stays flat where the interleaved baseline's rides
  every update.  ``--mesh PtxPr`` tenant-shards the query path over a
  (tenant, data) 2-D device mesh (``core/distributed``).

      PYTHONPATH=src python -m repro.launch.serve --mode kpca --decouple \
          --capacity 512 --points 200 --tenants 8 --query-rate 2

* ``--mode nystrom``: streaming landmark-lifecycle service.  Points
  arrive one at a time as observed rows; ``--landmark-policy append``
  admits every point as a landmark until the budget fills (the paper's
  §4 loop), while ``--landmark-policy leverage`` admits on projection
  residual, replaces the lowest-leverage landmark when at budget, and
  stops admitting once the incremental ``trace_error`` trend plateaus
  (the sufficient-subset rule).

      PYTHONPATH=src python -m repro.launch.serve --mode nystrom \
          --capacity 128 --points 300 --landmark-policy leverage
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.data.synthetic import TokenStream
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def _make_plan(args):
    from repro.core import engine as eng

    health = None
    if getattr(args, "health", False):
        from repro.core import health as hl

        health = hl.DEFAULT_POLICY
    # Any export surface implies the in-graph metric lane; --metrics turns
    # it on without one (counters still land in the printed report).
    metrics = bool(getattr(args, "metrics", False)
                   or getattr(args, "metrics_jsonl", None)
                   or getattr(args, "metrics_port", None) is not None)
    return eng.UpdatePlan(matmul=args.matmul, dispatch=args.dispatch,
                          window=args.window,
                          landmark_policy=args.landmark_policy,
                          fuse_krow=args.fuse_krow,
                          serve_every=args.serve_every,
                          serve_components=args.serve_components,
                          health=health, metrics=metrics)


def _parse_mesh(text):
    """'PtxPr' -> (P_t, P_r), e.g. '2x1'; None passes through."""
    if not text:
        return None
    pt, _, pr = text.lower().partition("x")
    return int(pt), int(pr or 1)


def _export_metrics(args, hub) -> None:
    """Flush the hub out whatever export surface the flags asked for
    (the --metrics-port HTTP server is started in main() so it scrapes
    live during the run, not just after it)."""
    if getattr(args, "metrics_jsonl", None):
        hub.close_jsonl()   # stop live streaming before the final rewrite
        obs.write_jsonl(args.metrics_jsonl, hub)
        print(f"[obs] metrics -> {args.metrics_jsonl}")


def _update_rung(args, m: int):
    """Compile key of the next update dispatch: the active bucket rung
    (bucketed dispatch recompiles per rung; fixed compiles once)."""
    from repro.core import engine as eng

    if args.dispatch != "bucketed":
        return -1
    return eng.bucket_for(max(int(m), 1), args.capacity,
                          eng.DEFAULT_PLAN.min_bucket)


class IngestServeLoop:
    """Decoupled ingest/serve over a ``StreamBatch``: ingest folds blocks
    into the working state A while query micro-batches run against the
    last PUBLISHED immutable snapshot B (``core/serving``).

    Queries for a service step are issued BEFORE that step's ingest
    dispatch — they read only the published snapshot, so they have no
    data dependency on the in-flight update and never queue behind it;
    the interleaved baseline's transform, by contrast, consumes the
    just-updated state and eats the whole update latency in its p99.
    Every ``plan.serve_every`` ingested blocks the working state is
    republished (O(M·C + M·d), never the (M, M) eigenvectors) and the
    buffer swap is a host reference flip.  ``query_fn`` overrides the
    query executor — e.g. ``distributed.make_tenant_query`` on a
    (tenant, data) 2-D mesh shards the same stacked snapshot over the
    tenant axis with zero collectives.

    **Graceful degradation** (``plan.health``): every publication is
    gated on a vmapped probe pass over the working states — an unhealthy
    tenant first gets one heal-ladder attempt (``StreamBatch.heal``); if
    the cohort still fails the verdict the publication is REFUSED
    (``skipped`` counts it) and queries keep reading the last healthy
    snapshot, so a NaN-poisoned or drifting ingest path serves
    stale-but-correct answers instead of garbage generations.

    **Staleness-aware publication** (``publish_on_drift``): instead of a
    fixed ``serve_every`` cadence, republish when any tenant's working
    top-C spectrum has drifted (relative L2) past the threshold from the
    reference frozen at the last publication — the same probe pass
    produces the verdict AND the drift, so the check costs one fused
    dispatch.  ``serve_every`` then acts as the max-staleness fallback,
    and ``drift_probe_every`` rate-limits the probe itself: the drift
    dispatch fires every k-th non-publish ingest instead of every one
    (``drift_probes`` counts the dispatches that actually ran).

    Publish/heal/drift decisions are mirrored into a ``TelemetryHub``
    (``hub=``, default the process hub) and — when the plan carries the
    metric lane — into the batch's in-graph ``MetricsState``.
    """

    def __init__(self, batch, spec, *, plan=None, n_components=None,
                 query_fn=None, publish_on_drift=None,
                 drift_probe_every=1, hub=None):
        self.batch = batch
        self.spec = spec
        self.plan = plan if plan is not None else batch.plan
        self.serve_every = max(1, int(getattr(self.plan, "serve_every", 1)))
        self.n_components = n_components
        self._query_fn = query_fn
        self.policy = getattr(self.plan, "health", None)
        self.publish_on_drift = publish_on_drift
        self.drift_probe_every = max(1, int(drift_probe_every))
        self.hub = hub if hub is not None else obs.get_hub()
        self.skipped = 0           # publications refused on health
        self.heals = 0             # tenants sent down the heal ladder
        self.drift_publishes = 0   # publications triggered by drift
        self.drift_probes = 0      # drift probe dispatches actually run
        self.ref_lam = None        # (B, C) top spectrum at last publish
        self._last_drift = 0.0     # most recent probed max drift
        self._since_probe = 0
        self.snaps = batch.publish(n_components)
        self.generation = 0          # host mirror of snaps.generation
        self._since = 0
        self._record_ref()

    def _record_ref(self):
        """Freeze the published top-C spectrum as the drift reference."""
        if self.policy is None and self.publish_on_drift is None:
            return
        from repro.core import health as hl

        st = self.batch.working_states()[0]
        nc = int(self.n_components
                 if self.n_components is not None
                 else getattr(self.plan, "serve_components", 8))
        self.ref_lam = jax.vmap(lambda s: hl.top_spectrum(s, nc))(st)
        self._last_drift = 0.0
        self._since_probe = 0

    def query(self, q):
        """(B, nq, d) queries against the published snapshot; safe to call
        at any point relative to ingest — snapshots are immutable."""
        if self._query_fn is not None:
            return self._query_fn(self.snaps, q)
        from repro.core import serving

        return serving.query_batch(self.snaps, q, spec=self.spec,
                                   plan=self.plan)

    def publish(self):
        """Republish the working state: new snapshot, host-flip the
        buffer.  With a health policy the publication is gated on the
        probe verdict (heal once, then refuse — the previous snapshot
        keeps serving and ``skipped`` counts the refusal).  Returns the
        current (tenant-stacked) snapshot either way."""
        if self.policy is not None:
            from repro.core import health as hl

            healthy, _ = self.batch.probe_all()
            if not healthy.all():
                try:
                    n = self.batch.heal()
                    self.heals += n
                    self.hub.inc("heals_total", n)
                except hl.HealthError:
                    # Stored points corrupt: in-place healing impossible.
                    # Restore-from-checkpoint belongs to whoever owns the
                    # checkpoint directory — degrade to stale serving.
                    pass
                healthy, _ = self.batch.probe_all()
            if not healthy.all():
                self.skipped += 1
                self.hub.inc("skipped_publishes_total")
                self.hub.emit({"event": "skipped_publish",
                               "generation": self.generation})
                self.batch.note_skipped_publish()
                return self.snaps
        self.snaps = self.batch.publish(self.n_components)
        self.generation += 1
        self.hub.inc("publishes_total")
        self.hub.set_gauge("generation", self.generation)
        self.hub.emit({"event": "publish", "generation": self.generation,
                       "drift": self._last_drift})
        self._since = 0
        self._record_ref()
        return self.snaps

    def _drift_due(self) -> bool:
        """True when any tenant's spectrum has left the published one.

        The probe dispatch is rate-limited to every ``drift_probe_every``
        call; between probes the decision rides the cached drift (which a
        publish resets), so the steady non-publish path pays the fused
        probe once per k ingests instead of every step."""
        import numpy as np

        if self.publish_on_drift is None or self.ref_lam is None:
            return False
        self._since_probe += 1
        if self._since_probe < self.drift_probe_every:
            return self._last_drift > self.publish_on_drift
        self._since_probe = 0
        self.drift_probes += 1
        self.hub.inc("drift_probes_total")
        _, drift = self.batch.probe_all(ref_lam=self.ref_lam)
        self._last_drift = float(np.max(drift))
        self.hub.set_gauge("spectral_drift", self._last_drift)
        self.batch.note_drift(drift)   # per-tenant lane gauge
        return self._last_drift > self.publish_on_drift

    def _publish_due(self) -> bool:
        """Shared publish decision (the ``ingest`` path and the timed
        decoupled driver both use it): serve_every cadence first, else
        the rate-limited drift trigger."""
        cadence = self._since >= self.serve_every
        drifted = (not cadence) and self._drift_due()
        if drifted:
            self.drift_publishes += 1
            self.hub.inc("drift_publishes_total")
        return cadence or drifted

    def ingest(self, xs) -> bool:
        """Fold one (B, d) block into the working state; republish when
        the serve_every cadence — or, with ``publish_on_drift``, the
        spectral-drift trigger — says so.  True iff a publish happened."""
        self.batch.update(xs)
        self._since += 1
        if not self._publish_due():
            return False
        gen0 = self.generation
        self.publish()
        return self.generation != gen0

    def step(self, xs, queries=None):
        """One service step: queries first (against B), then ingest
        (into A).  Returns (query results or None, published flag)."""
        y = self.query(queries) if queries is not None else None
        return y, self.ingest(xs)


def kpca_main(args) -> dict:
    import numpy as np

    from repro.core import inkpca, kernels_fn as kf

    rng = np.random.default_rng(args.seed)
    d = args.dim
    x0 = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    stream = inkpca.KPCAStream(x0, args.capacity, spec, adjusted=True,
                               plan=_make_plan(args), dtype=jnp.float32)

    # Ingest and query phases are timed into SEPARATE hub histograms — a
    # single flattened latency list conflated update steps with transform
    # calls, and warm-up compiles (first call per bucket rung / component
    # count) polluted the percentiles.  Keyed first calls go to
    # *_compile_ms (obs.LatencyHistogram).
    hub = obs.fresh_hub()
    upd, qry = hub.histogram("update_ms"), hub.histogram("query_ms")
    n_served = 0
    n_heals = 0
    t_total = time.time()
    for i in range(args.points):
        x = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        rung = _update_rung(args, int(stream.kpca_state.m) + 1)
        with upd.timed(key=rung) as t:
            stream.update(x)
            st = stream.kpca_state
            t.sync(st.L)
        if (i + 1) % args.transform_every == 0:
            # Self-healing cadence rides the transform interval: one host
            # read of the in-graph probe verdict, heal ladder on failure.
            if args.health and not stream.is_healthy():
                stream.heal()
                n_heals += 1
                hub.inc("heals_total")
                st = stream.kpca_state
            q = jnp.asarray(rng.normal(size=(args.batch, d)), jnp.float32)
            n_comp = min(8, int(st.m))
            with qry.timed(key=n_comp) as t:
                t.sync(stream.transform(q, n_components=n_comp))
            n_served += args.batch
    t_total = time.time() - t_total

    st = stream.kpca_state
    result = {
        "mode": "kpca", "dispatch": args.dispatch, "capacity": args.capacity,
        "window": args.window,
        "points": args.points, "m_final": int(st.m),
        **upd.summary("update_ms"),
        **qry.summary("query_ms"),
        "transforms_served": n_served,
        "total_s": t_total,
        "finite": bool(jnp.isfinite(st.L).all()),
    }
    if args.health:
        result["heals"] = n_heals
        result["health"] = stream.health_report()
    if stream.metrics is not None:
        result["metrics"] = hub.observe_metrics_state(stream.metrics)
    _export_metrics(args, hub)
    print(f"[serve/kpca] {args.dispatch}: {args.points} updates to "
          f"m={result['m_final']} (capacity {args.capacity}, "
          f"window {args.window}), "
          f"update p50 {result['update_ms_p50']:.1f} ms, "
          f"query p50 {result['query_ms_p50']:.1f} ms  {result}")
    return result


def nystrom_main(args) -> dict:
    """Streaming Nyström landmark-lifecycle service (grow_rows mode)."""
    import numpy as np

    from repro.core import engine as eng, kernels_fn as kf, nystrom

    rng = np.random.default_rng(args.seed)
    d = args.dim
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    engine = eng.Engine(spec, _make_plan(args), adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    state = nystrom.init_nystrom(None, x0, args.capacity, spec,
                                 grow_rows=True)
    rule = nystrom.SufficientSubsetRule(rel_tol=args.stop_rel_tol,
                                        patience=args.stop_patience)
    budget = args.landmark_budget or args.capacity - 1
    hub = obs.fresh_hub()
    # Landmark lifecycle counted as one labelled family in the hub; the
    # result dict reads the counters back (single source of truth).
    admit = {k: hub.counter("landmark_total", action=k)
             for k in ("admitted", "rejected", "replaced")}
    ms = None
    if engine.plan.metrics:
        from repro.core import telemetry as tm

        ms = tm.init_metrics()
    n_quarantined = 0
    quarantine = (getattr(engine.plan, "health", None) is not None
                  and engine.plan.health.quarantine)
    stopped_at = None
    t_total = time.time()
    leverage = engine.plan.landmark_policy == "leverage"
    # Incremental trace_error: O(n·m) per admission instead of the
    # O(n·m²) exact recompute the stopping rule used to trigger.
    tracker = nystrom.TraceErrorTracker(state, spec) if leverage else None
    for i in range(args.points):
        x = jnp.asarray(rng.normal(size=(d,)), jnp.float32)
        if quarantine and not bool(jnp.isfinite(x).all()):
            # The observe_rows gate would drop the row anyway; counting
            # and skipping here keeps it out of the landmark offer too.
            n_quarantined += 1
            hub.inc("quarantined_total")
            continue
        res = None
        if leverage and not rule.sufficient:
            # ONE residual dispatch serves both the tracker's observe
            # increment and the admission gate below.  Once the rule has
            # stopped admissions the tracker freezes too — the stopped
            # regime pays zero per-point eigensystem dispatches.
            res = float(nystrom.admission_residual(state, x, spec))
            tracker.observe(state, x, residual=res)
        state = nystrom.observe_rows(state, x, spec, plan=engine.plan)
        if leverage and rule.sufficient:
            admit["rejected"].inc()
            continue
        prev = state
        state, action = engine.offer_landmark(state, x, budget=budget,
                                              residual=res)
        admit[action].inc()
        if leverage and action in ("admitted", "replaced"):
            if action == "admitted":
                tracker.admitted(prev, x)
            else:
                # Incremental leave-one-out swap delta: no exact resync
                # unless the delta itself is numerically untrustworthy.
                tracker.replaced(state, state_before=prev, x=x)
            tracker.maybe_resync(state)
            if ms is not None:
                from repro.core import telemetry as tm

                ms = tm.note_trace_error(ms, tracker.value)
            if rule.observe(tracker.value):
                stopped_at = i
    t_total = time.time() - t_total

    err = float(nystrom.trace_error(state, spec))
    hub.set_gauge("trace_error", err)
    hub.set_gauge("active_m", int(state.kpca.m))
    counts = {k: int(c.value) for k, c in admit.items()}
    result = {
        "mode": "nystrom", "policy": args.landmark_policy,
        "capacity": args.capacity, "budget": budget,
        "points": args.points, "m_final": int(state.kpca.m),
        "rows": int(state.Knm.shape[0]),
        "trace_error": err, "stopped_at": stopped_at,
        # Drift is only meaningful while the tracker was live: after the
        # stopping rule fires it freezes (rows keep arriving untracked).
        "tracker_drift": (abs(tracker.value - err)
                          if tracker and not rule.sufficient else None),
        "total_s": t_total,
        "finite": bool(jnp.isfinite(state.kpca.L).all()
                       and np.isfinite(err)),
        **counts,
    }
    if quarantine:
        result["quarantined"] = n_quarantined
    if ms is not None:
        hub.observe_metrics_state(ms, prefix="nystrom")
    _export_metrics(args, hub)
    print(f"[serve/nystrom] {args.landmark_policy}: {args.points} points, "
          f"{counts['admitted']} admitted / {counts['replaced']} replaced / "
          f"{counts['rejected']} rejected -> m={result['m_final']}, "
          f"trace err {err:.4f}, stopped_at={stopped_at}  {result}")
    return result


def kpca_multitenant_main(args) -> dict:
    """B independent tenant streams, one vmapped device step per point."""
    import numpy as np

    from repro.core import engine as eng, kernels_fn as kf

    rng = np.random.default_rng(args.seed)
    B, d = args.tenants, args.dim
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)), jnp.float32)
    batch = eng.StreamBatch(x0, args.capacity, spec, plan=_make_plan(args),
                            adjusted=True, dtype=jnp.float32,
                            cohorts=args.cohorts, window=args.window)

    # Ingest steps and transform calls are timed into separate hub
    # histograms (they used to share one flattened list — and transforms
    # were never timed at all), with warm-up compiles split out per
    # rung-set / component count.
    hub = obs.fresh_hub()
    upd, qry = hub.histogram("step_ms"), hub.histogram("query_ms")
    n_served = 0
    t_total = time.time()
    for i in range(args.points):
        xs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
        rungs = tuple(sorted({_update_rung(args, int(v) + 1)
                              for st in batch.working_states()
                              for v in np.atleast_1d(st.m)}))
        with upd.timed(key=rungs) as t:
            batch.update(xs)
            t.sync([st.L for st in batch.working_states()])
        if (i + 1) % args.transform_every == 0:
            q = jnp.asarray(rng.normal(size=(B, args.batch, d)), jnp.float32)
            n_comp = min(8, min(int(v) for st in batch.working_states()
                                for v in np.atleast_1d(st.m)))
            with qry.timed(key=n_comp) as t:
                t.sync(batch.transform(q, n_components=n_comp))
            n_served += B * args.batch
    t_total = time.time() - t_total

    m_final = [int(v) for v in np.asarray(batch.states.m)]
    steady = np.median(np.asarray(upd.ms)) if upd.ms else float("nan")
    result = {
        "mode": "kpca-multitenant", "tenants": B,
        "dispatch": args.dispatch, "cohorts": args.cohorts,
        "window": args.window,
        "capacity": args.capacity,
        "points": args.points, "m_final": m_final,
        **upd.summary("step_ms"),
        **qry.summary("query_ms"),
        "aggregate_updates_per_s": float(B / (steady / 1e3)),
        "transforms_served": n_served,
        "total_s": t_total,
        "finite": bool(jnp.isfinite(batch.states.L).all()),
    }
    if args.health:
        result["quarantined"] = batch.health_summary()["quarantined"]
    if batch.metrics is not None:
        report = hub.observe_metrics_state(batch.metrics)
        result["metrics"] = {k: (v.tolist() if hasattr(v, "tolist") else v)
                             for k, v in report.items()}
    _export_metrics(args, hub)
    print(f"[serve/kpca] {B} tenants x {args.points} updates to "
          f"m={m_final[0]} (capacity {args.capacity}), "
          f"step p50 {result['step_ms_p50']:.1f} ms = "
          f"{result['aggregate_updates_per_s']:.0f} updates/s aggregate, "
          f"query p50 {result['query_ms_p50']:.1f} ms  {result}")
    return result


def kpca_decoupled_main(args) -> dict:
    """Decoupled ingest/serve (``--decouple``): B tenant streams ingest
    into working state A while ``--query-rate`` query micro-batches per
    step run against the published snapshot B — the ``IngestServeLoop``.

    With ``--mesh PtxPr`` the query path runs tenant-sharded over a
    (tenant, data) 2-D mesh (``distributed.make_tenant_query``) when the
    host exposes P_t x P_r devices (XLA_FLAGS=--xla_force_host_platform_-
    device_count=N on CPU).  Reported query percentiles are measured
    UNDER concurrent ingest; publish (snapshot swap) cost is timed
    separately — see benchmarks/bench_serving.py for the controlled
    comparison against the interleaved baseline.
    """
    import numpy as np

    from repro.core import engine as eng, kernels_fn as kf

    rng = np.random.default_rng(args.seed)
    B, d = args.tenants, args.dim
    plan = _make_plan(args)
    spec = kf.KernelSpec(name="rbf", sigma=float(d))
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)), jnp.float32)
    batch = eng.StreamBatch(x0, args.capacity, spec, plan=plan,
                            adjusted=True, dtype=jnp.float32,
                            cohorts=args.cohorts, window=args.window)

    query_fn = None
    mesh_shape = _parse_mesh(args.mesh)
    if mesh_shape is not None:
        from repro.core import distributed as dist

        pt, pr = mesh_shape
        if len(jax.devices()) >= pt * pr and B % pt == 0:
            tmesh = dist.make_tenant_mesh(pt, pr)
            query_fn = dist.make_tenant_query(tmesh, spec, plan=plan)
        else:
            print(f"[serve/kpca-decoupled] --mesh {args.mesh} needs "
                  f"{pt * pr} devices (have {len(jax.devices())}) and "
                  f"P_t | tenants; falling back to local queries")

    hub = obs.fresh_hub()
    loop = IngestServeLoop(batch, spec, plan=plan, query_fn=query_fn,
                           publish_on_drift=args.publish_on_drift,
                           drift_probe_every=args.drift_probe_every,
                           hub=hub)
    ing, qry, pub = (hub.histogram("ingest_ms"), hub.histogram("query_ms"),
                     hub.histogram("publish_ms"))
    n_served = 0
    t_total = time.time()
    for i in range(args.points):
        xs = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)
        # Queries first: they read only the published snapshot, so they
        # never wait on this step's ingest.
        for _ in range(args.query_rate):
            q = jnp.asarray(rng.normal(size=(B, args.batch, d)), jnp.float32)
            with qry.timed(key=loop.generation == 0) as t:
                t.sync(loop.query(q))
            n_served += B * args.batch
        rungs = tuple(sorted({_update_rung(args, int(v) + 1)
                              for st in batch.working_states()
                              for v in np.atleast_1d(st.m)}))
        with ing.timed(key=rungs) as t:
            batch.update(xs)
            t.sync([st.L for st in batch.working_states()])
        loop._since += 1
        if loop._publish_due():
            with pub.timed(key=rungs) as t:
                t.sync(loop.publish().S)
    t_total = time.time() - t_total

    m_final = [int(v) for v in np.asarray(batch.states.m)]
    result = {
        "mode": "kpca-decoupled", "tenants": B,
        "dispatch": args.dispatch, "cohorts": args.cohorts,
        "capacity": args.capacity, "window": args.window,
        "mesh": args.mesh, "tenant_sharded_queries": query_fn is not None,
        "serve_every": args.serve_every,
        "query_rate": args.query_rate,
        "publish_on_drift": args.publish_on_drift,
        "points": args.points, "m_final": m_final,
        "generations": loop.generation,
        "drift_publishes": loop.drift_publishes,
        "drift_probes": loop.drift_probes,
        "skipped_publishes": loop.skipped,
        "heals": loop.heals,
        "quarantined": int(batch.quarantined.sum()),
        **ing.summary("ingest_ms"),
        **qry.summary("query_ms"),
        **pub.summary("publish_ms"),
        "queries_served": n_served,
        "total_s": t_total,
        "finite": bool(jnp.isfinite(batch.states.L).all()),
    }
    if batch.metrics is not None:
        report = hub.observe_metrics_state(batch.metrics)
        result["metrics"] = {k: (v.tolist() if hasattr(v, "tolist") else v)
                             for k, v in report.items()}
    _export_metrics(args, hub)
    print(f"[serve/kpca-decoupled] {B} tenants x {args.points} blocks "
          f"(publish every {args.serve_every}), "
          f"ingest p50 {result['ingest_ms_p50']:.1f} ms, "
          f"query p50 {result['query_ms_p50']:.2f} / "
          f"p99 {result['query_ms_p99']:.2f} ms under ingest, "
          f"publish p50 {result['publish_ms_p50']:.2f} ms  {result}")
    return result


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "kpca", "nystrom"),
                    default="lm")
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    # kpca-mode flags
    ap.add_argument("--capacity", type=int, default=512)
    ap.add_argument("--points", type=int, default=100)
    ap.add_argument("--dim", type=int, default=16)
    ap.add_argument("--dispatch", choices=("fixed", "bucketed"),
                    default="bucketed")
    ap.add_argument("--matmul", default="jnp",
                    choices=("jnp", "pallas", "jnp2", "pallas2"))
    ap.add_argument("--transform-every", type=int, default=16)
    ap.add_argument("--fuse-krow", action="store_true",
                    help="route ingest + batched transform through the "
                         "fused kernel-row producers (single dispatch "
                         "builds the kernel row and projects it; see "
                         "kernels/rbf_gram/krow_fused.py)")
    ap.add_argument("--tenants", type=int, default=1,
                    help="number of independent KPCA streams folded per "
                         "vmapped device step (kpca mode)")
    ap.add_argument("--cohorts", choices=("max", "bucket", "bucket-padded"),
                    default="max",
                    help="multi-tenant cohort geometry: 'max' runs the "
                         "whole cohort at the largest tenant's bucket; "
                         "'bucket' groups tenants by their own bucket; "
                         "'bucket-padded' additionally pads group sizes "
                         "to powers of two (bounded recompiles under "
                         "tenant churn)")
    ap.add_argument("--window", type=int, default=None,
                    help="sliding-window size W: evict the oldest point "
                         "before ingesting past a full window (kpca mode, "
                         "single and multi-tenant)")
    ap.add_argument("--decouple", action="store_true",
                    help="decoupled ingest/serve: queries run against the "
                         "last published immutable snapshot instead of "
                         "the working state (kpca mode, any tenant count)")
    ap.add_argument("--query-rate", type=int, default=1,
                    help="decoupled mode: query micro-batches (of --batch "
                         "points each, per tenant) issued per ingest step "
                         "against the published snapshot")
    ap.add_argument("--serve-every", type=int, default=1,
                    help="decoupled mode: republish the serving snapshot "
                         "every N ingested blocks")
    ap.add_argument("--serve-components", type=int, default=8,
                    help="components C frozen into published snapshots")
    ap.add_argument("--health", action="store_true",
                    help="attach the default health policy to the plan: "
                         "in-graph probes ride the update, non-finite "
                         "points are quarantined before the rank-one "
                         "pair fires, and unhealthy states go down the "
                         "heal ladder instead of being served")
    ap.add_argument("--metrics", action="store_true",
                    help="attach the in-graph metric lane (MetricsState) "
                         "to the plan: per-stream counters and gauges "
                         "ride the update pytree with zero extra host "
                         "syncs; implied by the export flags below")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve GET /metrics (Prometheus text format, "
                         "counters + gauges + phase-latency summaries) "
                         "from a daemon thread during the run; 0 picks "
                         "an ephemeral port")
    ap.add_argument("--metrics-jsonl", default=None, metavar="PATH",
                    help="append hub events during the run and write a "
                         "final full-registry scrape line to PATH "
                         "(one JSON object per line)")
    ap.add_argument("--drift-probe-every", type=int, default=4,
                    metavar="K",
                    help="decoupled mode: run the spectral-drift probe "
                         "dispatch every K-th non-publish ingest instead "
                         "of every one (--publish-on-drift)")
    ap.add_argument("--publish-on-drift", type=float, default=None,
                    metavar="THRESH",
                    help="decoupled mode: staleness-aware publication — "
                         "republish when any tenant's working top-C "
                         "spectrum drifts (relative L2) past THRESH from "
                         "the last published reference; --serve-every "
                         "then acts as the max-staleness fallback")
    ap.add_argument("--mesh", default=None, metavar="PtxPr",
                    help="decoupled mode: 2-D (tenant, data) mesh shape, "
                         "e.g. '2x1' — tenant-shards the query path over "
                         "P_t x P_r devices when the host exposes them")
    ap.add_argument("--landmark-policy", choices=("append", "leverage"),
                    default="append",
                    help="nystrom mode admission policy (see module "
                         "docstring)")
    ap.add_argument("--landmark-budget", type=int, default=None,
                    help="max landmarks (default capacity - 1)")
    ap.add_argument("--stop-rel-tol", type=float, default=1e-2,
                    help="sufficient-subset rule: relative improvement "
                         "below this counts as flat")
    ap.add_argument("--stop-patience", type=int, default=3,
                    help="sufficient-subset rule: consecutive flat "
                         "admissions before stopping")
    args = ap.parse_args(argv)

    if args.metrics_port is not None:
        # Start before the mode main so the run is scrapeable live; the
        # mains reset the same default-hub OBJECT (fresh_hub), so the
        # server keeps reading the active registry.  Daemon thread —
        # dies with the process.
        srv = obs.serve_metrics(obs.get_hub(), args.metrics_port)
        print(f"[obs] /metrics on :{srv.server_address[1]}")
    if args.metrics_jsonl:
        obs.get_hub().open_jsonl(args.metrics_jsonl)

    if args.mode == "nystrom":
        return nystrom_main(args)
    if args.mode == "kpca":
        if args.decouple:
            return kpca_decoupled_main(args)
        if args.tenants > 1:
            return kpca_multitenant_main(args)
        return kpca_main(args)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.gen

    with shd.use_mesh(mesh):
        params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
        serve_step = jax.jit(steps_lib.make_serve_step(cfg))

        stream = TokenStream(vocab=cfg.vocab, seq_len=args.prompt_len,
                             global_batch=args.batch, seed=args.seed)
        prompts = stream.batch_at(jnp.int32(0))["tokens"]

        caches = lm.init_caches(params, cfg, args.batch, max_seq)
        # Prefill: teacher-forced decode over the prompt (cache warm-up).
        t0 = time.time()
        tok = prompts[:, :1]
        for t in range(args.prompt_len):
            pos = jnp.full((args.batch, 1), t, jnp.int32)
            nxt, _, caches = serve_step(params, caches, prompts[:, t:t+1],
                                        pos)
        t_prefill = time.time() - t0

        # Decode: greedy continuation.
        generated = []
        tok = nxt
        t0 = time.time()
        for t in range(args.prompt_len, max_seq):
            pos = jnp.full((args.batch, 1), t, jnp.int32)
            tok, _, caches = serve_step(params, caches, tok, pos)
            generated.append(tok)
        t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    toks_per_s = args.batch * args.gen / max(t_decode, 1e-9)
    result = {"prefill_s": t_prefill, "decode_s": t_decode,
              "tokens_per_s": toks_per_s,
              "generated_shape": tuple(gen.shape),
              "finite": bool(jnp.isfinite(gen).all())}
    print(f"served {args.batch}x{args.gen} tokens: "
          f"{toks_per_s:.1f} tok/s (CPU smoke) {result}")
    return result


if __name__ == "__main__":
    main()
