"""Batched serving driver (decode loop with KV/recurrent caches).

CPU-runnable on smoke configs; the same step function is what the
decode_32k / long_500k dry-run cells lower for the production mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_32b --smoke \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.synthetic import TokenStream
from repro.distributed import sharding as shd
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import lm


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh()
    max_seq = args.prompt_len + args.gen

    with shd.use_mesh(mesh):
        params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
        serve_step = jax.jit(steps_lib.make_serve_step(cfg))

        stream = TokenStream(vocab=cfg.vocab, seq_len=args.prompt_len,
                             global_batch=args.batch, seed=args.seed)
        prompts = stream.batch_at(jnp.int32(0))["tokens"]

        caches = lm.init_caches(params, cfg, args.batch, max_seq)
        # Prefill: teacher-forced decode over the prompt (cache warm-up).
        t0 = time.time()
        tok = prompts[:, :1]
        for t in range(args.prompt_len):
            pos = jnp.full((args.batch, 1), t, jnp.int32)
            nxt, _, caches = serve_step(params, caches, prompts[:, t:t+1],
                                        pos)
        t_prefill = time.time() - t0

        # Decode: greedy continuation.
        generated = []
        tok = nxt
        t0 = time.time()
        for t in range(args.prompt_len, max_seq):
            pos = jnp.full((args.batch, 1), t, jnp.int32)
            tok, _, caches = serve_step(params, caches, tok, pos)
            generated.append(tok)
        t_decode = time.time() - t0

    gen = jnp.concatenate(generated, axis=1)
    toks_per_s = args.batch * args.gen / max(t_decode, 1e-9)
    result = {"prefill_s": t_prefill, "decode_s": t_decode,
              "tokens_per_s": toks_per_s,
              "generated_shape": tuple(gen.shape),
              "finite": bool(jnp.isfinite(gen).all())}
    print(f"served {args.batch}x{args.gen} tokens: "
          f"{toks_per_s:.1f} tok/s (CPU smoke) {result}")
    return result


if __name__ == "__main__":
    main()
