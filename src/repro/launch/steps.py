"""Train/serve step builders + sharding derivation for states and inputs.

Everything here is mesh-agnostic: steps close over the ArchConfig, and
shardings are derived from the logical rules installed by
``sharding.use_mesh`` — the same builders serve the 1-device smoke tests
and the 512-chip dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import lm
from repro.models.config import ArchConfig
from repro.optim import OptState, make_optimizer
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.optim.schedules import Schedule, ScheduleConfig, make_schedule

Array = jax.Array
PyTree = Any


class TrainState(NamedTuple):
    step: Array
    params: PyTree
    opt: OptState


def init_train_state(rng, cfg: ArchConfig, optimizer: Optimizer) -> TrainState:
    params = lm.init_params(rng, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=optimizer.init(params))


# ----------------------------------------------------------- train step ----
def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    schedule: Schedule, *, accum: int = 1,
                    clip: float = 1.0):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum`` > 1 runs gradient accumulation over microbatches via
    lax.scan (sequential, activation memory / accum).
    """

    def loss_fn(params, batch):
        return lm.loss_fn(params, cfg, batch)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: dict):
        if accum == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb):
                (l, m), g = grad_fn(state.params, mb)
                carry = jax.tree.map(jnp.add, carry, (l, g))
                return carry, m

            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params))
            (loss_sum, grads), ms = jax.lax.scan(acc_body, zero, micro)
            loss = loss_sum / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m[-1], ms)
            metrics["loss"] = loss

        grads, gnorm = clip_by_global_norm(grads, clip)
        lr = schedule(state.opt.step)
        params, opt = optimizer.update(grads, state.opt, state.params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return TrainState(step=state.step + 1, params=params, opt=opt), metrics

    return train_step


# ----------------------------------------------------------- serve step ----
def make_serve_step(cfg: ArchConfig, *, greedy: bool = True):
    """serve_step(params, caches, token (B,1), pos (B,1)) ->
    (next_token (B,1), logits, caches) — one decode iteration."""

    def serve_step(params, caches, token: Array, pos: Array):
        logits, caches = lm.decode_step(params, cfg, caches, token, pos)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(token.dtype)
        return nxt, logits, caches

    return serve_step


def make_prefill_step(cfg: ArchConfig):
    """prefill(params, tokens) -> logits — full-sequence forward (no cache
    materialization; used for prefill_32k lowering and perplexity eval)."""

    def prefill(params, batch):
        logits = lm.forward(params, cfg, batch["tokens"],
                            batch.get("embeddings"), remat=True)
        return logits

    return prefill


# ------------------------------------------------------------ shardings ----
def _spec_of(names: tuple, shape: tuple):
    return shd.named_sharding(names, shape)


def batch_shardings(batch_shapes: dict) -> dict:
    out = {}
    for k, v in batch_shapes.items():
        names = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = _spec_of(names, tuple(v.shape))
    return out


def param_sharding_tree(param_shapes: PyTree) -> PyTree:
    logical = lm.param_logical_specs(param_shapes)
    return jax.tree.map(
        lambda names, leaf: _spec_of(names, tuple(leaf.shape)),
        logical, param_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def _opt_leaf_sharding(names: tuple, pshape: tuple, leaf) -> Any:
    """Optimizer-state leaf sharding derived from its param's logical names.

    AdamW/SGDm moments mirror the param exactly; Adafactor's factored stats
    drop the last (vr) or second-to-last (vc) dim.
    """
    lshape = tuple(leaf.shape)
    if lshape == pshape:
        return _spec_of(names, lshape)
    if lshape == pshape[:-1]:                      # adafactor vr
        return _spec_of(names[:-1], lshape)
    if lshape == pshape[:-2] + pshape[-1:]:        # adafactor vc
        return _spec_of(names[:-2] + names[-1:], lshape)
    return _spec_of((None,) * len(lshape), lshape)


def state_shardings(state_shapes: TrainState) -> TrainState:
    """Shardings for a TrainState (from jax.eval_shape output)."""
    p_shard = param_sharding_tree(state_shapes.params)
    logical = lm.param_logical_specs(state_shapes.params)
    is_spec = lambda x: (isinstance(x, tuple) and all(       # noqa: E731
        isinstance(e, (str, type(None))) for e in x))
    flat_logical = jax.tree.leaves(logical, is_leaf=is_spec)
    flat_pshapes = jax.tree.leaves(state_shapes.params)
    ptreedef = jax.tree.structure(state_shapes.params)

    def per_param_tree(tree):
        """Map a pytree shaped like params (each param leaf replaced by an
        arbitrary subtree of moment arrays) to shardings."""
        flat_inner = ptreedef.flatten_up_to(tree)
        out = [jax.tree.map(
            lambda leaf: _opt_leaf_sharding(n, tuple(p.shape), leaf), sub)
            for n, p, sub in zip(flat_logical, flat_pshapes, flat_inner)]
        return ptreedef.unflatten(out)

    inner = state_shapes.opt.inner
    if isinstance(inner, dict) and set(inner) == {"mu", "nu"}:   # adamw
        inner_sh = {k: per_param_tree(v) for k, v in inner.items()}
    else:                                           # adafactor / sgdm
        inner_sh = per_param_tree(inner)

    return TrainState(
        step=_spec_of((), ()),
        params=p_shard,
        opt=OptState(step=_spec_of((), ()), inner=inner_sh))


_CACHE_RULES = {
    # full-attention KV cache: sequence-sharded over 'model'
    "k": ("layers", "batch", "seq_shard", None, None),
    "v": ("layers", "batch", "seq_shard", None, None),
    # nystrom cache
    "psi": ("layers", "batch", "kv_heads", None, None),
    "zeta": ("layers", "batch", "kv_heads", None),
    "beta": ("layers", "batch", None),
    "ginv": ("layers", None, None, None),
    # mamba
    "S": ("layers", "batch", "heads", None, None),
    "conv_buf": ("layers", "batch", None, "mlp"),
    # mlstm extras (S shared above), slstm
    "z": ("layers", "batch", "heads", None),
    "m": ("layers", "batch", None),
    "c": ("layers", "batch", "mlp"),
    "n": ("layers", "batch", "mlp"),
    "h": ("layers", "batch", "mlp"),
}


def cache_shardings(cache_shapes: PyTree) -> PyTree:
    def leaf_spec(path, leaf):
        name = None
        for k in reversed(path):
            key = getattr(k, "key", getattr(k, "name", None))
            if isinstance(key, str) and key in _CACHE_RULES:
                name = key
                break
        shape = tuple(leaf.shape)
        if name is None:
            return _spec_of((None,) * len(shape), shape)
        names = _CACHE_RULES[name][: len(shape)]
        if len(names) < len(shape):
            names = names + (None,) * (len(shape) - len(names))
        return _spec_of(names, shape)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_shapes)


# --------------------------------------------------------- convenience -----
def optimizer_for(arch_name: str) -> Optimizer:
    if "kimi" in arch_name:
        return make_optimizer("adafactor")
    return make_optimizer("adamw")


def schedule_for(arch_name: str, total: int = 10_000) -> Schedule:
    kind = "wsd" if "minicpm" in arch_name else "cosine"
    return make_schedule(ScheduleConfig(kind=kind, total=total))
