"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the jit is
lowered against ShapeDtypeStruct inputs (no allocation), compiled for the
production mesh, and the compiled artifact yields the §Roofline terms
(memory_analysis, cost_analysis, collective bytes from the HLO).

Usage:
    python -m repro.launch.dryrun --arch qwen3_32b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod
    python -m repro.launch.dryrun --all --both-meshes --out experiments/dryrun
"""
# The 512 placeholder devices MUST be configured before any other import —
# jax locks the device count on first initialization.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse        # noqa: E402
import dataclasses     # noqa: E402
import json            # noqa: E402
import time            # noqa: E402
import traceback       # noqa: E402
from functools import partial  # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro import configs                      # noqa: E402
from repro.data.synthetic import make_batch_specs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import hlo_parse, hlo_stats, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm                    # noqa: E402
from repro.models.config import active_param_count  # noqa: E402


def input_specs(cfg, shape_spec: dict) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if shape_spec["kind"] == "train":
        return make_batch_specs(cfg, shape_spec["global_batch"],
                                shape_spec["seq_len"])
    B = shape_spec["global_batch"]
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((B, 1), jnp.int32),
    }


def pick_accum(cfg, shape_spec: dict, mesh, target_bytes: float = 4e9) -> int:
    """Gradient-accumulation factor so per-device activations fit HBM."""
    if shape_spec["kind"] != "train":
        return 1
    data_ways = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(shape_spec["global_batch"] // data_ways, 1)
    # saved residual stream per scan step (bf16), × periods
    per = (b_loc * shape_spec["seq_len"] * cfg.d_model * 2
           * (cfg.n_layers // cfg.period))
    accum = 1
    while per / accum > target_bytes and accum < b_loc:
        accum *= 2
    return accum


def lower_cell(arch: str, shape: str, *, multi_pod: bool,
               nystrom: bool = False, accum: int | None = None,
               overrides: dict | None = None,
               rule_overrides: dict | None = None):
    cfg = configs.get_config(arch)
    if nystrom:
        cfg = dataclasses.replace(cfg, attention="nystrom")
    if overrides:
        moe_over = {k[4:]: v for k, v in overrides.items()
                    if k.startswith("moe_") and k != "moe_every"}
        overrides = {k: v for k, v in overrides.items()
                     if not (k.startswith("moe_") and k != "moe_every")}
        if moe_over and cfg.moe is not None:
            overrides["moe"] = dataclasses.replace(cfg.moe, **moe_over)
        cfg = dataclasses.replace(cfg, **overrides)
    shape_spec = configs.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    rng = jax.random.PRNGKey(0)

    rules = None
    if rule_overrides:
        rules = dict(shd.DEFAULT_RULES)
        rules.update(rule_overrides)

    with shd.use_mesh(mesh, rules=rules):
        if shape_spec["kind"] == "train":
            optimizer = steps.optimizer_for(arch)
            schedule = steps.schedule_for(arch)
            accum = accum or pick_accum(cfg, shape_spec, mesh)
            step_fn = steps.make_train_step(cfg, optimizer, schedule,
                                            accum=accum)
            state_shapes = jax.eval_shape(
                partial(steps.init_train_state, cfg=cfg,
                        optimizer=optimizer), rng)
            state_sh = steps.state_shardings(state_shapes)
            batch_specs = input_specs(cfg, shape_spec)
            batch_sh = steps.batch_shardings(batch_specs)
            jitted = jax.jit(step_fn,
                             in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_shapes, batch_specs)
            tokens = shape_spec["global_batch"] * shape_spec["seq_len"]
            model_flops = hlo_stats.model_flops_train(
                active_param_count(cfg), tokens)
        else:
            serve_fn = steps.make_serve_step(cfg)
            params_shapes = jax.eval_shape(
                partial(lm.init_params, cfg=cfg), rng)
            params_sh = steps.param_sharding_tree(params_shapes)
            B = shape_spec["global_batch"]
            cache_shapes = jax.eval_shape(
                partial(lm.init_caches, cfg=cfg, batch=B,
                        max_seq=shape_spec["seq_len"]), params_shapes)
            cache_sh = steps.cache_shardings(cache_shapes)
            io = input_specs(cfg, shape_spec)
            io_sh = {k: shd.named_sharding(("batch", None), tuple(v.shape))
                     for k, v in io.items()}
            jitted = jax.jit(serve_fn,
                             in_shardings=(params_sh, cache_sh,
                                           io_sh["token"], io_sh["pos"]),
                             out_shardings=(io_sh["token"], None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_shapes, cache_shapes,
                                   io["token"], io["pos"])
            model_flops = hlo_stats.model_flops_decode(
                active_param_count(cfg), shape_spec["global_batch"])
        accum_used = accum if shape_spec["kind"] == "train" else 1

    return lowered, {"arch": arch, "shape": shape, "chips": chips,
                     "mesh": "pod2x16x16" if multi_pod else "16x16",
                     "nystrom": nystrom, "accum": accum_used,
                     "model_flops": model_flops}


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: str,
             nystrom: bool = False, accum: int | None = None,
             hlo_dir: str | None = None, overrides: dict | None = None,
             rule_overrides: dict | None = None,
             tag_suffix: str = "") -> dict:
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape, multi_pod=multi_pod,
                               nystrom=nystrom, accum=accum,
                               overrides=overrides,
                               rule_overrides=rule_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = hlo_stats.memory_analysis_dict(compiled)
    xla_cost = hlo_stats.cost_analysis_terms(compiled, meta["chips"])
    hlo = compiled.as_text()
    # Trip-count-aware per-device accounting (hlo_parse), the roofline
    # source of truth; XLA cost_analysis retained as a cross-check (it
    # counts while bodies once).
    stats = hlo_parse.analyze(hlo)
    chips = meta["chips"]
    flops_global = stats.flops * chips
    bytes_global = stats.bytes * chips
    terms = hlo_stats.roofline_terms(flops_global, bytes_global,
                                     stats.wire_bytes, chips)
    result = {
        **meta,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem,
        "hlo_flops": flops_global,
        "hlo_bytes": bytes_global,
        "hlo_flops_per_device": stats.flops,
        "hlo_bytes_per_device": stats.bytes,
        "xla_cost_flops_1trip": xla_cost["hlo_flops"],
        "xla_cost_bytes_1trip": xla_cost["hlo_bytes"],
        "collective_wire_bytes": stats.wire_bytes,
        "collective_payload_bytes": stats.payload_bytes,
        "collective_by_kind": stats.by_kind,
        "collective_count": stats.coll_count,
        **terms,
        "useful_flops_ratio": (meta["model_flops"] / flops_global
                               if flops_global else 0.0),
    }
    tag = (f"{arch}_{shape}_{meta['mesh']}" + ("_nys" if nystrom else "")
           + tag_suffix)
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        with open(os.path.join(hlo_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=2)
    return result


def _fmt(r: dict) -> str:
    return (f"{r['arch']:22s} {r['shape']:11s} {r['mesh']:10s} "
            f"compile={r['compile_s']:7.1f}s "
            f"flops={r['hlo_flops']:.3e} "
            f"C/M/N={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
            f"{r['collective_s']:.2e} dom={r['dominant']:10s} "
            f"useful={r['useful_flops_ratio']:.2f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--nystrom", action="store_true",
                    help="force attention='nystrom' (long-context extra)")
    ap.add_argument("--set", action="append", default=[],
                    help="config overrides key=value (e.g. attn_impl=flash,"
                         " moe_impl=scatter); tagged into the output name")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding-rule overrides name=axis "
                         "(e.g. expert_cap=data)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--hlo-dir", default=None,
                    help="also dump compiled HLO text here")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = (configs.cells() if args.all
             else [(args.arch, args.shape, configs.SHAPES[args.shape],
                    False)])

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            pass
        overrides[k] = v
    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = v if v != "none" else None
    parts = [f"{k}={v}" for k, v in overrides.items()]
    parts += [f"r.{k}={v}" for k, v in rule_overrides.items()]
    suffix = args.tag or ("_" + "-".join(parts) if parts else "")

    failures = []
    for mp in meshes:
        for arch, shape, _, _ in cells:
            try:
                r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                             nystrom=args.nystrom, accum=args.accum,
                             hlo_dir=args.hlo_dir, overrides=overrides,
                             rule_overrides=rule_overrides,
                             tag_suffix=suffix)
                print(_fmt(r), flush=True)
                if args.verbose:
                    print(json.dumps(r["memory"], indent=2), flush=True)
            except Exception as e:      # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAIL {arch} {shape} multi_pod={mp}: {e}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")


if __name__ == "__main__":
    main()
