"""Roofline-term extraction from compiled dry-run artifacts.

``collective_bytes`` parses the (post-SPMD-partitioning) HLO text and sums
the per-device tensor bytes moved by every collective op.  Wire-byte
accounting per op kind (ring algorithms on P participants):

    all-reduce        2·(P-1)/P · bytes(out)      (reduce-scatter+all-gather)
    all-gather        (P-1)/P  · bytes(out)       (out is the gathered buf)
    reduce-scatter    (P-1)/P  · bytes(in)  ≈ (P-1) · bytes(out)
    all-to-all        (P-1)/P  · bytes(out)
    collective-permute  bytes(out)

P is read from the op's replica_groups. Roofline terms (v5e):

    compute    = HLO_FLOPs / (chips · 197e12)            [bf16 MXU]
    memory     = HLO_bytes / (chips · 819e9)
    collective = wire_bytes_per_device / 50e9            [ICI per link]
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link direction

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[8,128]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{(.*?)\}")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0              # per device, ring-model
    payload_bytes: float = 0.0           # raw tensor bytes per device
    by_kind: dict = field(default_factory=dict)
    count: int = 0

    def add(self, kind: str, wire: float, payload: float):
        self.wire_bytes += wire
        self.payload_bytes += payload
        k = self.by_kind.setdefault(kind, {"count": 0, "wire_bytes": 0.0})
        k["count"] += 1
        k["wire_bytes"] += wire
        self.count += 1


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        mo = _OP_RE.search(line)
        if not mo:
            continue
        if "-done(" in line:
            continue                      # count async pairs once (at start)
        dtype, dims, kind = mo.group(1), mo.group(2), mo.group(3)
        out_bytes = _shape_bytes(dtype, dims)

        p = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            p = len([t for t in mg.group(1).split(",") if t.strip() != ""])
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                p = int(mi.group(2))
            elif kind == "collective-permute":
                ms = _SRC_TGT_RE.search(line)
                p = 2 if ms else 1
        if p <= 1 and kind != "collective-permute":
            continue

        if kind == "all-reduce":
            wire = 2.0 * (p - 1) / p * out_bytes
        elif kind == "all-gather":
            wire = (p - 1) / p * out_bytes
        elif kind == "reduce-scatter":
            wire = (p - 1) * out_bytes
        elif kind == "all-to-all":
            wire = (p - 1) / p * out_bytes
        else:                              # collective-permute
            wire = out_bytes
        stats.add(kind, wire, out_bytes)
    return stats


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   chips: int) -> dict:
    """Three roofline terms in seconds (per-step / per-call)."""
    compute_s = flops / (chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (chips * HBM_BW)
    collective_s = wire_bytes / ICI_BW       # wire_bytes is already per-dev
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "roofline_s": max(compute_s, memory_s, collective_s),
    }


def cost_analysis_terms(compiled, chips: int) -> dict:
    """Pull flops/bytes from compiled.cost_analysis() (device-total)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"hlo_flops": flops, "hlo_bytes": byts}


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def model_flops_train(n_active_params: int, tokens: int) -> float:
    """6·N·D — the useful-flops yardstick for a train step."""
    return 6.0 * n_active_params * tokens


def model_flops_decode(n_active_params: int, tokens: int) -> float:
    """2·N per generated token (forward only)."""
    return 2.0 * n_active_params * tokens
