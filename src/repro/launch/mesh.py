"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — the dry-run must set XLA_FLAGS
*before* the first jax device query.

Topology (TPU v5e pods):
  single-pod: (data=16, model=16)            = 256 chips
  multi-pod:  (pod=2, data=16, model=16)     = 512 chips
The 'pod' axis carries pure data parallelism (gradient all-reduce, int8
compressed), 'data' carries FSDP + batch, 'model' carries TP/EP/sequence.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever-fits mesh for CPU smoke runs (1 device -> (1, 1))."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
