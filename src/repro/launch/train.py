"""End-to-end training driver.

CPU-runnable (smoke/reduced configs) and structured the way the 512-chip
launch would be: sharded state init under the mesh, step-indexed data (no
loader state), async atomic checkpoints + resume, straggler heartbeats,
optional streaming-KPCA spectral monitor on activations.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm_2b --smoke \
        --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.data.synthetic import TokenStream, frontend_embeddings
from repro.distributed import sharding as shd
from repro.distributed.straggler import HeartbeatMonitor
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.spectral import SpectralMonitor


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm_2b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--monitor-spectra", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(model=args.model_axis)
    optimizer = steps_lib.optimizer_for(args.arch)
    schedule = steps_lib.schedule_for(args.arch, total=args.steps)
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    monitor = SpectralMonitor(capacity=96) if args.monitor_spectra else None
    hb = HeartbeatMonitor(n_workers=1, timeout_s=300.0)
    ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    with shd.use_mesh(mesh):
        state_shapes = jax.eval_shape(
            partial(steps_lib.init_train_state, cfg=cfg, optimizer=optimizer),
            jax.random.PRNGKey(args.seed))
        state_sh = steps_lib.state_shardings(state_shapes)
        init_fn = jax.jit(partial(steps_lib.init_train_state, cfg=cfg,
                                  optimizer=optimizer),
                          out_shardings=state_sh)
        state = init_fn(jax.random.PRNGKey(args.seed))

        start = 0
        if args.resume and args.ckpt_dir:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                tgt = jax.tree.map(
                    lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                                      sharding=s),
                    state_shapes, state_sh)
                state = load_checkpoint(args.ckpt_dir, last, tgt)
                start = last
                print(f"resumed from step {last}")

        step_fn = jax.jit(
            steps_lib.make_train_step(cfg, optimizer, schedule,
                                      accum=args.accum),
            in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,))

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = frontend_embeddings(cfg, stream.batch_at(jnp.int32(step)))
            state, metrics = step_fn(state, batch)
            hb.beat(0, step)
            if monitor is not None and step % 20 == 0:
                h = lm.embed_tokens(state.params, cfg, batch["tokens"],
                                    batch.get("embeddings"))
                feats = jax.device_get(h.mean(axis=1))  # (B, d) pooled
                monitor.observe(feats)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                extra = ""
                if monitor is not None and monitor.history:
                    extra = (" eff_rank="
                             f"{monitor.history[-1]['effective_rank']:.1f}")
                print(f"step {step:5d} loss={loss:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f}"
                      f"{extra}", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        wall = time.time() - t0

    if ckpt:
        ckpt.close()
    result = {"first_loss": losses[0], "last_loss": losses[-1],
              "steps": args.steps, "wall_s": wall,
              "stragglers": hb.report()}
    print(f"done: {result}")
    return result


if __name__ == "__main__":
    main()
