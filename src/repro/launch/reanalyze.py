"""Re-derive roofline stats for saved dry-run artifacts from their dumped
HLO text (no recompilation) — used when the analyzer improves (e.g. the
bf16-legalization wire adjustment).

    python -m repro.launch.reanalyze --hlo-dir experiments/hlo \
        --out experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.launch import hlo_parse, hlo_stats


def reanalyze(hlo_path: str, json_path: str) -> dict | None:
    if not os.path.exists(json_path):
        return None
    with open(json_path) as f:
        result = json.load(f)
    with open(hlo_path) as f:
        stats = hlo_parse.analyze(f.read())
    chips = result["chips"]
    flops_global = stats.flops * chips
    bytes_global = stats.bytes * chips
    terms = hlo_stats.roofline_terms(flops_global, bytes_global,
                                     stats.wire_bytes, chips)
    result.update(
        hlo_flops=flops_global, hlo_bytes=bytes_global,
        hlo_flops_per_device=stats.flops, hlo_bytes_per_device=stats.bytes,
        collective_wire_bytes=stats.wire_bytes,
        collective_payload_bytes=stats.payload_bytes,
        collective_by_kind=stats.by_kind, collective_count=stats.coll_count,
        useful_flops_ratio=(result["model_flops"] / flops_global
                            if flops_global else 0.0),
        **terms)
    with open(json_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    for hlo_path in sorted(glob.glob(os.path.join(args.hlo_dir,
                                                  "*.hlo.txt"))):
        tag = os.path.basename(hlo_path)[: -len(".hlo.txt")]
        json_path = os.path.join(args.out, tag + ".json")
        r = reanalyze(hlo_path, json_path)
        if r:
            print(f"{tag:55s} C/M/N={r['compute_s']:.2e}/{r['memory_s']:.2e}"
                  f"/{r['collective_s']:.2e} dom={r['dominant']}")


if __name__ == "__main__":
    main()
