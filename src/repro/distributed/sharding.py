"""Logical-axis sharding rules (MaxText-style) + constraint helper.

Model code annotates tensors with *logical* dim names; the active rule set
maps them to mesh axes.  Rules are installed by the launcher for the chosen
mesh, so the same model code serves 1-device smoke tests (no rules -> no-op)
and the 512-chip production mesh.

Also exports ``shard_map``: a version-guarded dispatch to the JAX shard_map
API, which moved from ``jax.experimental.shard_map`` (kwarg ``check_rep``)
to top-level ``jax.shard_map`` (kwarg ``check_vma``).  All call sites in
this repo go through the wrapper so either JAX generation works.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _CHECK_KWARG = "check_vma"
else:  # older JAX: experimental API with the check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _CHECK_KWARG = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool | None = None):
    """Portable shard_map: maps ``check_vma`` onto this JAX's spelling."""
    kwargs = {}
    if check_vma is not None:
        kwargs[_CHECK_KWARG] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


DEFAULT_RULES: dict[str, tuple[str, ...] | str | None] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "model",        # sequence-parallel regions / decode KV cache
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",          # TP-EP: experts over 'model', FSDP over 'data'
    "expert_cap": None,
    "landmarks": None,
    # params at rest (FSDP dim + TP dim)
    "fsdp": "data",
    "tp": "model",
    "vocab_fsdp": "data",
    "vocab_tp": "model",         # embedding tables: vocab rows over TP axis
    "recurrent_in": "model",     # sLSTM r_in (overridden to None in §Perf)
    "recurrent_out": "data",
    # EP expert-bank layout: experts over the (pod×)data axis, d_ff over
    # model — fully sharded at rest, consumed in place by the shard_map
    # EP block (moe.py).
    "experts_data": ("pod", "data"),
    "expert_ff": "model",
    "layers": None,
    "conv": None,
    "state": None,
}


def set_rules(rules: dict | None) -> None:
    _state.rules = rules


def get_rules() -> dict | None:
    return getattr(_state, "rules", None)


def set_mesh(mesh: Mesh | None) -> None:
    _state.mesh = mesh


def get_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    """Install mesh + rules for model tracing; restores previous on exit."""
    prev_mesh, prev_rules = get_mesh(), get_rules()
    set_mesh(mesh)
    rules = dict(DEFAULT_RULES) if rules is None else rules
    # Drop axes the mesh doesn't have (e.g. 'pod' on the single-pod mesh).
    axes = set(mesh.axis_names)

    def _filter(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in axes else None
        vv = tuple(a for a in v if a in axes)
        return vv if vv else None

    set_rules({k: _filter(v) for k, v in rules.items()})
    try:
        yield
    finally:
        set_mesh(prev_mesh)
        set_rules(prev_rules)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def logical_to_spec(names: tuple[str | None, ...],
                    shape: tuple[int, ...] | None = None) -> P:
    """Map logical dim names to mesh axes; with ``shape`` given, axes that do
    not evenly divide the dim are dropped (e.g. 36 heads on a 16-way axis,
    or a prime vocab) — the tensor falls back to replication on that dim."""
    rules = get_rules() or {}
    mesh = get_mesh()
    out = []
    used: set[str] = set()
    for i, n in enumerate(names):
        axes = rules.get(n) if n else None
        if axes is not None and shape is not None and mesh is not None:
            if shape[i] % _axis_size(mesh, axes) != 0:
                axes = None
        # a mesh axis may shard at most one dim: first dim wins
        if axes is not None:
            alist = (axes,) if isinstance(axes, str) else tuple(axes)
            alist = tuple(a for a in alist if a not in used)
            used.update(alist)
            axes = alist if alist else None
            if axes is not None and shape is not None and mesh is not None:
                if shape[i] % _axis_size(mesh, axes) != 0:
                    axes = None
        # normalize 1-tuples to the bare axis name: older PartitionSpec
        # compares ('model',) != 'model'
        if isinstance(axes, tuple) and len(axes) == 1:
            axes = axes[0]
        out.append(axes)
    return P(*out)


def constrain(x: jax.Array, names: tuple[str | None, ...]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = get_mesh()
    rules = get_rules()
    if mesh is None or rules is None:
        return x
    spec = logical_to_spec(names, tuple(x.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(names: tuple[str | None, ...],
                   shape: tuple[int, ...] | None = None
                   ) -> NamedSharding | None:
    mesh = get_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_to_spec(names, shape))
