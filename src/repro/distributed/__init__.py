from repro.distributed.sharding import use_mesh, constrain, DEFAULT_RULES
from repro.distributed.straggler import HeartbeatMonitor, StepTimer
from repro.distributed.pipeline import pipeline_apply, make_stage_fn
