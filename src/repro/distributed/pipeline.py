"""GPipe-style pipeline parallelism over a mesh axis (SPMD formulation).

The stage dimension is a mesh axis (deployment plan: the 'pod' axis, so
inter-stage hops ride the sparse inter-pod links exactly once per
microbatch). All devices run the same program; at schedule step t, stage s
works on microbatch (t - s). Activations move stage→stage+1 with a single
``collective_permute`` per step — the only inter-stage communication.

Bubble fraction is the usual (S-1)/(M+S-1); pick microbatches >> stages.

``pipeline_apply`` is deliberately fn-agnostic: ``stage_fn(params, x)`` is
any per-stage computation (e.g. a slice of transformer periods), and
``stage_params`` carries a leading stage dimension sharded over the stage
axis by the caller (shard_map slices it).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array


def pipeline_apply(stage_fn: Callable[[Any, Array], Array],
                   stage_params: Any, x: Array, *, mesh: Mesh,
                   axis: str = "pod", microbatches: int | None = None
                   ) -> Array:
    """Run ``x`` through S pipeline stages laid out on mesh axis ``axis``.

    stage_params: pytree with leading dim S on every leaf.
    x: (B, ...) global batch; split into ``microbatches`` (default S).
    Returns stage_{S-1} ∘ ... ∘ stage_0 applied per microbatch.
    """
    S = mesh.shape[axis]
    M = microbatches or S
    B = x.shape[0]
    assert B % M == 0, (B, M)
    mb = B // M

    def body(params, x_local):
        # x_local: (B, ...) replicated over the stage axis inside shard_map;
        # params: this stage's slice (leading dim 1).
        p_stage = jax.tree.map(lambda l: l[0], params)
        sid = jax.lax.axis_index(axis)
        xs = x_local.reshape((M, mb) + x_local.shape[1:])

        n_steps = M + S - 1
        perm = [(i, i + 1) for i in range(S - 1)]

        def step(carry, t):
            buf, outs = carry                      # (mb, ...), (M, mb, ...)
            # stage 0 injects microbatch t (clamped; masked later)
            inj = xs[jnp.minimum(t, M - 1)]
            cur = jnp.where(sid == 0, inj, buf)
            y = stage_fn(p_stage, cur)
            # last stage collects microbatch (t - S + 1)
            out_idx = jnp.clip(t - (S - 1), 0, M - 1)
            valid = (t >= S - 1) & (sid == S - 1)
            upd = jnp.where(valid, y, outs[out_idx])
            outs = jax.lax.dynamic_update_index_in_dim(outs, upd, out_idx,
                                                       axis=0)
            nxt = jax.lax.ppermute(y, axis, perm) if S > 1 else y
            return (nxt, outs), None

        buf0 = jnp.zeros_like(xs[0])
        outs0 = jnp.zeros((M, mb) + x_local.shape[1:], x_local.dtype)
        (_, outs), _ = jax.lax.scan(step, (buf0, outs0),
                                    jnp.arange(n_steps))
        # results live on the last stage; broadcast to every stage so the
        # out_spec can be replicated over the stage axis.
        outs = jax.lax.psum(
            jnp.where(sid == S - 1, outs, jnp.zeros_like(outs)), axis)
        return outs.reshape((B,) + x_local.shape[1:])

    other = [a for a in mesh.axis_names if a != axis]
    in_specs = (jax.tree.map(lambda _: P(axis), stage_params,
                             is_leaf=lambda l: hasattr(l, "shape")),
                P())
    from repro.distributed.sharding import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_vma=False)
    del other
    return fn(stage_params, x)


def make_stage_fn(block_fn: Callable, n_blocks_per_stage: int):
    """Compose ``n_blocks_per_stage`` applications of block_fn into one
    pipeline stage (params leading dim = blocks within the stage)."""

    def stage_fn(params, x):
        def inner(x, p):
            return block_fn(p, x), None
        y, _ = jax.lax.scan(inner, x, params)
        return y

    return stage_fn
