"""Straggler / failure detection for the training loop.

On a real multi-pod deployment every host runs the same SPMD program; a
straggling or dead host manifests as a stalled collective.  The standard
mitigation layer (used here) is host-side:

* ``HeartbeatMonitor`` — each worker beats (worker_id, step, t); the
  monitor flags workers whose last beat is older than ``timeout_s`` or
  more than ``max_step_lag`` steps behind the median.  The launcher policy
  on a flagged worker is drop-and-restart from the latest atomic
  checkpoint with the elastic reshard loader (checkpoint/npz_store.py) on
  the surviving mesh — in this container the policy decision is what we
  exercise (see tests), the actual re-exec is the cluster manager's job.
* ``StepTimer`` — per-step wall-time EWMA + spike detection, the cheap
  in-process signal that *this* host is the straggler (e.g. thermal
  throttling), used to trigger voluntary pre-emption before the
  collective timeout fires.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout_s: float = 60.0
    max_step_lag: int = 10
    _last: dict = field(default_factory=dict)   # worker -> (step, t)

    def beat(self, worker: int, step: int, t: float | None = None) -> None:
        self._last[worker] = (step, time.time() if t is None else t)

    def flagged(self, now: float | None = None) -> list[dict]:
        now = time.time() if now is None else now
        if not self._last:
            return []
        steps = sorted(s for s, _ in self._last.values())
        median = steps[len(steps) // 2]
        out = []
        for w in range(self.n_workers):
            if w not in self._last:
                out.append({"worker": w, "reason": "never-beat"})
                continue
            step, t = self._last[w]
            if now - t > self.timeout_s:
                out.append({"worker": w, "reason": "timeout",
                            "stale_s": now - t})
            elif median - step > self.max_step_lag:
                out.append({"worker": w, "reason": "lagging",
                            "lag": median - step})
        return out

    def healthy(self, now: float | None = None) -> bool:
        return not self.flagged(now)

    def report(self) -> dict:
        return {"workers": self.n_workers, "flagged": self.flagged()}


@dataclass
class StepTimer:
    alpha: float = 0.1
    spike_factor: float = 3.0
    ewma: float | None = None
    spikes: int = 0
    _t0: float | None = None

    def start(self) -> None:
        self._t0 = time.time()

    def stop(self) -> float:
        dt = time.time() - self._t0
        if self.ewma is None:
            self.ewma = dt
        else:
            if dt > self.spike_factor * self.ewma:
                self.spikes += 1
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return dt
