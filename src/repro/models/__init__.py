from repro.models.config import ArchConfig, MoEConfig, param_count, \
    active_param_count
