"""Mixture-of-Experts FFN with three dispatch implementations.

* ``impl='einsum'``  — GShard-style one-hot dispatch/combine einsums with a
  per-expert capacity.  Robust and GSPMD-friendly, but dispatch flops scale
  as O(T * E*C * d) ≈ O(top_k * T^2 * d / tokens-per-expert) — visible as
  HLO_FLOPs above MODEL_FLOPS in the roofline table for large E (kimi
  baseline: useful ratio 0.05).
* ``impl='scatter'`` — position-computed scatter/gather dispatch under
  GSPMD: kills the dispatch flops but GSPMD partitions the scatters
  pathologically (§Perf kimi iteration 2: collective term 337 s → 2480 s).
  Kept as the measured negative result.
* ``impl='ep'``      — the §Perf winner: an explicit shard_map expert-
  parallel block. Local scatter dispatch (bytes, no GSPMD choice),
  all-to-all over the 'data' axis to the expert owners, expert FFN
  TP-sharded over 'model' (weights E→data, d_ff→model: FULLY sharded, no
  FSDP all-gather of the 2 TB expert bank), one psum over 'model', and the
  reverse all-to-all. Falls back to 'einsum' when the mesh lacks the axes
  (CPU smoke tests exercise it on a (1,1) mesh).

All compute experts as block-diagonal grouped matmuls and drop overflow
tokens beyond capacity (standard GShard semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig
from repro.models.layers import dense_init

Array = jax.Array


def moe_init(rng, cfg: ArchConfig) -> dict:
    mo = cfg.moe
    d, f = cfg.d_model, mo.d_ff_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, mo.n_experts), dtype=jnp.float32),
        "w_up": dense_init(ks[1], (mo.n_experts, d, f), dtype=dt),
        "w_gate": dense_init(ks[2], (mo.n_experts, d, f), dtype=dt),
        "w_down": dense_init(ks[3], (mo.n_experts, f, d), dtype=dt),
    }
    if mo.n_shared_experts:
        fs = f * mo.n_shared_experts
        p["shared"] = {
            "w_up": dense_init(ks[4], (d, fs), dtype=dt),
            "w_gate": dense_init(jax.random.fold_in(ks[4], 1), (d, fs), dtype=dt),
            "w_down": dense_init(jax.random.fold_in(ks[4], 2), (fs, d), dtype=dt),
        }
    return p


def _router(p: dict, cfg: ArchConfig, x2d: Array):
    mo = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mo.top_k)           # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return gate_vals, idx, probs


def _capacity(cfg: ArchConfig, T: int) -> int:
    mo = cfg.moe
    c = int(T * mo.top_k / mo.n_experts * mo.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _experts_ffn(p: dict, cfg: ArchConfig, xe: Array) -> Array:
    """xe: (E, C, d) -> (E, C, d) block-diagonal grouped matmuls."""
    xe = shd.constrain(xe, ("experts", "expert_cap", None))
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h = jax.nn.silu(g) * h
    h = shd.constrain(h, ("experts", "expert_cap", None))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _causal_positions(onehot: Array, counts0: Array | None = None
                      ) -> tuple[Array, Array]:
    """Per-(group, expert) capacity-slot positions, causal within each group.

    onehot: (G, S, K, E) int32 assignment one-hots.  The slot position of
    each assignment counts earlier assignments of the SAME group only,
    token-major then k-major — so the drop decision for token (g, s)
    depends exclusively on tokens (g, <= s), and a decode loop can
    reproduce it exactly from a running per-expert count (``counts0``, the
    counts carried in from previous tokens of the same sequence).

    Returns (pos (G, S, K), counts_end (G, E)).  Counts include dropped
    assignments — the parallel cumsum does too, so parity holds after
    capacity is exceeded.
    """
    G, S, K, E = onehot.shape
    flat = onehot.reshape(G, S * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1).reshape(G, S, K, E) - 1
    if counts0 is not None:
        pos_in_e = pos_in_e + counts0[:, None, None, :]
    pos = jnp.sum(pos_in_e * onehot, axis=-1)
    counts_end = jnp.sum(flat, axis=1)
    if counts0 is not None:
        counts_end = counts_end + counts0
    return pos, counts_end


def _moe_einsum(p: dict, cfg: ArchConfig, x3d: Array) -> Array:
    """GShard one-hot dispatch over (G, S, d): G groups (batch rows), each
    with its own capacity C = _capacity(cfg, S) and causal slot positions
    (see ``_causal_positions`` — this is what makes decode reproducible)."""
    mo = cfg.moe
    G, S, d = x3d.shape
    E, K = mo.n_experts, mo.top_k
    C = _capacity(cfg, S)
    gate_vals, idx, _ = _router(p, cfg, x3d.reshape(G * S, d))
    gate_vals = gate_vals.reshape(G, S, K)

    onehot = jax.nn.one_hot(idx.reshape(G, S, K), E,
                            dtype=jnp.int32)                  # (G, S, K, E)
    pos, _ = _causal_positions(onehot)
    keep = pos < C
    # dispatch tensor (G, S, E, C): combines expert one-hot and slot.
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=x3d.dtype)[..., :C]           # (G, S, K, C)
    oh = onehot.astype(x3d.dtype)
    disp = jnp.einsum("gske,gskc->gsec", oh, slot)
    comb = jnp.einsum("gsk,gske,gskc->gsec",
                      gate_vals.astype(x3d.dtype), oh, slot)
    xe = jnp.einsum("gsd,gsec->egcd", x3d, disp)              # (E, G, C, d)
    ye = _experts_ffn(p, cfg, xe.reshape(E, G * C, d))
    ye = ye.reshape(E, G, C, d)
    return jnp.einsum("egcd,gsec->gsd", ye, comb)


def _moe_scatter(p: dict, cfg: ArchConfig, x3d: Array) -> Array:
    """Scatter/gather dispatch over (G, S, d) with the same per-group
    causal slot positions as ``_moe_einsum`` (identical keep sets)."""
    mo = cfg.moe
    G, S, d = x3d.shape
    E, K = mo.n_experts, mo.top_k
    C = _capacity(cfg, S)
    gate_vals, idx, _ = _router(p, cfg, x3d.reshape(G * S, d))

    flat_e = idx.reshape(G, S * K)                             # (G, SK)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (G, SK, E)
    pos = jnp.sum(onehot * (jnp.cumsum(onehot, axis=1) - 1), axis=-1)
    keep = pos < C                                             # (G, SK)
    pos_c = jnp.where(keep, pos, C - 1)
    g_idx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, S * K))
    tok_idx = jnp.broadcast_to(jnp.repeat(jnp.arange(S), K)[None],
                               (G, S * K))

    # Scatter tokens into (E, G, C, d) — bytes, not matmul flops.
    xe = jnp.zeros((E, G, C, d), x3d.dtype)
    upd = x3d[g_idx, tok_idx] * keep[..., None].astype(x3d.dtype)
    xe = xe.at[flat_e, g_idx, pos_c].add(upd)

    ye = _experts_ffn(p, cfg, xe.reshape(E, G * C, d))
    ye = ye.reshape(E, G, C, d)

    # Gather back and combine with gate weights.
    out_tk = ye[flat_e, g_idx, pos_c] * keep[..., None].astype(x3d.dtype)
    out_tk = out_tk * gate_vals.reshape(G, S * K, 1).astype(x3d.dtype)
    return jnp.zeros((G, S, d), x3d.dtype).at[g_idx, tok_idx].add(out_tk)


# ------------------------------------------------- explicit EP (shard_map) --
def _ep_local(x_loc: Array, router_w: Array, w_up: Array, w_gate: Array,
              w_down: Array, cfg: ArchConfig, *, axis_data, axis_model,
              n_data: int, n_model: int) -> Array:
    """Per-device body under shard_map (sequence-parallel EP + TP experts).

    x_loc: (T_loc, d) — a DISTINCT token slice per device (tokens split
           over data AND model: §Perf kimi iteration 4 — replicating the
           dispatch over 'model' cost a 16× larger all-to-all).
    w_*:   (E_loc, d, f_loc) / (E_loc, f_loc, d) — experts over 'data',
           d_ff over 'model'.

    Wire per device per call: 2 all-to-alls of (E, C, d) + one model-axis
    all-gather and one psum-scatter of the owner-row buffer — all sized by
    the actual dispatched tokens (T_loc·K·d·cf), never by the expert bank.
    """
    mo = cfg.moe
    T_loc, d = x_loc.shape
    E, K = mo.n_experts, mo.top_k
    E_loc = w_up.shape[0]
    C = _capacity(cfg, T_loc)

    gate_vals, idx, _ = _router({"router": router_w}, cfg, x_loc)

    # --- local scatter dispatch into (E, C, d): bytes, not matmul flops ---
    flat_e = idx.reshape(T_loc * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum(onehot * (jnp.cumsum(onehot, axis=0) - 1), axis=-1)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)
    tok_idx = jnp.repeat(jnp.arange(T_loc), K)
    upd = x_loc[tok_idx] * keep[:, None].astype(x_loc.dtype)
    buf = jnp.zeros((E, C, d), x_loc.dtype).at[flat_e, pos_c].add(upd)

    # --- all-to-all over 'data': expert rows -> their owners --------------
    buf = buf.reshape(n_data, E_loc, C, d)
    recv = jax.lax.all_to_all(buf, axis_data, split_axis=0, concat_axis=0,
                              tiled=False)          # (n_data, E_loc, C, d)
    xe = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_data * C, d)

    # --- owner row: gather the 16 model columns' token sets, TP the FFN ---
    if n_model > 1:
        xe = jax.lax.all_gather(xe, axis_model, axis=1, tiled=True)
    # xe: (E_loc, n_model*n_data*C, d); each column computes its f_loc slice
    h = jnp.einsum("ecd,edf->ecf", xe, w_up)
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)      # PARTIAL over 'model'
    if n_model > 1:
        # reduce over 'model' AND return each column its own token slice
        ye = jax.lax.psum_scatter(ye, axis_model, scatter_dimension=1,
                                  tiled=True)       # (E_loc, n_data*C, d)

    # --- reverse all-to-all + local combine --------------------------------
    ye = jnp.moveaxis(ye.reshape(E_loc, n_data, C, d), 1, 0)
    back = jax.lax.all_to_all(ye, axis_data, split_axis=0, concat_axis=0,
                              tiled=False)          # (n_data, E_loc, C, d)
    ye_loc = back.reshape(E, C, d)
    out_tk = ye_loc[flat_e, pos_c] * keep[:, None].astype(x_loc.dtype)
    out_tk = out_tk * gate_vals.reshape(T_loc * K, 1).astype(x_loc.dtype)
    return jnp.zeros((T_loc, d), x_loc.dtype).at[tok_idx].add(out_tk)


def _ep_decode_local(x_all: Array, router_w: Array, w_up: Array,
                     w_gate: Array, w_down: Array, cfg: ArchConfig, *,
                     axis_data, axis_model) -> Array:
    """Decode-time EP body: tokens REPLICATED (few at decode), experts
    sharded. Each device runs its local experts over every token, masked
    by the routing gates; one psum over (data, model) assembles the
    result. No dispatch, no all-to-all — wire cost is one (T, d) psum.
    """
    mo = cfg.moe
    T, d = x_all.shape
    E = mo.n_experts
    E_loc = w_up.shape[0]
    didx = jax.lax.axis_index(axis_data)

    gate_vals, idx, _ = _router({"router": router_w}, cfg, x_all)
    dense_gates = jnp.sum(
        jax.nn.one_hot(idx, E, dtype=x_all.dtype)
        * gate_vals[..., None].astype(x_all.dtype), axis=1)     # (T, E)
    my_gates = jax.lax.dynamic_slice_in_dim(dense_gates, didx * E_loc,
                                            E_loc, axis=1)      # (T, E_loc)

    h = jnp.einsum("td,edf->etf", x_all, w_up)
    g = jnp.einsum("td,edf->etf", x_all, w_gate)
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * h, w_down)
    y = jnp.einsum("etd,te->td", ye, my_gates)      # partial: local experts
    return jax.lax.psum(y, axis_data + (axis_model,))


# tokens-per-call threshold below which the replicated decode path wins
_EP_DECODE_MAX_TOKENS = 4096


def _moe_ep(p: dict, cfg: ArchConfig, x: Array) -> Array | None:
    """x: (B, T, d). Returns None when the mesh/shapes can't EP.

    The shard_map consumes the NATURAL activation layout — batch over
    'data', seq over 'model' (sequence parallelism) — so entering the
    region is a local slice.  (Fusing (B·T) rows and resharding instead
    triggered GSPMD's 'involuntary full rematerialization' path: the whole
    activation was replicated per layer; §Perf kimi iteration 4.)
    """
    mesh = shd.get_mesh()
    axes = set(mesh.axis_names)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1) if "model" in axes else 1
    B, T, d = x.shape
    if cfg.moe.n_experts % max(n_data, 1):
        return None                                 # mesh can't EP

    # Decode / tiny-token path: replicated tokens, local-expert compute.
    if B * T <= _EP_DECODE_MAX_TOKENS or B % max(n_data, 1):
        body = partial(_ep_decode_local, cfg=cfg, axis_data=data_axes,
                       axis_model="model")

        def body3d(x_rep, router_w, w_up, w_gate, w_down):
            return body(x_rep.reshape(B * T, d), router_w, w_up, w_gate,
                        w_down).reshape(B, T, d)

        fn = shd.shard_map(
            body3d, mesh=mesh,
            in_specs=(P(None, None, None),          # x replicated
                      P(),
                      P(data_axes, None, "model"),
                      P(data_axes, None, "model"),
                      P(data_axes, "model", None)),
            out_specs=P(None, None, None),
            check_vma=False)
        return fn(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])

    def body(x_loc, router_w, w_up, w_gate, w_down):
        Bl, Tl, _ = x_loc.shape
        y = _ep_local(x_loc.reshape(Bl * Tl, d), router_w, w_up, w_gate,
                      w_down, cfg, axis_data=data_axes, axis_model="model",
                      n_data=n_data, n_model=n_model)
        return y.reshape(Bl, Tl, d)

    seq_axis = "model" if (n_model > 1 and T % n_model == 0) else None
    fn = shd.shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axes, seq_axis, None),     # x: batch×seq split
                  P(),                              # router (replicated)
                  P(data_axes, None, "model"),      # w_up
                  P(data_axes, None, "model"),      # w_gate
                  P(data_axes, "model", None)),     # w_down
        out_specs=P(data_axes, seq_axis, None),
        check_vma=False)
    return fn(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])


def _shared_experts(p: dict, x2d: Array) -> Array:
    sp = p["shared"]
    h = jax.nn.silu(x2d @ sp["w_gate"]) * (x2d @ sp["w_up"])
    return h @ sp["w_down"]


def moe_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    B, T, d = x.shape
    x2d = x.reshape(B * T, d)
    impl = cfg.moe.impl
    y = None
    if impl == "ep" and shd.get_mesh() is not None:
        y3d = _moe_ep(p, cfg, x)
        y = None if y3d is None else y3d.reshape(B * T, d)
    if y is None:
        # einsum/scatter dispatch groups = batch rows: capacity is per
        # sequence and slot positions are causal within it, so a decode
        # loop with a count cache reproduces the drops exactly.
        if impl == "scatter":
            y = _moe_scatter(p, cfg, x).reshape(B * T, d)
        else:
            y = _moe_einsum(p, cfg, x).reshape(B * T, d)
    if cfg.moe.n_shared_experts:
        y = y + _shared_experts(p, x2d)
    return y.reshape(B, T, d)


# ------------------------------------------------------------- decode ------
def moe_cache_init(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    """Per-sequence decode state: running per-expert assignment counts and
    the capacity the parallel path would use for a ``max_seq`` sequence.

    The einsum/scatter paths drop tokens by causal per-row slot position,
    so decode parity just needs the count each row's earlier tokens (and
    earlier k-slots of the same token) contributed per expert — PLUS a
    matching capacity: decode replays a T-token parallel pass exactly iff
    ``_capacity(cfg, max_seq) == _capacity(cfg, T)`` (init the caches
    with ``max_seq`` equal to the sequence length being compared; a
    serving loop that only ever decodes just needs ONE consistent
    capacity, which ``max_seq`` provides).
    """
    return {
        "counts": jnp.zeros((batch, cfg.moe.n_experts), jnp.int32),
        "capacity": jnp.asarray(_capacity(cfg, max_seq), jnp.int32),
    }


def moe_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict
               ) -> tuple[Array, dict]:
    """One decode chunk x: (B, S, d) (S is typically 1) through the MoE FFN.

    Matches ``moe_apply`` on the einsum/scatter paths token-for-token: the
    router and gates are identical per token, and the capacity-drop
    decision replays the parallel path's causal slot positions from the
    cached counts (given the capacity contract in ``moe_cache_init``).
    The expert compute itself is dense over the few decode tokens (the
    ``_ep_decode_local`` trick) — at S·B tokens the dispatch machinery
    costs more than it saves.  Includes the shared experts.
    """
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.n_experts, mo.top_k
    x2d = x.reshape(B * S, d)
    gate_vals, idx, _ = _router(p, cfg, x2d)
    onehot = jax.nn.one_hot(idx.reshape(B, S, K), E, dtype=jnp.int32)
    pos, counts = _causal_positions(onehot, cache["counts"])
    keep = pos < cache["capacity"]                           # (B, S, K)
    gates = jnp.einsum(
        "bsk,bske->bse",
        jnp.where(keep, gate_vals.reshape(B, S, K), 0.0).astype(x.dtype),
        onehot.astype(x.dtype))                              # dense (B,S,E)

    h = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    g = jnp.einsum("bsd,edf->bsef", x, p["w_gate"])
    ye = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * h, p["w_down"])
    y = jnp.einsum("bsed,bse->bsd", ye, gates)
    if mo.n_shared_experts:
        y = y + _shared_experts(p, x2d).reshape(B, S, d)
    return y, {"counts": counts, "capacity": cache["capacity"]}
