"""Mixture-of-Experts FFN with three dispatch implementations.

* ``impl='einsum'``  — GShard-style one-hot dispatch/combine einsums with a
  per-expert capacity.  Robust and GSPMD-friendly, but dispatch flops scale
  as O(T * E*C * d) ≈ O(top_k * T^2 * d / tokens-per-expert) — visible as
  HLO_FLOPs above MODEL_FLOPS in the roofline table for large E (kimi
  baseline: useful ratio 0.05).
* ``impl='scatter'`` — position-computed scatter/gather dispatch under
  GSPMD: kills the dispatch flops but GSPMD partitions the scatters
  pathologically (§Perf kimi iteration 2: collective term 337 s → 2480 s).
  Kept as the measured negative result.
* ``impl='ep'``      — the §Perf winner: an explicit shard_map expert-
  parallel block. Local scatter dispatch (bytes, no GSPMD choice),
  all-to-all over the 'data' axis to the expert owners, expert FFN
  TP-sharded over 'model' (weights E→data, d_ff→model: FULLY sharded, no
  FSDP all-gather of the 2 TB expert bank), one psum over 'model', and the
  reverse all-to-all. Falls back to 'einsum' when the mesh lacks the axes
  (CPU smoke tests exercise it on a (1,1) mesh).

All compute experts as block-diagonal grouped matmuls and drop overflow
tokens beyond capacity (standard GShard semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig
from repro.models.layers import dense_init

Array = jax.Array


def moe_init(rng, cfg: ArchConfig) -> dict:
    mo = cfg.moe
    d, f = cfg.d_model, mo.d_ff_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    p = {
        "router": dense_init(ks[0], (d, mo.n_experts), dtype=jnp.float32),
        "w_up": dense_init(ks[1], (mo.n_experts, d, f), dtype=dt),
        "w_gate": dense_init(ks[2], (mo.n_experts, d, f), dtype=dt),
        "w_down": dense_init(ks[3], (mo.n_experts, f, d), dtype=dt),
    }
    if mo.n_shared_experts:
        fs = f * mo.n_shared_experts
        p["shared"] = {
            "w_up": dense_init(ks[4], (d, fs), dtype=dt),
            "w_gate": dense_init(jax.random.fold_in(ks[4], 1), (d, fs), dtype=dt),
            "w_down": dense_init(jax.random.fold_in(ks[4], 2), (fs, d), dtype=dt),
        }
    return p


def _router(p: dict, cfg: ArchConfig, x2d: Array):
    mo = cfg.moe
    logits = (x2d.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, mo.top_k)           # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    return gate_vals, idx, probs


def _capacity(cfg: ArchConfig, T: int) -> int:
    mo = cfg.moe
    c = int(T * mo.top_k / mo.n_experts * mo.capacity_factor)
    return max(8, -(-c // 8) * 8)


def _experts_ffn(p: dict, cfg: ArchConfig, xe: Array) -> Array:
    """xe: (E, C, d) -> (E, C, d) block-diagonal grouped matmuls."""
    xe = shd.constrain(xe, ("experts", "expert_cap", None))
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    h = jax.nn.silu(g) * h
    h = shd.constrain(h, ("experts", "expert_cap", None))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _moe_einsum(p: dict, cfg: ArchConfig, x2d: Array) -> Array:
    mo = cfg.moe
    T, d = x2d.shape
    E, K = mo.n_experts, mo.top_k
    C = _capacity(cfg, T)
    gate_vals, idx, _ = _router(p, cfg, x2d)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)          # (T, K, E)
    pos_in_e = jnp.cumsum(onehot.reshape(T * K, E), axis=0).reshape(T, K, E) - 1
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                 # (T, K)
    keep = pos < C
    # dispatch tensor (T, E, C): combines expert one-hot and capacity slot.
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=x2d.dtype)[..., :C]           # (T, K, C)
    disp = jnp.einsum("tke,tkc->tec", onehot.astype(x2d.dtype), slot)
    comb = jnp.einsum("tk,tke,tkc->tec",
                      gate_vals.astype(x2d.dtype), onehot.astype(x2d.dtype),
                      slot)
    xe = jnp.einsum("td,tec->ecd", x2d, disp)                 # (E, C, d)
    ye = _experts_ffn(p, cfg, xe)
    return jnp.einsum("ecd,tec->td", ye, comb)


def _moe_scatter(p: dict, cfg: ArchConfig, x2d: Array) -> Array:
    mo = cfg.moe
    T, d = x2d.shape
    E, K = mo.n_experts, mo.top_k
    C = _capacity(cfg, T)
    gate_vals, idx, _ = _router(p, cfg, x2d)

    flat_e = idx.reshape(T * K)                                # (TK,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (TK, E) ints
    pos = jnp.sum(onehot * (jnp.cumsum(onehot, axis=0) - 1), axis=-1)  # (TK,)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)

    # Scatter tokens into (E, C, d) — bytes, not matmul flops.
    tok_idx = jnp.repeat(jnp.arange(T), K)
    xe = jnp.zeros((E, C, d), x2d.dtype)
    upd = x2d[tok_idx] * keep[:, None].astype(x2d.dtype)
    xe = xe.at[flat_e, pos_c].add(upd)

    ye = _experts_ffn(p, cfg, xe)

    # Gather back and combine with gate weights.
    out_tk = ye[flat_e, pos_c] * keep[:, None].astype(x2d.dtype)
    out_tk = out_tk * gate_vals.reshape(T * K, 1).astype(x2d.dtype)
    y = jnp.zeros((T, d), x2d.dtype).at[tok_idx].add(out_tk)
    return y


# ------------------------------------------------- explicit EP (shard_map) --
def _ep_local(x_loc: Array, router_w: Array, w_up: Array, w_gate: Array,
              w_down: Array, cfg: ArchConfig, *, axis_data, axis_model,
              n_data: int, n_model: int) -> Array:
    """Per-device body under shard_map (sequence-parallel EP + TP experts).

    x_loc: (T_loc, d) — a DISTINCT token slice per device (tokens split
           over data AND model: §Perf kimi iteration 4 — replicating the
           dispatch over 'model' cost a 16× larger all-to-all).
    w_*:   (E_loc, d, f_loc) / (E_loc, f_loc, d) — experts over 'data',
           d_ff over 'model'.

    Wire per device per call: 2 all-to-alls of (E, C, d) + one model-axis
    all-gather and one psum-scatter of the owner-row buffer — all sized by
    the actual dispatched tokens (T_loc·K·d·cf), never by the expert bank.
    """
    mo = cfg.moe
    T_loc, d = x_loc.shape
    E, K = mo.n_experts, mo.top_k
    E_loc = w_up.shape[0]
    C = _capacity(cfg, T_loc)

    gate_vals, idx, _ = _router({"router": router_w}, cfg, x_loc)

    # --- local scatter dispatch into (E, C, d): bytes, not matmul flops ---
    flat_e = idx.reshape(T_loc * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.sum(onehot * (jnp.cumsum(onehot, axis=0) - 1), axis=-1)
    keep = pos < C
    pos_c = jnp.where(keep, pos, C - 1)
    tok_idx = jnp.repeat(jnp.arange(T_loc), K)
    upd = x_loc[tok_idx] * keep[:, None].astype(x_loc.dtype)
    buf = jnp.zeros((E, C, d), x_loc.dtype).at[flat_e, pos_c].add(upd)

    # --- all-to-all over 'data': expert rows -> their owners --------------
    buf = buf.reshape(n_data, E_loc, C, d)
    recv = jax.lax.all_to_all(buf, axis_data, split_axis=0, concat_axis=0,
                              tiled=False)          # (n_data, E_loc, C, d)
    xe = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_data * C, d)

    # --- owner row: gather the 16 model columns' token sets, TP the FFN ---
    if n_model > 1:
        xe = jax.lax.all_gather(xe, axis_model, axis=1, tiled=True)
    # xe: (E_loc, n_model*n_data*C, d); each column computes its f_loc slice
    h = jnp.einsum("ecd,edf->ecf", xe, w_up)
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("ecf,efd->ecd", h, w_down)      # PARTIAL over 'model'
    if n_model > 1:
        # reduce over 'model' AND return each column its own token slice
        ye = jax.lax.psum_scatter(ye, axis_model, scatter_dimension=1,
                                  tiled=True)       # (E_loc, n_data*C, d)

    # --- reverse all-to-all + local combine --------------------------------
    ye = jnp.moveaxis(ye.reshape(E_loc, n_data, C, d), 1, 0)
    back = jax.lax.all_to_all(ye, axis_data, split_axis=0, concat_axis=0,
                              tiled=False)          # (n_data, E_loc, C, d)
    ye_loc = back.reshape(E, C, d)
    out_tk = ye_loc[flat_e, pos_c] * keep[:, None].astype(x_loc.dtype)
    out_tk = out_tk * gate_vals.reshape(T_loc * K, 1).astype(x_loc.dtype)
    return jnp.zeros((T_loc, d), x_loc.dtype).at[tok_idx].add(out_tk)


def _ep_decode_local(x_all: Array, router_w: Array, w_up: Array,
                     w_gate: Array, w_down: Array, cfg: ArchConfig, *,
                     axis_data, axis_model) -> Array:
    """Decode-time EP body: tokens REPLICATED (few at decode), experts
    sharded. Each device runs its local experts over every token, masked
    by the routing gates; one psum over (data, model) assembles the
    result. No dispatch, no all-to-all — wire cost is one (T, d) psum.
    """
    mo = cfg.moe
    T, d = x_all.shape
    E = mo.n_experts
    E_loc = w_up.shape[0]
    didx = jax.lax.axis_index(axis_data)

    gate_vals, idx, _ = _router({"router": router_w}, cfg, x_all)
    dense_gates = jnp.sum(
        jax.nn.one_hot(idx, E, dtype=x_all.dtype)
        * gate_vals[..., None].astype(x_all.dtype), axis=1)     # (T, E)
    my_gates = jax.lax.dynamic_slice_in_dim(dense_gates, didx * E_loc,
                                            E_loc, axis=1)      # (T, E_loc)

    h = jnp.einsum("td,edf->etf", x_all, w_up)
    g = jnp.einsum("td,edf->etf", x_all, w_gate)
    ye = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * h, w_down)
    y = jnp.einsum("etd,te->td", ye, my_gates)      # partial: local experts
    return jax.lax.psum(y, axis_data + (axis_model,))


# tokens-per-call threshold below which the replicated decode path wins
_EP_DECODE_MAX_TOKENS = 4096


def _moe_ep(p: dict, cfg: ArchConfig, x: Array) -> Array | None:
    """x: (B, T, d). Returns None when the mesh/shapes can't EP.

    The shard_map consumes the NATURAL activation layout — batch over
    'data', seq over 'model' (sequence parallelism) — so entering the
    region is a local slice.  (Fusing (B·T) rows and resharding instead
    triggered GSPMD's 'involuntary full rematerialization' path: the whole
    activation was replicated per layer; §Perf kimi iteration 4.)
    """
    mesh = shd.get_mesh()
    axes = set(mesh.axis_names)
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1) if "model" in axes else 1
    B, T, d = x.shape
    if cfg.moe.n_experts % max(n_data, 1):
        return None                                 # mesh can't EP

    # Decode / tiny-token path: replicated tokens, local-expert compute.
    if B * T <= _EP_DECODE_MAX_TOKENS or B % max(n_data, 1):
        body = partial(_ep_decode_local, cfg=cfg, axis_data=data_axes,
                       axis_model="model")

        def body3d(x_rep, router_w, w_up, w_gate, w_down):
            return body(x_rep.reshape(B * T, d), router_w, w_up, w_gate,
                        w_down).reshape(B, T, d)

        fn = shd.shard_map(
            body3d, mesh=mesh,
            in_specs=(P(None, None, None),          # x replicated
                      P(),
                      P(data_axes, None, "model"),
                      P(data_axes, None, "model"),
                      P(data_axes, "model", None)),
            out_specs=P(None, None, None),
            check_vma=False)
        return fn(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])

    def body(x_loc, router_w, w_up, w_gate, w_down):
        Bl, Tl, _ = x_loc.shape
        y = _ep_local(x_loc.reshape(Bl * Tl, d), router_w, w_up, w_gate,
                      w_down, cfg, axis_data=data_axes, axis_model="model",
                      n_data=n_data, n_model=n_model)
        return y.reshape(Bl, Tl, d)

    seq_axis = "model" if (n_model > 1 and T % n_model == 0) else None
    fn = shd.shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axes, seq_axis, None),     # x: batch×seq split
                  P(),                              # router (replicated)
                  P(data_axes, None, "model"),      # w_up
                  P(data_axes, None, "model"),      # w_gate
                  P(data_axes, "model", None)),     # w_down
        out_specs=P(data_axes, seq_axis, None),
        check_vma=False)
    return fn(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])


def moe_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    B, T, d = x.shape
    x2d = x.reshape(B * T, d)
    impl = cfg.moe.impl
    y = None
    if impl == "ep" and shd.get_mesh() is not None:
        y3d = _moe_ep(p, cfg, x)
        y = None if y3d is None else y3d.reshape(B * T, d)
    if y is None:
        if impl == "scatter":
            y = _moe_scatter(p, cfg, x2d)
        else:
            y = _moe_einsum(p, cfg, x2d)
    if cfg.moe.n_shared_experts:
        sp = p["shared"]
        h = jax.nn.silu(x2d @ sp["w_gate"]) * (x2d @ sp["w_up"])
        y = y + h @ sp["w_down"]
    return y.reshape(B, T, d)
