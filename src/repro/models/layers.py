"""Shared transformer layers: norms, RoPE, GQA attention (+KV cache), MLP.

Functional style: ``*_init(rng, cfg) -> params dict`` and
``*_apply(params, x, ...) -> y``.  Param leaves carry a ``logical`` sharding
via init-time metadata (see ``param_specs``) consumed by the launcher.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig

Array = jax.Array


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(rng, shape, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[0]
    return (scale * jax.random.normal(rng, shape) / jnp.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------- RMSNorm --
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: dict, x: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE --
def rope_freqs(hd: int, theta: float, fraction: float) -> Array:
    rot = int(hd * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: Array, positions: Array, inv_freq: Array) -> Array:
    """x: (..., T, H, hd); positions: (..., T) int32."""
    rot2 = inv_freq.shape[0]
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., T, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x_rot = x[..., : 2 * rot2].astype(jnp.float32)
    x1, x2 = x_rot[..., :rot2], x_rot[..., rot2:]
    y = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([y.astype(x.dtype), x[..., 2 * rot2:]], axis=-1)


# -------------------------------------------------------------- Attention --
def attention_init(rng, cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.hd
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dt),
        "wk": dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wv": dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dt),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dt)
        p["k_norm"] = rmsnorm_init(hd, dt)
    return p


def _qkv(p: dict, cfg: ArchConfig, x: Array, positions: Array):
    B, T, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    inv_freq = rope_freqs(hd, cfg.rope_theta, cfg.rope_fraction)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    q = shd.constrain(q, ("batch", "seq", "heads", None))
    k = shd.constrain(k, ("batch", "seq", "kv_heads", None))
    v = shd.constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def _naive_attention(q: Array, k: Array, v: Array, positions: Array,
                     hd: int) -> Array:
    """Materializes the full (T, S) score matrix — the baseline path whose
    O(T²) f32 temporaries dominate the prefill memory roofline."""
    B, T = q.shape[:2]
    logits = jnp.einsum("btkgh,bskh->bkgts", q, k) / jnp.sqrt(hd).astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    mask = positions[:, None, None, :, None] >= positions[:, None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgts,bskh->btkgh", probs, v).reshape(B, T, -1)


def _flash_attention(q: Array, k: Array, v: Array, positions: Array,
                     hd: int, block: int) -> Array:
    """Blockwise online-softmax attention (FlashAttention recurrence).

    Structure chosen across two refuted attempts (§Perf log):
    1. scanning over KV blocks with a full-length carry just moves the
       O(T·hd·nk) traffic into the scan carry;
    2. splitting heads into (kv_heads, group) kills the 16-way head
       sharding (64 -> (8, 8) is not GSPMD-expressible), leaving the block
       temporaries unsharded.
    So: heads stay FUSED (KV expanded to full heads — a per-device-local
    slice under head sharding), **Q blocks outside** (unrolled, small
    static count), inner lax.scan over the j < i KV blocks with an O(Bq)
    carry, one causal-masked diagonal block. The inner body is
    checkpointed so backward recomputes probabilities.

    q: (B, T, Hq, hd); k, v: (B, S, Hkv, hd) — expanded here.
    Assumes causal layout with monotone positions (train/prefill).
    """
    B, T, Hq, _ = q.shape
    S = k.shape[1]
    Hkv = k.shape[2]
    if Hkv != Hq:                       # GQA: local expansion under sharding
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    k = shd.constrain(k, ("batch", "seq", "heads", None))
    v = shd.constrain(v, ("batch", "seq", "heads", None))
    Bq = min(block, T)
    assert T % Bq == 0 and S % Bq == 0, (T, S, Bq)
    nq = T // Bq
    scale = 1.0 / jnp.sqrt(hd)

    kb = jnp.moveaxis(k.reshape(B, nq, Bq, Hq, hd), 1, 0)    # (nq,B,Bq,H,hd)
    vb = jnp.moveaxis(v.reshape(B, nq, Bq, Hq, hd), 1, 0)
    qb = jnp.moveaxis(q.reshape(B, nq, Bq, Hq, hd), 1, 0)
    pb = jnp.moveaxis(positions.reshape(B, nq, Bq), 1, 0)

    def make_off_diag(qi):
        def off_diag(carry, inp):
            m, l, acc = carry                   # (B,Bq,H[,hd]) f32
            kj, vj = inp                        # fully-visible past block
            s = jnp.einsum("bthd,bshd->bhts", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhts,bshd->bhtd", p, vj,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None
        return off_diag

    outs = []
    for i in range(nq):
        qi = qb[i]
        m = jnp.full((B, Hq, Bq), -1e30, jnp.float32)
        l = jnp.zeros((B, Hq, Bq), jnp.float32)
        acc = jnp.zeros((B, Hq, Bq, hd), jnp.float32)
        if i > 0:
            (m, l, acc), _ = jax.lax.scan(
                jax.checkpoint(make_off_diag(qi)), (m, l, acc),
                (kb[:i], vb[:i]))
        # diagonal block: causal mask within the block
        s = jnp.einsum("bthd,bshd->bhts", qi, kb[i],
                       preferred_element_type=jnp.float32) * scale
        mask = (pb[i][:, None, :, None] >= pb[i][:, None, None, :])
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p, vb[i], preferred_element_type=jnp.float32)
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,H,Bq,hd)
        outs.append(jnp.moveaxis(out_i, 1, 2).astype(q.dtype))
    out = jnp.stack(outs, axis=1)               # (B,nq,Bq,H,hd)
    return out.reshape(B, T, -1)


def attention_apply(p: dict, cfg: ArchConfig, x: Array, positions: Array
                    ) -> Array:
    """Causal GQA self-attention (training/prefill path)."""
    B, T, _ = x.shape
    hd = cfg.hd
    q, k, v = _qkv(p, cfg, x, positions)
    if cfg.attn_impl == "flash":
        out = _flash_attention(q, k, v, positions, hd, cfg.flash_block)
    else:
        groups = cfg.n_heads // cfg.n_kv_heads
        q = q.reshape(B, T, cfg.n_kv_heads, groups, hd)
        out = _naive_attention(q, k, v, positions, hd)
    out = shd.constrain(out, ("batch", "seq", "heads"))
    return out @ p["wo"]


def attention_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict,
                     pos: Array) -> tuple[Array, dict]:
    """One-token decode against a (B, S, Hkv, hd) KV cache.

    The cache is sequence-sharded ('seq_shard' -> model axis); the softmax
    reductions over the sharded S dim lower to flash-decode-style partial
    max/sum collectives under GSPMD.
    """
    B = x.shape[0]
    hd = cfg.hd
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos
    q, k_new, v_new = _qkv(p, cfg, x, positions)

    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), positions[0, 0], axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), positions[0, 0], axis=1)
    # sequence-sharded KV cache (flash-decode-style partial softmax);
    # kv_heads stays unsharded here — 'model' is taken by the seq dim.
    k_cache = shd.constrain(k_cache, ("batch", "seq_shard", None, None))
    v_cache = shd.constrain(v_cache, ("batch", "seq_shard", None, None))

    S = k_cache.shape[1]
    groups = cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(B, 1, cfg.n_kv_heads, groups, hd)
    logits = jnp.einsum("btkgh,bskh->bkgts", qh, k_cache) / jnp.sqrt(hd)
    logits = logits.astype(jnp.float32)
    valid = jnp.arange(S)[None, :] <= positions[:, 0][:, None]      # (B, S)
    logits = jnp.where(valid[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v_cache).reshape(B, 1, -1)
    return out @ p["wo"], {"k": k_cache, "v": v_cache}


def attention_cache_init(cfg: ArchConfig, batch: int, max_seq: int) -> dict:
    dt = _dtype(cfg)
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ------------------------------------------------------------------- MLP --
def mlp_init(rng, cfg: ArchConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    d_ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(rng, 3)
    p = {"w_up": dense_init(ks[0], (d, d_ff), dtype=dt),
         "w_down": dense_init(ks[1], (d_ff, d), dtype=dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, d_ff), dtype=dt)
    return p


def mlp_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    h = x @ p["w_up"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    h = shd.constrain(h, ("batch", "seq", "mlp"))
    return h @ p["w_down"]


# ------------------------------------------------------------- Embedding --
def embed_init(rng, cfg: ArchConfig) -> dict:
    dt = _dtype(cfg)
    p = {"table": (jax.random.normal(rng, (cfg.vocab, cfg.d_model)) * 0.02
                   ).astype(dt)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(jax.random.fold_in(rng, 1),
                               (cfg.d_model, cfg.vocab), dtype=dt)
    return p


def embed_apply(p: dict, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


def logits_apply(p: dict, cfg: ArchConfig, h: Array) -> Array:
    if cfg.tie_embeddings:
        logits = h @ p["table"].T
    else:
        logits = h @ p["head"]
    logits = shd.constrain(logits, ("batch", "seq", "vocab"))
    if cfg.logit_soft_cap > 0:
        c = cfg.logit_soft_cap
        logits = c * jnp.tanh(logits / c)
    return logits
