"""Nyström attention — the paper's technique as a first-class LM feature.

The softmax-attention kernel factorizes through the paper's RBF kernel:

    exp(q·k/√hd) = c(q) · g(q, k) · c(k),
    g(x, y) = exp(-‖x-y‖²/σ),  σ = 2√hd,   c(x) = exp(‖x‖²/σ).

so a Nyström approximation of the attention gram matrix over a set of m
landmark keys L (paper §4) gives

    Σ_s exp(q·k_s/√hd) v_s ≈ c(q) · g(q,L) · G_LL⁻¹ · Ψ,
        Ψ = Σ_s g(L, k_s) c(k_s) v_sᵀ            (m × dv running statistic)
        ζ = Σ_s g(L, k_s) c(k_s)                 (m   running normalizer)

G_LL = g(L, L) is the landmark gram matrix — exactly the K_{m,m} whose
eigendecomposition the paper maintains incrementally (Algorithm 1), and
``grow_landmark`` adds serve-time landmarks with that machinery (the
incremental-Nyström "empirical subset-size" loop, applied to KV caches).

Numerics: all k-side weights carry c̃(k) = exp(‖k‖²/σ − β) with a running
flash-style shift β (the running max of ‖k‖²/σ), so every factor is ≤ 1;
the q-side c̃(q) cancels in the num/den ratio. Exact intra-chunk attention
is combined with the Nyström inter-chunk terms in the same c̃-scaled space,
so prefill is *exact within a chunk* and Nyström-approximate across chunks.

Memory: decode state is O(m·(dv+2)) per head — independent of context
length. This is the sub-quadratic path that makes ``long_500k`` lowerable
for dense architectures (recorded as a beyond-paper extra in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig
from repro.models.layers import (apply_rope, attention_init, dense_init,
                                 rmsnorm_apply, rope_freqs)

Array = jax.Array

_JITTER = 1e-4


def nystrom_attention_init(rng, cfg: ArchConfig) -> dict:
    """Regular GQA projections + learned landmark keys (inducing points)."""
    p = attention_init(rng, cfg)
    m = cfg.nystrom_landmarks
    p["landmarks"] = dense_init(jax.random.fold_in(rng, 7),
                                (cfg.n_kv_heads, m, cfg.hd),
                                dtype=jnp.float32) * jnp.sqrt(cfg.hd)
    return p


def _sigma(hd: int) -> float:
    return 2.0 * float(hd) ** 0.5


def _rbf(x: Array, y: Array, sigma: float) -> Array:
    """g(x, y) over trailing feature dim; broadcast-friendly."""
    d2 = (jnp.sum(x * x, -1)[..., :, None] + jnp.sum(y * y, -1)[..., None, :]
          - 2.0 * jnp.einsum("...qd,...sd->...qs", x, y))
    return jnp.exp(-jnp.maximum(d2, 0.0) / sigma)


def _ginv(landmarks: Array, sigma: float) -> Array:
    """(H, m, m) inverse of the jittered landmark gram.

    The train path uses a plain differentiable inverse (eigh gradients are
    unstable near degenerate spectra); at serve time this matrix is
    *maintained incrementally* by the paper's Algorithm 1 instead of being
    recomputed (see ``grow_landmark`` / ``ginv_from_eig``).
    """
    G = _rbf(landmarks, landmarks, sigma)
    G = G + _JITTER * jnp.eye(G.shape[-1], dtype=G.dtype)
    return jnp.linalg.inv(G)


class NystromChunkCarry(NamedTuple):
    psi: Array    # (B, Hkv, m, dv)
    zeta: Array   # (B, Hkv, m)
    beta: Array   # (B, Hkv) running shift (max ‖k‖²/σ)


def nystrom_attention_apply(p: dict, cfg: ArchConfig, x: Array,
                            positions: Array, *, chunk: int = 0) -> Array:
    """Chunk-causal Nyström attention (train / prefill path).

    Exact softmax attention within each chunk; Nyström-approximate over all
    previous chunks via the (Ψ, ζ) running statistics. chunk=0 picks
    max(landmarks, 128).
    """
    B, T, _ = x.shape
    hd = cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    groups = Hq // Hkv
    sigma = _sigma(hd)
    Q = chunk or max(cfg.nystrom_landmarks, 128)
    Q = min(Q, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q

    # Projections identical to the dense path.
    q = (x @ p["wq"]).reshape(B, T, Hq, hd)
    k = (x @ p["wk"]).reshape(B, T, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, T, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    inv_freq = rope_freqs(hd, cfg.rope_theta, cfg.rope_fraction)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)

    lm = p["landmarks"].astype(jnp.float32)            # (Hkv, m, hd)
    m = lm.shape[1]
    Ginv = _ginv(lm, sigma)                            # (Hkv, m, m)

    qf = jnp.moveaxis(q.reshape(B, nc, Q, Hq, hd), 1, 0).astype(jnp.float32)
    kf = jnp.moveaxis(k.reshape(B, nc, Q, Hkv, hd), 1, 0).astype(jnp.float32)
    vf = jnp.moveaxis(v.reshape(B, nc, Q, Hkv, hd), 1, 0).astype(jnp.float32)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def step(carry: NystromChunkCarry, inp):
        qb, kb, vb = inp                               # (B,Q,H*,hd)
        knorm = jnp.sum(kb * kb, -1) / sigma           # (B,Q,Hkv)
        beta_new = jnp.maximum(carry.beta, jnp.max(knorm, axis=1))
        scale = jnp.exp(carry.beta - beta_new)         # ≤ 1
        psi = carry.psi * scale[..., None, None]
        zeta = carry.zeta * scale[..., None]

        ck = jnp.exp(knorm - beta_new[:, None, :])     # c̃(k) ≤ 1
        # Nyström inter-chunk read-out for this chunk's queries.
        phiq = _rbf(jnp.moveaxis(qb.reshape(B, Q, Hkv, groups, hd), 1, 3),
                    lm[None, :, None], sigma)          # (B,Hkv,groups,Q,m)
        r = jnp.einsum("bhgqm,hmn->bhgqn", phiq, Ginv)
        num_nys = jnp.einsum("bhgqn,bhnv->bhgqv", r, psi)
        den_nys = jnp.einsum("bhgqn,bhn->bhgq", r, zeta)

        # Exact intra-chunk attention, in the same c̃-scaled space:
        # exp(q·k/σq) · e^{-‖q‖²/σ-‖k‖²/σ+...} — equivalently g(q,k)·c̃(k).
        g_qk = _rbf(jnp.moveaxis(qb.reshape(B, Q, Hkv, groups, hd), 1, 3),
                    jnp.moveaxis(kb, 1, 2)[:, :, None], sigma)  # (B,Hkv,g,Q,S)
        w_intra = g_qk * jnp.moveaxis(ck, 1, 2)[:, :, None, None, :]
        w_intra = jnp.where(causal[None, None, None], w_intra, 0.0)
        num_intra = jnp.einsum("bhgqs,bshv->bhgqv", w_intra, vb)
        den_intra = jnp.sum(w_intra, -1)

        num = num_intra + num_nys                      # c̃(q) cancels in ratio
        den = den_intra + den_nys
        out = num / jnp.maximum(den, 1e-9)[..., None]  # (B,Hkv,g,Q,hd)
        out = jnp.moveaxis(out, 3, 1).reshape(B, Q, Hq, hd)

        # Fold this chunk's keys into the running statistics.
        phik = _rbf(lm[None], jnp.moveaxis(kb, 1, 2), sigma)  # (B,Hkv,m,Q)
        wk = phik * ck.transpose(0, 2, 1)[:, :, None, :]
        psi = psi + jnp.einsum("bhms,bshv->bhmv", wk, vb)
        zeta = zeta + jnp.sum(wk, -1)
        return NystromChunkCarry(psi, zeta, beta_new), out

    # beta starts at 0 (knorm >= 0): exp(beta-beta_new) stays differentiable
    # (an -inf start produces 0·inf = NaN in the backward pass) and the
    # initial psi/zeta are zero so the under-estimate is harmless.
    carry0 = NystromChunkCarry(
        psi=jnp.zeros((B, Hkv, m, hd), jnp.float32),
        zeta=jnp.zeros((B, Hkv, m), jnp.float32),
        beta=jnp.zeros((B, Hkv), jnp.float32))
    _, outs = jax.lax.scan(step, carry0, (qf, kf, vf))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, T, Hq * hd).astype(x.dtype)
    out = shd.constrain(out, ("batch", "seq", "heads"))
    return out @ p["wo"]


# ------------------------------------------------------------------ decode --
class NystromCache(NamedTuple):
    """O(m) per-head decode state — context-length independent."""
    psi: Array     # (B, Hkv, m, hd)
    zeta: Array    # (B, Hkv, m)
    beta: Array    # (B, Hkv)
    ginv: Array    # (Hkv, m, m) — maintained by Alg. 1 at serve time


def nystrom_cache_init(p: dict, cfg: ArchConfig, batch: int) -> NystromCache:
    m = cfg.nystrom_landmarks
    hd = cfg.hd
    lm = p["landmarks"].astype(jnp.float32)
    return NystromCache(
        psi=jnp.zeros((batch, cfg.n_kv_heads, m, hd), jnp.float32),
        zeta=jnp.zeros((batch, cfg.n_kv_heads, m), jnp.float32),
        beta=jnp.zeros((batch, cfg.n_kv_heads), jnp.float32),
        ginv=_ginv(lm, _sigma(hd)))


def nystrom_decode(p: dict, cfg: ArchConfig, x: Array, cache: NystromCache,
                   pos: Array) -> tuple[Array, NystromCache]:
    """One-token decode: O(m·hd) flops, O(m·hd) state. x: (B, 1, d)."""
    B = x.shape[0]
    hd = cfg.hd
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads
    groups = Hq // Hkv
    sigma = _sigma(hd)
    positions = jnp.broadcast_to(pos[None], (B, 1)) if pos.ndim == 0 else pos

    q = (x @ p["wq"]).reshape(B, 1, Hq, hd)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, hd)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, hd)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    inv_freq = rope_freqs(hd, cfg.rope_theta, cfg.rope_fraction)
    q = apply_rope(q, positions, inv_freq).astype(jnp.float32)[:, 0]
    k = apply_rope(k, positions, inv_freq).astype(jnp.float32)[:, 0]
    v = v.astype(jnp.float32)[:, 0]                    # (B,Hkv,hd)

    lm = p["landmarks"].astype(jnp.float32)

    # Fold the new key/value into (Ψ, ζ) with the flash-style shift update.
    knorm = jnp.sum(k * k, -1) / sigma                 # (B,Hkv)
    beta = jnp.maximum(cache.beta, knorm)
    scale = jnp.exp(cache.beta - beta)
    ck = jnp.exp(knorm - beta)
    phik = _rbf(lm[None], k[:, :, None, :], sigma)[..., 0]   # (B,Hkv,m)
    wk = phik * ck[..., None]
    psi = cache.psi * scale[..., None, None] + wk[..., None] * v[:, :, None, :]
    zeta = cache.zeta * scale[..., None] + wk

    # Read out: num/den via the maintained G⁻¹ (c̃(q) cancels).
    qg = q.reshape(B, Hkv, groups, hd)
    phiq = _rbf(qg, lm[None], sigma)                   # (B,Hkv,groups,m)
    r = jnp.einsum("bhgm,hmn->bhgn", phiq, cache.ginv)
    num = jnp.einsum("bhgn,bhnv->bhgv", r, psi)
    den = jnp.einsum("bhgn,bhn->bhg", r, zeta)
    out = (num / jnp.maximum(den, 1e-9)[..., None]).reshape(B, 1, Hq * hd)
    return (out.astype(x.dtype) @ p["wo"],
            NystromCache(psi=psi, zeta=zeta, beta=beta, ginv=cache.ginv))


# ----------------------------------------------- serve-time landmark growth --
def grow_landmark(landmarks: Array, L: Array, U: Array, m_active: Array,
                  new_lm: Array, sigma: float, *, plan=None
                  ) -> tuple[Array, Array, Array, Array]:
    """Add one landmark with the paper's Algorithm 1 (incremental eigh of the
    landmark gram K_{m,m}) — the incremental-Nyström loop of §4 applied to
    attention. Returns updated (landmarks, L, U, m_active).

    landmarks: (M, hd) fixed-capacity landmark buffer for one head;
    (L, U): maintained eigendecomposition of g(landmarks, landmarks).
    """
    from repro.core import engine as eng, inkpca, kernels_fn as kf

    plan = plan if plan is not None else eng.DEFAULT_PLAN
    M = landmarks.shape[0]
    spec = kf.KernelSpec(name="rbf", sigma=float(sigma))
    mask = jnp.arange(M) < m_active
    a = jnp.where(mask, kf.kernel_row(new_lm, landmarks, spec=spec), 0.0)
    k_new = jnp.asarray(1.0, L.dtype)                  # RBF diagonal
    state = inkpca.KPCAState(L=L, U=U, m=m_active,
                             S=jnp.zeros((), L.dtype),
                             K1=jnp.zeros((M,), L.dtype), X=landmarks)
    state = inkpca.update_unadjusted(state, a, k_new, new_lm, plan=plan)
    return state.X, state.L, state.U, state.m


def ginv_from_eig(L: Array, U: Array, m_active: Array,
                  jitter: float = _JITTER) -> Array:
    """G⁻¹ from maintained eigenpairs (paper eq. 7 rescaling pattern)."""
    M = L.shape[0]
    mask = jnp.arange(M) < m_active
    inv = jnp.where(mask & (L > jitter), 1.0 / jnp.where(L > jitter, L, 1.0),
                    0.0)
    return (U * inv[None, :]) @ U.T
