"""Architecture configuration — one frozen dataclass consumed everywhere.

Every assigned architecture is expressed as an ``ArchConfig`` in
``repro.configs.<id>``; reduced smoke variants shrink the same dataclass.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Literal


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    n_shared_experts: int = 0
    impl: Literal["einsum", "scatter"] = "einsum"
    router_dtype: str = "float32"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0        # stablelm uses partial rotary (0.25)
    parallel_block: bool = False      # command-r style attn ∥ mlp
    act: Literal["swiglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    residual_scale: float = 1.0       # minicpm depth-scaled residuals
    logit_soft_cap: float = 0.0

    moe: MoEConfig | None = None
    moe_every: int = 1                # apply MoE at layers i % moe_every == moe_offset
    moe_offset: int = 0

    # Block pattern over one period, e.g. jamba: 8-layer period with one attn.
    # Entries: 'attn' | 'mamba' | 'mlstm' | 'slstm'
    block_pattern: tuple[str, ...] = ("attn",)

    # SSM (mamba/SSD) geometry
    ssm_d_state: int = 16
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # xLSTM geometry
    xlstm_expand: int = 2

    attention: Literal["full", "nystrom"] = "full"
    nystrom_landmarks: int = 256
    # 'naive' materializes the (T, S) score matrix (the paper-era baseline);
    # 'flash' is the blockwise online-softmax form (no T² materialization) —
    # the §Perf memory-term optimization. Numerics identical (f32 softmax).
    attn_impl: Literal["naive", "flash"] = "naive"
    flash_block: int = 1024

    # Modality frontend stub: 'tokens' or 'embeddings' (vlm/audio backbones
    # receive precomputed frame/patch embeddings for part of the sequence).
    frontend: Literal["tokens", "embeddings"] = "tokens"
    frontend_len: int = 0             # positions fed as raw embeddings

    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    def block_kind(self, i: int) -> str:
        return self.block_pattern[i % self.period]

    def ffn_kind(self, i: int) -> str:
        if self.moe is not None and i % self.moe_every == self.moe_offset:
            return "moe"
        return "dense" if self.d_ff > 0 else "none"

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        n_layers = max(self.period, 2 if self.period == 1 else self.period)
        kw = dict(
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=128,
            ssm_d_state=8,
            ssm_head_dim=16,
            ssm_chunk=8,
            nystrom_landmarks=8,
            frontend_len=4 if self.frontend == "embeddings" else 0,
            dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = replace(self.moe, n_experts=4,
                                top_k=min(self.moe.top_k, 2), d_ff_expert=32)
        return replace(self, **kw)


def param_count(cfg: ArchConfig) -> int:
    """Analytic parameter count (used for 6·N·D model-flops and memory plan)."""
    d, hd = cfg.d_model, cfg.hd
    n = 0
    n += cfg.vocab * d                                   # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab * d                               # lm head
    for i in range(cfg.n_layers):
        kind = cfg.block_kind(i)
        if kind == "attn":
            n += d * (cfg.n_heads * hd) + d * hd * cfg.n_kv_heads * 2
            n += cfg.n_heads * hd * d                    # o_proj
            n += 2 * d                                   # norms
            if cfg.qk_norm:
                n += 2 * hd
        elif kind == "mamba":
            d_in = cfg.ssm_expand * d
            n += d * 2 * d_in                            # in_proj (x, gate)
            n += d_in * cfg.ssm_conv                     # conv
            heads = d_in // cfg.ssm_head_dim
            n += d_in * 2 * cfg.ssm_d_state + d_in + heads * 2  # B,C,dt,A,D
            n += d_in * d + d                            # out_proj + norm
        elif kind in ("mlstm", "slstm"):
            d_in = cfg.xlstm_expand * d
            n += d * 3 * d_in + 3 * d_in                 # qkv(+gates approx)
            n += d_in * d + 2 * d
        ffn = cfg.ffn_kind(i)
        if ffn == "dense":
            mult = 3 if cfg.act == "swiglu" else 2
            n += mult * d * cfg.d_ff + d
        elif ffn == "moe":
            mo = cfg.moe
            n += d * mo.n_experts                        # router
            n += mo.n_experts * 3 * d * mo.d_ff_expert
            n += mo.n_shared_experts * 3 * d * mo.d_ff_expert
            n += d
    n += d                                               # final norm
    return n


def active_param_count(cfg: ArchConfig) -> int:
    """Active (per-token) parameters — MoE counts only top_k experts."""
    if cfg.moe is None:
        return param_count(cfg)
    dense_like = replace(
        cfg, moe=replace(cfg.moe,
                         n_experts=cfg.moe.top_k + cfg.moe.n_shared_experts,
                         n_shared_experts=0))
    return param_count(dense_like)
