"""Selective SSM block (Mamba), implemented in the SSD (Mamba-2) chunked
matmul form — the TPU-native adaptation: the recurrence becomes dense
(Q×Q)·(Q×P) matmuls that keep the MXU busy, instead of the element-wise
parallel scan a GPU implementation would use.  Hardware-adaptation note in
DESIGN.md §3.

Train/prefill: chunked parallel form, lax.scan over T/Q chunk states.
Decode: O(1) recurrent state update per token.

Shapes: d_in = expand * d_model; heads H = d_in / head_dim(P); state N.
Scalar-per-head decay a_t = exp(dt_t * A) (A < 0), shared B_t, C_t (N,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig
from repro.models.layers import dense_init, rmsnorm_apply, rmsnorm_init

Array = jax.Array


def mamba_init(rng, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_d_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, d_in)) * 0.1
                   ).astype(dt),
        "bc_proj": dense_init(ks[2], (d_in, 2 * N), dtype=dt),
        "dt_proj": dense_init(ks[3], (d_in, H), dtype=dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32) / H + 0.5),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out_norm": rmsnorm_init(d_in, dt),
        "out_proj": dense_init(ks[4], (d_in, d), dtype=dt),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv over time; x: (B, T, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    segs = [pad[:, i: i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K)]
    return sum(segs)


def _ssd_scan(xh: Array, a_log: Array, B: Array, C: Array, chunk: int
              ) -> Array:
    """Chunked SSD: xh (B,T,H,P) pre-scaled by dt; a_log (B,T,H) = log decay;
    B, C: (B,T,N).  Returns (B,T,H,P).

    lax.scan over chunks with a checkpointed body: only ONE chunk's
    (Q, Q, H) decay tensor is live at a time. (The all-chunks-at-once
    vectorized form materialized nc of them — 174 GB/device temp on the
    jamba train_4k dry-run; §Perf jamba iteration 1.)
    """
    Bb, T, H, P = xh.shape
    N = B.shape[-1]
    Q = min(chunk, T)
    nc = T // Q
    xc = jnp.moveaxis(xh.reshape(Bb, nc, Q, H, P), 1, 0)
    ac = jnp.moveaxis(a_log.reshape(Bb, nc, Q, H), 1, 0)
    Bc = jnp.moveaxis(B.reshape(Bb, nc, Q, N), 1, 0)
    Cc = jnp.moveaxis(C.reshape(Bb, nc, Q, N), 1, 0)
    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def step(S_prev, inp):                           # S_prev: (B,H,N,P)
        x_c, a_c, B_c, C_c = inp
        cum = jnp.cumsum(a_c, axis=1)                # (B,Q,H)
        total = cum[:, -1, :]                        # (B,H)

        # Intra-chunk: M[t,s] = (C_t·B_s) exp(cum_t - cum_s), s <= t.
        scores = jnp.einsum("bqn,bsn->bqs", C_c, B_c)
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]      # (B,Q,Q,H)
        decay = jnp.where(causal[None, :, :, None], jnp.exp(ldiff), 0.0)
        M = scores[..., None] * decay
        y = jnp.einsum("bqsh,bshp->bqhp", M.astype(xh.dtype), x_c)

        # Inter-chunk: y_t += C_t^T exp(cum_t) S_prev.
        w_in = jnp.exp(cum).astype(xh.dtype)
        y = y + jnp.einsum("bqn,bqh,bhnp->bqhp", C_c, w_in, S_prev)

        # Advance the chunk state.
        w_end = jnp.exp(total[:, None, :] - cum).astype(xh.dtype)
        S_new = (jnp.exp(total)[..., None, None].astype(xh.dtype) * S_prev
                 + jnp.einsum("bqh,bqn,bqhp->bhnp", w_end, B_c, x_c))
        return S_new, y

    S0 = jnp.zeros((Bb, H, N, P), xh.dtype)
    _, ys = jax.lax.scan(jax.checkpoint(step), S0, (xc, ac, Bc, Cc))
    return jnp.moveaxis(ys, 0, 1).reshape(Bb, T, H, P)


def mamba_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    Bb, T, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim

    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = _causal_conv(xs, p["conv_w"])
    xs = jax.nn.silu(xs)
    xs = shd.constrain(xs, ("batch", "seq", "mlp"))

    BC = xs @ p["bc_proj"]                           # (B,T,2N)
    Bm, Cm = jnp.split(BC, 2, axis=-1)
    dt_raw = xs @ p["dt_proj"] + p["dt_bias"].astype(xs.dtype)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32))            # (B,T,H)
    A = -jnp.exp(p["a_log"])                                    # (H,) < 0
    a_log_step = (dt * A[None, None, :]).astype(jnp.float32)    # log decay

    xh = xs.reshape(Bb, T, H, P)
    xh_dt = xh * dt[..., None].astype(xh.dtype)
    y = _ssd_scan(xh_dt, a_log_step, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["d_skip"].astype(xh.dtype)[None, None, :, None]
    y = y.reshape(Bb, T, d_in)
    y = rmsnorm_apply(p["out_norm"], y) * jax.nn.silu(z)
    return y @ p["out_proj"]


# -------------------------------------------------------------- decoding --
def mamba_cache_init(cfg: ArchConfig, batch: int) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    dt = jnp.dtype(cfg.dtype)
    return {
        "S": jnp.zeros((batch, H, cfg.ssm_d_state, cfg.ssm_head_dim), dt),
        "conv_buf": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dt),
    }


def mamba_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict
                 ) -> tuple[Array, dict]:
    """One-token recurrent step; x: (B, 1, d)."""
    Bb, _, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim

    xz = x[:, 0] @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                # (B, d_in)
    window = jnp.concatenate([cache["conv_buf"], xs[:, None, :]], axis=1)
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"])
    xs_c = jax.nn.silu(conv_out)

    BC = xs_c @ p["bc_proj"]
    Bm, Cm = jnp.split(BC, 2, axis=-1)               # (B, N)
    dt = jax.nn.softplus((xs_c @ p["dt_proj"]
                          + p["dt_bias"].astype(xs_c.dtype)).astype(jnp.float32))
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * A[None, :])                 # (B, H)

    xh = xs_c.reshape(Bb, H, P) * dt[..., None].astype(xs_c.dtype)
    S = (decay[..., None, None].astype(cache["S"].dtype) * cache["S"]
         + jnp.einsum("bn,bhp->bhnp", Bm, xh))
    y = jnp.einsum("bn,bhnp->bhp", Cm, S)
    y = y + xs_c.reshape(Bb, H, P) * p["d_skip"].astype(xs_c.dtype)[None, :, None]
    y = y.reshape(Bb, d_in)
    y = rmsnorm_apply(p["out_norm"], y) * jax.nn.silu(z)
    y = (y @ p["out_proj"])[:, None, :]
    return y, {"S": S, "conv_buf": window[:, 1:, :]}
