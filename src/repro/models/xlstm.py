"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential recurrence with exponential gating).

TPU adaptation: mLSTM's parallel form is evaluated in the same chunked
matmul style as SSD (see ssm.py) — linear attention with data-dependent
decay — with the log-space stabilizer m_t folded into per-chunk weights.
sLSTM is inherently sequential (recurrent h feedback); it lowers to
lax.scan over time — its O(T) latency is why xLSTM-125m pairs a few sLSTM
blocks with mostly-mLSTM blocks (we follow the paper's 1:~5 ratio).

Simplifications vs the reference CUDA implementation (documented in
DESIGN.md): scalar (per-head) gates, no head-wise causal conv front-end,
GroupNorm -> RMSNorm.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.config import ArchConfig
from repro.models.layers import dense_init, rmsnorm_apply, rmsnorm_init

Array = jax.Array


# ------------------------------------------------------------------ mLSTM --
def mlstm_init(rng, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    d_in = cfg.xlstm_expand * d
    H = cfg.n_heads
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 7)
    return {
        "w_q": dense_init(ks[0], (d, d_in), dtype=dt),
        "w_k": dense_init(ks[1], (d, d_in), dtype=dt),
        "w_v": dense_init(ks[2], (d, d_in), dtype=dt),
        "w_i": dense_init(ks[3], (d, H), dtype=jnp.float32),
        "w_f": dense_init(ks[4], (d, H), dtype=jnp.float32),
        "f_bias": jnp.full((H,), 3.0, jnp.float32),   # open forget gates
        "w_gate": dense_init(ks[5], (d, d_in), dtype=dt),
        "out_norm": rmsnorm_init(d_in, dt),
        "w_o": dense_init(ks[6], (d_in, d), dtype=dt),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, chunk: int):
    """Stabilized chunked mLSTM (linear attention with data-dependent decay).

    q,k,v: (B,T,H,P); log_f, log_i: (B,T,H) in fp32.

    Exact log-space stabilization (matches ``mlstm_decode`` token-for-token):
    with lc_t = within-chunk cumsum(log_f), g_s = log_i_s - lc_s and the
    carried stabilizer m_in (relative to the chunk start),

        Mx_t   = max(m_in, cummax_{s<=t} g_s)            (running stabilizer)
        y_t    = e^{m_in-Mx_t} q_t·S_in
                 + sum_{s<=t} e^{g_s-Mx_t} (q_t·k_s/√P) v_s
        den_t  = same with z_in / k_s
        h_t    = y_t / max(|den_t|, 1)

    every exponent is ≤ 0 by construction, so the fp32 weights are bounded.
    The chunk carry (S, z, m) advances with the end-of-chunk stabilizer, and
    the scan over T/Q chunks is the only sequential dependence.
    """
    B, T, H, P = q.shape
    Q = min(chunk, T)
    nc = T // Q
    dt = q.dtype
    qc = jnp.moveaxis(q.reshape(B, nc, Q, H, P), 1, 0)     # (nc,B,Q,H,P)
    kc = jnp.moveaxis(k.reshape(B, nc, Q, H, P), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, Q, H, P), 1, 0)
    fc = jnp.moveaxis(log_f.reshape(B, nc, Q, H), 1, 0)    # (nc,B,Q,H) fp32
    ic = jnp.moveaxis(log_i.reshape(B, nc, Q, H), 1, 0)
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
    inv_sqrt_p = 1.0 / jnp.sqrt(P)

    def step(carry, inp):
        S_in, z_in, m_in = carry                # (B,H,P,P),(B,H,P),(B,H)
        qb, kb, vb, fb, ib = inp
        lc = jnp.cumsum(fb, axis=1)             # (B,Q,H)
        g = ib - lc
        Mx = jnp.maximum(jax.lax.cummax(g, axis=1), m_in[:, None, :])

        # Intra-chunk: D[t,s] = exp(g_s - Mx_t) on the causal triangle.
        dlog = g[:, None, :, :] - Mx[:, :, None, :]        # (B,Q,Q,H)
        D = jnp.where(causal, jnp.exp(dlog), 0.0)
        scores = jnp.einsum("bqhp,bshp->bqsh", qb, kb).astype(jnp.float32)
        M = scores * inv_sqrt_p * D
        y = jnp.einsum("bqsh,bshp->bqhp", M.astype(dt), vb)
        den = jnp.sum(M, axis=2)                           # (B,Q,H)

        # Inherited carry contribution.
        cw = jnp.exp(m_in[:, None, :] - Mx)                # (B,Q,H) ≤ 1
        qw = (qb * inv_sqrt_p * cw[..., None].astype(dt))
        y = y + jnp.einsum("bqhp,bhpn->bqhn", qw, S_in)
        den = den + jnp.einsum("bqhp,bhp->bqh", qw, z_in).astype(jnp.float32)

        # Advance the carry with the end-of-chunk stabilizer.
        Mx_end = Mx[:, -1, :]                              # (B,H)
        wk = jnp.exp(g - Mx_end[:, None, :])[..., None].astype(dt) * kb
        S_out = (jnp.exp(m_in - Mx_end)[..., None, None].astype(dt) * S_in
                 + jnp.einsum("bshp,bshn->bhpn", wk, vb))
        z_out = (jnp.exp(m_in - Mx_end)[..., None].astype(dt) * z_in
                 + jnp.sum(wk, axis=1))

        h = y / jnp.maximum(jnp.abs(den), 1.0)[..., None].astype(dt)
        # Re-base the carried stabilizer to the next chunk's cum reference:
        # m_in' = Mx_end + sum(log_f over this chunk).
        return (S_out, z_out, Mx_end + lc[:, -1, :]), h

    S0 = jnp.zeros((B, H, P, P), dt)
    z0 = jnp.zeros((B, H, P), dt)
    m0 = jnp.zeros((B, H), jnp.float32)   # m_0 = 0, as in mlstm_cache_init
    _, hs = jax.lax.scan(step, (S0, z0, m0), (qc, kc, vc, fc, ic))
    return jnp.moveaxis(hs, 0, 1).reshape(B, T, H * P)


def mlstm_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    B, T, d = x.shape
    d_in = cfg.xlstm_expand * d
    H = cfg.n_heads
    P = d_in // H
    q = (x @ p["w_q"]).reshape(B, T, H, P)
    k = (x @ p["w_k"]).reshape(B, T, H, P)
    v = (x @ p["w_v"]).reshape(B, T, H, P)
    log_i = (x.astype(jnp.float32) @ p["w_i"])
    log_f = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["w_f"]
                               + p["f_bias"])
    y = _mlstm_chunked(q, k, v, log_f, log_i, cfg.ssm_chunk)
    y = rmsnorm_apply(p["out_norm"], y)
    y = y * jax.nn.silu(x @ p["w_gate"])
    return y @ p["w_o"]


def mlstm_cache_init(cfg: ArchConfig, batch: int) -> dict:
    d_in = cfg.xlstm_expand * cfg.d_model
    H = cfg.n_heads
    P = d_in // H
    dt = jnp.dtype(cfg.dtype)
    return {"S": jnp.zeros((batch, H, P, P), dt),
            "z": jnp.zeros((batch, H, P), dt),
            "m": jnp.zeros((batch, H), jnp.float32)}


def mlstm_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict
                 ) -> tuple[Array, dict]:
    B, _, d = x.shape
    d_in = cfg.xlstm_expand * d
    H = cfg.n_heads
    P = d_in // H
    xt = x[:, 0]
    q = (xt @ p["w_q"]).reshape(B, H, P) / jnp.sqrt(P).astype(x.dtype)
    k = (xt @ p["w_k"]).reshape(B, H, P)
    v = (xt @ p["w_v"]).reshape(B, H, P)
    log_i = xt.astype(jnp.float32) @ p["w_i"]
    log_f = jax.nn.log_sigmoid(xt.astype(jnp.float32) @ p["w_f"] + p["f_bias"])

    m_new = jnp.maximum(log_f + cache["m"], log_i)
    wf = jnp.exp(log_f + cache["m"] - m_new).astype(x.dtype)
    wi = jnp.exp(log_i - m_new).astype(x.dtype)
    S = wf[..., None, None] * cache["S"] + wi[..., None, None] * \
        jnp.einsum("bhp,bhn->bhpn", k, v)
    z = wf[..., None] * cache["z"] + wi[..., None] * k
    num = jnp.einsum("bhp,bhpn->bhn", q, S)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, z)), 1.0)
    y = (num / den[..., None]).reshape(B, d_in)
    y = rmsnorm_apply(p["out_norm"], y) * jax.nn.silu(xt @ p["w_gate"])
    return (y @ p["w_o"])[:, None, :], {"S": S, "z": z, "m": m_new}


# ------------------------------------------------------------------ sLSTM --
def slstm_init(rng, cfg: ArchConfig) -> dict:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 3)
    return {
        "w_in": dense_init(ks[0], (d, 4 * d), dtype=dt),     # i, f, z, o
        "r_in": dense_init(ks[1], (d, 4 * d), scale=0.5, dtype=dt),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": rmsnorm_init(d, dt),
        "w_o": dense_init(ks[2], (d, d), dtype=dt),
    }


def _slstm_cell(p, gx_t, h_dtype, state):
    """One sLSTM step with exponential gating + stabilizer (paper eq.
    13-20). ``gx_t`` is the *precomputed* input-gate projection x_t@W —
    hoisted out of the token recurrence (§Perf xlstm iteration 1): the
    input projection of all T tokens becomes one TP matmul instead of T
    tiny per-token matmuls with per-token weight collectives."""
    c, n, m, h = state
    gates = (gx_t + h @ p["r_in"]).astype(jnp.float32) + p["bias"]
    i_, f_, z_, o_ = jnp.split(gates, 4, axis=-1)
    m_new = jnp.maximum(f_ + m, i_)
    i_s = jnp.exp(i_ - m_new)
    f_s = jnp.exp(f_ + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(z_)
    n_new = f_s * n + i_s
    h_new = (jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1.0)
             ).astype(h_dtype)
    return (c_new, n_new, m_new, h_new)


def slstm_apply(p: dict, cfg: ArchConfig, x: Array) -> Array:
    B, T, d = x.shape
    gx = x @ p["w_in"]                    # hoisted input projection (B,T,4d)
    gx = shd.constrain(gx, ("batch", "seq", None))
    state = (jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), jnp.float32),
             jnp.zeros((B, d), jnp.float32), jnp.zeros((B, d), x.dtype))

    def step(state, gx_t):
        state = _slstm_cell(p, gx_t, x.dtype, state)
        return state, state[3]

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1)
    return rmsnorm_apply(p["out_norm"], y) @ p["w_o"]


def slstm_cache_init(cfg: ArchConfig, batch: int) -> dict:
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.dtype(cfg.dtype))}


def slstm_decode(p: dict, cfg: ArchConfig, x: Array, cache: dict
                 ) -> tuple[Array, dict]:
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    gx = x[:, 0] @ p["w_in"]
    c, n, m, h = _slstm_cell(p, gx, x.dtype, state)
    y = rmsnorm_apply(p["out_norm"], h[:, None, :]) @ p["w_o"]
    return y, {"c": c, "n": n, "m": m, "h": h}
