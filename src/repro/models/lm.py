"""Full LM assembly over heterogeneous block patterns.

A config's ``block_pattern`` defines one *period* of layers (e.g. jamba's
7 mamba + 1 attention); the network is ``n_layers // period`` repetitions.
Parameters for slot j are stacked over periods, and the forward pass is a
``lax.scan`` over periods with the slots unrolled inside the body — HLO size
stays O(period), compile time stays flat in depth, and remat applies at
period granularity.

Caches for decode mirror the same structure: per slot, a pytree stacked over
periods, scanned jointly with the hidden state.
"""
from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import moe as moe_mod
from repro.models import nystrom_attention as nys
from repro.models import ssm, xlstm
from repro.models.config import ArchConfig
from repro.models.layers import (attention_apply, attention_cache_init,
                                 attention_decode, attention_init,
                                 embed_apply, embed_init, logits_apply,
                                 mlp_apply, mlp_init, rmsnorm_apply,
                                 rmsnorm_init)

Array = jax.Array


# ------------------------------------------------------------- init ---------
def _mixer_init(rng, cfg: ArchConfig, kind: str) -> dict:
    if kind == "attn":
        if cfg.attention == "nystrom":
            return nys.nystrom_attention_init(rng, cfg)
        return attention_init(rng, cfg)
    if kind == "mamba":
        return ssm.mamba_init(rng, cfg)
    if kind == "mlstm":
        return xlstm.mlstm_init(rng, cfg)
    if kind == "slstm":
        return xlstm.slstm_init(rng, cfg)
    raise ValueError(kind)


def _slot_init(rng, cfg: ArchConfig, slot: int) -> dict:
    kind = cfg.block_kind(slot)
    ffn = cfg.ffn_kind(slot)
    ks = jax.random.split(rng, 3)
    p: dict[str, Any] = {
        "norm1": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
        "mixer": _mixer_init(ks[0], cfg, kind),
    }
    if ffn != "none" and not cfg.parallel_block:
        p["norm2"] = rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype))
    if ffn == "dense":
        p["ffn"] = mlp_init(ks[1], cfg)
    elif ffn == "moe":
        p["ffn"] = moe_mod.moe_init(ks[2], cfg)
    return p


def n_periods(cfg: ArchConfig) -> int:
    assert cfg.n_layers % cfg.period == 0, (cfg.n_layers, cfg.period)
    return cfg.n_layers // cfg.period


def init_params(rng, cfg: ArchConfig) -> dict:
    """{'embed', 'slots': {slot_j: stacked-over-periods params}, 'final_norm'}."""
    np_ = n_periods(cfg)
    k_embed, k_blocks = jax.random.split(rng)
    slots = {}
    for j in range(cfg.period):
        rngs = jax.random.split(jax.random.fold_in(k_blocks, j), np_)
        slots[f"slot{j}"] = jax.vmap(partial(_slot_init, cfg=cfg, slot=j))(rngs)
    return {
        "embed": embed_init(k_embed, cfg),
        "slots": slots,
        "final_norm": rmsnorm_init(cfg.d_model, jnp.dtype(cfg.dtype)),
    }


# ------------------------------------------------------------ forward -------
def _mixer_apply(p: dict, cfg: ArchConfig, kind: str, h: Array,
                 positions: Array) -> Array:
    if kind == "attn":
        if cfg.attention == "nystrom":
            return nys.nystrom_attention_apply(p, cfg, h, positions)
        return attention_apply(p, cfg, h, positions)
    if kind == "mamba":
        return ssm.mamba_apply(p, cfg, h)
    if kind == "mlstm":
        return xlstm.mlstm_apply(p, cfg, h)
    if kind == "slstm":
        return xlstm.slstm_apply(p, cfg, h)
    raise ValueError(kind)


def _ffn_apply(p: dict, cfg: ArchConfig, h: Array) -> Array:
    if cfg.moe is not None and "router" in p:
        return moe_mod.moe_apply(p, cfg, h)
    return mlp_apply(p, cfg, h)


def _block(p: dict, cfg: ArchConfig, slot: int, h: Array, positions: Array
           ) -> Array:
    kind = cfg.block_kind(slot)
    ffn = cfg.ffn_kind(slot)
    rs = cfg.residual_scale
    hn = rmsnorm_apply(p["norm1"], h)
    if cfg.parallel_block and ffn != "none":
        # command-r style: attention and FFN read the same normed input.
        h = h + rs * (_mixer_apply(p["mixer"], cfg, kind, hn, positions)
                      + _ffn_apply(p["ffn"], cfg, hn))
        return shd.constrain(h, ("batch", "seq", None))
    h = h + rs * _mixer_apply(p["mixer"], cfg, kind, hn, positions)
    if ffn != "none":
        h = h + rs * _ffn_apply(p["ffn"], cfg, rmsnorm_apply(p["norm2"], h))
    return shd.constrain(h, ("batch", "seq", None))


def embed_tokens(params: dict, cfg: ArchConfig, tokens: Array,
                 embeddings: Array | None = None) -> Array:
    """Token embedding; modality frontends supply the first ``frontend_len``
    positions as precomputed embeddings (the assignment's frontend STUB)."""
    h = embed_apply(params["embed"], tokens)
    if cfg.frontend == "embeddings" and embeddings is not None:
        F = cfg.frontend_len
        h = jnp.concatenate([embeddings.astype(h.dtype), h[:, F:]], axis=1)
    return shd.constrain(h, ("batch", "seq", None))


def forward(params: dict, cfg: ArchConfig, tokens: Array,
            embeddings: Array | None = None, *,
            remat: bool = True) -> Array:
    """tokens: (B, T) -> logits (B, T, vocab)."""
    B, T = tokens.shape
    h = embed_tokens(params, cfg, tokens, embeddings)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def period_body(h, period_params):
        for j in range(cfg.period):
            h = _block(period_params[f"slot{j}"], cfg, j, h, positions)
        return h, None

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.save_only_these_names())
    h, _ = jax.lax.scan(body, h, params["slots"])
    h = rmsnorm_apply(params["final_norm"], h)
    return logits_apply(params["embed"], cfg, h)


def loss_fn(params: dict, cfg: ArchConfig, batch: dict) -> tuple[Array, dict]:
    """Next-token cross entropy; labels < 0 are masked (frontend/pad).

    Written to stay *vocab-sharded*: the label logit is picked with a fused
    one-hot reduction and the normalizer via explicit max/logsumexp — both
    reduce over the sharded vocab dim locally plus a (B, T)-sized cross-
    shard reduction, so the (B, T, V) tensor is never all-gathered (a
    take_along_axis here costs a 13 GB/device all-gather at 50k vocab).
    """
    logits = forward(params, cfg, batch["tokens"],
                     batch.get("embeddings"))
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)

    l32 = logits.astype(jnp.float32)
    vmax = jnp.max(l32, axis=-1)                               # (B, T)
    lse = vmax + jnp.log(jnp.sum(jnp.exp(l32 - vmax[..., None]), axis=-1))
    onehot = (jnp.arange(logits.shape[-1], dtype=labels.dtype)[None, None, :]
              == labels_safe[..., None])                       # fused iota
    label_logit = jnp.sum(jnp.where(onehot, l32, 0.0), axis=-1)
    ll = label_logit - lse

    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(ll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": jnp.sum(mask)}


# ------------------------------------------------------------- decode -------
def _slot_cache_init(params_slot: dict, cfg: ArchConfig, slot: int,
                     batch: int, max_seq: int):
    """Per-slot decode cache: {'mixer': ..., 'ffn': ...} with the 'ffn'
    entry present only for MoE slots (running per-expert assignment counts
    so decode replays the parallel path's capacity drops)."""
    kind = cfg.block_kind(slot)
    np_ = n_periods(cfg)
    if kind == "attn":
        if cfg.attention == "nystrom":
            mixer = jax.vmap(lambda p: nys.nystrom_cache_init(p, cfg, batch)
                             )(params_slot["mixer"])
        else:
            mixer = jax.vmap(
                lambda _: attention_cache_init(cfg, batch, max_seq)
            )(jnp.arange(np_))
    else:
        if kind == "mamba":
            fn = lambda _: ssm.mamba_cache_init(cfg, batch)        # noqa: E731
        elif kind == "mlstm":
            fn = lambda _: xlstm.mlstm_cache_init(cfg, batch)      # noqa: E731
        else:
            fn = lambda _: xlstm.slstm_cache_init(cfg, batch)      # noqa: E731
        mixer = jax.vmap(fn)(jnp.arange(np_))
    cache = {"mixer": mixer}
    if cfg.ffn_kind(slot) == "moe":
        cache["ffn"] = jax.vmap(
            lambda _: moe_mod.moe_cache_init(cfg, batch, max_seq)
        )(jnp.arange(np_))
    return cache


def init_caches(params: dict, cfg: ArchConfig, batch: int, max_seq: int):
    return {f"slot{j}": _slot_cache_init(params["slots"][f"slot{j}"], cfg, j,
                                         batch, max_seq)
            for j in range(cfg.period)}


def _mixer_decode(p: dict, cfg: ArchConfig, kind: str, h: Array, cache,
                  pos: Array):
    if kind == "attn":
        if cfg.attention == "nystrom":
            return nys.nystrom_decode(p, cfg, h, cache, pos)
        return attention_decode(p, cfg, h, cache, pos)
    if kind == "mamba":
        return ssm.mamba_decode(p, cfg, h, cache)
    if kind == "mlstm":
        return xlstm.mlstm_decode(p, cfg, h, cache)
    return xlstm.slstm_decode(p, cfg, h, cache)


def decode_step(params: dict, cfg: ArchConfig, caches: dict, token: Array,
                pos: Array) -> tuple[Array, dict]:
    """One decode step. token: (B, 1) int32; pos: (B, 1) positions.

    Returns (logits (B, 1, vocab), updated caches).
    """
    h = embed_apply(params["embed"], token)
    h = shd.constrain(h, ("batch", None, None))

    def period_body(h, xs):
        period_params, period_caches = xs
        new_caches = {}
        for j in range(cfg.period):
            p = period_params[f"slot{j}"]
            cache = period_caches[f"slot{j}"]
            kind = cfg.block_kind(j)
            ffn = cfg.ffn_kind(j)
            rs = cfg.residual_scale
            hn = rmsnorm_apply(p["norm1"], h)
            y, new_mixer = _mixer_decode(p["mixer"], cfg, kind, hn,
                                         cache["mixer"], pos)
            new_cache = {"mixer": new_mixer}

            def ffn_decode(x):
                # MoE slots thread the per-expert count cache so decode
                # replays the parallel path's capacity drops (capacity
                # fixed at cache init from max_seq — see moe_cache_init).
                if "ffn" in cache:
                    out, new_cache["ffn"] = moe_mod.moe_decode(
                        p["ffn"], cfg, x, cache["ffn"])
                    return out
                return _ffn_apply(p["ffn"], cfg, x)

            if cfg.parallel_block and ffn != "none":
                h = h + rs * (y + ffn_decode(hn))
            else:
                h = h + rs * y
                if ffn != "none":
                    h = h + rs * ffn_decode(rmsnorm_apply(p["norm2"], h))
            new_caches[f"slot{j}"] = new_cache
        return h, new_caches

    h, new_caches = jax.lax.scan(period_body, h, (params["slots"], caches))
    h = rmsnorm_apply(params["final_norm"], h)
    return logits_apply(params["embed"], cfg, h), new_caches


# --------------------------------------------------------- param specs ------
_REVERSED = ("wo", "w_down", "out_proj", "w_o", "head")
_REPLICATED_SUFFIX = ("scale", "bias", "dt_bias", "a_log", "d_skip", "f_bias")


def _leaf_logical(path: tuple, shape: tuple) -> tuple:
    """Map a param leaf to logical dim names (see distributed.sharding)."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    stacked = "slots" in names          # leading period dim
    nd = len(shape) - (1 if stacked else 0)
    if leaf in _REPLICATED_SUFFIX or nd <= 1:
        spec: tuple = (None,) * nd
    elif leaf == "table":
        # vocab rows over the TP axis: the tied-head matmul then yields
        # vocab-sharded logits directly (matching the loss constraint);
        # FSDP'ing the embed dim instead makes GSPMD materialize full
        # (B,T,V) logits per device in the backward pass (~13 GB at 50k
        # vocab) — measured in the xlstm dry-run.
        spec = ("vocab_tp", None)
    elif leaf == "router":
        spec = ("fsdp", None)
    elif leaf == "r_in":
        # sLSTM recurrent weight: lives inside the T-step token scan, so
        # its sharding is a dedicated logical pair — the §Perf xlstm
        # iteration toggles it to replicated (--rule recurrent_in=none
        # recurrent_out=none) to kill per-token weight collectives.
        spec = ("recurrent_in", "recurrent_out")
    elif leaf == "landmarks":
        spec = ("kv_heads", None, None)
    elif leaf == "conv_w":
        spec = (None, "tp")
    elif nd == 3:                       # MoE experts (E, d, f)
        # EP layout (§Perf kimi iteration 3): experts over the data axis,
        # d_ff over model — the expert bank is FULLY sharded at rest and
        # used in place by the shard_map EP block (no FSDP all-gather of
        # ~2 TB of expert weights per microbatch). The einsum-baseline
        # layout is recovered with --rule experts_data=model expert_ff=none.
        spec = ("experts_data", "expert_ff", None) if leaf in _REVERSED \
            else ("experts_data", None, "expert_ff")
    elif leaf in _REVERSED:
        spec = ("tp", "fsdp")
    else:
        spec = ("fsdp", "tp")
    if stacked:
        spec = ("layers",) + spec
    return spec


def param_logical_specs(params: dict) -> dict:
    """Pytree of logical-name tuples matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_logical(path, leaf.shape), params)


def param_shardings(params_or_shapes) -> Any:
    """Pytree of NamedShardings under the active mesh + rules."""
    logical = param_logical_specs(params_or_shapes)
    return jax.tree.map(lambda names: shd.named_sharding(names), logical,
                        is_leaf=lambda x: isinstance(x, tuple))
