from repro.data.synthetic import TokenStream, make_batch_specs
from repro.data.uci_like import magic_like, yeast_like, load_dataset
