"""Deterministic synthetic data pipeline (step-indexed PRNG).

Every batch is a pure function of (seed, step) — there is *no* data-loader
state to checkpoint, and resume-after-failure replays the identical stream
on any device topology (the elastic-rescale story: batch content depends
only on the step index, not on the device count).

The token stream is a Zipf-distributed Markov-ish stream with enough
structure that a ~100M model visibly learns within a few hundred steps
(the quickstart/e2e examples assert the loss drops), while remaining fully
offline.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig

Array = jax.Array


@dataclass(frozen=True)
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.1

    def _zipf_logits(self) -> Array:
        ranks = jnp.arange(1, self.vocab + 1, dtype=jnp.float32)
        return -self.zipf_a * jnp.log(ranks)

    def batch_at(self, step: Array) -> dict:
        """Batch for a given step — jit-safe, O(1) state."""
        rng = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(rng)
        B, T = self.global_batch, self.seq_len
        base = jax.random.categorical(
            k1, self._zipf_logits()[None, None, :], shape=(B, T))
        # Structure: with p=0.5, token t is a deterministic function of the
        # *actual* previous token (a fixed permutation) — a true Markov
        # chain the LM can learn; otherwise a fresh Zipf draw.
        perm = jax.random.permutation(jax.random.PRNGKey(self.seed + 1),
                                      self.vocab)
        gate = jax.random.bernoulli(k2, 0.5, (B, T - 1))

        def chain(prev, inp):
            b, g = inp
            tok = jnp.where(g, perm[prev], b)
            return tok, tok

        _, rest = jax.lax.scan(chain, base[:, 0],
                               (base[:, 1:].T, gate.T))
        tokens = jnp.concatenate([base[:, :1], rest.T], axis=1)
        labels = jnp.concatenate(
            [tokens[:, 1:], jnp.full((B, 1), -1, tokens.dtype)], axis=1)
        return {"tokens": tokens, "labels": labels}


def make_batch_specs(cfg: ArchConfig, global_batch: int, seq_len: int,
                     *, dtype=jnp.int32) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run inputs)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), dtype),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), dtype),
    }
    if cfg.frontend == "embeddings":
        specs["embeddings"] = jax.ShapeDtypeStruct(
            (global_batch, cfg.frontend_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return specs


def frontend_embeddings(cfg: ArchConfig, batch: dict, seed: int = 7) -> dict:
    """Attach stub modality embeddings (precomputed frame/patch features)."""
    if cfg.frontend != "embeddings":
        return batch
    B = batch["tokens"].shape[0]
    emb = jax.random.normal(jax.random.PRNGKey(seed),
                            (B, cfg.frontend_len, cfg.d_model),
                            jnp.dtype(cfg.dtype)) * 0.02
    labels = batch["labels"].at[:, : cfg.frontend_len].set(-1)
    return {**batch, "embeddings": emb, "labels": labels}
