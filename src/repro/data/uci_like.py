"""Offline stand-ins for the paper's UCI datasets (Magic, Yeast).

The container has no network access, so the paper's experiments run on
deterministic synthetic datasets matched to the originals' shape and
coarse statistics (documented in DESIGN.md §6):

* Magic gamma telescope: n≈19020, d=10, continuous, heavy-tailed and
  correlated features, two overlapping clusters (gamma/hadron).
* Yeast: n≈1484, d=8, continuous in [0,1], several small clusters
  (protein localization sites).

Both are mixtures of anisotropic Gaussians pushed through mild
non-linearities — enough structure that kernel PCA spectra decay the way
the paper's figures show (fast early decay, long tail).
"""
from __future__ import annotations

import numpy as np


def magic_like(n: int = 19020, d: int = 10, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n1 = int(n * 0.65)
    cov1 = _rand_cov(rng, d, scale=2.0)
    cov2 = _rand_cov(rng, d, scale=3.0)
    x1 = rng.multivariate_normal(np.zeros(d), cov1, size=n1)
    x2 = rng.multivariate_normal(rng.normal(0, 1.5, d), cov2, size=n - n1)
    x = np.concatenate([x1, x2], axis=0)
    # heavy tails on a few features, as in the telescope shower statistics
    x[:, :3] = np.sign(x[:, :3]) * np.abs(x[:, :3]) ** 1.5
    rng.shuffle(x)
    return x.astype(np.float64)


def yeast_like(n: int = 1484, d: int = 8, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.2, 0.8, size=(6, d))
    weights = np.array([0.31, 0.29, 0.16, 0.11, 0.07, 0.06])
    counts = np.floor(weights * n).astype(int)
    counts[0] += n - counts.sum()
    xs = [rng.normal(c, 0.08, size=(k, d)) for c, k in zip(centers, counts)]
    x = np.clip(np.concatenate(xs, axis=0), 0.0, 1.0)
    rng.shuffle(x)
    return x.astype(np.float64)


def load_dataset(name: str, n: int | None = None, seed: int = 0) -> np.ndarray:
    if name == "magic":
        x = magic_like(seed=seed)
    elif name == "yeast":
        x = yeast_like(seed=seed)
    else:
        raise ValueError(name)
    if n is not None:
        x = x[:n]
    # standardize, as is conventional before the RBF median heuristic
    return (x - x.mean(0)) / np.maximum(x.std(0), 1e-9)


def _rand_cov(rng, d: int, scale: float = 1.0) -> np.ndarray:
    a = rng.normal(size=(d, d))
    cov = a @ a.T / d
    # exponentially decaying eigenvalue profile (correlated features)
    w, v = np.linalg.eigh(cov)
    w = scale * np.exp(-np.arange(d)[::-1] / 2.5)
    return (v * w) @ v.T
