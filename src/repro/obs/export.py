"""Exporters: Prometheus text exposition + JSONL event log + /metrics.

Two surfaces over one ``TelemetryHub``:

* ``to_prometheus(hub)`` renders the text exposition format
  (counters/gauges/histogram summaries); ``serve_metrics(hub, port)``
  serves it on ``GET /metrics`` from a daemon thread —
  ``serve.py --metrics-port P`` wires it up.
* ``write_jsonl(path, hub)`` dumps the buffered events plus one final
  ``scrape`` event; ``hub.open_jsonl(path)`` streams events live
  instead.  ``read_jsonl`` / ``parse_prometheus`` close the round trip
  (and are what the exporter tests diff against).
"""
from __future__ import annotations

import json
import re
import threading


# ----------------------------------------------------------- Prometheus --
_LINE_RE = re.compile(r"^([a-zA-Z_:][a-zA-Z0-9_:]*(?:\{[^}]*\})?)\s+(\S+)$")


def _base_name(key: str) -> str:
    return key.partition("{")[0]


def _labeled(key: str, extra: dict) -> str:
    """Merge extra labels into an already-rendered key."""
    from repro.obs.hub import render_key

    base, _, rest = key.partition("{")
    labels = dict(extra)
    if rest:
        for part in rest.rstrip("}").split(","):
            k, _, v = part.partition("=")
            labels[k] = v.strip('"')
    return render_key(base, labels)


def to_prometheus(hub) -> str:
    """Text exposition: counters, gauges, and histograms as summaries
    (quantile-labelled series + _count/_sum, with the compile split as
    companion ``*_compiles`` / ``*_compile_ms`` series)."""
    lines: list[str] = []
    typed: set[str] = set()

    def header(key: str, kind: str):
        base = _base_name(key)
        if base not in typed:
            typed.add(base)
            lines.append(f"# TYPE {base} {kind}")

    with hub._lock:
        for key, c in sorted(hub._counters.items()):
            header(key, "counter")
            lines.append(f"{key} {c.value:g}")
        for key, g in sorted(hub._gauges.items()):
            header(key, "gauge")
            lines.append(f"{key} {g.value:g}")
        for key, h in sorted(hub._hists.items()):
            s = h.summary(key)
            header(key, "summary")
            for q, field in (("0.5", "p50"), ("0.9", "p90"),
                             ("0.99", "p99")):
                lines.append(f'{_labeled(key, {"quantile": q})} '
                             f'{s[f"{key}_{field}"]:g}')
            lines.append(f"{key}_count {len(h.ms):g}")
            lines.append(f"{key}_sum {sum(h.ms):g}")
            header(f"{key}_compiles", "counter")
            lines.append(f"{key}_compiles {len(h.compile_ms):g}")
            header(f"{key}_compile_ms", "counter")
            lines.append(f"{key}_compile_ms {sum(h.compile_ms):g}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> dict:
    """Inverse of ``to_prometheus`` for round-trip tests: rendered key →
    float value (comments/TYPE lines skipped)."""
    out: dict = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        mt = _LINE_RE.match(line)
        if mt:
            out[mt.group(1)] = float(mt.group(2))
    return out


# ----------------------------------------------------------------- JSONL --
def write_jsonl(path, hub) -> None:
    """Dump the hub's buffered events plus one final ``scrape`` event —
    the full registry (latency summaries included), one JSON object per
    line."""
    with open(path, "w") as f:
        for evt in hub.events:
            f.write(json.dumps(evt) + "\n")
        f.write(json.dumps({"event": "scrape", **hub.scrape()}) + "\n")


def read_jsonl(path) -> list[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


# -------------------------------------------------------------- /metrics --
def serve_metrics(hub, port: int = 0):
    """Serve ``GET /metrics`` (Prometheus text) from a daemon thread.

    Returns the live ``HTTPServer`` — read the bound port from
    ``server.server_address[1]`` (pass ``port=0`` for an ephemeral one)
    and stop it with ``server.shutdown()``.
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.rstrip("/") not in ("", "/metrics", "/metric"):
                self.send_error(404)
                return
            body = hub.to_prometheus().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                     # quiet scrapes
            pass

    server = ThreadingHTTPServer(("0.0.0.0", port), Handler)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    return server
