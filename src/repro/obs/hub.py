"""TelemetryHub — the host-side metric registry for the serving stack.

One hub answers "what is the stream doing right now": counters (kernel
dispatch routes, publishes, drift probes), gauges (active m, drift,
trace error — usually mirrored out of an in-graph
``core/telemetry.MetricsState``), latency histograms with the
compile-vs-steady key split that used to be copy-pasted as ``_PhaseTimer``
across ``launch/serve.py``, and a JSONL event log.  ``scrape()`` returns
the whole registry as a flat dict; ``to_prometheus()`` renders the text
exposition format (served by ``obs.export.serve_metrics`` under
``serve.py --metrics-port``).

The hub is plain host state — nothing here ever enters a jitted graph.
Metric identity is ``name`` plus an optional label set, rendered
Prometheus-style (``kernel_dispatch_total{kernel="rbf_gram",route="ref"}``).
"""
from __future__ import annotations

import contextlib
import re
import threading
import time

from repro.obs.trace import trace_annotation

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize(name: str) -> str:
    """Prometheus-legal metric name."""
    out = _NAME_RE.sub("_", name)
    return out if not out[:1].isdigit() else "_" + out


def render_key(name: str, labels: dict | None = None) -> str:
    name = sanitize(name)
    if not labels:
        return name
    inner = ",".join(f'{sanitize(str(k))}="{v}"'
                     for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


def _percentiles(samples) -> dict:
    import numpy as np

    arr = np.asarray(samples, float) if len(samples) else np.zeros((1,))
    return {"p50": float(np.percentile(arr, 50)),
            "p90": float(np.percentile(arr, 90)),
            "p99": float(np.percentile(arr, 99)),
            "max": float(arr.max())}


class Counter:
    """Monotone counter handle (hub-registered)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        """Absolute set — for mirroring a cumulative in-graph counter."""
        self.value = float(v)


class Gauge:
    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = float(v)


class _TimedHandle:
    """Yielded by ``LatencyHistogram.timed``: call ``.sync(x)`` with the
    arrays the phase produced so the recorded wall-clock includes the
    device execution (``jax.block_until_ready``), not just dispatch."""

    def __init__(self):
        self._sync = None

    def sync(self, x) -> None:
        self._sync = x


class LatencyHistogram:
    """Steady-state vs warm-up latency split (one per service phase).

    The first sample of each compilation KEY (bucket rung for updates,
    component count for transforms, ...) pays jit tracing + compile;
    folding it into the same series as steady-state steps is what used
    to pollute the reported p50/p99.  Keyed first calls land in
    ``compile_ms``; everything else in ``ms``.  (The hub-registered
    successor of ``launch/serve.py``'s three ``_PhaseTimer`` copies.)
    """

    def __init__(self, name: str = "phase"):
        self.name = name
        self.ms: list[float] = []
        self.compile_ms: list[float] = []
        self._seen: set = set()

    def add(self, sample_ms: float, key=None) -> None:
        if key not in self._seen:
            self._seen.add(key)
            self.compile_ms.append(sample_ms)
        else:
            self.ms.append(sample_ms)

    @contextlib.contextmanager
    def timed(self, key=None, name: str | None = None):
        """Time a phase (and annotate the profiler timeline with its
        name, so spans line up in Perfetto/TensorBoard).  The yielded
        handle's ``.sync(arrays)`` blocks on device results before the
        clock stops — without it only host dispatch time is measured."""
        handle = _TimedHandle()
        with trace_annotation(name or self.name):
            t0 = time.perf_counter()
            yield handle
            if handle._sync is not None:
                import jax

                jax.block_until_ready(handle._sync)
        self.add((time.perf_counter() - t0) * 1e3, key=key)

    def summary(self, name: str | None = None) -> dict:
        name = name if name is not None else self.name
        pct = _percentiles(self.ms)
        out = {f"{name}_{k}": v for k, v in pct.items()}
        out[f"{name}_compiles"] = len(self.compile_ms)
        out[f"{name}_compile_ms"] = float(sum(self.compile_ms))
        return out


class TelemetryHub:
    """Registry of counters/gauges/histograms plus a JSONL event buffer.

    Thread-safe for the registration paths (the decoupled serving loop
    and a ``--metrics-port`` scrape thread share one hub).
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, LatencyHistogram] = {}
        self.events: list[dict] = []
        self._jsonl = None

    # ---- registration ----------------------------------------------------
    def counter(self, name: str, **labels) -> Counter:
        key = render_key(name, labels)
        with self._lock:
            return self._counters.setdefault(key, Counter())

    def gauge(self, name: str, **labels) -> Gauge:
        key = render_key(name, labels)
        with self._lock:
            return self._gauges.setdefault(key, Gauge())

    def histogram(self, name: str) -> LatencyHistogram:
        key = sanitize(name)
        with self._lock:
            return self._hists.setdefault(key, LatencyHistogram(key))

    # convenience spellings
    def inc(self, name: str, n=1, **labels) -> None:
        self.counter(name, **labels).inc(n)

    def set_gauge(self, name: str, v, **labels) -> None:
        self.gauge(name, **labels).set(v)

    # ---- events (JSONL) --------------------------------------------------
    def open_jsonl(self, path) -> None:
        """Stream every subsequent ``emit`` to ``path`` as one JSON line
        (flushed per event — the log survives a crash)."""
        import json  # noqa: F401  (validated import for emit)

        self._jsonl = open(path, "a", buffering=1)

    def emit(self, event: dict) -> None:
        """Append a structured event (a publish, a heal, a scrape...)."""
        import json

        evt = {"ts": time.time(), **event}
        with self._lock:
            self.events.append(evt)
            if self._jsonl is not None:
                self._jsonl.write(json.dumps(evt) + "\n")

    def close_jsonl(self) -> None:
        if self._jsonl is not None:
            self._jsonl.close()
            self._jsonl = None

    # ---- in-graph mirror -------------------------------------------------
    def observe_metrics_state(self, mstate, prefix: str = "stream") -> dict:
        """Mirror a (possibly tenant-stacked) ``core/telemetry.MetricsState``
        into the registry — THE host sync for the in-graph lane.  Scalar
        streams land unlabelled; stacked lanes get a ``tenant`` label per
        entry.  Returns the host-side report dict."""
        import numpy as np

        from repro.core import telemetry as tm

        report = tm.metrics_report(mstate)
        counters = {"ingests", "rejections", "evictions", "downdates",
                    "publishes", "skipped_publishes", "heals_polish",
                    "heals_resync"}
        for field, value in report.items():
            if field.endswith("_total"):
                base = field[: -len("_total")]
                self.counter(f"{prefix}_{base}_total").set(value)
                continue
            kind = "counter" if field in counters else "gauge"
            arr = np.asarray(value)
            if arr.ndim == 0:
                v = float(arr)
                if kind == "counter":
                    self.counter(f"{prefix}_{field}_total").set(v)
                else:
                    self.gauge(f"{prefix}_{field}").set(v)
            else:
                for i, v in enumerate(arr.tolist()):
                    if kind == "counter":
                        self.counter(f"{prefix}_{field}_total",
                                     tenant=i).set(v)
                    else:
                        self.gauge(f"{prefix}_{field}", tenant=i).set(v)
        return report

    # ---- read-out --------------------------------------------------------
    def scrape(self) -> dict:
        """The whole registry as a flat dict: counters/gauges by rendered
        key, histograms expanded through their summaries."""
        with self._lock:
            out: dict = {}
            for key, c in self._counters.items():
                out[key] = c.value
            for key, g in self._gauges.items():
                out[key] = g.value
            for key, h in self._hists.items():
                out.update(h.summary(key))
            return out

    def to_prometheus(self) -> str:
        from repro.obs import export

        return export.to_prometheus(self)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self.events.clear()


_DEFAULT = TelemetryHub()


def get_hub() -> TelemetryHub:
    """The process-default hub (kernel dispatch counters land here)."""
    return _DEFAULT


def fresh_hub() -> TelemetryHub:
    """Reset and return the default hub — service entry points call this
    so one process can run several serving mains without cross-talk."""
    _DEFAULT.reset()
    return _DEFAULT


def note_kernel_dispatch(kernel: str, route: str) -> None:
    """Count one kernel *dispatch decision* (pallas / interpret / ref).

    The ``kernels/*/ops.py`` wrappers run at TRACE time inside jit, so
    each increment is one retrace — i.e. a jit-cache MISS (a compile
    event), not a per-step execution.  A steady-state serving loop holds
    these counters flat; growth means recompilation churn (new bucket
    rungs, shape changes) worth investigating.
    """
    _DEFAULT.counter("kernel_dispatch_total", kernel=kernel, route=route
                     ).inc()
