"""Phase tracing — named spans that line up in Perfetto/TensorBoard.

``span("ingest")`` wraps ``jax.profiler.TraceAnnotation``, so when a
profiler trace is being captured (``jax.profiler.trace(...)`` or
TensorBoard's capture button) every host-side service phase shows up as
a named slice on the timeline, aligned with the device ops it
dispatched.  Without an active capture the annotation is free.

Pass a ``LatencyHistogram`` (``hist=``) to ALSO record the span's
wall-clock into the hub — one context manager, both sinks.
"""
from __future__ import annotations

import contextlib


def trace_annotation(name: str):
    """``jax.profiler.TraceAnnotation(name)`` or a null context when the
    profiler surface is unavailable (stripped builds)."""
    try:
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


@contextlib.contextmanager
def span(name: str, *, hist=None, key=None):
    """Named phase scope.

    With ``hist`` (an ``obs.LatencyHistogram``) the span is timed into
    it under the compile-split ``key`` and yields the histogram's timing
    handle (call ``.sync(arrays)`` before exit to block on device
    results); without it the span only annotates the profiler timeline
    and yields None.
    """
    if hist is not None:
        with hist.timed(key=key, name=name) as handle:
            yield handle
        return
    with trace_annotation(name):
        yield None
