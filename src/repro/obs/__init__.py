"""Host-side observability: hub registry, phase tracing, exporters.

The in-graph half lives in ``repro.core.telemetry`` (a ``MetricsState``
pytree riding the stream); this package is everything that happens on
the host — the ``TelemetryHub`` registry, ``span()`` profiler tracing,
per-kernel dispatch counters, and the Prometheus / JSONL export surface
used by ``launch/serve.py``.
"""
from repro.obs.export import (parse_prometheus, read_jsonl, serve_metrics,
                              to_prometheus, write_jsonl)
from repro.obs.hub import (Counter, Gauge, LatencyHistogram, TelemetryHub,
                           fresh_hub, get_hub, note_kernel_dispatch,
                           render_key, sanitize)
from repro.obs.trace import span, trace_annotation

__all__ = [
    "Counter", "Gauge", "LatencyHistogram", "TelemetryHub",
    "fresh_hub", "get_hub", "note_kernel_dispatch", "render_key",
    "sanitize", "span", "trace_annotation", "to_prometheus",
    "parse_prometheus", "serve_metrics", "write_jsonl", "read_jsonl",
]
