# Developer entry points.  `make check` is the pre-push gate: the fast test
# tier (slow-marked integration tests deselected) plus a smoke benchmark —
# ~2 minutes on an unloaded CPU container (the slow tier alone is ~5 min).

PYTHONPATH := src

.PHONY: check test test-all bench bench-quick

check:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow" -x
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick --only flops_table

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow"

test-all:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

bench-quick:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick
