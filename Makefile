# Developer entry points.  `make check` is the pre-push gate: the fast test
# tier (slow-marked integration tests deselected) plus smoke benchmarks —
# ~3 minutes on an unloaded CPU container (the slow tier alone is ~5 min).

PYTHONPATH := src

.PHONY: check test test-all bench bench-quick bench-smoke faults metrics \
	lint-api

check: lint-api
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow" -x
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick --only flops_table
	$(MAKE) bench-smoke

# API-surface gate: fails if a new *_guarded/*_metered cartesian variant
# appears on Engine outside the deprecation shim block — cross-cutting
# features must be added as stages of the composed step pipeline.
lint-api:
	python scripts/lint_api.py

# Toy-size perf-driver smoke: exercises the update-scaling, multi-tenant
# and sharded benchmark drivers end-to-end and fails on non-finite output,
# so the perf harness can't silently rot between full benchmark runs.
# Never overwrites the tracked BENCH_*.json numbers.  (bench_sharded
# re-execs itself per device count to set the XLA host-device override.)
bench-smoke:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_update_scaling --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_multitenant --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_sharded --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_window --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_serving --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.roofline --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_health --smoke
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_observability --smoke

# Fault-injection sweep: kill-mid-save crash matrix, corruptor units,
# quarantine/heal behaviour, P=2 sharded NaN rejection.
faults:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q tests/test_faults.py tests/test_health.py

# Short decoupled serving run with the telemetry layer on, printing the
# resulting Prometheus scrape (counters, gauges, phase-latency summaries).
metrics:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.bench_observability --scrape

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q -m "not slow"

test-all:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -q

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run

bench-quick:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run --quick
