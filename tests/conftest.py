import os

# Tests exercise the Pallas kernel bodies on CPU via interpret mode.
os.environ.setdefault("REPRO_PALLAS_FORCE", "ref")

import jax  # noqa: E402

# The numerics tests (rank-one updates, drift) need f64; model code pins its
# dtypes explicitly so this is safe globally.
jax.config.update("jax_enable_x64", True)
