import os

# Tests exercise the Pallas kernel bodies on CPU via interpret mode.
os.environ.setdefault("REPRO_PALLAS_FORCE", "ref")

import jax  # noqa: E402
import pytest  # noqa: E402

# The numerics tests (rank-one updates, drift) need f64; model code pins its
# dtypes explicitly so this is safe globally.
jax.config.update("jax_enable_x64", True)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Drop jit/compile caches after each test module.

    The full suite compiles thousands of distinct executables in one
    process; letting them accumulate segfaults CPU XLA partway through
    (deterministically, inside ``backend_compile``).  Per-module
    clearing bounds the live compile state; within-module caching —
    which the dispatch-count and retrace regression tests rely on — is
    untouched."""
    yield
    jax.clear_caches()
