"""Bucketed dispatch (engine.UpdatePlan/Engine): m-scaled updates must
match the fixed-capacity path across bucket crossings.

Historically these tests drove the ``repro.core.buckets`` kwarg shims;
they now exercise the same geometry and dispatch through the engine API
directly (the shim module is a deprecation stub slated for deletion).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf, nystrom, rankone

RNG = np.random.default_rng(11)
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)


def _bplan(min_bucket: int, **kw) -> eng.UpdatePlan:
    return eng.DEFAULT_PLAN._replace(dispatch="bucketed",
                                     min_bucket=min_bucket, **kw)


# ------------------------------------------------------- bucket geometry --
def test_bucket_sizes_ladder():
    assert eng.bucket_sizes(1024, 128) == (128, 256, 512, 1024)
    assert eng.bucket_sizes(1000, 128) == (128, 256, 512, 1000)
    assert eng.bucket_sizes(100, 128) == (100,)
    assert eng.bucket_sizes(128, 128) == (128,)


def test_bucket_for_smallest_fit():
    assert eng.bucket_for(1, 1024, 128) == 128
    assert eng.bucket_for(128, 1024, 128) == 128
    assert eng.bucket_for(129, 1024, 128) == 256
    assert eng.bucket_for(1024, 1024, 128) == 1024
    with pytest.raises(ValueError):
        eng.bucket_for(1025, 1024, 128)


def test_slice_scatter_roundtrip():
    x0 = jnp.asarray(RNG.normal(size=(6, 3)))
    state = inkpca.init_state(x0, 32, SPEC, adjusted=True, dtype=jnp.float64)
    sub = eng.slice_state(state, 16)
    assert sub.L.shape == (16,) and sub.U.shape == (16, 16)
    back = eng.scatter_state(state, sub)
    np.testing.assert_allclose(np.asarray(back.U), np.asarray(state.U))
    np.testing.assert_allclose(np.asarray(back.L[:6]), np.asarray(state.L[:6]))
    # tail is re-sentinelized: still ascending, still above the spectrum
    L = np.asarray(back.L)
    assert (np.diff(L) > 0).all()


# ------------------------------------------------- crossing equivalence --
@pytest.mark.parametrize("adjusted", [True, False])
def test_bucketed_stream_matches_fixed_across_crossings(adjusted):
    """min_bucket=8 with 36 streamed points forces crossings at m=8,16,32."""
    X = RNG.normal(size=(40, 5))
    fix = inkpca.KPCAStream(jnp.asarray(X[:4]), 64, SPEC, adjusted=adjusted,
                            dtype=jnp.float64)
    buk = inkpca.KPCAStream(jnp.asarray(X[:4]), 64, SPEC, adjusted=adjusted,
                            dtype=jnp.float64, dispatch="bucketed",
                            min_bucket=8)
    fix.update_block(jnp.asarray(X[4:]))
    buk.update_block(jnp.asarray(X[4:]))
    assert int(fix.state.m) == int(buk.state.m) == 40
    lf, _ = fix.eigpairs()
    lb, _ = buk.eigpairs()
    np.testing.assert_allclose(np.asarray(lb[:40]), np.asarray(lf[:40]),
                               rtol=1e-8, atol=1e-8)
    np.testing.assert_allclose(np.asarray(buk.reconstruction()),
                               np.asarray(fix.reconstruction()), atol=1e-7)
    q = jnp.asarray(RNG.normal(size=(3, 5)))
    np.testing.assert_allclose(np.abs(np.asarray(buk.transform(q, 4))),
                               np.abs(np.asarray(fix.transform(q, 4))),
                               atol=1e-7)


def test_bucketed_single_updates_match_fixed():
    X = RNG.normal(size=(20, 4))
    fix = inkpca.KPCAStream(jnp.asarray(X[:4]), 32, SPEC, dtype=jnp.float64)
    buk = inkpca.KPCAStream(jnp.asarray(X[:4]), 32, SPEC, dtype=jnp.float64,
                            dispatch="bucketed", min_bucket=8)
    for i in range(4, 20):
        fix.update(jnp.asarray(X[i]))
        buk.update(jnp.asarray(X[i]))
    np.testing.assert_allclose(np.asarray(buk.reconstruction()),
                               np.asarray(fix.reconstruction()), atol=1e-8)


def test_bucketed_rank_one_update_matches_fixed():
    m, M = 10, 64
    A = RNG.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M)
    U = np.eye(M)
    L[:m] = lam
    U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    v = np.zeros(M)
    v[:m] = RNG.normal(size=m)
    Lf, Uf = rankone.rank_one_update(jnp.asarray(L), jnp.asarray(U),
                                     jnp.asarray(v), jnp.float64(1.1),
                                     jnp.int32(m))
    Lb, Ub = eng.rank_one(jnp.asarray(L), jnp.asarray(U), jnp.asarray(v),
                          jnp.float64(1.1), jnp.int32(m), plan=_bplan(16))
    np.testing.assert_allclose(np.asarray(Lb[:m]), np.asarray(Lf[:m]),
                               atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(Ub[:m, :m])),
                               np.abs(np.asarray(Uf[:m, :m])), atol=1e-10)
    # outside the bucket: untouched identity
    np.testing.assert_allclose(np.asarray(Ub[16:, 16:]), np.eye(M - 16))


def test_bucketed_add_landmark_matches_fixed():
    X = RNG.normal(size=(30, 4))
    x_all = jnp.asarray(X)
    fix = nystrom.init_nystrom(x_all, x_all[:4], 32, SPEC,
                               dtype=jnp.float64)
    buk = nystrom.init_nystrom(x_all, x_all[:4], 32, SPEC,
                               dtype=jnp.float64)
    engine = eng.Engine(SPEC, _bplan(8), adjusted=False)
    for i in range(4, 20):
        fix = nystrom.add_landmark(fix, x_all, x_all[i], SPEC)
        buk = engine.add_landmark(buk, x_all, x_all[i])
    np.testing.assert_allclose(np.asarray(buk.Knm), np.asarray(fix.Knm),
                               atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(nystrom.reconstruct_tilde(buk)),
        np.asarray(nystrom.reconstruct_tilde(fix)), atol=1e-7)


def test_capacity_exhaustion_raises():
    X = RNG.normal(size=(10, 3))
    buk = inkpca.KPCAStream(jnp.asarray(X[:4]), 8, SPEC, dtype=jnp.float64,
                            dispatch="bucketed", min_bucket=4)
    buk.update_block(jnp.asarray(X[4:8]))
    with pytest.raises(ValueError):
        buk.update(jnp.asarray(X[8]))


# ------------------------------------------------- fused pair equivalence --
def test_fused_pair_stream_matches_sequential():
    """matmul='jnp2' (fused double rotation) must track the sequential
    two-update path through both algorithms."""
    X = RNG.normal(size=(24, 4))
    for adjusted in (True, False):
        seq = inkpca.KPCAStream(jnp.asarray(X[:4]), 32, SPEC,
                                adjusted=adjusted, dtype=jnp.float64)
        fus = inkpca.KPCAStream(jnp.asarray(X[:4]), 32, SPEC,
                                adjusted=adjusted, dtype=jnp.float64,
                                matmul="jnp2")
        seq.update_block(jnp.asarray(X[4:]))
        fus.update_block(jnp.asarray(X[4:]))
        np.testing.assert_allclose(np.asarray(fus.reconstruction()),
                                   np.asarray(seq.reconstruction()),
                                   atol=1e-7)
