"""Incremental KRR via the maintained eigendecomposition (paper §3's
'applies to any kernel method needing the inverse' claim)."""
import numpy as np
import jax.numpy as jnp

from repro.core import kernels_fn as kf, krr
import pytest

RNG = np.random.default_rng(11)


def _problem(n=30, d=3, noise=0.05):
    X = RNG.normal(size=(n, d))
    f = np.sin(X[:, 0]) + 0.5 * np.cos(2 * X[:, 1])
    y = f + noise * RNG.normal(size=n)
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    return X, y, kf.KernelSpec(name="rbf", sigma=sigma)


def test_incremental_krr_matches_direct_solve():
    X, y, spec = _problem()
    lam = 0.1
    state = krr.init_krr(jnp.asarray(X[:6]), jnp.asarray(y[:6]), 30, spec)
    for i in range(6, 30):
        state = krr.add_point(state, jnp.asarray(X[i]), y[i], spec)
    alpha = np.asarray(krr.coefficients(state, lam))[:30]
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    alpha_ref = np.linalg.solve(K + lam * np.eye(30), y)
    np.testing.assert_allclose(alpha, alpha_ref, atol=1e-7)


def test_krr_predicts_heldout():
    X, y, spec = _problem(n=60)
    state = krr.init_krr(jnp.asarray(X[:10]), jnp.asarray(y[:10]), 50, spec)
    for i in range(10, 50):
        state = krr.add_point(state, jnp.asarray(X[i]), y[i], spec)
    pred = np.asarray(krr.predict(state, jnp.asarray(X[50:]), 0.05, spec))
    mse = float(np.mean((pred - y[50:]) ** 2))
    var = float(np.var(y[50:]))
    assert mse < 0.5 * var, (mse, var)   # clearly better than the mean


@pytest.mark.slow
def test_lambda_sweep_is_cheap_and_loocv_sane():
    X, y, spec = _problem(n=40)
    state = krr.init_krr(jnp.asarray(X[:8]), jnp.asarray(y[:8]), 40, spec)
    for i in range(8, 40):
        state = krr.add_point(state, jnp.asarray(X[i]), y[i], spec)
    # LOOCV residuals across a λ path from the SAME maintained eigenpairs
    lams = [1e-3, 1e-2, 1e-1, 1.0, 10.0]
    scores = [float(np.mean(np.asarray(krr.loocv_residuals(state, l))[:40]
                            ** 2)) for l in lams]
    assert np.isfinite(scores).all()
    # massive over-regularization must look worse than the best choice
    assert min(scores) < scores[-1]


@pytest.mark.slow
def test_loocv_matches_brute_force():
    X, y, spec = _problem(n=20)
    lam = 0.1
    state = krr.init_krr(jnp.asarray(X[:5]), jnp.asarray(y[:5]), 20, spec)
    for i in range(5, 20):
        state = krr.add_point(state, jnp.asarray(X[i]), y[i], spec)
    e = np.asarray(krr.loocv_residuals(state, lam))[:20]
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    # brute force: refit without point i, predict point i
    for i in (0, 7, 19):
        idx = [j for j in range(20) if j != i]
        a = np.linalg.solve(K[np.ix_(idx, idx)] + lam * np.eye(19), y[idx])
        pred = K[i, idx] @ a
        np.testing.assert_allclose(e[i], y[i] - pred, atol=1e-6)
