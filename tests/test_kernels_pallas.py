"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp ref."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.eigvec_update.eigvec_update import eigvec_rotate
from repro.kernels.eigvec_update.ref import eigvec_rotate_ref
from repro.kernels.nystrom_recon.nystrom_recon import scaled_gram
from repro.kernels.nystrom_recon.ref import scaled_gram_ref
from repro.kernels.rbf_gram.rbf_gram import rbf_gram
from repro.kernels.rbf_gram.ref import rbf_gram_ref

RNG = np.random.default_rng(3)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M", [32, 128, 200, 257])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_eigvec_rotate_sweep(M, dtype):
    u = jnp.asarray(RNG.normal(size=(M, M)), dtype)
    z = jnp.asarray(RNG.normal(size=M), dtype)
    d = jnp.asarray(np.sort(RNG.normal(size=M)), dtype)
    lam = d + 0.4
    inv = jnp.asarray(RNG.uniform(0.5, 2.0, size=M), dtype)
    out = eigvec_rotate(u, z, d, lam, inv, interpret=True, block=128)
    ref = eigvec_rotate_ref(u, z, d, lam, inv)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64),
                               rtol=5e-3, atol=5e-3)
    assert np.isfinite(np.asarray(out, np.float64)).all()


def _padded_rotation_inputs(M, m, extra_shift=0.4):
    """Inputs honoring the rankone padding contract: U identity beyond the
    active block, zhat/inv zero and d/lam sentinel beyond m."""
    U = np.eye(M, dtype=np.float32)
    q, _ = np.linalg.qr(RNG.normal(size=(m, m)))
    U[:m, :m] = q
    mask = np.arange(M) < m
    z = np.where(mask, RNG.normal(size=M), 0.0)
    d = np.sort(RNG.normal(size=M))
    lam = d + extra_shift
    inv = RNG.uniform(0.5, 2.0, size=M)
    to = lambda v: jnp.asarray(v, jnp.float32)
    return (to(U), to(z), to(np.where(mask, d, 2e30)),
            to(np.where(mask, lam, 1e30)), to(np.where(mask, inv, 0.0)))


@pytest.mark.parametrize("M,m", [(200, 70), (256, 130), (300, 257)])
def test_eigvec_rotate_grid_pruning(M, m):
    """Pruned grid (num_active=m, m NOT a multiple of the block) must match
    the unpruned reference on all rows of the active columns, and return
    zeros beyond the active tile range."""
    u, z, d, lam, inv = _padded_rotation_inputs(M, m)
    block = 64
    out = eigvec_rotate(u, z, d, lam, inv, jnp.int32(m), interpret=True,
                        block=block)
    ref = eigvec_rotate_ref(u, z, d, lam, inv)
    np.testing.assert_allclose(np.asarray(out[:, :m], np.float64),
                               np.asarray(ref[:, :m], np.float64),
                               rtol=5e-3, atol=5e-3)
    g = -(-m // block)
    tiles = -(-M // block)
    if g < tiles:
        assert np.abs(np.asarray(out[:, g * block:])).max() == 0.0


def test_eigvec_rotate2_matches_two_rotations():
    """Fused double rotation == two sequential single rotations (and the
    dense ref), including deflated identity columns with a permuted cid."""
    from repro.kernels.eigvec_update.eigvec_update import eigvec_rotate2
    from repro.kernels.eigvec_update.ref import (cauchy_factor_ref,
                                                 eigvec_rotate2_ref)
    M, m, block = 200, 70, 64
    u, z1, d1, lam1, inv1 = _padded_rotation_inputs(M, m)
    _, z2, d2, lam2, inv2 = _padded_rotation_inputs(M, m, extra_shift=0.9)
    defl1 = jnp.zeros(M, jnp.float32).at[5].set(1.0)
    defl2 = jnp.zeros(M, jnp.float32).at[9].set(1.0)
    cid1 = jnp.arange(M, dtype=jnp.int32).at[5].set(12)
    cid2 = jnp.arange(M, dtype=jnp.int32)
    args = (z1, d1, lam1, inv1, defl1, cid1, z2, d2, lam2, inv2, defl2,
            cid2)

    ref = eigvec_rotate2_ref(u, *args)
    # two sequential dense rotations, spelled out
    W1 = cauchy_factor_ref(z1, d1, lam1, inv1, defl1, cid1)
    W2 = cauchy_factor_ref(z2, d2, lam2, inv2, defl2, cid2)
    np.testing.assert_allclose(np.asarray((u @ W1) @ W2), np.asarray(ref))

    for na in (None, jnp.int32(m)):
        out = eigvec_rotate2(u, *args, na, interpret=True, block=block)
        np.testing.assert_allclose(np.asarray(out[:, :m], np.float64),
                                   np.asarray(ref[:, :m], np.float64),
                                   rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.float64, 1e-12)])
@pytest.mark.parametrize("R,off", [(100, 0), (100, 100), (64, 64),
                                   (90, 30)])
def test_eigvec_rotate_rectangular_matches_ref(R, off, dtype, tol):
    """Rectangular (R, M) row blocks at any row offset must match the
    dense ref on the active columns (rel. tol 1e-5 f32 / 1e-12 f64) and
    return exact zeros on kernel-pruned rows/columns."""
    from repro.kernels.eigvec_update.ref import pruned_region_mask
    M, m, block = 200, 70, 64
    u, z, d, lam, inv = (v.astype(dtype)
                         for v in _padded_rotation_inputs(M, m))
    blk = u[off:off + R]
    out = eigvec_rotate(blk, z, d, lam, inv, jnp.int32(m), jnp.int32(off),
                        interpret=True, block=block)
    ref = eigvec_rotate_ref(u, z, d, lam, inv)[off:off + R]
    np.testing.assert_allclose(np.asarray(out[:, :m], np.float64),
                               np.asarray(ref[:, :m], np.float64),
                               rtol=tol, atol=tol)
    row_mask, col_mask = (np.asarray(v) for v in
                          pruned_region_mask(R, M, m, off, block=block))
    if (~col_mask).any():
        assert np.abs(np.asarray(out[:, ~col_mask])).max() == 0.0
    if (~row_mask).any():
        assert np.abs(np.asarray(out[~row_mask])).max() == 0.0


def test_eigvec_rotate_grid_is_pruned_when_m_below_capacity():
    """The scalar-prefetched tile counts must shrink below the full grid
    whenever m < M — on both axes, including offset row blocks."""
    from repro.kernels.eigvec_update.eigvec_update import _tile_counts
    M, R, m, block = 512, 128, 70, 64
    steps_r, steps_c = R // block, M // block
    g = np.asarray(_tile_counts(jnp.int32(m), jnp.int32(0), R, M, block,
                                steps_r, steps_c))
    assert g[1] == -(-m // block) < steps_c          # columns pruned
    assert g[0] == -(-m // block) == g[1]            # offset-0 rows pruned
    # block fully past the active prefix: zero row tiles survive
    g = np.asarray(_tile_counts(jnp.int32(m), jnp.int32(256), R, M, block,
                                steps_r, steps_c))
    assert g[0] == 0 and g[1] == -(-m // block)
    # no pruning info -> full grid
    g = np.asarray(_tile_counts(None, None, R, M, block, steps_r, steps_c))
    assert g[0] == steps_r and g[1] == steps_c


def test_eigvec_rotate2_rectangular_matches_ref():
    """Fused double rotation on rectangular row blocks == dense ref rows,
    including deflated identity columns and row-axis pruning."""
    from repro.kernels.eigvec_update.eigvec_update import eigvec_rotate2
    from repro.kernels.eigvec_update.ref import eigvec_rotate2_ref
    M, m, block = 200, 70, 64
    u, z1, d1, lam1, inv1 = _padded_rotation_inputs(M, m)
    _, z2, d2, lam2, inv2 = _padded_rotation_inputs(M, m, extra_shift=0.9)
    defl1 = jnp.zeros(M, jnp.float32).at[5].set(1.0)
    defl2 = jnp.zeros(M, jnp.float32).at[9].set(1.0)
    cid1 = jnp.arange(M, dtype=jnp.int32).at[5].set(12)
    cid2 = jnp.arange(M, dtype=jnp.int32)
    args = (z1, d1, lam1, inv1, defl1, cid1, z2, d2, lam2, inv2, defl2,
            cid2)
    ref = eigvec_rotate2_ref(u, *args)
    for R, off in ((100, 0), (100, 100), (90, 30)):
        out = eigvec_rotate2(u[off:off + R], *args, jnp.int32(m),
                             jnp.int32(off), interpret=True, block=block)
        scale = np.abs(np.asarray(ref[off:off + R, :m])).max() + 1.0
        np.testing.assert_allclose(
            np.asarray(out[:, :m], np.float64) / scale,
            np.asarray(ref[off:off + R, :m], np.float64) / scale,
            rtol=1e-5, atol=1e-5)


def test_rank_one_update_row_blocks_match_full_both_signs():
    """rank_one_update applied to row blocks (via the interpret-mode rect
    Pallas kernel and the un-flip) must reproduce the full update's rows
    for sigma of EITHER sign — active stays a prefix under the flip."""
    import os
    from repro.core import rankone
    rng = np.random.default_rng(11)
    m, M, R = 10, 32, 16
    A = rng.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L0 = np.zeros(M, np.float32)
    U0 = np.eye(M, dtype=np.float32)
    L0[:m] = lam
    U0[:m, :m] = vec
    L0 = rankone.sentinelize(jnp.asarray(L0), jnp.int32(m), jnp.float32(0.0))
    v = np.zeros(M, np.float32)
    v[:m] = rng.normal(size=m)
    for sigma in (1.3, -1.3):
        Lf, Uf = rankone.rank_one_update(
            L0, jnp.asarray(U0), jnp.asarray(v), jnp.float32(sigma),
            jnp.int32(m), precise=False)
        os.environ["REPRO_PALLAS_FORCE"] = "interpret"
        try:
            for off in (0, R):
                blk = jnp.asarray(U0[off:off + R])
                z = jnp.asarray(U0.T @ v)
                Lb, Ub = rankone._update_body(
                    L0, blk, jnp.asarray(v), jnp.float32(sigma),
                    jnp.int32(m), iters=32, method="gu", matmul="pallas",
                    precise=False, z=z, row_offset=jnp.int32(off))
        finally:
            os.environ["REPRO_PALLAS_FORCE"] = "ref"
        np.testing.assert_allclose(np.asarray(Lb[:m]), np.asarray(Lf[:m]),
                                   atol=2e-5)


def test_rank_one_update_pair_matches_sequential_pallas():
    """rank_one_update_pair(matmul='pallas') through the interpret-mode
    fused kernel == two sequential jnp updates."""
    import os
    from repro.core import rankone
    m, M = 10, 16
    A = RNG.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M)
    U = np.eye(M)
    L[:m] = lam
    U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L, jnp.float32), jnp.int32(m),
                            jnp.float32(0.0))
    v1 = np.zeros(M)
    v1[:m] = RNG.normal(size=m)
    v2 = np.zeros(M)
    v2[:m] = RNG.normal(size=m)
    La, Ua = rankone.rank_one_update(
        L, jnp.asarray(U, jnp.float32), jnp.asarray(v1, jnp.float32),
        jnp.float32(1.1), jnp.int32(m), precise=False)
    La, Ua = rankone.rank_one_update(
        La, Ua, jnp.asarray(v2, jnp.float32), jnp.float32(-1.1),
        jnp.int32(m), precise=False)
    os.environ["REPRO_PALLAS_FORCE"] = "interpret"
    try:
        Lp, Up = rankone.rank_one_update_pair(
            L, jnp.asarray(U, jnp.float32), jnp.asarray(v1, jnp.float32),
            jnp.float32(1.1), jnp.asarray(v2, jnp.float32),
            jnp.float32(-1.1), jnp.int32(m), matmul="pallas", precise=False)
    finally:
        os.environ["REPRO_PALLAS_FORCE"] = "ref"
    np.testing.assert_allclose(np.asarray(Lp[:m]), np.asarray(La[:m]),
                               atol=1e-4)
    np.testing.assert_allclose(np.abs(np.asarray(Up[:m, :m])),
                               np.abs(np.asarray(Ua[:m, :m])), atol=1e-3)


@pytest.mark.parametrize("n,m,d", [(64, 64, 8), (150, 90, 17), (129, 257, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rbf_gram_sweep(n, m, d, dtype):
    x = jnp.asarray(RNG.normal(size=(n, d)), dtype)
    y = jnp.asarray(RNG.normal(size=(m, d)), dtype)
    sigma = jnp.asarray(2.5, jnp.float32)
    g = rbf_gram(x, y, sigma, interpret=True)
    ref = rbf_gram_ref(x, y, sigma)
    np.testing.assert_allclose(np.asarray(g, np.float64),
                               np.asarray(ref, np.float64), **_tol(dtype))
    assert g.dtype == dtype


def test_rbf_gram_diagonal_is_one():
    x = jnp.asarray(RNG.normal(size=(40, 7)), jnp.float32)
    g = rbf_gram(x, x, jnp.asarray(3.0, jnp.float32), interpret=True)
    np.testing.assert_allclose(np.diag(np.asarray(g)), 1.0, atol=1e-5)


@pytest.mark.parametrize("n,m", [(64, 32), (170, 60), (130, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_scaled_gram_sweep(n, m, dtype):
    b = jnp.asarray(RNG.normal(size=(n, m)), dtype)
    s = jnp.asarray(RNG.uniform(0.1, 1.0, size=m), dtype)
    k = scaled_gram(b, s, interpret=True)
    ref = scaled_gram_ref(b, s)
    np.testing.assert_allclose(np.asarray(k, np.float64),
                               np.asarray(ref, np.float64),
                               rtol=1e-3, atol=1e-3)
    # symmetry
    np.testing.assert_allclose(np.asarray(k), np.asarray(k).T, atol=1e-5)


@pytest.mark.parametrize("BH,T,hd", [(2, 64, 32), (3, 128, 64), (1, 64, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_sweep(BH, T, hd, dtype):
    from repro.kernels.flash_attn.flash_attn import flash_attention
    from repro.kernels.flash_attn.ref import flash_attention_ref
    q = jnp.asarray(RNG.normal(size=(BH, T, hd)) * 0.5, dtype)
    k = jnp.asarray(RNG.normal(size=(BH, T, hd)) * 0.5, dtype)
    v = jnp.asarray(RNG.normal(size=(BH, T, hd)), dtype)
    out = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64),
                               rtol=tol, atol=tol)
    assert out.dtype == dtype


def test_flash_attention_kernel_causality():
    from repro.kernels.flash_attn.flash_attn import flash_attention
    q = jnp.asarray(RNG.normal(size=(1, 64, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 64, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 64, 32)), jnp.float32)
    o1 = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    k2 = k.at[:, -1].add(10.0)
    v2 = v.at[:, -1].add(10.0)
    o2 = flash_attention(q, k2, v2, block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :-1]),
                               np.asarray(o2[:, :-1]), atol=1e-6)


@pytest.mark.parametrize("G,Q,N,H,P", [(2, 16, 8, 2, 16), (3, 32, 16, 4, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_chunk_kernel_sweep(G, Q, N, H, P, dtype):
    from repro.kernels.ssd_chunk.ssd_chunk import ssd_intra_chunk
    from repro.kernels.ssd_chunk.ref import ssd_intra_chunk_ref
    c = jnp.asarray(RNG.normal(size=(G, Q, N)) * 0.3, dtype)
    b = jnp.asarray(RNG.normal(size=(G, Q, N)) * 0.3, dtype)
    x = jnp.asarray(RNG.normal(size=(G, Q, H, P)), dtype)
    cum = jnp.asarray(-np.abs(np.cumsum(RNG.uniform(0, 0.2, (G, Q, H)),
                                        axis=1)), jnp.float32)
    out = ssd_intra_chunk(c, b, x, cum, interpret=True)
    ref = ssd_intra_chunk_ref(c, b, x, cum)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunk_kernel_causality():
    from repro.kernels.ssd_chunk.ssd_chunk import ssd_intra_chunk
    G, Q, N, H, P = 1, 16, 8, 2, 8
    c = jnp.asarray(RNG.normal(size=(G, Q, N)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(G, Q, N)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(G, Q, H, P)), jnp.float32)
    cum = jnp.zeros((G, Q, H), jnp.float32)
    o1 = ssd_intra_chunk(c, b, x, cum, interpret=True)
    x2 = x.at[:, -1].add(5.0)
    o2 = ssd_intra_chunk(c, b, x2, cum, interpret=True)
    np.testing.assert_allclose(np.asarray(o1[:, :-1]),
                               np.asarray(o2[:, :-1]), atol=1e-6)


def test_eigvec_rotate_used_in_rank_one_update():
    """End-to-end: rank_one_update(matmul='pallas') == 'jnp' (interpret)."""
    import os
    import jax
    from repro.core import rankone
    os.environ["REPRO_PALLAS_FORCE"] = "interpret"
    try:
        m, M = 10, 16
        A = RNG.normal(size=(m, m))
        A = A @ A.T
        lam, vec = np.linalg.eigh(A)
        L = np.zeros(M); U = np.eye(M)
        L[:m] = lam; U[:m, :m] = vec
        L = rankone.sentinelize(jnp.asarray(L, jnp.float32), jnp.int32(m),
                                jnp.float32(0.0))
        v = np.zeros(M); v[:m] = RNG.normal(size=m)
        with jax.disable_jit():
            La, Ua = rankone.rank_one_update(
                jnp.asarray(L, jnp.float32), jnp.asarray(U, jnp.float32),
                jnp.asarray(v, jnp.float32), jnp.float32(0.9), jnp.int32(m),
                matmul="pallas", precise=False)
        Lb, Ub = rankone.rank_one_update(
            jnp.asarray(L, jnp.float32), jnp.asarray(U, jnp.float32),
            jnp.asarray(v, jnp.float32), jnp.float32(0.9), jnp.int32(m),
            matmul="jnp", precise=False)
        np.testing.assert_allclose(np.asarray(La), np.asarray(Lb), atol=1e-5)
        np.testing.assert_allclose(np.abs(np.asarray(Ua[:m, :m])),
                                   np.abs(np.asarray(Ub[:m, :m])), atol=1e-3)
    finally:
        os.environ["REPRO_PALLAS_FORCE"] = "ref"
