"""Incremental Nyström (paper §4): exactness vs batch, error behaviour."""
import numpy as np
import jax.numpy as jnp

from repro.core import inkpca, kernels_fn as kf, nystrom

RNG = np.random.default_rng(2)


def _setup(n=40, d=4, m0=5):
    X = RNG.normal(size=(n, d))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:m0]),
                                 capacity=24, spec=spec, dtype=jnp.float64)
    return X, spec, K, state


def _batch_nystrom(K, m):
    Knm = K[:, :m]
    Kmm = K[:m, :m]
    return Knm @ np.linalg.solve(Kmm, Knm.T)


def test_incremental_equals_batch_at_every_m():
    X, spec, K, state = _setup()
    for m in range(5, 15):
        Kt = np.asarray(nystrom.reconstruct_tilde(state))
        ref = _batch_nystrom(K, m)
        assert np.abs(Kt - ref).max() < 1e-7, m
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)


def test_nystrom_eigpair_rescaling():
    """Paper eq. (7): U_nys Λ_nys U_nysᵀ == K_nm K_mm⁻¹ K_mn."""
    X, spec, K, state = _setup()
    for m in range(5, 10):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    n = X.shape[0]
    lam, U = nystrom.nystrom_eigpairs(state, n)
    lam = np.asarray(lam)
    U = np.asarray(U)
    Kt = (U * lam[None, :]) @ U.T
    ref = _batch_nystrom(K, 10)
    assert np.abs(Kt - ref).max() < 1e-6


def test_error_norms_decrease_with_m():
    X, spec, K, state = _setup(n=60)
    errs = []
    for m in range(5, 20):
        Kt = np.asarray(nystrom.reconstruct_tilde(state))
        errs.append(nystrom.approximation_error(jnp.asarray(K),
                                                jnp.asarray(Kt)).fro)
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    # overall trend must be decreasing (paper Fig. 2)
    assert errs[-1] < errs[0] * 0.9
    assert min(errs) == errs[-1] or errs[-1] < 1.05 * min(errs)


def test_full_landmark_set_is_exact():
    X, spec, K, state = _setup(n=20, m0=5)
    for m in range(5, 20):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    Kt = np.asarray(nystrom.reconstruct_tilde(state))
    assert np.abs(Kt - K).max() < 1e-6


def test_error_norms_fields():
    e = nystrom.approximation_error(jnp.eye(4), jnp.zeros((4, 4)))
    assert e.fro == 2.0 and e.spectral == 1.0 and e.trace == 4.0


# ------------------------------------------------------ growing row mode ---
def test_grow_rows_matches_batch_gram():
    """grow_rows: Knm rows appended as the stream is observed must equal
    the batch gram of (observed points, landmarks) at every size."""
    X = RNG.normal(size=(30, 4))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    state = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=16,
                                 spec=spec, dtype=jnp.float64,
                                 grow_rows=True)
    for i in range(4, 30):
        state = nystrom.observe_rows(state, jnp.asarray(X[i]), spec)
        if i % 3 == 0:      # every third observed point becomes a landmark
            state = nystrom.add_landmark(state, None, jnp.asarray(X[i]),
                                         spec)
    m = int(state.kpca.m)
    assert state.Knm.shape[0] == 30         # memory tracks the stream
    landmarks = jnp.asarray(np.asarray(state.kpca.X[:m]))
    ref = np.asarray(kf.gram_block(state.Xrows, landmarks, spec=spec))
    np.testing.assert_allclose(np.asarray(state.Knm[:, :m]), ref,
                               atol=1e-10)
    # inactive columns stay zero
    assert float(jnp.abs(state.Knm[:, m:]).max()) == 0.0


def test_grow_rows_reconstruction_matches_fixed_rows():
    """Same landmarks + same rows => grow_rows reconstruction equals the
    dense init_nystrom path."""
    X = RNG.normal(size=(18, 3))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    fixed = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:4]),
                                 capacity=12, spec=spec, dtype=jnp.float64)
    grown = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=12,
                                 spec=spec, dtype=jnp.float64,
                                 grow_rows=True)
    grown = nystrom.observe_rows(grown, jnp.asarray(X[4:]), spec)
    for i in range(4, 9):
        fixed = nystrom.add_landmark(fixed, jnp.asarray(X),
                                     jnp.asarray(X[i]), spec)
        grown = nystrom.add_landmark(grown, None, jnp.asarray(X[i]), spec)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(grown)),
                               np.asarray(nystrom.reconstruct_tilde(fixed)),
                               atol=1e-9)


def test_grow_rows_argument_validation():
    X = jnp.asarray(RNG.normal(size=(6, 3)))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    import pytest
    with pytest.raises(ValueError):
        nystrom.init_nystrom(X, X[:2], capacity=8, spec=spec, grow_rows=True)
    with pytest.raises(ValueError):
        nystrom.init_nystrom(None, X[:2], capacity=8, spec=spec)
    fixed = nystrom.init_nystrom(X, X[:2], capacity=8, spec=spec,
                                 dtype=jnp.float64)
    with pytest.raises(ValueError):
        nystrom.observe_rows(fixed, X[3], spec)
