"""Incremental Nyström (paper §4): exactness vs batch, error behaviour."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import inkpca, kernels_fn as kf, nystrom

RNG = np.random.default_rng(2)


def _setup(n=40, d=4, m0=5):
    X = RNG.normal(size=(n, d))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:m0]),
                                 capacity=24, spec=spec, dtype=jnp.float64)
    return X, spec, K, state


def _batch_nystrom(K, m):
    Knm = K[:, :m]
    Kmm = K[:m, :m]
    return Knm @ np.linalg.solve(Kmm, Knm.T)


def test_incremental_equals_batch_at_every_m():
    X, spec, K, state = _setup()
    for m in range(5, 15):
        Kt = np.asarray(nystrom.reconstruct_tilde(state))
        ref = _batch_nystrom(K, m)
        assert np.abs(Kt - ref).max() < 1e-7, m
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)


def test_nystrom_eigpair_rescaling():
    """Paper eq. (7): U_nys Λ_nys U_nysᵀ == K_nm K_mm⁻¹ K_mn."""
    X, spec, K, state = _setup()
    for m in range(5, 10):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    n = X.shape[0]
    lam, U = nystrom.nystrom_eigpairs(state, n)
    lam = np.asarray(lam)
    U = np.asarray(U)
    Kt = (U * lam[None, :]) @ U.T
    ref = _batch_nystrom(K, 10)
    assert np.abs(Kt - ref).max() < 1e-6


def test_error_norms_decrease_with_m():
    X, spec, K, state = _setup(n=60)
    errs = []
    for m in range(5, 20):
        Kt = np.asarray(nystrom.reconstruct_tilde(state))
        errs.append(nystrom.approximation_error(jnp.asarray(K),
                                                jnp.asarray(Kt)).fro)
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    # overall trend must be decreasing (paper Fig. 2)
    assert errs[-1] < errs[0] * 0.9
    assert min(errs) == errs[-1] or errs[-1] < 1.05 * min(errs)


def test_full_landmark_set_is_exact():
    X, spec, K, state = _setup(n=20, m0=5)
    for m in range(5, 20):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    Kt = np.asarray(nystrom.reconstruct_tilde(state))
    assert np.abs(Kt - K).max() < 1e-6


def test_error_norms_fields():
    e = nystrom.approximation_error(jnp.eye(4), jnp.zeros((4, 4)))
    assert e.fro == 2.0 and e.spectral == 1.0 and e.trace == 4.0


# ------------------------------------------------------ growing row mode ---
def test_grow_rows_matches_batch_gram():
    """grow_rows: Knm rows appended as the stream is observed must equal
    the batch gram of (observed points, landmarks) at every size."""
    X = RNG.normal(size=(30, 4))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    state = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=16,
                                 spec=spec, dtype=jnp.float64,
                                 grow_rows=True)
    for i in range(4, 30):
        state = nystrom.observe_rows(state, jnp.asarray(X[i]), spec)
        if i % 3 == 0:      # every third observed point becomes a landmark
            state = nystrom.add_landmark(state, None, jnp.asarray(X[i]),
                                         spec)
    m = int(state.kpca.m)
    assert state.Knm.shape[0] == 30         # memory tracks the stream
    landmarks = jnp.asarray(np.asarray(state.kpca.X[:m]))
    ref = np.asarray(kf.gram_block(state.Xrows, landmarks, spec=spec))
    np.testing.assert_allclose(np.asarray(state.Knm[:, :m]), ref,
                               atol=1e-10)
    # inactive columns stay zero
    assert float(jnp.abs(state.Knm[:, m:]).max()) == 0.0


def test_grow_rows_reconstruction_matches_fixed_rows():
    """Same landmarks + same rows => grow_rows reconstruction equals the
    dense init_nystrom path."""
    X = RNG.normal(size=(18, 3))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    fixed = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:4]),
                                 capacity=12, spec=spec, dtype=jnp.float64)
    grown = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=12,
                                 spec=spec, dtype=jnp.float64,
                                 grow_rows=True)
    grown = nystrom.observe_rows(grown, jnp.asarray(X[4:]), spec)
    for i in range(4, 9):
        fixed = nystrom.add_landmark(fixed, jnp.asarray(X),
                                     jnp.asarray(X[i]), spec)
        grown = nystrom.add_landmark(grown, None, jnp.asarray(X[i]), spec)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(grown)),
                               np.asarray(nystrom.reconstruct_tilde(fixed)),
                               atol=1e-9)


# --------------------------------------------------- landmark lifecycle ---
def test_remove_landmark_matches_batch():
    """remove_landmark(j) == batch Nyström with landmark j dropped, at
    every j (interior, first, boundary)."""
    X, spec, K, state = _setup(n=30)
    for m in range(5, 12):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    for j in (0, 3, 11):
        st2 = nystrom.remove_landmark(state, jnp.int32(j), spec)
        keep = [i for i in range(12) if i != j]
        ref = K[:, keep] @ np.linalg.solve(K[np.ix_(keep, keep)],
                                           K[:, keep].T)
        np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(st2)),
                                   ref, atol=1e-9)
        assert int(st2.kpca.m) == 11
        # evicted landmark's column zeroed, survivors' order preserved
        assert float(jnp.abs(st2.Knm[:, 11:]).max()) == 0.0
        np.testing.assert_allclose(np.asarray(st2.Knm[:, :11]), K[:, keep],
                                   atol=1e-12)


def test_replace_landmark_matches_batch():
    """replace_landmark == remove + add == batch Nyström on the swapped
    landmark set, and round-trips add∘remove of the same point."""
    X, spec, K, state = _setup(n=30)
    for m in range(5, 12):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    st2 = nystrom.replace_landmark(state, jnp.asarray(X), jnp.int32(2),
                                   jnp.asarray(X[20]), spec)
    keep = [i for i in range(12) if i != 2] + [20]
    ref = K[:, keep] @ np.linalg.solve(K[np.ix_(keep, keep)], K[:, keep].T)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(st2)),
                               ref, atol=1e-8)
    # replacing a landmark with ITSELF is the identity (downdate∘update)
    st3 = nystrom.replace_landmark(state, jnp.asarray(X), jnp.int32(11),
                                   jnp.asarray(X[11]), spec)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(st3)),
                               np.asarray(nystrom.reconstruct_tilde(state)),
                               atol=1e-9)


def test_engine_remove_landmark_bucketed_matches_fixed():
    """Bucketed Engine.remove/replace_landmark == the fixed-dispatch
    module functions (slice/scatter soundness for the decremental path)."""
    from repro.core import engine as eng

    X, spec, K, _ = _setup(n=30)
    buk = eng.Engine(spec, eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
                     adjusted=False)
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:5]),
                                 capacity=24, spec=spec, dtype=jnp.float64)
    for m in range(5, 12):
        state = buk.add_landmark(state, jnp.asarray(X), jnp.asarray(X[m]))
    a = buk.remove_landmark(state, 3)
    b = nystrom.remove_landmark(state, jnp.int32(3), spec)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(a)),
                               np.asarray(nystrom.reconstruct_tilde(b)),
                               atol=1e-10)
    c = buk.replace_landmark(state, jnp.asarray(X), 3, jnp.asarray(X[25]))
    d = nystrom.replace_landmark(state, jnp.asarray(X), jnp.int32(3),
                                 jnp.asarray(X[25]), spec)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(c)),
                               np.asarray(nystrom.reconstruct_tilde(d)),
                               atol=1e-10)


def test_remove_landmark_grow_rows_keeps_observed_stream():
    """In grow_rows mode an ex-landmark stays an observed ROW: only its
    column dies, and the reconstruction matches batch on the survivors."""
    X = RNG.normal(size=(20, 3))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    state = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=12,
                                 spec=spec, dtype=jnp.float64,
                                 grow_rows=True)
    state = nystrom.observe_rows(state, jnp.asarray(X[4:]), spec)
    for i in range(4, 9):
        state = nystrom.add_landmark(state, None, jnp.asarray(X[i]), spec)
    n_rows = state.Knm.shape[0]
    st2 = nystrom.remove_landmark(state, jnp.int32(1), spec)
    assert st2.Knm.shape[0] == n_rows
    assert st2.Xrows.shape == state.Xrows.shape
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    keep = [0, 2, 3, 4, 5, 6, 7, 8]
    ref = K[:, keep] @ np.linalg.solve(K[np.ix_(keep, keep)], K[:, keep].T)
    np.testing.assert_allclose(np.asarray(nystrom.reconstruct_tilde(st2)),
                               ref, atol=1e-9)


def test_leverage_and_residual_scores():
    X, spec, K, state = _setup(n=30)
    for m in range(5, 12):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    lev = np.asarray(nystrom.leverage_scores(state, reg=1e-2))
    m = int(state.kpca.m)
    assert (lev[:m] > 0).all() and (lev[:m] <= 1.0 + 1e-9).all()
    assert np.abs(lev[m:]).max() == 0.0
    # a landmark is spanned: residual ~ 0; a held-out point is not
    assert float(nystrom.admission_residual(state, jnp.asarray(X[3]),
                                            spec)) < 1e-10
    assert float(nystrom.admission_residual(state, jnp.asarray(X[25]),
                                            spec)) > 1e-4


def test_trace_error_matches_offline_trace_norm():
    """trace_error (O(n·m), no n×n matrix) must equal the trace norm of
    K − K̃ (K − K̃ is PSD for Nyström)."""
    X, spec, K, state = _setup(n=40)
    for m in range(5, 12):
        state = nystrom.add_landmark(state, jnp.asarray(X),
                                     jnp.asarray(X[m]), spec)
    te = float(nystrom.trace_error(state, spec, x_all=jnp.asarray(X)))
    off = nystrom.approximation_error(
        jnp.asarray(K), jnp.asarray(nystrom.reconstruct_tilde(state))).trace
    np.testing.assert_allclose(te, off, rtol=1e-8)


def test_sufficient_subset_rule():
    rule = nystrom.SufficientSubsetRule(rel_tol=0.05, patience=2)
    assert not rule.observe(10.0)
    assert not rule.observe(5.0)        # big improvement resets
    assert not rule.observe(4.9)        # flat 1
    assert rule.observe(4.89)           # flat 2 -> sufficient
    assert rule.sufficient
    # improvement after sufficiency would reset the counter
    rule2 = nystrom.SufficientSubsetRule(rel_tol=0.05, patience=2)
    rule2.observe(10.0); rule2.observe(9.99)
    assert not rule2.observe(5.0)


def test_consider_landmark_policy_paths():
    """The leverage admission policy takes all three actions and the
    error never regresses through a replace."""
    from repro.core import engine as eng

    X, spec, K, _ = _setup(n=40)
    engine = eng.Engine(spec, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8), adjusted=False)
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:5]),
                                 capacity=24, spec=spec, dtype=jnp.float64)
    actions = []
    for i in range(5, 40):
        state, act = nystrom.consider_landmark(
            engine, state, jnp.asarray(X[i]), x_all=jnp.asarray(X),
            budget=10)
        actions.append(act)
    assert "admitted" in actions and "rejected" in actions
    assert int(state.kpca.m) <= 10
    # a duplicate of an existing landmark is always rejected
    state2, act = nystrom.consider_landmark(engine, state,
                                            jnp.asarray(X[0]),
                                            x_all=jnp.asarray(X), budget=10)
    assert act == "rejected"
    assert state2 is state


def test_offer_landmark_routes_on_plan_policy():
    """UpdatePlan.landmark_policy drives Engine.offer_landmark: append
    admits anything below budget (even a duplicate), leverage rejects
    spanned candidates and replaces at budget."""
    from repro.core import engine as eng

    X, spec, K, _ = _setup(n=30)
    state0 = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:5]),
                                  capacity=24, spec=spec,
                                  dtype=jnp.float64)
    app = eng.Engine(spec, eng.UpdatePlan(landmark_policy="append"),
                     adjusted=False)
    lev = eng.Engine(spec, eng.UpdatePlan(landmark_policy="leverage"),
                     adjusted=False)
    dup = jnp.asarray(X[0])                   # already a landmark
    st, act = app.offer_landmark(state0, dup, x_all=jnp.asarray(X))
    assert act == "admitted" and int(st.kpca.m) == 6
    st, act = lev.offer_landmark(state0, dup, x_all=jnp.asarray(X))
    assert act == "rejected" and int(st.kpca.m) == 5
    # append rejects only at budget
    st, act = app.offer_landmark(state0, dup, x_all=jnp.asarray(X),
                                 budget=5)
    assert act == "rejected"
    with pytest.raises(ValueError):
        eng.Engine(spec, eng.UpdatePlan(landmark_policy="bogus"),
                   adjusted=False).offer_landmark(state0, dup)


def test_replace_landmark_donate_matches_copy_at_full_bucket():
    """donate=True must produce the same state as the copying spelling,
    including for a fixed-dispatch plan where Mb == M (the donation
    previously silently degraded there)."""
    from repro.core import engine as eng

    X, spec, K, _ = _setup(n=30)
    engine = eng.Engine(spec, eng.UpdatePlan(), adjusted=False)  # fixed
    state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:5]),
                                 capacity=24, spec=spec,
                                 dtype=jnp.float64)
    for m in range(5, 10):
        state = engine.add_landmark(state, jnp.asarray(X),
                                    jnp.asarray(X[m]))
    x_new = jnp.asarray(X[20])
    ref = engine.replace_landmark(state, jnp.asarray(X), 2, x_new)
    # donation consumes its input: hand it a throwaway copy
    spare = jax.tree.map(lambda leaf: leaf + 0, state)
    out = engine.replace_landmark(spare, jnp.asarray(X), 2, x_new,
                                  donate=True)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grow_rows_argument_validation():
    X = jnp.asarray(RNG.normal(size=(6, 3)))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    import pytest
    with pytest.raises(ValueError):
        nystrom.init_nystrom(X, X[:2], capacity=8, spec=spec, grow_rows=True)
    with pytest.raises(ValueError):
        nystrom.init_nystrom(None, X[:2], capacity=8, spec=spec)
    fixed = nystrom.init_nystrom(X, X[:2], capacity=8, spec=spec,
                                 dtype=jnp.float64)
    with pytest.raises(ValueError):
        nystrom.observe_rows(fixed, X[3], spec)


# ------------------------------------------- incremental trace_error ----
def test_admission_trace_delta_matches_exact_recompute():
    """Δtrace from the Schur rank-one identity (O(n·m)) must equal the
    before/after difference of the exact O(n·m²) trace_error."""
    from repro.core import engine as eng

    rng = np.random.default_rng(43)
    d = 3
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    engine = eng.Engine(spec, eng.UpdatePlan(), adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True,
                                 dtype=jnp.float64)
    state = nystrom.observe_rows(state, jnp.asarray(rng.normal(size=(20, d))),
                                 spec)
    for _ in range(5):
        x = jnp.asarray(rng.normal(size=(d,)))
        state = nystrom.observe_rows(state, x, spec)
        before = float(nystrom.trace_error(state, spec))
        delta, res = nystrom.admission_trace_delta(state, x, spec)
        state = engine.add_landmark(state, None, x)
        after = float(nystrom.trace_error(state, spec))
        assert float(res) > 0
        np.testing.assert_allclose(before - after, float(delta), atol=1e-9)


def test_trace_error_tracker_drift_vs_exact_every_k():
    """Drive the full lifecycle (observe/admit/replace/reject) through a
    TraceErrorTracker and compare against the exact recompute every K
    admissions — the incremental value must not drift (ISSUE satellite)."""
    from repro.core import engine as eng

    rng = np.random.default_rng(47)
    d, K_CHECK = 3, 4
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    engine = eng.Engine(spec, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8,
                                             landmark_policy="leverage"),
                        adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True,
                                 dtype=jnp.float64)
    tracker = nystrom.TraceErrorTracker(state, spec, resync_every=1000)
    admits, checked, actions = 0, 0, set()
    for i in range(60):
        x = jnp.asarray(rng.normal(size=(d,)))
        tracker.observe(state, x)
        state = nystrom.observe_rows(state, x, spec)
        prev = state
        state, action = engine.offer_landmark(state, x, budget=10)
        actions.add(action)
        if action == "admitted":
            tracker.admitted(prev, x)
            admits += 1
        elif action == "replaced":
            tracker.replaced(state)
        if action == "admitted" and admits % K_CHECK == 0:
            exact = float(nystrom.trace_error(state, spec))
            np.testing.assert_allclose(tracker.value, exact, atol=1e-8)
            checked += 1
    assert checked >= 1 and "admitted" in actions
    # the whole run stays in lockstep with the exact value, not just the
    # checked admissions
    np.testing.assert_allclose(tracker.value,
                               float(nystrom.trace_error(state, spec)),
                               atol=1e-8)


def test_trace_error_tracker_periodic_resync_fires():
    from repro.core import engine as eng

    rng = np.random.default_rng(53)
    d = 3
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    engine = eng.Engine(spec, eng.UpdatePlan(), adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True,
                                 dtype=jnp.float64)
    tracker = nystrom.TraceErrorTracker(state, spec, resync_every=2)
    for _ in range(4):
        x = jnp.asarray(rng.normal(size=(d,)))
        tracker.observe(state, x)
        state = nystrom.observe_rows(state, x, spec)
        prev = state
        state = engine.add_landmark(state, None, x)
        tracker.admitted(prev, x)
        tracker.maybe_resync(state)
    assert not tracker._pending_resync
    np.testing.assert_allclose(tracker.value,
                               float(nystrom.trace_error(state, spec)),
                               atol=1e-10)


def test_trace_error_fallbacks_without_x_all():
    """Fixed-row states without x_all must fall back to the stored
    landmark rows (n == m) or the constant kernel diagonal instead of
    raising; only the genuinely underdetermined case raises."""
    from repro.core import engine as eng

    rng = np.random.default_rng(59)
    d = 3
    poly = kf.KernelSpec(name="poly", degree=2, coef0=1.0)
    x_all = jnp.asarray(rng.normal(size=(6, d)))
    epoly = eng.Engine(poly, eng.UpdatePlan(), adjusted=False)
    st = nystrom.init_nystrom(x_all, x_all[:2], 16, poly, dtype=jnp.float64)
    for i in range(2, 6):
        st = epoly.add_landmark(st, x_all, x_all[i])
    # n == m: every observed row is a stored landmark — covered
    np.testing.assert_allclose(
        float(nystrom.trace_error(st, poly)),
        float(nystrom.trace_error(st, poly, x_all)), atol=1e-12)
    # constant-diagonal kernel: covered at any n
    rbf = kf.KernelSpec(name="rbf", sigma=3.0)
    x_all2 = jnp.asarray(rng.normal(size=(9, d)))
    st2 = nystrom.init_nystrom(x_all2, x_all2[:3], 16, rbf,
                               dtype=jnp.float64)
    np.testing.assert_allclose(
        float(nystrom.trace_error(st2, rbf)),
        float(nystrom.trace_error(st2, rbf, x_all2)), atol=1e-12)
    # non-constant diagonal + rows beyond the landmarks: underdetermined
    st3 = nystrom.init_nystrom(x_all2, x_all2[:3], 16, poly,
                               dtype=jnp.float64)
    with pytest.raises(ValueError):
        nystrom.trace_error(st3, poly)
    # n == m but a landmark came from OUTSIDE the observed rows: the
    # stored points do NOT cover the stream — the count coincidence must
    # not silently mix the two sets (Knm consistency check catches it)
    st4 = nystrom.init_nystrom(x_all, x_all[:2], 16, poly,
                               dtype=jnp.float64)
    for i in range(2, 5):
        st4 = epoly.add_landmark(st4, x_all, x_all[i])
    st4 = epoly.add_landmark(st4, x_all,
                             jnp.asarray(rng.normal(size=(d,))))
    assert int(st4.kpca.m) == x_all.shape[0]          # n == m holds...
    with pytest.raises(ValueError):
        nystrom.trace_error(st4, poly)                # ...but still raises
