"""Nyström attention: kernel factorization identity, approximation quality,
serve-time landmark growth via the paper's Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernels_fn as kf
from repro.models import nystrom_attention as nys
from repro.models.config import ArchConfig
from repro.models.layers import attention_apply

RNG = np.random.default_rng(9)


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=1, d_model=32, n_heads=4,
                n_kv_heads=2, d_ff=64, vocab=64, attention="nystrom",
                nystrom_landmarks=16, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def test_softmax_kernel_rbf_factorization():
    """exp(q·k/√d) == c(q)·g(q,k)·c(k) with σ = 2√d (the paper's RBF)."""
    d = 16
    q = jnp.asarray(RNG.normal(size=(5, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(7, d)), jnp.float32)
    sigma = 2.0 * np.sqrt(d)
    g = nys._rbf(q, k, sigma)
    cq = jnp.exp(jnp.sum(q * q, -1) / sigma)
    ck = jnp.exp(jnp.sum(k * k, -1) / sigma)
    lhs = jnp.exp(q @ k.T / np.sqrt(d))
    rhs = cq[:, None] * g * ck[None, :]
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4)


def test_prefill_finite_and_causal():
    cfg = _cfg()
    B, T = 2, 32
    p = nys.nystrom_attention_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(B, T, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    y = nys.nystrom_attention_apply(p, cfg, x, pos, chunk=8)
    assert y.shape == (B, T, cfg.d_model)
    assert bool(jnp.isfinite(y).all())
    # chunk-causality: future chunks don't affect past outputs
    x2 = x.at[:, -8:].add(1.0)
    y2 = nys.nystrom_attention_apply(p, cfg, x2, pos, chunk=8)
    np.testing.assert_allclose(np.asarray(y[:, :-8]), np.asarray(y2[:, :-8]),
                               atol=1e-5)


def test_first_chunk_matches_exact_attention():
    """Within the first chunk there is no Nyström term — the output must be
    EXACT softmax attention."""
    cfg = _cfg(nystrom_landmarks=8)
    B, T = 2, 8
    p = nys.nystrom_attention_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(RNG.normal(size=(B, T, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    y_nys = nys.nystrom_attention_apply(p, cfg, x, pos, chunk=T)
    y_full = attention_apply(p, cfg, x, pos)
    np.testing.assert_allclose(np.asarray(y_nys), np.asarray(y_full),
                               rtol=2e-3, atol=2e-3)


def test_decode_state_is_context_length_independent():
    cfg = _cfg()
    B = 2
    p = nys.nystrom_attention_init(jax.random.PRNGKey(0), cfg)
    cache = nys.nystrom_cache_init(p, cfg, B)
    m = cfg.nystrom_landmarks
    assert cache.psi.shape == (B, cfg.n_kv_heads, m, cfg.hd)
    for t in range(12):
        x = jnp.asarray(RNG.normal(size=(B, 1, cfg.d_model)), jnp.float32)
        y, cache = nys.nystrom_decode(p, cfg, x, cache,
                                      jnp.full((B, 1), t, jnp.int32))
        assert cache.psi.shape == (B, cfg.n_kv_heads, m, cfg.hd)
    assert bool(jnp.isfinite(y).all())


def test_grow_landmark_uses_alg1_and_matches_batch():
    """grow_landmark (Algorithm 1 on the landmark gram) must reproduce the
    batch eigendecomposition of the grown landmark set."""
    from repro.core import inkpca
    hd = 8
    sigma = 2.0 * np.sqrt(hd)
    M = 12
    m0 = 6
    lms = np.zeros((M, hd))
    lms[:m0] = RNG.normal(size=(m0, hd))
    spec = kf.KernelSpec(name="rbf", sigma=float(sigma))
    st = inkpca.init_state(jnp.asarray(lms[:m0]), M, spec, adjusted=False,
                           dtype=jnp.float64)
    L, U, mact, X = st.L, st.U, st.m, jnp.asarray(lms)
    new1 = jnp.asarray(RNG.normal(size=hd))
    X2, L2, U2, m2 = nys.grow_landmark(X, L, U, mact, new1, sigma)
    assert int(m2) == m0 + 1
    grown = np.vstack([lms[:m0], np.asarray(new1)[None]])
    G = np.asarray(kf.gram_block(jnp.asarray(grown), jnp.asarray(grown),
                                 spec=spec))
    lam_ref = np.linalg.eigh(G)[0]
    lam_inc = np.sort(np.asarray(L2[: m0 + 1]))
    np.testing.assert_allclose(lam_inc, lam_ref, atol=1e-8)
    # and the maintained G^{-1} matches the direct inverse
    Ginv = np.asarray(nys.ginv_from_eig(L2, U2, m2, jitter=0.0))
    np.testing.assert_allclose(Ginv[: m0 + 1, : m0 + 1], np.linalg.inv(G),
                               rtol=1e-6, atol=1e-8)


def test_nystrom_read_out_approximates_full_attention_decode():
    """With landmarks covering the key distribution, the Nyström decode
    read-out approximates exact softmax attention over the history."""
    hd = 8
    sigma = 2.0 * np.sqrt(hd)
    S = 64
    keys = RNG.normal(size=(S, hd)) * 0.5
    vals = RNG.normal(size=(S, hd))
    q = RNG.normal(size=(hd,)) * 0.5
    # landmarks = a subset of the keys themselves (good coverage)
    lms = keys[:: S // 16][:16]
    g_lk = np.exp(-((lms[:, None] - keys[None]) ** 2).sum(-1) / sigma)
    ck = np.exp((keys ** 2).sum(-1) / sigma)
    G = np.exp(-((lms[:, None] - lms[None]) ** 2).sum(-1) / sigma)
    psi = (g_lk * ck[None, :]) @ vals
    zeta = (g_lk * ck[None, :]).sum(1)
    phiq = np.exp(-((q[None] - lms) ** 2).sum(-1) / sigma)
    r = phiq @ np.linalg.inv(G + 1e-6 * np.eye(16))
    approx = (r @ psi) / (r @ zeta)
    w = np.exp(keys @ q / np.sqrt(hd))
    exact = (w @ vals) / w.sum()
    err = np.abs(approx - exact).max() / np.abs(exact).max()
    assert err < 0.15, err
