"""Unified telemetry layer (``core/telemetry`` + ``repro.obs``).

The two invariants that make metrics free to turn on are locked in here:

* **Bitwise identity** — a stream with ``plan.metrics`` on runs the
  *identical* jitted update callables as one with it off, on every
  dispatch path (plain, guarded, windowed, multi-tenant, P=2 sharded);
  the eigensystem/ring/clock leaves must be bitwise equal.
* **Exact counters** — ingests/rejections/evictions are identities over
  values the updates already produce, checked against a pure-Python
  oracle over a long mixed stream (growth, full-window eviction,
  quarantined NaNs, block and single-point entry points).
"""
import os
import subprocess
import sys
import urllib.request
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import engine as eng
from repro.core import health as hl
from repro.core import inkpca
from repro.core import kernels_fn as kf
from repro.core import telemetry as tm
from repro.testing import faults

SPEC = kf.KernelSpec(name="rbf", sigma=2.0)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y, equal_nan=True)) for x, y in zip(la, lb))


def _drive(stream, X, poison_at=()):
    """Mixed driver: singles, one block, optional NaN injections."""
    n = X.shape[0]
    for i in range(n // 2):
        x = X[i]
        if i in poison_at:
            x = jnp.asarray(faults.nan_point(X.shape[1]))
        stream.update(x)
    rest = np.array(X[n // 2:])
    for i in poison_at:
        if 0 <= i - n // 2 < rest.shape[0]:
            rest[i - n // 2] = np.nan
    stream.update_block(jnp.asarray(rest))


# ------------------------------------------------- bitwise identity ------
@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("health", [False, True])
def test_metrics_on_off_bitwise_single_stream(window, health):
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.normal(size=(26, 4)))
    policy = hl.DEFAULT_POLICY if health else None
    poison = (7, 15) if health else ()
    streams = []
    for metrics in (False, True):
        plan = eng.UpdatePlan(health=policy, metrics=metrics)
        s = inkpca.KPCAStream(X[:4], 16, SPEC, adjusted=not window,
                              plan=plan, dtype=jnp.float64, window=window)
        _drive(s, X[4:], poison_at=poison)
        streams.append(s)
    off, on = streams
    assert _leaves_equal(off.state, on.state)
    assert off.metrics is None and on.metrics is not None
    rep = on.metrics_report()
    offered = 22
    assert rep["rejections"] == len(poison)
    assert rep["ingests"] == offered - len(poison)
    assert rep["m"] == float(int(on.kpca_state.m))
    if window:
        assert rep["evictions"] == rep["ingests"] - (int(on.kpca_state.m) - 4)
        assert rep["window_fill"] == pytest.approx(
            int(on.kpca_state.m) / window)
    else:
        assert rep["evictions"] == 0
        assert rep["window_fill"] == tm.GAUGE_UNSET


def test_metrics_on_off_bitwise_streambatch():
    rng = np.random.default_rng(4)
    B, d = 3, 4
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    steps = [jnp.asarray(rng.normal(size=(B, d))) for _ in range(12)]
    bad = np.array(steps[5])
    bad[1] = np.nan
    steps[5] = jnp.asarray(bad)
    batches = []
    for metrics in (False, True):
        plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY, metrics=metrics)
        b = eng.StreamBatch(x0, 16, SPEC, plan=plan, dtype=jnp.float64,
                            window=8)
        for xs in steps[:8]:
            b.update(xs)
        b.update_block(jnp.stack(steps[8:]))      # (T, B, d)
        b.publish(4)
        batches.append(b)
    off, on = batches
    off._flush(), on._flush()
    assert _leaves_equal(off._full, on._full)
    rep = on.metrics_report()
    np.testing.assert_array_equal(rep["rejections"], [0, 1, 0])
    np.testing.assert_array_equal(rep["ingests"], [12, 11, 12])
    np.testing.assert_array_equal(rep["publishes"], [1, 1, 1])
    assert rep["ingests_total"] == 35


# ------------------------------------------------- counter exactness -----
def test_counter_oracle_500_step_mixed_stream():
    """500 offered points through a guarded sliding window, counted
    against a pure-Python oracle (NaN every 23rd point, singles and
    blocks interleaved)."""
    rng = np.random.default_rng(5)
    W, d = 12, 3
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY, metrics=True)
    s = inkpca.KPCAStream(jnp.asarray(rng.normal(size=(4, d))), 16, SPEC,
                          adjusted=False, plan=plan, dtype=jnp.float64,
                          window=W)
    oracle = {"ingests": 0, "rejections": 0, "evictions": 0, "m": 4}
    offered = 0
    buf = []

    def offer(x):
        nonlocal offered
        offered += 1
        if not np.isfinite(x).all():
            oracle["rejections"] += 1
            return
        oracle["ingests"] += 1
        if oracle["m"] == W:
            oracle["evictions"] += 1
        else:
            oracle["m"] += 1

    while offered < 500:
        x = rng.normal(size=(d,))
        if offered % 23 == 7:
            x = x * np.nan
        offer(x)
        buf.append(x)
        # flush as a block every 9 points, as singles otherwise
        if len(buf) == 9:
            s.update_block(jnp.asarray(np.stack(buf)))
            buf = []
        elif offered % 4 == 0:
            for b in buf:
                s.update(jnp.asarray(b))
            buf = []
    for b in buf:
        s.update(jnp.asarray(b))

    rep = s.metrics_report()
    assert rep["ingests"] == oracle["ingests"]
    assert rep["rejections"] == oracle["rejections"]
    assert rep["evictions"] == oracle["evictions"]
    assert rep["m"] == float(oracle["m"]) == float(int(s.kpca_state.m))
    assert int(s.state.clock) == oracle["ingests"] + 4   # + seed rows


def test_stacked_lanes_match_per_tenant_streams():
    """B metric lanes through the vmapped StreamBatch == B independent
    KPCAStream loops over the same per-tenant points."""
    rng = np.random.default_rng(6)
    B, d, W = 3, 4, 8
    x0 = np.asarray(rng.normal(size=(B, 4, d)))
    steps = np.asarray(rng.normal(size=(14, B, d)))
    steps[4, 2] = np.nan
    steps[9, 0] = np.nan

    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY, metrics=True)
    batch = eng.StreamBatch(jnp.asarray(x0), 16, SPEC, plan=plan,
                            dtype=jnp.float64, window=W)
    for xs in steps:
        batch.update(jnp.asarray(xs))
    got = batch.metrics_report()

    want = {k: [] for k in ("ingests", "rejections", "evictions", "m")}
    for t in range(B):
        s = inkpca.KPCAStream(jnp.asarray(x0[t]), 16, SPEC, adjusted=False,
                              plan=plan, dtype=jnp.float64, window=W)
        for i in range(steps.shape[0]):
            s.update(jnp.asarray(steps[i, t]))
        rep = s.metrics_report()
        for k in want:
            want[k].append(rep[k])
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]), err_msg=k)


# ------------------------------------------------- sharded path (P=2) ----
def test_sharded_window_metered_bitwise_subprocess():
    """P=2: the metered sharded window block wraps the UNMODIFIED inner
    executable — outputs bitwise equal to the plain builder's, and the
    riding MetricsState counts the NaN rejection from replicated scalars
    only (no extra collectives, shard-consistent)."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, health as hl, \
    inkpca, kernels_fn as kf, telemetry as tm
from repro.testing import faults
assert jax.device_count() == 2
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)
rng = np.random.default_rng(21)
X = rng.normal(size=(12, 4))
W = 8
stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64, window=W)
for i in range(4, 12):
    stream.update(jnp.asarray(X[i]))
ws = stream.state
xs = np.asarray(rng.normal(size=(6, 4)))
xs[2] = faults.nan_point(4)
xs = jnp.asarray(xs)
mesh = jax.make_mesh((2,), ("data",))
plan = eng.UpdatePlan(fuse_krow=True, matmul="jnp2",
                      health=hl.DEFAULT_POLICY)
wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=plan)
wbm = dkpca.make_sharded_window_block_metered(mesh, SPEC, plan=plan)
plain = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages, ws.clock, xs,
           ws.kpca.m)
ms = tm.init_metrics(jnp.float64)
metered = wbm(ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages, ws.clock, xs,
              ws.kpca.m, ms)
same = all(bool(jnp.array_equal(a, b)) for a, b in zip(plain, metered[:5]))
rep = tm.metrics_report(metered[5])
print("RESULT:" + str({"bitwise": same, "ingests": rep["ingests"],
                       "rejections": rep["rejections"],
                       "evictions": rep["evictions"],
                       "fill": rep["window_fill"]}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    res = eval(line[len("RESULT:"):])
    assert res == {"bitwise": True, "ingests": 5, "rejections": 1,
                   "evictions": 5, "fill": 1.0}


# ------------------------------------------------- plan normalization ----
def test_kernel_plan_normalizes_metrics():
    """``metrics`` is dispatch policy, not kernel policy: it must not
    split the jit cache key that kernel_plan() produces."""
    a = eng.UpdatePlan(metrics=True).kernel_plan()
    b = eng.UpdatePlan(metrics=False).kernel_plan()
    assert a == b


# ------------------------------------------------- hub + exporters -------
def test_latency_histogram_compile_split():
    h = obs.LatencyHistogram("update_ms")
    h.add(100.0, key="rung0")    # first per key -> compile bucket
    h.add(1.0, key="rung0")
    h.add(2.0, key="rung0")
    h.add(50.0, key="rung1")
    s = h.summary("update_ms")
    assert s["update_ms_compiles"] == 2
    assert s["update_ms_compile_ms"] == 150.0
    assert s["update_ms_p50"] == 1.5
    assert s["update_ms_max"] == 2.0
    with h.timed(key="rung0") as t:
        t.sync(jnp.ones((2,)))
    assert len(h.ms) == 3


def test_exporter_roundtrip(tmp_path):
    hub = obs.TelemetryHub()
    hub.counter("pub_total").inc(3)
    hub.counter("lm_total", action="admitted").inc(2)
    hub.gauge("drift").set(0.25)
    hist = hub.histogram("query_ms")
    for v in (4.0, 1.0, 2.0, 3.0):
        hist.add(v, key="warm")   # first sample per key -> compile bucket
    hub.emit({"event": "publish", "generation": 1})

    text = hub.to_prometheus()
    parsed = obs.parse_prometheus(text)
    assert parsed["pub_total"] == 3.0
    assert parsed['lm_total{action="admitted"}'] == 2.0
    assert parsed["drift"] == 0.25
    assert parsed['query_ms{quantile="0.5"}'] == 2.0
    assert parsed["query_ms_count"] == 3.0
    assert parsed["query_ms_compiles"] == 1.0
    # every scrape counter/gauge survives the text round trip
    for k, v in hub.scrape().items():
        if k in parsed:
            assert parsed[k] == pytest.approx(v)

    path = tmp_path / "metrics.jsonl"
    obs.write_jsonl(path, hub)
    events = obs.read_jsonl(path)
    assert events[0]["event"] == "publish"
    assert events[-1]["event"] == "scrape"
    assert events[-1]["pub_total"] == 3.0

    srv = obs.serve_metrics(hub, 0)
    try:
        port = srv.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        assert obs.parse_prometheus(body) == parsed
    finally:
        srv.shutdown()


def test_hub_mirrors_metrics_state():
    hub = obs.TelemetryHub()
    ms = tm.note_publish(tm.init_metrics(), 2)
    hub.observe_metrics_state(ms)
    sc = hub.scrape()
    assert sc["stream_publishes_total"] == 1.0
    assert sc["stream_generation"] == 2.0
    hub.observe_metrics_state(tm.init_metrics_stacked(2), prefix="lane")
    sc = hub.scrape()
    assert sc['lane_m{tenant="1"}'] == 0.0
    assert sc["lane_ingests_total"] == 0.0


def test_kernel_dispatch_counter():
    from repro.kernels.rbf_gram import ops as gops

    hub = obs.fresh_hub()
    x = jnp.ones((4, 2))
    gops.gram(x, x, 1.0)
    gops.gram(x, x, 1.0, force="ref")
    key = 'kernel_dispatch_total{kernel="rbf_gram",route="ref"}'
    assert hub.scrape()[key] == 2.0


# ------------------------------------------------- serving loop ----------
def _make_loop(drift_probe_every, serve_every=1000):
    from repro.launch.serve import IngestServeLoop

    rng = np.random.default_rng(7)
    B, d = 2, 4
    plan = eng.UpdatePlan(serve_every=serve_every, serve_components=4,
                          health=hl.DEFAULT_POLICY)
    batch = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d))), 16,
                            SPEC, plan=plan, dtype=jnp.float64)
    loop = IngestServeLoop(batch, SPEC, n_components=4,
                           publish_on_drift=10.0,   # never trips
                           drift_probe_every=drift_probe_every,
                           hub=obs.TelemetryHub())
    return loop, rng, (B, d)


@pytest.mark.parametrize("every,expected", [(1, 9), (3, 3)])
def test_drift_probe_rate_limited(every, expected):
    """Regression for the per-ingest drift probe: with ``--publish-on-
    drift`` the probe dispatch must fire every k-th non-publish ingest,
    not every one.  Counted two ways: the loop's own counter and a
    wrapped ``probe_all``."""
    loop, rng, (B, d) = _make_loop(every)
    calls = {"n": 0}
    inner = loop.batch.probe_all

    def counting_probe_all(*a, **k):
        calls["n"] += 1
        return inner(*a, **k)

    loop.batch.probe_all = counting_probe_all
    for _ in range(9):
        loop.ingest(jnp.asarray(rng.normal(size=(B, d))))
    assert loop.drift_probes == expected
    # probe_all also runs inside publish(); none happened here
    assert calls["n"] == expected
    assert loop.generation == 0


def test_drift_trigger_still_fires_with_rate_limit():
    loop, rng, (B, d) = _make_loop(3)
    loop.publish_on_drift = 1e-9    # any motion trips it
    published = 0
    for _ in range(6):
        published += bool(loop.ingest(jnp.asarray(rng.normal(size=(B, d)))))
    assert published >= 1
    assert loop.drift_publishes == published
    assert loop.hub.scrape()["publishes_total"] == published


# ------------------------------------------------- spectral monitor ------
def test_monitor_publishes_hub_gauges_and_drift():
    from repro.spectral import SpectralMonitor

    hub = obs.TelemetryHub()
    rng = np.random.default_rng(8)
    mon = SpectralMonitor(capacity=24, hub=hub)
    s1 = mon.observe(rng.normal(size=(12, 6)))
    assert s1["drift"] == 0.0
    s2 = mon.observe(rng.normal(size=(12, 6)))
    assert s2["drift"] > 0.0
    sc = hub.scrape()
    assert sc["spectral_drift"] == pytest.approx(s2["drift"])
    assert sc["spectral_m"] == s2["m"]
    assert sc["spectral_effective_rank"] == pytest.approx(
        s2["effective_rank"])
