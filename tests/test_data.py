"""Data pipeline: determinism, label alignment, dataset stand-ins."""
import jax.numpy as jnp
import numpy as np

from repro.data import TokenStream, load_dataset, magic_like, yeast_like


def test_stream_deterministic():
    s1 = TokenStream(vocab=100, seq_len=32, global_batch=4, seed=1)
    s2 = TokenStream(vocab=100, seq_len=32, global_batch=4, seed=1)
    b1 = s1.batch_at(jnp.int32(5))
    b2 = s2.batch_at(jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = s1.batch_at(jnp.int32(6))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    s = TokenStream(vocab=50, seq_len=16, global_batch=2, seed=0)
    b = s.batch_at(jnp.int32(0))
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert (np.asarray(b["labels"][:, -1]) == -1).all()


def test_stream_has_learnable_structure():
    """~Half the transitions follow a fixed permutation."""
    s = TokenStream(vocab=64, seq_len=256, global_batch=8, seed=2)
    b = np.asarray(s.batch_at(jnp.int32(0))["tokens"])
    # successor entropy must be far below uniform
    pair_counts = {}
    for row in b:
        for a, c in zip(row[:-1], row[1:]):
            pair_counts.setdefault(int(a), []).append(int(c))
    top_frac = np.mean([
        max(np.bincount(v).max() / len(v), 0.0)
        for v in pair_counts.values() if len(v) >= 10])
    assert top_frac > 0.35   # permutation followed ~50% of the time


def test_tokens_in_range():
    s = TokenStream(vocab=37, seq_len=64, global_batch=2, seed=3)
    b = np.asarray(s.batch_at(jnp.int32(1))["tokens"])
    assert b.min() >= 0 and b.max() < 37


def test_uci_like_shapes_and_stats():
    m = load_dataset("magic")
    y = load_dataset("yeast")
    assert m.shape == (19020, 10)
    assert y.shape == (1484, 8)
    # standardized
    np.testing.assert_allclose(m.mean(0), 0.0, atol=1e-9)
    np.testing.assert_allclose(m.std(0), 1.0, atol=1e-6)
    # deterministic
    np.testing.assert_array_equal(load_dataset("magic"), m)


def test_raw_generators():
    assert magic_like(n=100).shape[0] == 100 or magic_like().shape[0] == 19020
    assert yeast_like().shape == (1484, 8)
