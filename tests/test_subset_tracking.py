"""Dominant-subset tracking (paper conclusion): truncate to k eigenpairs
and keep streaming — the Hoegaerts-style regime."""
import numpy as np
import jax.numpy as jnp

from repro.core import batch, inkpca, kernels_fn as kf
import pytest

RNG = np.random.default_rng(21)


@pytest.mark.slow
def test_truncated_stream_tracks_dominant_eigenvalues():
    n, d, k = 40, 4, 8
    X = RNG.normal(size=(n, d))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)

    stream = inkpca.KPCAStream(jnp.asarray(X[:20]), capacity=n, spec=spec,
                               adjusted=False, dtype=jnp.float64)
    stream.truncate(k)
    stream.update_block(jnp.asarray(X[20:]))

    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    lam_ref = np.sort(np.asarray(batch.batch_kpca(jnp.asarray(K),
                                                  adjusted=False)[0]))[::-1]
    lam, _ = stream.eigpairs()
    lam_top = np.asarray(lam[:3])
    # truncated tracking is approximate: the discarded tail's energy folds
    # into the kept directions, so dominant eigenvalues OVER-estimate but
    # stay in the right regime (k=8 of 40 here -> within ~25%).
    rel = np.abs(lam_top - lam_ref[:3]) / lam_ref[:3]
    assert (rel < 0.25).all(), rel
    assert lam_top[0] >= 0.95 * lam_ref[0]       # no collapse
    assert np.isfinite(np.asarray(stream.state.L)).all()


def test_truncate_keeps_exactly_k_active():
    X = RNG.normal(size=(12, 3))
    spec = kf.KernelSpec(name="rbf", sigma=3.0)
    stream = inkpca.KPCAStream(jnp.asarray(X[:10]), capacity=12, spec=spec,
                               adjusted=False, dtype=jnp.float64)
    st = stream.truncate(4)
    assert int(st.m) == 4
    rec = np.asarray(stream.reconstruction())[:4, :4]
    assert np.isfinite(rec).all()
