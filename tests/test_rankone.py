"""Unit + property tests for the rank-one eigendecomposition update (§3.2).

The property tests need ``hypothesis``; when it is absent (the container
does not ship it) they are skipped via no-op decorator stand-ins so the
deterministic tests still collect and run.
"""
import numpy as np
import jax.numpy as jnp
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised in the container
    HAVE_HYPOTHESIS = False

    def given(**kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco

    def settings(**kwargs):
        def deco(fn):
            return fn
        return deco

    class _St:
        """Stand-in for hypothesis.strategies; decorators skip anyway."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _St()

from repro.core import rankone

RNG = np.random.default_rng(0)


def _padded_eigensystem(m, M, scale=1.0):
    A = RNG.normal(size=(m, m)) * scale
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M)
    U = np.eye(M)
    L[:m] = lam
    U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    return A, jnp.asarray(L), jnp.asarray(U)


@pytest.mark.parametrize("sigma", [0.5, -0.5, 4.0, -4.0])
@pytest.mark.parametrize("m,M", [(6, 8), (10, 10), (17, 32)])
def test_rank_one_update_matches_eigh(sigma, m, M):
    A, L, U = _padded_eigensystem(m, M)
    v = np.zeros(M)
    v[:m] = RNG.normal(size=m)
    L2, U2 = rankone.rank_one_update(L, U, jnp.asarray(v),
                                     jnp.float64(sigma), jnp.int32(m))
    B = A + sigma * np.outer(v[:m], v[:m])
    lam_ref = np.linalg.eigh(B)[0]
    np.testing.assert_allclose(np.sort(np.asarray(L2[:m])), lam_ref,
                               rtol=1e-9, atol=1e-9)
    rec = np.asarray(rankone.reconstruct(L2, U2, jnp.int32(m)))[:m, :m]
    np.testing.assert_allclose(rec, B, rtol=1e-8, atol=1e-8)


@pytest.mark.parametrize("method", ["gu", "bns"])
def test_orthogonality_after_update(method):
    m, M = 12, 16
    _, L, U = _padded_eigensystem(m, M)
    v = np.zeros(M)
    v[:m] = RNG.normal(size=m)
    L2, U2 = rankone.rank_one_update(L, U, jnp.asarray(v), jnp.float64(1.3),
                                     jnp.int32(m), method=method)
    G = np.asarray(U2[:m, :m]).T @ np.asarray(U2[:m, :m])
    assert np.abs(G - np.eye(m)).max() < 1e-8


@settings(max_examples=25, deadline=None)
@given(m=st.integers(3, 12), sigma=st.floats(-5.0, 5.0),
       seed=st.integers(0, 10_000))
def test_interlacing_bounds(m, sigma, seed):
    """Paper eq. (5): updated eigenvalues interlace the old ones."""
    if abs(sigma) < 1e-3:
        sigma = 1e-3
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    v = rng.normal(size=m)
    z = vec.T @ v
    lam2 = np.linalg.eigh(A + sigma * np.outer(v, v))[0]
    tol = 1e-8 * max(1.0, np.abs(lam).max())
    if sigma > 0:
        for i in range(m - 1):
            assert lam[i] - tol <= lam2[i] <= lam[i + 1] + tol
        assert lam[-1] - tol <= lam2[-1] <= lam[-1] + sigma * z @ z + tol
    else:
        for i in range(1, m):
            assert lam[i - 1] - tol <= lam2[i] <= lam[i] + tol
        assert lam[0] + sigma * z @ z - tol <= lam2[0] <= lam[0] + tol


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 10), seed=st.integers(0, 10_000),
       sigma=st.sampled_from([0.7, -0.7, 2.5, -2.5]))
def test_update_matches_eigh_property(m, seed, sigma):
    rng = np.random.default_rng(seed)
    M = m + rng.integers(0, 4)
    A = rng.normal(size=(m, m))
    A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M); U = np.eye(M)
    L[:m] = lam; U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    v = np.zeros(M); v[:m] = rng.normal(size=m)
    L2, _ = rankone.rank_one_update(jnp.asarray(L), jnp.asarray(U),
                                    jnp.asarray(v), jnp.float64(sigma),
                                    jnp.int32(m))
    lam_ref = np.linalg.eigh(A + sigma * np.outer(v[:m], v[:m]))[0]
    np.testing.assert_allclose(np.sort(np.asarray(L2[:m])), lam_ref,
                               rtol=1e-7, atol=1e-7)


def test_expand_eigensystem():
    m, M = 5, 8
    A, L, U = _padded_eigensystem(m, M)
    L2, U2, m2 = rankone.expand_eigensystem(L, U, jnp.float64(0.33),
                                            jnp.int32(m))
    assert int(m2) == m + 1
    rec = np.asarray(rankone.reconstruct(L2, U2, m2))[:m + 1, :m + 1]
    ref = np.zeros((m + 1, m + 1))
    ref[:m, :m] = A
    ref[m, m] = 0.33
    np.testing.assert_allclose(rec, ref, atol=1e-10)


def test_deflation_clamp_tiny_z():
    """v orthogonal to U's range (z ~ 0) must not produce NaNs."""
    m, M = 6, 8
    _, L, U = _padded_eigensystem(m, M)
    v = np.zeros(M)  # exactly zero update
    L2, U2 = rankone.rank_one_update(L, U, jnp.asarray(v), jnp.float64(2.0),
                                     jnp.int32(m))
    assert np.isfinite(np.asarray(L2)).all()
    assert np.isfinite(np.asarray(U2)).all()


def test_sentinelize_keeps_active_sorted_top():
    L = jnp.asarray([3.0, 1.0, 0.0, 0.0])
    Ls = rankone.sentinelize(L, jnp.int32(2), jnp.float64(0.0))
    assert float(Ls[2]) > 3.0 and float(Ls[3]) > float(Ls[2])


# --------------------------------------------- fused-pair merge fallback ---
def _clustered_eigensystem(m, M, n_cluster, seed, width=1e-14):
    """Eigensystem with a near-degenerate cluster (dlaed2 territory)."""
    rng = np.random.default_rng(seed)
    lam = np.sort(np.concatenate([
        2.0 + rng.normal(size=n_cluster) * width,
        rng.uniform(3.0, 6.0, size=m - n_cluster)]))
    vec, _ = np.linalg.qr(rng.normal(size=(m, m)))
    L = np.zeros(M)
    U = np.eye(M)
    L[:m] = lam
    U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    v1 = np.zeros(M)
    v2 = np.zeros(M)
    v1[:m] = rng.normal(size=m)
    v2[:m] = rng.normal(size=m)
    return jnp.asarray(L), jnp.asarray(U), jnp.asarray(v1), jnp.asarray(v2)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("sigma", [1.3, -0.8])
def test_pair_merge_fallback_on_clustered_spectrum(seed, sigma):
    """When a dlaed2 cluster-merge fires, the fused pair must cond into the
    sequential two-update path (ROADMAP follow-up): eigenvalues AND
    orthogonality must match two rank_one_update calls exactly."""
    m, M = 10, 16
    L, U, v1, v2 = _clustered_eigensystem(m, M, n_cluster=4, seed=seed)
    z1 = U.T @ v1
    assert bool(rankone._merge_fires(L, z1, jnp.float64(sigma),
                                     jnp.int32(m)))

    Ls, Us = rankone.rank_one_update(L, U, v1, jnp.float64(sigma),
                                     jnp.int32(m))
    Ls, Us = rankone.rank_one_update(Ls, Us, v2, jnp.float64(-sigma),
                                     jnp.int32(m))
    Lp, Up = rankone.rank_one_update_pair(L, U, v1, jnp.float64(sigma),
                                          v2, jnp.float64(-sigma),
                                          jnp.int32(m))
    np.testing.assert_allclose(np.asarray(Lp[:m]), np.asarray(Ls[:m]),
                               atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(Up[:m, :m])),
                               np.abs(np.asarray(Us[:m, :m])), atol=1e-10)
    G = np.asarray(Up[:m, :m]).T @ np.asarray(Up[:m, :m])
    assert np.abs(G - np.eye(m)).max() < 1e-9


def test_pair_no_fallback_on_clean_spectrum():
    """A well-separated spectrum must NOT trip the fallback (the fused
    rotation is the steady-state path)."""
    m, M = 10, 16
    _, L, U = _padded_eigensystem(m, M)
    v = np.zeros(M)
    v[:m] = RNG.normal(size=m)
    z = jnp.asarray(U).T @ jnp.asarray(v)
    assert not bool(rankone._merge_fires(jnp.asarray(L), z,
                                         jnp.float64(1.3), jnp.int32(m)))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_cluster=st.integers(2, 6),
       sigma=st.sampled_from([0.7, -0.7, 2.5]))
def test_pair_merge_fallback_property(seed, n_cluster, sigma):
    """Property form: for random near-degenerate spectra the fused pair
    (with fallback) always reproduces the sequential path and keeps the
    updated eigenvectors orthogonal."""
    m, M = 9, 12
    L, U, v1, v2 = _clustered_eigensystem(m, M, n_cluster=n_cluster,
                                          seed=seed,
                                          width=10.0 ** -np.random.default_rng(
                                              seed).integers(12, 16))
    Ls, Us = rankone.rank_one_update(L, U, v1, jnp.float64(sigma),
                                     jnp.int32(m))
    Ls, Us = rankone.rank_one_update(Ls, Us, v2, jnp.float64(-sigma),
                                     jnp.int32(m))
    Lp, Up = rankone.rank_one_update_pair(L, U, v1, jnp.float64(sigma),
                                          v2, jnp.float64(-sigma),
                                          jnp.int32(m))
    np.testing.assert_allclose(np.asarray(Lp[:m]), np.asarray(Ls[:m]),
                               atol=1e-9)
    G = np.asarray(Up[:m, :m]).T @ np.asarray(Up[:m, :m])
    assert np.abs(G - np.eye(m)).max() < 1e-8
