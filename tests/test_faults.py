"""Fault-injection harness + crash-safety of the checkpoint store.

Kill-mid-save: ``npz_store.save_checkpoint`` embeds named killpoints at
every instant a real process can die.  Arming each one in turn simulates
a kill -9 at exactly that line; after every simulated crash the store's
``latest_step`` must still point at an INTACT, loadable checkpoint, and
the next successful save must leave no debris.

The P=2 subprocess test drives the sharded fused window path with a NaN
arrival: the quarantine verdict is computed from the replicated point, so
every shard rejects identically, the collective schedule never diverges,
and the final state is bitwise the one of a stream that never saw the
point.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.testing import faults

KILLPOINTS = ("checkpoint.mid_write", "checkpoint.after_write",
              "checkpoint.between_renames", "checkpoint.after_publish")


# ------------------------------------------------------------ harness --
def test_trip_is_noop_unless_armed():
    faults.trip("never.armed")          # must not raise
    assert not faults.armed("some.point")


def test_arm_trip_disarm_cycle():
    faults.arm("p1")
    assert faults.armed("p1")
    with pytest.raises(faults.FaultInjected) as ei:
        faults.trip("p1")
    assert ei.value.point == "p1"
    assert not faults.armed("p1")       # auto-disarmed on fire
    faults.trip("p1")                   # now a no-op again


def test_arm_after_skips_n_hits():
    faults.arm("p2", after=2)
    faults.trip("p2")
    faults.trip("p2")
    with pytest.raises(faults.FaultInjected):
        faults.trip("p2")


def test_injected_contextmanager_disarms():
    with pytest.raises(faults.FaultInjected):
        with faults.injected("p3"):
            faults.trip("p3")
    assert not faults.armed("p3")
    with faults.injected("p4"):
        pass
    assert not faults.armed("p4")


def test_fault_injected_not_caught_by_except_exception():
    faults.arm("p5")
    with pytest.raises(faults.FaultInjected):
        try:
            faults.trip("p5")
        except Exception:               # a recovery block must NOT eat it
            pytest.fail("FaultInjected was swallowed by except Exception")


# --------------------------------------------------------- corruptors --
def test_nan_point_kinds():
    for kind, val in (("nan", np.nan), ("inf", np.inf), ("-inf", -np.inf)):
        x = faults.nan_point(5, kind=kind, index=2)
        assert x.shape == (5,)
        if kind == "nan":
            assert np.isnan(x[2])
        else:
            assert x[2] == val
    base = np.arange(4.0)
    x = faults.nan_point(4, base=base, index=1)
    assert np.isnan(x[1]) and x[0] == 0.0 and x[3] == 3.0
    assert base[1] == 1.0               # base not mutated


def _state(dtype=jnp.float64):
    from repro.core import inkpca, kernels_fn as kf

    rng = np.random.default_rng(0)
    spec = kf.KernelSpec(name="rbf", sigma=2.0)
    return inkpca.init_state(jnp.asarray(rng.normal(size=(6, 3)), dtype),
                             8, spec, adjusted=True, dtype=dtype)


def test_bitflip_eigvec():
    st = _state()
    flipped = faults.bitflip_eigvec(st, 1, 2, bit=63)   # f64 sign bit
    U0, U1 = np.asarray(st.U), np.array(flipped.U)
    assert U1[1, 2] == -U0[1, 2]
    U1[1, 2] = U0[1, 2]
    np.testing.assert_array_equal(U0, U1)


def test_corrupt_eigvecs_touches_only_active_block():
    st = _state()
    bad = faults.corrupt_eigvecs(st, magnitude=0.1, seed=1)
    m = int(st.m)
    np.testing.assert_array_equal(np.asarray(bad.U[m:, :]),
                                  np.asarray(st.U[m:, :]))
    np.testing.assert_array_equal(np.asarray(bad.U[:, m:]),
                                  np.asarray(st.U[:, m:]))
    assert float(jnp.abs(bad.U - st.U).max()) > 0


def test_corrupt_eigenvalue_and_poison_row():
    st = _state()
    assert float(faults.corrupt_eigenvalue(st, 0, value=-2.0).L[0]) == -2.0
    assert np.isnan(np.asarray(faults.poison_stored_row(st, 1).X[1])).all()


# ------------------------------------------------------ kill-mid-save --
def _tree(step):
    return {"w": jnp.arange(6, dtype=jnp.float32) + step,
            "step": jnp.asarray(step, jnp.int32)}


def _shapes():
    return jax.eval_shape(lambda: _tree(0))


@pytest.mark.parametrize("point", KILLPOINTS)
def test_kill_mid_save_fresh_step(tmp_path, point):
    """Crash while writing step 2 (step 1 already on disk): latest_step
    must keep serving an intact checkpoint — step 1 for every pre-publish
    crash, step 2 once the publish rename happened."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    try:
        with faults.injected(point):
            save_checkpoint(d, 2, _tree(2))
        crashed = False
    except faults.FaultInjected:
        crashed = True
    # between_renames never trips for a FRESH step (no aside to rename);
    # after_publish trips after the checkpoint is already live.
    assert crashed == (point != "checkpoint.between_renames")
    step = latest_step(d)
    assert step in (1, 2)
    out = load_checkpoint(d, step, _shapes())
    assert int(out["step"]) == step
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(6, dtype=np.float32) + step)
    if point in ("checkpoint.mid_write", "checkpoint.after_write"):
        assert step == 1                # crash before publish: old survives
    if point == "checkpoint.after_publish":
        assert step == 2                # publish completed before the kill


@pytest.mark.parametrize("point", KILLPOINTS)
def test_kill_mid_overwrite_same_step(tmp_path, point):
    """Crash while OVERWRITING an existing step: either the old or the
    new content must load — never a torn directory."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _tree(3))
    save_checkpoint(d, 7, _tree(7))
    try:
        with faults.injected(point):
            save_checkpoint(d, 7, {"w": jnp.full((6,), -1.0, jnp.float32),
                                   "step": jnp.asarray(7, jnp.int32)})
    except faults.FaultInjected:
        pass
    step = latest_step(d)
    assert step in (3, 7)
    out = load_checkpoint(d, step, _shapes())
    w = np.asarray(out["w"])
    assert (np.array_equal(w, np.arange(6, dtype=np.float32) + step)
            or np.array_equal(w, np.full((6,), -1.0, np.float32)))


def test_recovery_save_cleans_debris(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree(1))
    for point in KILLPOINTS:
        try:
            with faults.injected(point):
                save_checkpoint(d, 2, _tree(2))
        except faults.FaultInjected:
            pass
    save_checkpoint(d, 3, _tree(3))
    names = os.listdir(d)
    assert all(".tmp-" not in n for n in names), names
    assert latest_step(d) == 3


# --------------------------------------- P=2 sharded NaN quarantine ---
def test_sharded_quarantine_multidevice_subprocess():
    """P=2: a NaN arrival on the sharded fused-window path is rejected
    identically on both shards (replicated verdict, fixed collective
    schedule — no divergence/deadlock) and the final state is bitwise the
    clean stream's; the quarantine count is recoverable from the clock."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, health as hl, \
    inkpca, kernels_fn as kf
from repro.testing import faults
assert jax.device_count() == 2
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)
rng = np.random.default_rng(21)
X = rng.normal(size=(12, 4))
W = 8
stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64, window=W)
for i in range(4, 12):
    stream.update(jnp.asarray(X[i]))
ws = stream.state
clean = jnp.asarray(rng.normal(size=(5, 4)))
bad = np.array(clean)
bad = np.insert(bad, 2, faults.nan_point(4).astype(np.float64), axis=0)
mesh = jax.make_mesh((2,), ("data",))
plan = eng.UpdatePlan(fuse_krow=True, matmul="jnp2",
                      health=hl.DEFAULT_POLICY)
wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=plan)
Lb, Ub, Xb, agesb, clockb = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages,
                               ws.clock, jnp.asarray(bad), ws.kpca.m)
Lc, Uc, Xc, agesc, clockc = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages,
                               ws.clock, clean, ws.kpca.m)
same = all(bool(jnp.array_equal(a, b)) for a, b in
           ((Lb, Lc), (Ub, Uc), (Xb, Xc), (agesb, agesc)))
quarantined = int(bad.shape[0] - (clockb - ws.clock))
print("RESULT:" + str({"bitwise": same, "quarantined": quarantined,
                       "clock_matches": int(clockb) == int(clockc)}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    res = eval(line[len("RESULT:"):])
    assert res == {"bitwise": True, "quarantined": 1, "clock_matches": True}
