"""Integration tests: incremental KPCA streams vs the batch eigh oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import batch, inkpca, kernels_fn as kf, rankone

RNG = np.random.default_rng(1)


def _data(n=30, d=4):
    X = RNG.normal(size=(n, d))
    sigma = float(np.median(((X[:, None] - X[None]) ** 2).sum(-1)))
    return X, kf.KernelSpec(name="rbf", sigma=sigma)


@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("kernel", ["rbf", "linear", "poly"])
def test_stream_matches_batch(adjusted, kernel):
    # the linear kernel needs d >= n for a full-rank gram — the paper
    # assumes the kernel matrix stays non-singular (§3, §5); degeneracy is
    # covered by test_rank_deficient_stream_stays_finite below.
    X, spec0 = _data(d=40) if kernel == "linear" else _data()
    spec = kf.KernelSpec(name=kernel, sigma=spec0.sigma)
    n = X.shape[0]
    stream = inkpca.KPCAStream(jnp.asarray(X[:6]), capacity=n, spec=spec,
                               adjusted=adjusted, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[6:]))
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    lam_ref = np.asarray(batch.batch_kpca(jnp.asarray(K),
                                          adjusted=adjusted)[0])
    lam_inc = np.sort(np.asarray(stream.state.L[:n]))
    scale = max(1.0, np.abs(lam_ref).max())
    assert np.abs(lam_inc - lam_ref).max() / scale < 5e-5
    Keff = np.asarray(kf.center_gram(jnp.asarray(K))) if adjusted else K
    rec = np.asarray(stream.reconstruction())[:n, :n]
    assert np.abs(rec - Keff).max() / scale < 5e-5


def test_update_block_equals_sequential():
    X, spec = _data(n=16)
    s1 = inkpca.KPCAStream(jnp.asarray(X[:4]), capacity=16, spec=spec,
                           adjusted=True, dtype=jnp.float64)
    s2 = inkpca.KPCAStream(jnp.asarray(X[:4]), capacity=16, spec=spec,
                           adjusted=True, dtype=jnp.float64)
    s1.update_block(jnp.asarray(X[4:]))
    for i in range(4, 16):
        s2.update(jnp.asarray(X[i]))
    np.testing.assert_allclose(np.sort(np.asarray(s1.state.L)),
                               np.sort(np.asarray(s2.state.L)), atol=1e-9)


def test_bookkeeping_S_and_K1():
    X, spec = _data(n=12)
    stream = inkpca.KPCAStream(jnp.asarray(X[:5]), capacity=12, spec=spec,
                               adjusted=True, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[5:]))
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    np.testing.assert_allclose(float(stream.state.S), K.sum(), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(stream.state.K1[:12]), K.sum(1),
                               rtol=1e-10)


def test_transform_projects_consistently():
    X, spec = _data(n=24)
    stream = inkpca.KPCAStream(jnp.asarray(X[:8]), capacity=24, spec=spec,
                               adjusted=False, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[8:]))
    k = 3
    Z = np.asarray(stream.transform(jnp.asarray(X), n_components=k))
    # projections of the training set onto kPCA components have variance
    # lam_i / n ... up to scaling; check orthogonality of component scores
    C = Z.T @ Z
    off = C - np.diag(np.diag(C))
    assert np.abs(off).max() < 1e-6 * max(1.0, np.abs(C).max())


def test_rotated_eigh_baseline_step():
    X, spec = _data(n=10)
    m = 9
    K_prev = np.asarray(kf.gram_block(jnp.asarray(X[:m]), jnp.asarray(X[:m]),
                                      spec=spec))
    K_new = np.asarray(kf.gram_block(jnp.asarray(X[:m + 1]),
                                     jnp.asarray(X[:m + 1]), spec=spec))
    lam, vec = batch.batch_kpca(jnp.asarray(K_prev), adjusted=True)
    lam2, vec2 = batch.rotated_eigh_step(lam, vec, jnp.asarray(K_prev),
                                         jnp.asarray(K_new))
    lam_ref = np.asarray(batch.batch_kpca(jnp.asarray(K_new),
                                          adjusted=True)[0])
    np.testing.assert_allclose(np.asarray(lam2), lam_ref, atol=1e-9)


def test_flop_model_ordering():
    f = batch.flop_model(512)
    assert f["ours_adjusted"] < f["rotated_eigh_baseline"] \
        < f["chin_suter_2007"]
    assert f["ours_unadjusted"] == pytest.approx(f["ours_adjusted"] / 2)


def test_rank_deficient_stream_stays_finite():
    """Linear kernel with n >> d: the gram is rank-deficient, the exact
    regime the paper handles by deflation/exclusion (§5). Our deflation
    clamp must keep the state finite; accuracy on the non-null spectrum is
    degraded but bounded."""
    X, _ = _data(n=24, d=3)
    spec = kf.KernelSpec(name="linear")
    stream = inkpca.KPCAStream(jnp.asarray(X[:6]), capacity=24, spec=spec,
                               adjusted=False, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[6:]))
    assert np.isfinite(np.asarray(stream.state.L)).all()
    assert np.isfinite(np.asarray(stream.state.U)).all()
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    lam_ref = np.linalg.eigvalsh(K)
    lam_inc = np.sort(np.asarray(stream.state.L[:24]))
    # top (true-rank) eigenvalues remain accurate to ~1e-3 relative
    scale = np.abs(lam_ref).max()
    assert np.abs(lam_inc[-3:] - lam_ref[-3:]).max() / scale < 1e-2


@pytest.mark.slow
def test_drift_stays_small_over_long_stream():
    """Paper Fig. 1: drift of the incremental reconstruction is small."""
    X, spec = _data(n=60, d=5)
    stream = inkpca.KPCAStream(jnp.asarray(X[:10]), capacity=60, spec=spec,
                               adjusted=True, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[10:]))
    K = np.asarray(kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec))
    Keff = np.asarray(kf.center_gram(jnp.asarray(K)))
    rec = np.asarray(stream.reconstruction())[:60, :60]
    fro = np.linalg.norm(rec - Keff) / np.linalg.norm(Keff)
    assert fro < 1e-5
