"""Fused kernel-row ingest + fused batched transform (ISSUE 6).

The fused kernels must be numerically the reference pipeline:

* ``rbf_gram.krow_project`` (a, P) == masked kernel row + U^T [a | aux],
  square and rectangular row blocks, both stationary kernels, f32/f64,
  interpret mode exercising the real Pallas body with tile pruning.
* one fused ingest step == one unfused step (masked_row then update),
  adjusted and unadjusted, single- and double-rotation matmul modes.
* ``nystrom_recon.transform_project`` == the masked-gram projection, and
  ``engine.transform_state`` under a fused plan == the unfused path
  (including the adjusted centering post-correction and the bucketed
  slice the stream applies before transforming).
* the distributed window scan with ``fuse_krow`` (psum'd partial P,
  injected Z) == the local unfused stream on a real P=2 mesh.
* ``StreamBatch.update_block`` with a window and a mixed cohort (steady
  lanes scanned, growing lanes stepped) == the per-point update loop.
* the incremental swap/removal trace deltas keep ``TraceErrorTracker``
  on the exact ``trace_error`` over a replace-heavy landmark lifecycle.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, inkpca, kernels_fn as kf, nystrom, \
    rankone
from repro.kernels.nystrom_recon import ops as nops
from repro.kernels.nystrom_recon.ref import transform_project_ref
from repro.kernels.nystrom_recon.transform_batch import (
    transform_project as transform_project_pallas)
from repro.kernels.rbf_gram import ops as gops
from repro.kernels.rbf_gram.krow_fused import krow_project as krow_pallas
from repro.kernels.rbf_gram.ref import krow_project_ref

SPECS = {"rbf": kf.KernelSpec(name="rbf", sigma=5.0),
         "matern32": kf.KernelSpec(name="matern32", sigma=2.0)}


def _tol(dtype):
    return 1e-5 if dtype == jnp.float32 else 1e-12


def _invariant_u(rng, M, m, dtype):
    """Capacity-M eigenvector matrix honoring the state invariant:
    inactive columns are exact identity columns, active columns have no
    mass on rows >= m (what tile pruning relies on)."""
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    u = np.eye(M)
    u[:m, :m] = q
    return jnp.asarray(u, dtype)


def _grown_state(n, capacity, d, spec, *, adjusted, dtype, seed=0):
    """Grow an unfused fixed-dispatch state to n active points."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    st = inkpca.init_state(jnp.asarray(X[:4], dtype), capacity, spec,
                           adjusted=adjusted, dtype=dtype)
    plan = eng.UpdatePlan().kernel_plan()
    for i in range(4, n):
        st = eng._ingest(st, jnp.asarray(X[i], dtype), spec, adjusted, plan)
    return st


# ------------------------------------------------------ krow_project ----
@pytest.mark.parametrize("name", ["rbf", "matern32"])
def test_krow_project_ref_matches_manual(name):
    spec = SPECS[name]
    rng = np.random.default_rng(3)
    M, m, d = 24, 9, 5
    u = _invariant_u(rng, M, m, jnp.float64)
    x = jnp.asarray(rng.normal(size=(M, d)))
    x_new = jnp.asarray(rng.normal(size=(d,)))
    aux = jnp.asarray(rng.normal(size=(M, 2)))
    a, P = krow_project_ref(u, x, x_new, aux, jnp.int32(m), spec=spec)
    kr = kf.gram_block(x, x_new[None, :], spec=spec)[:, 0]
    a_man = jnp.where(jnp.arange(M) < m, kr, 0.0)
    aux_man = jnp.where(jnp.arange(M)[:, None] < m, aux, 0.0)
    P_man = u.T @ jnp.concatenate([a_man[:, None], aux_man], axis=1)
    np.testing.assert_allclose(a, a_man, atol=1e-14)
    np.testing.assert_allclose(P, P_man, atol=1e-14)


@pytest.mark.parametrize("name", ["rbf", "matern32"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_krow_project_interpret_matches_ref_square(name, dtype):
    """Real Pallas body (interpret) vs oracle, block=8 so the m=10 active
    prefix prunes row/col tiles inside the M=32 grid."""
    spec = SPECS[name]
    rng = np.random.default_rng(4)
    M, m, d = 32, 10, 6
    u = _invariant_u(rng, M, m, dtype)
    x = jnp.asarray(np.where(np.arange(M)[:, None] < m,
                             rng.normal(size=(M, d)), 0.0), dtype)
    x_new = jnp.asarray(rng.normal(size=(d,)), dtype)
    aux = jnp.asarray(rng.normal(size=(M, 2)), dtype)
    a_r, P_r = krow_project_ref(u, x, x_new, aux, jnp.int32(m), spec=spec)
    a_p, P_p = krow_pallas(u, x, x_new, aux, jnp.int32(m), spec=spec,
                           block=8, interpret=True)
    np.testing.assert_allclose(a_p, a_r, atol=_tol(dtype))
    np.testing.assert_allclose(P_p, P_r, atol=_tol(dtype))


@pytest.mark.parametrize("r0", [0, 16])
def test_krow_project_rectangular_row_block(r0):
    """(R, M) shard covering global rows [r0, r0+R): partial P sums over
    shards to the full projection (the distributed contract)."""
    spec = SPECS["rbf"]
    rng = np.random.default_rng(5)
    M, R, m, d = 32, 16, 10, 6
    dtype = jnp.float64
    u = _invariant_u(rng, M, m, dtype)
    x = jnp.asarray(np.where(np.arange(M)[:, None] < m,
                             rng.normal(size=(M, d)), 0.0), dtype)
    x_new = jnp.asarray(rng.normal(size=(d,)), dtype)
    aux = jnp.asarray(rng.normal(size=(M, 2)), dtype)
    sh = slice(r0, r0 + R)
    a_r, P_r = krow_project_ref(u[sh], x[sh], x_new, aux[sh], jnp.int32(m),
                                jnp.int32(r0), spec=spec)
    a_p, P_p = krow_pallas(u[sh], x[sh], x_new, aux[sh], jnp.int32(m),
                           jnp.int32(r0), spec=spec, block=8, interpret=True)
    np.testing.assert_allclose(a_p, a_r, atol=1e-12)
    np.testing.assert_allclose(P_p, P_r, atol=1e-12)
    # Both shards together reproduce the square projection.
    a_f, P_f = krow_project_ref(u, x, x_new, aux, jnp.int32(m), spec=spec)
    other = slice(16 - r0, 32 - r0)
    _, P_o = krow_pallas(u[other], x[other], x_new, aux[other], jnp.int32(m),
                         jnp.int32(16 - r0), spec=spec, block=8,
                         interpret=True)
    np.testing.assert_allclose(P_p + P_o, P_f, atol=1e-12)
    np.testing.assert_allclose(a_f[sh], a_p, atol=1e-12)


def test_krow_ops_dispatch_forces_ref_for_non_stationary():
    """Kernels without a fused epilogue (linear) must dispatch to the
    reference path even when a Pallas force is requested."""
    spec = kf.KernelSpec(name="linear", sigma=1.0)
    rng = np.random.default_rng(6)
    M, m, d = 16, 6, 4
    u = _invariant_u(rng, M, m, jnp.float64)
    x = jnp.asarray(rng.normal(size=(M, d)))
    x_new = jnp.asarray(rng.normal(size=(d,)))
    aux = jnp.zeros((M, 0))
    a_r, P_r = krow_project_ref(u, x, x_new, aux, jnp.int32(m), spec=spec)
    a_o, P_o = gops.krow_project(u, x, x_new, aux, jnp.int32(m), spec=spec,
                                 force="interpret")
    np.testing.assert_allclose(a_o, a_r, atol=1e-14)
    np.testing.assert_allclose(P_o, P_r, atol=1e-14)


# ------------------------------------------------------- fused ingest ----
@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("matmul", ["jnp", "jnp2"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_fused_ingest_matches_unfused_single_step(adjusted, matmul, dtype):
    spec = SPECS["rbf"]
    st = _grown_state(12, 32, 5, spec, adjusted=adjusted, dtype=dtype)
    x_new = jnp.asarray(np.random.default_rng(7).normal(size=(5,)), dtype)
    plan_u = eng.UpdatePlan(matmul=matmul).kernel_plan()
    plan_f = eng.UpdatePlan(matmul=matmul, fuse_krow=True).kernel_plan()
    s_u = eng._ingest(st, x_new, spec, adjusted, plan_u)
    s_f = eng._ingest(st, x_new, spec, adjusted, plan_f)
    tol = _tol(dtype)
    m = int(s_u.m)
    assert int(s_f.m) == m
    np.testing.assert_allclose(s_f.L[:m], s_u.L[:m], atol=tol, rtol=tol)
    K_u = rankone.reconstruct(s_u.L, s_u.U, s_u.m)
    K_f = rankone.reconstruct(s_f.L, s_f.U, s_f.m)
    np.testing.assert_allclose(K_f, K_u, atol=10 * tol)
    np.testing.assert_allclose(s_f.X, s_u.X, atol=tol)
    if adjusted:
        np.testing.assert_allclose(s_f.K1, s_u.K1, atol=tol)
        np.testing.assert_allclose(s_f.S, s_u.S, atol=tol)


@pytest.mark.parametrize("name", ["rbf", "matern32"])
def test_fused_bucketed_stream_matches_fixed_unfused(name):
    """End-to-end KPCAStream: fused + bucketed + double-rotation vs the
    seed fixed unfused path over a 20-point stream (accumulated fp drift
    bounded, not bitwise)."""
    spec = SPECS[name]
    rng = np.random.default_rng(8)
    X = rng.normal(size=(20, 5))
    kw = dict(adjusted=True, dtype=jnp.float64)
    s_ref = inkpca.KPCAStream(jnp.asarray(X[:4]), 64, spec,
                              plan=eng.UpdatePlan(dispatch="fixed"), **kw)
    s_fus = inkpca.KPCAStream(
        jnp.asarray(X[:4]), 64, spec,
        plan=eng.UpdatePlan(matmul="jnp2", dispatch="bucketed",
                            fuse_krow=True), **kw)
    for i in range(4, 20):
        s_ref.update(jnp.asarray(X[i]))
        s_fus.update(jnp.asarray(X[i]))
    a, b = s_ref.kpca_state, s_fus.kpca_state
    assert int(a.m) == int(b.m) == 20
    K_a = rankone.reconstruct(a.L, a.U, a.m)
    K_b = rankone.reconstruct(b.L, b.U, b.m)
    np.testing.assert_allclose(K_b, K_a, atol=1e-8)
    q = jnp.asarray(rng.normal(size=(3, 5)))
    np.testing.assert_allclose(s_fus.transform(q, n_components=6),
                               s_ref.transform(q, n_components=6), atol=1e-7)


# --------------------------------------------------- fused transform ----
@pytest.mark.parametrize("name", ["rbf", "matern32"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
@pytest.mark.parametrize("Q", [32, 50])
def test_transform_project_interpret_matches_ref(name, dtype, Q):
    spec = SPECS[name]
    rng = np.random.default_rng(9)
    M, d, C, m = 32, 6, 4, 11
    x = jnp.asarray(rng.normal(size=(M, d)), dtype)
    xq = jnp.asarray(rng.normal(size=(Q, d)), dtype)
    s = jnp.asarray(rng.normal(size=(M, C)), dtype)
    y_r, rs_r = transform_project_ref(xq, x, s, jnp.int32(m), spec=spec)
    y_p, rs_p = transform_project_pallas(xq, x, s, jnp.int32(m), spec=spec,
                                         block=8, interpret=True)
    tol = _tol(dtype) * 10
    np.testing.assert_allclose(y_p, y_r, atol=tol)
    np.testing.assert_allclose(rs_p, rs_r, atol=tol)


@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_transform_state_fused_matches_unfused(adjusted, dtype):
    spec = SPECS["rbf"]
    st = _grown_state(14, 32, 5, spec, adjusted=adjusted, dtype=dtype)
    q = jnp.asarray(np.random.default_rng(10).normal(size=(7, 5)), dtype)
    plan = eng.UpdatePlan(fuse_krow=True).kernel_plan()
    y_u = eng.transform_state(st, q, spec=spec, adjusted=adjusted,
                              n_components=6, plan=None)
    y_f = eng.transform_state(st, q, spec=spec, adjusted=adjusted,
                              n_components=6, plan=plan)
    np.testing.assert_allclose(y_f, y_u, atol=_tol(dtype) * 10)
    # Bucketed spelling: transforming the sliced state is the same map.
    Mb = eng.bucket_for(int(st.m), 32, plan.min_bucket)
    if Mb < 32:
        y_b = eng.transform_state(eng.slice_state(st, Mb), q, spec=spec,
                                  adjusted=adjusted, n_components=6,
                                  plan=plan)
        np.testing.assert_allclose(y_b, y_u, atol=_tol(dtype) * 10)


def test_stream_transform_routes_fused_bucketed():
    """KPCAStream.transform under a fused bucketed plan slices to the
    active bucket before the fused projection — output must match the
    full-capacity unfused transform."""
    spec = SPECS["rbf"]
    rng = np.random.default_rng(11)
    X = rng.normal(size=(12, 5))
    stream = inkpca.KPCAStream(
        jnp.asarray(X[:4]), 64, spec, adjusted=True,
        plan=eng.UpdatePlan(dispatch="bucketed", fuse_krow=True),
        dtype=jnp.float64)
    for i in range(4, 12):
        stream.update(jnp.asarray(X[i]))
    q = jnp.asarray(rng.normal(size=(5, 5)))
    y_f = stream.transform(q, n_components=4)
    y_u = eng.transform_state(stream.kpca_state, q, spec=spec, adjusted=True,
                              n_components=4, plan=None)
    np.testing.assert_allclose(y_f, y_u, atol=1e-11)


def test_nystrom_fused_add_landmark_and_query_features():
    spec = SPECS["rbf"]
    rng = np.random.default_rng(12)
    x0 = jnp.asarray(rng.normal(size=(4, 5)))
    # f64 lifecycle: per-step fused-vs-unfused is exact, but f32 rounding
    # differences compound through near-degenerate secular solves when the
    # two states evolve independently for several steps.
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True,
                                 dtype=jnp.float64)
    plan_u = eng.UpdatePlan().kernel_plan()
    plan_f = eng.UpdatePlan(fuse_krow=True).kernel_plan()
    s_u = s_f = state
    for i in range(6):
        x = jnp.asarray(rng.normal(size=(5,)))
        s_u = nystrom.observe_rows(s_u, x, spec, plan=plan_u)
        s_f = nystrom.observe_rows(s_f, x, spec, plan=plan_f)
        s_u = nystrom.add_landmark(s_u, None, x, spec, plan=plan_u)
        s_f = nystrom.add_landmark(s_f, None, x, spec, plan=plan_f)
    K_u = rankone.reconstruct(s_u.kpca.L, s_u.kpca.U, s_u.kpca.m)
    K_f = rankone.reconstruct(s_f.kpca.L, s_f.kpca.U, s_f.kpca.m)
    np.testing.assert_allclose(K_f, K_u, atol=1e-10)
    np.testing.assert_allclose(s_f.Knm, s_u.Knm, atol=1e-12)
    xq = jnp.asarray(rng.normal(size=(4, 5)))
    f_u = nystrom.query_features(s_u, xq, 3, spec, plan=plan_u)
    f_f = nystrom.query_features(s_f, xq, 3, spec, plan=plan_f)
    np.testing.assert_allclose(f_f, f_u, atol=1e-10)


# ----------------------------------------- distributed fused window ----
def test_sharded_fused_window_multidevice_subprocess():
    """P=2 end-to-end: the sharded window block under ``fuse_krow`` (per
    shard partial P psum'd into the injected Z) must match the local
    unfused stream."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, inkpca, \
    kernels_fn as kf, rankone
assert jax.device_count() == 2
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)
rng = np.random.default_rng(21)
X = rng.normal(size=(12, 4))
W = 8
stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64, window=W)
for i in range(4, 12):
    stream.update(jnp.asarray(X[i]))
ws = stream.state
xs = jnp.asarray(rng.normal(size=(5, 4)))
mesh = jax.make_mesh((2,), ("data",))
errs = {}
for tag, plan in (("fixed", eng.UpdatePlan(fuse_krow=True, matmul="jnp2")),
                  ("bucketed", eng.UpdatePlan(dispatch="bucketed",
                                              min_bucket=8, fuse_krow=True,
                                              matmul="jnp2"))):
    wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=plan)
    L2, U2, X2, ages2, clock2 = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X,
                                   ws.ages, ws.clock, xs, ws.kpca.m)
    ref = stream
    import copy
    ref = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                            dtype=jnp.float64, window=W)
    for i in range(4, 12):
        ref.update(jnp.asarray(X[i]))
    for t in range(5):
        ref.update(xs[t])
    r = ref.state
    errs[tag + "_L"] = float(jnp.abs(L2[:W] - r.kpca.L[:W]).max())
    errs[tag + "_K"] = float(jnp.abs(
        rankone.reconstruct(L2, U2, jnp.int32(W))
        - rankone.reconstruct(r.kpca.L, r.kpca.U, r.kpca.m)).max())
print("RESULT:" + str(errs))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    errs = eval(line[len("RESULT:"):])
    for k, v in errs.items():
        assert v < 1e-9, errs


# ------------------------------------- StreamBatch windowed blocks ----
@pytest.mark.parametrize("cohorts", ["max", "bucket"])
def test_streambatch_windowed_block_matches_per_point(cohorts):
    """Mixed cohort at a window: steady lanes fold the block in one scan,
    growers step to the window then scan — must equal the per-point loop."""
    spec = SPECS["rbf"]
    rng = np.random.default_rng(13)
    B, d, W, cap = 3, 4, 6, 16
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    kw = dict(plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
              adjusted=True, dtype=jnp.float64, cohorts=cohorts, window=W)
    blk = eng.StreamBatch(x0, cap, spec, **kw)
    ref = eng.StreamBatch(x0, cap, spec, **kw)
    # Stagger: tenant 0 reaches the window first via masked updates.
    pre = jnp.asarray(rng.normal(size=(2, B, d)))
    mask = jnp.asarray([True, False, False])
    for t in range(2):
        blk.update(pre[t], active=mask)
        ref.update(pre[t], active=mask)
    assert list(blk._m_host) == [6, 4, 4]
    xs = jnp.asarray(rng.normal(size=(5, B, d)))
    blk.update_block(xs)
    for t in range(5):
        ref.update(xs[t])
    sa, sb = blk.states, ref.states
    assert list(blk._m_host) == list(ref._m_host)
    for la, lb in zip(jax.tree_util.tree_leaves(sa),
                      jax.tree_util.tree_leaves(sb)):
        np.testing.assert_allclose(la, lb, atol=1e-9)


# ---------------------------------------------- trace-delta tracking ----
def test_removal_trace_delta_matches_exact():
    spec = SPECS["rbf"]
    rng = np.random.default_rng(14)
    x0 = jnp.asarray(rng.normal(size=(4, 4)))
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True)
    for i in range(8):
        x = jnp.asarray(rng.normal(size=(4,)))
        state = nystrom.observe_rows(state, x, spec)
        if i % 2 == 0:
            state = nystrom.add_landmark(state, None, x, spec)
    before = float(nystrom.trace_error(state, spec))
    for j in [0, 3, 6]:
        delta, wjj = nystrom.removal_trace_delta(state, jnp.int32(j))
        assert float(wjj) > 0
        after = float(nystrom.trace_error(
            nystrom.remove_landmark(state, jnp.int32(j), spec), spec))
        np.testing.assert_allclose(after - before, float(delta), atol=1e-9)


def test_tracker_swap_delta_drift_over_replace_heavy_lifecycle():
    """Replace-heavy landmark lifecycle: the tracker (swap deltas, no
    periodic resync) must stay on the exact trace_error.

    The leverage policy's swap arm compares ridge-leverage scores that
    saturate near 1 for any non-degenerate landmark set against a
    normalized residual below 1, so an i.i.d. candidate stream never
    fires it on its own; the swap-heavy lifecycle is driven explicitly
    through ``Engine.replace_landmark`` with the policy's own
    argmin-leverage victim choice, which is what exercises the
    ``swap_trace_delta`` path this test is about."""
    spec = SPECS["rbf"]
    rng = np.random.default_rng(15)
    x0 = jnp.asarray(rng.normal(size=(4, 4)))
    state = nystrom.init_nystrom(None, x0, 16, spec, grow_rows=True)
    engine = eng.Engine(spec, eng.UpdatePlan(landmark_policy="leverage"),
                        adjusted=False)
    tracker = nystrom.TraceErrorTracker(state, spec, resync_every=10_000)
    counts = {"admitted": 0, "rejected": 0, "replaced": 0}
    for i in range(36):
        x = jnp.asarray(rng.normal(size=(4,)))
        res = float(nystrom.admission_residual(state, x, spec))
        tracker.observe(state, x, residual=res)
        state = nystrom.observe_rows(state, x, spec)
        prev = state
        m = int(state.kpca.m)
        if m >= 6 and i % 3 == 0:
            lev = np.asarray(nystrom.leverage_scores(state)[:m])
            victim = int(np.argmin(lev))
            state = engine.replace_landmark(state, None, victim, x)
            action = "replaced"
        else:
            state, action = engine.offer_landmark(state, x, budget=6,
                                                  residual=res)
        counts[action] += 1
        if action == "admitted":
            tracker.admitted(prev, x)
        elif action == "replaced":
            tracker.replaced(state, state_before=prev, x=x)
    assert counts["replaced"] >= 5, counts    # lifecycle must be swap-heavy
    exact = float(nystrom.trace_error(state, spec))
    # ~1e-8 relative rounding per accumulated swap delta, 11 swaps here
    assert abs(tracker.value - exact) <= 1e-7 * max(exact, 1.0), \
        (tracker.value, exact, counts)
