"""Pipeline parallelism: single-stage equality + multi-stage equivalence in
a subprocess with forced host devices (the main test process must keep
jax's device count at 1)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.pipeline import pipeline_apply
import pytest


def _stage_fn(p, x):
    return jnp.tanh(x @ p["w"])


def test_single_stage_identity():
    mesh = jax.make_mesh((1,), ("pod",))
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)}
    x = jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)
    y = pipeline_apply(_stage_fn, params, x, mesh=mesh, axis="pod",
                       microbatches=2)
    ref = _stage_fn(jax.tree.map(lambda l: l[0], params), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-6)


@pytest.mark.slow
def test_multi_stage_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(4, 8, 8)), jnp.float32)}
        x = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        y = pipeline_apply(stage_fn, params, x, mesh=mesh, axis="pod",
                           microbatches=4)
        ref = x
        for s in range(4):
            ref = stage_fn({"w": params["w"][s]}, ref)
        err = float(jnp.abs(y - ref).max())
        assert err < 1e-5, err
        print("PIPELINE_OK", err)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", script], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=300)
    assert "PIPELINE_OK" in out.stdout, (out.stdout, out.stderr)
