"""Model zoo behaviour: parallel-vs-decode equivalence, grads, invariances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # model-zoo / driver integration tier

from repro.models import lm, ssm, xlstm
from repro.models.config import ArchConfig, MoEConfig

B, T = 2, 16


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab=128, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


CFGS = {
    "dense": _cfg(qk_norm=True),
    "parallel": _cfg(parallel_block=True),
    "partial_rope": _cfg(rope_fraction=0.25),
    "moe": _cfg(family="moe", d_ff=0,
                moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                              n_shared_experts=1)),
    "hybrid": _cfg(family="hybrid", n_layers=4,
                   block_pattern=("mamba", "attn"), ssm_d_state=8,
                   ssm_head_dim=16, ssm_chunk=8),
    "xlstm": _cfg(family="ssm", n_layers=4, n_kv_heads=4,
                  block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
                  ssm_chunk=8),
}


def _batch(cfg):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    return {"tokens": tokens, "labels": tokens}


@pytest.mark.parametrize("name", list(CFGS))
def test_loss_and_grads_finite(name):
    cfg = CFGS[name]
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, _batch(cfg)), has_aux=True)(params)
    assert jnp.isfinite(loss)
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("name", list(CFGS))
def test_decode_matches_parallel(name):
    cfg = CFGS[name]
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg)["tokens"]
    logits_par = lm.forward(params, cfg, tokens, remat=False)
    caches = lm.init_caches(params, cfg, B, T)
    outs = []
    for t in range(T):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches = lm.decode_step(params, cfg, caches, tokens[:, t:t+1],
                                    pos)
        outs.append(lg)
    err = float(jnp.abs(logits_par - jnp.concatenate(outs, 1)).max())
    assert err < 2e-2, err


def test_causality():
    """Perturbing a future token must not change past logits."""
    cfg = CFGS["dense"]
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg)["tokens"]
    l1 = lm.forward(params, cfg, tokens, remat=False)
    tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
    l2 = lm.forward(params, cfg, tokens2, remat=False)
    assert float(jnp.abs(l1[:, :-1] - l2[:, :-1]).max()) < 1e-5


@pytest.mark.parametrize("block", ["mamba", "mlstm", "slstm"])
def test_recurrent_blocks_match_decode(block):
    cfg = _cfg(n_kv_heads=4, ssm_d_state=8, ssm_head_dim=16, ssm_chunk=8)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    mod = {"mamba": ssm, "mlstm": xlstm, "slstm": xlstm}[block]
    p = getattr(mod, f"{block}_init")(jax.random.PRNGKey(3), cfg)
    y_par = getattr(mod, f"{block}_apply")(p, cfg, x)
    cache = getattr(mod, f"{block}_cache_init")(cfg, B)
    ys = []
    for t in range(T):
        y_t, cache = getattr(mod, f"{block}_decode")(p, cfg, x[:, t:t+1],
                                                     cache)
        ys.append(y_t)
    err = float(jnp.abs(y_par - jnp.concatenate(ys, 1)).max())
    assert err < 1e-4, err


def test_mlstm_chunk_invariance():
    """Chunked mLSTM must be invariant to the chunk size."""
    cfg8 = _cfg(n_kv_heads=4, ssm_chunk=8)
    cfg4 = _cfg(n_kv_heads=4, ssm_chunk=4)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, T, cfg8.d_model),
                          jnp.float32)
    p = xlstm.mlstm_init(jax.random.PRNGKey(5), cfg8)
    y8 = xlstm.mlstm_apply(p, cfg8, x)
    y4 = xlstm.mlstm_apply(p, cfg4, x)
    assert float(jnp.abs(y8 - y4).max()) < 1e-4


def test_flash_equals_naive_attention():
    import dataclasses
    cfg = CFGS["dense"]
    cfgf = dataclasses.replace(cfg, attn_impl="flash", flash_block=8)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg)["tokens"]
    l1 = lm.forward(params, cfg, tokens, remat=False)
    l2 = lm.forward(params, cfgf, tokens, remat=False)
    assert float(jnp.abs(l1 - l2).max()) < 1e-4
    g1 = jax.grad(lambda p: lm.loss_fn(p, cfg, _batch(cfg))[0])(params)
    g2 = jax.grad(lambda p: lm.loss_fn(p, cfgf, _batch(cfg))[0])(params)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-4, err


def test_moe_ep_equals_einsum_on_host_mesh():
    import dataclasses
    from repro.distributed import sharding as shd
    cfg = CFGS["moe"]
    # high capacity factor so no tokens drop (drop order differs per impl)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    cfg_ep = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="ep"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    tokens = _batch(cfg)["tokens"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.use_mesh(mesh):
        l1 = lm.forward(params, cfg, tokens, remat=False)
        l2 = lm.forward(params, cfg_ep, tokens, remat=False)
    assert float(jnp.abs(l1 - l2).max()) < 1e-4


def test_moe_einsum_equals_scatter():
    moe_e = CFGS["moe"]
    moe_s = _cfg(family="moe", d_ff=0,
                 moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32,
                               n_shared_experts=1, impl="scatter"))
    params = lm.init_params(jax.random.PRNGKey(0), moe_e)
    tokens = _batch(moe_e)["tokens"]
    l1 = lm.forward(params, moe_e, tokens, remat=False)
    l2 = lm.forward(params, moe_s, tokens, remat=False)
    assert float(jnp.abs(l1 - l2).max()) < 1e-3


def test_param_count_matches_init():
    from repro.models.config import param_count
    for name, cfg in CFGS.items():
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        expect = param_count(cfg)
        assert abs(actual - expect) / expect < 0.12, (name, actual, expect)


def test_frontend_embeddings_path():
    cfg = _cfg(family="vlm", frontend="embeddings", frontend_len=4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    emb = jax.random.normal(jax.random.PRNGKey(6), (B, 4, cfg.d_model))
    batch["embeddings"] = emb
    batch["labels"] = batch["labels"].at[:, :4].set(-1)
    loss, m = lm.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss)
    # changing the frontend embeddings must change the loss
    batch2 = dict(batch, embeddings=emb + 1.0)
    loss2, _ = lm.loss_fn(params, cfg, batch2)
    assert abs(float(loss - loss2)) > 1e-6


def test_moe_decode_reproduces_capacity_drops():
    """Capacity drops are per-row causal: a decode loop with the count
    cache must reproduce moe_apply token-for-token even when the capacity
    binds (low capacity_factor forces drops)."""
    import dataclasses
    from repro.models import moe as moe_mod

    cfg = CFGS["moe"]
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    cfg_nodrop = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = jax.tree.map(lambda v: v[0],
                     lm.init_params(jax.random.PRNGKey(0),
                                    cfg)["slots"]["slot0"]["ffn"])
    x = jax.random.normal(jax.random.PRNGKey(7), (B, T, cfg.d_model),
                          jnp.float32)
    y_par = moe_mod.moe_apply(p, cfg, x)
    # the tight capacity really drops tokens (outputs differ vs no-drop)
    y_nodrop = moe_mod.moe_apply(p, cfg_nodrop, x)
    assert float(jnp.abs(y_par - y_nodrop).max()) > 1e-3

    cache = moe_mod.moe_cache_init(cfg, B, T)
    outs = []
    for t in range(T):
        y_t, cache = moe_mod.moe_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(y_par - y_dec).max())
    assert err < 1e-4, err
    # einsum and scatter agree on the keep set under forced drops
    cfg_sc = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="scatter"))
    y_sc = moe_mod.moe_apply(p, cfg_sc, x)
    assert float(jnp.abs(y_par - y_sc).max()) < 1e-4
