"""Elastic scaling: a checkpoint written on an N-device mesh restores onto
an M-device mesh (subprocess with forced host devices — the main process
keeps 1 device)."""
import os
import subprocess
import sys
import textwrap
import pytest

pytestmark = pytest.mark.slow  # model-zoo / driver integration tier


def test_reshard_4_to_2_devices(tmp_path):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import save_checkpoint, load_checkpoint

        d = {str(tmp_path)!r}
        mesh4 = jax.make_mesh((4,), ("data",))
        sh4 = NamedSharding(mesh4, P("data"))
        tree = {{"w": jax.device_put(jnp.arange(16, dtype=jnp.float32), sh4),
                 "b": jax.device_put(jnp.ones((4, 8), jnp.bfloat16),
                                     NamedSharding(mesh4, P("data", None)))}}
        save_checkpoint(d, 1, tree)

        # restore onto a 2-device mesh (simulating shrink-after-failure)
        mesh2 = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("data",))
        sh2 = NamedSharding(mesh2, P("data"))
        target = {{"w": jax.ShapeDtypeStruct((16,), jnp.float32,
                                             sharding=sh2),
                   "b": jax.ShapeDtypeStruct((4, 8), jnp.bfloat16,
                                             sharding=NamedSharding(
                                                 mesh2, P("data", None)))}}
        out = load_checkpoint(d, 1, target)
        assert out["w"].sharding == sh2
        np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(16))
        np.testing.assert_array_equal(
            np.asarray(out["b"], np.float32), np.ones((4, 8)))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", script], cwd=root, env=env,
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC_OK" in out.stdout, (out.stdout, out.stderr)
