"""Self-healing layer (``core/health``): probes, quarantine, heal ladder,
graceful serving degradation and staleness-aware publication.

The quarantine tests assert BITWISE equality between a stream that saw a
poisoned point and one that never did — the gate must reject before the
rank-one pair fires, leaving the eigensystem, arrival ring and clock
untouched on every dispatch path (fixed, bucketed, scanned window,
multi-tenant).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import batch as batch_mod
from repro.core import engine as eng
from repro.core import health as hl
from repro.core import inkpca
from repro.core import kernels_fn as kf
from repro.core import serving
from repro.testing import faults

SPEC = kf.KernelSpec(name="rbf", sigma=2.0)
HPLAN = eng.UpdatePlan(health=hl.DEFAULT_POLICY)


def _stream(n=10, d=4, cap=16, *, plan=eng.UpdatePlan(), adjusted=True,
            dtype=jnp.float64, seed=0, window=None):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    s = inkpca.KPCAStream(jnp.asarray(X[:4], dtype), cap, SPEC,
                          adjusted=adjusted, plan=plan, dtype=dtype,
                          window=window)
    for i in range(4, n):
        s.update(jnp.asarray(X[i], dtype))
    return s, rng


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------------- probes --
def test_probe_healthy_then_detects_corruption():
    s, _ = _stream(12)
    st = s.kpca_state
    h = hl.probe(st, hl.init_health(st.L.dtype), hl.DEFAULT_POLICY)
    assert hl.is_healthy(h, hl.DEFAULT_POLICY)
    assert float(h.orth_err) < 1e-8

    bad = faults.corrupt_eigvecs(st, magnitude=0.3, seed=1)
    h2 = hl.probe(bad, hl.init_health(st.L.dtype), hl.DEFAULT_POLICY)
    assert not hl.is_healthy(h2, hl.DEFAULT_POLICY)
    assert float(h2.orth_err) > 1e-2

    neg = faults.corrupt_eigenvalue(st, j=0, value=-1.0)
    h3 = hl.probe(neg, hl.init_health(st.L.dtype), hl.DEFAULT_POLICY)
    assert not hl.is_healthy(h3, hl.DEFAULT_POLICY)
    assert float(h3.neg_frac) > hl.DEFAULT_POLICY.neg_tol

    nanU = st._replace(U=st.U.at[0, 0].set(jnp.nan))
    h4 = hl.probe(nanU, hl.init_health(st.L.dtype), hl.DEFAULT_POLICY)
    assert int(h4.nonfinite) == 1
    # sticky: a later healthy probe does not clear the flag
    h5 = hl.probe(st, h4, hl.DEFAULT_POLICY)
    assert int(h5.nonfinite) == 1


def test_probe_rotates_over_all_columns():
    s, _ = _stream(12)
    st = s.kpca_state
    # Support-violation on a column outside the first probe window still
    # gets caught once the rotation reaches it.
    bad = st._replace(U=st.U.at[int(st.m) - 1, 0].add(0.5))
    h = hl.init_health(st.L.dtype)
    seen_bad = False
    for _ in range(int(np.ceil(int(st.m) / hl.DEFAULT_POLICY.probe_cols))):
        h = hl.probe(bad, h, hl.DEFAULT_POLICY)
        seen_bad = seen_bad or float(h.orth_err) > 1e-2
    assert seen_bad


# --------------------------------------------------------- quarantine --
@pytest.mark.parametrize("plan", [
    eng.UpdatePlan(health=hl.DEFAULT_POLICY),
    eng.UpdatePlan(dispatch="bucketed", min_bucket=8,
                   health=hl.DEFAULT_POLICY),
], ids=["fixed", "bucketed"])
def test_guarded_update_bitwise_reject(plan):
    engine = eng.Engine(SPEC, plan, adjusted=True)
    ref_engine = eng.Engine(SPEC, plan._replace(health=None), adjusted=True)
    s, rng = _stream(9)
    st = s.kpca_state
    h = hl.init_health(st.L.dtype)

    # clean point: guarded == unguarded, bit for bit
    x = jnp.asarray(rng.normal(size=(4,)))
    st1, h1 = engine.update_guarded(st, h, x)
    _assert_trees_equal(st1, ref_engine.update(st, x))
    assert int(h1.quarantined) == 0 and int(h1.rejected_last) == 0

    # poisoned point: state survives bitwise, counter ticks
    st2, h2 = st1, h1
    for kind in ("nan", "inf", "-inf"):
        st2, h2 = engine.update_guarded(st2, h2, faults.nan_point(
            4, kind=kind, base=np.asarray(x)))
        _assert_trees_equal(st2, st1)
    assert int(h2.quarantined) == 3 and int(h2.rejected_last) == 1


def test_guarded_block_splits_at_poisoned_points():
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    engine = eng.Engine(SPEC, plan, adjusted=True)
    s, rng = _stream(8)
    st = s.kpca_state
    xs = rng.normal(size=(6, 4))
    bad = np.array(xs)
    bad[2] = faults.nan_point(4, base=bad[2])
    clean = np.delete(np.array(xs), 2, axis=0)

    h = hl.init_health(st.L.dtype)
    got, hg = engine.update_block_guarded(st, h, jnp.asarray(bad))
    ref, _ = engine.update_block_guarded(st, hl.init_health(st.L.dtype),
                                         jnp.asarray(clean))
    _assert_trees_equal(got, ref)
    assert int(hg.quarantined) == 1


def test_window_ingest_quarantine_leaves_ring_untouched():
    """The PR's window bugfix: a rejected point must leave the kpca state,
    the ages ring AND the clock exactly as they were — the old path
    evicted and stamped regardless."""
    W = 6
    plan = eng.UpdatePlan(window=W, health=hl.DEFAULT_POLICY)
    engine = eng.Engine(SPEC, plan, adjusted=True)
    s, rng = _stream(10, window=W, plan=plan)
    ws = s.state

    from repro.core import window as win
    out, h = win.ingest(engine, ws, faults.nan_point(4), window=W,
                        hstate=hl.init_health(ws.kpca.L.dtype))
    _assert_trees_equal(out, ws)
    assert int(h.quarantined) == 1

    # and the stream-level spelling: poisoned mid-stream == never seen
    p2 = eng.UpdatePlan(window=W, health=hl.DEFAULT_POLICY)
    sa, rng = _stream(10, window=W, plan=p2, seed=3)
    sb, _ = _stream(10, window=W, plan=p2, seed=3)
    xs = rng.normal(size=(4, 4))
    for t in range(4):
        sa.update(jnp.asarray(xs[t]))
        sb.update(jnp.asarray(xs[t]))
        if t == 1:
            sa.update(faults.nan_point(4))
    _assert_trees_equal(sa.state, sb.state)
    assert int(sa.health.quarantined) == 1
    assert int(sb.health.quarantined) == 0


@pytest.mark.parametrize("cohorts,window", [("max", None), ("max", 6),
                                            ("bucket", None),
                                            ("bucket-padded", 6)])
def test_streambatch_quarantine_bitwise(cohorts, window):
    rng = np.random.default_rng(0)
    B, d, cap = 3, 4, 16
    x0 = rng.normal(size=(B, 4, d))
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    sb = eng.StreamBatch(jnp.asarray(x0), cap, SPEC, plan=plan,
                         dtype=jnp.float64, cohorts=cohorts, window=window)
    rf = eng.StreamBatch(jnp.asarray(x0), cap, SPEC, plan=eng.UpdatePlan(),
                         dtype=jnp.float64, cohorts=cohorts, window=window)
    T = 8
    xs = rng.normal(size=(T, B, d))
    bad = np.array(xs)
    bad[3, 1, 0] = np.nan
    bad[6, 0, 2] = np.inf
    sb.update_block(jnp.asarray(bad))
    # Reference mirrors the guarded dispatch split: clean runs ride the
    # block path, poisoned steps the per-step masked path — bitwise
    # equality then isolates the quarantine gate itself.
    finite = np.isfinite(bad).all(axis=(1, 2))
    t = 0
    while t < T:
        if finite[t]:
            u = t
            while u < T and finite[u]:
                u += 1
            rf.update_block(jnp.asarray(bad[t:u]))
            t = u
        else:
            ok = np.isfinite(bad[t]).all(axis=1)
            rf.update(jnp.asarray(np.where(ok[:, None], bad[t], 0.0)),
                      active=ok)
            t += 1
    _assert_trees_equal(sb.states, rf.states)
    assert sb.health_summary()["quarantined"] == 2
    np.testing.assert_array_equal(sb.quarantined, [1, 1, 0])
    np.testing.assert_array_equal(sb._m_host, rf._m_host)


def test_outlier_gate_rejects_far_point():
    # RBF: a point far outside the stored set has k(x,x) = 1 but a kernel
    # row that underflows to ~0 — with outlier_tol on, it is quarantined.
    spec = kf.KernelSpec(name="rbf", sigma=0.5)
    pol = hl.HealthPolicy(outlier_tol=1e-6)
    engine = eng.Engine(spec, eng.UpdatePlan(health=pol), adjusted=False)
    rng = np.random.default_rng(5)
    st = inkpca.init_state(jnp.asarray(rng.normal(size=(5, 3))), 8, spec,
                           adjusted=False, dtype=jnp.float64)
    h = hl.init_health(st.L.dtype)
    far = jnp.full((3,), 1e3, jnp.float64)
    st1, h1 = engine.update_guarded(st, h, far)
    _assert_trees_equal(st1, st)
    assert int(h1.quarantined) == 1
    # a nearby point still passes
    st2, h2 = engine.update_guarded(st1, h1,
                                    jnp.asarray(rng.normal(size=(3,))))
    assert int(st2.m) == int(st.m) + 1
    assert int(h2.quarantined) == 1


# -------------------------------------------------------- heal ladder --
def test_heal_polish_restores_orthogonality():
    s, _ = _stream(12)
    st = s.kpca_state
    tilted = faults.corrupt_eigvecs(st, magnitude=1e-3, seed=7)
    r0 = hl.exact_orth_residual(tilted)
    # unhealthy, but inside the polish band (orth_tol, polish_max)
    assert hl.DEFAULT_POLICY.orth_tol < r0 < hl.DEFAULT_POLICY.polish_max
    healed = hl.heal_kpca(tilted, SPEC, True)
    assert hl.exact_orth_residual(healed) < 1e-10


def test_heal_resync_matches_batch_kpca_f32():
    """Post-heal the state must match a from-scratch batch KPCA of the
    stored points to f32 round-off (acceptance: <= 1e-6)."""
    s, _ = _stream(12, dtype=jnp.float32)
    st = s.kpca_state
    bad = faults.corrupt_eigvecs(st, magnitude=0.5, seed=2)
    healed = hl.heal_kpca(bad, SPEC, True)   # auto escalates to resync
    m = int(st.m)
    K = kf.gram_block(st.X[:m], st.X[:m], spec=SPEC)
    lam, _ = batch_mod.batch_kpca(K, adjusted=True)
    np.testing.assert_allclose(np.sort(np.asarray(healed.L[:m])),
                               np.asarray(lam), atol=1e-6)
    assert hl.exact_orth_residual(healed) < 1e-5
    # the re-fit oracle lands on the same eigensystem
    refit = batch_mod.refit_state(st, SPEC, adjusted=True)
    np.testing.assert_allclose(np.asarray(healed.L), np.asarray(refit.L),
                               atol=1e-6)


def test_heal_noop_when_healthy_and_restore_rung():
    s, _ = _stream(10)
    st = s.kpca_state
    assert hl.heal_kpca(st, SPEC, True) is st   # auto: no-op
    poisoned = faults.poison_stored_row(st, row=1)
    with pytest.raises(hl.HealthError):
        hl.heal_kpca(poisoned, SPEC, True)
    with pytest.raises(hl.HealthError):
        hl.resync(poisoned, SPEC, True)


def test_engine_heal_routes_state_kinds():
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    engine = eng.Engine(SPEC, plan, adjusted=True)

    # plain KPCAState
    s, _ = _stream(10)
    bad = faults.corrupt_eigvecs(s.kpca_state, magnitude=0.5, seed=3)
    healed = engine.heal(bad)
    assert hl.exact_orth_residual(healed) < 1e-8

    # WindowState: ages/clock survive the heal
    W = 6
    wplan = eng.UpdatePlan(window=W, health=hl.DEFAULT_POLICY)
    sw, _ = _stream(10, window=W, plan=wplan)
    ws = sw.state
    wbad = ws._replace(kpca=faults.corrupt_eigvecs(ws.kpca, magnitude=0.5,
                                                   seed=4))
    wh = eng.Engine(SPEC, wplan, adjusted=True).heal(wbad)
    np.testing.assert_array_equal(np.asarray(wh.ages), np.asarray(ws.ages))
    assert int(wh.clock) == int(ws.clock)
    assert hl.exact_orth_residual(wh.kpca) < 1e-8


def test_stream_heal_after_drift_matches_batch():
    """Drift past threshold triggers heal; post-heal == batch KPCA."""
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    s, _ = _stream(12, plan=plan, dtype=jnp.float32)
    # inject drift directly into the stream state
    s.state = faults.corrupt_eigvecs(s.state, magnitude=0.3, seed=9)
    s.health = hl.probe(s.state, s.health, plan.health)
    assert not s.is_healthy()
    s.heal()
    s.health = hl.probe(s.state, s.health, plan.health)
    assert s.is_healthy()
    st = s.kpca_state
    m = int(st.m)
    K = kf.gram_block(st.X[:m], st.X[:m], spec=SPEC)
    lam, _ = batch_mod.batch_kpca(K, adjusted=True)
    np.testing.assert_allclose(np.sort(np.asarray(st.L[:m])),
                               np.asarray(lam), atol=1e-6)


# ------------------------------------------- serving degradation ------
def test_double_buffer_never_publishes_unhealthy():
    s, rng = _stream(10)
    buf = serving.DoubleBuffer(n_components=4, adjusted=True)
    with pytest.raises(ValueError):
        buf.publish(s.kpca_state, healthy=False)   # nothing to fall back on
    snap0 = buf.publish(s.kpca_state)
    gen0 = int(snap0.generation)

    s.update(jnp.asarray(rng.normal(size=(4,))))
    snap1 = buf.publish(s.kpca_state, healthy=False)
    assert snap1 is snap0
    assert buf.skipped == 1
    assert int(buf.front.generation) == gen0
    # queries still served from the stale-but-correct front
    y = buf.query(jnp.asarray(rng.normal(size=(3, 4))), spec=SPEC)
    assert np.isfinite(np.asarray(y)).all()

    snap2 = buf.publish(s.kpca_state, healthy=True)
    assert int(snap2.generation) == gen0 + 1
    assert buf.ref_lam is not None and buf.ref_lam.shape == (4,)


def test_ingest_serve_loop_serves_stale_under_faults():
    from repro.launch.serve import IngestServeLoop

    rng = np.random.default_rng(0)
    B, d, cap = 2, 4, 16
    plan = eng.UpdatePlan(serve_every=1, serve_components=4,
                          health=hl.DEFAULT_POLICY)
    batch = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d))), cap,
                            SPEC, plan=plan, dtype=jnp.float64)
    loop = IngestServeLoop(batch, SPEC, n_components=4)
    loop.ingest(jnp.asarray(rng.normal(size=(B, d))))
    gen = loop.generation
    snap = loop.snaps

    # corrupt tenant 0 beyond repair: U *and* stored rows poisoned, so the
    # heal ladder ends in HealthError and publication must be refused
    batch._flush()
    full = batch._full
    U = np.array(full.U)
    U[0, :, 0] = np.nan
    X = np.array(full.X)
    X[0, 0] = np.nan
    batch._full = full._replace(U=jnp.asarray(U), X=jnp.asarray(X))

    published = loop.ingest(jnp.asarray(rng.normal(size=(B, d))))
    assert not published
    assert loop.skipped == 1
    assert loop.generation == gen
    assert loop.snaps is snap    # same object: the last healthy snapshot
    y = loop.query(jnp.asarray(rng.normal(size=(B, 3, d))))
    assert np.isfinite(np.asarray(y)).all()


def test_ingest_serve_loop_heals_and_publishes():
    from repro.launch.serve import IngestServeLoop

    rng = np.random.default_rng(1)
    B, d, cap = 2, 4, 16
    plan = eng.UpdatePlan(serve_every=1, serve_components=4,
                          health=hl.DEFAULT_POLICY)
    batch = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d))), cap,
                            SPEC, plan=plan, dtype=jnp.float64)
    loop = IngestServeLoop(batch, SPEC, n_components=4)
    gen = loop.generation

    # recoverable corruption (stored rows intact): heal, then publish
    batch._flush()
    full = batch._full
    U = np.array(full.U)
    U[1, :3, :3] += 0.4
    batch._full = full._replace(U=jnp.asarray(U))

    assert loop.ingest(jnp.asarray(rng.normal(size=(B, d))))
    assert loop.heals >= 1
    assert loop.skipped == 0
    assert loop.generation == gen + 1


def test_staleness_aware_publication():
    from repro.launch.serve import IngestServeLoop

    rng = np.random.default_rng(2)
    B, d, cap = 2, 4, 32
    plan = eng.UpdatePlan(serve_every=1000, serve_components=4,
                          health=hl.DEFAULT_POLICY)
    batch = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d))), cap,
                            SPEC, plan=plan, dtype=jnp.float64)
    loop = IngestServeLoop(batch, SPEC, n_components=4,
                           publish_on_drift=0.05)
    gen = loop.generation
    published = 0
    for t in range(12):
        # growing spectrum: drift accumulates until the trigger fires
        published += int(loop.ingest(jnp.asarray(
            rng.normal(size=(B, d)) * (1.0 + 0.5 * t))))
    assert loop.drift_publishes >= 1
    assert published == loop.drift_publishes   # cadence (1000) never fired
    assert loop.generation > gen


# ------------------------------------------------------------- soak ---
def test_soak_f32_periodic_heal_bounds_residual():
    """5k-step f32 sliding-window soak: with periodic healing the exact
    orthogonality residual stays under the policy threshold; with healing
    off the same stream drifts measurably past the healed run."""
    W, cap, d = 24, 32, 4
    rng = np.random.default_rng(0)
    plan = eng.UpdatePlan(window=W)
    engine = eng.Engine(SPEC, plan, adjusted=True)
    hengine = eng.Engine(SPEC, plan._replace(health=hl.DEFAULT_POLICY),
                         adjusted=True)

    from repro.core import window as win
    x0 = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
    ws_off = win.init_window(x0, cap, SPEC, adjusted=True,
                             dtype=jnp.float32)
    ws_on = ws_off

    steps, chunk = 5000, 500
    for c in range(steps // chunk):
        xs = jnp.asarray(rng.normal(size=(chunk, d)), jnp.float32)
        ws_off = engine.window_block(ws_off, xs, window=W)
        ws_on = engine.window_block(ws_on, xs, window=W)
        ws_on = hengine.heal(ws_on)
    r_off = hl.exact_orth_residual(ws_off.kpca)
    r_on = hl.exact_orth_residual(ws_on.kpca)
    assert np.isfinite(r_on) and np.isfinite(r_off)
    assert r_on <= hl.DEFAULT_POLICY.orth_tol, (r_on, r_off)
    assert r_on <= r_off, (r_on, r_off)


def test_checkpoint_restore_continue_after_corruption(tmp_path):
    """Restore rung end-to-end: corrupt stored rows -> heal raises ->
    reload last checkpoint, replay the tail -> equals the uninterrupted
    stream."""
    from repro.checkpoint import latest_step, load_checkpoint, \
        save_checkpoint

    d = str(tmp_path)
    plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
    engine = eng.Engine(SPEC, plan, adjusted=True)
    s, rng = _stream(10, plan=plan)
    st = s.kpca_state
    save_checkpoint(d, 0, st._asdict())

    tail = rng.normal(size=(5, 4))
    ref = st
    h = hl.init_health(st.L.dtype)
    for t in range(5):
        ref, h = engine.update_guarded(ref, h, jnp.asarray(tail[t]))

    # corruption strikes the live state: the ladder ends in HealthError
    dead = faults.poison_stored_row(st, row=0)
    with pytest.raises(hl.HealthError):
        engine.heal(dead, level="resync")

    step = latest_step(d)
    restored = type(st)(**load_checkpoint(
        d, step, jax.eval_shape(lambda: st._asdict())))
    h2 = hl.init_health(st.L.dtype)
    got = restored
    for t in range(5):
        got, h2 = engine.update_guarded(got, h2, jnp.asarray(tail[t]))
    _assert_trees_equal(got, ref)
