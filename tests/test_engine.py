"""Engine layer (repro.core.engine): UpdatePlan routing, bucketed
slice/update/scatter, shrink compaction, and vmapped multi-tenant
streaming — all consumers share this one code path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, inkpca, kernels_fn as kf, rankone

RNG = np.random.default_rng(17)
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)


# ----------------------------------------------------------- UpdatePlan ---
def test_plan_fused_and_inner_matmul():
    assert not eng.UpdatePlan(matmul="jnp").fused
    assert not eng.UpdatePlan(matmul="pallas").fused
    assert eng.UpdatePlan(matmul="jnp2").fused
    assert eng.UpdatePlan(matmul="pallas2").fused
    assert eng.UpdatePlan(matmul="jnp2").inner_matmul == "jnp"
    assert eng.UpdatePlan(matmul="pallas2").inner_matmul == "pallas"
    assert eng.UpdatePlan(matmul="pallas").inner_matmul == "pallas"


def test_kernel_plan_normalizes_dispatch_fields():
    """Jitted updates must cache once per numerics, not per bucket ladder."""
    a = eng.UpdatePlan(dispatch="bucketed", min_bucket=8).kernel_plan()
    b = eng.UpdatePlan(dispatch="fixed", min_bucket=64).kernel_plan()
    assert a == b
    assert hash(a) == hash(b)       # usable as a jit static argument


def test_resolve_iters_by_dtype():
    assert eng.resolve_iters(None, jnp.float64) == 62
    assert eng.resolve_iters(None, jnp.float32) == 32
    assert eng.resolve_iters(17, jnp.float32) == 17


# ----------------------------------------------- engine stream dispatch ---
def test_engine_bucketed_stream_matches_fixed():
    X = RNG.normal(size=(24, 4))
    fix = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=True)
    buk = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
                     adjusted=True)
    s_fix = inkpca.init_state(jnp.asarray(X[:4]), 32, SPEC, adjusted=True,
                              dtype=jnp.float64)
    s_buk = s_fix
    for i in range(4, 14):
        s_fix = fix.update(s_fix, jnp.asarray(X[i]))
        s_buk = buk.update(s_buk, jnp.asarray(X[i]))
    s_fix = fix.update_block(s_fix, jnp.asarray(X[14:]))
    s_buk = buk.update_block(s_buk, jnp.asarray(X[14:]))
    assert int(s_fix.m) == int(s_buk.m) == 24
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(s_buk.L, s_buk.U, s_buk.m)),
        np.asarray(rankone.reconstruct(s_fix.L, s_fix.U, s_fix.m)),
        atol=1e-8)


def test_engine_fused_plan_matches_sequential():
    X = RNG.normal(size=(16, 3))
    seq = eng.Engine(SPEC, eng.UpdatePlan(matmul="jnp"), adjusted=True)
    fus = eng.Engine(SPEC, eng.UpdatePlan(matmul="jnp2"), adjusted=True)
    s0 = inkpca.init_state(jnp.asarray(X[:4]), 16, SPEC, adjusted=True,
                           dtype=jnp.float64)
    s1 = seq.update_block(s0, jnp.asarray(X[4:]))
    s2 = fus.update_block(s0, jnp.asarray(X[4:]))
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(s2.L, s2.U, s2.m)),
        np.asarray(rankone.reconstruct(s1.L, s1.U, s1.m)), atol=1e-7)


# ------------------------------------------------- truncate / compaction ---
def _grown_stream(n=16, capacity=64, adjusted=False):
    X = RNG.normal(size=(n, 4))
    st = inkpca.KPCAStream(jnp.asarray(X[:4]), capacity, SPEC,
                           adjusted=adjusted, dtype=jnp.float64,
                           dispatch="bucketed", min_bucket=8)
    st.update_block(jnp.asarray(X[4:]))
    return st, X


def test_compact_shapes_shrink_to_bucket():
    """The satellite claim: compaction frees the old large bucket — the
    state's arrays really are re-allocated at the active bucket."""
    st, _ = _grown_stream(n=16, capacity=64)
    st.truncate(6, compact=True)
    Mb = eng.bucket_for(7, 64, 8)           # = 8
    assert st.state.L.shape == (Mb,)
    assert st.state.U.shape == (Mb, Mb)
    assert st.state.K1.shape == (Mb,)
    assert st.state.X.shape == (Mb, 4)
    assert int(st.state.m) == 6
    assert bool(jnp.isfinite(st.state.L).all())


def test_compact_exact_for_prefix_supported_state():
    """For a never-truncated stream (support is already a prefix) compaction
    is a pure re-allocation: the active block reconstruction is unchanged."""
    st, _ = _grown_stream(n=12, capacity=64)
    m = int(st.state.m)
    before = np.asarray(st.engine.compact(st.state).L[:m])
    rec0 = np.asarray(rankone.reconstruct(st.state.L, st.state.U,
                                          st.state.m))[:m, :m]
    comp = st.engine.compact(st.state)
    rec1 = np.asarray(rankone.reconstruct(comp.L, comp.U, comp.m))[:m, :m]
    np.testing.assert_allclose(rec1, rec0, atol=1e-9)
    np.testing.assert_allclose(np.sort(before), np.sort(np.asarray(
        st.state.L[:m])), atol=1e-9)


def test_truncate_without_compact_keeps_bucketed_correct():
    """Post-truncate, kept eigenvectors have support on the OLD rows; the
    engine must keep bucketing at the support floor or results diverge
    from the fixed path."""
    X = RNG.normal(size=(26, 4))
    fix = inkpca.KPCAStream(jnp.asarray(X[:4]), 64, SPEC, adjusted=False,
                            dtype=jnp.float64)
    buk = inkpca.KPCAStream(jnp.asarray(X[:4]), 64, SPEC, adjusted=False,
                            dtype=jnp.float64, dispatch="bucketed",
                            min_bucket=8)
    fix.update_block(jnp.asarray(X[4:18]))
    buk.update_block(jnp.asarray(X[4:18]))
    fix.truncate(5)
    buk.truncate(5)
    fix.update_block(jnp.asarray(X[18:]))
    buk.update_block(jnp.asarray(X[18:]))
    np.testing.assert_allclose(np.asarray(buk.reconstruction()),
                               np.asarray(fix.reconstruction()), atol=1e-8)


def test_truncate_with_compact_keeps_streaming_until_exhaustion():
    """A compacted state keeps streaming inside its new (smaller) capacity
    and raises — rather than silently clamping — once it fills up."""
    st, X = _grown_stream(n=16, capacity=64)
    st.truncate(6, compact=True)            # re-allocated at bucket 8
    st.update_block(jnp.asarray(RNG.normal(size=(2, 4))))
    assert int(st.state.m) == 8
    assert bool(jnp.isfinite(st.state.L).all())
    assert bool(jnp.isfinite(st.state.U).all())
    with pytest.raises(ValueError):
        st.update(jnp.asarray(RNG.normal(size=(4,))))
    # an explicit compaction capacity leaves room to keep growing
    st2, _ = _grown_stream(n=16, capacity=64)
    st2.truncate(6, compact=True)
    st2.state = st2.engine.compact(st2.state, capacity=32)
    st2.update_block(jnp.asarray(RNG.normal(size=(8, 4))))
    assert int(st2.state.m) == 14


def test_engine_truncate_default_is_safe_for_direct_callers():
    """Bare engine.truncate on a bucketed engine must leave a state that
    streams correctly WITHOUT any min_rows bookkeeping (support folded to
    a prefix at unchanged capacity)."""
    X = RNG.normal(size=(24, 4))
    engine = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8), adjusted=False)
    state = inkpca.init_state(jnp.asarray(X[:4]), 64, SPEC, adjusted=False,
                              dtype=jnp.float64)
    state = engine.update_block(state, jnp.asarray(X[4:18]))
    state = engine.truncate(state, 5)       # default: compact, same capacity
    assert state.L.shape == (64,)           # capacity unchanged
    # support is a prefix again: rows >= 5 of active columns are zero
    assert float(jnp.abs(state.U[5:, :5]).max()) < 1e-12
    state = engine.update_block(state, jnp.asarray(X[18:]))
    assert bool(jnp.isfinite(state.L).all())
    rec = rankone.reconstruct(state.L, state.U, state.m)
    assert bool(jnp.isfinite(rec).all())


def test_compact_capacity_must_hold_active_set():
    st, _ = _grown_stream(n=12, capacity=64)
    with pytest.raises(ValueError):
        st.engine.compact(st.state, capacity=int(st.state.m))


# ------------------------------------------------------ multi-tenant batch --
def _tenant_setup(B=3, capacity=32, min_bucket=8, n=12, d=5):
    x0 = jnp.asarray(RNG.normal(size=(B, 4, d)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=min_bucket)
    batch = eng.StreamBatch(x0, capacity, SPEC, plan=plan, adjusted=True,
                            dtype=jnp.float64)
    streams = [inkpca.KPCAStream(x0[i], capacity, SPEC, adjusted=True,
                                 dtype=jnp.float64, plan=plan)
               for i in range(B)]
    X = jnp.asarray(RNG.normal(size=(n, B, d)))
    return batch, streams, X


def test_streambatch_matches_per_tenant_loop():
    batch, streams, X = _tenant_setup()
    for t in range(X.shape[0]):
        batch.update(X[t])
        for i, s in enumerate(streams):
            s.update(X[t, i])
    for i, s in enumerate(streams):
        st = batch.state_of(i)
        np.testing.assert_allclose(np.asarray(st.L), np.asarray(s.state.L),
                                   atol=1e-9)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(st.L, st.U, st.m)),
            np.asarray(s.reconstruction()), atol=1e-8)


def test_streambatch_update_block_matches_stepwise():
    batch, streams, X = _tenant_setup()
    batch.update_block(X)
    for i, s in enumerate(streams):
        s.update_block(X[:, i])
        np.testing.assert_allclose(np.asarray(batch.state_of(i).L),
                                   np.asarray(s.state.L), atol=1e-9)


def test_streambatch_active_mask_diverges_tenants():
    batch, _, X = _tenant_setup(B=3)
    batch.update(X[0])
    before = np.asarray(batch.state_of(1).L)
    batch.update(X[1], active=jnp.asarray([True, False, True]))
    ms = [int(v) for v in np.asarray(batch.states.m)]
    assert ms == [6, 5, 6]
    # idle tenant's state is bitwise untouched by the masked step
    np.testing.assert_array_equal(np.asarray(batch.state_of(1).L), before)


def test_streambatch_transform_shape_and_finite():
    batch, _, X = _tenant_setup(B=3, d=5)
    batch.update_block(X)
    q = jnp.asarray(RNG.normal(size=(3, 4, 5)))
    y = batch.transform(q, n_components=3)
    assert y.shape == (3, 4, 3)
    assert bool(jnp.isfinite(y).all())


def test_streambatch_capacity_exhaustion_raises():
    x0 = jnp.asarray(RNG.normal(size=(2, 4, 3)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=4)
    batch = eng.StreamBatch(x0, 8, SPEC, plan=plan, dtype=jnp.float64)
    batch.update_block(jnp.asarray(RNG.normal(size=(4, 2, 3))))
    with pytest.raises(ValueError):
        batch.update(jnp.asarray(RNG.normal(size=(2, 3))))


def test_streambatch_rejects_non_batched_seeds():
    with pytest.raises(ValueError):
        eng.StreamBatch(jnp.zeros((4, 3)), 16, SPEC)


# ------------------------------------------- bucket-homogeneous cohorts ---
def _mixed_batches(cohorts, B=6, d=4, capacity=64):
    rng = np.random.default_rng(23)
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    seeds = jnp.asarray(rng.normal(size=(B, 3, d)))
    batch = eng.StreamBatch(seeds, capacity, SPEC, plan=plan, adjusted=True,
                            dtype=jnp.float64, cohorts=cohorts)
    streams = [inkpca.KPCAStream(seeds[i], capacity, SPEC, adjusted=True,
                                 dtype=jnp.float64, plan=plan)
               for i in range(B)]
    return batch, streams, rng


def test_streambatch_bucket_cohorts_match_per_tenant_loop():
    """Bucket-homogeneous cohorts (masked updates diverging tenant sizes,
    then a block) must equal B independent Python-loop streams."""
    batch, streams, rng = _mixed_batches("bucket")
    B, d = len(streams), 4
    for step in range(18):
        xs = jnp.asarray(rng.normal(size=(B, d)))
        active = np.array([(step % (i + 1)) == 0 for i in range(B)])
        batch.update(xs, active=jnp.asarray(active))
        for i, s in enumerate(streams):
            if active[i]:
                s.update(xs[i])
    xs_blk = jnp.asarray(rng.normal(size=(6, B, d)))
    batch.update_block(xs_blk)
    for i, s in enumerate(streams):
        s.update_block(xs_blk[:, i])
    # the cohort actually split into >1 bucket group
    assert batch._groups is not None and len(batch._groups) > 1
    assert len({g["Mb"] for g in batch._groups}) == len(batch._groups)
    sts = batch.states
    for i, s in enumerate(streams):
        np.testing.assert_allclose(np.asarray(sts.L[i]),
                                   np.asarray(s.state.L), atol=1e-9)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(sts.L[i], sts.U[i], sts.m[i])),
            np.asarray(s.reconstruction()), atol=1e-8)


def test_streambatch_bucket_cohorts_transform_matches_max():
    """transform() must agree between cohort geometries (same states)."""
    rng = np.random.default_rng(29)
    B, d = 4, 4
    seeds = jnp.asarray(rng.normal(size=(B, 3, d)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    kw = dict(plan=plan, adjusted=True, dtype=jnp.float64)
    a = eng.StreamBatch(seeds, 32, SPEC, cohorts="max", **kw)
    b = eng.StreamBatch(seeds, 32, SPEC, cohorts="bucket", **kw)
    xs = jnp.asarray(rng.normal(size=(8, B, d)))
    a.update_block(xs)
    b.update_block(xs)
    q = jnp.asarray(rng.normal(size=(B, 5, d)))
    ya = a.transform(q, n_components=3)
    yb = b.transform(q, n_components=3)
    np.testing.assert_allclose(np.asarray(yb), np.asarray(ya), atol=1e-8)


def test_streambatch_bucket_cohorts_capacity_exhaustion_raises():
    rng = np.random.default_rng(31)
    x0 = jnp.asarray(rng.normal(size=(2, 4, 3)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=4)
    batch = eng.StreamBatch(x0, 8, SPEC, plan=plan, dtype=jnp.float64,
                            cohorts="bucket")
    batch.update_block(jnp.asarray(rng.normal(size=(4, 2, 3))))
    with pytest.raises(ValueError):
        batch.update(jnp.asarray(rng.normal(size=(2, 3))))


def test_streambatch_bucket_padded_identical_states():
    """ISSUE satellite: padded and unpadded cohorts produce IDENTICAL
    states — pad lanes are masked out of every step and never scattered
    back (bitwise equality, masked updates + scans + regroup crossings)."""
    rng = np.random.default_rng(43)
    B, d = 6, 4
    seeds = jnp.asarray(rng.normal(size=(B, 3, d)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    kw = dict(plan=plan, adjusted=True, dtype=jnp.float64)
    a = eng.StreamBatch(seeds, 64, SPEC, cohorts="bucket", **kw)
    b = eng.StreamBatch(seeds, 64, SPEC, cohorts="bucket-padded", **kw)
    padded_seen = False
    for step in range(18):
        xs = jnp.asarray(rng.normal(size=(B, d)))
        act = np.array([(step % (i + 1)) == 0 for i in range(B)])
        a.update(xs, active=jnp.asarray(act))
        b.update(xs, active=jnp.asarray(act))
        padded_seen |= any(len(g["idx_pad"]) > g["n_real"]
                           for g in b._groups)
    xs_blk = jnp.asarray(rng.normal(size=(6, B, d)))
    a.update_block(xs_blk)
    b.update_block(xs_blk)
    # padding really happened at some point, and sizes stay powers of two
    assert padded_seen
    for g in b._groups:
        size = len(g["idx_pad"])
        assert size & (size - 1) == 0
    for la, lb in zip(jax.tree.leaves(a.states), jax.tree.leaves(b.states)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    # transform agrees too (pad lanes sliced off)
    q = jnp.asarray(rng.normal(size=(B, 4, d)))
    np.testing.assert_allclose(np.asarray(b.transform(q, n_components=3)),
                               np.asarray(a.transform(q, n_components=3)),
                               atol=1e-12)


def test_streambatch_bucket_padded_bounded_compile_keys():
    """Padded group sizes take at most log2(B)+1 distinct values per
    bucket, whatever churn does to group cuts (the recompile bound)."""
    sizes = set()
    rng = np.random.default_rng(47)
    B = 7
    seeds = jnp.asarray(rng.normal(size=(B, 3, 3)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    batch = eng.StreamBatch(seeds, 32, SPEC, plan=plan, adjusted=True,
                            dtype=jnp.float64, cohorts="bucket-padded")
    for step in range(16):
        xs = jnp.asarray(rng.normal(size=(B, 3)))
        act = np.array([(step % (i + 2)) != 0 for i in range(B)])
        batch.update(xs, active=jnp.asarray(act))
        for g in batch._groups:
            sizes.add((len(g["idx_pad"]), g["Mb"]))
    pad_sizes = {s for s, _ in sizes}
    assert all(s & (s - 1) == 0 for s in pad_sizes)
    assert len(pad_sizes) <= int(np.ceil(np.log2(B))) + 1


# ------------------------------------- Nyström truncate/compact guard ---
def test_nystrom_truncate_compact_preserves_observed_rows():
    """Engine.truncate(compact=True) on a grow_rows Nyström state must keep
    every observed row/landmark (row-support clamp) while shrinking
    capacity, and reproduce the uncompacted truncated reconstruction."""
    from repro.core import nystrom

    rng = np.random.default_rng(37)
    d, cap = 4, 64
    engine = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8), adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    st = nystrom.init_nystrom(None, x0, cap, SPEC, dtype=jnp.float64,
                              grow_rows=True)
    for _ in range(16):
        st = engine.add_landmark(st, None, jnp.asarray(rng.normal(size=d)))
    st = nystrom.observe_rows(st, jnp.asarray(rng.normal(size=(10, d))),
                              SPEC)
    n_rows, m_before = st.Knm.shape[0], int(st.kpca.m)

    t_nc = engine.truncate(st, 8, compact=False)
    t_c = engine.truncate(st, 8, compact=True)
    # observed rows and landmark support survive; capacity shrinks
    assert t_c.Knm.shape[0] == n_rows
    assert t_c.Xrows.shape == st.Xrows.shape
    assert int(t_c.kpca.m) == m_before
    assert t_c.kpca.L.shape[0] < cap
    np.testing.assert_allclose(
        np.asarray(nystrom.reconstruct_tilde(t_c)),
        np.asarray(nystrom.reconstruct_tilde(t_nc)), atol=1e-10)
    # streaming continues on the compacted state
    t2 = nystrom.observe_rows(t_c, jnp.asarray(rng.normal(size=(2, d))),
                              SPEC)
    t2 = engine.add_landmark(t2, None, jnp.asarray(rng.normal(size=d)))
    assert bool(jnp.isfinite(nystrom.reconstruct_tilde(t2)).all())
    # explicit capacity below the row-support floor is refused
    with pytest.raises(ValueError):
        engine.truncate(st, 8, compact=True, capacity=16)


def test_nystrom_uncompacted_truncate_add_landmark_min_rows():
    """After an UNcompacted Nyström truncate, bucketed add_landmark must
    honor the row-support floor (min_rows = pre-truncation landmark
    count) and then match the fixed-dispatch reference exactly."""
    from repro.core import nystrom

    rng = np.random.default_rng(41)
    d, cap = 4, 64
    buk = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                          min_bucket=8), adjusted=False)
    fix = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=False)
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    st = nystrom.init_nystrom(None, x0, cap, SPEC, dtype=jnp.float64,
                              grow_rows=True)
    for _ in range(16):
        st = buk.add_landmark(st, None, jnp.asarray(rng.normal(size=d)))
    r = int(st.kpca.m)
    t = buk.truncate(st, 8, compact=False)
    x_new = [jnp.asarray(rng.normal(size=d)) for _ in range(3)]
    a = b = t
    for x in x_new:
        a = buk.add_landmark(a, None, x, min_rows=r)
        b = fix.add_landmark(b, None, x)
    np.testing.assert_allclose(
        np.asarray(nystrom.reconstruct_tilde(a)),
        np.asarray(nystrom.reconstruct_tilde(b)), atol=1e-9)


def test_sharded_bucketed_update_full_capacity_state():
    """A full state (m == M) still receives rank-one corrections: the
    bucketed sharded dispatcher must not demand room for m+1."""
    from repro.core import distributed as dkpca, rankone

    rng = np.random.default_rng(43)
    M = 16
    A = rng.normal(size=(M, M)); A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = jnp.asarray(np.sort(lam))
    U = jnp.asarray(vec)
    v = jnp.asarray(rng.normal(size=M))
    mesh = jax.make_mesh((1,), ("data",))
    upd = dkpca.make_sharded_update(
        mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=8))
    Ls, Us = upd(L, U, v, jnp.float64(1.7), jnp.int32(M))
    Ll, Ul = rankone.rank_one_update(L, U, v, jnp.float64(1.7),
                                     jnp.int32(M))
    np.testing.assert_allclose(np.asarray(Ls), np.asarray(Ll), atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(Ls, Us, jnp.int32(M))),
        np.asarray(rankone.reconstruct(Ll, Ul, jnp.int32(M))), atol=1e-8)
