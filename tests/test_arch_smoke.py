"""Per-assigned-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, output shapes + no NaNs (assignment req. (f))."""
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # model-zoo / driver integration tier

from repro import configs
from repro.data.synthetic import TokenStream, frontend_embeddings
from repro.models import lm

B, T = 2, 16


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(vocab=cfg.vocab, seq_len=T, global_batch=B)
    batch = frontend_embeddings(cfg, stream.batch_at(jnp.int32(0)))

    logits = lm.forward(params, cfg, batch["tokens"],
                        batch.get("embeddings"), remat=False)
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    (loss, _), grads = jax.value_and_grad(
        lambda p: lm.loss_fn(p, cfg, batch), has_aux=True)(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g.astype(jnp.float32)).all())
               for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", ["qwen3_32b", "jamba_1_5_large_398b",
                                  "xlstm_125m", "kimi_k2_1t_a32b"])
def test_arch_smoke_decode_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    caches = lm.init_caches(params, cfg, B, max_seq=8)
    tok = jnp.zeros((B, 1), jnp.int32)
    for t in range(3):
        pos = jnp.full((B, 1), t, jnp.int32)
        logits, caches = lm.decode_step(params, cfg, caches, tok, pos)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_cells_enumeration():
    cells = configs.cells(include_skipped=True)
    assert len(cells) == 40                      # 10 archs × 4 shapes
    runnable = [c for c in cells if not c[3]]
    skipped = [c for c in cells if c[3]]
    # long_500k skipped exactly for the 8 full-attention archs
    assert len(skipped) == 8
    assert all(s[1] == "long_500k" for s in skipped)
    assert {("xlstm_125m", "long_500k"), ("jamba_1_5_large_398b",
                                          "long_500k")} <= {
        (c[0], c[1]) for c in runnable}


def test_param_counts_match_assignment():
    from repro.models.config import param_count
    targets = {
        "pixtral_12b": 12e9, "jamba_1_5_large_398b": 398e9,
        "qwen3_32b": 32e9, "stablelm_12b": 12e9,
        "command_r_plus_104b": 104e9, "kimi_k2_1t_a32b": 1.0e12,
        "dbrx_132b": 132e9,
    }
    for arch, t in targets.items():
        n = param_count(configs.get_config(arch))
        assert 0.9 < n / t < 1.15, (arch, n, t)
