"""Decoupled serving: published snapshots, double buffering, the
projection kernel, and the tenant-axis mesh builders.

The serving contract under test (core/serving.py):

* ``engine.transform_state`` IS publish-then-query, so frozen-state
  transforms and snapshot queries are bit-identical by construction —
  regardless of kernel path (fused / masked-gram reference).
* Snapshots are immutable jax arrays: concurrent ingest into the working
  state can never perturb a query against a published snapshot, and the
  order of (swap, query) around a retained generation doesn't matter.
* The double-buffered (working state, snapshot) pair checkpoints and
  resumes mid-block at 1e-12.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine as eng
from repro.core import inkpca, kernels_fn as kf, krr, nystrom, serving

SPEC = kf.KernelSpec(name="rbf", sigma=2.0)


def _stream(n0=4, d=5, capacity=64, seed=0, **kw):
    rng = np.random.default_rng(seed)
    x0 = jnp.asarray(rng.normal(size=(n0, d)))
    return (inkpca.KPCAStream(x0, capacity, SPEC, adjusted=True,
                              dtype=jnp.float64, **kw), rng, d)


def _bits_equal(a, b):
    return (np.asarray(a) == np.asarray(b)).all()


@pytest.mark.parametrize("fuse", [False, True])
def test_transform_is_publish_query(fuse):
    """Frozen-state transform == snapshot query, bit for bit, on both
    kernel paths."""
    plan = eng.DEFAULT_PLAN._replace(fuse_krow=fuse)
    stream, rng, d = _stream(plan=plan)
    for _ in range(6):
        stream.update(jnp.asarray(rng.normal(size=(d,))))
    st = stream.kpca_state
    q = jnp.asarray(rng.normal(size=(7, d)))
    y1 = eng.transform_state(st, q, n_components=4, spec=SPEC, plan=plan,
                            adjusted=True)
    snap = serving.publish_transform(st, n_components=4, adjusted=True)
    y2 = serving.query(snap, q, spec=SPEC, plan=plan)
    assert _bits_equal(y1, y2)


def test_snapshot_immutable_under_ingest():
    """Queries against a published snapshot are bit-identical no matter
    how much concurrent ingest hits the working state."""
    stream, rng, d = _stream()
    for _ in range(5):
        stream.update(jnp.asarray(rng.normal(size=(d,))))
    buf = serving.DoubleBuffer(stream.kpca_state, n_components=4)
    q = jnp.asarray(rng.normal(size=(6, d)))
    y0 = np.asarray(buf.query(q, spec=SPEC))
    for _ in range(8):                       # ingest into A; B untouched
        stream.update(jnp.asarray(rng.normal(size=(d,))))
        assert _bits_equal(buf.query(q, spec=SPEC), y0)
    # After republishing from the mutated state, queries see the new
    # eigensystem (and match its frozen transform exactly).
    buf.publish(stream.kpca_state)
    y1 = buf.query(q, spec=SPEC)
    assert not _bits_equal(y1, y0)
    assert _bits_equal(
        y1, eng.transform_state(stream.kpca_state, q, n_components=4,
                                spec=SPEC, adjusted=True))


def test_swap_then_query_commutes():
    """swap-then-query == query-then-swap on the published generation: a
    retained snapshot handle answers identically before and after the
    next publish (one publish ahead is the double-buffer guarantee; the
    handle retired two publishes back gets donated)."""
    stream, rng, d = _stream()
    for _ in range(5):
        stream.update(jnp.asarray(rng.normal(size=(d,))))
    buf = serving.DoubleBuffer(stream.kpca_state, n_components=4)
    snap_g = buf.front
    q = jnp.asarray(rng.normal(size=(6, d)))
    y_before = np.asarray(serving.query(snap_g, q, spec=SPEC))

    stream.update(jnp.asarray(rng.normal(size=(d,))))
    buf.publish(stream.kpca_state)           # swap: generation g+1 live
    y_after = serving.query(snap_g, q, spec=SPEC)
    assert _bits_equal(y_before, y_after)
    assert int(buf.front.generation) == int(snap_g.generation) + 1


def test_double_buffer_checkpoint_roundtrip_mid_block():
    """Checkpointing the (working state, published snapshot) pair
    MID-BLOCK — snapshot one generation stale — resumes to the same
    service trajectory at 1e-12."""
    from repro.checkpoint import npz_store

    plan = eng.DEFAULT_PLAN
    stream, rng, d = _stream()
    for _ in range(6):
        stream.update(jnp.asarray(rng.normal(size=(d,))))
    buf = serving.DoubleBuffer(stream.kpca_state, n_components=4)
    # Mid-block: ingest past the publish point without republishing.
    tail = [jnp.asarray(rng.normal(size=(d,))) for _ in range(3)]
    for x in tail:
        stream.update(x)

    ckpt_dir = "/tmp/test_serving_ckpt"
    pair = {"state": stream.kpca_state, "snap": buf.front}
    npz_store.save_checkpoint(ckpt_dir, 0, pair)
    restored = npz_store.load_checkpoint(
        ckpt_dir, 0, jax.tree.map(jnp.zeros_like, pair))

    q = jnp.asarray(rng.normal(size=(5, d)))
    more = [jnp.asarray(rng.normal(size=(d,))) for _ in range(3)]

    def finish(state, snap):
        y_stale = serving.query(snap, q, spec=SPEC)     # pre-swap reads
        for x in more:
            state = inkpca.ingest_adjusted(state, x, spec=SPEC, plan=plan)
        snap = serving.publish_transform(
            state, n_components=4, adjusted=True,
            generation=snap.generation + 1)
        return y_stale, serving.query(snap, q, spec=SPEC), snap

    ys1, yn1, s1 = finish(stream.kpca_state, buf.front)
    ys2, yn2, s2 = finish(restored["state"], restored["snap"])
    assert float(jnp.abs(ys1 - ys2).max()) < 1e-12
    assert float(jnp.abs(yn1 - yn2).max()) < 1e-12
    assert int(s1.generation) == int(s2.generation)


def test_krr_and_nystrom_snapshot_heads():
    """The KRR / Nyström snapshot heads reproduce their per-call query
    paths exactly (same contraction, hoisted to publication)."""
    rng = np.random.default_rng(3)
    d = 4
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    y0 = jnp.asarray(rng.normal(size=(4,)))
    kst = krr.init_krr(x0, y0, 32, SPEC)
    for _ in range(5):
        kst = krr.add_point(kst, jnp.asarray(rng.normal(size=(d,))),
                            float(rng.normal()), SPEC)
    xq = jnp.asarray(rng.normal(size=(6, d)))
    lam = 0.1
    snap = krr.publish_predict(kst, lam)
    assert _bits_equal(krr.snapshot_predict(snap, xq, SPEC),
                       krr.predict(kst, xq, lam, SPEC))

    nst = nystrom.init_nystrom(None, x0, 32, SPEC, dtype=jnp.float64,
                               grow_rows=True)
    for _ in range(5):
        x = jnp.asarray(rng.normal(size=(d,)))
        nst = nystrom.observe_rows(nst, x, SPEC)
        nst = nystrom.add_landmark(nst, None, x, SPEC)
    n = int(nst.Knm.shape[0])
    fsnap = nystrom.publish_features(nst, n)
    assert _bits_equal(nystrom.snapshot_features(fsnap, xq, SPEC),
                       nystrom.query_features(nst, xq, n, SPEC))


def test_stream_batch_publish_matches_transform():
    """Tenant-stacked snapshots from ``StreamBatch.publish`` answer
    ``query_batch`` bit-identically to the engine's frozen transform."""
    rng = np.random.default_rng(4)
    B, d = 3, 5
    plan = eng.DEFAULT_PLAN._replace(serve_components=4)
    sb = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 4, d))), 64, SPEC,
                         plan=plan, adjusted=True, dtype=jnp.float64)
    for _ in range(4):
        sb.update(jnp.asarray(rng.normal(size=(B, d))))
    snaps = sb.publish()
    q = jnp.asarray(rng.normal(size=(B, 6, d)))
    y = serving.query_batch(snaps, q, spec=SPEC, plan=plan)
    assert _bits_equal(y, sb.transform(q, n_components=4))
    assert list(np.asarray(snaps.generation)) == [0] * B
    assert list(np.asarray(sb.publish().generation)) == [1] * B


def test_project_vectors_kernel_matches_ref():
    """The rect-pruned Uᵀv projection kernel (interpret mode) matches the
    dense reference on the active block and writes exact zeros beyond it
    (inactive columns are identity, supported on rows >= m)."""
    from repro.kernels.eigvec_update import ops as eops

    rng = np.random.default_rng(5)
    M, m, C = 320, 150, 2
    U = np.eye(M)
    qq, _ = np.linalg.qr(rng.normal(size=(m, m)))
    U[:m, :m] = qq
    U = jnp.asarray(U)
    V = jnp.asarray(rng.normal(size=(M, C))) * (np.arange(M) < m)[:, None]
    ref = np.asarray(eops.project_vectors(U, V, jnp.int32(m), force="ref"))
    ker = np.asarray(eops.project_vectors(U, V, jnp.int32(m),
                                          force="interpret"))
    g_cols = -(-m // 128) * 128              # active column tiles
    assert np.abs(ker[:g_cols] - ref[:g_cols]).max() < 1e-10
    assert (ker[g_cols:] == 0.0).all()
    # Masking contract: rows >= m of v are ignored even if nonzero.
    V_dirty = V.at[m:].set(1.0)
    ker2 = np.asarray(eops.project_vectors(U, V_dirty, jnp.int32(m),
                                           force="interpret"))
    assert np.abs(ker2[:g_cols] - ref[:g_cols]).max() < 1e-10


def test_fused_ingest_kernel_projection_matches_dense():
    """ingest_adjusted (second pair projected through the rect-pruned
    kernel) tracks the dense update_adjusted chain."""
    plan = eng.DEFAULT_PLAN
    rng = np.random.default_rng(6)
    d = 5
    x0 = jnp.asarray(rng.normal(size=(4, d)))
    st_a = inkpca.init_state(x0, 64, SPEC, adjusted=True, dtype=jnp.float64)
    st_b = st_a
    for _ in range(8):
        x = jnp.asarray(rng.normal(size=(d,)))
        st_a = inkpca.ingest_adjusted(st_a, x, spec=SPEC, plan=plan)
        a, k_new = inkpca._masked_row(st_b, x, SPEC)
        st_b = inkpca.update_adjusted(st_b, a, k_new, x, plan=plan)
    assert float(jnp.abs(st_a.L[:int(st_a.m)]
                         - st_b.L[:int(st_b.m)]).max()) < 1e-9
    q = jnp.asarray(rng.normal(size=(5, d)))
    ya = eng.transform_state(st_a, q, n_components=4, spec=SPEC,
                             adjusted=True)
    yb = eng.transform_state(st_b, q, n_components=4, spec=SPEC,
                             adjusted=True)
    assert float(jnp.abs(ya - yb).max()) < 1e-9


def test_tenant_mesh_builders_multidevice_subprocess():
    """P_t x P_r = 2x2: the tenant-axis pair matches the local fused pair
    per tenant, tenant-sharded queries match query_batch, and the
    row-rebalanced update matches the full-mesh bucketed update on both
    sides of the crossover (sub-mesh and fallback)."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dist, engine as eng, rankone
from repro.core import kernels_fn as kf, serving
assert jax.device_count() == 4
rng = np.random.default_rng(7)
M, B, d = 32, 4, 5
plan = eng.DEFAULT_PLAN
kw = dict(iters=eng.resolve_iters(plan.iters, jnp.float64),
          method=plan.method, matmul=plan.inner_matmul,
          precise=plan.precise, merge_fallback=plan.merge_fallback)
def make_state(m):
    A = rng.normal(size=(m, m)); K = A @ A.T
    lam, vec = np.linalg.eigh(K)
    L = jnp.full((M,), 2e30).at[:m].set(jnp.asarray(lam))
    U = jnp.eye(M).at[:m, :m].set(jnp.asarray(vec))
    return L, U
mesh2 = dist.make_tenant_mesh(2, 2)
pair2d = dist.make_tenant_update_pair(mesh2, plan=plan)
Ls, Us, v1s, v2s, ms = [], [], [], [], []
for b in range(B):
    m = 10 + b
    L, U = make_state(m)
    v = jnp.asarray(rng.normal(size=(M,))).at[m:].set(0.0)
    w = jnp.asarray(rng.normal(size=(M,))).at[m:].set(0.0)
    Ls.append(L); Us.append(U); v1s.append(v); v2s.append(w); ms.append(m)
S1 = jnp.asarray(rng.uniform(1.0, 2.0, size=(B,)))
mst = jnp.asarray(ms, jnp.int32)
Lo, Uo = pair2d(jnp.stack(Ls), jnp.stack(Us), jnp.stack(v1s), S1,
                jnp.stack(v2s), -S1, mst)
err_pair = 0.0
for b in range(B):
    Lr, Ur = rankone.rank_one_update_pair(Ls[b], Us[b], v1s[b], S1[b],
                                          v2s[b], -S1[b], ms[b], **kw)
    act = jnp.where(jnp.arange(M) < ms[b], 1.0, 0.0)
    Ko = Uo[b] @ jnp.diag(act * Lo[b]) @ Uo[b].T
    Kr = Ur @ jnp.diag(act * Lr) @ Ur.T
    err_pair = max(err_pair, float(jnp.abs(Ko - Kr).max()))
spec = kf.KernelSpec(name="rbf", sigma=2.0)
sb = eng.StreamBatch(jnp.asarray(rng.normal(size=(B, 3, d))), M, spec,
                     plan=plan._replace(serve_components=4), adjusted=True,
                     dtype=jnp.float64)
for _ in range(4):
    sb.update(jnp.asarray(rng.normal(size=(B, d))))
snaps = sb.publish()
q = jnp.asarray(rng.normal(size=(B, 6, d)))
qt = dist.make_tenant_query(mesh2, spec, plan=plan)
err_q = float(jnp.abs(qt(snaps, q)
                      - serving.query_batch(snaps, q, spec=spec,
                                            plan=plan)).max())
mesh1 = jax.make_mesh((4,), ("data",))
bplan = plan._replace(dispatch="bucketed", min_bucket=8)
reb = dist.make_rebalanced_update(mesh1, plan=bplan)
full = dist.make_sharded_update(mesh1, plan=bplan)
errs_reb = []
for m in (5, 30):          # below / above the P_eff crossover
    L, U = make_state(m)
    v = jnp.asarray(rng.normal(size=(M,))).at[m:].set(0.0)
    L1, U1 = reb(L, U, v, jnp.float64(1.3), jnp.int32(m))
    L2, U2 = full(L, U, v, jnp.float64(1.3), jnp.int32(m))
    errs_reb.append(max(float(jnp.abs(L1 - L2).max()),
                        float(jnp.abs(jnp.asarray(U1)
                                      - jnp.asarray(U2)).max())))
print("RESULT:" + str({"err_pair": err_pair, "err_q": err_q,
                       "err_reb_sub": errs_reb[0],
                       "err_reb_full": errs_reb[1]}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    errs = eval(line[len("RESULT:"):])
    assert errs["err_pair"] < 1e-8, errs
    assert errs["err_q"] < 1e-12, errs
    assert errs["err_reb_sub"] < 1e-10, errs
    assert errs["err_reb_full"] < 1e-10, errs
