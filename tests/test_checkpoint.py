"""Checkpoint: atomic roundtrip, latest-step discovery, async, reshard."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)


def _tree():
    return {"params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                       "b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 7, _tree())
    assert latest_step(d) == 7
    out = load_checkpoint(d, 7, jax.eval_shape(_tree))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(_tree()["params"]["w"]))
    assert out["params"]["b"].dtype == jnp.bfloat16
    assert int(out["step"]) == 7


def test_latest_step_and_gc(tmp_path):
    d = str(tmp_path)
    assert latest_step(d) is None
    for s in (1, 5, 3):
        save_checkpoint(d, s, _tree())
    assert latest_step(d) == 5


def test_atomicity_no_partial_dirs(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _tree())
    # simulate a crashed save: stale tmp dir must be ignored and removed
    os.makedirs(os.path.join(d, "step_9.tmp-deadbeef"))
    assert latest_step(d) == 1
    save_checkpoint(d, 2, _tree())
    assert not any(".tmp-" in p for p in os.listdir(d))


def test_missing_leaf_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(KeyError):
        load_checkpoint(d, 1, jax.eval_shape(lambda: {"b": jnp.zeros(3)}))


def test_shape_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        load_checkpoint(d, 1, jax.eval_shape(lambda: {"a": jnp.zeros(4)}))


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    ck = AsyncCheckpointer(d, keep=2)
    for s in range(1, 5):
        ck.save(s, _tree())
    ck.close()
    assert latest_step(d) == 4
    steps = sorted(int(p.split("_")[1]) for p in os.listdir(d))
    assert len(steps) <= 2          # gc keeps the last 2


def test_elastic_reshard_load(tmp_path):
    """Checkpoint written under one sharding loads under another (here:
    single-device target with explicit sharding objects)."""
    d = str(tmp_path)
    mesh = jax.make_mesh((1,), ("data",))
    sharding = jax.sharding.NamedSharding(mesh,
                                          jax.sharding.PartitionSpec("data"))
    tree = {"w": jax.device_put(jnp.arange(8, dtype=jnp.float32), sharding)}
    save_checkpoint(d, 3, tree)
    target = {"w": jax.ShapeDtypeStruct((8,), jnp.float32,
                                        sharding=sharding)}
    out = load_checkpoint(d, 3, target)
    assert out["w"].sharding == sharding
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


def test_train_resume_equivalence(tmp_path):
    """Stopping and resuming from a checkpoint reproduces the un-interrupted
    run exactly (deterministic step-indexed data + saved state)."""
    from repro.launch import steps as steps_lib
    from repro.data.synthetic import TokenStream
    from repro.models.config import ArchConfig
    from repro.optim import make_optimizer
    from repro.optim.schedules import ScheduleConfig, make_schedule

    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32")
    opt = make_optimizer("adamw")
    sched = make_schedule(ScheduleConfig(kind="constant", lr=1e-3))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, sched))
    stream = TokenStream(vocab=64, seq_len=16, global_batch=2)

    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    # run 4 steps straight
    s_straight = state
    for t in range(4):
        s_straight, _ = step_fn(s_straight, stream.batch_at(jnp.int32(t)))

    # run 2 steps, checkpoint, "crash", restore, run 2 more
    s = state
    for t in range(2):
        s, _ = step_fn(s, stream.batch_at(jnp.int32(t)))
    save_checkpoint(str(tmp_path), 2, s)
    restored = load_checkpoint(str(tmp_path), 2, jax.eval_shape(lambda: s))
    for t in range(2, 4):
        restored, _ = step_fn(restored, stream.batch_at(jnp.int32(t)))

    for a, b in zip(jax.tree.leaves(s_straight.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


# --------------------------------------------- KPCA / Nyström state trees ---
def test_nystrom_state_roundtrip(tmp_path):
    """NystromState (nested KPCAState + Knm + grow-rows Xrows) survives the
    npz store bit-exactly, both row regimes."""
    from repro.core import kernels_fn as kf, nystrom

    rng = np.random.default_rng(4)
    X = rng.normal(size=(20, 3))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    for grow in (False, True):
        if grow:
            state = nystrom.init_nystrom(None, jnp.asarray(X[:4]),
                                         capacity=8, spec=spec,
                                         dtype=jnp.float64, grow_rows=True)
            state = nystrom.observe_rows(state, jnp.asarray(X[4:]), spec)
            state = nystrom.add_landmark(state, None, jnp.asarray(X[5]),
                                         spec)
        else:
            state = nystrom.init_nystrom(jnp.asarray(X), jnp.asarray(X[:4]),
                                         capacity=8, spec=spec,
                                         dtype=jnp.float64)
            state = nystrom.add_landmark(state, jnp.asarray(X),
                                         jnp.asarray(X[5]), spec)
        d = str(tmp_path / f"grow_{grow}")
        save_checkpoint(d, 1, state)
        out = load_checkpoint(d, 1, jax.eval_shape(lambda: state))
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(
            np.asarray(nystrom.reconstruct_tilde(out)),
            np.asarray(nystrom.reconstruct_tilde(state)), atol=0)


def test_windowed_kpca_midwindow_resume_equivalence(tmp_path):
    """Save a SLIDING-WINDOW stream mid-window (evictions already past),
    restore into a fresh process-alike stream, continue: the result must
    equal the uninterrupted windowed run exactly.  This is what the FIFO
    ring being IN the state (window.WindowState.ages/clock) buys — the
    eviction order is checkpoint state, not host bookkeeping."""
    from repro.core import inkpca, kernels_fn as kf

    rng = np.random.default_rng(21)
    X = rng.normal(size=(30, 4))
    spec = kf.KernelSpec(name="rbf", sigma=5.0)

    def make_stream():
        return inkpca.KPCAStream(jnp.asarray(X[:4]), 16, spec,
                                 adjusted=True, dtype=jnp.float64,
                                 dispatch="bucketed", min_bucket=8,
                                 window=8)

    straight = make_stream()
    for i in range(4, 30):
        straight.update(jnp.asarray(X[i]))

    part = make_stream()
    for i in range(4, 18):                      # window full, 6 evictions
        part.update(jnp.asarray(X[i]))
    save_checkpoint(str(tmp_path), 18, part.state)

    resumed = make_stream()                     # "crash": fresh stream
    resumed.state = load_checkpoint(str(tmp_path), 18,
                                    jax.eval_shape(lambda: part.state))
    assert int(resumed.state.clock) == 18
    for i in range(18, 30):
        resumed.update(jnp.asarray(X[i]))

    for a, b in zip(jax.tree.leaves(straight.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-12)


def test_replaced_landmark_nystrom_resume_equivalence(tmp_path):
    """Save a NystromState right after a replace_landmark, restore,
    continue the lifecycle (observe + add + replace): equals the
    uninterrupted run bit-for-bit at save and to rounding afterwards."""
    from repro.core import engine as eng, kernels_fn as kf, nystrom

    rng = np.random.default_rng(33)
    X = rng.normal(size=(26, 3))
    spec = kf.KernelSpec(name="rbf", sigma=4.0)
    engine = eng.Engine(spec, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8), adjusted=False)

    def grow():
        st = nystrom.init_nystrom(None, jnp.asarray(X[:4]), capacity=16,
                                  spec=spec, dtype=jnp.float64,
                                  grow_rows=True)
        st = nystrom.observe_rows(st, jnp.asarray(X[4:20]), spec)
        for i in range(4, 10):
            st = engine.add_landmark(st, None, jnp.asarray(X[i]))
        return engine.replace_landmark(st, None, 2, jnp.asarray(X[15]))

    def continue_lifecycle(st):
        st = nystrom.observe_rows(st, jnp.asarray(X[20:]), spec)
        st = engine.add_landmark(st, None, jnp.asarray(X[21]))
        return engine.replace_landmark(st, None, 0, jnp.asarray(X[22]))

    state = grow()
    save_checkpoint(str(tmp_path), 1, state)
    restored = load_checkpoint(str(tmp_path), 1,
                               jax.eval_shape(lambda: state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    straight = continue_lifecycle(state)
    resumed = continue_lifecycle(restored)
    np.testing.assert_allclose(
        np.asarray(nystrom.reconstruct_tilde(resumed)),
        np.asarray(nystrom.reconstruct_tilde(straight)), atol=0)


def test_bucketed_kpca_midstream_resume_equivalence(tmp_path):
    """Save a bucketed stream mid-bucket (m inside M_b), restore, continue:
    the result must match the uninterrupted bucketed run exactly, bucket
    crossings included."""
    from repro.core import inkpca, kernels_fn as kf

    rng = np.random.default_rng(9)
    X = rng.normal(size=(26, 4))
    spec = kf.KernelSpec(name="rbf", sigma=5.0)

    def make_stream():
        return inkpca.KPCAStream(jnp.asarray(X[:4]), 32, spec,
                                 adjusted=True, dtype=jnp.float64,
                                 dispatch="bucketed", min_bucket=8)

    straight = make_stream()
    straight.update_block(jnp.asarray(X[4:]))

    part = make_stream()
    part.update_block(jnp.asarray(X[4:14]))     # m=14, inside bucket 16
    save_checkpoint(str(tmp_path), 14, part.state)

    resumed = make_stream()                     # "crash": fresh process
    resumed.state = load_checkpoint(str(tmp_path), 14,
                                    jax.eval_shape(lambda: part.state))
    assert int(resumed.state.m) == 14
    resumed.update_block(jnp.asarray(X[14:]))   # crosses bucket 16 -> 32

    assert int(resumed.state.m) == int(straight.state.m) == 26
    for a, b in zip(jax.tree.leaves(straight.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-12)


def test_windowed_kpca_midblock_resume_equivalence(tmp_path):
    """Save a windowed stream BETWEEN window_block calls (steady state,
    scanned evict+ingest already past), restore, continue with more
    blocks: equals the uninterrupted blocked run — the scanned path
    keeps the arrival ring checkpoint-portable exactly like the
    per-point path (ISSUE satellite)."""
    from repro.core import inkpca, kernels_fn as kf

    rng = np.random.default_rng(27)
    X = rng.normal(size=(36, 4))
    spec = kf.KernelSpec(name="rbf", sigma=5.0)

    def make_stream():
        return inkpca.KPCAStream(jnp.asarray(X[:4]), 16, spec,
                                 adjusted=True, dtype=jnp.float64,
                                 dispatch="bucketed", min_bucket=8,
                                 window=8)

    straight = make_stream()
    straight.update_block(jnp.asarray(X[4:20]))     # growth + steady scan
    straight.update_block(jnp.asarray(X[20:36]))

    part = make_stream()
    part.update_block(jnp.asarray(X[4:20]))
    save_checkpoint(str(tmp_path), 20, part.state)

    resumed = make_stream()                          # "crash": fresh stream
    resumed.state = load_checkpoint(str(tmp_path), 20,
                                    jax.eval_shape(lambda: part.state))
    assert int(resumed.state.clock) == 20
    resumed.update_block(jnp.asarray(X[20:36]))

    for a, b in zip(jax.tree.leaves(straight.state),
                    jax.tree.leaves(resumed.state)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-12)
