"""Sharding rules, HLO parser, straggler monitor, distributed KPCA."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.straggler import HeartbeatMonitor, StepTimer
from repro.launch import hlo_parse


# ------------------------------------------------------------- sharding ----
def test_logical_to_spec_divisibility_drop():
    mesh = jax.make_mesh((1,), ("model",))
    with shd.use_mesh(mesh, rules={"heads": "model", "batch": "data"}):
        # 'data' axis absent from mesh -> dropped by use_mesh filtering
        spec = shd.logical_to_spec(("batch", "heads"), (4, 8))
        assert spec == P(None, "model")


def test_logical_to_spec_dedup_axes():
    mesh = jax.make_mesh((1,), ("model",))
    with shd.use_mesh(mesh, rules={"a": "model", "b": "model"}):
        spec = shd.logical_to_spec(("a", "b"), (4, 4))
        assert spec == P("model", None)   # first dim wins


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, ("batch", None))
    assert y is x


def test_use_mesh_restores_state():
    mesh = jax.make_mesh((1,), ("data",))
    assert shd.get_mesh() is None
    with shd.use_mesh(mesh):
        assert shd.get_mesh() is mesh
    assert shd.get_mesh() is None


# ------------------------------------------------------------ hlo parser ---
def test_hlo_parse_counts_real_matmul_flops():
    m, k, n = 64, 32, 48

    def f(a, b):
        return a @ b

    hlo = (jax.jit(f)
           .lower(jnp.zeros((m, k)), jnp.zeros((k, n))).compile().as_text())
    stats = hlo_parse.analyze(hlo)
    expect = 2.0 * m * k * n
    assert stats.flops == expect, (stats.flops, expect)


def test_hlo_parse_scan_trip_multiplication():
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    hlo = jax.jit(f).lower(jnp.zeros((16, 16))).compile().as_text()
    stats = hlo_parse.analyze(hlo)
    assert stats.flops == 7 * 2.0 * 16 ** 3, stats.flops


def test_hlo_parse_bytes_reasonable():
    n = 256

    def f(a, b):
        return a @ b

    hlo = (jax.jit(f)
           .lower(jnp.zeros((n, n), jnp.float32),
                  jnp.zeros((n, n), jnp.float32)).compile().as_text())
    stats = hlo_parse.analyze(hlo)
    raw = 3 * n * n * 4
    assert raw <= stats.bytes <= 5 * raw


def test_collective_wire_model():
    line = ("  %all-reduce.1 = f32[1024]{0} all-reduce(%x), "
            "replica_groups={{0,1,2,3}}, to_apply=%add")
    comps = {"c": hlo_parse.Computation(name="c")}
    op = hlo_parse.Op(name="all-reduce.1", opcode="all-reduce",
                      result_bytes=4096.0, line=line,
                      result_seg="f32[1024]{0}")
    kind, wire, payload = hlo_parse._collective_wire(op)
    assert kind == "all-reduce"
    assert wire == 2.0 * 3 / 4 * 4096
    assert payload == 4096


# ------------------------------------------------------------- straggler ---
def test_heartbeat_flags_timeout():
    hb = HeartbeatMonitor(n_workers=2, timeout_s=10.0)
    hb.beat(0, step=5, t=100.0)
    hb.beat(1, step=5, t=100.0)
    assert hb.healthy(now=105.0)
    flagged = hb.flagged(now=150.0)
    assert len(flagged) == 2 and flagged[0]["reason"] == "timeout"


def test_heartbeat_flags_lag():
    hb = HeartbeatMonitor(n_workers=3, timeout_s=1e9, max_step_lag=5)
    hb.beat(0, step=100, t=0.0)
    hb.beat(1, step=100, t=0.0)
    hb.beat(2, step=50, t=0.0)
    flagged = hb.flagged(now=1.0)
    assert [f["worker"] for f in flagged] == [2]
    assert flagged[0]["reason"] == "lagging"


def test_heartbeat_never_beat():
    hb = HeartbeatMonitor(n_workers=2)
    hb.beat(0, step=1)
    assert any(f["reason"] == "never-beat" for f in hb.flagged())


def test_step_timer_spike_detection():
    st = StepTimer(alpha=0.5, spike_factor=2.0)
    st.ewma = 1.0
    st._t0 = 0.0
    import time as _t
    real = _t.time
    try:
        _t.time = lambda: 10.0   # 10s step vs 1s ewma -> spike
        st.stop()
    finally:
        _t.time = real
    assert st.spikes == 1


# ---------------------------------------------------- distributed KPCA -----
def test_sharded_rank_one_update_matches_local():
    from repro.core import distributed as dkpca, rankone

    rng = np.random.default_rng(7)
    m, M = 10, 16
    A = rng.normal(size=(m, m)); A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M); U = np.eye(M)
    L[:m] = lam; U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    v = np.zeros(M); v[:m] = rng.normal(size=m)

    mesh = jax.make_mesh((1,), ("data",))
    upd = dkpca.make_sharded_update(mesh)
    Ls, Us = upd(jnp.asarray(L), jnp.asarray(U), jnp.asarray(v),
                 jnp.float64(1.7), jnp.int32(m))
    Ll, Ul = rankone.rank_one_update(jnp.asarray(L), jnp.asarray(U),
                                     jnp.asarray(v), jnp.float64(1.7),
                                     jnp.int32(m))
    np.testing.assert_allclose(np.asarray(Ls), np.asarray(Ll), atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(Us)),
                               np.abs(np.asarray(Ul)), atol=1e-8)


def test_sharded_pair_update_matches_local_pair():
    """Fused ±sigma pair under shard_map (one psum for both z vectors) must
    match the local fused pair; plans come from the engine layer."""
    from repro.core import distributed as dkpca, engine as eng, rankone

    rng = np.random.default_rng(8)
    m, M = 10, 16
    A = rng.normal(size=(m, m)); A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M); U = np.eye(M)
    L[:m] = lam; U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    v1 = np.zeros(M); v1[:m] = rng.normal(size=m)
    v2 = np.zeros(M); v2[:m] = rng.normal(size=m)

    mesh = jax.make_mesh((1,), ("data",))
    pair = dkpca.make_sharded_update_pair(mesh, plan=eng.UpdatePlan())
    Ls, Us = pair(jnp.asarray(L), jnp.asarray(U), jnp.asarray(v1),
                  jnp.float64(1.7), jnp.asarray(v2), jnp.float64(-1.7),
                  jnp.int32(m))
    Ll, Ul = rankone.rank_one_update_pair(
        jnp.asarray(L), jnp.asarray(U), jnp.asarray(v1), jnp.float64(1.7),
        jnp.asarray(v2), jnp.float64(-1.7), jnp.int32(m), precise=False,
        merge_fallback=False)
    np.testing.assert_allclose(np.asarray(Ls), np.asarray(Ll), atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(Us)),
                               np.abs(np.asarray(Ul)), atol=1e-8)
    # and against two sequential local updates (end-to-end semantics)
    L2, U2 = rankone.rank_one_update(jnp.asarray(L), jnp.asarray(U),
                                     jnp.asarray(v1), jnp.float64(1.7),
                                     jnp.int32(m))
    L2, U2 = rankone.rank_one_update(L2, U2, jnp.asarray(v2),
                                     jnp.float64(-1.7), jnp.int32(m))
    np.testing.assert_allclose(np.asarray(Ls[:m]), np.asarray(L2[:m]),
                               atol=1e-8)


def _clustered_state(rng, m, M):
    """Spectrum with a tight eigenvalue cluster so the dlaed2 merge fires."""
    from repro.core import rankone
    lam = np.sort(rng.uniform(1.0, 5.0, size=m))
    lam[3:7] = lam[3]            # exactly-degenerate run
    q, _ = np.linalg.qr(rng.normal(size=(m, m)))
    L = np.zeros(M); U = np.eye(M)
    L[:m] = lam; U[:m, :m] = q
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    return L, jnp.asarray(U)


def test_sharded_pair_fallback_matches_two_single_updates_clustered():
    """On a clustered spectrum the collective-balanced merge fallback must
    route the sharded fused pair through the sequential pipeline — landing
    exactly on two single sharded updates."""
    from repro.core import distributed as dkpca, engine as eng, rankone

    rng = np.random.default_rng(9)
    m, M = 12, 16
    L, U = _clustered_state(rng, m, M)
    v1 = np.zeros(M); v1[:m] = rng.normal(size=m)
    v2 = np.zeros(M); v2[:m] = rng.normal(size=m)
    # the scenario actually exercises the fallback branch
    assert bool(rankone._merge_fires(L, U.T @ jnp.asarray(v1),
                                     jnp.float64(1.7), jnp.int32(m)))

    mesh = jax.make_mesh((1,), ("data",))
    pair = dkpca.make_sharded_update_pair(
        mesh, plan=eng.UpdatePlan(merge_fallback=True))
    Lp, Up = pair(L, U, jnp.asarray(v1), jnp.float64(1.7), jnp.asarray(v2),
                  jnp.float64(-1.7), jnp.int32(m))
    upd = dkpca.make_sharded_update(mesh)
    Ls, Us = upd(L, U, jnp.asarray(v1), jnp.float64(1.7), jnp.int32(m))
    Ls, Us = upd(Ls, Us, jnp.asarray(v2), jnp.float64(-1.7), jnp.int32(m))
    np.testing.assert_allclose(np.asarray(Lp), np.asarray(Ls), atol=1e-10)
    np.testing.assert_allclose(np.abs(np.asarray(Up)),
                               np.abs(np.asarray(Us)), atol=1e-8)
    # orthogonality is what the fallback buys on clustered spectra
    orth = np.abs(np.asarray(Up[:m, :m]) @ np.asarray(Up[:m, :m]).T
                  - np.eye(m)).max()
    assert orth < 1e-10, orth


def test_sharded_bucketed_update_matches_local():
    """Bucketed sharded dispatch (rectangular local slices) must equal the
    full-capacity local update while m < M_b."""
    from repro.core import distributed as dkpca, engine as eng, rankone

    rng = np.random.default_rng(10)
    m, M = 10, 64
    A = rng.normal(size=(m, m)); A = A @ A.T
    lam, vec = np.linalg.eigh(A)
    L = np.zeros(M); U = np.eye(M)
    L[:m] = lam; U[:m, :m] = vec
    L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
    U = jnp.asarray(U)
    v = np.zeros(M); v[:m] = rng.normal(size=m)
    v = jnp.asarray(v)

    mesh = jax.make_mesh((1,), ("data",))
    upd = dkpca.make_sharded_update(
        mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=16))
    Ls, Us = upd(L, U, v, jnp.float64(1.7), jnp.int32(m))
    Ll, Ul = rankone.rank_one_update(L, U, v, jnp.float64(1.7),
                                     jnp.int32(m))
    # active spectrum + reconstruction (sentinel tails are bookkeeping and
    # legitimately differ between the bucketed and fixed paths)
    np.testing.assert_allclose(np.asarray(Ls[:m]), np.asarray(Ll[:m]),
                               atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(Ls, Us, jnp.int32(m))),
        np.asarray(rankone.reconstruct(Ll, Ul, jnp.int32(m))), atol=1e-8)

    pairb = dkpca.make_sharded_update_pair(
        mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=16,
                                  merge_fallback=False))
    v2 = np.zeros(M); v2[:m] = rng.normal(size=m)
    Lp, Up = pairb(L, U, v, jnp.float64(1.7), jnp.asarray(v2),
                   jnp.float64(-1.7), jnp.int32(m))
    Lr, Ur = rankone.rank_one_update_pair(
        L, U, v, jnp.float64(1.7), jnp.asarray(v2), jnp.float64(-1.7),
        jnp.int32(m), merge_fallback=False)
    np.testing.assert_allclose(np.asarray(Lp[:m]), np.asarray(Lr[:m]),
                               atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(Lp, Up, jnp.int32(m))),
        np.asarray(rankone.reconstruct(Lr, Ur, jnp.int32(m))), atol=1e-8)


def test_sharded_rect_pruning_multidevice_subprocess():
    """P=2 end-to-end: the bucketed rectangular path on a REAL two-device
    mesh (host-device override needs a fresh process) must match the local
    update, fused pair fallback included."""
    import json
    import os
    import subprocess
    import sys
    from pathlib import Path

    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, rankone
assert jax.device_count() == 2
rng = np.random.default_rng(12)
m, M = 10, 32
A = rng.normal(size=(m, m)); A = A @ A.T
lam, vec = np.linalg.eigh(A)
L = np.zeros(M); U = np.eye(M)
L[:m] = lam; U[:m, :m] = vec
L = rankone.sentinelize(jnp.asarray(L), jnp.int32(m), jnp.float64(0.0))
U = jnp.asarray(U)
v1 = np.zeros(M); v1[:m] = rng.normal(size=m)
v2 = np.zeros(M); v2[:m] = rng.normal(size=m)
v1, v2 = jnp.asarray(v1), jnp.asarray(v2)
mesh = jax.make_mesh((2,), ("data",))
upd = dkpca.make_sharded_update(
    mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=16))
Ls, Us = upd(L, U, v1, jnp.float64(1.7), jnp.int32(m))
Ll, Ul = rankone.rank_one_update(L, U, v1, jnp.float64(1.7), jnp.int32(m))
pair = dkpca.make_sharded_update_pair(
    mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=16,
                              merge_fallback=True))
Lp, Up = pair(L, U, v1, jnp.float64(1.7), v2, jnp.float64(-1.7),
              jnp.int32(m))
L2, U2 = rankone.rank_one_update(L, U, v1, jnp.float64(1.7), jnp.int32(m))
L2, U2 = rankone.rank_one_update(L2, U2, v2, jnp.float64(-1.7),
                                 jnp.int32(m))
K_s = rankone.reconstruct(Ls, Us, jnp.int32(m))
K_l = rankone.reconstruct(Ll, Ul, jnp.int32(m))
print("RESULT:" + str({
    "err_L": float(jnp.abs(Ls[:m] - Ll[:m]).max()),
    "err_U": float(jnp.abs(K_s - K_l).max()),
    "err_pair_L": float(jnp.abs(Lp[:m] - L2[:m]).max()),
}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    errs = eval(line[len("RESULT:"):])
    assert errs["err_L"] < 1e-10, errs
    assert errs["err_U"] < 1e-8, errs
    assert errs["err_pair_L"] < 1e-8, errs


def test_sharded_evict_and_window_multidevice_subprocess():
    """P=2 end-to-end: arbitrary-row sharded eviction (in-graph boundary
    permutation) and the scanned sharded window block on a REAL
    two-device mesh must match the local decremental path (ISSUE
    acceptance: sharded arbitrary-row eviction == local downdate)."""
    import os
    import subprocess
    import sys
    from pathlib import Path

    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, inkpca, \
    kernels_fn as kf, rankone
assert jax.device_count() == 2
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)
rng = np.random.default_rng(37)
X = rng.normal(size=(12, 4))
engine = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=False)
st = inkpca.init_state(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                       dtype=jnp.float64)
for i in range(4, 11):
    st = engine.update(st, jnp.asarray(X[i]))
mesh = jax.make_mesh((2,), ("data",))
errs = {}
ev = dkpca.make_sharded_evict(
    mesh, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=8))
victim = 3                                         # interior row
a = kf.kernel_row(st.X[victim], st.X, spec=SPEC)
a = jnp.where(rankone.active_mask(16, st.m), a, 0.0)
Ls, Us, ms = ev(st.L, st.U, a, a[victim], jnp.int32(victim), st.m)
ref = engine.downdate(st, victim)
errs["evict_L"] = float(jnp.abs(Ls[:int(ms)] - ref.L[:int(ms)]).max())
errs["evict_K"] = float(jnp.abs(
    rankone.reconstruct(Ls, Us, ms)
    - rankone.reconstruct(ref.L, ref.U, ref.m)).max())
W = 8
stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64, window=W)
for i in range(4, 12):
    stream.update(jnp.asarray(X[i]))
ws = stream.state
xs = jnp.asarray(rng.normal(size=(5, 4)))
wb = dkpca.make_sharded_window_block(
    mesh, SPEC, plan=eng.UpdatePlan(dispatch="bucketed", min_bucket=8))
L2, U2, X2, ages2, clock2 = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages,
                               ws.clock, xs, ws.kpca.m)
for t in range(5):
    stream.update(xs[t])
r = stream.state
errs["win_L"] = float(jnp.abs(L2[:W] - r.kpca.L[:W]).max())
errs["win_K"] = float(jnp.abs(
    rankone.reconstruct(L2, U2, jnp.int32(W))
    - rankone.reconstruct(r.kpca.L, r.kpca.U, r.kpca.m)).max())
errs["win_ages"] = int(jnp.abs(ages2 - r.ages).max())
print("RESULT:" + str(errs))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    errs = eval(line[len("RESULT:"):])
    assert errs["evict_L"] < 1e-10, errs
    assert errs["evict_K"] < 1e-10, errs
    assert errs["win_L"] < 1e-10, errs
    assert errs["win_K"] < 1e-10, errs
    assert errs["win_ages"] == 0, errs
