"""Optimizers, schedules, clipping, int8 error-feedback compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, adafactor, sgdm, compress_int8,
                         decompress_int8)
from repro.optim.compression import CompressionState, init_state
from repro.optim.optimizers import clip_by_global_norm, global_norm
from repro.optim.schedules import ScheduleConfig, make_schedule


@pytest.mark.parametrize("opt_fn", [adamw, adafactor, sgdm])
def test_optimizer_decreases_quadratic(opt_fn):
    opt = opt_fn()
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(8, 6)),
                               jnp.float32)}
    target = jnp.ones((8, 6), jnp.float32)
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(0.05, jnp.float32))
    assert float(loss(params)) < 0.2 * l0
    assert int(state.step) == 60


def test_adafactor_state_is_factored():
    opt = adafactor()
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,)),
              "e": jnp.zeros((4, 16, 8))}
    st = opt.init(params)
    assert st.inner["w"]["vr"].shape == (16,)
    assert st.inner["w"]["vc"].shape == (8,)
    assert st.inner["e"]["vr"].shape == (4, 16)
    assert st.inner["e"]["vc"].shape == (4, 8)
    assert st.inner["b"]["v"].shape == (8,)


def test_adafactor_nd_param_update_shapes():
    opt = adafactor()
    params = {"e": jnp.ones((3, 5, 4), jnp.float32)}
    st = opt.init(params)
    g = {"e": jnp.full((3, 5, 4), 0.1, jnp.float32)}
    p2, st2 = opt.update(g, st, params, jnp.asarray(0.01))
    assert p2["e"].shape == (3, 5, 4)
    assert jnp.isfinite(p2["e"]).all()


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 3.0, jnp.float32)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(90.0), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # small grads untouched
    grads = {"a": jnp.full((4,), 0.01, jnp.float32)}
    clipped, _ = clip_by_global_norm(grads, 1.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]), 0.01, rtol=1e-6)


def test_wsd_schedule_shape():
    sched = make_schedule(ScheduleConfig(kind="wsd", lr=1.0, warmup=10,
                                         total=100, decay_frac=0.2))
    lr = [float(sched(jnp.asarray(s))) for s in range(100)]
    assert lr[0] < 0.2                      # warmup starts low
    assert lr[10] == pytest.approx(1.0)     # warmed up
    assert lr[50] == pytest.approx(1.0)     # stable plateau
    assert lr[79] == pytest.approx(1.0)     # still stable
    assert lr[99] < 0.1                     # decayed fast at the end


def test_cosine_schedule_monotone_decay():
    sched = make_schedule(ScheduleConfig(kind="cosine", lr=1.0, warmup=5,
                                         total=50, floor=0.1))
    lr = [float(sched(jnp.asarray(s))) for s in range(50)]
    assert lr[4] <= 1.0 and lr[5] == pytest.approx(1.0, abs=1e-6)
    assert all(a >= b - 1e-9 for a, b in zip(lr[5:], lr[6:]))
    assert lr[-1] >= 0.09


def test_int8_roundtrip_accuracy():
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.normal(size=(1000,)) * 0.01, jnp.float32)
    q, s = compress_int8(g)
    assert q.dtype == jnp.int8
    deq = decompress_int8(q, s, g.shape, g.size)
    rel = float(jnp.max(jnp.abs(deq - g)) / jnp.max(jnp.abs(g)))
    assert rel < 0.01   # blockwise int8: <1% of block max


def test_error_feedback_compensates_bias():
    """EF property: accumulated quantization error stays bounded, and the
    running sum of dequantized values tracks the true running sum."""
    rng = np.random.default_rng(6)
    state = init_state({"g": jnp.zeros((256,), jnp.float32)})
    true_sum = np.zeros(256)
    deq_sum = np.zeros(256)
    for t in range(50):
        g = rng.normal(size=256).astype(np.float32) * 0.1
        true_sum += g
        target = jnp.asarray(g) + state.error["g"]
        q, s = compress_int8(target)
        deq = decompress_int8(q, s, (256,), 256)
        state = CompressionState(error={"g": target - deq})
        deq_sum += np.asarray(deq)
    # without EF the bias would accumulate ~ t * quantization_error
    drift = np.abs(deq_sum - true_sum).max()
    assert drift < 0.02, drift
    assert float(jnp.abs(state.error["g"]).max()) < 0.01
