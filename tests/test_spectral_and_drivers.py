"""Spectral monitor behaviour + end-to-end driver smoke tests."""
import numpy as np
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # model-zoo / driver integration tier

from repro.spectral import SpectralMonitor


def test_monitor_tracks_rank():
    rng = np.random.default_rng(0)
    mon = SpectralMonitor(capacity=48)
    # low-rank features: effective rank should come out low
    basis = rng.normal(size=(3, 16))
    feats = rng.normal(size=(32, 3)) @ basis + 0.01 * rng.normal(size=(32, 16))
    stats = mon.observe(feats)
    assert 1.0 <= stats["effective_rank"] <= 10.0
    assert stats["m"] > 4
    assert stats["explained_90"] <= 8


def test_monitor_full_rank_higher():
    rng = np.random.default_rng(1)
    lo = SpectralMonitor(capacity=48)
    hi = SpectralMonitor(capacity=48)
    basis = rng.normal(size=(2, 16))
    s_lo = lo.observe(rng.normal(size=(32, 2)) @ basis
                      + 1e-3 * rng.normal(size=(32, 16)))
    s_hi = hi.observe(rng.normal(size=(32, 16)))
    assert s_hi["effective_rank"] > s_lo["effective_rank"]


def test_monitor_incremental_updates():
    rng = np.random.default_rng(2)
    mon = SpectralMonitor(capacity=40)
    mon.observe(rng.normal(size=(16, 8)))
    m1 = mon.stats()["m"]
    mon.observe(rng.normal(size=(16, 8)))
    assert mon.stats()["m"] > m1
    assert len(mon.history) == 2
    ev = mon.eigenvalues()
    assert (np.diff(ev) <= 1e-9).all()      # descending


def test_train_driver_smoke(tmp_path):
    from repro.launch.train import main as train_main
    res = train_main(["--arch", "minicpm_2b", "--smoke", "--steps", "6",
                      "--batch", "2", "--seq", "32", "--log-every", "2",
                      "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert np.isfinite(res["last_loss"])
    assert res["stragglers"]["flagged"] == []
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path)) == 6


def test_serve_driver_smoke():
    from repro.launch.serve import main as serve_main
    res = serve_main(["--arch", "qwen3_32b", "--smoke", "--batch", "2",
                      "--prompt-len", "4", "--gen", "4"])
    assert res["finite"]
    assert res["generated_shape"] == (2, 4)


def test_serve_kpca_window_smoke():
    """--window W: the stream slides instead of saturating — m stays at
    W while points keep flowing past capacity."""
    from repro.launch.serve import main as serve_main
    res = serve_main(["--mode", "kpca", "--capacity", "32", "--points",
                      "40", "--window", "16", "--dispatch", "bucketed",
                      "--dim", "4"])
    assert res["finite"]
    assert res["m_final"] == 16
    assert res["points"] == 40


def test_serve_multitenant_window_smoke():
    from repro.launch.serve import main as serve_main
    res = serve_main(["--mode", "kpca", "--capacity", "32", "--points",
                      "24", "--tenants", "2", "--window", "12",
                      "--dispatch", "bucketed", "--cohorts",
                      "bucket-padded", "--dim", "4"])
    assert res["finite"]
    assert res["m_final"] == [12, 12]


def test_serve_nystrom_lifecycle_smoke():
    from repro.launch.serve import main as serve_main
    res = serve_main(["--mode", "nystrom", "--capacity", "16", "--points",
                      "40", "--landmark-policy", "leverage", "--dim", "4",
                      "--landmark-budget", "8"])
    assert res["finite"]
    assert res["m_final"] <= 8
    assert res["admitted"] + res["rejected"] + res["replaced"] == 40
