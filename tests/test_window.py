"""Sliding-window streams (core/window.py): the trailing-window state must
equal batch KPCA on the trailing window, across single streams, tenant
batches and the spectral monitor."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, inkpca, kernels_fn as kf, rankone
from repro.core import window as wnd

SPEC = kf.KernelSpec(name="rbf", sigma=5.0)


def _batch_eff(X, adjusted):
    K = kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=SPEC)
    return np.asarray(kf.center_gram(K)) if adjusted else np.asarray(K)


@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("dispatch", ["fixed", "bucketed"])
def test_windowed_stream_matches_trailing_batch(adjusted, dispatch):
    """After every ingest past the window, the maintained eigensystem is
    exactly batch KPCA of the trailing W points (ISSUE acceptance)."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(26, 4))
    W = 8
    stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC,
                               adjusted=adjusted, dtype=jnp.float64,
                               dispatch=dispatch, min_bucket=8, window=W)
    for i in range(4, 26):
        stream.update(jnp.asarray(X[i]))
        st = stream.kpca_state
        m = int(st.m)
        lo = max(0, i + 1 - W)
        Keff = _batch_eff(X[lo:i + 1], adjusted)
        rec = np.asarray(rankone.reconstruct(st.L, st.U, st.m))[:m, :m]
        np.testing.assert_allclose(rec, Keff, atol=1e-9)
    # eigenpairs match a batch eigh of the trailing window
    lam_ref = np.sort(np.linalg.eigvalsh(Keff))[::-1]
    lam = np.sort(np.asarray(st.L[:m]))[::-1]
    np.testing.assert_allclose(lam, lam_ref, atol=1e-9)
    # the stored rows ARE the trailing window, in arrival order
    np.testing.assert_allclose(np.asarray(st.X[:m]), X[26 - W:], atol=0)
    # the FIFO ring survives in-state: ages are consecutive arrival stamps
    ages = np.asarray(stream.state.ages[:m])
    np.testing.assert_array_equal(ages, np.arange(26 - W, 26))


def test_windowed_stream_bounded_forever():
    """An endless stream stays at m == W with finite state — the
    bounded-memory serving scenario (append-only streams exhaust here)."""
    rng = np.random.default_rng(5)
    stream = inkpca.KPCAStream(jnp.asarray(rng.normal(size=(4, 3))), 8,
                               SPEC, adjusted=True, dtype=jnp.float64,
                               window=8)
    for i in range(30):           # 30 > capacity: append-only would raise
        stream.update(jnp.asarray(rng.normal(size=3)))
    st = stream.kpca_state
    assert int(st.m) == 8
    assert bool(jnp.isfinite(st.L).all())
    assert int(stream.state.clock) == 34


def test_window_validation():
    x0 = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)))
    with pytest.raises(ValueError):
        inkpca.KPCAStream(x0, 16, SPEC, window=1)
    with pytest.raises(ValueError):
        inkpca.KPCAStream(x0, 16, SPEC, window=32)
    with pytest.raises(ValueError):
        inkpca.KPCAStream(x0, 16, SPEC, window=3)     # seed > window
    stream = inkpca.KPCAStream(x0, 16, SPEC, window=8)
    with pytest.raises(ValueError):
        stream.truncate(4)


def test_plan_window_field_drives_stream():
    """UpdatePlan.window is the policy spelling of the same mode, and
    kernel_plan() normalizes it away from jit cache keys."""
    x0 = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)))
    plan = eng.UpdatePlan(window=8)
    stream = inkpca.KPCAStream(x0, 16, SPEC, plan=plan)
    assert stream.window == 8
    assert isinstance(stream.state, wnd.WindowState)
    assert plan.kernel_plan() == eng.UpdatePlan().kernel_plan()


@pytest.mark.parametrize("cohorts", ["max", "bucket", "bucket-padded"])
def test_streambatch_window_matches_per_tenant_loop(cohorts):
    """Windowed StreamBatch (masked batched downdates) == B independent
    windowed single streams, under every cohort geometry."""
    rng = np.random.default_rng(13)
    B, d, W = 3, 4, 8
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    batch = eng.StreamBatch(x0, 16, SPEC, plan=plan, adjusted=True,
                            dtype=jnp.float64, window=W, cohorts=cohorts)
    streams = [inkpca.KPCAStream(x0[i], 16, SPEC, adjusted=True,
                                 dtype=jnp.float64, plan=plan, window=W)
               for i in range(B)]
    for t in range(14):
        xs = jnp.asarray(rng.normal(size=(B, d)))
        act = np.array([(t % (i + 1)) == 0 for i in range(B)])
        batch.update(xs, active=jnp.asarray(act))
        for i, s in enumerate(streams):
            if act[i]:
                s.update(xs[i])
    sts = batch.states
    for i, s in enumerate(streams):
        ref = s.kpca_state
        m = int(ref.m)
        assert int(sts.m[i]) == m
        np.testing.assert_allclose(np.asarray(sts.L[i][:m]),
                                   np.asarray(ref.L[:m]), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(sts.L[i], sts.U[i], sts.m[i])),
            np.asarray(rankone.reconstruct(ref.L, ref.U, ref.m)),
            atol=1e-10)


def test_streambatch_window_update_block():
    """update_block on a windowed batch slides every tenant to the
    trailing window (point-by-point semantics)."""
    rng = np.random.default_rng(17)
    B, d, W = 2, 3, 6
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    batch = eng.StreamBatch(x0, 8, SPEC, adjusted=False, dtype=jnp.float64,
                            window=W)
    xs = jnp.asarray(rng.normal(size=(10, B, d)))
    batch.update_block(xs)
    sts = batch.states
    for i in range(B):
        assert int(sts.m[i]) == W
        allpts = np.concatenate([np.asarray(x0[i]), np.asarray(xs[:, i])])
        Keff = _batch_eff(allpts[-W:], False)
        rec = np.asarray(rankone.reconstruct(sts.L[i], sts.U[i],
                                             sts.m[i]))[:W, :W]
        np.testing.assert_allclose(rec, Keff, atol=1e-9)


def test_streambatch_window_at_capacity_never_exhausts():
    """window == capacity: the idle-tenant ceiling must not trip the
    exhaustion raise; active tenants evict and keep going forever."""
    rng = np.random.default_rng(19)
    B, d = 2, 3
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    batch = eng.StreamBatch(x0, 8, SPEC, adjusted=True, dtype=jnp.float64,
                            window=8)
    for t in range(10):
        batch.update(jnp.asarray(rng.normal(size=(B, d))))
    # park tenant 1 idle at the full window, keep tenant 0 streaming
    for t in range(4):
        batch.update(jnp.asarray(rng.normal(size=(B, d))),
                     active=jnp.asarray([True, False]))
    ms = [int(v) for v in np.asarray(batch.states.m)]
    assert ms == [8, 8]
    assert bool(jnp.isfinite(batch.states.L).all())


# --------------------------------------------------------- monitor fix ---
def test_monitor_history_evolves_past_capacity():
    """Regression (ISSUE satellite): the pre-window monitor silently
    dropped every block once room == 0 — history froze at capacity.  The
    windowed monitor keeps ingesting and its stats track drift forever."""
    from repro.spectral import SpectralMonitor

    rng = np.random.default_rng(7)
    mon = SpectralMonitor(capacity=24, dtype=jnp.float64)
    mon.observe(rng.normal(size=(24, 6)))           # fills to capacity
    assert mon.stats()["m"] == 24
    frozen = mon.eigenvalues()
    # drifted distribution: later blocks look nothing like the first
    mon.observe(5.0 + 0.1 * rng.normal(size=(16, 6)))
    moved = mon.eigenvalues()
    assert mon.stats()["m"] == 24                   # still bounded
    assert mon.stats()["seen"] == 40                # ...but still ingesting
    assert np.abs(moved - frozen).max() > 1e-3      # history evolving
    assert len(mon.history) == 2
    # and the tracked spectrum is batch KPCA of the trailing 24 (the
    # near-duplicate drifted block clusters the spectrum, so this runs in
    # the dlaed2-trade regime — rounding-level exactness is not expected)
    st = mon._stream.kpca_state
    lam_ref = np.sort(np.linalg.eigvalsh(_batch_eff_spec(
        np.asarray(st.X[:24]), mon._stream.spec)))[::-1]
    np.testing.assert_allclose(np.sort(np.asarray(st.L[:24]))[::-1],
                               lam_ref, atol=2e-3)


def _batch_eff_spec(X, spec):
    K = kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec)
    return np.asarray(kf.center_gram(K))


def test_rebase_ages_preserves_eviction_order():
    """Near-sentinel clocks rebase instead of colliding with the
    sentinel (without x64 the ring is int32 — a forever stream would
    otherwise break at ~10⁹ points)."""
    rng = np.random.default_rng(25)
    stream = inkpca.KPCAStream(jnp.asarray(rng.normal(size=(4, 3))), 8,
                               SPEC, adjusted=False, dtype=jnp.float64,
                               window=6)
    for _ in range(8):
        stream.update(jnp.asarray(rng.normal(size=3)))
    st = stream.state
    sent = wnd.age_sentinel(st.ages.dtype)
    # fast-forward the clock to the sentinel boundary, keeping offsets
    shift = (sent - 1) - int(st.clock)
    aged = st._replace(ages=jnp.where(st.ages == sent, sent,
                                      st.ages + shift),
                       clock=st.clock + shift)
    order_before = np.argsort(np.asarray(aged.ages[:6]))
    stream.state = aged
    stream.update(jnp.asarray(rng.normal(size=3)))      # triggers rebase
    st2 = stream.state
    assert int(st2.clock) < sent // 2                   # rebased
    assert int(st2.kpca.m) == 6
    # relative eviction order of the survivors is unchanged
    order_after = np.argsort(np.asarray(st2.ages[:5]))
    np.testing.assert_array_equal(order_before[1:6][np.argsort(
        order_before[1:6])], np.arange(1, 6))
    assert bool(jnp.isfinite(st2.kpca.L).all())
    # and further streaming keeps matching the trailing batch window
    for _ in range(3):
        stream.update(jnp.asarray(rng.normal(size=3)))
    st3 = stream.kpca_state
    Keff = _batch_eff(np.asarray(st3.X[:6]), False)
    rec = np.asarray(rankone.reconstruct(st3.L, st3.U, st3.m))[:6, :6]
    np.testing.assert_allclose(rec, Keff, atol=1e-9)


def test_monitor_explicit_window_below_capacity():
    from repro.spectral import SpectralMonitor

    rng = np.random.default_rng(9)
    mon = SpectralMonitor(capacity=32, window=12, dtype=jnp.float64)
    mon.observe(rng.normal(size=(30, 5)))
    assert mon.stats()["m"] == 12
    assert mon.stats()["seen"] == 30


# ----------------------------------------------- steady-state scan ------
@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("dispatch", ["fixed", "bucketed"])
def test_window_block_matches_pointwise_every_step(adjusted, dispatch):
    """update_block on a windowed stream (ONE scanned dispatch at steady
    state) must equal the per-point windowed loop at EVERY step — cuts
    cover pure growth, the growth→steady transition inside a block, and
    pure steady state (ISSUE acceptance, f64 ≤ 1e-10)."""
    rng = np.random.default_rng(61)
    X = rng.normal(size=(40, 4))
    W = 8

    def mk():
        return inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC,
                                 adjusted=adjusted, dtype=jnp.float64,
                                 dispatch=dispatch, min_bucket=8, window=W)

    ref, blk = mk(), mk()
    i = 4
    for cut in (7, 13, 25, 40):     # growth-only, transition, steady, steady
        for t in range(i, cut):
            ref.update(jnp.asarray(X[t]))
        blk.partial_fit_block(jnp.asarray(X[i:cut]))
        i = cut
        a, b = ref.state, blk.state
        assert int(a.kpca.m) == int(b.kpca.m)
        np.testing.assert_allclose(np.asarray(b.kpca.L),
                                   np.asarray(a.kpca.L), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(b.kpca.L, b.kpca.U, b.kpca.m)),
            np.asarray(rankone.reconstruct(a.kpca.L, a.kpca.U, a.kpca.m)),
            atol=1e-10)
        np.testing.assert_array_equal(np.asarray(b.ages), np.asarray(a.ages))
        assert int(b.clock) == int(a.clock)
        np.testing.assert_allclose(np.asarray(b.kpca.X),
                                   np.asarray(a.kpca.X), atol=1e-12)


def test_window_block_single_dispatch_at_steady_state(monkeypatch):
    """A steady-state block must fold through exactly ONE scanned-chunk
    dispatch — no per-point host-side evict decision, no per-point
    rebase read (the zero-host-syncs-in-block acceptance)."""
    rng = np.random.default_rng(67)
    X = rng.normal(size=(30, 3))
    stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=True,
                               dtype=jnp.float64, dispatch="bucketed",
                               min_bucket=8, window=8)
    stream.update_block(jnp.asarray(X[4:12]))       # fill the window
    assert int(stream.kpca_state.m) == 8
    calls = {"scan": 0, "ingest": 0}
    real_chunk = eng._window_scan_chunk

    def counting_chunk(*a, **k):
        calls["scan"] += 1
        return real_chunk(*a, **k)

    real_ingest = wnd.ingest

    def counting_ingest(*a, **k):
        calls["ingest"] += 1
        return real_ingest(*a, **k)

    monkeypatch.setattr(eng, "_window_scan_chunk", counting_chunk)
    monkeypatch.setattr(wnd, "ingest", counting_ingest)
    stream.update_block(jnp.asarray(X[12:30]))      # 18 steady-state steps
    assert calls["scan"] == 1
    assert calls["ingest"] == 0
    assert int(stream.kpca_state.m) == 8


def test_engine_window_step_matches_ingest():
    """The fused single-step spelling equals window.ingest at steady
    state (and append-only below the window)."""
    rng = np.random.default_rng(71)
    X = rng.normal(size=(20, 3))
    engine = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8), adjusted=True)
    ws_a = wnd.init_window(jnp.asarray(X[:4]), 16, SPEC, adjusted=True,
                           dtype=jnp.float64)
    ws_b = ws_a
    for t in range(4, 20):
        ws_a = wnd.ingest(engine, ws_a, jnp.asarray(X[t]), window=6)
        ws_b = engine.window_step(ws_b, jnp.asarray(X[t]), window=6)
        np.testing.assert_allclose(np.asarray(ws_b.kpca.L),
                                   np.asarray(ws_a.kpca.L), atol=1e-10)
        np.testing.assert_array_equal(np.asarray(ws_b.ages),
                                      np.asarray(ws_a.ages))
        assert int(ws_b.clock) == int(ws_a.clock)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(ws_b.kpca.L, ws_b.kpca.U,
                                       ws_b.kpca.m)),
        np.asarray(rankone.reconstruct(ws_a.kpca.L, ws_a.kpca.U,
                                       ws_a.kpca.m)), atol=1e-10)


@pytest.mark.parametrize("cohorts", ["max", "bucket", "bucket-padded"])
def test_streambatch_window_block_matches_per_tenant_loop(cohorts):
    """Windowed StreamBatch.update_block (per-cohort steady-state scan)
    == B independent per-point windowed streams, for every cohort
    geometry (ISSUE acceptance)."""
    rng = np.random.default_rng(73)
    B, d, W = 3, 4, 6
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    xs = jnp.asarray(rng.normal(size=(14, B, d)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
    batch = eng.StreamBatch(x0, 16, SPEC, plan=plan, adjusted=True,
                            dtype=jnp.float64, window=W, cohorts=cohorts)
    batch.update_block(xs)
    streams = [inkpca.KPCAStream(x0[i], 16, SPEC, adjusted=True,
                                 dtype=jnp.float64, plan=plan, window=W)
               for i in range(B)]
    for t in range(14):
        for i, s in enumerate(streams):
            s.update(xs[t, i])
    sts = batch.states
    for i, s in enumerate(streams):
        ref = s.kpca_state
        m = int(ref.m)
        assert int(sts.m[i]) == m == W
        np.testing.assert_allclose(np.asarray(sts.L[i][:m]),
                                   np.asarray(ref.L[:m]), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(sts.L[i], sts.U[i], sts.m[i])),
            np.asarray(rankone.reconstruct(ref.L, ref.U, ref.m)),
            atol=1e-10)


def test_streambatch_window_block_then_update_consistent():
    """Interleaving block and per-point windowed updates must keep host
    bookkeeping (m_host/ceiling) and device state in lockstep."""
    rng = np.random.default_rng(79)
    B, d, W = 2, 3, 6
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    batch = eng.StreamBatch(x0, 8, SPEC, adjusted=False, dtype=jnp.float64,
                            window=W)
    batch.update_block(jnp.asarray(rng.normal(size=(5, B, d))))
    batch.update(jnp.asarray(rng.normal(size=(B, d))))
    batch.update_block(jnp.asarray(rng.normal(size=(4, B, d))))
    sts = batch.states
    assert [int(v) for v in np.asarray(sts.m)] == [W, W]
    assert bool(jnp.isfinite(sts.L).all())


def test_window_block_hoisted_rebase_preserves_order():
    """A block whose clock span crosses the sentinel threshold rebases
    ONCE up front and keeps matching the trailing batch window."""
    rng = np.random.default_rng(83)
    stream = inkpca.KPCAStream(jnp.asarray(rng.normal(size=(4, 3))), 8,
                               SPEC, adjusted=False, dtype=jnp.float64,
                               window=6)
    for _ in range(8):
        stream.update(jnp.asarray(rng.normal(size=3)))
    st = stream.state
    sent = wnd.age_sentinel(st.ages.dtype)
    shift = (sent - 4) - int(st.clock)       # block of 8 crosses sent-1
    stream.state = st._replace(ages=jnp.where(st.ages == sent, sent,
                                              st.ages + shift),
                               clock=st.clock + shift)
    stream.update_block(jnp.asarray(rng.normal(size=(8, 3))))
    st2 = stream.state
    assert int(st2.clock) < sent // 2        # rebased once, up front
    Keff = _batch_eff(np.asarray(st2.kpca.X[:6]), False)
    rec = np.asarray(rankone.reconstruct(st2.kpca.L, st2.kpca.U,
                                         st2.kpca.m))[:6, :6]
    np.testing.assert_allclose(rec, Keff, atol=1e-9)
