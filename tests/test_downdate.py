"""Decremental updates (core/downdate.py): the inverse ±sigma pair +
contraction must exactly undo Algorithms 1/2, preserve every padding
invariant, and re-bucket downward under bucketed dispatch."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import engine as eng, inkpca, kernels_fn as kf, rankone

RNG = np.random.default_rng(11)
SPEC = kf.KernelSpec(name="rbf", sigma=5.0)


def _grow(adjusted, plan, n=11, capacity=16, d=4, seed_rng=None):
    rng = seed_rng if seed_rng is not None else RNG
    X = rng.normal(size=(n, d))
    engine = eng.Engine(SPEC, plan, adjusted=adjusted)
    st = inkpca.init_state(jnp.asarray(X[:4]), capacity, SPEC,
                          adjusted=adjusted, dtype=jnp.float64)
    for i in range(4, n):
        st = engine.update(st, jnp.asarray(X[i]))
    return engine, st, X


PLANS = [
    eng.UpdatePlan(),
    eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
    eng.UpdatePlan(matmul="jnp2"),
    eng.UpdatePlan(dispatch="bucketed", min_bucket=8, matmul="jnp2"),
]


@pytest.mark.parametrize("adjusted", [False, True])
@pytest.mark.parametrize("plan", PLANS, ids=lambda p: f"{p.dispatch}-{p.matmul}")
def test_downdate_update_roundtrip(adjusted, plan):
    """downdate(update(state, x), last) == state to <= 1e-10 in f64, for
    both Algorithms and both dispatch modes (ISSUE acceptance bound)."""
    engine, st, X = _grow(adjusted, plan)
    x_new = jnp.asarray(RNG.normal(size=4))
    st1 = engine.update(st, x_new)
    st2 = engine.downdate(st1, int(st1.m) - 1)
    m = int(st.m)
    assert int(st2.m) == m
    np.testing.assert_allclose(np.asarray(st2.L[:m]), np.asarray(st.L[:m]),
                               atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(st2.L, st2.U, st2.m)),
        np.asarray(rankone.reconstruct(st.L, st.U, st.m)), atol=1e-10)
    np.testing.assert_allclose(np.asarray(st2.K1), np.asarray(st.K1),
                               atol=1e-10)
    np.testing.assert_allclose(float(st2.S), float(st.S), atol=1e-9)
    np.testing.assert_allclose(np.asarray(st2.X), np.asarray(st.X),
                               atol=1e-12)


@pytest.mark.parametrize("adjusted", [False, True])
def test_downdate_interior_matches_batch(adjusted):
    """Removing an interior point must leave exactly the batch (centered)
    gram eigensystem of the surviving points."""
    engine, st, X = _grow(adjusted, eng.UpdatePlan())
    st2 = engine.downdate(st, 2)
    keep = [i for i in range(11) if i != 2]
    Xk = jnp.asarray(X[keep])
    K = kf.gram_block(Xk, Xk, spec=SPEC)
    Keff = np.asarray(kf.center_gram(K)) if adjusted else np.asarray(K)
    m = int(st2.m)
    rec = np.asarray(rankone.reconstruct(st2.L, st2.U, st2.m))[:m, :m]
    np.testing.assert_allclose(rec, Keff, atol=1e-10)
    # survivors keep their arrival order
    np.testing.assert_allclose(np.asarray(st2.X[:m]), np.asarray(Xk),
                               atol=0)


def test_downdate_preserves_padding_invariants():
    """Post-downdate state must satisfy every invariant the kernels'
    active-tile pruning assumes: inactive columns exactly identity,
    active columns zero on rows >= m, L sentinels ascending on top,
    U orthogonal."""
    engine, st, _ = _grow(True, eng.UpdatePlan(dispatch="bucketed",
                                               min_bucket=8))
    st2 = engine.downdate(st, 4)
    M = st2.L.shape[0]
    m = int(st2.m)
    U = np.asarray(st2.U)
    np.testing.assert_array_equal(U[:, m:], np.eye(M)[:, m:])
    assert np.abs(U[m:, :m]).max() == 0.0
    L = np.asarray(st2.L)
    assert (np.diff(L) > 0).all() or (np.sort(L[:m]) <= L[m:].min()).all()
    assert L[m:].min() > L[:m].max()
    np.testing.assert_allclose(U @ U.T, np.eye(M), atol=1e-12)


def test_downdate_rebuckets_downward_and_keeps_streaming():
    """Bucketed dispatch: downdating across a bucket rung must re-bucket
    the NEXT step downward (cost scales with the shrunk m) and keep
    producing states identical to the fixed-dispatch path."""
    rng = np.random.default_rng(23)
    X = rng.normal(size=(20, 4))
    buk = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
                     adjusted=True)
    fix = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=True)
    sb = inkpca.init_state(jnp.asarray(X[:4]), 32, SPEC, adjusted=True,
                           dtype=jnp.float64)
    sf = sb
    for i in range(4, 10):        # m=10: inside bucket 16
        sb = buk.update(sb, jnp.asarray(X[i]))
        sf = fix.update(sf, jnp.asarray(X[i]))
    for _ in range(3):            # back below the 8-rung: m=7
        sb = buk.downdate(sb, 0)
        sf = fix.downdate(sf, 0)
    assert eng.bucket_for(int(sb.m) + 1, 32, 8) == 8   # re-buckets at 8
    for i in range(10, 20):       # stream on, crossing 8 -> 16 again
        sb = buk.update(sb, jnp.asarray(X[i]))
        sf = fix.update(sf, jnp.asarray(X[i]))
    m = int(sb.m)
    assert m == int(sf.m) == 17
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(sb.L, sb.U, sb.m)),
        np.asarray(rankone.reconstruct(sf.L, sf.U, sf.m)), atol=1e-9)


def test_engine_replace_swaps_point():
    """replace(i, x) must equal the batch eigensystem of the point set
    with X[i] swapped for x — on a FULL state (downdate frees the slot)."""
    rng = np.random.default_rng(29)
    X = rng.normal(size=(8, 3))
    engine = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=True)
    st = inkpca.init_state(jnp.asarray(X[:4]), 8, SPEC, adjusted=True,
                          dtype=jnp.float64)
    for i in range(4, 8):
        st = engine.update(st, jnp.asarray(X[i]))
    assert int(st.m) == 8         # full: plain update would raise
    x_new = jnp.asarray(rng.normal(size=3))
    st2 = engine.replace(st, 3, x_new)
    Xk = np.concatenate([X[[0, 1, 2, 4, 5, 6, 7]], np.asarray(x_new)[None]])
    Keff = np.asarray(kf.center_gram(kf.gram_block(jnp.asarray(Xk),
                                                   jnp.asarray(Xk),
                                                   spec=SPEC)))
    rec = np.asarray(rankone.reconstruct(st2.L, st2.U, st2.m))
    np.testing.assert_allclose(rec, Keff, atol=1e-10)


def test_downdate_validation():
    engine, st, _ = _grow(False, eng.UpdatePlan())
    with pytest.raises(ValueError):
        engine.downdate(st, int(st.m))          # out of active range
    with pytest.raises(ValueError):
        engine.downdate(st, -1)
    small = inkpca.init_state(jnp.asarray(RNG.normal(size=(1, 4))), 8, SPEC,
                              adjusted=False, dtype=jnp.float64)
    with pytest.raises(ValueError):
        engine.downdate(small, 0)               # m < 2


def test_batched_downdate_masked_matches_loop():
    """The vmapped masked downdate (StreamBatch's eviction step) must
    equal per-tenant engine downdates, with inactive tenants bitwise
    untouched."""
    rng = np.random.default_rng(31)
    B, d = 3, 4
    engine = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=True)
    states, X0 = [], rng.normal(size=(B, 9, d))
    for b in range(B):
        st = inkpca.init_state(jnp.asarray(X0[b, :4]), 16, SPEC,
                               adjusted=True, dtype=jnp.float64)
        for i in range(4, 9):
            st = engine.update(st, jnp.asarray(X0[b, i]))
        states.append(st)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *states)
    rows = jnp.asarray([0, 2, 1], jnp.int32)
    active = jnp.asarray([True, False, True])
    out = eng._batched_downdate_masked(stacked, rows, active, SPEC, True,
                                       eng.UpdatePlan())
    for b in range(B):
        got = jax.tree.map(lambda leaf: leaf[b], out)
        if bool(active[b]):
            ref = engine.downdate(states[b], int(rows[b]))
            np.testing.assert_allclose(np.asarray(got.L), np.asarray(ref.L),
                                       atol=1e-12)
            np.testing.assert_allclose(
                np.asarray(rankone.reconstruct(got.L, got.U, got.m)),
                np.asarray(rankone.reconstruct(ref.L, ref.U, ref.m)),
                atol=1e-11)
        else:
            for a, r in zip(jax.tree.leaves(got),
                            jax.tree.leaves(states[b])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


# ------------------------------------------------------- sharded downdate ---
def _sharded_setup():
    rng = np.random.default_rng(37)
    X = rng.normal(size=(11, 4))
    engine = eng.Engine(SPEC, eng.UpdatePlan(), adjusted=False)
    st = inkpca.init_state(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64)
    for i in range(4, 11):
        st = engine.update(st, jnp.asarray(X[i]))
    return engine, st


@pytest.mark.parametrize("plan", [
    eng.UpdatePlan(),
    eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
    eng.UpdatePlan(matmul="jnp2", merge_fallback=True),
], ids=lambda p: f"{p.dispatch}-{p.matmul}")
def test_sharded_downdate_matches_local(plan):
    """make_sharded_downdate == Engine.downdate of the boundary point,
    across dispatch modes and the fused pair with merge fallback."""
    from repro.core import distributed as dkpca

    engine, st = _sharded_setup()
    mesh = jax.make_mesh((1,), ("data",))
    ddown = dkpca.make_sharded_downdate(mesh, plan=plan)
    q = int(st.m) - 1
    a = kf.kernel_row(st.X[q], st.X, spec=SPEC)
    a = jnp.where(rankone.active_mask(16, st.m), a, 0.0)
    Ls, Us, ms = ddown(st.L, st.U, a, a[q], st.m)
    ref = engine.downdate(st, q)
    assert int(ms) == int(ref.m)
    np.testing.assert_allclose(np.asarray(Ls[:int(ms)]),
                               np.asarray(ref.L[:int(ms)]), atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(Ls, Us, ms)),
        np.asarray(rankone.reconstruct(ref.L, ref.U, ref.m)), atol=1e-10)


def test_sharded_downdate_then_update_roundtrip():
    """A sharded update followed by a sharded downdate of the same point
    returns the original sharded (L, U) — the distributed path has the
    same sign-symmetry as the local one."""
    from repro.core import distributed as dkpca

    engine, st = _sharded_setup()
    mesh = jax.make_mesh((1,), ("data",))
    plan = eng.UpdatePlan()
    x_new = jnp.asarray(np.random.default_rng(41).normal(size=4))
    st1 = engine.update(st, x_new)
    ddown = dkpca.make_sharded_downdate(mesh, plan=plan)
    q = int(st1.m) - 1
    a = kf.kernel_row(st1.X[q], st1.X, spec=SPEC)
    a = jnp.where(rankone.active_mask(16, st1.m), a, 0.0)
    Ls, Us, ms = ddown(st1.L, st1.U, a, a[q], st1.m)
    np.testing.assert_allclose(np.asarray(Ls[:int(ms)]),
                               np.asarray(st.L[:int(ms)]), atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(Ls, Us, ms)),
        np.asarray(rankone.reconstruct(st.L, st.U, st.m)), atol=1e-10)


@pytest.mark.parametrize("plan", [
    eng.UpdatePlan(),
    eng.UpdatePlan(dispatch="bucketed", min_bucket=8),
    eng.UpdatePlan(matmul="jnp2", merge_fallback=True),
], ids=lambda p: f"{p.dispatch}-{p.matmul}")
def test_sharded_evict_arbitrary_row_matches_local(plan):
    """make_sharded_evict (in-graph boundary permutation: ppermute + one
    psum gather along the replicated axis) == Engine.downdate of the SAME
    arbitrary row — no host round-trip decides the victim (the ROADMAP
    sharded-boundary-permutation follow-up)."""
    from repro.core import distributed as dkpca

    engine, st = _sharded_setup()
    mesh = jax.make_mesh((1,), ("data",))
    ev = dkpca.make_sharded_evict(mesh, plan=plan)
    for victim in (0, 3, int(st.m) - 1):
        a = kf.kernel_row(st.X[victim], st.X, spec=SPEC)
        a = jnp.where(rankone.active_mask(16, st.m), a, 0.0)
        Ls, Us, ms = ev(st.L, st.U, a, a[victim], jnp.int32(victim), st.m)
        ref = engine.downdate(st, victim)
        assert int(ms) == int(ref.m)
        np.testing.assert_allclose(np.asarray(Ls[:int(ms)]),
                                   np.asarray(ref.L[:int(ms)]), atol=1e-10)
        np.testing.assert_allclose(
            np.asarray(rankone.reconstruct(Ls, Us, ms)),
            np.asarray(rankone.reconstruct(ref.L, ref.U, ref.m)),
            atol=1e-10)


@pytest.mark.parametrize("dispatch", ["fixed", "bucketed"])
def test_sharded_window_block_matches_local_windowed_stream(dispatch):
    """make_sharded_window_block (scan of in-graph evict+ingest steps,
    victim from the replicated arrival ring) == the local windowed
    stream, state and ring both."""
    from repro.core import distributed as dkpca

    rng = np.random.default_rng(43)
    X = rng.normal(size=(12, 4))
    W = 8
    plan = (eng.UpdatePlan(dispatch="bucketed", min_bucket=8)
            if dispatch == "bucketed" else eng.UpdatePlan())
    stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC,
                               adjusted=False, dtype=jnp.float64,
                               plan=plan, window=W)
    for i in range(4, 12):                       # window exactly full
        stream.update(jnp.asarray(X[i]))
    ws = stream.state
    assert int(ws.kpca.m) == W
    xs = jnp.asarray(rng.normal(size=(5, 4)))
    mesh = jax.make_mesh((1,), ("data",))
    wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=plan)
    L2, U2, X2, ages2, clock2 = wb(ws.kpca.L, ws.kpca.U, ws.kpca.X,
                                   ws.ages, ws.clock, xs, ws.kpca.m)
    for t in range(5):
        stream.update(xs[t])
    ref = stream.state
    np.testing.assert_allclose(np.asarray(L2[:W]),
                               np.asarray(ref.kpca.L[:W]), atol=1e-10)
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(L2, U2, jnp.int32(W))),
        np.asarray(rankone.reconstruct(ref.kpca.L, ref.kpca.U,
                                       ref.kpca.m)), atol=1e-10)
    np.testing.assert_allclose(np.asarray(X2), np.asarray(ref.kpca.X),
                               atol=1e-12)
    np.testing.assert_array_equal(np.asarray(ages2), np.asarray(ref.ages))
    assert int(clock2) == int(ref.clock)


def test_sharded_window_block_rebases_near_sentinel():
    """A sharded window block whose clock span would reach the age
    sentinel must rebase the ring at block entry (traced, like the
    local hoisted check) and keep evicting in true FIFO order."""
    from repro.core import distributed as dkpca
    from repro.core import window as wnd

    rng = np.random.default_rng(47)
    X = rng.normal(size=(12, 4))
    W = 8
    stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC,
                               adjusted=False, dtype=jnp.float64, window=W)
    for i in range(4, 12):
        stream.update(jnp.asarray(X[i]))
    ws = stream.state
    sent = wnd.age_sentinel(ws.ages.dtype)
    shift = (sent - 3) - int(ws.clock)         # block of 5 would collide
    aged = ws._replace(ages=jnp.where(ws.ages == sent, sent,
                                      ws.ages + shift),
                       clock=ws.clock + shift)
    xs = jnp.asarray(rng.normal(size=(5, 4)))
    mesh = jax.make_mesh((1,), ("data",))
    wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=eng.UpdatePlan())
    L2, U2, X2, ages2, clock2 = wb(aged.kpca.L, aged.kpca.U, aged.kpca.X,
                                   aged.ages, aged.clock, xs, aged.kpca.m)
    assert int(clock2) < sent // 2             # rebased at block entry
    # eigensystem still matches the local windowed stream (rebasing never
    # touches the kpca state), and the FIFO order survives
    stream.state = aged
    for t in range(5):
        stream.update(xs[t])
    ref = stream.state
    np.testing.assert_allclose(
        np.asarray(rankone.reconstruct(L2, U2, jnp.int32(W))),
        np.asarray(rankone.reconstruct(ref.kpca.L, ref.kpca.U,
                                       ref.kpca.m)), atol=1e-10)
    np.testing.assert_array_equal(np.argsort(np.asarray(ages2[:W])),
                                  np.argsort(np.asarray(ref.ages[:W])))
