"""Golden parity for the composed stream-step pipeline (``Engine.step``).

The variant-matrix collapse holds only if every pre-collapse spelling is
a pure re-spelling: the 2×2×2 (window × health × metrics) combinations
must produce BITWISE-identical bundles whether driven through the legacy
``Engine`` methods or directly through ``step``/``step_block``, the
steady-state window block must still compile to ONE scanned dispatch
(zero added dispatches from the composition), and the fully-composed
(guarded + metered + windowed) P=2 sharded block must agree with the
single-device composed pipeline.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import engine as eng
from repro.core import health as hl
from repro.core import inkpca
from repro.core import kernels_fn as kf
from repro.core import telemetry as tm
from repro.core import window as wnd

SPEC = kf.KernelSpec(name="rbf", sigma=3.0)
W = 8
COMBOS = [(window, health, metrics)
          for window in (None, W)
          for health in (False, True)
          for metrics in (False, True)]


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        bool(jnp.array_equal(x, y, equal_nan=True)) for x, y in zip(la, lb))


def _setup(window, health, metrics):
    """Engine + initial (legacy-track pieces, bundle) for one combo."""
    rng = np.random.default_rng(13)
    X = jnp.asarray(rng.normal(size=(24, 4)))
    plan = eng.UpdatePlan(dispatch="bucketed", min_bucket=8,
                          health=hl.DEFAULT_POLICY if health else None,
                          window=window, metrics=metrics)
    engine = eng.Engine(SPEC, plan, adjusted=True)
    if window is not None:
        state = wnd.init_window(X[:4], 16, SPEC, adjusted=True,
                                dtype=jnp.float64)
    else:
        # append-only: room for the 4 seeds plus all 14 offered points
        state = inkpca.init_state(X[:4], 32, SPEC, adjusted=True,
                                  dtype=jnp.float64)
    h = hl.init_health(jnp.float64) if health else None
    ms = tm.init_metrics(jnp.float64) if metrics else None
    xs = np.asarray(rng.normal(size=(14, 4)))
    if health:
        xs[3] = np.nan          # growth-phase reject
        xs[9] = np.nan          # steady-state reject (window combos)
    return engine, state, h, ms, jnp.asarray(xs)


@pytest.mark.parametrize("window,health,metrics", COMBOS)
def test_step_parity_with_legacy_point_spellings(window, health, metrics):
    """Point-wise: each legacy spelling and the composed ``step`` advance
    bitwise-identical bundles at EVERY offered point (growth, the
    growth→steady transition, steady state, rejections)."""
    engine, state, h, ms, xs = _setup(window, health, metrics)
    stream = eng.make_stream(state, health=h, metrics=ms)
    for t in range(xs.shape[0]):
        x = xs[t]
        # legacy track
        if window is None:
            if health and metrics:
                state, h, ms = engine.update_guarded_metered(state, h, ms, x)
            elif health:
                state, h = engine.update_guarded(state, h, x)
            elif metrics:
                state, ms = engine.update_metered(state, ms, x)
            else:
                state = engine.update(state, x)
        else:
            if health and metrics:
                state, h, ms = engine.window_ingest_guarded_metered(
                    state, h, ms, x, window=W)
            elif health:
                state, h = engine.window_ingest_guarded(state, h, x,
                                                        window=W)
            elif metrics:
                # pre-collapse KPCAStream spelling: unguarded ingest +
                # clock-delta note
                m0, c0 = state.kpca.m, state.clock
                state = wnd.ingest(engine, state, x, window=W)
                ms = tm.note_block(ms, m0, state.kpca.m, 1,
                                   state.clock - c0, None, window=W)
            else:
                state = wnd.ingest(engine, state, x, window=W)
        # composed track
        stream = engine.step(stream, x, window=window)
        assert _leaves_equal(stream, eng.make_stream(state, health=h,
                                                     metrics=ms))


@pytest.mark.parametrize("window,health,metrics", COMBOS)
def test_step_block_parity_with_legacy_block_spellings(window, health,
                                                       metrics):
    """Block-wise: legacy block spellings and ``step_block`` agree
    bitwise across a growth→steady block and a pure steady block."""
    engine, state, h, ms, xs = _setup(window, health, metrics)
    stream = eng.make_stream(state, health=h, metrics=ms)
    for lo, hi in ((0, 9), (9, 14)):    # transition block, steady block
        blk = xs[lo:hi]
        if window is None:
            if health and metrics:
                state, h, ms = engine.update_block_guarded_metered(
                    state, h, ms, blk)
            elif health:
                state, h = engine.update_block_guarded(state, h, blk)
            elif metrics:
                state, ms = engine.update_block_metered(state, ms, blk)
            else:
                state = engine.update_block(state, blk)
        else:
            if health and metrics:
                state, h, ms = engine.window_block_guarded_metered(
                    state, h, ms, blk, window=W)
            elif health:
                state, h = engine.window_block_guarded(state, h, blk,
                                                       window=W)
            elif metrics:
                state, ms = engine.window_block_metered(state, ms, blk,
                                                        window=W)
            else:
                state = engine.window_block(state, blk, window=W)
        stream = engine.step_block(stream, blk, window=window)
        assert _leaves_equal(stream, eng.make_stream(state, health=h,
                                                     metrics=ms))


def test_bundle_treestructure_is_plan_static():
    """Absent members stay ``None`` leaves through the pipeline, so the
    bundle's treedef — and with it every jit cache key — is a pure
    function of the plan, never of stream history."""
    for window, health, metrics in COMBOS:
        engine, state, h, ms, xs = _setup(window, health, metrics)
        s0 = eng.make_stream(state, health=h, metrics=ms)
        s1 = engine.step(s0, xs[0], window=window)
        s2 = engine.step_block(s1, xs[1:5], window=window)
        assert jax.tree.structure(s0) == jax.tree.structure(s1) \
            == jax.tree.structure(s2)
        assert s2.windowed == (window is not None)
        assert (s2.health is None) == (not health)
        assert (s2.metrics is None) == (not metrics)


def test_step_block_single_dispatch_at_steady_state(monkeypatch):
    """The composed pipeline adds ZERO dispatches to the steady-state
    window scan: one ``_window_scan_chunk`` call per block (unguarded
    bundle), one ``_guarded_window_chunk_impl`` per block (guarded
    bundle), no point-path fallbacks, plus one note dispatch when the
    bundle is metered."""
    rng = np.random.default_rng(23)
    X = jnp.asarray(rng.normal(size=(30, 3)))
    engine = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                             min_bucket=8, window=W,
                                             health=hl.DEFAULT_POLICY),
                        adjusted=True)
    ws = wnd.init_window(X[:4], 16, SPEC, adjusted=True, dtype=jnp.float64)
    stream = eng.make_stream(ws, health=hl.init_health(jnp.float64),
                             metrics=tm.init_metrics(jnp.float64))
    stream = engine.step_block(stream, X[4:12])      # fill the window
    assert int(stream.kpca.m) == W
    calls = {"scan": 0, "guarded_scan": 0, "point": 0, "note": 0}
    real_scan = eng._window_scan_chunk
    real_guarded = hl._guarded_window_chunk_impl
    real_point = engine._window_point
    real_note = tm.note_block

    def count(key, fn):
        def wrapper(*a, **k):
            calls[key] += 1
            return fn(*a, **k)
        return wrapper

    monkeypatch.setattr(eng, "_window_scan_chunk", count("scan", real_scan))
    monkeypatch.setattr(hl, "_guarded_window_chunk_impl",
                        count("guarded_scan", real_guarded))
    monkeypatch.setattr(engine, "_window_point", count("point", real_point))
    monkeypatch.setattr(tm, "note_block", count("note", real_note))
    stream = engine.step_block(stream, X[12:30])     # 18 steady-state steps
    assert calls == {"scan": 0, "guarded_scan": 1, "point": 0, "note": 1}
    assert int(stream.kpca.m) == W

    # unguarded bundle: the plain scan, once, nothing else
    engine2 = eng.Engine(SPEC, eng.UpdatePlan(dispatch="bucketed",
                                              min_bucket=8, window=W),
                         adjusted=True)
    ws2 = wnd.init_window(X[:4], 16, SPEC, adjusted=True, dtype=jnp.float64)
    s2 = engine2.step_block(eng.make_stream(ws2), X[4:12])
    calls.update(scan=0, guarded_scan=0, point=0, note=0)
    monkeypatch.setattr(engine2, "_window_point",
                        count("point", engine2._window_point))
    engine2.step_block(s2, X[12:30])
    assert calls == {"scan": 1, "guarded_scan": 0, "point": 0, "note": 0}


def test_streambatch_composed_metrics_bitwise():
    """Guarded+metered+windowed StreamBatch lanes are bitwise equal to a
    metrics-off batch — the multi-tenant path rides the same shared
    ``_window_pair`` stage the single-stream scan folds."""
    rng = np.random.default_rng(29)
    B, d = 2, 4
    x0 = jnp.asarray(rng.normal(size=(B, 4, d)))
    steps = [jnp.asarray(rng.normal(size=(B, d))) for _ in range(10)]
    bad = np.array(steps[6])
    bad[0] = np.nan
    steps[6] = jnp.asarray(bad)
    batches = []
    for metrics in (False, True):
        plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY, metrics=metrics,
                              dispatch="bucketed", min_bucket=8)
        b = eng.StreamBatch(x0, 16, SPEC, plan=plan, dtype=jnp.float64,
                            cohorts="bucket", window=W)
        for xs in steps[:6]:
            b.update(xs)
        b.update_block(jnp.stack(steps[6:]))
        batches.append(b)
    off, on = batches
    off._flush(), on._flush()
    assert _leaves_equal(off._full, on._full)
    rep = on.metrics_report()
    np.testing.assert_array_equal(rep["rejections"], [1, 0])
    np.testing.assert_array_equal(rep["ingests"], [9, 10])


@pytest.mark.slow
def test_fully_composed_sharded_block_matches_local_subprocess():
    """P=2: the fully-composed (guarded + metered + windowed) sharded
    block — quarantine gate, FIFO evict, ±sigma pair, note — is bitwise
    equal to the plain sharded builder plus a manual note, and tracks the
    single-device composed ``step_block`` pipeline (same ring/clock/
    counters exactly, eigensystem to collective-reduction tolerance)."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)
from repro.core import distributed as dkpca, engine as eng, health as hl, \
    inkpca, kernels_fn as kf, telemetry as tm, window as wnd
assert jax.device_count() == 2
SPEC = kf.KernelSpec(name="rbf", sigma=3.0)
rng = np.random.default_rng(31)
X = rng.normal(size=(12, 4))
W = 8
stream = inkpca.KPCAStream(jnp.asarray(X[:4]), 16, SPEC, adjusted=False,
                           dtype=jnp.float64, window=W)
for i in range(4, 12):
    stream.update(jnp.asarray(X[i]))
ws = stream.state
xs = np.asarray(rng.normal(size=(6, 4)))
xs[2] = np.nan
xs = jnp.asarray(xs)
mesh = jax.make_mesh((2,), ("data",))
plan = eng.UpdatePlan(health=hl.DEFAULT_POLICY)
wb = dkpca.make_sharded_window_block(mesh, SPEC, plan=plan)
wbm = dkpca.make_sharded_window_block_metered(mesh, SPEC, plan=plan)
args = (ws.kpca.L, ws.kpca.U, ws.kpca.X, ws.ages, ws.clock, xs, ws.kpca.m)
plain = wb(*args)
metered = wbm(*args, tm.init_metrics(jnp.float64))
bitwise = all(bool(jnp.array_equal(a, b)) for a, b in zip(plain, metered[:5]))
rep = tm.metrics_report(metered[5])
# single-device composed pipeline on the same inputs
engine = eng.Engine(SPEC, plan, adjusted=False)
bundle = eng.make_stream(ws, health=hl.init_health(jnp.float64),
                         metrics=tm.init_metrics(jnp.float64))
out = engine.step_block(bundle, xs, window=W)
lrep = tm.metrics_report(out.metrics)
err_L = float(jnp.max(jnp.abs(metered[0][:W] - out.kpca.L[:W])))
ring_equal = bool(jnp.array_equal(metered[3], out.ages)) \
    and int(metered[4]) == int(out.clock)
print("RESULT:" + str({
    "bitwise": bitwise, "ring_equal": ring_equal, "err_L": err_L < 1e-8,
    "ingests": rep["ingests"], "rejections": rep["rejections"],
    "local_ingests": lrep["ingests"], "local_rejections": lrep["rejections"],
    "evictions": rep["evictions"], "fill": rep["window_fill"]}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parent.parent / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT:")][-1]
    res = eval(line[len("RESULT:"):])
    assert res == {"bitwise": True, "ring_equal": True, "err_L": True,
                   "ingests": 5, "rejections": 1, "local_ingests": 5,
                   "local_rejections": 1, "evictions": 5, "fill": 1.0}
