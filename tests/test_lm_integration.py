"""End-to-end integration: short training runs must learn; serving loops
must be self-consistent."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.models.config import ArchConfig
from repro.optim import make_optimizer
from repro.optim.schedules import ScheduleConfig, make_schedule
import pytest

pytestmark = pytest.mark.slow  # model-zoo / driver integration tier


def _train(cfg, opt_name, steps=40, lr=3e-3, accum=1):
    opt = make_optimizer(opt_name)
    sched = make_schedule(ScheduleConfig(kind="cosine", lr=lr, warmup=8,
                                         total=steps))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, sched,
                                                accum=accum))
    stream = TokenStream(vocab=cfg.vocab, seq_len=32, global_batch=4)
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    losses = []
    for t in range(steps):
        state, m = step_fn(state, stream.batch_at(jnp.int32(t)))
        losses.append(float(m["loss"]))
    return losses


def test_training_reduces_loss_adamw():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                     dtype="float32")
    losses = _train(cfg, "adamw")
    assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:3]), losses[:3]


def test_training_reduces_loss_adafactor():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                     dtype="float32")
    losses = _train(cfg, "adafactor", lr=1e-2)
    assert np.mean(losses[-5:]) < 0.9 * np.mean(losses[:3])


def test_grad_accumulation_matches_large_batch():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=32,
                     n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                     dtype="float32")
    opt = make_optimizer("sgdm", momentum=0.0)
    sched = make_schedule(ScheduleConfig(kind="constant", lr=1e-2))
    stream = TokenStream(vocab=64, seq_len=16, global_batch=4)
    batch = stream.batch_at(jnp.int32(0))

    s1 = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    s2 = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    f1 = jax.jit(steps_lib.make_train_step(cfg, opt, sched, accum=1))
    f2 = jax.jit(steps_lib.make_train_step(cfg, opt, sched, accum=2))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    # token-masked mean over microbatches vs full batch: equal token counts
    # per microbatch here, so grads (and the update) must match closely.
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_greedy_decode_consistent_with_forward():
    cfg = ArchConfig(name="t", family="dense", n_layers=2, d_model=64,
                     n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                     dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    serve = steps_lib.make_serve_step(cfg)
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, 128)
    caches = lm.init_caches(params, cfg, B, T + 1)
    for t in range(T):
        pos = jnp.full((B, 1), t, jnp.int32)
        nxt, logits, caches = serve(params, caches, toks[:, t:t+1], pos)
    full = lm.forward(params, cfg, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(nxt[:, 0]),
                                  np.asarray(jnp.argmax(full[:, -1], -1)))
