"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic token stream, with checkpointing and resume — deliverable (b)
end-to-end example.

The default config is a 12-layer, d=512 dense transformer (~100M params
with the 50k vocab). On CPU this takes a few minutes; pass --tiny for a
seconds-scale sanity run.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --tiny --steps 60
"""
import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data.synthetic import TokenStream
from repro.launch import steps as steps_lib
from repro.models.config import ArchConfig
from repro.optim import make_optimizer
from repro.optim.schedules import ScheduleConfig, make_schedule


def make_cfg(tiny: bool) -> ArchConfig:
    if tiny:
        return ArchConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab=512, dtype="float32")
    # ~100M params: 12L d=512 (50k vocab contributes 2×25M)
    return ArchConfig(name="lm100m", family="dense", n_layers=12,
                      d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
                      vocab=50304, tie_embeddings=False, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = make_cfg(args.tiny)
    from repro.models.config import param_count
    print(f"arch={cfg.name}: {param_count(cfg) / 1e6:.1f}M params")

    opt = make_optimizer("adamw")
    sched = make_schedule(ScheduleConfig(kind="cosine", lr=3e-3, warmup=20,
                                         total=args.steps))
    step_fn = jax.jit(steps_lib.make_train_step(cfg, opt, sched),
                      donate_argnums=(0,))
    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)

    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(),
                                             "repro_train_lm")
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), cfg, opt)
    start = 0
    last = latest_step(ckpt_dir)
    if last is not None and last < args.steps:
        state = load_checkpoint(ckpt_dir, last, jax.eval_shape(lambda: state))
        start = last
        print(f"resumed from checkpoint step {last}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        state, metrics = step_fn(state, stream.batch_at(jnp.int32(step)))
        if step % 20 == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            losses.append(loss)
            tps = args.batch * args.seq * (step - start + 1) \
                / max(time.time() - t0, 1e-9)
            print(f"step {step:5d} loss={loss:.4f} "
                  f"acc={float(metrics['accuracy']):.3f} "
                  f"lr={float(metrics['lr']):.2e} tok/s={tps:,.0f}",
                  flush=True)
        if (step + 1) % 100 == 0:
            save_checkpoint(ckpt_dir, step + 1, state)
            print(f"checkpointed step {step + 1} -> {ckpt_dir}")

    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({args.steps} steps, {time.time() - t0:.0f}s)")
    assert losses[-1] < losses[0], "training must reduce the loss"


if __name__ == "__main__":
    main()
