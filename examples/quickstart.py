"""Quickstart: incremental kernel PCA on a data stream (paper Algorithms
1 & 2) and the things you can do with the maintained eigendecomposition.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core import inkpca, kernels_fn as kf, batch          # noqa: E402
from repro.data.uci_like import load_dataset                     # noqa: E402


def main():
    # --- a stream of observations (Yeast-like, standardized) -------------
    X = load_dataset("yeast", n=200)
    sigma = float(kf.median_heuristic(jnp.asarray(X)))
    spec = kf.KernelSpec(name="rbf", sigma=sigma)
    print(f"stream of {len(X)} points, RBF sigma={sigma:.3f} "
          "(median heuristic)")

    # --- seed with 20 points, stream the rest one at a time --------------
    stream = inkpca.KPCAStream(jnp.asarray(X[:20]), capacity=200, spec=spec,
                               adjusted=True, dtype=jnp.float64)
    stream.update_block(jnp.asarray(X[20:]))    # one jit'd scan

    # --- the maintained eigendecomposition is exact ----------------------
    K = kf.gram_block(jnp.asarray(X), jnp.asarray(X), spec=spec)
    lam_ref = np.asarray(batch.batch_kpca(K, adjusted=True)[0])
    lam_inc = np.sort(np.asarray(stream.state.L[:200]))
    print(f"top-5 eigenvalues (incremental): {lam_inc[-5:][::-1].round(3)}")
    print(f"top-5 eigenvalues (batch eigh) : {lam_ref[-5:][::-1].round(3)}")
    print(f"max |difference|: {np.abs(lam_inc - lam_ref).max():.2e}")

    # --- project new points on the kernel principal components -----------
    X_new = load_dataset("yeast", n=210)[200:]
    Z = np.asarray(stream.transform(jnp.asarray(X_new), n_components=3))
    print(f"projected {Z.shape[0]} unseen points onto 3 components:")
    print(Z.round(3))


if __name__ == "__main__":
    main()
